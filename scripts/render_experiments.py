"""Inject rendered dry-run/roofline tables into EXPERIMENTS.md."""
import json
import re
import sys

sys.path.insert(0, "scripts")
from roofline_table import dominant_fraction, fmt_table  # noqa: E402


def main():
    recs = json.load(open("results/dryrun.json"))
    single = [r for r in recs if r["mesh"] == "16x16"]
    multi = [r for r in recs if r["mesh"] == "2x16x16"]

    dry = []
    dry.append("### Single-pod 16×16 (256 chips) — full baseline table\n")
    dry.append(fmt_table(single, "16x16"))
    n_ok = sum(r["status"] == "ok" for r in single)
    n_skip = sum(r["status"] == "skipped" for r in single)
    n_err = sum(r["status"] == "error" for r in single)
    dry.append(f"\n{n_ok} compiled ok, {n_skip} skipped (assignment rules),"
               f" {n_err} errors.\n")
    dry.append("\n### Multi-pod 2×16×16 (512 chips) — pod-axis proof\n")
    if multi:
        dry.append(fmt_table(multi, "2x16x16"))
        n_ok = sum(r["status"] == "ok" for r in multi)
        n_skip = sum(r["status"] == "skipped" for r in multi)
        n_err = sum(r["status"] == "error" for r in multi)
        dry.append(f"\n{n_ok} compiled ok, {n_skip} skipped,"
                   f" {n_err} errors.\n")
    else:
        dry.append("\n(multi-pod sweep pending)\n")
    dry_text = "\n".join(dry)

    roof = []
    roof.append(
        "Terms per §Dry-run methodology; `useful` = MODEL_FLOPS (6·N·D"
        " dense / 6·N_active·D MoE; 2·N·D prefill; 2·N_active·B decode)"
        " / compiled HLO FLOPs — the remat/padding/dispatch-waste"
        " detector. `roofline fraction` = compute term / dominant term"
        " (1.0 = the dominant bottleneck is pure MXU compute).\n")
    oks = [r for r in single if r["status"] == "ok"]
    roof.append("Cells ranked by roofline fraction (hillclimb candidates"
                " at the top):\n")
    roof.append("| fraction | arch × shape | bound | one-line lever |")
    roof.append("|---|---|---|---|")
    LEVERS = {
        "decode": "inherently BW-bound: batch growth / KV quantization",
        "prefill": "flash KV-chunking already applied; next: fused QKV",
        "train": "bf16 grad-sync + AR→RS (TPU backend) + collective overlap",
        "search": "MQO batch growth raises arithmetic intensity linearly",
    }
    for r in sorted(oks, key=dominant_fraction):
        rf = r["roofline"]
        f = dominant_fraction(r)
        roof.append(
            f"| {f:.3f} | {r['arch']} × {r['shape']} |"
            f" {rf['bottleneck']} | {LEVERS.get(r['kind'], '')} |")
    roof_text = "\n".join(roof)

    md = open("EXPERIMENTS.md").read()
    md = re.sub(r"<!-- DRYRUN_TABLES -->.*?(?=\n## )",
                "<!-- DRYRUN_TABLES -->\n" + dry_text + "\n",
                md, flags=re.S) if "<!-- DRYRUN_TABLES -->" in md else md
    md = re.sub(r"<!-- ROOFLINE_SECTION -->.*?(?=\n## )",
                "<!-- ROOFLINE_SECTION -->\n" + roof_text + "\n",
                md, flags=re.S) if "<!-- ROOFLINE_SECTION -->" in md else md
    open("EXPERIMENTS.md", "w").write(md)
    print("EXPERIMENTS.md updated:",
          len(single), "single-pod records,", len(multi), "multi-pod")


if __name__ == "__main__":
    main()
