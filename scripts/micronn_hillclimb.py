"""Hillclimb iterations for the micronn-search cell (paper's technique).

Compiles distributed_search variants on the production mesh and prints the
roofline terms per variant. No writes to dryrun.json.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import argparse

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.types import DeltaStore, IVFConfig, IVFIndex
from repro.distributed.sharded_index import distributed_search, \
    index_shardings
from repro.launch import costs
from repro.launch.mesh import make_production_mesh


def build_index_specs(vec_dtype=jnp.float32):
    dim, k_parts, p_max, dcap, n_attr = 512, 8192, 128, 8192, 0
    cfg = IVFConfig(dim=dim, delta_capacity=dcap)
    sds = lambda s, d=jnp.float32: jax.ShapeDtypeStruct(s, d)
    index = IVFIndex(
        centroids=sds((k_parts, dim)), csizes=sds((k_parts,)),
        vectors=sds((k_parts, p_max, dim), vec_dtype),
        ids=sds((k_parts, p_max), jnp.int32),
        attrs=sds((k_parts, p_max, n_attr), vec_dtype),
        valid=sds((k_parts, p_max), jnp.bool_),
        counts=sds((k_parts,), jnp.int32),
        delta=DeltaStore(
            vectors=sds((dcap, dim), vec_dtype),
            ids=sds((dcap,), jnp.int32),
            attrs=sds((dcap, n_attr), vec_dtype),
            valid=sds((dcap,), jnp.bool_), count=sds((), jnp.int32)),
        base_mean_size=sds(()), config=cfg)
    return index


def probe(name, *, vec_dtype=jnp.float32, local_cap=None,
          merge="tournament", Q=4096, topk=100, n_probe=64):
    mesh = make_production_mesh()
    index = build_index_specs(vec_dtype)
    queries = jax.ShapeDtypeStruct((Q, 512), jnp.float32)
    idx_shard = index_shardings(index, mesh)
    q_shard = NamedSharding(mesh, P("data", None))

    def search_step(index, queries):
        res = distributed_search(index, queries, topk, n_probe, mesh,
                                 data_axes=("data",), local_cap=local_cap,
                                 merge=merge)
        return res.ids, res.scores

    with mesh:
        c = jax.jit(search_step,
                    in_shardings=(idx_shard, q_shard)).lower(
            index, queries).compile()
    t = costs.extract(c)
    mem = costs.memory_dict(c)
    print(f"{name:34s} compute={t.t_compute*1e6:8.1f}us"
          f" memory={t.t_memory*1e6:8.1f}us"
          f" coll={t.t_collective*1e6:8.1f}us"
          f" -> {t.bottleneck:10s} peak={mem['peak_bytes_est']/1e6:.0f}MB")
    return t


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    runs = {
        "baseline(f32,cap=n_probe,tourn)": dict(),
        "i1:bf16-vectors": dict(vec_dtype=jnp.bfloat16),
        "i2:bf16+cap16": dict(vec_dtype=jnp.bfloat16, local_cap=16),
        "i3:bf16+cap16+allgather": dict(vec_dtype=jnp.bfloat16,
                                        local_cap=16, merge="allgather"),
    }
    for name, kw in runs.items():
        if args.only and args.only not in name:
            continue
        probe(name, **kw)
