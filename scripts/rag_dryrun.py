"""Flagship integration cell: RAG-fused decode on the production mesh.

One compiled program = llama3-8b serve_step (32k KV cache, batch 128)
+ distributed MicroNN search over a pod-sharded 1M x 4096d datastore
+ kNN-LM logit interpolation. This is the paper's engine inside the LM
serving path at 256 chips — the retrieval index is the same *updatable*
IVF structure (delta partition scanned every decode step).

    PYTHONPATH=src python scripts/rag_dryrun.py
Appends a `llama3-8b-rag x decode_32k` record to results/dryrun.json.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_arch
from repro.core import topk as topk_lib
from repro.core.rag import RagConfig
from repro.core.types import DeltaStore, IVFConfig, IVFIndex
from repro.distributed.sharded_index import distributed_search, \
    index_shardings
from repro.launch import costs, steps
from repro.launch.mesh import make_production_mesh


def main(out="results/dryrun.json"):
    mesh = make_production_mesh()
    arch = get_arch("llama3-8b")
    cfg = arch.config
    shape = SHAPES["decode_32k"]
    rcfg = RagConfig(k=16, n_probe=32, lam=0.25)

    # datastore: 1M x d_model, partitions sharded over `model`
    dim, k_parts, p_max, dcap = cfg.d_model, 8192, 128, 8192
    sds = lambda s, d=jnp.bfloat16: jax.ShapeDtypeStruct(s, d)
    icfg = IVFConfig(dim=dim, delta_capacity=dcap)
    index = IVFIndex(
        centroids=sds((k_parts, dim), jnp.float32), csizes=sds((k_parts,), jnp.float32),
        vectors=sds((k_parts, p_max, dim)),
        ids=sds((k_parts, p_max), jnp.int32),
        attrs=sds((k_parts, p_max, 0), jnp.float32),
        valid=sds((k_parts, p_max), jnp.bool_),
        counts=sds((k_parts,), jnp.int32),
        delta=DeltaStore(vectors=sds((dcap, dim)),
                         ids=sds((dcap,), jnp.int32),
                         attrs=sds((dcap, 0), jnp.float32),
                         valid=sds((dcap,), jnp.bool_),
                         count=sds((), jnp.int32)),
        base_mean_size=sds((), jnp.float32), config=icfg)
    next_token = sds((k_parts * p_max + 1,), jnp.int32)

    lw = steps.decode_lowerable(arch, shape, mesh)
    params, cache, token, pos = lw.args
    from repro.models import decode as decode_lib

    def rag_serve_step(params, cache, token, pos, index, next_tok):
        logits, hidden, new_cache = decode_lib.decode_step(
            cfg, params, cache, token, pos)
        res = distributed_search(index, hidden.astype(jnp.float32),
                                 rcfg.k, rcfg.n_probe, mesh,
                                 data_axes=("data",), local_cap=16)
        ok = res.ids >= 0
        toks = next_tok[jnp.maximum(res.ids, 0)]
        w = jax.nn.softmax(
            jnp.where(ok, -res.scores * rcfg.temperature, -jnp.inf), -1)
        knn = jnp.zeros(logits.shape, jnp.float32).at[
            jnp.arange(logits.shape[0])[:, None], toks].add(
            jnp.where(ok, w, 0.0))
        knn = jnp.where(ok.any(-1, keepdims=True), knn,
                        1.0 / logits.shape[-1])
        lm_logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        out = jnp.logaddexp(jnp.log1p(-rcfg.lam) + lm_logp,
                            jnp.log(rcfg.lam) +
                            jnp.log(jnp.maximum(knn, 1e-20)))
        return out, new_cache

    idx_shard = index_shardings(index, mesh)
    nt_shard = NamedSharding(mesh, P(None))
    t0 = time.time()
    import repro.models.sharding as shard_lib
    with mesh, shard_lib.activation_sharding(mesh, lw.rules):
        compiled = jax.jit(
            rag_serve_step,
            in_shardings=(*lw.in_shardings, idx_shard, nt_shard),
            donate_argnums=(1,)).lower(
            params, cache, token, pos, index, next_token).compile()
    t1 = time.time()
    terms = costs.extract(compiled)
    mem = costs.memory_dict(compiled)
    rec = {
        "arch": "llama3-8b-rag", "shape": "decode_32k", "mesh": "16x16",
        "n_chips": 256, "kind": "decode", "status": "ok",
        "compile_s": round(t1 - t0, 2), "memory": mem,
        "roofline": terms.as_dict(),
        "hbm_ok": bool(mem["peak_bytes_est"] < 16e9),
        "note": "LM decode + distributed MicroNN retrieval + kNN-LM"
                " interpolation fused in ONE compiled program",
    }
    print(f"[ok] llama3-8b-rag x decode_32k compile={rec['compile_s']}s"
          f" peak={mem['peak_bytes_est']/1e9:.2f}G"
          f" compute={terms.t_compute*1e3:.2f}ms"
          f" memory={terms.t_memory*1e3:.2f}ms"
          f" coll={terms.t_collective*1e3:.2f}ms"
          f" -> {terms.bottleneck}")
    recs = json.load(open(out)) if os.path.exists(out) else []
    recs = [r for r in recs
            if (r["arch"], r["shape"], r["mesh"]) !=
            ("llama3-8b-rag", "decode_32k", "16x16")] + [rec]
    json.dump(recs, open(out, "w"), indent=1)


if __name__ == "__main__":
    main()
