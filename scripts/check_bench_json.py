#!/usr/bin/env python
"""Validate the benchmark trajectory artifacts (BENCH_<name>.json).

scripts/ci.sh points BENCH_JSON_DIR at a scratch directory, runs the
smoke benches (each persists its measurements + acceptance-gate outcomes
via benchmarks.common.write_json), then runs this validator:

    python scripts/check_bench_json.py <dir> <name> [<name> ...]

For every requested name the artifact must exist, parse, carry the
expected schema (schema_version == 1, matching name, timestamp, git_rev,
config, non-empty numeric metrics, gates), and every recorded gate must
have passed. A bench that silently stopped measuring, dropped its
artifact, or regressed past a pinned threshold fails CI here -- on the
machine-readable record, not just on a stray assert inside the bench.

Exit status: 0 iff every artifact validates and every gate passed.
"""
from __future__ import annotations

import json
import numbers
import os
import sys

SCHEMA_VERSION = 1
REQUIRED_KEYS = ("schema_version", "name", "timestamp", "git_rev",
                 "config", "metrics", "gates")

# per-bench metric keys that MUST be present (a bench that silently
# stopped measuring a gated quantity fails here even if its remaining
# gates pass). PR 10: the obs artifact must carry the flight-recorder
# replay + recording-off overhead fields.
EXPECTED_METRICS = {
    "obs": ("exec_xla_q1_overhead", "paged_overhead",
            "recording_exec_xla_q1_overhead", "recording_paged_overhead",
            "replay_records", "replay_matched", "replay_ok"),
}
# per-bench gates that MUST be recorded
EXPECTED_GATES = {
    "obs": ("overhead_recording_exec_xla_q1", "overhead_recording_paged",
            "replay_bit_parity"),
}


def check_artifact(path: str, name: str) -> list:
    """Return a list of human-readable problems (empty == valid)."""
    probs = []
    if not os.path.isfile(path):
        return [f"missing artifact {path}"]
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]
    if not isinstance(doc, dict):
        return [f"{path}: top level is not an object"]
    for key in REQUIRED_KEYS:
        if key not in doc:
            probs.append(f"{path}: missing key '{key}'")
    if probs:
        return probs
    if doc["schema_version"] != SCHEMA_VERSION:
        probs.append(f"{path}: schema_version {doc['schema_version']!r}"
                     f" != {SCHEMA_VERSION}")
    if doc["name"] != name:
        probs.append(f"{path}: name {doc['name']!r} != {name!r}")
    if not (isinstance(doc["timestamp"], str) and doc["timestamp"]):
        probs.append(f"{path}: empty/invalid timestamp")
    if not isinstance(doc["config"], dict):
        probs.append(f"{path}: config is not an object")
    metrics = doc["metrics"]
    if not (isinstance(metrics, dict) and metrics):
        probs.append(f"{path}: metrics must be a non-empty object")
    else:
        for m, v in metrics.items():
            ok = isinstance(v, numbers.Number) or (
                isinstance(v, list)
                and all(isinstance(x, (numbers.Number, dict)) for x in v))
            if not ok:
                probs.append(f"{path}: metric {m!r} is not numeric")
    if isinstance(metrics, dict):
        for key in EXPECTED_METRICS.get(name, ()):
            if key not in metrics:
                probs.append(f"{path}: missing expected metric {key!r}")
    gates = doc["gates"]
    if isinstance(gates, dict):
        for key in EXPECTED_GATES.get(name, ()):
            if key not in gates:
                probs.append(f"{path}: missing expected gate {key!r}")
    if not isinstance(gates, dict):
        probs.append(f"{path}: gates is not an object")
    else:
        for g, st in gates.items():
            if not (isinstance(st, dict) and isinstance(
                    st.get("passed"), bool)):
                probs.append(f"{path}: gate {g!r} has no boolean 'passed'")
            elif not st["passed"]:
                probs.append(
                    f"{path}: gate {g!r} FAILED"
                    f" ({st.get('detail', '') or 'no detail'})")
    return probs


def main(argv) -> int:
    if len(argv) < 3:
        print(__doc__, file=sys.stderr)
        return 2
    d, names = argv[1], argv[2:]
    failures = []
    for name in names:
        path = os.path.join(d, f"BENCH_{name}.json")
        probs = check_artifact(path, name)
        if probs:
            failures.extend(probs)
        else:
            with open(path) as f:
                doc = json.load(f)
            print(f"ok: {path} ({len(doc['metrics'])} metrics, "
                  f"{len(doc['gates'])} gates passed)")
    for p in failures:
        print(f"FAIL: {p}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
