"""Final merge + render: fold fix-up records into dryrun.json (keep-last
per key), then inject tables into EXPERIMENTS.md."""
import json
import os
import subprocess
import sys


def merge(dst="results/dryrun.json", extras=("results/xlstm_fix.json",)):
    recs = json.load(open(dst))
    for path in extras:
        if os.path.exists(path):
            recs += json.load(open(path))
    # keep-last per (arch, shape, mesh)
    out = {}
    for r in recs:
        out[(r["arch"], r["shape"], r["mesh"])] = r
    merged = list(out.values())
    json.dump(merged, open(dst, "w"), indent=1)
    n_ok = sum(r["status"] == "ok" for r in merged)
    n_sk = sum(r["status"] == "skipped" for r in merged)
    n_er = sum(r["status"] == "error" for r in merged)
    print(f"merged: {len(merged)} records ({n_ok} ok / {n_sk} skipped /"
          f" {n_er} error)")
    for r in merged:
        if r["status"] == "error":
            print("  ERROR:", r["arch"], r["shape"], r["mesh"])


if __name__ == "__main__":
    merge()
    subprocess.run([sys.executable, "scripts/render_experiments.py"],
                   check=True)
