#!/usr/bin/env bash
# Tier-1 entry point: import smoke + full pytest run.
#   scripts/ci.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -c "import repro; print('import ok:', repro.__name__)"
python -m pytest -q "$@"
