#!/usr/bin/env bash
# Tier-1 entry point: import smoke + full pytest run.
#   scripts/ci.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -c "import repro; print('import ok:', repro.__name__)"
# every smoke bench persists a machine-readable trajectory artifact
# (BENCH_<name>.json: metrics + config + git rev + gate outcomes) into
# this directory; check_bench_json.py validates them after the runs
export BENCH_JSON_DIR="${BENCH_JSON_DIR:-$(mktemp -d)}"
# fast regression gate for the int8 scalar-quantization tier (recall +
# resident-bytes rows + the integer-domain scan's speed/recall pins vs
# the dequantize-then-f32 scan; fails loud if the quantized path rots)
python -m benchmarks.bench_quantized --smoke
# regression gate for the disk-resident pager: paged-vs-resident parity,
# recall pin at every budget, resident bytes <= budget, the scan-
# resistant admission hit-rate pin, and prefetch on/off bit-identity
python -m benchmarks.bench_paged --smoke
# regression gate for the incremental maintenance subsystem (Fig. 10d):
# sustained churn maintained by the split/merge scheduler alone must keep
# recall >= 0.95x a freshly rebuilt oracle while its local repairs write
# <= 0.25x the bytes of the legacy rebuild-at-50%-growth policy, with
# every step bounded by max_rows_per_step
python -m benchmarks.bench_updates --smoke
# regression gate for the serving front door (PR 7): coalesced
# micro-batches bit-identical to solo query(), daemon-on/off durable
# equivalence, sustained-QPS floor + uplift over the one-at-a-time
# baseline, and a p99 tail-latency bound under mixed read/write load
python -m benchmarks.bench_serve --smoke
# regression gate for the observability layer (PR 8): tracing-off hooks
# cost <= 3% on the resident exec_xla_q1 path and the paged path, and an
# explain() trace's fault/compile counters reconcile exactly against the
# pager stats deltas and the executor jit trace count
python -m benchmarks.bench_obs --smoke
# fleet-mode gate (PR 9): T tenants sharing ONE FramePool at budget B
# vs naive per-tenant B/T pools on a Zipf-skewed workload -- answers
# bit-identical across arms, pool bytes never exceed B, and the shared
# pool's sustained QPS beats the equal split by >= 1.2x
python -m benchmarks.bench_fleet --smoke
# validate the artifacts: each bench must have written a well-formed
# BENCH_*.json and no recorded acceptance gate may have failed
python scripts/check_bench_json.py "$BENCH_JSON_DIR" quantized paged updates serve obs fleet
# cross-run trend gate (PR 10): compare this run's trend-gated metrics
# (recalls, qps, overhead ratios) against the last committed record in
# BENCH_history/ -- a >25% worse-direction move fails CI -- then append
# this run to the append-only history (committed with the PR)
python scripts/bench_trend.py "$BENCH_JSON_DIR" BENCH_history \
    quantized paged updates serve obs fleet --append
# public-API smoke: the quickstart exercises QuerySpec/ResultSet, write
# sessions, hybrid queries and recovery end-to-end -- API breakage fails
# the gate before the unit tests even start
python examples/quickstart.py
python -m pytest -q "$@"
