"""Perf-iteration helper: re-run one dry-run cell and diff vs the stored
baseline record.

    PYTHONPATH=src python scripts/perf_cell.py llama3-8b train_4k \
        [--baseline results/dryrun.json] [--save results/perf.json]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import argparse
import json

from repro.launch.dryrun import run_cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--baseline", default="results/dryrun.json")
    ap.add_argument("--save", default=None)
    ap.add_argument("--tag", default="candidate")
    args = ap.parse_args()

    rec = run_cell(args.arch, args.shape, args.multi_pod)
    base = None
    if os.path.exists(args.baseline):
        for r in json.load(open(args.baseline)):
            if (r["arch"], r["shape"]) == (args.arch, args.shape) and \
                    r.get("status") == "ok" and \
                    ("pod" in r["mesh"].lower()) == False:
                if (len(r["mesh"].split("x")) == 3) == args.multi_pod:
                    base = r
    if base and rec.get("status") == "ok":
        b, n = base["roofline"], rec["roofline"]
        print("\n== delta vs baseline ==")
        for key in ("t_compute_s", "t_memory_s", "t_collective_s"):
            old, new = b[key], n[key]
            pct = (new - old) / old * 100 if old else float("nan")
            print(f"  {key:16s} {old*1e3:10.2f}ms -> {new*1e3:10.2f}ms"
                  f"  ({pct:+.1f}%)")
        mo = base["memory"]["temp_bytes"] / 1e9
        mn = rec["memory"]["temp_bytes"] / 1e9
        print(f"  temp_bytes       {mo:10.2f}G  -> {mn:10.2f}G")
    if args.save and rec.get("status") == "ok":
        rec["tag"] = args.tag
        hist = json.load(open(args.save)) if os.path.exists(args.save) else []
        hist.append(rec)
        json.dump(hist, open(args.save, "w"), indent=1)


if __name__ == "__main__":
    main()
