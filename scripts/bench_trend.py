#!/usr/bin/env python
"""Cross-run benchmark trend gate + append-only history (PR 10).

Every CI run persists per-bench trajectory artifacts (BENCH_<name>.json,
validated by check_bench_json.py) -- but each run used to stand alone:
nothing compared a fresh run against the last committed one, so a
gradual regression that stayed inside a bench's absolute gates could
rot quality unnoticed. This tool closes the loop:

    python scripts/bench_trend.py <fresh_dir> <history_dir> <name>...

For each bench name it

  1. loads the fresh artifact `<fresh_dir>/BENCH_<name>.json`;
  2. compares its TREND-GATED metrics (the per-bench table below --
     quality metrics with a declared better-direction, chosen for
     run-to-run stability) against the most recent record in
     `<history_dir>/<name>.jsonl`; a metric that moved in the WORSE
     direction by more than BENCH_TREND_TOL (default 25%) fails the
     run with a per-metric report;
  3. with `--append` (what scripts/ci.sh passes), appends the fresh
     artifact as one JSON line to the history file -- an append-only,
     git-committed record, so the NEXT run diffs against this one.

First run (no history) passes trivially and just seeds the record.
Metrics are matched by regex and compared only when present in BOTH
runs, so smoke/full shape differences do not produce false alarms.
Set BENCH_TREND_TOL=0.5 for a looser 50% band on noisy hosts.
"""
from __future__ import annotations

import argparse
import json
import numbers
import os
import re
import sys

# per-bench trend-gated metrics: (regex over metric keys, direction).
# "higher" = bigger is better (recall, qps, speedup); "lower" = smaller
# is better (bytes ratios, overhead multipliers). Raw wall-clock
# latencies are deliberately NOT trend-gated -- they legitimately move
# >25% across hosts; the ratio/recall/parity metrics are host-relative
# and stable.
TREND: dict = {
    "quantized": [
        (r"int8_rerank\d+_recall_at_\d+", "higher"),
        (r"scan_(int8|dequant)_rf\d+_recall", "higher"),
        (r"code_to_f32_bytes_ratio", "lower"),
    ],
    "paged": [
        (r"budget.*_recall_at_\d+", "higher"),
        (r"prefetch_speedup", "higher"),
    ],
    "updates": [
        (r"recall_(sched|oracle)", "higher"),
    ],
    "serve": [
        (r"qps_(solo|coalesce)", "higher"),
        (r"batch_occupancy", "higher"),
    ],
    "obs": [
        (r"(exec_xla_q1|paged)_overhead", "lower"),
        (r"recording_(exec_xla_q1|paged)_overhead", "lower"),
        (r"replay_ok", "higher"),
    ],
    "fleet": [
        (r"qps_uplift", "higher"),
    ],
}


def history_path(history_dir: str, name: str) -> str:
    return os.path.join(history_dir, f"{name}.jsonl")


def last_record(path: str):
    """The most recent JSON line of an append-only history file (None
    when the file is missing/empty; a trailing corrupt line -- e.g. a
    crash mid-append -- falls back to the previous intact one)."""
    if not os.path.isfile(path):
        return None
    with open(path) as f:
        lines = [ln for ln in f.read().splitlines() if ln.strip()]
    for ln in reversed(lines):
        try:
            doc = json.loads(ln)
            if isinstance(doc, dict):
                return doc
        except json.JSONDecodeError:
            continue
    return None


def trend_gated(name: str, keys) -> dict:
    """Map of metric key -> direction for the keys the table gates."""
    out = {}
    for pattern, direction in TREND.get(name, ()):  # unknown bench: none
        rx = re.compile(rf"^{pattern}$")
        for k in keys:
            if rx.match(k):
                out[k] = direction
    return out


def compare(name: str, fresh: dict, prev: dict, tol: float) -> list:
    """Return regression descriptions (empty == within the band)."""
    fm, pm = fresh.get("metrics", {}), prev.get("metrics", {})
    probs = []
    for key, direction in trend_gated(name, fm).items():
        if key not in pm:
            continue
        new, old = fm[key], pm[key]
        if not (isinstance(new, numbers.Number)
                and isinstance(old, numbers.Number)):
            continue
        if direction == "higher":
            # worse = dropped below (1 - tol) * old
            bad = new < (1.0 - tol) * old
            move = f"{old:.6g} -> {new:.6g} (want higher)"
        else:
            bad = old > 0 and new > (1.0 + tol) * old
            move = f"{old:.6g} -> {new:.6g} (want lower)"
        if bad:
            probs.append(
                f"{name}.{key}: {move}, beyond the {tol:.0%} band"
                f" vs {prev.get('git_rev', '?')}"
                f" @ {prev.get('timestamp', '?')}")
    return probs


def append_record(path: str, doc: dict):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(doc, sort_keys=True) + "\n")


def main(argv) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh_dir")
    ap.add_argument("history_dir")
    ap.add_argument("names", nargs="+")
    ap.add_argument("--append", action="store_true",
                    help="append each fresh artifact to its history "
                         "file after the comparison")
    ap.add_argument("--tol", type=float, default=float(
        os.environ.get("BENCH_TREND_TOL", "0.25")),
        help="allowed worse-direction move (fraction, default 0.25)")
    args = ap.parse_args(argv[1:])

    failures = []
    for name in args.names:
        fresh_path = os.path.join(args.fresh_dir, f"BENCH_{name}.json")
        if not os.path.isfile(fresh_path):
            failures.append(f"{name}: missing fresh artifact"
                            f" {fresh_path}")
            continue
        with open(fresh_path) as f:
            fresh = json.load(f)
        hpath = history_path(args.history_dir, name)
        prev = last_record(hpath)
        ok = True
        if prev is None:
            print(f"trend {name}: no history yet"
                  f" ({len(trend_gated(name, fresh.get('metrics', {})))}"
                  f" gated metrics will seed {hpath})")
        else:
            probs = compare(name, fresh, prev, args.tol)
            if probs:
                failures.extend(probs)
                ok = False
            else:
                n = len([k for k in
                         trend_gated(name, fresh.get("metrics", {}))
                         if k in prev.get("metrics", {})])
                print(f"trend {name}: {n} gated metrics within"
                      f" {args.tol:.0%} of"
                      f" {prev.get('git_rev', '?')}")
        # a regressed run is NOT appended: the next run keeps diffing
        # against the last good record instead of ratcheting downward
        if args.append and ok:
            append_record(hpath, fresh)
    for p in failures:
        print(f"TREND FAIL: {p}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
