"""Render EXPERIMENTS.md tables from results/dryrun.json."""
import json
import sys


def fmt_table(recs, mesh_filter):
    rows = []
    hdr = ("| arch | shape | compute (ms) | memory (ms) | collective (ms) |"
           " bound | useful | peak GB | fits |")
    sep = "|" + "---|" * 9
    rows.append(hdr)
    rows.append(sep)
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh_filter:
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — |"
                        f" skipped: {r['reason'][:40]} | — | — | — |")
            continue
        if r["status"] == "error":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — |"
                        f" ERROR | — | — | — |")
            continue
        rf = r["roofline"]
        peak = r["memory"]["peak_bytes_est"] / 1e9
        rows.append(
            f"| {r['arch']} | {r['shape']} |"
            f" {rf['t_compute_s']*1e3:.1f} | {rf['t_memory_s']*1e3:.1f} |"
            f" {rf['t_collective_s']*1e3:.1f} | **{rf['bottleneck']}** |"
            f" {r.get('useful_flops_ratio', 0):.2f} | {peak:.1f} |"
            f" {'yes' if r.get('hbm_ok') else 'NO'} |")
    return "\n".join(rows)


def dominant_fraction(r):
    """roofline fraction = compute term / dominant term (how close the
    dominant bottleneck is to pure-MXU execution)."""
    rf = r["roofline"]
    dom = max(rf["t_compute_s"], rf["t_memory_s"], rf["t_collective_s"])
    return rf["t_compute_s"] / dom if dom else 0.0


if __name__ == "__main__":
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.json"
    recs = json.load(open(path))
    print("## single-pod (16x16)\n")
    print(fmt_table(recs, "16x16"))
    print("\n\n## multi-pod (2x16x16)\n")
    print(fmt_table([r for r in recs if r["mesh"].count("x") == 2],
                    "2x16x16"))
    print("\n\n## roofline fractions (sorted; hillclimb candidates)\n")
    oks = [r for r in recs if r["status"] == "ok" and r["mesh"] == "16x16"]
    for r in sorted(oks, key=dominant_fraction):
        print(f"  {dominant_fraction(r):.3f}  {r['arch']} x {r['shape']}"
              f"  ({r['roofline']['bottleneck']}-bound)")
