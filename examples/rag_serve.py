"""End-to-end driver: serve a small LM with batched requests + MicroNN RAG.

    PYTHONPATH=src python examples/rag_serve.py

The datastore is the *updatable* MicroNN index: documents upserted while
the engine is serving become retrievable on the very next decode step --
the paper's freshness story surfaced at the serving tier.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.configs.smoke import smoke_config
from repro.core import delta as delta_ops
from repro.core.rag import RagConfig
from repro.launch.serve import build_rag_datastore
from repro.models import init_model
from repro.serving import Request, ServeEngine


def main():
    cfg = smoke_config(get_arch("llama3-8b").config)
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    rag = build_rag_datastore(cfg, n=4096)
    eng = ServeEngine(cfg, params, slots=4, s_max=64, rag=rag,
                      rag_cfg=RagConfig(k=8, n_probe=4, lam=0.3))

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=list(map(int, rng.integers(1, 400, 6))),
                    max_new_tokens=12) for i in range(8)]
    for r in reqs:
        eng.submit(r)

    steps = 0
    while (eng.queue or any(s is not None for s in eng.active)) and steps < 300:
        eng.step()
        steps += 1
        if steps == 5:
            # live datastore update mid-serving (streaming upsert)
            fresh = rng.normal(size=(16, cfg.d_model)).astype(np.float32)
            rag.index = delta_ops.upsert(
                rag.index, jnp.asarray(fresh),
                jnp.arange(50_000, 50_016, dtype=jnp.int32),
                jnp.zeros((16, rag.index.n_attr)))
            print(f"[step {steps}] upserted 16 docs into the live datastore")

    done = sum(r.done for r in reqs)
    print(f"served {done}/{len(reqs)} requests in {steps} steps"
          f" (4 slots, continuous batching, kNN-LM interpolation)")
    for r in reqs[:3]:
        print(f"  req {r.uid}: {r.prompt} -> {r.out}")


if __name__ == "__main__":
    main()
