"""Async serving quickstart (PR 9): the FrontDoor from asyncio.

    PYTHONPATH=src python examples/async_serve.py

An asyncio server task awaits `FrontDoor.query_async()` (or holds the
`submit_async()` future) instead of blocking a thread on `.result()`:
the request still flows through the same admission queue and
cross-request micro-batching dispatcher, so N concurrent coroutines
coalesce into fused scans exactly like N caller threads would -- with
bit-identical results -- while the event loop stays free. With
`adaptive_window=True` the dispatcher sizes its coalescing wait from
the observed arrival rate (EWMA of inter-arrival gaps, clamped to
[0, window_s]): a burst of concurrent requests batches, a lone request
executes with ~zero added latency.
"""
import asyncio
import os
import tempfile

import numpy as np

from repro.core.query import Q
from repro.serving import FrontDoor
from repro.storage import MicroNN


async def one_request(fd: FrontDoor, q: np.ndarray, k: int):
    rs = await fd.query_async(q, Q.knn(k=k).probe(8))
    return rs.ids[0]


async def main_async(fd: FrontDoor, queries: np.ndarray):
    # 32 concurrent "server tasks": arrivals land inside one adaptive
    # window and coalesce into a handful of fused calls
    results = await asyncio.gather(
        *(one_request(fd, q, 5) for q in queries))
    st = fd.stats()
    print(f"completed={st['completed']} batches={st['batches']} "
          f"coalesced={st['coalesced']} "
          f"occupancy={st['batch_occupancy']:.1f}")
    print(f"adaptive window={st['window_ms']:.3f}ms "
          f"(arrival ewma={st['arrival_ewma_ms']:.3f}ms)")
    return results


def main():
    rng = np.random.default_rng(0)
    n, d = 2000, 32
    with tempfile.TemporaryDirectory() as tmp:
        eng = MicroNN(dim=d, path=os.path.join(tmp, "db.sqlite"))
        with eng.session() as s:
            s.upsert(np.arange(n), rng.normal(size=(n, d)))
        eng.build()
        queries = rng.normal(size=(32, d)).astype(np.float32)
        with FrontDoor(eng, adaptive_window=True) as fd:
            results = asyncio.run(main_async(fd, queries))
        # async answers == the plain synchronous engine's, bit for bit
        for q, ids in zip(queries, results):
            solo = eng.query(q, Q.knn(k=5).probe(8))
            assert np.array_equal(solo.ids[0], ids)
        print("async results bit-identical to solo query(): ok")


if __name__ == "__main__":
    main()
