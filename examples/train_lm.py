"""Train a small LM end-to-end with checkpoint/restart fault tolerance.

    PYTHONPATH=src python examples/train_lm.py --steps 300 --size 15m

Synthetic n-gram data gives real learnable signal: loss drops visibly.
Kill the process mid-run and re-run with the same --ckpt-dir: it resumes
from the last checkpoint (including data-stream position).
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.tokens import TokenStream
from repro.models import init_model
from repro.train import Trainer, TrainerConfig, optim

SIZES = {
    "2m": dict(num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
               head_dim=32, d_ff=512, vocab_size=2048),
    "15m": dict(num_layers=4, d_model=384, num_heads=6, num_kv_heads=2,
                head_dim=64, d_ff=1536, vocab_size=8192),
    "110m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
                 head_dim=64, d_ff=3072, vocab_size=32768),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--size", choices=SIZES, default="2m")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = ModelConfig(name=f"lm-{args.size}", family="dense",
                      pattern=("attn",), mlp_act="silu_glu",
                      tie_embeddings=True, scan_layers=True,
                      **SIZES[args.size])
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model: {n/1e6:.1f}M params, {args.steps} steps,"
          f" batch {args.batch}x{args.seq}")

    tcfg = TrainerConfig(
        opt=optim.AdamWConfig(lr=3e-3, warmup_steps=20,
                              total_steps=args.steps),
        checkpoint_every=50, ckpt_dir=args.ckpt_dir)
    trainer = Trainer(cfg, tcfg)
    stream = TokenStream(vocab=cfg.vocab_size, batch=args.batch,
                         seq=args.seq)

    def data(start):
        for b in stream.iter_from(start):
            yield {"tokens": jnp.asarray(b["tokens"])}

    trainer.fit(params, data, args.steps)
    h = trainer.history
    k = max(1, len(h) // 10)
    first = float(np.mean([m["loss"] for m in h[:k]]))
    last = float(np.mean([m["loss"] for m in h[-k:]]))
    print(f"loss: {first:.4f} -> {last:.4f}"
          f"  ({'LEARNING' if last < first - 0.05 else 'check config'})")


if __name__ == "__main__":
    main()
