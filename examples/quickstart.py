"""MicroNN quickstart: the embedded vector search engine end-to-end,
against the declarative query API.

    PYTHONPATH=src python examples/quickstart.py

Covers the full paper workflow on the two public objects -- `QuerySpec`
(built with the fluent `Q` builder; one frozen spec == one compile-cache
entry) and `ResultSet` -- plus batched write sessions: build -> ANN
search -> hybrid search with the query optimizer -> a write session
(one transaction) -> incremental maintenance -> durable recovery, all
against a real SQLite file.
"""
import os
import tempfile

import numpy as np

from repro.core.hybrid import Pred
from repro.core.query import Q
from repro.core.types import IVFConfig
from repro.data import synthetic
from repro.storage import MicroNN


def main():
    ds = synthetic.make("sift", scale=0.01)   # 10k x 128d, L2
    print(f"dataset: {ds.name} {ds.X.shape} metric={ds.metric}")
    attrs = np.stack([
        np.random.default_rng(0).integers(0, 10, len(ds.X)),   # "location"
        np.random.default_rng(1).integers(2000, 2025, len(ds.X)),  # "year"
    ], axis=1).astype(np.float32)

    with tempfile.TemporaryDirectory() as td:
        eng = MicroNN(dim=ds.dim, n_attr=2,
                      path=os.path.join(td, "vectors.db"),
                      config=IVFConfig(dim=ds.dim, target_partition_size=100,
                                       kmeans_iters=60, delta_capacity=512))
        eng.upsert(np.arange(len(ds.X)), ds.X, attrs)
        eng.build()
        print(f"built IVF index: k={eng.index.k} partitions,"
              f" p_max={eng.index.p_max}")

        # --- ANN search at a recall target: build the spec once ----------
        knn100 = Q.knn(k=100, n_probe=8)
        res = eng.query(ds.Q[:32], knn100)
        rec = synthetic.recall(np.asarray(res.ids), ds.gt[:32],
                               np.arange(len(ds.X)), 100)
        print(f"ANN recall@100 (n_probe=8): {rec:.3f}")

        # --- hybrid search: predicates live IN the query object ----------
        # (the optimizer resolves pre- vs post-filtering from selectivity)
        hybrid = Q.knn(k=10).where(Pred(0, "==", 3.0),
                                   Pred(1, ">=", 2020)).with_attrs()
        res = eng.query(ds.Q[:4], hybrid)
        top = res[0]                      # per-query ResultSet indexing
        print(f"hybrid (selective): top ids {top.ids[:5]}"
              f" attrs {top.attrs[:2].tolist()}")

        # --- write session: one transaction, one delta-encode batch ------
        new_vecs = ds.Q[:8] + 0.01
        with eng.session() as s:
            s.upsert(np.arange(10_000_000, 10_000_008), new_vecs,
                     np.zeros((8, 2), np.float32))
            s.delete(np.asarray([10_000_000]))        # coalesced at commit
        r = eng.query(new_vecs[:2], Q.knn(k=1))
        print(f"freshly inserted are immediately searchable:"
              f" {np.asarray(r.ids).ravel()}")
        eng.maintain(force="flush")
        print(f"after flush: delta live rows ="
              f" {int(np.asarray(eng.index.delta.valid).sum())}")

        # --- observability: the spec cache is part of stats() ------------
        st = eng.stats()
        print(f"executor: trace_count={st['trace_count']}"
              f" compile_cache_size={st['compile_cache_size']}")

        # --- durable recovery --------------------------------------------
        eng2 = MicroNN(dim=ds.dim, n_attr=2,
                       path=os.path.join(td, "vectors.db"),
                       config=eng.config)
        eng2.recover()
        r2 = eng2.query(new_vecs[1:2], Q.knn(k=1))
        print(f"recovered engine still finds upsert:"
              f" {int(r2.ids[0, 0])} (expect 10000001)")


if __name__ == "__main__":
    main()
