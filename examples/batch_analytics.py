"""Visual-analytics style batch workload (paper Example 2): large query
batches with MQO vs sequential execution, with hybrid attribute filters.

    PYTHONPATH=src python examples/batch_analytics.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import ivf, mqo, search
from repro.core.hybrid import Pred, compile_filter
from repro.core.types import IVFConfig
from repro.data import synthetic


def main():
    ds = synthetic.make("internala", scale=0.05, with_gt=False)
    attrs = np.random.default_rng(0).integers(
        0, 4, (len(ds.X), 1)).astype(np.float32)
    idx = ivf.build_index(
        ds.X, attrs=attrs,
        cfg=IVFConfig(dim=ds.dim, metric=ds.metric,
                      target_partition_size=100, kmeans_iters=40))
    print(f"index: {len(ds.X)} vectors, k={idx.k}")

    for batch in (32, 128, 512):
        q = jnp.asarray(np.tile(ds.Q, (max(1, batch // len(ds.Q)) + 1, 1))
                        [:batch])
        t0 = time.perf_counter()
        r1 = search.ann_search(idx, q, 100, n_probe=8)
        jnp.asarray(r1.ids).block_until_ready()
        t_naive = time.perf_counter() - t0
        t0 = time.perf_counter()
        r2 = mqo.mqo_search(idx, q, 100, n_probe=8)
        jnp.asarray(r2.ids).block_until_ready()
        t_mqo = time.perf_counter() - t0
        io_naive = mqo.gathered_bytes(idx, batch, 8, mqo=False)
        io_mqo = mqo.gathered_bytes(idx, batch, 8, mqo=True)
        print(f"batch={batch:4d}: naive {t_naive*1e3:7.1f}ms"
              f" mqo {t_mqo*1e3:7.1f}ms"
              f"  partition I/O {io_naive/1e6:7.1f}MB -> {io_mqo/1e6:7.1f}MB"
              f" ({io_naive/max(io_mqo,1):.1f}x less)")

    # hybrid filter over the batch
    f = compile_filter(Pred(0, "eq", 2.0))
    r = mqo.mqo_search(idx, jnp.asarray(ds.Q[:64]), 10, n_probe=8,
                       attr_filter=f)
    print("hybrid batch top-1 ids:", np.asarray(r.ids)[:4, 0])


if __name__ == "__main__":
    main()
