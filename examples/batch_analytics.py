"""Visual-analytics style batch workload (paper Example 2): large query
batches with MQO vs sequential execution, with hybrid attribute filters
-- written against the declarative query API.

An MQO batch is just an ANN QuerySpec (the shared probe union IS the
plan); `union_cap` bounds the scan union. Because a frozen spec is the
executor's jit cache key, the three batch sizes below share compile
entries per query-count bucket and re-running a spec never retraces.

    PYTHONPATH=src python examples/batch_analytics.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import executor, ivf, mqo
from repro.core.hybrid import Pred
from repro.core.query import Q
from repro.core.types import IVFConfig
from repro.data import synthetic


def main():
    ds = synthetic.make("internala", scale=0.05, with_gt=False)
    attrs = np.random.default_rng(0).integers(
        0, 4, (len(ds.X), 1)).astype(np.float32)
    idx = ivf.build_index(
        ds.X, attrs=attrs,
        cfg=IVFConfig(dim=ds.dim, metric=ds.metric,
                      target_partition_size=100, kmeans_iters=40))
    print(f"index: {len(ds.X)} vectors, k={idx.k}")

    spec = Q.knn(k=100, n_probe=8)                 # built once, reused
    for batch in (32, 128, 512):
        q = jnp.asarray(np.tile(ds.Q, (max(1, batch // len(ds.Q)) + 1, 1))
                        [:batch])
        t0 = time.perf_counter()
        r1 = executor.run(idx, q, spec)            # shared-union batch scan
        jnp.asarray(r1.ids).block_until_ready()
        t_shared = time.perf_counter() - t0
        t0 = time.perf_counter()
        r2 = executor.run(idx, q, spec.union_cap(24))   # capped union
        jnp.asarray(r2.ids).block_until_ready()
        t_capped = time.perf_counter() - t0
        io_naive = mqo.gathered_bytes(idx, batch, 8, mqo=False)
        io_mqo = mqo.gathered_bytes(idx, batch, 8, mqo=True)
        print(f"batch={batch:4d}: shared {t_shared*1e3:7.1f}ms"
              f" capped-union {t_capped*1e3:7.1f}ms"
              f"  partition I/O {io_naive/1e6:7.1f}MB -> {io_mqo/1e6:7.1f}MB"
              f" ({io_naive/max(io_mqo,1):.1f}x less)")

    # hybrid filter over the batch: the predicate lives in the spec
    r = executor.run(idx, jnp.asarray(ds.Q[:64]),
                     Q.knn(k=10, n_probe=8).where(Pred(0, "==", 2.0)))
    print("hybrid batch top-1 ids:", np.asarray(r.ids)[:4, 0])
    # per-query consumption via the ResultSet iterator
    first = next(iter(r))
    print(f"first query: {len(first)} hits, best score {first.scores[0]:.3f}")


if __name__ == "__main__":
    main()
