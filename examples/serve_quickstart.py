"""Serving front door quickstart: MicroNN under concurrent load.

    PYTHONPATH=src python examples/serve_quickstart.py

A bare `MicroNN` executes every call on the caller's thread. The
serving tier (`repro.serving.FrontDoor`) puts an admission queue in
front of it: caller threads submit queries and block on futures, a
dispatcher coalesces same-spec requests arriving within a small window
into ONE fused executor call (each caller gets its slice back,
bit-identical to a solo query), and the maintenance scheduler runs as a
background daemon that drains bounded repair quanta whenever the queue
is idle -- writes serialize on the engine mutex, reads never wait.

This script walks that story: build -> serve from many threads ->
write concurrently through a session -> watch the daemon keep the
index healthy -> read the latency/occupancy counters from stats().
"""
import os
import tempfile
import threading

import numpy as np

from repro.core.query import Q
from repro.core.types import IVFConfig
from repro.serving import FrontDoor
from repro.storage import MicroNN


def main():
    rng = np.random.default_rng(0)
    n, dim = 4000, 32
    centers = rng.normal(size=(24, dim)).astype(np.float32) * 5.0
    X = (centers[rng.integers(0, 24, n)]
         + rng.normal(size=(n, dim)).astype(np.float32))

    with tempfile.TemporaryDirectory() as td:
        eng = MicroNN(dim=dim, path=os.path.join(td, "vectors.db"),
                      config=IVFConfig(dim=dim, target_partition_size=64,
                                       kmeans_iters=20, delta_capacity=256))
        eng.upsert(np.arange(n), X)
        eng.build()
        print(f"built: k={eng.index.k} partitions over {n} rows")

        # maintenance=True promotes the scheduler to a daemon thread --
        # no more hand-cranked maintain_step() calls
        with FrontDoor(eng, window_s=0.002, maintenance=True) as fd:
            spec = Q.knn(k=10, n_probe=8)

            # --- many caller threads, one fused execution path ---------
            out = {}

            def caller(t, q):
                # blocking query() from any thread; same-window callers
                # sharing `spec` coalesce into one micro-batched call
                out[t] = fd.query(q, spec, timeout=60)

            qs = centers[:8] + 0.1
            threads = [threading.Thread(target=caller, args=(t, qs[t]))
                       for t in range(8)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            top = {t: int(np.asarray(rs.ids)[0, 0])
                   for t, rs in sorted(out.items())}
            print(f"8 concurrent callers served; top hits: {top}")

            # every coalesced answer is bit-identical to the solo path
            solo = eng.query(qs[0], spec)
            assert np.array_equal(np.asarray(out[0].ids),
                                  np.asarray(solo.ids))
            assert np.array_equal(np.asarray(out[0].scores),
                                  np.asarray(solo.scores))
            print("coalesced == solo, bitwise")

            # --- writes interleave safely with serving ------------------
            new = rng.normal(size=(200, dim)).astype(np.float32)
            with eng.session() as s:           # serialized on eng.lock
                s.upsert(np.arange(n, n + 200), new)
            rs = fd.query(new[0], spec, timeout=60)
            assert int(np.asarray(rs.ids)[0, 0]) == n
            print("fresh upsert immediately visible through the queue")

            # the daemon picks up the flush/split work in the background
            fd.drain()
            stats = eng.stats()
            print(f"daemon alive={stats['daemon_alive']}"
                  f" steps={stats['daemon_steps']}"
                  f" pending={stats['scheduler_depth']}")

            # --- serving telemetry --------------------------------------
            s = stats["frontdoor"]
            print(f"served={s['completed']} coalesced={s['coalesced']}"
                  f" batches={s['batches']}"
                  f" occupancy={s['batch_occupancy']:.2f}")
            print(f"queue wait p50={s['queue_wait_p50_ms']:.2f}ms"
                  f" p99={s['queue_wait_p99_ms']:.2f}ms |"
                  f" total p50={s['total_p50_ms']:.2f}ms"
                  f" p99={s['total_p99_ms']:.2f}ms")

        # the context exit stopped the dispatcher and the daemon
        assert not eng.scheduler.daemon_alive
        print("front door closed; engine still usable:",
              np.asarray(eng.query(qs[0], spec).ids)[0, :3])
        eng.store.close()


if __name__ == "__main__":
    main()
