"""Observability quickstart: tracing, metrics, and the event log (PR 8).

    PYTHONPATH=src python examples/observability_quickstart.py

Every MicroNN component -- pager, executor compile cache, scheduler,
serving front door -- registers its counters into ONE process metrics
registry (`repro.obs`). This script drives a mixed workload over a
disk-resident quantized engine and then reads the three observability
surfaces back:

  1. `explain()` -- a per-stage QueryTrace (plan / probe / pager_fault /
     scan / rerank / merge) whose work counters reconcile exactly with
     the component counters;
  2. the registry -- `MicroNN.stats()` as the derived dict view, plus
     the Prometheus text exposition for scraping;
  3. the trace ring -- last-N traces, the maintenance event log, and
     the slow-query log;
  4. (PR 10) the flight recorder -- capture a sampled window of live
     traffic to one SQLite file, then `replay()` it and verify every
     ResultSet is bit-identical to what production served;
  5. (PR 10) the live exposition endpoint -- a stdlib HTTP server on a
     daemon thread; while this script runs you can also
     `curl http://127.0.0.1:<port>/metrics` (or /healthz, /traces,
     /slow, /events) from another shell.
"""
import json
import os
import tempfile
import threading
import urllib.request

import numpy as np

from repro.core.query import Q
from repro.core.types import IVFConfig
from repro.obs import metrics as obs_metrics
from repro.obs import recorder as obs_recorder
from repro.obs.http import ExpositionServer
from repro.serving import FrontDoor
from repro.storage import MicroNN


def main():
    rng = np.random.default_rng(0)
    n, dim = 4000, 32
    centers = rng.normal(size=(24, dim)).astype(np.float32) * 5.0
    X = (centers[rng.integers(0, 24, n)]
         + rng.normal(size=(n, dim)).astype(np.float32))

    with tempfile.TemporaryDirectory() as td:
        eng = MicroNN(dim=dim, path=os.path.join(td, "vectors.db"),
                      config=IVFConfig(dim=dim, target_partition_size=64,
                                       kmeans_iters=20, delta_capacity=256,
                                       quantize="int8", rerank_factor=4),
                      memory_budget_mb=0.5,       # disk-resident + pager
                      slow_query_ms=50.0)         # slow-log threshold
        eng.upsert(np.arange(n), X)
        eng.build()
        spec = Q.knn(k=10, n_probe=8)

        # --- 1. explain(): the per-stage trace --------------------------
        tr = eng.explain(centers[:2] + 0.1, spec)
        print("=== explain() -- cold pager, cold jit cache ===")
        print(tr.format())
        tr2 = eng.explain(centers[:2] + 0.1, spec)
        print("\n=== same query again -- warm (cache_hit, fewer faults) ===")
        print(tr2.format())
        # the trace's fault counters ARE the pager's counters: exact
        s0 = eng.stats()
        tr3 = eng.explain(centers[10:12], spec)
        s1 = eng.stats()
        assert tr3.counter("pager_fault", "misses") == \
            s1["misses"] - s0["misses"]
        print("\nfault counters reconcile with pager stats, exactly")

        # --- mixed workload: threads + writes + daemon maintenance ------
        with FrontDoor(eng, window_s=0.002, maintenance=True) as fd:
            def caller(i):
                # every 3rd caller asks for a trace: per-caller
                # queue_wait + the shared fused-call spans
                rs = fd.query(centers[i % 24] + 0.1, spec,
                              trace=(i % 3 == 0), timeout=60)
                if rs.trace is not None:
                    assert "queue_wait" in rs.trace
            ts = [threading.Thread(target=caller, args=(i,))
                  for i in range(12)]
            for t in ts:
                t.start()
            with eng.session() as s:             # interleaved writes
                s.upsert(np.arange(n, n + 150),
                         rng.normal(size=(150, dim)).astype(np.float32))
            for t in ts:
                t.join()
            fd.drain()
            st = fd.stats()
            print(f"\nserved={st['completed']}"
                  f" coalesced={st['coalesced']}"
                  f" total p50={st['total_p50_ms']:.2f}ms"
                  f" p99={st['total_p99_ms']:.2f}ms")
        eng.maintain(until_idle=True)

        # --- 2. the unified registry ------------------------------------
        print("\n=== stats(): derived view over the registry ===")
        s = eng.stats()
        print(f"pager: hits={s['hits']} misses={s['misses']}"
              f" bytes_read={s['bytes_read']}")
        print(f"scheduler: {s['scheduler']}")
        print("\n=== Prometheus exposition (first 12 lines) ===")
        text = obs_metrics.default_registry().to_prometheus()
        print("\n".join(text.splitlines()[:12]))

        # --- 3. the ring: event log + slow-query log --------------------
        print("\n=== maintenance event log ===")
        for e in eng.traces.events(5):
            print(f"  {e.kind:<12} action={e.action or '-':<10}"
                  f" rows={e.rows} dur={e.dur_ms:.2f}ms")
        print(f"\nslow queries (> {eng.traces.slow_ms:.0f}ms):"
              f" {len(eng.traces.slow())} of"
              f" {len(eng.traces.traces())} traced")
        for t in eng.traces.slow():
            print(f"  {t.total_ms:8.2f}ms  {t.mode}  {list(t.span_names)}")

        # --- 4. flight recorder: capture a window, replay it bit-exact --
        cap = os.path.join(td, "flight.db")
        with obs_recorder.recording(cap, sample_every=2) as rec:
            for i in range(10):              # live traffic, half sampled
                eng.query(centers[i % 24] + 0.1, spec)
            print(f"\n=== flight recorder ===\ncaptured "
                  f"{rec.recorded} of {rec.stats()['seen']} queries"
                  f" (sample_every=2) -> {os.path.basename(cap)}")
        report = obs_recorder.replay(cap, engine=eng, strict=True)
        print(f"replayed {report.replayed}: {report.matched} matched"
              f" capture digests bit-exactly (ids AND f32 scores)")

        # --- 5. the exposition endpoint ---------------------------------
        with ExpositionServer.for_target(eng) as srv:
            print(f"\n=== exposition endpoint at {srv.url} ===")
            with urllib.request.urlopen(srv.url + "/metrics") as r:
                lines = r.read().decode().splitlines()
            print(f"GET /metrics -> {len(lines)} lines, e.g.:")
            print("\n".join(f"  {ln}" for ln in lines[:4]))
            with urllib.request.urlopen(srv.url + "/healthz") as r:
                health = json.loads(r.read())
            print(f"GET /healthz -> hits={health['hits']}"
                  f" misses={health['misses']}"
                  f" daemon_alive={health['daemon_alive']}")
            with urllib.request.urlopen(srv.url + "/events") as r:
                print(f"GET /events -> {len(json.loads(r.read()))}"
                      f" maintenance events")
        eng.store.close()


if __name__ == "__main__":
    main()
