"""Quantized tier: recall@100 + resident index bytes, float32 vs int8.

The paper's memory headline (top-100 @ 90% recall in ~10 MB at million
scale) rests on scanning compact codes and reranking at full precision.
This section measures the reproduction of that trade-off on synthetic
clustered data:

  * resident scan-tier bytes: int8 codes vs float32 vectors (the codes
    must come in at ~25% -- acceptance bound <= 30%);
  * recall@100 of the int8 scan + float32 rerank against the float32
    ANN path on the *same* plans, at rerank_factor in {1, 2, 4};
  * latency of both tiers at the same n_probe.

`--smoke` shrinks the dataset so scripts/ci.sh can run this as a fast
regression gate (the quantized path must not silently rot).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import executor, ivf
from repro.core.query import Q
from repro.core.types import IVFConfig

from .common import _recall, emit, timeit


def main(smoke: bool = False):
    rng = np.random.default_rng(0)
    n, d, n_centers = (3000, 32, 12) if smoke else (20000, 64, 40)
    n_q, k, n_probe = (16, 20, 4) if smoke else (64, 100, 8)
    centers = rng.normal(size=(n_centers, d)).astype(np.float32) * 5
    X = (centers[rng.integers(0, n_centers, n)]
         + rng.normal(size=(n, d))).astype(np.float32)
    cfg = IVFConfig(dim=d, target_partition_size=100,
                    kmeans_iters=10 if smoke else 20,
                    quantize="int8", rerank_factor=4)
    idx = ivf.build_index(X, cfg=cfg)
    q = jnp.asarray(X[:n_q])

    # -- resident scan-tier bytes (the paper's memory axis) -----------------
    vec_bytes = idx.vectors.nbytes
    code_bytes = idx.codes.nbytes + idx.qstats.lo.nbytes + \
        idx.qstats.scale.nbytes
    emit("sq_resident_bytes", 0.0,
         f"codes_mb={code_bytes / 2**20:.2f};f32_mb={vec_bytes / 2**20:.2f};"
         f"ratio={code_bytes / vec_bytes:.3f}")

    # -- recall + latency: float32 tier vs int8 tier at rerank factors ------
    spec = Q.knn(k=k, n_probe=n_probe)
    r_f32 = executor.run(idx, q, spec.quantized(False))
    us_f32 = timeit(lambda: executor.run(idx, q, spec.quantized(False)))
    emit(f"sq_f32_scan_k{k}", us_f32, "recall=1.000(reference)")
    ref_ids = np.asarray(r_f32.ids)
    recalls = {}
    for rf in (1, 2, 4):
        idx_rf = dataclasses.replace(
            idx, config=dataclasses.replace(cfg, rerank_factor=rf))
        r = executor.run(idx_rf, q, spec.quantized(True))
        recalls[rf] = _recall(np.asarray(r.ids), ref_ids, k)
        us = timeit(lambda: executor.run(idx_rf, q, spec.quantized(True)))
        emit(f"sq_int8_rerank{rf}_k{k}", us,
             f"recall_at_{k}={recalls[rf]:.3f};vs_f32={us_f32 / us:.2f}x")

    # acceptance gate (scripts/ci.sh --smoke): the quantized path must not
    # silently rot -- fail loud on the memory ratio or the recall pin
    assert code_bytes / vec_bytes <= 0.30, \
        f"code tier too large: {code_bytes / vec_bytes:.3f} > 0.30"
    assert recalls[4] >= 0.95, \
        f"int8+rerank4 recall@{k}={recalls[4]:.3f} < 0.95 vs the f32 path"


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes for the CI regression gate")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(smoke=args.smoke)
