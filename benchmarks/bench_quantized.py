"""Quantized tier: recall@100 + resident index bytes, float32 vs int8.

The paper's memory headline (top-100 @ 90% recall in ~10 MB at million
scale) rests on scanning compact codes and reranking at full precision.
This section measures the reproduction of that trade-off on synthetic
clustered data:

  * resident scan-tier bytes: int8 codes vs float32 vectors (the codes
    must come in at ~25% -- acceptance bound <= 30%);
  * recall@100 of the int8 scan + float32 rerank against the float32
    ANN path on the *same* plans, at rerank_factor in {1, 2, 4};
  * latency of both tiers at the same n_probe;
  * the integer-domain candidate scan (PR 6) against the old
    dequantize-then-f32 scan it replaced: same plan, same k', direct
    jitted scan calls -- wall-clock AND candidate recall, per
    rerank_factor, plus the paper's on-device regime (Q=1). The
    int8-domain scan must match the dequant scan's recall everywhere
    and beat its wall-clock at Q=1; the large-batch sweep's speed pin
    is hardware-aware (the two-term query fold costs a second gemm
    that only an int8 matmul unit absorbs -- on plain CPU the large-Q
    ratio is pinned within tolerance, not required to win).

`--smoke` shrinks the dataset so scripts/ci.sh can run this as a fast
regression gate (the quantized path must not silently rot). With
BENCH_JSON_DIR set, the measurements + gate outcomes persist as
BENCH_quantized.json (see common.write_json).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import executor, ivf
from repro.core.query import Q
from repro.core.types import IVFConfig

from .common import _recall, emit, timeit, write_json


def _cand_recall(cand: np.ndarray, ref: np.ndarray, k: int) -> float:
    """Recall@k of the exact-f32 rerank over a candidate set: the rerank
    rescores candidates exactly, so every reference top-k member among
    the candidates lands in the final top-k -- recall is candidate
    membership, no need to run the rerank itself."""
    hits = 0
    for a, b in zip(cand, ref[:, :k]):
        real = set(int(x) for x in b if x >= 0)
        hits += len(set(int(x) for x in a if x >= 0) & real)
    return hits / max(1, ref.shape[0] * k)


def main(smoke: bool = False):
    rng = np.random.default_rng(0)
    n, d, n_centers = (3000, 32, 12) if smoke else (20000, 64, 40)
    n_q, k, n_probe = (16, 20, 4) if smoke else (64, 100, 8)
    centers = rng.normal(size=(n_centers, d)).astype(np.float32) * 5
    X = (centers[rng.integers(0, n_centers, n)]
         + rng.normal(size=(n, d))).astype(np.float32)
    cfg = IVFConfig(dim=d, target_partition_size=100,
                    kmeans_iters=10 if smoke else 20,
                    quantize="int8", rerank_factor=4)
    idx = ivf.build_index(X, cfg=cfg)
    q = jnp.asarray(X[:n_q])
    metrics, gates = {}, {}

    # -- resident scan-tier bytes (the paper's memory axis) -----------------
    vec_bytes = idx.vectors.nbytes
    code_bytes = idx.codes.nbytes + idx.qstats.lo.nbytes + \
        idx.qstats.scale.nbytes
    emit("sq_resident_bytes", 0.0,
         f"codes_mb={code_bytes / 2**20:.2f};f32_mb={vec_bytes / 2**20:.2f};"
         f"ratio={code_bytes / vec_bytes:.3f}")
    metrics["code_to_f32_bytes_ratio"] = code_bytes / vec_bytes

    # -- recall + latency: float32 tier vs int8 tier at rerank factors ------
    spec = Q.knn(k=k, n_probe=n_probe)
    r_f32 = executor.run(idx, q, spec.quantized(False))
    us_f32 = timeit(lambda: executor.run(idx, q, spec.quantized(False)))
    emit(f"sq_f32_scan_k{k}", us_f32, "recall=1.000(reference)")
    metrics["f32_us_per_call"] = us_f32
    ref_ids = np.asarray(r_f32.ids)
    recalls = {}
    for rf in (1, 2, 4):
        idx_rf = dataclasses.replace(
            idx, config=dataclasses.replace(cfg, rerank_factor=rf))
        r = executor.run(idx_rf, q, spec.quantized(True))
        recalls[rf] = _recall(np.asarray(r.ids), ref_ids, k)
        us = timeit(lambda: executor.run(idx_rf, q, spec.quantized(True)))
        emit(f"sq_int8_rerank{rf}_k{k}", us,
             f"recall_at_{k}={recalls[rf]:.3f};vs_f32={us_f32 / us:.2f}x")
        metrics[f"int8_rerank{rf}_us_per_call"] = us
        metrics[f"int8_rerank{rf}_recall_at_{k}"] = recalls[rf]

    # -- int8-domain scan vs dequantize-then-f32 scan (PR 6 tentpole) -------
    # Direct jitted calls on the SAME shared-union plan: the only moving
    # part is the candidate scan's arithmetic domain. Candidate recall is
    # computed by reference-membership (the exact rerank makes recall a
    # pure function of the candidate set).
    plan = executor.plan_ann(idx, q, k=k, n_probe=n_probe)

    def scan_int8(queries, part_ids, qsel, kprime):
        return executor._xla_sq_scan(
            queries, idx.codes, idx.qstats, idx.valid, idx.ids, part_ids,
            kprime, metric=cfg.metric, qsel=qsel, norms=idx.code_norms)

    def scan_dequant(queries, part_ids, qsel, kprime):
        return executor._xla_sq_scan_dequant(
            queries, idx.codes, idx.qstats, idx.valid, idx.ids, part_ids,
            kprime, metric=cfg.metric, qsel=qsel)

    j_int8 = jax.jit(scan_int8, static_argnames=("kprime",))
    j_dequant = jax.jit(scan_dequant, static_argnames=("kprime",))
    # smoke shapes finish in ~1 ms, where scheduler noise swamps a tight
    # ratio -- more iters + a looser pin keep the gate meaningful without
    # flaking. At full size the pin is hardware-aware: with an int8
    # matmul unit (TPU MXU / GPU tensor cores) the integer-domain scan
    # must win outright; on plain CPU the accumulation runs as an f32
    # gemm over BOTH fold terms (2Q x d), so the large-Q sweep is pinned
    # within tolerance and the outright win is gated at Q=1 below -- the
    # paper's on-device regime, where dequant's n*d materialization
    # dominates and the fold wins on any hardware.
    on_accel = jax.default_backend() in ("tpu", "gpu")
    iters = 15 if smoke else 8
    speed_tol = 1.25 if smoke else (1.10 if on_accel else 1.25)
    speed_ok, recall_ok = True, True
    for rf in (1, 2, 4):
        kprime = min(rf * k, int(idx.valid.sum()))
        _, i_i8 = j_int8(plan.queries, plan.part_ids, plan.qsel, kprime)
        _, i_dq = j_dequant(plan.queries, plan.part_ids, plan.qsel, kprime)
        rec_i8 = _cand_recall(np.asarray(i_i8), ref_ids, k)
        rec_dq = _cand_recall(np.asarray(i_dq), ref_ids, k)
        us_i8 = timeit(
            lambda: j_int8(plan.queries, plan.part_ids, plan.qsel, kprime),
            iters=iters)
        us_dq = timeit(
            lambda: j_dequant(plan.queries, plan.part_ids, plan.qsel,
                              kprime), iters=iters)
        emit(f"sq_scan_int8_domain_rf{rf}", us_i8,
             f"recall_at_{k}={rec_i8:.3f};vs_dequant={us_dq / us_i8:.2f}x")
        emit(f"sq_scan_dequant_rf{rf}", us_dq,
             f"recall_at_{k}={rec_dq:.3f}")
        metrics[f"scan_int8_rf{rf}_us"] = us_i8
        metrics[f"scan_dequant_rf{rf}_us"] = us_dq
        metrics[f"scan_int8_rf{rf}_recall"] = rec_i8
        metrics[f"scan_dequant_rf{rf}_recall"] = rec_dq
        speed_ok &= us_i8 <= us_dq * speed_tol
        recall_ok &= rec_i8 + 1e-12 >= rec_dq

    # -- the on-device regime: one query per call (the paper's workload).
    # Here the candidate scan is memory-bound on the probe union and the
    # dequant path pays an n*d f32 materialization the fold never does:
    # the int8-domain scan must win outright on every backend.
    plan1 = executor.plan_ann(idx, jnp.asarray(X[:1]), k=k,
                              n_probe=n_probe)
    kp1 = min(4 * k, int(idx.valid[plan1.part_ids].sum()))
    q1_iters = 30 if smoke else 50
    us_i8_q1 = timeit(
        lambda: j_int8(plan1.queries, plan1.part_ids, plan1.qsel, kp1),
        iters=q1_iters)
    us_dq_q1 = timeit(
        lambda: j_dequant(plan1.queries, plan1.part_ids, plan1.qsel, kp1),
        iters=q1_iters)
    emit("sq_scan_int8_domain_q1", us_i8_q1,
         f"vs_dequant={us_dq_q1 / us_i8_q1:.2f}x")
    emit("sq_scan_dequant_q1", us_dq_q1, "")
    metrics["scan_int8_q1_us"] = us_i8_q1
    metrics["scan_dequant_q1_us"] = us_dq_q1
    # sub-ms region: a small tolerance absorbs scheduler noise without
    # letting a real regression (the measured margin is ~1.6x) slip by
    q1_ok = us_i8_q1 <= us_dq_q1 * (1.25 if smoke else 1.05)

    # acceptance gates (scripts/ci.sh --smoke): the quantized path must not
    # silently rot -- fail loud on the memory ratio or the recall pin
    gates["code_bytes_ratio"] = (
        code_bytes / vec_bytes <= 0.30,
        f"{code_bytes / vec_bytes:.3f} <= 0.30")
    gates["recall_rerank4"] = (
        recalls[4] >= 0.95, f"recall@{k}={recalls[4]:.3f} >= 0.95")
    gates["int8_domain_recall_vs_dequant"] = (
        recall_ok, "int8-domain candidate recall >= dequant at rf 1/2/4")
    gates["int8_domain_speed_vs_dequant"] = (
        speed_ok, f"int8-domain scan <= {speed_tol:.2f}x dequant "
                  f"wall-clock at rf 1/2/4 "
                  f"(backend={jax.default_backend()})")
    gates["int8_domain_q1_faster"] = (
        q1_ok, f"on-device Q=1: int8-domain {us_i8_q1:.0f}us vs "
               f"dequant {us_dq_q1:.0f}us "
               f"({us_dq_q1 / max(us_i8_q1, 1e-9):.2f}x)")
    write_json("quantized", metrics,
               config={"n": n, "d": d, "n_q": n_q, "k": k,
                       "n_probe": n_probe, "smoke": smoke},
               gates=gates)
    assert code_bytes / vec_bytes <= 0.30, \
        f"code tier too large: {code_bytes / vec_bytes:.3f} > 0.30"
    assert recalls[4] >= 0.95, \
        f"int8+rerank4 recall@{k}={recalls[4]:.3f} < 0.95 vs the f32 path"
    assert recall_ok, "int8-domain scan recall regressed vs dequant"
    assert speed_ok, \
        f"int8-domain scan slower than dequant (>{speed_tol:.2f}x)"
    assert q1_ok, \
        f"int8-domain lost the on-device Q=1 regime: {us_i8_q1:.0f}us " \
        f"vs dequant {us_dq_q1:.0f}us"


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes for the CI regression gate")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(smoke=args.smoke)
