"""Fig. 10: incremental vs full index rebuild under updates.

Two sections:

  * `fig10()` -- the original micro-level epochs: delta flush vs full
    rebuild on a bare IVFIndex (recall, rebuild time, write I/O).
  * `churn()` (PR 5, Fig. 10d-style) -- the engine-level sustained
    upsert/delete churn: a MicroNN maintained ONLY by the incremental
    split/merge scheduler (`maintain(until_idle=True)`, no full_rebuild
    ever) against a twin maintained the legacy way (flush + full rebuild
    at 50% mean-size growth). Reports bytes-written-per-row (flash wear)
    and recall@100 against a freshly rebuilt oracle index, and asserts
    the PR's acceptance pins:
      - scheduler recall@100 >= 0.95x the fresh-rebuild oracle's,
      - scheduler write bytes <= 0.25x the rebuild-at-50%-growth arm's,
      - every scheduler step respects max_rows_per_step,
      - the scheduler log contains no "full" rebuild.

`--smoke` shrinks the workload so scripts/ci.sh runs the churn as a
regression gate.
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import delta, ivf, maintenance, search
from repro.core.types import IVFConfig
from repro.data import synthetic
from repro.storage import MicroNN

from .common import emit, _recall, write_json


def fig10():
    ds = synthetic.make("internala", scale=0.04)
    n = len(ds.X)
    half = n // 2
    epoch = max(1, int(n * 0.03))
    cfg = IVFConfig(dim=ds.dim, metric=ds.metric, target_partition_size=100,
                    kmeans_iters=40, delta_capacity=max(1024, epoch + 8))
    row_ids = np.arange(n)

    idx_inc = ivf.build_index(ds.X[:half], ids=row_ids[:half].astype(np.int32),
                              cfg=cfg)
    idx_full = idx_inc
    q = jnp.asarray(ds.Q[:64])
    exact_ids = row_ids[ds.gt[:64, :100]]

    inserted = half
    io_inc = io_full = 0
    for e in range(6):
        hi = min(n, inserted + epoch)
        vec = jnp.asarray(ds.X[inserted:hi])
        ids = jnp.asarray(row_ids[inserted:hi].astype(np.int32))
        attrs = jnp.zeros((hi - inserted, 0))
        inserted = hi

        idx_inc = delta.upsert(idx_inc, vec, ids, attrs)
        t0 = time.perf_counter()
        idx_inc, st_inc = maintenance.flush_delta(idx_inc)
        t_inc = time.perf_counter() - t0
        io_inc += st_inc.bytes_written

        idx_full = delta.upsert(idx_full, vec, ids, attrs)
        t0 = time.perf_counter()
        idx_full, st_full = maintenance.full_rebuild(idx_full)
        t_full = time.perf_counter() - t0
        io_full += st_full.bytes_written

        # recall against the gt restricted to inserted rows
        mask = ds.gt[:64] < inserted
        r_inc = search.ann_search(idx_inc, q, 100, n_probe=8)
        r_full = search.ann_search(idx_full, q, 100, n_probe=8)
        rec_inc = _recall(np.asarray(r_inc.ids), exact_ids, 100)
        rec_full = _recall(np.asarray(r_full.ids), exact_ids, 100)
        emit(f"fig10_epoch{e}", t_inc * 1e6,
             f"recall_inc={rec_inc:.3f};recall_full={rec_full:.3f};"
             f"rebuild_full_us={t_full*1e6:.0f};"
             f"io_inc_MB={io_inc/1e6:.2f};io_full_MB={io_full/1e6:.2f}")
    emit("fig10_io_ratio", 0.0,
         f"incremental_vs_full={io_inc/max(io_full,1):.4f}")


def churn(smoke: bool = False):
    rng = np.random.default_rng(0)
    # sustained growth + update/delete churn (int8 tier, as on device).
    # The Fig. 10d question is the cost of keeping the CLUSTERING healthy
    # under that stream: the scheduler's local split/merge repairs vs the
    # legacy policy's full rebuilds (at 50% mean-size growth). The delta
    # flush is identical work in both arms and reported alongside.
    if smoke:
        n0, d, epochs, target = 3000, 32, 10, 50
        n_q, k, n_probe = 32, 100, 8
    else:
        n0, d, epochs, target = 20000, 64, 10, 100
        n_q, k, n_probe = 64, 100, 8
    grow = n0 // 7                   # ~+14%/epoch: 2+ legacy rebuilds
    n_upd = n0 // 30                 # light in-place churn rides along
    n_del = n0 // 60
    n_centers = max(8, n0 // 200)
    centers = rng.normal(size=(n_centers, d)).astype(np.float32) * 5
    cfg = IVFConfig(dim=d, target_partition_size=target,
                    kmeans_iters=10 if smoke else 20, quantize="int8",
                    delta_capacity=max(1024, grow + n_upd + 8))

    def make_rows(m):
        lab = rng.integers(0, n_centers, m)
        return (centers[lab]
                + rng.normal(size=(m, d)).astype(np.float32))

    X0 = make_rows(n0)
    sched = MicroNN(dim=d, config=cfg)              # split/merge only
    legacy = MicroNN(dim=d, config=cfg)             # legacy flush+rebuild
    for e in (sched, legacy):
        e.upsert(np.arange(n0), X0)
        e.build()

    quantum = sched.scheduler.max_rows_per_step
    live = {i: X0[i] for i in range(n0)}
    next_id = n0
    rows_written = n0
    rebuilds = 0
    t_sched = t_legacy = 0.0
    for ep in range(epochs):
        nv = make_rows(grow)
        ids = np.arange(next_id, next_id + grow)
        next_id += grow
        upd_ids = rng.choice(np.asarray(sorted(live)), n_upd,
                             replace=False)
        upd = make_rows(len(upd_ids))
        del_ids = rng.choice(
            np.setdiff1d(np.asarray(sorted(live)), upd_ids),
            n_del, replace=False)
        rows_written += grow + len(upd_ids)
        for eng in (sched, legacy):
            with eng.session() as s:
                s.upsert(ids, nv)
                s.upsert(upd_ids, upd)
                s.delete(del_ids)
        for i, v in zip(ids, nv):
            live[int(i)] = v
        for i, v in zip(upd_ids, upd):
            live[int(i)] = v
        for i in del_ids:
            del live[int(i)]

        t0 = time.perf_counter()
        reports = sched.maintain(until_idle=True)
        t_sched += time.perf_counter() - t0
        assert all(r.rows <= quantum for r in reports), \
            "scheduler step exceeded max_rows_per_step"
        t0 = time.perf_counter()
        legacy.maintain(force="flush")
        if legacy.maintain() == "rebuild":    # growth/tombstone verdict
            rebuilds += 1
        t_legacy += time.perf_counter() - t0

        io_s = sum(s.bytes_written for s in sched.maintenance_log)
        io_l = sum(s.bytes_written for s in legacy.maintenance_log)
        emit(f"fig10d_epoch{ep}", 0.0,
             f"io_sched_MB={io_s/1e6:.2f};io_legacy_MB={io_l/1e6:.2f};"
             f"steps={len(reports)};k_sched={sched.index.k};"
             f"rebuilds={rebuilds}")

    assert rebuilds >= 2, "workload must trip the legacy rebuild bar"
    assert not any(s.kind == "full" for s in sched.maintenance_log), \
        "scheduler arm must never full-rebuild"

    # recall@100 against the live set's exact top-k; the oracle is a
    # FRESH index rebuilt from the scheduler arm's durable rows
    q = np.stack([live[i] for i in
                  rng.choice(np.asarray(sorted(live)), n_q, replace=False)])
    gt = np.asarray(sched.search(q, k=k, exact=True).ids)
    oracle = MicroNN(dim=d, config=cfg)
    ids_all, _, vecs_all = sched.store.all_rows()
    oracle.upsert(ids_all, vecs_all)
    oracle.build()

    rec_sched = _recall(np.asarray(
        sched.search(q, k=k, n_probe=n_probe).ids), gt, k)
    rec_legacy = _recall(np.asarray(
        legacy.search(q, k=k, n_probe=n_probe).ids), gt, k)
    rec_oracle = _recall(np.asarray(
        oracle.search(q, k=k, n_probe=n_probe).ids), gt, k)

    # clustering-maintenance bytes: local repairs vs full rebuilds (the
    # delta flush is the same work in both arms -- reported, not compared)
    repair = sum(s.bytes_written for s in sched.maintenance_log
                 if s.kind != "incremental")
    rebuild = sum(s.bytes_written for s in legacy.maintenance_log
                  if s.kind == "full")
    flush_s = sum(s.bytes_written for s in sched.maintenance_log
                  if s.kind == "incremental")
    flush_l = sum(s.bytes_written for s in legacy.maintenance_log
                  if s.kind == "incremental")
    emit("fig10d_recall", 0.0,
         f"sched={rec_sched:.3f};legacy={rec_legacy:.3f};"
         f"oracle={rec_oracle:.3f};ratio={rec_sched/max(rec_oracle,1e-9):.3f}")
    emit("fig10d_write_bytes", 0.0,
         f"repair_per_row={repair/rows_written:.0f};"
         f"rebuild_per_row={rebuild/rows_written:.0f};"
         f"flush_per_row={flush_s/rows_written:.0f};"
         f"total_sched_MB={(repair+flush_s)/1e6:.2f};"
         f"total_legacy_MB={(rebuild+flush_l)/1e6:.2f};"
         f"repair_vs_rebuild={repair/max(rebuild,1):.3f};"
         f"maintain_s_sched={t_sched:.2f};maintain_s_legacy={t_legacy:.2f}")

    # trajectory artifact: measurements + gate outcomes, validated by
    # scripts/check_bench_json.py in CI (written before the asserts so a
    # regression leaves a machine-readable record of what regressed)
    write_json(
        "updates",
        {"recall_sched": rec_sched, "recall_legacy": rec_legacy,
         "recall_oracle": rec_oracle, "repair_bytes": repair,
         "rebuild_bytes": rebuild, "flush_bytes_sched": flush_s,
         "flush_bytes_legacy": flush_l, "rows_written": rows_written,
         "maintain_s_sched": t_sched, "maintain_s_legacy": t_legacy},
        config={"n0": n0, "d": d, "epochs": epochs, "k": k,
                "n_probe": n_probe, "smoke": smoke},
        gates={
            "recall_vs_oracle": (
                rec_sched >= 0.95 * rec_oracle,
                f"{rec_sched:.3f} >= 0.95 * {rec_oracle:.3f}"),
            "repair_io_vs_rebuild": (
                repair <= 0.25 * rebuild,
                f"{repair}B <= 0.25 * {rebuild}B"),
            "total_io_vs_legacy": (
                repair + flush_s <= rebuild + flush_l,
                f"{repair + flush_s}B <= {rebuild + flush_l}B"),
        })

    # acceptance pins (scripts/ci.sh --smoke regression gate)
    assert rec_sched >= 0.95 * rec_oracle, \
        f"scheduler recall {rec_sched:.3f} < 0.95x oracle {rec_oracle:.3f}"
    assert repair <= 0.25 * rebuild, \
        f"local repairs wrote {repair}B > 0.25x the rebuild arm's " \
        f"{rebuild}B of clustering maintenance"
    assert repair + flush_s <= rebuild + flush_l, \
        "scheduler total maintenance I/O exceeded the rebuild arm's"


def main(smoke: bool = False):
    if not smoke:
        fig10()
    churn(smoke=smoke)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small workload + acceptance asserts (CI gate)")
    main(**vars(ap.parse_args()))
