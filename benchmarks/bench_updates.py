"""Fig. 10: incremental vs full index rebuild across insert epochs:
recall, per-query latency, rebuild time, write I/O."""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import delta, ivf, maintenance, search
from repro.core.types import IVFConfig
from repro.data import synthetic

from .common import emit, _recall


def main():
    ds = synthetic.make("internala", scale=0.04)
    n = len(ds.X)
    half = n // 2
    epoch = max(1, int(n * 0.03))
    cfg = IVFConfig(dim=ds.dim, metric=ds.metric, target_partition_size=100,
                    kmeans_iters=40, delta_capacity=max(1024, epoch + 8))
    row_ids = np.arange(n)

    idx_inc = ivf.build_index(ds.X[:half], ids=row_ids[:half].astype(np.int32),
                              cfg=cfg)
    idx_full = idx_inc
    q = jnp.asarray(ds.Q[:64])
    exact_ids = row_ids[ds.gt[:64, :100]]

    inserted = half
    io_inc = io_full = 0
    for e in range(6):
        hi = min(n, inserted + epoch)
        vec = jnp.asarray(ds.X[inserted:hi])
        ids = jnp.asarray(row_ids[inserted:hi].astype(np.int32))
        attrs = jnp.zeros((hi - inserted, 0))
        inserted = hi

        idx_inc = delta.upsert(idx_inc, vec, ids, attrs)
        t0 = time.perf_counter()
        idx_inc, st_inc = maintenance.flush_delta(idx_inc)
        t_inc = time.perf_counter() - t0
        io_inc += st_inc.bytes_written

        idx_full = delta.upsert(idx_full, vec, ids, attrs)
        t0 = time.perf_counter()
        idx_full, st_full = maintenance.full_rebuild(idx_full)
        t_full = time.perf_counter() - t0
        io_full += st_full.bytes_written

        # recall against the gt restricted to inserted rows
        mask = ds.gt[:64] < inserted
        r_inc = search.ann_search(idx_inc, q, 100, n_probe=8)
        r_full = search.ann_search(idx_full, q, 100, n_probe=8)
        rec_inc = _recall(np.asarray(r_inc.ids), exact_ids, 100)
        rec_full = _recall(np.asarray(r_full.ids), exact_ids, 100)
        emit(f"fig10_epoch{e}", t_inc * 1e6,
             f"recall_inc={rec_inc:.3f};recall_full={rec_full:.3f};"
             f"rebuild_full_us={t_full*1e6:.0f};"
             f"io_inc_MB={io_inc/1e6:.2f};io_full_MB={io_full/1e6:.2f}")
    emit("fig10_io_ratio", 0.0,
         f"incremental_vs_full={io_inc/max(io_full,1):.4f}")


if __name__ == "__main__":
    main()
