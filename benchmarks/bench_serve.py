"""Serving front door under mixed load (PR 7): sustained QPS vs recall
with cross-request micro-batching, concurrent session writes, and the
daemonized maintenance scheduler.

Two arms over byte-identical copies of one built database, driving the
SAME fixed workload (T closed-loop reader threads x R single-vector
queries each, plus a writer thread applying W deterministic re-upsert
sessions, so both arms end in the same durable state):

  * `solo`    -- FrontDoor(window_s=0, max_batch_rows=1), daemon off:
                 the one-request-at-a-time baseline.
  * `coalesce`-- FrontDoor(window_s=2ms), maintenance daemon on: the
                 PR's serving configuration.

The writer re-upserts EXISTING rows with their original vectors -- real
write-path work (sessions, delta, flush quanta) whose net semantic
effect is nil, so exact ground truth computed once up front stays valid
and recall under churn is measurable.

Gates (scripts/ci.sh --smoke regression surface, persisted to
BENCH_serve.json):

  * parity_batched_vs_solo -- a forced fused call returns every caller
    bit-identical ids+scores vs direct engine.query().
  * daemon_off_equivalence -- both arms' engines end with identical row
    sets and order-insensitive-identical exact search results: the
    daemon changes WHEN maintenance runs, never what is stored.
  * qps_floor / p99_bound  -- the coalescing arm sustains a minimum
    throughput with bounded tail latency.
  * coalescing_uplift      -- coalescing beats the one-at-a-time
    baseline's sustained QPS.
  * recall_under_load      -- answers served mid-churn keep recall@k
    against the exact oracle.
"""
import glob
import os
import shutil
import tempfile
import threading
import time

import numpy as np

from repro.core.query import Q
from repro.core.types import IVFConfig
from repro.serving import FrontDoor
from repro.storage import MicroNN

from .common import emit, _recall, write_json

DIM = 32
K = 10
N_PROBE = 8


def _clustered(n, seed, scale=5.0, n_clusters=24):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_clusters, DIM)).astype(np.float32) * scale
    asg = rng.integers(0, n_clusters, n)
    return (centers[asg]
            + rng.normal(size=(n, DIM)).astype(np.float32))


def _copy_db(src, dst):
    for f in glob.glob(src + "*"):
        shutil.copy(f, dst + f[len(src):])


def _run_arm(eng, probes, gt, *, window_s, max_batch_rows, maintenance,
             threads, write_batches, write_rows, X):
    """Drive the fixed mixed workload through one front-door config;
    returns (qps, recall, frontdoor stats)."""
    per = len(probes) // threads
    hits = np.zeros((len(probes), K), np.int64)
    errors = []

    with FrontDoor(eng, window_s=window_s, max_batch_rows=max_batch_rows,
                   maintenance=maintenance) as fd:
        # warm both compile paths (solo bucket + fused bucket) so the
        # measured phase times serving, not tracing
        fd.query(probes[0], Q.knn(k=K, n_probe=N_PROBE), timeout=120)
        warm = [fd.submit(probes[i % len(probes)],
                          Q.knn(k=K, n_probe=N_PROBE))
                for i in range(max(2, min(threads, max_batch_rows)))]
        [f.result(120) for f in warm]

        def reader(t):
            spec = Q.knn(k=K, n_probe=N_PROBE)
            try:
                for i in range(t * per, (t + 1) * per):
                    rs = fd.query(probes[i], spec, timeout=120)
                    hits[i] = np.asarray(rs.ids)[0]
            except BaseException as e:  # noqa: BLE001
                errors.append(e)

        def writer():
            try:
                rng = np.random.default_rng(7)
                for _ in range(write_batches):
                    ids = rng.choice(len(X), size=write_rows, replace=False)
                    with eng.session() as s:
                        s.upsert(ids.astype(np.int64), X[ids])
            except BaseException as e:  # noqa: BLE001
                errors.append(e)

        ts = [threading.Thread(target=reader, args=(t,))
              for t in range(threads)] + [threading.Thread(target=writer)]
        t0 = time.perf_counter()
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        wall = time.perf_counter() - t0
        assert not errors, errors
        fd.drain(120)
        stats = fd.stats()

    eng.maintain(until_idle=True)
    n_served = threads * per
    qps = n_served / wall
    rec = _recall(hits[:n_served], gt[:n_served], K)
    return qps, rec, stats


def serve(smoke: bool = False):
    n = 1500 if smoke else 6000
    threads = 6
    per = 30 if smoke else 120           # requests per reader thread
    write_batches = 4 if smoke else 12
    write_rows = 64
    n_q = threads * per

    cfg = IVFConfig(dim=DIM, target_partition_size=64, kmeans_iters=12,
                    delta_capacity=256)
    X = _clustered(n, seed=5)
    probes = _clustered(n_q, seed=6, scale=5.0)

    with tempfile.TemporaryDirectory() as tmp:
        ref = os.path.join(tmp, "ref.db")
        builder = MicroNN(dim=DIM, path=ref, config=cfg)
        builder.upsert(np.arange(n), X)
        builder.build()
        gt = np.asarray(builder.query(probes, Q.exact(k=K)).ids)
        builder.store.close()

        # byte-identical starting state for both arms
        solo_db = os.path.join(tmp, "solo.db")
        coal_db = os.path.join(tmp, "coal.db")
        _copy_db(ref, solo_db)
        _copy_db(ref, coal_db)
        eng_solo = MicroNN(dim=DIM, path=solo_db, config=cfg)
        eng_solo.recover()
        eng_coal = MicroNN(dim=DIM, path=coal_db, config=cfg)
        eng_coal.recover()

        common = dict(threads=threads, write_batches=write_batches,
                      write_rows=write_rows, X=X)
        qps_solo, rec_solo, st_solo = _run_arm(
            eng_solo, probes, gt, window_s=0.0, max_batch_rows=1,
            maintenance=False, **common)
        qps_coal, rec_coal, st_coal = _run_arm(
            eng_coal, probes, gt, window_s=0.002, max_batch_rows=64,
            maintenance=True, **common)

        emit("serve_solo_qps", 1e6 / qps_solo,
             f"qps={qps_solo:.1f};recall={rec_solo:.3f};"
             f"p99_ms={st_solo['total_p99_ms']:.1f}")
        emit("serve_coalesce_qps", 1e6 / qps_coal,
             f"qps={qps_coal:.1f};recall={rec_coal:.3f};"
             f"p99_ms={st_coal['total_p99_ms']:.1f};"
             f"occupancy={st_coal['batch_occupancy']:.2f};"
             f"coalesced={st_coal['coalesced']}")

        # -- gate: forced fused call == solo query(), bitwise ------------
        spec = Q.knn(k=K, n_probe=N_PROBE)
        refs = [eng_coal.query(probes[i], spec) for i in range(7)]
        with FrontDoor(eng_coal, window_s=0.3, max_batch_rows=64) as fd:
            futs = [fd.submit(probes[i], spec) for i in range(7)]
            outs = [f.result(120) for f in futs]
            fused = fd.stats()["coalesced"]
        parity = fused >= 2 and all(
            np.array_equal(np.asarray(a.ids), np.asarray(b.ids))
            and np.array_equal(np.asarray(a.scores), np.asarray(b.scores))
            for a, b in zip(outs, refs))

        # -- gate: daemon on/off leaves identical durable state ----------
        ids_a, _, vecs_a = eng_solo.store.all_rows()
        ids_b, _, vecs_b = eng_coal.store.all_rows()
        oa, ob = np.argsort(ids_a), np.argsort(ids_b)
        rows_equal = (np.array_equal(ids_a[oa], ids_b[ob])
                      and np.array_equal(vecs_a[oa], vecs_b[ob]))
        ex_a = eng_solo.query(probes[:8], Q.exact(k=K))
        ex_b = eng_coal.query(probes[:8], Q.exact(k=K))
        exact_equal = (np.array_equal(np.sort(np.asarray(ex_a.ids), 1),
                                      np.sort(np.asarray(ex_b.ids), 1))
                       and np.array_equal(
                           np.sort(np.asarray(ex_a.scores), 1),
                           np.sort(np.asarray(ex_b.scores), 1)))
        daemon_equiv = rows_equal and exact_equal

        eng_solo.store.close()
        eng_coal.store.close()

    qps_floor = 5.0
    p99_bound_ms = 4000.0 if smoke else 2000.0
    uplift_min = 1.02
    recall_floor = 0.80

    write_json(
        "serve",
        metrics={"qps_solo": qps_solo, "qps_coalesce": qps_coal,
                 "recall_solo": rec_solo, "recall_coalesce": rec_coal,
                 "p99_solo_ms": st_solo["total_p99_ms"],
                 "p99_coalesce_ms": st_coal["total_p99_ms"],
                 "queue_wait_p50_ms": st_coal["queue_wait_p50_ms"],
                 "batch_occupancy": st_coal["batch_occupancy"],
                 "coalesced": st_coal["coalesced"],
                 "batches": st_coal["batches"]},
        config={"n": n, "dim": DIM, "k": K, "n_probe": N_PROBE,
                "threads": threads, "per_thread": per,
                "write_batches": write_batches, "write_rows": write_rows,
                "smoke": smoke},
        gates={
            "parity_batched_vs_solo": (
                parity, f"{fused} fused callers bit-identical to solo"),
            "daemon_off_equivalence": (
                daemon_equiv,
                f"rows_equal={rows_equal} exact_equal={exact_equal}"),
            "qps_floor": (qps_coal >= qps_floor,
                          f"{qps_coal:.1f} >= {qps_floor}"),
            "p99_bound": (st_coal["total_p99_ms"] <= p99_bound_ms,
                          f"{st_coal['total_p99_ms']:.1f}ms"
                          f" <= {p99_bound_ms}ms"),
            "coalescing_uplift": (
                qps_coal >= uplift_min * qps_solo,
                f"{qps_coal:.1f} >= {uplift_min} * {qps_solo:.1f}"),
            "recall_under_load": (
                min(rec_solo, rec_coal) >= recall_floor,
                f"min({rec_solo:.3f}, {rec_coal:.3f})"
                f" >= {recall_floor}"),
        })

    # acceptance pins (scripts/ci.sh --smoke regression gate)
    assert parity, "fused micro-batch diverged from solo query()"
    assert daemon_equiv, "daemon on/off reached different durable states"
    assert qps_coal >= qps_floor, f"QPS {qps_coal:.1f} < {qps_floor}"
    assert st_coal["total_p99_ms"] <= p99_bound_ms, \
        f"p99 {st_coal['total_p99_ms']:.1f}ms > {p99_bound_ms}ms"
    assert qps_coal >= uplift_min * qps_solo, \
        f"coalescing uplift {qps_coal / max(qps_solo, 1e-9):.2f}x" \
        f" < {uplift_min}x"
    assert min(rec_solo, rec_coal) >= recall_floor, \
        f"recall under load below {recall_floor}"


def main(smoke: bool = False):
    serve(smoke=smoke)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small workload + acceptance asserts (CI gate)")
    main(**vars(ap.parse_args()))
