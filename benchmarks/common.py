"""Benchmark helpers: timing, recall targets, CSV emission.

Output convention (one line per measurement):
    name,us_per_call,derived
`derived` carries the figure-specific quantity (recall, MB, ratio, ...).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def timeit(fn: Callable, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time per call in microseconds (blocks on jax arrays)."""
    def run():
        out = fn()
        for leaf in jax.tree.leaves(out):
            if hasattr(leaf, "block_until_ready"):
                leaf.block_until_ready()
        return out
    for _ in range(warmup):
        run()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        run()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def n_probe_for_recall(search_fn, exact_ids: np.ndarray, k: int,
                       target: float = 0.9, probes=(1, 2, 4, 8, 16, 32, 64)):
    """Smallest n_probe reaching the recall target (paper methodology)."""
    for n in probes:
        ids = np.asarray(search_fn(n).ids)
        rec = _recall(ids, exact_ids, k)
        if rec >= target:
            return n, rec
    return probes[-1], rec


def _recall(ids: np.ndarray, exact_ids: np.ndarray, k: int) -> float:
    """recall@k; the denominator counts only *real* exact ids so hybrid
    queries whose predicate qualifies fewer than k rows aren't penalised
    for results that cannot exist."""
    hits = denom = 0
    for a, b in zip(ids[:, :k], exact_ids[:, :k]):
        real = set(int(x) for x in b if x >= 0)
        hits += len(set(int(x) for x in a if x >= 0) & real)
        denom += max(1, len(real))
    return hits / denom
