"""Benchmark helpers: timing, recall targets, CSV + JSON emission.

Output convention (one line per measurement):
    name,us_per_call,derived
`derived` carries the figure-specific quantity (recall, MB, ratio, ...).

Besides the CSV rows, every bench can persist a machine-readable
trajectory artifact via `write_json(name, metrics, config, gates)`:
a `BENCH_<name>.json` file holding the measured metrics, the bench
configuration, the git revision, and the pass/fail state of each
acceptance gate. scripts/ci.sh points BENCH_JSON_DIR at a scratch
directory, runs the smoke benches, and then validates the artifacts
(scripts/check_bench_json.py) -- a bench that silently stopped
measuring, or a gate that regressed past its pinned threshold, fails
CI on the artifact, not just on a stray assert.
"""
from __future__ import annotations

import datetime
import json
import os
import subprocess
import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

ROWS: List[str] = []

SCHEMA_VERSION = 1

# artifact names written by this process (run.py uses it to avoid
# clobbering a bench's own richer artifact with the generic row dump)
WRITTEN: set = set()

_JSON_DIR: Optional[str] = None


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def set_json_dir(path: Optional[str]):
    """Programmatic override of the artifact directory (run.py
    --json-dir); the BENCH_JSON_DIR env var is the ambient default."""
    global _JSON_DIR
    _JSON_DIR = path


def json_dir() -> Optional[str]:
    return _JSON_DIR or os.environ.get("BENCH_JSON_DIR") or None


def _git_rev() -> Optional[str]:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        return out.stdout.strip() or None
    except OSError:
        return None


def write_json(name: str, metrics: Dict, config: Optional[Dict] = None,
               gates: Optional[Dict] = None) -> Optional[str]:
    """Persist one bench's trajectory artifact as BENCH_<name>.json.

    `gates` maps gate name -> (passed, detail) or a plain bool; the CI
    validator fails the build if any gate did not pass. No-op (returns
    None) unless a JSON dir is configured -- standalone bench runs
    without BENCH_JSON_DIR just print CSV as before."""
    d = json_dir()
    if d is None:
        return None
    norm = {}
    for g, v in (gates or {}).items():
        if isinstance(v, tuple):
            passed, detail = v
        else:
            passed, detail = v, ""
        norm[g] = {"passed": bool(passed), "detail": str(detail)}
    doc = {
        "schema_version": SCHEMA_VERSION,
        "name": name,
        "timestamp": datetime.datetime.now(
            datetime.timezone.utc).isoformat(),
        "git_rev": _git_rev(),
        "config": config or {},
        "metrics": metrics,
        "gates": norm,
    }
    # PR 8: every artifact carries the process metrics-registry snapshot
    # -- the counters behind the measurements (pager hits/misses, jit
    # compiles, scheduler rows moved) ride along for post-hoc analysis.
    # Guarded so a bench without the obs layer still writes its artifact.
    try:
        from repro.obs import metrics as _obs_metrics
        doc["metrics_registry"] = _obs_metrics.default_registry().snapshot()
    except Exception:
        pass
    os.makedirs(d, exist_ok=True)
    path = os.path.join(d, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    WRITTEN.add(name)
    print(f"wrote {path}", flush=True)
    return path


def timeit(fn: Callable, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time per call in microseconds (blocks on jax arrays)."""
    def run():
        out = fn()
        for leaf in jax.tree.leaves(out):
            if hasattr(leaf, "block_until_ready"):
                leaf.block_until_ready()
        return out
    for _ in range(warmup):
        run()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        run()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def n_probe_for_recall(search_fn, exact_ids: np.ndarray, k: int,
                       target: float = 0.9, probes=(1, 2, 4, 8, 16, 32, 64)):
    """Smallest n_probe reaching the recall target (paper methodology)."""
    for n in probes:
        ids = np.asarray(search_fn(n).ids)
        rec = _recall(ids, exact_ids, k)
        if rec >= target:
            return n, rec
    return probes[-1], rec


def _recall(ids: np.ndarray, exact_ids: np.ndarray, k: int) -> float:
    """recall@k; the denominator counts only *real* exact ids so hybrid
    queries whose predicate qualifies fewer than k rows aren't penalised
    for results that cannot exist."""
    hits = denom = 0
    for a, b in zip(ids[:, :k], exact_ids[:, :k]):
        real = set(int(x) for x in b if x >= 0)
        hits += len(set(int(x) for x in a if x >= 0) & real)
        denom += max(1, len(real))
    return hits / denom
