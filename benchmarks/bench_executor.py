"""Unified executor: backend comparison + plan/compile-cache latency.

Two claims measured against the seed implementation of Alg. 2 (kept
below as `_seed_ann_search`, the per-query-gather path that jit-retraced
for every new batch size):

  1. repeated-query latency: a stream of variable-size batches hits the
     executor's bucketed plan cache (compiles once per power-of-two
     bucket) while the seed path recompiles per batch size -- emitted as
     total wall time over the stream plus trace counts;
  2. steady-state latency + backend parity: executor XLA backend vs the
     seed gather path vs the Pallas (interpret) backend on a fixed shape.
"""
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import executor, ivf, search
from repro.core.topk import dedup_by_id, mask_scores, topk_smallest
from repro.core.types import IVFConfig, normalize_if_cosine, pairwise_scores

from .common import emit, timeit

_SEED_TRACES = 0


@partial(jax.jit, static_argnames=("k", "n_probe"))
def _seed_ann_search(index, queries, k, n_probe):
    """The seed's Alg. 2: per-query partition gather ([Q, n, p_max, d]
    intermediates) + fused scan. Reproduced verbatim as the baseline."""
    global _SEED_TRACES
    _SEED_TRACES += 1
    cfg = index.config
    q = normalize_if_cosine(queries.astype(jnp.float32), cfg.metric)
    parts = executor.find_nearest_centroids(index, q, n_probe)

    pv = index.vectors[parts]                              # [Q, n, p_max, d]
    pid = index.ids[parts]
    pok = index.valid[parts]
    dots = jnp.einsum("qd,qnpd->qnp", q, pv)
    if cfg.metric in ("ip", "cosine"):
        scores = -dots
    else:
        q2 = jnp.sum(q * q, axis=-1)[:, None, None]
        v2 = jnp.sum(pv * pv, axis=-1)
        scores = q2 + v2 - 2.0 * dots
    scores = mask_scores(scores, pok)

    Q = q.shape[0]
    flat_s = scores.reshape(Q, -1)
    flat_i = pid.reshape(Q, -1)

    d = index.delta
    ds = pairwise_scores(q, d.vectors, cfg.metric)
    ds = mask_scores(ds, d.valid[None, :])
    di = jnp.broadcast_to(d.ids[None, :], ds.shape)
    all_s = jnp.concatenate([flat_s, ds], axis=-1)
    all_i = jnp.concatenate([flat_i, di], axis=-1)
    s, i = topk_smallest(all_s, all_i, min(k, all_s.shape[-1]))
    return dedup_by_id(s, i)


def _block(out):
    for leaf in jax.tree.leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


def main():
    global _SEED_TRACES
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(40, 64)).astype(np.float32) * 5
    X = (centers[rng.integers(0, 40, 8000)]
         + rng.normal(size=(8000, 64))).astype(np.float32)
    cfg = IVFConfig(dim=64, target_partition_size=100, kmeans_iters=20)
    idx = ivf.build_index(X, cfg=cfg)
    k, n_probe = 100, 8

    # -- 1. variable-batch serving stream: compile-cache behaviour ----------
    # warm a few batch sizes, then measure previously-unseen sizes: the
    # executor's bucketed cache serves them without retracing, the seed
    # path pays a fresh jit compile per distinct size (the engine's
    # per-call recompile this layer removes).
    for s in (1, 3, 16, 32):
        _block(search.ann_search(idx, jnp.asarray(X[:s]), k, n_probe))
        _block(_seed_ann_search(idx, jnp.asarray(X[:s]), k, n_probe))
    fresh = [5, 10, 19, 23, 29]
    c0, s0 = executor.trace_count(), _SEED_TRACES
    t0 = time.perf_counter()
    for s in fresh:
        _block(search.ann_search(idx, jnp.asarray(X[:s]), k, n_probe))
    exec_fresh = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    for s in fresh:
        _block(_seed_ann_search(idx, jnp.asarray(X[:s]), k, n_probe))
    seed_fresh = (time.perf_counter() - t0) * 1e6
    emit("exec_fresh_sizes", exec_fresh / len(fresh),
         f"retraces={executor.trace_count() - c0}_of_{len(fresh)}")
    emit("seed_fresh_sizes", seed_fresh / len(fresh),
         f"retraces={_SEED_TRACES - s0}_of_{len(fresh)};"
         f"fresh_size_speedup={seed_fresh / exec_fresh:.2f}x")

    # -- 2. fixed-shape steady state: backends vs seed gather ---------------
    for Q in (1, 8, 64):
        q = jnp.asarray(X[:Q])
        us_seed = timeit(lambda: _seed_ann_search(idx, q, k, n_probe))
        us_xla = timeit(lambda: search.ann_search(idx, q, k, n_probe,
                                                  backend="xla"))
        emit(f"exec_xla_q{Q}", us_xla,
             f"seed_us={us_seed:.0f};vs_seed={us_seed / us_xla:.2f}x")
    # Pallas interpret mode is a functional (not performance) proxy off-TPU;
    # measure a small shape so the row stays cheap
    q = jnp.asarray(X[:4])
    us_pal = timeit(lambda: search.ann_search(idx, q, k, n_probe,
                                              backend="pallas"), iters=3)
    r_x = search.ann_search(idx, q, k, n_probe, backend="xla")
    r_p = search.ann_search(idx, q, k, n_probe, backend="pallas")
    agree = float((np.asarray(r_x.ids) == np.asarray(r_p.ids)).mean())
    emit("exec_pallas_interpret_q4", us_pal, f"id_agreement={agree:.3f}")


if __name__ == "__main__":
    main()
