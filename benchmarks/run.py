"""Benchmark driver -- one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig9]

Prints ``name,us_per_call,derived`` CSV rows.
"""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from . import (bench_build, bench_e2e, bench_executor, bench_hybrid,
                   bench_minibatch, bench_mqo, bench_paged, bench_quantized,
                   bench_roofline, bench_updates)
    sections = {
        "fig4_5_e2e": bench_e2e.main,
        "fig6_build": bench_build.main,
        "fig7_hybrid": bench_hybrid.main,
        "fig8_minibatch": bench_minibatch.main,
        "fig9_mqo": bench_mqo.main,
        "fig10_updates": bench_updates.main,
        "roofline": bench_roofline.main,
        "executor": bench_executor.main,
        "quantized": bench_quantized.main,
        "paged": bench_paged.main,
    }
    print("name,us_per_call,derived")
    failed = 0
    for name, fn in sections.items():
        if args.only and args.only not in name:
            continue
        try:
            fn()
        except Exception:
            failed += 1
            print(f"{name},0.0,ERROR", file=sys.stderr)
            traceback.print_exc()
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
