"""Benchmark driver -- one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig9] [--json-dir out/]

Prints ``name,us_per_call,derived`` CSV rows. With `--json-dir` (or the
BENCH_JSON_DIR env var) every section also persists a BENCH_<name>.json
trajectory artifact: sections with their own rich emitter write it
directly, the rest get a generic dump of their CSV rows.
"""
import argparse
import sys
import traceback

from . import common


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--json-dir", default=None,
                    help="write BENCH_<section>.json artifacts here "
                         "(defaults to $BENCH_JSON_DIR if set)")
    args = ap.parse_args()
    if args.json_dir:
        common.set_json_dir(args.json_dir)

    from . import (bench_build, bench_e2e, bench_executor, bench_fleet,
                   bench_hybrid, bench_minibatch, bench_mqo, bench_obs,
                   bench_paged, bench_quantized, bench_roofline,
                   bench_serve, bench_updates)
    sections = {
        "fig4_5_e2e": bench_e2e.main,
        "fig6_build": bench_build.main,
        "fig7_hybrid": bench_hybrid.main,
        "fig8_minibatch": bench_minibatch.main,
        "fig9_mqo": bench_mqo.main,
        "fig10_updates": bench_updates.main,
        "roofline": bench_roofline.main,
        "executor": bench_executor.main,
        "quantized": bench_quantized.main,
        "paged": bench_paged.main,
        "serve": bench_serve.main,
        "obs": bench_obs.main,
        "fleet": bench_fleet.main,
    }
    print("name,us_per_call,derived")
    failed = 0
    for name, fn in sections.items():
        if args.only and args.only not in name:
            continue
        before = len(common.ROWS)
        try:
            fn()
        except Exception:
            failed += 1
            print(f"{name},0.0,ERROR", file=sys.stderr)
            traceback.print_exc()
            continue
        if common.json_dir() and name not in common.WRITTEN:
            # generic artifact for sections without a dedicated emitter
            rows = [r.split(",", 2) for r in common.ROWS[before:]]
            common.write_json(name, {
                "rows": [{"name": r[0], "us_per_call": float(r[1]),
                          "derived": r[2] if len(r) > 2 else ""}
                         for r in rows]})
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
