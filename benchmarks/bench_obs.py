"""Observability overhead + reconciliation gate (PR 8).

The obs layer's contract is "free when off, exact when on":

  * **free when off** -- the tracing hooks threaded through executor.run,
    the pager fault path, and the engine planner must cost <= 3% on the
    hot query paths when no trace is active. Two arms, measured
    interleaved (enabled/disabled alternate every call, min per mode so
    scheduler noise and frequency drift cancel):

      - `exec_xla_q1`: the resident fused scan (n=8000, d=64, k=100,
        n_probe=8, backend=xla, Q=1) through search.ann_search ->
        executor.run -- the repo's headline single-query latency;
      - `paged`: engine.query on the disk-resident path (int8 scan tier
        under a small frame-pool budget) -- the fault-path hooks.

    Baseline is `trace.set_enabled(False)` (the global kill-switch: every
    hook short-circuits on one module-bool test); the measured arm is the
    normal configuration, enabled=True with NO active trace (the default
    production hot path: one thread-local lookup per hook site).

  * **exact when on** -- an explain() trace's counters must reconcile
    exactly against the independent registry-backed component counters:
    pager_fault hits/misses/bytes_read == the pager stats() delta across
    the traced call, and the scan span's `compiled` == the executor
    trace-count delta. Asserted here on both engine modes, gated into
    BENCH_obs.json.

  * **zero allocation when off** -- untraced queries must not create new
    registry series (registry.size() stable).

PR 10 adds the flight-recorder contracts to the same gate:

  * **recording off is free** -- the recorder hook in MicroNN.query is
    one global load + branch when no recorder is installed; the A/B
    arms an armed-but-sampling-out recorder (a strict upper bound on
    the off path) against uninstalled and holds both engine modes to
    the same <= 3% tolerance.
  * **replay is bit-exact** -- workloads captured on the resident
    (xla + pallas), paged, and multi-tenant Fleet paths replay to
    bit-identical ids + scores (obs.recorder.replay strict mode).

`--smoke` shrinks shapes for scripts/ci.sh; the full run uses the
bench_executor exec_xla_q1 shape verbatim.
"""
import os
import tempfile
import time

import numpy as np

from repro.core import executor, ivf, search
from repro.core.query import Q
from repro.core.types import IVFConfig
from repro.obs import metrics as obs_metrics
from repro.obs import recorder as obs_recorder
from repro.obs import trace as obs_trace
from repro.storage import MicroNN

from .common import emit, write_json

OVERHEAD_TOL = 1.03     # tracing-off hot path <= 3% over the kill-switch


def _block(out):
    import jax
    for leaf in jax.tree.leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


def _ab_arm(fn, *, calls: int, repeats: int = 3,
            toggle=obs_trace.set_enabled, restore: bool = True):
    """Paired-difference A/B: each pair runs one enabled and one
    disabled call back-to-back (order alternating per pair so neither
    mode is systematically first), GC off. Adjacent calls share the
    same noise regime (CPU frequency, cache state, allocator phase),
    so the per-pair (on - off) delta isolates the systematic hook cost
    while min- or median-of-independent-samples would need the two
    modes' noise floors to coincide -- which on a shared CI container
    they don't. The second call of a pair is also systematically
    faster (warmer caches), which shifts on-first deltas up and
    off-first deltas down by the same slot bias; the combined delta
    population is therefore BImodal and its median lands anywhere
    between the modes, so the estimator takes the median per pair
    ORDER and averages the two -- the slot bias cancels exactly.

    The whole A/B runs `repeats` independent windows and keeps the
    smallest debiased delta: the hook cost is systematic (present in
    every window), so the min over windows is the tightest upper bound
    on it, while a bursty window (another container stealing the core
    mid-run) can only inflate a delta, never deflate all of them.
    Returns (on_us, off_us) with on_us = off + debiased delta.
    """
    import gc
    best_delta, best_off = None, None
    gc_was = gc.isenabled()
    gc.disable()
    try:
        for _ in range(repeats):
            d_on_first, d_off_first, offs = [], [], []
            for pair in range(calls // 2):
                on_first = pair % 2 == 0
                order = (True, False) if on_first else (False, True)
                t = {}
                for flag in order:
                    toggle(flag)
                    t0 = time.perf_counter()
                    _block(fn())
                    t[flag] = (time.perf_counter() - t0) * 1e6
                (d_on_first if on_first else d_off_first).append(
                    t[True] - t[False])
                offs.append(t[False])
            delta = (float(np.median(d_on_first))
                     + float(np.median(d_off_first))) / 2.0
            if best_delta is None or delta < best_delta:
                best_delta, best_off = delta, float(np.median(offs))
    finally:
        toggle(restore)
        if gc_was:
            gc.enable()
    return best_off + best_delta, best_off


def _recorder_toggle(rec):
    """A/B toggle for the flight-recorder arm: flag=True installs a
    sampling-everything-out recorder (the worst legal 'hook armed' cost
    -- one lock + modulo + counter bump per call, never an encode),
    flag=False is the production recording-off path (one global load +
    branch). Restore state is False: recording stays off after."""
    def toggle(flag):
        if flag:
            obs_recorder.install(rec)
        else:
            obs_recorder.uninstall(rec)
    return toggle


def main(smoke: bool = False):
    metrics, gates = {}, {}
    rng = np.random.default_rng(0)
    if smoke:
        n, d, n_centers, k, n_probe = 3000, 64, 24, 100, 8
        kmeans_iters, calls_exec, calls_paged = 8, 400, 160
        n_paged, d_paged = 3000, 32
    else:
        n, d, n_centers, k, n_probe = 8000, 64, 40, 100, 8
        kmeans_iters, calls_exec, calls_paged = 20, 800, 400
        n_paged, d_paged = 8000, 32

    # -- resident arm: exec_xla_q1 ------------------------------------------
    centers = rng.normal(size=(n_centers, d)).astype(np.float32) * 5
    X = (centers[rng.integers(0, n_centers, n)]
         + rng.normal(size=(n, d))).astype(np.float32)
    cfg = IVFConfig(dim=d, target_partition_size=100,
                    kmeans_iters=kmeans_iters)
    idx = ivf.build_index(X, cfg=cfg)
    q1 = X[:1]
    # warm the compile cache in both modes before the A/B
    _block(search.ann_search(idx, q1, k, n_probe, backend="xla"))
    size0 = obs_metrics.default_registry().size()
    us_on, us_off = _ab_arm(
        lambda: search.ann_search(idx, q1, k, n_probe, backend="xla"),
        calls=calls_exec)
    over_res = us_on / us_off
    emit("obs_exec_xla_q1_traceoff", us_on,
         f"killswitch_us={us_off:.1f};overhead={over_res:.3f}x")
    metrics["exec_xla_q1_on_us"] = us_on
    metrics["exec_xla_q1_off_us"] = us_off
    metrics["exec_xla_q1_overhead"] = over_res
    gates["overhead_exec_xla_q1"] = (
        over_res <= OVERHEAD_TOL,
        f"{us_on:.1f}us <= {OVERHEAD_TOL} * {us_off:.1f}us")
    # zero-allocation contract: untraced queries registered nothing new
    size1 = obs_metrics.default_registry().size()
    gates["no_registry_growth_untraced"] = (
        size1 == size0, f"registry series {size0} -> {size1}")

    # -- paged arm + reconciliation -----------------------------------------
    cfg_p = IVFConfig(dim=d_paged, target_partition_size=100,
                      kmeans_iters=kmeans_iters, quantize="int8",
                      rerank_factor=4)
    centers_p = rng.normal(size=(16, d_paged)).astype(np.float32) * 5
    Xp = (centers_p[rng.integers(0, 16, n_paged)]
          + rng.normal(size=(n_paged, d_paged))).astype(np.float32)
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "obs.db")
        builder = MicroNN(dim=d_paged, path=path, config=cfg_p)
        builder.upsert(np.arange(n_paged), Xp)
        builder.build()
        builder.store.close()

        # budget sized so the probe working set stays resident: the A/B
        # then times the hook sites on a hit-dominated steady state
        # (fault() runs every chunk either way) instead of SQLite read
        # variance, which is ms-scale and would swamp a 3% gate
        pag = MicroNN(dim=d_paged, path=path, config=cfg_p,
                      memory_budget_mb=1 if smoke else 4)
        pag.recover()
        qp = Xp[:4]
        spec = Q.knn(k=20, n_probe=n_probe)
        for _ in range(3):
            pag.query(qp, spec)                   # warm compile + pool
        us_on_p, us_off_p = _ab_arm(lambda: pag.query(qp, spec),
                                    calls=calls_paged)
        over_pag = us_on_p / us_off_p
        emit("obs_paged_traceoff", us_on_p,
             f"killswitch_us={us_off_p:.1f};overhead={over_pag:.3f}x")
        metrics["paged_on_us"] = us_on_p
        metrics["paged_off_us"] = us_off_p
        metrics["paged_overhead"] = over_pag
        gates["overhead_paged"] = (
            over_pag <= OVERHEAD_TOL,
            f"{us_on_p:.1f}us <= {OVERHEAD_TOL} * {us_off_p:.1f}us")

        # -- recording-off overhead, hit-dominated paged path (PR 10) ----
        # A/B: armed-but-sampling-out recorder vs uninstalled. The
        # sampled-out path upper-bounds the uninstalled one (it runs the
        # same branch PLUS the sampling bookkeeping), so gating it
        # gates both
        dummy = obs_recorder.FlightRecorder(
            os.path.join(tmp, "dummy.db"), sample_every=1 << 30)
        us_on_rp, us_off_rp = _ab_arm(
            lambda: pag.query(qp, spec), calls=calls_paged,
            toggle=_recorder_toggle(dummy), restore=False)
        dummy.close()
        over_rec_pag = us_on_rp / us_off_rp
        emit("obs_paged_recordoff", us_on_rp,
             f"recoff_us={us_off_rp:.1f};overhead={over_rec_pag:.3f}x")
        metrics["recording_paged_on_us"] = us_on_rp
        metrics["recording_paged_off_us"] = us_off_rp
        metrics["recording_paged_overhead"] = over_rec_pag
        gates["overhead_recording_paged"] = (
            over_rec_pag <= OVERHEAD_TOL,
            f"{us_on_rp:.1f}us <= {OVERHEAD_TOL} * {us_off_rp:.1f}us")

        # -- replay bit-parity, paged arm (PR 10) ------------------------
        cap_paged = os.path.join(tmp, "cap_paged.db")
        with obs_recorder.recording(cap_paged):
            for i in range(6):
                pag.query(Xp[i * 4:i * 4 + 4], spec)
        rep_paged = obs_recorder.replay(cap_paged, engine=pag,
                                        strict=True)

        # -- reconciliation: trace counters == independent stats deltas ----
        s0 = pag.stats()
        tr = pag.explain(Xp[n_paged // 2:n_paged // 2 + 4], spec)
        s1 = pag.stats()
        f_hits = tr.counter("pager_fault", "hits")
        f_miss = tr.counter("pager_fault", "misses")
        f_bytes = tr.counter("pager_fault", "bytes_read")
        d_hits = s1["hits"] - s0["hits"]
        d_miss = s1["misses"] - s0["misses"]
        d_bytes = s1["bytes_read"] - s0["bytes_read"]
        recon_paged = (f_hits == d_hits and f_miss == d_miss
                       and f_bytes == d_bytes)
        complete_paged = all(
            s in tr for s in ("plan", "probe", "scan", "merge"))
        metrics["recon_fault_hits"] = f_hits
        metrics["recon_fault_misses"] = f_miss
        metrics["recon_fault_bytes"] = f_bytes
        gates["reconcile_paged_fault_counters"] = (
            recon_paged,
            f"trace h/m/b={f_hits}/{f_miss}/{f_bytes}"
            f" vs stats delta {d_hits}/{d_miss}/{d_bytes}")
        pag.store.close()

    # resident reconciliation: scan `compiled` == jit trace-count delta
    res = MicroNN(dim=d, config=cfg)
    res.upsert(np.arange(n), X)
    res.build()
    spec_r = Q.knn(k=k, n_probe=n_probe).backend("xla")
    c0 = executor.trace_count()
    tr_cold = res.explain(X[:1], spec_r)          # fresh Q-bucket: compiles
    c1 = executor.trace_count()
    tr_warm = res.explain(X[1:2], spec_r)         # same bucket: cache hit
    c2 = executor.trace_count()
    recon_res = (tr_cold.counter("scan", "compiled") == c1 - c0
                 and tr_warm.counter("scan", "compiled") == c2 - c1
                 and tr_warm.counter("scan", "cache_hit") is True)
    complete_res = all(s in tr_cold for s in ("plan", "probe", "scan"))
    gates["reconcile_resident_compiles"] = (
        recon_res,
        f"cold compiled={tr_cold.counter('scan', 'compiled')}"
        f" (delta {c1 - c0}),"
        f" warm compiled={tr_warm.counter('scan', 'compiled')}"
        f" (delta {c2 - c1})")
    gates["trace_complete"] = (
        complete_res and complete_paged,
        f"resident spans={list(tr_cold.span_names)}")
    metrics["traced_resident_ms"] = tr_cold.total_ms

    # -- recording-off overhead, resident engine.query path (PR 10) ---------
    spec_warm = Q.knn(k=k, n_probe=n_probe).backend("xla")
    _block(res.query(X[:1], spec_warm))
    with tempfile.TemporaryDirectory() as tmp2:
        dummy = obs_recorder.FlightRecorder(
            os.path.join(tmp2, "dummy.db"), sample_every=1 << 30)
        us_on_rr, us_off_rr = _ab_arm(
            lambda: res.query(X[:1], spec_warm), calls=calls_exec,
            toggle=_recorder_toggle(dummy), restore=False)
        dummy.close()
        over_rec_res = us_on_rr / us_off_rr
        emit("obs_exec_xla_q1_recordoff", us_on_rr,
             f"recoff_us={us_off_rr:.1f};overhead={over_rec_res:.3f}x")
        metrics["recording_exec_xla_q1_on_us"] = us_on_rr
        metrics["recording_exec_xla_q1_off_us"] = us_off_rr
        metrics["recording_exec_xla_q1_overhead"] = over_rec_res
        gates["overhead_recording_exec_xla_q1"] = (
            over_rec_res <= OVERHEAD_TOL,
            f"{us_on_rr:.1f}us <= {OVERHEAD_TOL} * {us_off_rr:.1f}us")

        # -- replay bit-parity: resident xla + pallas, multi-tenant fleet --
        cap_res = os.path.join(tmp2, "cap_res.db")
        spec_pal = Q.knn(k=k, n_probe=n_probe).backend("pallas")
        _block(res.query(X[:1], spec_pal))            # warm pallas bucket
        with obs_recorder.recording(cap_res):
            for i in range(3):
                res.query(X[i:i + 1], spec_warm)
                res.query(X[i:i + 2], spec_pal)
        rep_res = obs_recorder.replay(cap_res, engine=res, strict=True)

        from repro.fleet import Fleet
        d_f = 16
        cfg_f = IVFConfig(dim=d_f, target_partition_size=50,
                          kmeans_iters=4)
        Xf = rng.normal(size=(400, d_f)).astype(np.float32)
        fleet = Fleet(os.path.join(tmp2, "fleet"), dim=d_f,
                      budget_mb=0.5, max_live=4, config=cfg_f)
        for t in ("t0", "t1", "t2"):
            eng = fleet.get(t)
            with eng.session() as s:
                s.upsert(np.arange(400), Xf)
            eng.build()
        cap_fleet = os.path.join(tmp2, "cap_fleet.db")
        with obs_recorder.recording(cap_fleet):
            for i in range(4):
                fleet.query(f"t{i % 3}", Xf[i:i + 2], Q.knn(k=10))
                fleet.query(f"t{(i + 1) % 3}", Xf[i:i + 1],
                            Q.knn(k=5).backend("pallas"))
        rep_fleet = obs_recorder.replay(cap_fleet, fleet=fleet,
                                        strict=True)
        fleet.close()

        replay_total = (rep_paged.replayed + rep_res.replayed
                        + rep_fleet.replayed)
        replay_matched = (rep_paged.matched + rep_res.matched
                          + rep_fleet.matched)
        replay_ok = (rep_paged.ok and rep_res.ok and rep_fleet.ok
                     and replay_total >= 6 + 6 + 8)
        metrics["replay_records"] = replay_total
        metrics["replay_matched"] = replay_matched
        metrics["replay_ok"] = int(replay_ok)
        gates["replay_bit_parity"] = (
            replay_ok,
            f"paged {rep_paged.matched}/{rep_paged.replayed}, resident "
            f"{rep_res.matched}/{rep_res.replayed}, fleet "
            f"{rep_fleet.matched}/{rep_fleet.replayed} bit-identical")
    res.store.close()

    write_json("obs", metrics,
               config={"n": n, "d": d, "k": k, "n_probe": n_probe,
                       "n_paged": n_paged, "d_paged": d_paged,
                       "calls_exec": calls_exec,
                       "calls_paged": calls_paged,
                       "overhead_tol": OVERHEAD_TOL, "smoke": smoke,
                       "cpu_count": os.cpu_count()},
               gates=gates)

    assert recon_paged, "paged trace counters diverged from pager stats"
    assert recon_res, "scan compile counter diverged from trace_count()"
    assert complete_res and complete_paged, "incomplete explain() trace"
    assert over_res <= OVERHEAD_TOL, \
        f"tracing-off overhead {over_res:.3f}x > {OVERHEAD_TOL}x" \
        f" on exec_xla_q1"
    assert over_pag <= OVERHEAD_TOL, \
        f"tracing-off overhead {over_pag:.3f}x > {OVERHEAD_TOL}x on paged"
    assert over_rec_res <= OVERHEAD_TOL, \
        f"recording-off overhead {over_rec_res:.3f}x > {OVERHEAD_TOL}x" \
        f" on exec_xla_q1"
    assert over_rec_pag <= OVERHEAD_TOL, \
        f"recording-off overhead {over_rec_pag:.3f}x > {OVERHEAD_TOL}x" \
        f" on paged"
    assert replay_ok, "flight-recorder replay lost bit-parity"


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes + acceptance asserts (CI gate)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(smoke=args.smoke)
