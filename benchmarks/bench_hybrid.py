"""Fig. 7: hybrid query optimizer -- latency + recall vs selectivity for
pre-filtering, post-filtering, and the optimizer's choice."""
import jax.numpy as jnp
import numpy as np

from repro.core import ivf, search
from repro.core.hybrid import AttributeStats, Pred, compile_filter
from repro.core.optimizer import HybridOptimizer
from repro.core.types import IVFConfig
from repro.data import synthetic

from .common import emit, timeit, _recall


def main():
    ds = synthetic.make("sift", scale=0.02)
    n, dim = ds.X.shape
    rng = np.random.default_rng(0)
    # tag column engineered to give selectivity decades ~1e-3 .. ~1
    col = rng.choice(
        [0, 1, 2, 3, 4],
        p=[0.001, 0.01, 0.1, 0.4, 0.489], size=n).astype(np.float32)
    attrs = col[:, None]
    cfg = IVFConfig(dim=dim, metric=ds.metric, target_partition_size=100,
                    kmeans_iters=60)
    idx = ivf.build_index(ds.X, attrs=attrs, cfg=cfg)
    stats = AttributeStats(attrs)
    opt = HybridOptimizer(stats)
    q = jnp.asarray(ds.Q[:16])
    n_probe = 8

    for tag in (0, 1, 2, 3):
        pred = Pred(0, "eq", float(tag))
        sel = float((col == tag).mean())
        f = compile_filter(pred)
        exact = search.exact_search(idx, q, 100, attr_filter=f)
        ex_ids = np.asarray(exact.ids)

        dec = opt.choose(idx, pred, n_probe)
        r_pre = search.prefilter_search(idx, q, 100, f,
                                        cap=dec.prefilter_cap)
        t_pre = timeit(lambda: search.prefilter_search(
            idx, q, 100, f, cap=dec.prefilter_cap))
        r_post = search.ann_search(idx, q, 100, n_probe=n_probe,
                                   attr_filter=f)
        t_post = timeit(lambda: search.ann_search(
            idx, q, 100, n_probe=n_probe, attr_filter=f))
        r_opt, d = opt.execute(idx, q, pred, 100, n_probe)
        t_opt = t_pre if d.plan == "pre" else t_post

        emit(f"fig7_pre_sel{sel:.4f}", t_pre / 16,
             f"recall={_recall(np.asarray(r_pre.ids), ex_ids, 100):.3f}")
        emit(f"fig7_post_sel{sel:.4f}", t_post / 16,
             f"recall={_recall(np.asarray(r_post.ids), ex_ids, 100):.3f}")
        emit(f"fig7_opt_sel{sel:.4f}", t_opt / 16,
             f"recall={_recall(np.asarray(r_opt.ids), ex_ids, 100):.3f};"
             f"plan={d.plan}")


if __name__ == "__main__":
    main()
