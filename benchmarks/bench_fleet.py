"""Fleet mode under Zipf tenant skew (PR 9): one shared FramePool at
budget B vs T naive solo engines at B/T each.

T per-tenant databases (distinct data, identical geometry) are built
once, WAL-checkpointed, and copied byte-identically into both arms.
Both arms then serve the SAME deterministic workload -- a Zipf(s)
sequence over tenant ranks, single-vector ANN probes -- so per-query
answers are directly comparable:

  * `fleet` -- one Fleet: every tenant's PartitionCache is a view into
    ONE FramePool of budget B. Global CLOCK lets the hot tenants'
    working sets occupy most of the pool while cold tenants shrink to
    ~nothing, which is the whole argument for fleet mode.
  * `naive` -- T independent engines, each with its own private pool of
    budget B/T: the equal-split allocation a process-per-tenant or
    container-per-tenant deployment is stuck with. The hot tenant
    thrashes its sliver while the cold tenants' slivers idle.

Gates (scripts/ci.sh --smoke regression surface, BENCH_fleet.json):

  * per_tenant_parity -- every query's ids+scores are bit-identical
    across the two arms: pool sharing and eviction pressure never
    change what a tenant's search computes.
  * budget_bound -- the fleet pool's resident bytes never exceed B at
    any sampled point (it is preallocated, so this pins the accounting).
  * qps_uplift -- the shared pool beats the naive split's sustained
    QPS by >= 1.2x on the skewed workload.
"""
import glob
import os
import shutil
import tempfile
import time

import numpy as np

from repro.core.query import Q
from repro.core.types import IVFConfig
from repro.fleet import Fleet
from repro.storage import MicroNN

from .common import emit, write_json

DIM = 64
K = 10
N_PROBE = 8
BATCH = 4           # rows per query call (one user's request burst)
ZIPF_S = 1.6
UPLIFT_MIN = 1.2


def _clustered(n, seed, scale=5.0, n_clusters=24):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_clusters, DIM)).astype(np.float32) * scale
    asg = rng.integers(0, n_clusters, n)
    return (centers[asg]
            + rng.normal(size=(n, DIM)).astype(np.float32))


def _copy_db(src, dst):
    for f in glob.glob(src + "*"):
        shutil.copy(f, dst + f[len(src):])


def _build_sources(tmp, cfg, tenants, n):
    """One built db per tenant (distinct data), WAL folded in so the
    bare .db file is the complete durable state."""
    src = os.path.join(tmp, "src")
    os.makedirs(src)
    data = {}
    for r, name in enumerate(tenants):
        X = _clustered(n, seed=100 + r)
        eng = MicroNN(dim=DIM, path=os.path.join(src, f"{name}.db"),
                      config=cfg)
        eng.upsert(np.arange(n), X)
        eng.build()
        eng.store.db.commit()
        eng.store.db.execute("PRAGMA wal_checkpoint(TRUNCATE)")
        eng.store.close()
        data[name] = X
    return src, data


def _zipf_schedule(tenants, n_q, seed):
    """Deterministic Zipf(s) draw over tenant RANKS: rank r (0-based)
    gets probability ~ 1/(r+1)^s -- tenant 0 is the hot one."""
    ranks = np.arange(1, len(tenants) + 1, dtype=np.float64)
    p = ranks ** -ZIPF_S
    p /= p.sum()
    rng = np.random.default_rng(seed)
    return rng.choice(len(tenants), size=n_q, p=p)


def _drive(query_fn, tenants, schedule, probes, *, sample_fn=None):
    """Run the fixed workload; returns (wall_s, answers, max_sample)."""
    answers = []
    peak = 0
    t0 = time.perf_counter()
    for i, r in enumerate(schedule):
        rs = query_fn(tenants[r], probes[i])
        answers.append((np.asarray(rs.ids).copy(),
                        np.asarray(rs.scores).copy()))
        if sample_fn is not None and i % 16 == 0:
            peak = max(peak, sample_fn())
    return time.perf_counter() - t0, answers, peak


def fleet(smoke: bool = False):
    T = 6 if smoke else 16
    n = 4800
    n_q = 120 if smoke else 800          # query CALLS (BATCH rows each)
    budget_mb = 6.0
    tenants = [f"user{r}" for r in range(T)]

    # big partitions: a fault moves ~150KB/frame, so paging -- not jit
    # dispatch -- is what the two arms get measured on
    cfg = IVFConfig(dim=DIM, target_partition_size=256, kmeans_iters=12,
                    delta_capacity=256)
    spec = Q.knn(k=K, n_probe=N_PROBE)

    with tempfile.TemporaryDirectory() as tmp:
        src, _ = _build_sources(tmp, cfg, tenants, n)
        probes = _clustered(n_q * BATCH, seed=9).astype(
            np.float32).reshape(n_q, BATCH, DIM)
        schedule = _zipf_schedule(tenants, n_q, seed=11)

        # -- fleet arm: ONE pool at budget B --------------------------------
        froot = os.path.join(tmp, "fleet")
        os.makedirs(froot)
        for name in tenants:
            _copy_db(os.path.join(src, f"{name}.db"),
                     os.path.join(froot, f"{name}.db"))
        fl = Fleet(froot, dim=DIM, budget_mb=budget_mb, max_live=T,
                   config=cfg)
        # the skew premise: the whole fleet does NOT fit (so sharing is
        # a policy question), but one hot tenant's tier does
        k0 = fl.get(tenants[0]).index.k
        assert fl.pool.capacity < T * k0, "budget too generous"
        assert fl.pool.capacity >= k0, "budget below one tenant's tier"
        for name in tenants:                      # warm compiles, not frames
            fl.query(name, probes[0], spec)
        budget_bytes = fl.pool.budget_bytes
        wall_f, ans_f, peak_resident = _drive(
            lambda t, q: fl.query(t, q, spec), tenants, schedule, probes,
            sample_fn=lambda: fl.pool.resident_bytes)
        misses_fleet = sum(fl.get(t).index.cache.misses for t in tenants)
        fl.close()

        # -- naive arm: T private pools at B/T each -------------------------
        nroot = os.path.join(tmp, "naive")
        os.makedirs(nroot)
        solos = {}
        for name in tenants:
            path = os.path.join(nroot, f"{name}.db")
            _copy_db(os.path.join(src, f"{name}.db"), path)
            eng = MicroNN(dim=DIM, path=path, config=cfg,
                          memory_budget_mb=budget_mb / T)
            eng.recover()
            solos[name] = eng
        for name in tenants:
            solos[name].query(probes[0], spec)
        wall_n, ans_n, _ = _drive(
            lambda t, q: solos[t].query(q, spec), tenants, schedule,
            probes)
        misses_naive = sum(e.index.cache.misses for e in solos.values())
        for eng in solos.values():
            eng.store.close()

    parity = all(
        np.array_equal(a[0], b[0]) and np.array_equal(a[1], b[1])
        for a, b in zip(ans_f, ans_n))
    qps_f, qps_n = n_q / wall_f, n_q / wall_n
    uplift = qps_f / max(qps_n, 1e-9)

    emit("fleet_qps_shared", 1e6 * wall_f / n_q,
         f"qps={qps_f:.1f} T={T} budget={budget_mb}MB")
    emit("fleet_qps_naive_split", 1e6 * wall_n / n_q,
         f"qps={qps_n:.1f} uplift={uplift:.2f}x")
    emit("fleet_pool_misses", 0.0,
         f"shared={misses_fleet} naive={misses_naive}")

    write_json(
        "fleet",
        metrics={"qps_fleet": qps_f, "qps_naive": qps_n,
                 "qps_uplift": uplift,
                 "misses_fleet": misses_fleet,
                 "misses_naive": misses_naive,
                 "peak_resident_bytes": peak_resident,
                 "budget_bytes": budget_bytes},
        config={"tenants": T, "rows_per_tenant": n, "queries": n_q,
                "budget_mb": budget_mb, "zipf_s": ZIPF_S, "dim": DIM,
                "k": K, "n_probe": N_PROBE, "smoke": smoke},
        gates={
            "per_tenant_parity": (
                parity,
                "fleet ids+scores bitwise == naive per-tenant engines"),
            "budget_bound": (
                peak_resident <= budget_bytes,
                f"peak resident {peak_resident} <= {budget_bytes}"),
            "qps_uplift": (
                uplift >= UPLIFT_MIN,
                f"{qps_f:.1f} >= {UPLIFT_MIN} * {qps_n:.1f}"),
        })

    # acceptance pins (scripts/ci.sh --smoke regression gate)
    assert parity, "shared pool changed a tenant's answers"
    assert peak_resident <= budget_bytes, \
        f"fleet pool exceeded budget: {peak_resident} > {budget_bytes}"
    assert uplift >= UPLIFT_MIN, \
        f"fleet uplift {uplift:.2f}x < {UPLIFT_MIN}x"


def main(smoke: bool = False):
    fleet(smoke=smoke)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small workload + acceptance asserts (CI gate)")
    main(**vars(ap.parse_args()))
