"""Fig. 8: recall + construction memory vs mini-batch size fraction.
Paper: batch sizes from 0.04% to 100% of the data barely change recall;
memory scales with the batch."""
import jax.numpy as jnp
import numpy as np

from repro.core import ivf, search
from repro.core.types import IVFConfig
from repro.data import synthetic

from .common import emit, _recall


def main():
    ds = synthetic.make("internala", scale=0.05)
    n, dim = ds.X.shape
    q = jnp.asarray(ds.Q[:64])
    row_ids = np.arange(n)
    exact_ids = row_ids[ds.gt[:64, :100]]

    n_probe = None
    for frac in (0.0004, 0.004, 0.04, 0.25, 1.0):
        bs = max(16, int(n * frac))
        cfg = IVFConfig(dim=dim, metric=ds.metric, target_partition_size=100,
                        minibatch_size=bs,
                        kmeans_iters=max(10, min(80, int(3 * n / bs))))
        idx = ivf.build_index(ds.X, cfg=cfg)
        if n_probe is None:  # fix n at the smallest batch size (paper)
            from .common import n_probe_for_recall
            n_probe, _ = n_probe_for_recall(
                lambda p: search.ann_search(idx, q, 100, n_probe=p),
                exact_ids, 100)
        res = search.ann_search(idx, q, 100, n_probe=n_probe)
        rec = _recall(np.asarray(res.ids), exact_ids, 100)
        mem = (bs * dim + idx.k * dim + bs * idx.k) * 4
        emit(f"fig8_minibatch_{frac*100:g}pct", 0.0,
             f"recall={rec:.3f};mem_MB={mem/1e6:.2f};n_probe={n_probe}")


if __name__ == "__main__":
    main()
