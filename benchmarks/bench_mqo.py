"""Fig. 9: multi-query optimization -- batch time vs sequential, and the
amortised per-query latency. Paper: batch-512 cuts per-query latency >30%;
I/O amortises as partitions are scanned once per batch."""
import jax.numpy as jnp
import numpy as np

from repro.core import ivf, mqo, search
from repro.core.types import IVFConfig
from repro.data import synthetic

from .common import emit, timeit


def main():
    ds = synthetic.make("internala", scale=0.05, with_gt=False)
    cfg = IVFConfig(dim=ds.dim, metric=ds.metric, target_partition_size=100,
                    kmeans_iters=40)
    idx = ivf.build_index(ds.X, cfg=cfg)
    rng = np.random.default_rng(0)
    pool = np.concatenate([ds.Q] * 20)[:1024]

    t1 = timeit(lambda: search.ann_search(
        idx, jnp.asarray(pool[:1]), 100, n_probe=8), iters=10)
    for batch in (16, 64, 256, 512):
        q = jnp.asarray(pool[:batch])
        t_mqo = timeit(lambda: mqo.mqo_search(idx, q, 100, n_probe=8))
        io_naive = mqo.gathered_bytes(idx, batch, 8, mqo=False)
        io_mqo = mqo.gathered_bytes(idx, batch, 8, mqo=True)
        emit(f"fig9_batch{batch}", t_mqo / batch,
             f"sequential_us={t1:.0f};speedup={t1*batch/t_mqo:.2f}x;"
             f"io_ratio={io_naive/max(io_mqo,1):.1f}x")


if __name__ == "__main__":
    main()
