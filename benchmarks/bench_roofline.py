"""Roofline table from the dry-run artifacts (results/dryrun.json)."""
import json
import os

from .common import emit


def main():
    path = os.environ.get("DRYRUN_JSON", "results/dryrun.json")
    if not os.path.exists(path):
        emit("roofline_missing", 0.0, f"run repro.launch.dryrun first")
        return
    with open(path) as f:
        recs = json.load(f)
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        name = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
        if r["status"] != "ok":
            emit(name, 0.0, f"status={r['status']}")
            continue
        rf = r["roofline"]
        dominant = max(rf["t_compute_s"], rf["t_memory_s"],
                       rf["t_collective_s"])
        emit(name, dominant * 1e6,
             f"bottleneck={rf['bottleneck']};"
             f"compute_ms={rf['t_compute_s']*1e3:.1f};"
             f"memory_ms={rf['t_memory_s']*1e3:.1f};"
             f"collective_ms={rf['t_collective_s']*1e3:.1f};"
             f"useful={r.get('useful_flops_ratio', 0):.2f};"
             f"fits={r.get('hbm_ok')}")


if __name__ == "__main__":
    main()
