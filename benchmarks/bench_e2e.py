"""Fig. 4 + Fig. 5: ANN latency at 90% recall@100 and memory working set.

Paper claim: <7 ms top-100 @ 90% recall on million-scale data using
~10 MB (two orders of magnitude below the in-memory index). On CPU we
re-synthesise scaled Table-2 datasets; the *relative* claims are what we
reproduce: ANN latency ~ exact-scan latency / large factor, and the
probed working set is orders of magnitude smaller than the index.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import ivf, search
from repro.core.types import IVFConfig
from repro.data import synthetic

from .common import emit, n_probe_for_recall, timeit

DATASETS = [("sift", 0.02), ("nytimes", 0.02), ("mnist", 0.05),
            ("internala", 0.05)]


def main():
    for name, scale in DATASETS:
        ds = synthetic.make(name, scale=scale)
        cfg = IVFConfig(dim=ds.dim, metric=ds.metric,
                        target_partition_size=100, kmeans_iters=60,
                        minibatch_size=256)
        idx = ivf.build_index(ds.X, cfg=cfg)
        q = jnp.asarray(ds.Q[:64])
        row_ids = np.arange(len(ds.X))
        exact_ids = row_ids[ds.gt[:64, :100]]

        n, rec = n_probe_for_recall(
            lambda n: search.ann_search(idx, q, 100, n_probe=n),
            exact_ids, 100)
        us_ann = timeit(lambda: search.ann_search(idx, q, 100, n_probe=n))
        us_exact = timeit(lambda: search.exact_search(idx, q, 100))

        # working set: probed partitions + centroids + delta (the paper's
        # "memory during query processing"); index = full vector table
        ws = (n * idx.p_max * ds.dim * 4 + idx.k * ds.dim * 4
              + idx.delta.capacity * ds.dim * 4)
        full = idx.k * idx.p_max * ds.dim * 4
        emit(f"fig4_latency_{name}_ann@90", us_ann / 64,
             f"recall={rec:.3f};n_probe={n}")
        emit(f"fig4_latency_{name}_exact", us_exact / 64, "recall=1.0")
        emit(f"fig5_memory_{name}", us_ann / 64,
             f"working_set_MB={ws/1e6:.2f};index_MB={full/1e6:.2f};"
             f"ratio={full/max(ws,1):.1f}x")


if __name__ == "__main__":
    main()
