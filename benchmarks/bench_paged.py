"""Paged tier: resident bytes vs recall vs latency at fixed memory budgets.

The paper's headline is *disk-resident* search -- top-100 @ 90% recall in
<7 ms with ~10 MB resident at million scale. PR 3's pager makes that
literal: the scan tier (int8 codes) stays in SQLite and is faulted into a
budget-bounded frame pool; the rerank gathers f32 rows from disk. This
section measures the reproduction of that trade-off:

  * resident scan-tier bytes at budgets of 4 / 10 / 32 MB (0.1 / 0.25 MB
    in --smoke) -- asserted <= the budget across the whole run;
  * paged-vs-resident parity: the paged engine must return bit-identical
    ids to the fully-resident quantized path on the same queries;
  * recall@k of the paged int8 scan + disk rerank against the resident
    *float32* ANN path (the acceptance pin: >= 0.95);
  * latency (cold faults amortised by the warmup calls -- steady-state);
  * cache hit rate under a Zipfian probe workload (skewed cluster
    popularity, the on-device access pattern the buffer pool exploits).

`--smoke` shrinks the dataset so scripts/ci.sh runs this as a regression
gate (the paged path must not silently rot).
"""
import os
import tempfile

import numpy as np

from repro.core import executor
from repro.core.types import IVFConfig
from repro.storage import MicroNN

from .common import _recall, emit, timeit


def main(smoke: bool = False):
    rng = np.random.default_rng(0)
    if smoke:
        n, d, n_centers = 4000, 32, 16
        n_q, k, n_probe = 16, 20, 8
        budgets_mb = (0.1, 0.25)
        iters = 10
    else:
        n, d, n_centers = 100_000, 64, 100
        n_q, k, n_probe = 64, 100, 8
        budgets_mb = (4, 10, 32)
        iters = 20
    centers = rng.normal(size=(n_centers, d)).astype(np.float32) * 5
    labels = rng.integers(0, n_centers, n)
    X = (centers[labels] + rng.normal(size=(n, d))).astype(np.float32)
    cfg = IVFConfig(dim=d, target_partition_size=100,
                    kmeans_iters=10 if smoke else 20,
                    quantize="int8", rerank_factor=4)

    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "paged.db")
        builder = MicroNN(dim=d, path=path, config=cfg)
        builder.upsert(np.arange(n), X)
        builder.build()
        builder.store.db.commit()

        res = MicroNN(dim=d, path=path, config=cfg)
        res.recover()
        q = X[:n_q]
        # reference: the resident float32 ANN path (recall denominator)
        r_f32 = executor.search(res.index, q, k=k, n_probe=n_probe,
                                quantized=False)
        ref_ids = np.asarray(r_f32.ids)
        r_res = res.search(q, k=k, n_probe=n_probe)     # resident int8 path
        us_res = timeit(lambda: res.search(q, k=k, n_probe=n_probe),
                        iters=iters)
        resident_bytes = res.stats()["resident_bytes"]
        emit(f"paged_resident_ref_k{k}", us_res,
             f"resident_mb={resident_bytes / 2**20:.2f};"
             f"recall_vs_f32={_recall(np.asarray(r_res.ids), ref_ids, k):.3f}")

        recalls = {}
        for mb in budgets_mb:
            pag = MicroNN(dim=d, path=path, config=cfg, memory_budget_mb=mb)
            pag.recover()
            budget = int(mb * 2 ** 20)
            r_pag = pag.search(q, k=k, n_probe=n_probe)
            # acceptance: bit-identical to the fully-resident path, and the
            # pool never exceeds the budget
            assert np.array_equal(np.asarray(r_pag.ids),
                                  np.asarray(r_res.ids)), \
                f"paged ids diverge from resident at {mb} MB"
            assert np.array_equal(np.asarray(r_pag.scores),
                                  np.asarray(r_res.scores)), \
                f"paged scores diverge from resident at {mb} MB"
            assert pag.index.cache.resident_bytes <= budget
            us = timeit(lambda: pag.search(q, k=k, n_probe=n_probe),
                        iters=iters)
            assert pag.index.cache.resident_bytes <= budget
            recalls[mb] = _recall(np.asarray(r_pag.ids), ref_ids, k)
            s = pag.stats()
            emit(f"paged_budget{mb}mb_k{k}", us,
                 f"resident_mb={s['resident_bytes'] / 2**20:.3f};"
                 f"frames={s['capacity_frames']};"
                 f"recall_at_{k}={recalls[mb]:.3f};"
                 f"vs_resident={us_res / us:.2f}x")

            # Zipfian probe workload: skewed cluster popularity -- the
            # regime where a small pool captures most of the traffic
            zipf = 1.0 / np.arange(1, n_centers + 1) ** 1.1
            zipf /= zipf.sum()
            h0, m0 = pag.index.cache.hits, pag.index.cache.misses
            for _ in range(30 if smoke else 60):
                c = rng.choice(n_centers, size=4, p=zipf)
                zq = (centers[c] + rng.normal(size=(4, d))
                      ).astype(np.float32)
                pag.search(zq, k=k, n_probe=n_probe)
                assert pag.index.cache.resident_bytes <= budget
            h, m = pag.index.cache.hits - h0, pag.index.cache.misses - m0
            emit(f"paged_budget{mb}mb_zipf_hit_rate", 0.0,
                 f"hit_rate={h / max(h + m, 1):.3f};hits={h};misses={m};"
                 f"evictions={pag.stats()['evictions']}")

        # regression gate (scripts/ci.sh --smoke): the paged path must keep
        # the paper's recall at every budget
        for mb, r in recalls.items():
            assert r >= 0.95, \
                f"paged recall@{k}={r:.3f} < 0.95 at budget {mb} MB"


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes for the CI regression gate")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(smoke=args.smoke)
