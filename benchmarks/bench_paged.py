"""Paged tier: resident bytes vs recall vs latency at fixed memory budgets.

The paper's headline is *disk-resident* search -- top-100 @ 90% recall in
<7 ms with ~10 MB resident at million scale. PR 3's pager makes that
literal: the scan tier (int8 codes) stays in SQLite and is faulted into a
budget-bounded frame pool; the rerank gathers f32 rows from disk. This
section measures the reproduction of that trade-off:

  * resident scan-tier bytes at budgets of 4 / 10 / 32 MB (0.1 / 0.25 MB
    in --smoke) -- asserted <= the budget across the whole run;
  * paged-vs-resident parity: the paged engine must return bit-identical
    ids to the fully-resident quantized path on the same queries;
  * recall@k of the paged int8 scan + disk rerank against the resident
    *float32* ANN path (the acceptance pin: >= 0.95);
  * latency (cold faults amortised by the warmup calls -- steady-state);
  * cache hit rate under a Zipfian probe workload (skewed cluster
    popularity, the on-device access pattern the buffer pool exploits),
    with a one-off exact full-collection scan injected mid-stream: the
    pager's scan-resistant admission (fault(admit=False) rides a small
    reusable ring) must keep the hot ANN working set resident, asserted
    as hit-rate non-regression across the scan.

All queries are issued through the declarative API (QuerySpec ->
ResultSet). `--smoke` shrinks the dataset so scripts/ci.sh runs this as
a regression gate (the paged path must not silently rot).
"""
import os
import tempfile

import numpy as np

from repro.core import executor
from repro.core.query import Q
from repro.core.types import IVFConfig
from repro.storage import MicroNN

from .common import _recall, emit, timeit, write_json


def main(smoke: bool = False):
    metrics, gates = {}, {}
    rng = np.random.default_rng(0)
    if smoke:
        n, d, n_centers = 4000, 32, 16
        n_q, k, n_probe = 16, 20, 8
        budgets_mb = (0.1, 0.25)
        iters = 10
    else:
        n, d, n_centers = 100_000, 64, 100
        n_q, k, n_probe = 64, 100, 8
        budgets_mb = (4, 10, 32)
        iters = 20
    centers = rng.normal(size=(n_centers, d)).astype(np.float32) * 5
    labels = rng.integers(0, n_centers, n)
    X = (centers[labels] + rng.normal(size=(n, d))).astype(np.float32)
    cfg = IVFConfig(dim=d, target_partition_size=100,
                    kmeans_iters=10 if smoke else 20,
                    quantize="int8", rerank_factor=4)

    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "paged.db")
        builder = MicroNN(dim=d, path=path, config=cfg)
        builder.upsert(np.arange(n), X)
        builder.build()
        builder.store.db.commit()

        res = MicroNN(dim=d, path=path, config=cfg)
        res.recover()
        q = X[:n_q]
        spec = Q.knn(k=k, n_probe=n_probe)       # ONE spec for every engine
        # reference: the resident float32 ANN path (recall denominator)
        r_f32 = executor.run(res.index, q, spec.quantized(False))
        ref_ids = np.asarray(r_f32.ids)
        r_res = res.query(q, spec)               # resident int8 path
        us_res = timeit(lambda: res.query(q, spec), iters=iters)
        resident_bytes = res.stats()["resident_bytes"]
        emit(f"paged_resident_ref_k{k}", us_res,
             f"resident_mb={resident_bytes / 2**20:.2f};"
             f"recall_vs_f32={_recall(np.asarray(r_res.ids), ref_ids, k):.3f}")

        recalls = {}
        for mb in budgets_mb:
            pag = MicroNN(dim=d, path=path, config=cfg, memory_budget_mb=mb)
            pag.recover()
            budget = int(mb * 2 ** 20)
            r_pag = pag.query(q, spec)
            # acceptance: bit-identical to the fully-resident path, and the
            # pool never exceeds the budget
            assert np.array_equal(np.asarray(r_pag.ids),
                                  np.asarray(r_res.ids)), \
                f"paged ids diverge from resident at {mb} MB"
            assert np.array_equal(np.asarray(r_pag.scores),
                                  np.asarray(r_res.scores)), \
                f"paged scores diverge from resident at {mb} MB"
            assert pag.index.cache.resident_bytes <= budget
            us = timeit(lambda: pag.query(q, spec), iters=iters)
            assert pag.index.cache.resident_bytes <= budget
            recalls[mb] = _recall(np.asarray(r_pag.ids), ref_ids, k)
            s = pag.stats()
            emit(f"paged_budget{mb}mb_k{k}", us,
                 f"resident_mb={s['resident_bytes'] / 2**20:.3f};"
                 f"frames={s['capacity_frames']};"
                 f"recall_at_{k}={recalls[mb]:.3f};"
                 f"vs_resident={us_res / us:.2f}x")
            metrics[f"budget{mb}mb_us_per_call"] = us
            metrics[f"budget{mb}mb_recall_at_{k}"] = recalls[mb]

            # Zipfian probe workload: skewed cluster popularity -- the
            # regime where a small pool captures most of the traffic
            zipf = 1.0 / np.arange(1, n_centers + 1) ** 1.1
            zipf /= zipf.sum()

            def zipf_phase(n_iter):
                h0, m0 = pag.index.cache.hits, pag.index.cache.misses
                for _ in range(n_iter):
                    c = rng.choice(n_centers, size=4, p=zipf)
                    zq = (centers[c] + rng.normal(size=(4, d))
                          ).astype(np.float32)
                    pag.query(zq, spec)
                    assert pag.index.cache.resident_bytes <= budget
                h = pag.index.cache.hits - h0
                m = pag.index.cache.misses - m0
                return h, m, h / max(h + m, 1)

            n_iter = 30 if smoke else 60
            h, m, rate1 = zipf_phase(n_iter)
            emit(f"paged_budget{mb}mb_zipf_hit_rate", 0.0,
                 f"hit_rate={rate1:.3f};hits={h};misses={m};"
                 f"evictions={pag.stats()['evictions']}")

            # scan-resistance: a one-off exact full-collection stream
            # (admit=False faults ride the scan ring) must NOT evict the
            # hot Zipf working set -- hit rate may not regress
            pag.query(q[:4], Q.exact(k))
            assert pag.index.cache.resident_bytes <= budget
            h2, m2, rate2 = zipf_phase(n_iter)
            emit(f"paged_budget{mb}mb_zipf_after_exact_scan", 0.0,
                 f"hit_rate={rate2:.3f};hits={h2};misses={m2}")
            assert rate2 >= rate1 - 0.05, \
                f"exact scan flushed the hot set at {mb} MB: " \
                f"Zipf hit rate {rate1:.3f} -> {rate2:.3f}"

        # -- double-buffered faulting (PR 6): prefetch chunk N+1's SQLite
        # fetch + frame copy while chunk N scans. The exact scan with the
        # smallest budget is the faulting-heavy extreme (scan ring <<
        # partitions, admit=False -> every call re-faults everything), so
        # it isolates the fault/compute overlap. Results must be
        # bit-identical with prefetch on/off by construction.
        pag = MicroNN(dim=d, path=path, config=cfg,
                      memory_budget_mb=budgets_mb[0])
        pag.recover()
        exact_spec = Q.exact(k)
        qe = q[:4]
        prefetch_before = executor.PAGED_PREFETCH
        try:
            executor.PAGED_PREFETCH = False
            r_off = pag.query(qe, exact_spec)
            us_off = timeit(lambda: pag.query(qe, exact_spec),
                            iters=iters)
            executor.PAGED_PREFETCH = True
            r_on = pag.query(qe, exact_spec)
            us_on = timeit(lambda: pag.query(qe, exact_spec),
                           iters=iters)
        finally:
            executor.PAGED_PREFETCH = prefetch_before
        bitwise = (np.array_equal(np.asarray(r_on.ids),
                                  np.asarray(r_off.ids))
                   and np.array_equal(np.asarray(r_on.scores),
                                      np.asarray(r_off.scores)))
        emit("paged_prefetch_off_exact", us_off, "double_buffering=off")
        emit("paged_prefetch_on_exact", us_on,
             f"double_buffering=on;speedup={us_off / us_on:.2f}x;"
             f"bitwise_identical={bitwise}")
        metrics["prefetch_off_us"] = us_off
        metrics["prefetch_on_us"] = us_on
        metrics["prefetch_speedup"] = us_off / us_on
        gates["prefetch_bitwise_identical"] = (
            bitwise, "prefetch on/off results bit-identical")
        # overlap can only buy wall-clock when a second core (or real
        # disk-I/O wait) runs the fetch while the scan computes; on a
        # single-core, page-cached container the two serialize and the
        # honest bound is break-even within scheduler noise. The gate
        # therefore pins "bit-identical + bounded overhead"; the
        # faulting-path latency win this PR ships on every machine is
        # the vectorized scan_partitions packing (see ROADMAP numbers).
        speed_tol = 1.20 if smoke else (
            1.0 if (os.cpu_count() or 1) > 1 else 1.10)
        gates["prefetch_not_slower"] = (
            us_on <= us_off * speed_tol,
            f"on={us_on:.0f}us <= {speed_tol:.2f} * off={us_off:.0f}us"
            f" (cpus={os.cpu_count()})")
        assert bitwise, "double-buffered faulting changed results"

        # regression gate (scripts/ci.sh --smoke): the paged path must keep
        # the paper's recall at every budget
        gates["recall_at_budgets"] = (
            all(r >= 0.95 for r in recalls.values()),
            ";".join(f"{mb}MB={r:.3f}" for mb, r in recalls.items()))
        write_json("paged", metrics,
                   config={"n": n, "d": d, "n_q": n_q, "k": k,
                           "n_probe": n_probe,
                           "budgets_mb": list(budgets_mb), "smoke": smoke,
                           "cpu_count": os.cpu_count()},
                   gates=gates)
        for mb, r in recalls.items():
            assert r >= 0.95, \
                f"paged recall@{k}={r:.3f} < 0.95 at budget {mb} MB"


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes for the CI regression gate")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(smoke=args.smoke)
