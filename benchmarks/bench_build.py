"""Fig. 6a/6b: index construction time and memory, mini-batch vs full
k-means. Paper claim: 4x-60x less memory at similar quality."""
import numpy as np

from repro.core import kmeans
from repro.core.types import IVFConfig
from repro.data import synthetic

from .common import emit, timeit


def main():
    ds = synthetic.make("internala", scale=0.05, with_gt=False)
    n, dim = ds.X.shape

    # mini-batch (paper): only s x d resident
    cfg_mb = IVFConfig(dim=dim, metric=ds.metric, target_partition_size=100,
                       minibatch_size=256, kmeans_iters=60)
    t_mb = timeit(lambda: kmeans.fit_in_memory(ds.X, cfg_mb), warmup=0,
                  iters=1)
    k = n // 100
    mem_mb = (256 * dim + k * dim + 256 * k) * 4  # batch + cents + dists

    # "full" k-means: every iteration touches the whole dataset
    cfg_full = IVFConfig(dim=dim, metric=ds.metric,
                         target_partition_size=100,
                         minibatch_size=n, kmeans_iters=10)
    t_full = timeit(lambda: kmeans.fit_in_memory(ds.X, cfg_full), warmup=0,
                    iters=1)
    mem_full = (n * dim + k * dim + n * k) * 4

    emit("fig6a_build_time_minibatch", t_mb, f"n={n};dim={dim}")
    emit("fig6a_build_time_full", t_full, f"n={n};dim={dim}")
    emit("fig6b_build_mem_minibatch", t_mb, f"MB={mem_mb/1e6:.1f}")
    emit("fig6b_build_mem_full", t_full,
         f"MB={mem_full/1e6:.1f};ratio={mem_full/mem_mb:.1f}x")


if __name__ == "__main__":
    main()
