"""Hypothesis property tests on system invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import delta, ivf, search
from repro.core.hybrid import AttributeStats, Pred
from repro.core.types import IVFConfig


def _index(n, dim, seed, cap=64):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, dim)).astype(np.float32)
    cfg = IVFConfig(dim=dim, target_partition_size=max(8, n // 8),
                    kmeans_iters=8, minibatch_size=32, delta_capacity=cap)
    return ivf.build_index(X, cfg=cfg), X


@given(st.integers(60, 200), st.integers(4, 16), st.integers(0, 10 ** 6))
@settings(max_examples=10, deadline=None)
def test_exact_search_is_true_knn(n, dim, seed):
    idx, X = _index(n, dim, seed)
    q = jnp.asarray(X[:3])
    res = search.exact_search(idx, q, 5)
    d2 = ((X[None, :, :] - X[:3][:, None, :]) ** 2).sum(-1)
    want = np.argsort(d2, axis=1, kind="stable")[:, :5]
    got_sets = [set(map(int, row[row >= 0]))
                for row in np.asarray(res.ids)]
    for g, w, drow in zip(got_sets, want, d2):
        # compare by distance values (ties can reorder ids)
        got_d = sorted(drow[list(g)])
        want_d = sorted(drow[w])
        np.testing.assert_allclose(got_d, want_d, rtol=1e-4, atol=1e-4)


@given(st.integers(0, 10 ** 6), st.integers(1, 20))
@settings(max_examples=10, deadline=None)
def test_upsert_then_delete_roundtrip(seed, batch):
    idx, X = _index(100, 8, seed)
    rng = np.random.default_rng(seed + 999_999)
    vecs = rng.normal(size=(batch, 8)).astype(np.float32)
    ids = jnp.arange(5000, 5000 + batch, dtype=jnp.int32)
    before = int(idx.num_live())
    idx2 = delta.upsert(idx, jnp.asarray(vecs), ids,
                        jnp.zeros((batch, 0)))
    assert int(idx2.num_live()) == before + batch
    idx3 = delta.delete(idx2, ids)
    assert int(idx3.num_live()) == before


@given(st.integers(0, 10 ** 6))
@settings(max_examples=10, deadline=None)
def test_upsert_idempotent(seed):
    idx, X = _index(100, 8, seed)
    rng = np.random.default_rng(seed + 999_999)  # decouple from X's stream
    v = rng.normal(size=(1, 8)).astype(np.float32)
    ids = jnp.asarray([7777], dtype=jnp.int32)
    a = delta.upsert(idx, jnp.asarray(v), ids, jnp.zeros((1, 0)))
    b = delta.upsert(a, jnp.asarray(v), ids, jnp.zeros((1, 0)))
    assert int(b.num_live()) == int(a.num_live())
    r = search.exact_search(b, jnp.asarray(v), 1)
    assert int(r.ids[0, 0]) == 7777


@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=20,
                max_size=200),
       st.floats(-100, 100, allow_nan=False))
@settings(max_examples=30, deadline=None)
def test_selectivity_bounds(vals, threshold):
    attrs = np.asarray(vals, np.float32)[:, None]
    stats = AttributeStats(attrs)
    for op in ("lt", "le", "gt", "ge", "eq", "ne"):
        f = stats.selectivity_factor(Pred(0, op, threshold))
        assert 0.0 <= f <= 1.0
    # complementary ops sum to ~1
    lt = stats.selectivity_factor(Pred(0, "lt", threshold))
    ge = stats.selectivity_factor(Pred(0, "ge", threshold))
    assert abs(lt + ge - 1.0) < 0.05
