"""Model zoo: per-arch smoke + decode/forward equivalence + mLSTM forms."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import arch_names, get_arch
from repro.configs.base import ShapeConfig
from repro.configs.inputs import input_specs, materialize
from repro.configs.smoke import smoke_config
from repro.models import (decode_step, forward, init_cache, init_model,
                          loss_fn)
from repro.models.decode import fill_cache_from_forward

SMOKE_TRAIN = ShapeConfig("t", "train", 32, 2)


@pytest.mark.parametrize("name", arch_names())
def test_arch_smoke(name):
    """Reduced config: one train step's loss + shapes + no NaNs."""
    cfg = smoke_config(get_arch(name).config)
    params, specs = init_model(cfg, jax.random.PRNGKey(0))
    # spec tree matches param tree leaf-for-leaf
    assert len(jax.tree.leaves(params)) > 0
    batch = materialize(input_specs(cfg, SMOKE_TRAIN))
    loss, metrics = loss_fn(cfg, params, batch)
    assert jnp.isfinite(loss), name
    logits, aux, hidden, _ = forward(cfg, params, batch)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("name", arch_names())
def test_decode_matches_forward(name):
    """Step-by-step decode with caches must reproduce the parallel
    forward's logits at every position (the strongest cache invariant)."""
    cfg = smoke_config(get_arch(name).config)
    extra = {}
    if cfg.n_experts:
        # capacity drops only exist in the parallel-training path; lift
        # the cap so forward == drop-free decode (verified semantics)
        extra["capacity_factor"] = float(cfg.n_experts)
    if cfg.family == "ssm":
        # recurrent state accumulates in a different order than the
        # chunk-parallel form; exact in f32, ~0.5 drift in bf16
        extra["dtype"] = "float32"
    cfg = dataclasses.replace(cfg, remat=False, **extra)
    params, _ = init_model(cfg, jax.random.PRNGKey(1))
    S = 8
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(1, 64, (2, S)), jnp.int32)}
    if cfg.num_img_tokens:
        batch["img"] = jnp.asarray(
            0.1 * rng.normal(size=(2, cfg.num_img_tokens, cfg.d_model)),
            jnp.bfloat16)
    if cfg.encoder_layers:
        batch["frames"] = jnp.asarray(
            0.1 * rng.normal(size=(2, cfg.enc_seq, cfg.d_model)),
            jnp.bfloat16)
    logits_ref, _, _, offset = forward(cfg, params, batch, remat=False)
    logits_ref = np.asarray(logits_ref, np.float32)

    import jax.numpy as _jnp
    cache = init_cache(cfg, 2, 32,
                       dtype=_jnp.float32 if cfg.dtype == "float32"
                       else _jnp.bfloat16)
    if cfg.encoder_layers or cfg.num_img_tokens:
        # prefill the non-token context (frames/img prefix) via cache fill
        ctx_batch = dict(batch)
        ctx_batch["tokens"] = batch["tokens"][:, :1]
        cache = fill_cache_from_forward(cfg, params, ctx_batch, 32)
        start = 1
    else:
        start = 0
    # decode token-by-token
    for t in range(start, S):
        pos = offset + t
        logits, hidden, cache = decode_step(
            cfg, params, cache, batch["tokens"][:, t:t + 1],
            jnp.asarray(pos, jnp.int32))
        got = np.asarray(logits, np.float32)
        want = logits_ref[:, pos]
        atol = 0.15 if cfg.dtype == "bfloat16" else 1e-4
        np.testing.assert_allclose(got, want, atol=atol, rtol=0.1,
                                   err_msg=f"{name} pos {t}")


def test_mlstm_chunked_equals_quadratic():
    from repro.models import xlstm
    from repro.models.layers import InitCtx
    ctx = InitCtx(jax.random.PRNGKey(0), jnp.float32)
    p, _ = xlstm.init_mlstm_block(ctx, 32, 4)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32)) * 0.5
    y1 = xlstm.mlstm_block(p, x)
    y2 = xlstm.mlstm_block_chunked(p, x, chunk=8)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-5, rtol=1e-5)


def test_local_attention_window():
    """Tokens beyond the window must not influence local attention."""
    from repro.models import attention
    from repro.models.layers import InitCtx
    ctx = InitCtx(jax.random.PRNGKey(0), jnp.float32)
    p, _ = attention.init_attention(ctx, 16, 2, 1, 8)
    S, W = 12, 4
    x = jax.random.normal(jax.random.PRNGKey(1), (1, S, 16))
    pos = jnp.arange(S)[None]
    y1 = attention.attention(p, x, pos, window=W)
    x2 = x.at[:, 0].set(x[:, 0] + 100.0)   # outside window of t >= 4
    y2 = attention.attention(p, x2, pos, window=W)
    np.testing.assert_allclose(np.asarray(y1[:, W + 1:]),
                               np.asarray(y2[:, W + 1:]), atol=1e-5)
    assert not np.allclose(np.asarray(y1[:, 0]), np.asarray(y2[:, 0]))


def test_ring_cache_long_context():
    """Local-attn ring cache: decoding past the window keeps only the
    last W positions (long_500k mechanism)."""
    from repro.models import attention
    from repro.models.layers import InitCtx
    ctx = InitCtx(jax.random.PRNGKey(0), jnp.float32)
    p, _ = attention.init_attention(ctx, 16, 2, 1, 8)
    W = 4
    cache = attention.init_kv_cache(1, attention.KVCacheSpec(W, 1, 8),
                                    dtype=jnp.float32)
    for t in range(10):
        x = jax.random.normal(jax.random.PRNGKey(t), (1, 1, 16))
        out, cache = attention.attention_decode(
            p, x, cache, jnp.asarray(t, jnp.int32), window=W)
    pos = np.asarray(cache["pos"])[0]
    assert sorted(pos) == [6, 7, 8, 9]   # only last W positions survive


def test_param_count_analytic_close():
    """ModelConfig.param_count ~ actual init size (sanity for 6ND)."""
    for name in ("llama3-8b", "gemma2-27b"):
        cfg = get_arch(name).config
        params, _ = init_model(cfg, abstract=True)
        actual = sum(int(np.prod(p.shape))
                     for p in jax.tree.leaves(params))
        est = cfg.param_count()
        assert abs(est - actual) / actual < 0.05, (name, est, actual)
