"""Serving engine: generation, continuous batching, RAG interpolation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.configs.smoke import smoke_config
from repro.core import ivf
from repro.core.rag import (RagConfig, RagDatastore, interpolate,
                            knn_logits, rag_decode_logits)
from repro.core.types import IVFConfig
from repro.models import init_model
from repro.serving import Request, ServeEngine


def _engine(rag=None, slots=2):
    cfg = smoke_config(get_arch("llama3-8b").config)
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    return cfg, ServeEngine(cfg, params, slots=slots, s_max=64, rag=rag)


def test_generates_and_finishes():
    cfg, eng = _engine()
    reqs = [Request(uid=i, prompt=[1, 2, 3], max_new_tokens=4)
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    for _ in range(40):
        if all(r.done for r in reqs):
            break
        eng.step()
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 4 for r in reqs)
    assert all(0 <= t < cfg.vocab_size for r in reqs for t in r.out)


def test_continuous_batching_reuses_slots():
    cfg, eng = _engine(slots=1)
    reqs = [Request(uid=i, prompt=[5, 6], max_new_tokens=2)
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    steps = 0
    while not all(r.done for r in reqs) and steps < 60:
        eng.step()
        steps += 1
    assert all(r.done for r in reqs)   # 3 requests through 1 slot


def test_greedy_decode_deterministic():
    cfg, eng = _engine()
    r1 = Request(uid=0, prompt=[7, 8, 9], max_new_tokens=5)
    eng.submit(r1)
    while not r1.done:
        eng.step()
    cfg2, eng2 = _engine()
    r2 = Request(uid=0, prompt=[7, 8, 9], max_new_tokens=5)
    eng2.submit(r2)
    while not r2.done:
        eng2.step()
    assert r1.out == r2.out


def test_rag_interpolation_shifts_logits():
    cfg = smoke_config(get_arch("llama3-8b").config)
    rng = np.random.default_rng(0)
    n = 512
    vecs = rng.normal(size=(n, cfg.d_model)).astype(np.float32)
    index = ivf.build_index(vecs, cfg=IVFConfig(
        dim=cfg.d_model, target_partition_size=64, kmeans_iters=10,
        delta_capacity=64))
    target_tok = 42
    ds = RagDatastore(index=index,
                      next_token=jnp.full((n + 1,), target_tok, jnp.int32))
    rcfg = RagConfig(k=8, n_probe=4, lam=0.9)
    hidden = jnp.asarray(vecs[:4])
    lm_logits = jnp.zeros((4, cfg.vocab_size))
    out = rag_decode_logits(ds, lm_logits, hidden, rcfg)
    assert (np.asarray(jnp.argmax(out, -1)) == target_tok).all()


def test_rag_lambda_zero_is_lm():
    lm = jnp.asarray(np.random.default_rng(0).normal(size=(2, 16)),
                     jnp.float32)
    knn = jnp.full((2, 16), np.log(1 / 16.0))
    out = interpolate(lm, knn, lam=1e-9)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jax.nn.log_softmax(lm)),
                               atol=1e-4)
