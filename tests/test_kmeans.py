"""Alg. 1: mini-batch balanced k-means."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kmeans
from repro.core.types import IVFConfig
from tests.conftest import clustered_data


def test_running_mean_equals_sequential():
    """The grouped centroid update must telescope to Alg. 1's sequential
    eta = 1/v[c] loop exactly."""
    rng = np.random.default_rng(0)
    k, d, s = 4, 8, 32
    cents = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    counts = jnp.asarray(rng.integers(1, 10, k).astype(np.float32))
    batch = jnp.asarray(rng.normal(size=(s, d)).astype(np.float32))

    new_c, new_v, assign = kmeans.assign_minibatch(
        cents, counts, batch, balance_weight=0.5, target_size=10)

    # sequential oracle (lines 9-13 of Alg. 1)
    c_ref = np.array(cents)
    v_ref = np.array(counts)
    for x, a in zip(np.array(batch), np.array(assign)):
        v_ref[a] += 1
        eta = 1.0 / v_ref[a]
        c_ref[a] = (1 - eta) * c_ref[a] + eta * x
    np.testing.assert_allclose(np.array(new_c), c_ref, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.array(new_v), v_ref)


def test_balance_constraint_reduces_max_partition():
    X = clustered_data(n=3000, seed=1)
    cfg_bal = IVFConfig(dim=32, target_partition_size=50, minibatch_size=128,
                        kmeans_iters=60, balance_weight=4.0,
                        balanced_final_assign=True)
    cfg_unb = IVFConfig(dim=32, target_partition_size=50, minibatch_size=128,
                        kmeans_iters=60, balance_weight=0.0)
    _, _, a_bal = kmeans.fit_in_memory(X, cfg_bal)
    _, _, a_unb = kmeans.fit_in_memory(X, cfg_unb)
    mx_bal = np.bincount(a_bal).max()
    mx_unb = np.bincount(a_unb).max()
    assert mx_bal < mx_unb, (mx_bal, mx_unb)
    # no mega-clusters: max stays within a small factor of target
    assert mx_bal <= 4 * cfg_bal.target_partition_size


def test_streaming_never_buffers_dataset():
    """fit() must work from a sampling callback -- full array never needed."""
    X = clustered_data(n=2000, seed=2)
    cfg = IVFConfig(dim=32, target_partition_size=100, minibatch_size=64,
                    kmeans_iters=20)
    km = kmeans.MiniBatchKMeans(cfg)

    calls = []
    def sample(size, rng):
        calls.append(size)
        idx = rng.integers(0, len(X), size)
        return X[idx]

    km.fit(sample, len(X))
    assert max(calls) <= max(cfg.minibatch_size, km.k)
    assert km.centroids.shape == (len(X) // 100, 32)


def test_mean_partition_size_near_target():
    X = clustered_data(n=4000, seed=3)
    cfg = IVFConfig(dim=32, target_partition_size=80, minibatch_size=128,
                    kmeans_iters=50)
    _, _, assign = kmeans.fit_in_memory(X, cfg)
    sizes = np.bincount(assign, minlength=len(X) // 80)
    assert abs(sizes.mean() - 80) < 1e-6  # k = n/target exactly
