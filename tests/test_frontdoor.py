"""Serving front door (PR 7): admission queue, cross-request
micro-batching, daemonized maintenance.

Pins the subsystem's two load-bearing guarantees:

  * **Coalescing is invisible.** N callers sharing a QuerySpec get
    results bit-identical (ids + scores) to the solo `query()` each
    replaced, on resident and paged engines and on both backends -- and
    the fused call compiles exactly once per Q-bucket (trace_count).

  * **Concurrency is safe.** Queries, session upserts, and daemon
    maintenance interleaving from many threads leave the engine in a
    state bit-identical to a single-threaded twin that applied the same
    writes (store row-set equality + exact-search parity), and the
    front door answers post-quiesce queries bit-identically to direct
    `query()` on the same engine.
"""
import threading

import numpy as np
import pytest

from repro.core import executor
from repro.core.query import Q, QuerySpec
from repro.core.types import IVFConfig
from repro.serving import FrontDoor, FrontDoorConfig, empty_stats
from repro.storage import MicroNN
from tests.conftest import clustered_data

DIM = 16


def _mk_engine(tmp_path, name, paged=False, n=900, seed=3):
    X = clustered_data(n=n, dim=DIM, seed=seed)
    eng = MicroNN(dim=DIM, path=str(tmp_path / f"{name}.db"),
                  config=IVFConfig(dim=DIM, target_partition_size=50,
                                   kmeans_iters=10, delta_capacity=64),
                  memory_budget_mb=0.05 if paged else None)
    eng.upsert(np.arange(n), X)
    eng.build()
    return eng, X


# -- coalescing: bit-parity + one compile per bucket -------------------------


@pytest.mark.parametrize("paged", [False, True], ids=["resident", "paged"])
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_coalesced_bit_parity_vs_solo(tmp_path, paged, backend):
    """Acceptance: N callers sharing a spec inside one window get the
    same ids+scores the solo query() path returns, bitwise, resident
    and paged, both backends."""
    eng, X = _mk_engine(tmp_path, f"par-{backend}", paged=paged)
    spec = Q.knn(k=10, n_probe=6).backend(backend)
    queries = X[:7] + 0.01  # 7 single-row callers -> one fused Q=7 call
    solo = [eng.query(queries[i], spec) for i in range(len(queries))]
    with FrontDoor(eng, window_s=0.2, max_batch_rows=64) as fd:
        futs = [fd.submit(queries[i], spec) for i in range(len(queries))]
        outs = [f.result(30) for f in futs]
        st = fd.stats()
    assert st["completed"] == len(queries)
    assert st["coalesced"] >= 2, "window should have fused the callers"
    for rs, ref in zip(outs, solo):
        np.testing.assert_array_equal(np.asarray(rs.ids),
                                      np.asarray(ref.ids))
        np.testing.assert_array_equal(np.asarray(rs.scores),
                                      np.asarray(ref.scores))
    eng.store.close()


def test_equal_specs_compile_once_per_bucket(tmp_path):
    """Acceptance: equal specs from N threads hit ONE jit entry -- the
    fused call traces once for its Q-bucket, and a second identical
    wave retraces nothing."""
    eng, X = _mk_engine(tmp_path, "trace")
    # a spec signature no other test in this process has run, so the
    # plan cache is provably cold for it
    spec = QuerySpec(k=9, n_probe=7)
    with FrontDoor(eng, window_s=0.3, max_batch_rows=64) as fd:
        before = executor.trace_count()
        futs = [fd.submit(X[i], spec) for i in range(6)]
        [f.result(30) for f in futs]
        st = fd.stats()
        assert st["batches"] == 1 and st["coalesced"] == 6, st
        assert executor.trace_count() == before + 1, \
            "one fused call == one trace for its Q-bucket"
        # same spec, same bucket, new callers: pure cache hit
        futs = [fd.submit(X[6 + i], spec) for i in range(6)]
        [f.result(30) for f in futs]
        assert executor.trace_count() == before + 1
    eng.store.close()


def test_distinct_specs_split_into_separate_calls(tmp_path):
    """Different specs in one drain never share a fused call (the spec
    IS the compile key), and each group still returns per-caller."""
    eng, X = _mk_engine(tmp_path, "groups")
    s1, s2 = Q.knn(k=5, n_probe=4), Q.knn(k=3, n_probe=4)
    with FrontDoor(eng, window_s=0.2) as fd:
        futs = [fd.submit(X[i], s1 if i % 2 else s2) for i in range(6)]
        outs = [f.result(30) for f in futs]
    for i, rs in enumerate(outs):
        assert np.asarray(rs.ids).shape == (1, 5 if i % 2 else 3)
    eng.store.close()


def test_window_zero_disables_coalescing(tmp_path):
    """window_s=0 is the one-request-at-a-time baseline: everything
    executes solo (this is bench_serve's control arm)."""
    eng, X = _mk_engine(tmp_path, "nowin")
    with FrontDoor(eng, window_s=0.0, max_batch_rows=1) as fd:
        futs = [fd.submit(X[i], Q.knn(k=5)) for i in range(5)]
        [f.result(30) for f in futs]
        fd.drain()
        st = fd.stats()
    assert st["batches"] == 0 and st["coalesced"] == 0
    assert st["solo"] == 5 and st["completed"] == 5
    eng.store.close()


def test_max_batch_rows_caps_fused_calls(tmp_path):
    """A drain bigger than max_batch_rows splits into several fused
    calls instead of one oversized bucket."""
    eng, X = _mk_engine(tmp_path, "cap")
    spec = Q.knn(k=4, n_probe=4)
    with FrontDoor(eng, window_s=0.3, max_batch_rows=4) as fd:
        futs = [fd.submit(X[i], spec) for i in range(10)]
        outs = [f.result(30) for f in futs]
        st = fd.stats()
    assert st["completed"] == 10
    assert st["batches"] >= 2, "10 rows over a 4-row cap must split"
    for i, rs in enumerate(outs):
        ref = eng.query(X[i], spec)
        np.testing.assert_array_equal(np.asarray(rs.ids),
                                      np.asarray(ref.ids))
    eng.store.close()


# -- interleave stress: queries + session upserts + daemon maintenance -------


def _stress(tmp_path, paged):
    n0, extra, writers_batches = 600, 40, 4
    eng, X = _mk_engine(tmp_path, f"stress-{int(paged)}", paged=paged,
                        n=n0, seed=13)
    new = clustered_data(n=writers_batches * extra, dim=DIM, seed=14)
    errors = []

    with FrontDoor(eng, window_s=0.002, maintenance=True) as fd:
        def writer():
            try:
                for b in range(writers_batches):
                    lo = b * extra
                    with eng.session() as s:
                        s.upsert(np.arange(n0 + lo, n0 + lo + extra),
                                 new[lo:lo + extra])
                        if b % 2:  # churn: re-upsert a few existing ids
                            s.upsert(np.arange(5), new[lo:lo + 5])
            except BaseException as e:  # noqa: BLE001
                errors.append(e)

        def reader(seed):
            rng = np.random.default_rng(seed)
            try:
                for _ in range(8):
                    q = rng.normal(size=(DIM,)).astype(np.float32)
                    rs = fd.query(q, Q.knn(k=5, n_probe=4), timeout=60)
                    assert np.asarray(rs.ids).shape == (1, 5)
            except BaseException as e:  # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=writer)] + \
            [threading.Thread(target=reader, args=(100 + i,))
             for i in range(3)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(120)
        assert not errors, errors
        fd.drain(60)
        assert eng.scheduler.daemon_alive

        # quiesce, then pin: front-door answers == direct query() on the
        # same engine state, bitwise
        eng.maintain(until_idle=True)
        probe = X[:6] + 0.02
        spec = Q.knn(k=8, n_probe=6)
        via_fd = fd.query(probe, spec, timeout=60)
        direct = eng.query(probe, spec)
        np.testing.assert_array_equal(np.asarray(via_fd.ids),
                                      np.asarray(direct.ids))
        np.testing.assert_array_equal(np.asarray(via_fd.scores),
                                      np.asarray(direct.scores))

    # single-threaded twin: same initial build, same writes, no
    # concurrency -- the durable row set must match exactly
    twin, _ = _mk_engine(tmp_path, f"twin-{int(paged)}", paged=paged,
                         n=n0, seed=13)
    for b in range(writers_batches):
        lo = b * extra
        with twin.session() as s:
            s.upsert(np.arange(n0 + lo, n0 + lo + extra),
                     new[lo:lo + extra])
            if b % 2:
                s.upsert(np.arange(5), new[lo:lo + 5])
    twin.maintain(until_idle=True)

    ids_a, _, vecs_a = eng.store.all_rows()
    ids_b, _, vecs_b = twin.store.all_rows()
    oa, ob = np.argsort(ids_a), np.argsort(ids_b)
    np.testing.assert_array_equal(ids_a[oa], ids_b[ob])
    np.testing.assert_array_equal(vecs_a[oa], vecs_b[ob])

    # exact search is partition-assignment independent: same rows ->
    # same neighbors regardless of how maintenance carved partitions
    ra = eng.query(X[:4], Q.exact(k=5))
    rb = twin.query(X[:4], Q.exact(k=5))
    np.testing.assert_array_equal(np.sort(np.asarray(ra.ids), axis=1),
                                  np.sort(np.asarray(rb.ids), axis=1))
    np.testing.assert_array_equal(np.sort(np.asarray(ra.scores), axis=1),
                                  np.sort(np.asarray(rb.scores), axis=1))
    assert not eng.scheduler.daemon_alive, "close() must stop the daemon"
    assert eng.scheduler.daemon_errors == 0, eng.scheduler.last_daemon_error
    eng.store.close()
    twin.store.close()


def test_interleave_stress_resident(tmp_path):
    """Satellite: queries + session upserts + daemon maintenance from
    many threads, resident mode, pinned against a single-threaded
    oracle."""
    _stress(tmp_path, paged=False)


def test_interleave_stress_paged(tmp_path):
    """Same stress over the disk-resident paged engine: reads ride the
    WAL snapshot connection + pager RLock while writers hold
    MicroNN.lock."""
    _stress(tmp_path, paged=True)


# -- daemonized maintenance ---------------------------------------------------


def test_daemon_drains_maintenance_queue(tmp_path):
    """The daemon alone (no hand-cranked maintain()) drains pending
    work in bounded quanta under the engine write mutex."""
    eng, X = _mk_engine(tmp_path, "daemon", n=500, seed=21)
    eng.upsert(np.arange(500, 560),
               clustered_data(n=60, dim=DIM, seed=22))
    with FrontDoor(eng, maintenance=True, daemon_interval_s=0.001) as fd:
        assert eng.scheduler.daemon_alive
        deadline = 30.0
        import time
        t0 = time.monotonic()
        while eng.scheduler.queue_depth() > 0:
            assert time.monotonic() - t0 < deadline, \
                eng.stats()["scheduler_depth"]
            time.sleep(0.005)
        assert eng.scheduler.daemon_steps >= 1
        assert eng.scheduler.daemon_errors == 0
        # the drained index still answers through the front door
        rs = fd.query(X[0], Q.knn(k=5), timeout=60)
        assert np.asarray(rs.ids).shape == (1, 5)
    assert not eng.scheduler.daemon_alive
    eng.store.close()


# -- uniform observability ----------------------------------------------------


@pytest.mark.parametrize("paged", [False, True], ids=["resident", "paged"])
def test_stats_uniform_frontdoor_keys(tmp_path, paged):
    """Satellite: stats() reports scheduler depth, daemon liveness, and
    the front-door counter block with identical keys in both modes --
    zeroed via empty_stats() when no front door is attached."""
    eng, X = _mk_engine(tmp_path, f"stats-{int(paged)}", paged=paged,
                        n=400, seed=31)
    s = eng.stats()
    for key in ("scheduler_depth", "daemon_alive", "daemon_steps",
                "frontdoor"):
        assert key in s, key
    assert s["frontdoor"] == empty_stats()
    with FrontDoor(eng, window_s=0.05, maintenance=True) as fd:
        futs = [fd.submit(X[i], Q.knn(k=3)) for i in range(4)]
        [f.result(30) for f in futs]
        fd.drain()
        live = eng.stats()
        assert live["daemon_alive"]
        fs = live["frontdoor"]
        assert sorted(fs) == sorted(empty_stats())
        assert fs["submitted"] == 4 and fs["completed"] == 4
        assert fs["total_p50_ms"] > 0 and fs["queue_wait_p99_ms"] >= 0
    assert eng.stats()["frontdoor"] == empty_stats(), \
        "close() detaches the front door from stats()"
    eng.store.close()


def test_close_is_idempotent_and_rejects_new_work(tmp_path):
    eng, X = _mk_engine(tmp_path, "close", n=300, seed=41)
    fd = FrontDoor(eng)
    fd.query(X[0], Q.knn(k=3), timeout=60)
    fd.close()
    fd.close()
    with pytest.raises(RuntimeError, match="closed"):
        fd.submit(X[0], Q.knn(k=3))
    eng.store.close()


# -- async surface + adaptive coalescing window (PR 9 satellites) ------------


def test_async_submit_bit_parity_and_coalescing(tmp_path):
    """query_async()/submit_async() ride the same admission queue and
    dispatcher: concurrent coroutines coalesce and return bit-identical
    answers to the solo query() path."""
    import asyncio

    eng, X = _mk_engine(tmp_path, "async", n=400, seed=51)
    spec = Q.knn(k=5, n_probe=6)
    queries = X[:8] + 0.01
    solo = [eng.query(queries[i], spec) for i in range(len(queries))]

    async def run(fd):
        futs = [fd.submit_async(queries[i], spec)
                for i in range(len(queries))]
        return await asyncio.gather(*futs)

    with FrontDoor(eng, window_s=0.2) as fd:
        outs = asyncio.run(run(fd))
        one = asyncio.run(fd.query_async(queries[0], spec))
        st = fd.stats()
    assert st["completed"] == len(queries) + 1
    assert st["coalesced"] >= 2
    for rs, ref in zip(outs, solo):
        np.testing.assert_array_equal(np.asarray(rs.ids),
                                      np.asarray(ref.ids))
        np.testing.assert_array_equal(np.asarray(rs.scores),
                                      np.asarray(ref.scores))
    np.testing.assert_array_equal(np.asarray(one.ids),
                                  np.asarray(solo[0].ids))
    eng.store.close()


def test_adaptive_window_tracks_arrival_rate(tmp_path):
    """adaptive_window=True sizes the coalescing wait from the EWMA of
    inter-arrival gaps: a dense burst yields an effective window well
    under the configured ceiling, surfaced via stats()/gauges, and the
    window never exceeds window_s."""
    eng, X = _mk_engine(tmp_path, "adaptive", n=400, seed=52)
    spec = Q.knn(k=5)
    with FrontDoor(eng, window_s=0.25, adaptive_window=True,
                   coalesce_target=4) as fd:
        # a tight burst: gaps are ~free, so the EWMA collapses
        futs = [fd.submit(X[i], spec) for i in range(16)]
        [f.result(30) for f in futs]
        st = fd.stats()
        assert st["completed"] == 16
        assert st["arrival_ewma_ms"] >= 0.0
        # effective window obeys the [0, window_s] clamp and, for a
        # back-to-back burst, sits far below the 250ms ceiling
        assert 0.0 <= st["window_ms"] <= 250.0
        assert st["window_ms"] < 125.0
        w = fd._effective_window()
        assert 0.0 <= w <= 0.25
    # fixed-window mode leaves the configured window untouched
    with FrontDoor(eng, window_s=0.05) as fd:
        fd.query(X[0], spec, timeout=60)
        assert fd.stats()["window_ms"] == pytest.approx(50.0)
    eng.store.close()


def test_stats_include_window_keys(tmp_path):
    """empty_stats() and live stats() agree on the new float keys."""
    from repro.serving import empty_stats
    es = empty_stats()
    assert "window_ms" in es and "arrival_ewma_ms" in es
    eng, X = _mk_engine(tmp_path, "wkeys", n=300, seed=53)
    with FrontDoor(eng) as fd:
        fd.query(X[0], Q.knn(k=3), timeout=60)
        assert sorted(fd.stats()) == sorted(es)
    eng.store.close()
