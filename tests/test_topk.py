"""Top-k merge algebra + hypothesis property tests."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import topk
from repro.core.types import INVALID_ID


@given(st.integers(1, 50), st.integers(1, 50), st.integers(1, 10),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=40, deadline=None)
def test_merge_equals_global_topk(n_a, n_b, k, seed):
    rng = np.random.default_rng(seed)
    sa = rng.normal(size=(1, n_a)).astype(np.float32)
    sb = rng.normal(size=(1, n_b)).astype(np.float32)
    ia = rng.integers(0, 10_000, (1, n_a)).astype(np.int32)
    ib = rng.integers(10_000, 20_000, (1, n_b)).astype(np.int32)
    kk = min(k, n_a + n_b)

    ta, tia = topk.topk_smallest(jnp.asarray(sa), jnp.asarray(ia),
                                 min(kk, n_a))
    tb, tib = topk.topk_smallest(jnp.asarray(sb), jnp.asarray(ib),
                                 min(kk, n_b))
    ms, mi = topk.merge_topk(ta, tia, tb, tib, kk)

    all_s = np.concatenate([sa, sb], axis=1)
    all_i = np.concatenate([ia, ib], axis=1)
    order = np.argsort(all_s[0], kind="stable")[:kk]
    np.testing.assert_allclose(np.asarray(ms)[0], all_s[0][order],
                               rtol=1e-6)


@given(st.integers(2, 6), st.integers(1, 8), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_merge_associative(parts, k, seed):
    """merge(merge(a,b),c) == merge(a,merge(b,c)) == topk(a++b++c)."""
    rng = np.random.default_rng(seed)
    chunks = [rng.normal(size=(1, 6)).astype(np.float32)
              for _ in range(parts)]
    ids = [np.full((1, 6), i, np.int32) * 100 + np.arange(6, dtype=np.int32)
           for i in range(parts)]

    k = min(k, 6)

    def fold(order):
        s, i = topk.topk_smallest(jnp.asarray(chunks[order[0]]),
                                  jnp.asarray(ids[order[0]]), k)
        for j in order[1:]:
            s2, i2 = topk.topk_smallest(jnp.asarray(chunks[j]),
                                        jnp.asarray(ids[j]), k)
            s, i = topk.merge_topk(s, i, s2, i2, k)
        return np.asarray(s)

    left = fold(list(range(parts)))
    right = fold(list(range(parts))[::-1])
    np.testing.assert_allclose(left, right, rtol=1e-6)


def test_dedup_keeps_best():
    s = jnp.asarray([[3.0, 1.0, 2.0, 1.5]])
    i = jnp.asarray([[7, 7, 8, 8]], dtype=jnp.int32)
    ds, di = topk.dedup_by_id(s, i)
    assert list(np.asarray(di)[0][:2]) == [7, 8]
    np.testing.assert_allclose(np.asarray(ds)[0][:2], [1.0, 1.5])
    assert (np.asarray(di)[0][2:] == INVALID_ID).all()
