"""Shared fixtures. NOTE: no XLA device-count flags here -- smoke tests
and benches must see 1 device; multi-device tests spawn subprocesses."""
import numpy as np
import pytest

from repro.core import ivf
from repro.core.types import IVFConfig


def clustered_data(n=2000, dim=32, n_clusters=20, seed=0, scale=5.0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_clusters, dim)).astype(np.float32) * scale
    asg = rng.integers(0, n_clusters, n)
    X = centers[asg] + rng.normal(size=(n, dim)).astype(np.float32)
    return X


@pytest.fixture(scope="session")
def small_index():
    X = clustered_data()
    cfg = IVFConfig(dim=32, target_partition_size=50, minibatch_size=128,
                    kmeans_iters=40, delta_capacity=256)
    return ivf.build_index(X, cfg=cfg), X
