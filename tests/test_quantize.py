"""Scalar-quantization tier: codec invariants, SQ-scan backend parity,
rerank recall pins, and code consistency through updates/maintenance.

Parity contract (mirrors tests/test_executor.py): on an identical plan
the Pallas (interpret) SQ backend and the XLA SQ reference select the
same candidate rows, and -- because the float32 rerank stage is shared
code downstream of candidate selection -- the final SearchResults agree
bit-for-bit.

Recall contract (acceptance pin): int8 scan + rerank_factor=4 rerank
reaches recall@10 >= 0.95 against the float32 ANN path on synthetic
clustered data.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import delta, executor, ivf, maintenance, quantize, search
from repro.core.hybrid import And, Pred, compile_filter
from repro.core.types import INVALID_ID, IVFConfig


def _mk_data(n=1500, d=24, n_centers=16, seed=7):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_centers, d)).astype(np.float32) * 5
    X = (centers[rng.integers(0, n_centers, n)]
         + rng.normal(size=(n, d))).astype(np.float32)
    attrs = np.stack([rng.integers(0, 8, n),
                      rng.normal(size=n) * 10], 1).astype(np.float32)
    return X, attrs


@pytest.fixture(scope="module")
def sq_index():
    X, attrs = _mk_data()
    cfg = IVFConfig(dim=24, target_partition_size=50, kmeans_iters=30,
                    delta_capacity=128, quantize="int8", rerank_factor=4)
    idx = ivf.build_index(X, attrs=attrs, cfg=cfg)
    # live delta rows so the full-precision delta merge is exercised too
    rng = np.random.default_rng(1)
    nv = rng.normal(size=(10, 24)).astype(np.float32)
    idx = delta.upsert(idx, jnp.asarray(nv),
                       jnp.arange(5000, 5010, dtype=jnp.int32),
                       jnp.asarray(attrs[:10]))
    return idx, X, attrs


def _ids(res):
    return np.asarray(res.ids)


def _recall(ids, ref_ids, k):
    hits = sum(len(set(a[:k]) & set(b[:k])) for a, b in zip(ids, ref_ids))
    return hits / (len(ids) * k)


# -- codec invariants --------------------------------------------------------


def test_roundtrip_error_bounded():
    X, _ = _mk_data(n=400)
    stats = quantize.train(jnp.asarray(X))
    rec = np.asarray(quantize.decode(stats, quantize.encode(stats, X)))
    # per-dimension error is at most half a quantization step
    err = np.abs(rec - X)
    bound = np.asarray(stats.scale) * 0.5 + 1e-6
    assert (err <= bound[None, :]).all()


def test_encode_deterministic_and_int8():
    X, _ = _mk_data(n=100)
    stats = quantize.train(jnp.asarray(X))
    c1 = np.asarray(quantize.encode(stats, X))
    c2 = quantize.encode_np(stats, X)
    assert c1.dtype == np.int8
    assert np.array_equal(c1, c2)


def test_build_packs_codes_row_for_row(sq_index):
    idx, _, _ = sq_index
    val = np.asarray(idx.valid)
    vecs = np.asarray(idx.vectors)[val]
    codes = np.asarray(idx.codes)[val]
    assert codes.dtype == np.int8
    assert np.array_equal(codes, quantize.encode_np(idx.qstats, vecs))
    # resident code tier is 4x smaller than the float32 tier
    assert idx.codes.nbytes * 4 == idx.vectors.nbytes


# -- SQ scan backend parity --------------------------------------------------


def test_sq_backend_parity_ann(sq_index):
    idx, X, _ = sq_index
    plan = executor.plan_ann(idx, jnp.asarray(X[:8]), 10, 6)
    rx = executor.execute_plan(idx, plan, backend="xla")
    rp = executor.execute_plan(idx, plan, backend="pallas")
    assert (_ids(rx) == _ids(rp)).all()
    # shared rerank stage downstream of identical candidates: bit-for-bit
    assert np.array_equal(np.asarray(rx.scores), np.asarray(rp.scores))


def test_sq_backend_parity_mqo(sq_index):
    idx, X, _ = sq_index
    plan = executor.plan_ann(idx, jnp.asarray(X[:32]), 10, 4, u_max=24)
    rx = executor.execute_plan(idx, plan, backend="xla")
    rp = executor.execute_plan(idx, plan, backend="pallas")
    assert (_ids(rx) == _ids(rp)).all()
    assert np.array_equal(np.asarray(rx.scores), np.asarray(rp.scores))


def test_sq_backend_parity_filtered(sq_index):
    idx, X, attrs = sq_index
    f = compile_filter(And((Pred(0, "eq", 3.0), Pred(1, "gt", 0.0))))
    plan = executor.plan_ann(idx, jnp.asarray(X[:8]), 10, 8, attr_filter=f)
    rx = executor.execute_plan(idx, plan, backend="xla")
    rp = executor.execute_plan(idx, plan, backend="pallas")
    assert (_ids(rx) == _ids(rp)).all()
    # predicate fused inside the SQ scan: no disqualified candidate survives
    for i in _ids(rx).ravel():
        if 0 <= i < 5000:
            assert attrs[i, 0] == 3 and attrs[i, 1] > 0


# -- rerank recall + score exactness -----------------------------------------


def test_int8_rerank_recall_pin_vs_float32(sq_index):
    """Acceptance pin: int8+rerank recall@10 >= 0.95 vs the float32 ANN
    path (same plans, same index, scan tier forced per call)."""
    idx, X, _ = sq_index
    q = jnp.asarray(X[:32])
    r_f32 = executor.search(idx, q, k=10, n_probe=8, quantized=False)
    r_int8 = executor.search(idx, q, k=10, n_probe=8, quantized=True)
    assert _recall(_ids(r_int8), _ids(r_f32), 10) >= 0.95


def test_rerank_scores_are_exact_float32(sq_index):
    """Reported scores come from the rerank stage, not the quantized
    approximation: every returned (query, id) score must equal the exact
    float32 distance."""
    idx, X, _ = sq_index
    q = X[:4]
    res = executor.search(idx, jnp.asarray(q), k=5, n_probe=idx.k)
    val = np.asarray(idx.valid)
    by_id = dict(zip(np.asarray(idx.ids)[val].tolist(),
                     np.asarray(idx.vectors)[val]))
    dval = np.asarray(idx.delta.valid)
    by_id.update(zip(np.asarray(idx.delta.ids)[dval].tolist(),
                     np.asarray(idx.delta.vectors)[dval]))
    for qi, (ids, scores) in enumerate(zip(_ids(res), np.asarray(res.scores))):
        for i, s in zip(ids, scores):
            if i == INVALID_ID:
                continue
            exact = float(((q[qi] - by_id[int(i)]) ** 2).sum())
            np.testing.assert_allclose(s, exact, rtol=1e-4, atol=1e-4)


def test_exact_search_stays_float32_oracle(sq_index):
    """exact_search keeps its 100%-recall oracle contract on a quantized
    index: it brute-forces the float32 tier, never the SQ+rerank path."""
    idx, X, _ = sq_index
    q = jnp.asarray(X[:8])
    oracle = search.exact_search(idx, q, 10)
    brute = executor.search(idx, q, k=10, kind="exact", quantized=False)
    assert np.array_equal(_ids(oracle), _ids(brute))
    assert np.array_equal(np.asarray(oracle.scores), np.asarray(brute.scores))
    # full-probe SQ ANN against that oracle still clears the recall pin
    approx = executor.search(idx, q, k=10, n_probe=idx.k)
    assert _recall(_ids(approx), _ids(oracle), 10) >= 0.95


# -- updates / maintenance keep codes consistent -----------------------------


def test_delta_encodes_on_insert(sq_index):
    idx, _, _ = sq_index
    dval = np.asarray(idx.delta.valid)
    dcod = np.asarray(idx.delta.codes)[dval]
    dvec = np.asarray(idx.delta.vectors)[dval]
    assert dval.sum() == 10
    assert np.array_equal(dcod, quantize.encode_np(idx.qstats, dvec))


def test_flush_moves_codes_without_drift(sq_index):
    idx, _, _ = sq_index
    flushed, stats = maintenance.flush_delta(idx)
    assert stats.rows_moved == 10
    val = np.asarray(flushed.valid)
    assert np.array_equal(
        np.asarray(flushed.codes)[val],
        quantize.encode_np(flushed.qstats, np.asarray(flushed.vectors)[val]))
    # delta emptied but still code-backed
    assert flushed.delta.codes is not None


def test_rebuild_retrains_and_reencodes(sq_index):
    idx, _, _ = sq_index
    rebuilt, _ = maintenance.full_rebuild(idx)
    assert rebuilt.codes is not None
    val = np.asarray(rebuilt.valid)
    assert np.array_equal(
        np.asarray(rebuilt.codes)[val],
        quantize.encode_np(rebuilt.qstats, np.asarray(rebuilt.vectors)[val]))


def test_delete_hides_rows_from_quantized_scan(sq_index):
    idx, X, _ = sq_index
    victim = int(_ids(executor.search(idx, jnp.asarray(X[:1]), k=1,
                                      n_probe=idx.k))[0, 0])
    idx2 = delta.delete(idx, jnp.asarray([victim], jnp.int32))
    res = executor.search(idx2, jnp.asarray(X[:1]), k=10, n_probe=idx.k)
    assert victim not in _ids(res)[0]


# -- plan/compile cache ------------------------------------------------------


def test_quantized_is_cache_key_dimension(sq_index):
    idx, X, _ = sq_index
    q = jnp.asarray(X[:4])
    executor.search(idx, q, k=10, n_probe=6, quantized=True)
    executor.search(idx, q, k=10, n_probe=6, quantized=False)
    c0 = executor.trace_count()
    # both tiers warm: re-running either never retraces
    executor.search(idx, q, k=10, n_probe=6, quantized=True)
    executor.search(idx, q, k=10, n_probe=6, quantized=False)
    executor.search(idx, q, k=10, n_probe=6)   # auto == quantized path
    assert executor.trace_count() == c0 + 1    # auto(None) is its own key


def test_unquantized_index_rejects_forced_quantized(sq_index):
    _, X, _ = sq_index
    cfg = IVFConfig(dim=24, target_partition_size=50, kmeans_iters=5)
    plain = ivf.build_index(X[:200], cfg=cfg)
    assert plain.codes is None
    with pytest.raises(AssertionError):
        executor.search(plain, jnp.asarray(X[:2]), k=5, quantized=True)


# -- storage/streaming + sharding integration --------------------------------


def test_train_from_store_matches_in_memory_train(tmp_path):
    from repro.storage import VectorStore
    X, _ = _mk_data(n=300, d=16)
    st = VectorStore(str(tmp_path / "t.db"), dim=16)
    st.upsert(list(range(300)), X)
    streamed = quantize.train_from_store(st, batch_size=64)
    in_mem = quantize.train(jnp.asarray(X))
    np.testing.assert_array_equal(np.asarray(streamed.lo),
                                  np.asarray(in_mem.lo))
    np.testing.assert_array_equal(np.asarray(streamed.scale),
                                  np.asarray(in_mem.scale))


def test_index_shardings_mirror_quantized_pytree(sq_index):
    """The sharding template must match the index's pytree structure,
    codes/qstats included, or device_put rejects a quantized index."""
    import jax
    from jax.sharding import Mesh
    from repro.distributed.sharded_index import index_shardings

    idx, _, _ = sq_index
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    tmpl = index_shardings(idx, mesh)
    assert tmpl.codes is not None and tmpl.qstats is not None
    placed = jax.device_put(idx, tmpl)
    assert np.array_equal(np.asarray(placed.codes), np.asarray(idx.codes))
