"""Observability layer (PR 8): unified metrics registry, per-query trace
spans, the maintenance event log.

Pins the layer's three contracts:

  * **free when off** -- untraced queries allocate no new registry
    series, record nothing into the trace ring, and return
    `rs.trace is None`;
  * **exact when on** -- `explain()` returns a complete per-stage
    QueryTrace in all four engine modes (resident/paged x f32/int8, on
    both backends), whose pager-fault counters reconcile EXACTLY with
    the pager's registry counters across the traced call and whose scan
    `compiled` count reconciles with the executor's jit trace count;
  * **one source of truth** -- `MicroNN.stats()` / `FrontDoor.stats()`
    keys are derived views over the registry (scheduler telemetry,
    pager counters), and every series exports through snapshot() /
    to_prometheus().
"""
import re
import threading

import numpy as np
import pytest

from repro.core import executor
from repro.core.query import Q
from repro.core.types import IVFConfig
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serving import FrontDoor
from repro.storage import MicroNN
from tests.conftest import clustered_data

DIM = 16


def _mk(tmp_path, name, *, paged=False, quant=False, n=400, seed=0,
        **eng_kw):
    cfg = IVFConfig(dim=DIM, target_partition_size=50, kmeans_iters=8,
                    delta_capacity=64,
                    **({"quantize": "int8", "rerank_factor": 4}
                       if quant else {}))
    eng = MicroNN(dim=DIM, path=str(tmp_path / f"{name}.db"), config=cfg,
                  memory_budget_mb=0.05 if paged else None, **eng_kw)
    X = clustered_data(n=n, dim=DIM, seed=seed)
    eng.upsert(np.arange(n), X)
    eng.build()
    return eng, X


# -- metrics registry unit behaviour -----------------------------------------


def test_counter_gauge_get_or_create():
    reg = obs_metrics.MetricsRegistry()
    c = reg.counter("reqs", comp="a")
    c.inc()
    c.inc(4)
    assert c.value == 5
    # same (name, labels) -> same object; different labels -> new series
    assert reg.counter("reqs", comp="a") is c
    assert reg.counter("reqs", comp="b") is not c
    g = reg.gauge("depth")
    g.set(3.5)
    assert g.value == 3.5
    reg.gauge("live", fn=lambda: 7)
    assert reg.gauge("live").value == 7
    # a name registered as one kind cannot be re-registered as another
    with pytest.raises(AssertionError):
        reg.histogram("reqs", comp="a")


def test_histogram_quantiles_and_merge():
    reg = obs_metrics.MetricsRegistry()
    h = reg.histogram("lat")
    assert h.quantile(0.5) == 0.0            # empty -> 0 (empty_stats)
    for v in (0.001, 0.002, 0.004, 0.008, 0.1):
        h.observe(v)
    assert h.count == 5 and h.sum == pytest.approx(0.115)
    p50 = h.quantile(0.50)
    assert 0.001 <= p50 <= 0.01
    assert h.quantile(1.0) == pytest.approx(0.1)
    # merge folds counts elementwise (same edges required)
    h2 = obs_metrics.Histogram("lat2")
    h2.observe(0.2)
    h.merge(h2)
    assert h.count == 6
    assert h.quantile(1.0) == pytest.approx(0.2)
    with pytest.raises(AssertionError):
        h.merge(obs_metrics.Histogram("odd", buckets=(1.0, 2.0)))


def test_scope_binds_and_nests_labels():
    reg = obs_metrics.MetricsRegistry()
    s = reg.scope(engine="0")
    c = s.counter("ops", component="pager")
    assert dict(c.labels) == {"engine": "0", "component": "pager"}
    # nested scopes merge, inner wins on conflict
    s2 = s.scope(component="exec").scope(component="exec2")
    assert dict(s2.counter("ops").labels) == {"engine": "0",
                                              "component": "exec2"}


def test_snapshot_and_prometheus_export():
    reg = obs_metrics.MetricsRegistry()
    reg.counter("hits", component="pager").inc(3)
    reg.gauge("depth").set(2)
    reg.histogram("wait_s").observe(0.005)
    snap = reg.snapshot()
    assert snap["counters"]['hits{component="pager"}'] == 3
    assert snap["gauges"]["depth"] == 2
    hs = snap["histograms"]["wait_s"]
    assert hs["count"] == 1 and hs["p50"] > 0
    text = reg.to_prometheus()
    assert "# TYPE hits counter" in text
    assert 'hits{component="pager"} 3' in text
    assert "# TYPE wait_s histogram" in text
    assert 'le="+Inf"' in text and "wait_s_count 1" in text


# -- explain(): complete traces in every engine mode -------------------------


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("quant", [False, True], ids=["f32", "int8"])
@pytest.mark.parametrize("paged", [False, True], ids=["resident", "paged"])
def test_explain_complete_all_modes(tmp_path, paged, quant, backend):
    """Acceptance: explain() returns a per-stage QueryTrace in all four
    engine modes, on both backends, with the mode-appropriate spans and
    work counters."""
    eng, X = _mk(tmp_path, f"ex-{paged}-{quant}-{backend}",
                 paged=paged, quant=quant)
    spec = Q.knn(k=5, n_probe=4).backend(backend)
    tr = eng.explain(X[:2] + 0.01, spec)
    assert tr is not None and tr.mode == ("paged" if paged else "resident")
    assert tr.n_queries == 2 and tr.total_ms > 0 and tr.spec is not None
    for stage in ("plan", "probe", "scan", "merge"):
        assert stage in tr, (stage, tr.span_names)
    scan = tr.get("scan")
    assert scan.counters["partitions"] > 0
    assert scan.counters["rows"] > 0
    assert scan.counters["backend"] == backend
    assert scan.counters["quantized"] is quant
    assert tr.counter("probe", "partitions") > 0
    if paged:
        assert "pager_fault" in tr
    if quant:
        assert "rerank" in tr
    # the trace carries its ResultSet, and the ring kept it
    assert tr.result is not None and tr.result.trace is tr
    assert tr in eng.traces.traces()
    # format() renders every span (the quickstart prints this)
    txt = tr.format()
    assert "scan" in txt and "QueryTrace" in txt
    eng.store.close()


def test_trace_counters_reconcile_paged(tmp_path):
    """Acceptance: the fault span's hits/misses/bytes_read equal the
    pager's registry-counter deltas across the traced call, EXACTLY."""
    eng, X = _mk(tmp_path, "recon", paged=True, quant=True, n=600)
    spec = Q.knn(k=5, n_probe=4)
    eng.query(X[:2], spec)                      # warm compile path
    s0 = eng.stats()
    tr = eng.explain(X[300:302], spec)
    s1 = eng.stats()
    assert tr.counter("pager_fault", "hits") == s1["hits"] - s0["hits"]
    assert tr.counter("pager_fault", "misses") == \
        s1["misses"] - s0["misses"]
    assert tr.counter("pager_fault", "bytes_read") == \
        s1["bytes_read"] - s0["bytes_read"]
    # the traced call faulted SOMETHING (fresh probe set, cold frames)
    assert tr.counter("pager_fault", "hits") \
        + tr.counter("pager_fault", "misses") > 0
    eng.store.close()


def test_trace_compile_counter_reconciles_resident(tmp_path):
    """Acceptance: scan `compiled` == executor.trace_count() delta --
    cold Q-bucket compiles, warm bucket is a cache hit."""
    eng, X = _mk(tmp_path, "compiles")
    spec = Q.knn(k=7, n_probe=5)                # fresh spec: cold cache
    c0 = executor.trace_count()
    tr_cold = eng.explain(X[:1], spec)
    c1 = executor.trace_count()
    tr_warm = eng.explain(X[1:2], spec)
    c2 = executor.trace_count()
    assert tr_cold.counter("scan", "compiled") == c1 - c0 > 0
    assert tr_cold.counter("scan", "cache_hit") is False
    assert tr_warm.counter("scan", "compiled") == c2 - c1 == 0
    assert tr_warm.counter("scan", "cache_hit") is True
    eng.store.close()


# -- tracing-off hot path: zero cost, zero allocation ------------------------


def test_untraced_queries_allocate_nothing(tmp_path):
    eng, X = _mk(tmp_path, "zero")
    spec = Q.knn(k=5, n_probe=4)
    eng.query(X[:1], spec)                      # register + compile once
    reg = obs_metrics.default_registry()
    size0, ring0 = reg.size(), len(eng.traces)
    for i in range(5):
        rs = eng.query(X[i:i + 1], spec)
        assert rs.trace is None
    assert reg.size() == size0, "untraced query registered a new series"
    assert len(eng.traces) == ring0, "untraced query entered the ring"
    # global kill-switch: even trace=True records nothing
    obs_trace.set_enabled(False)
    try:
        rs = eng.query(X[:1], spec, trace=True)
        assert rs.trace is None and len(eng.traces) == ring0
    finally:
        obs_trace.set_enabled(True)
    eng.store.close()


# -- front door: per-caller traces under concurrent load ---------------------


def test_frontdoor_traced_submits_under_threads(tmp_path):
    """Traced and untraced callers interleave from many threads: every
    traced caller gets its own queue_wait + the shared fused spans;
    untraced callers get rs.trace None; results match solo query()."""
    eng, X = _mk(tmp_path, "fdtrace")
    spec = Q.knn(k=5, n_probe=4)
    n_req = 8
    solo = [eng.query(X[i] + 0.01, spec) for i in range(n_req)]
    results = [None] * n_req
    with FrontDoor(eng, window_s=0.2, max_batch_rows=64) as fd:
        def worker(i):
            results[i] = fd.query(X[i] + 0.01, spec,
                                  trace=(i % 2 == 0), timeout=30)
        ts = [threading.Thread(target=worker, args=(i,))
              for i in range(n_req)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        st = fd.stats()
    assert st["completed"] == n_req and st["failed"] == 0
    for i, rs in enumerate(results):
        np.testing.assert_array_equal(np.asarray(rs.ids),
                                      np.asarray(solo[i].ids))
        if i % 2 == 0:
            tr = rs.trace
            assert tr is not None and "queue_wait" in tr
            for stage in ("plan", "probe", "scan"):
                assert stage in tr, (stage, tr.span_names)
            assert tr.shared is not None        # adopted the fused call
            assert tr in eng.traces.traces()
        else:
            assert rs.trace is None
    # coalesced traced callers reference the SAME fused-scan Span and
    # record their share of the batch in the split sub-span
    traced = [r.trace for i, r in enumerate(results) if i % 2 == 0]
    by_shared = {}
    for tr in traced:
        by_shared.setdefault(id(tr.shared), []).append(tr)
    for group in by_shared.values():
        if len(group) > 1:
            assert len({id(t.get("scan")) for t in group}) == 1
            for t in group:
                assert t.counter("split", "callers") >= len(group)
    eng.store.close()


def test_frontdoor_stats_derive_from_histograms(tmp_path):
    """The reservoir replacement: percentile keys are now derived from
    registry histograms and stay non-zero after traffic (the shape pin
    lives in test_serving's uniform-stats test)."""
    eng, X = _mk(tmp_path, "fdh")
    with FrontDoor(eng, window_s=0.0) as fd:
        for i in range(4):
            fd.query(X[i], Q.knn(k=5, n_probe=4), timeout=30)
        st = fd.stats()
        assert st["total_p50_ms"] > 0 and st["execute_p99_ms"] > 0
        # the series live in the process registry under this scope
        assert fd.metrics.histogram("total_s").count == 4
    eng.store.close()


# -- scheduler telemetry + maintenance event log -----------------------------


def test_scheduler_telemetry_and_event_log(tmp_path):
    eng, X = _mk(tmp_path, "sched", n=400)
    eng.upsert(np.arange(400, 480),
               clustered_data(n=80, dim=DIM, seed=9))
    reports = eng.maintain(until_idle=True)
    assert reports, "expected at least one maintenance step"
    st = eng.scheduler.stats()
    assert st["steps"] == len(reports)
    assert st["rows_moved"] == sum(r.rows for r in reports)
    assert st["bytes_written"] == sum(r.bytes_written for r in reports)
    assert sum(st["actions"].values()) == st["steps"]
    assert st["actions"]["flush"] >= 1
    # surfaced through the engine's uniform stats dict
    assert eng.stats()["scheduler"]["steps"] == st["steps"]
    # the event log saw every step: planned -> step pairs, in order
    events = eng.traces.events()
    kinds = [e.kind for e in events]
    assert kinds.count("step") == len(reports)
    assert kinds.index("planned") < kinds.index("step")
    steps = [e for e in events if e.kind == "step"]
    assert sum(e.rows for e in steps) == st["rows_moved"]
    assert all(e.dur_ms >= 0 and e.action for e in steps)
    assert all(e.to_dict()["kind"] == e.kind for e in events)
    eng.store.close()


# -- trace ring + slow-query log ---------------------------------------------


def test_trace_ring_bounded_and_slow_log(tmp_path):
    eng, X = _mk(tmp_path, "ring", trace_ring_capacity=4,
                 slow_query_ms=0.0)           # every trace is "slow"
    spec = Q.knn(k=5, n_probe=4)
    for i in range(6):
        eng.explain(X[i:i + 1], spec)
    assert len(eng.traces) == 4               # ring rotated
    assert len(eng.traces.traces()) == 4
    slow = eng.traces.slow()
    assert len(slow) == 6                     # slow log kept them all
    assert all(t.total_ms >= 0.0 for t in slow)
    eng.traces.clear()
    assert len(eng.traces) == 0 and not eng.traces.slow()
    eng.store.close()


def test_slow_log_threshold_filters(tmp_path):
    eng, X = _mk(tmp_path, "slowhi", slow_query_ms=1e9)
    eng.explain(X[:1], Q.knn(k=5, n_probe=4))
    assert len(eng.traces.traces()) == 1
    assert eng.traces.slow() == []            # under the threshold
    eng.store.close()


# -- registry cardinality guard (PR 9 satellite) -----------------------------


def test_registry_cardinality_guard_caps_per_name_series():
    """A runaway label set (one series per request id, say) is bounded:
    per-name LRU keeps the cap hottest series, evictions are counted in
    obs_series_evicted, and other names are untouched."""
    reg = obs_metrics.MetricsRegistry(max_series_per_name=4)
    for i in range(10):
        reg.counter("chatty", rid=str(i)).inc()
    snap = reg.snapshot()
    chatty = [k for k in snap["counters"] if k.startswith("chatty")]
    assert len(chatty) == 4
    kept = {k.split('rid="')[1].rstrip('"}') for k in chatty}
    assert kept == {"6", "7", "8", "9"}     # LRU: most recent survive
    ev = reg.counter("obs_series_evicted")
    assert ev.value == 6
    # an evicted series re-registers fresh (counts reset -- the guard
    # trades unbounded memory for that)
    c0 = reg.counter("chatty", rid="0")
    assert c0.value == 0


def test_registry_cardinality_guard_lru_touch_on_reuse():
    """Re-fetching a series refreshes its LRU slot, so steady-state
    series survive churn from one-shot labels."""
    reg = obs_metrics.MetricsRegistry(max_series_per_name=3)
    hot = reg.counter("m", k="hot")
    hot.inc(5)
    for i in range(8):
        reg.counter("m", k=f"cold{i}")
        assert reg.counter("m", k="hot") is hot     # touch keeps it live
    assert hot.value == 5
    assert reg.counter("obs_series_evicted").value == 6
    # distinct names each get their own budget; single-series names are
    # never at risk (the guard key is (name) -> labels LRU)
    for i in range(10):
        reg.gauge("g_other", i=str(i)).set(i)
    assert reg.counter("m", k="hot") is hot


# -- Prometheus exposition hardening (PR 10) ---------------------------------


_PROM_SAMPLE = re.compile(
    r'^([a-zA-Z_:][a-zA-Z0-9_:]*)(?:\{(.*)\})? (\S+)$')


def _parse_prom_labels(s):
    """Strict text-format label parser: `k="v",...` where v uses the
    \\\\ , \\" and \\n escapes. Raises on anything malformed -- the
    test's point is that a strict scraper accepts the page."""
    out = {}
    i = 0
    while i < len(s):
        eq = s.index("=", i)
        key = s[i:eq]
        assert s[eq + 1] == '"', s
        i, val = eq + 2, []
        while s[i] != '"':
            if s[i] == "\\":
                esc = s[i + 1]
                assert esc in ('\\', '"', 'n'), f"bad escape \\{esc}"
                val.append({"\\": "\\", '"': '"', "n": "\n"}[esc])
                i += 2
            else:
                val.append(s[i])
                i += 1
        out[key] = "".join(val)
        i += 1                                  # closing quote
        if i < len(s):
            assert s[i] == ",", s
            i += 1
    return out


def test_prometheus_roundtrip_nasty_labels():
    """Acceptance (PR 10): label values containing backslash, quote and
    newline survive export -> strict parse -> exact round-trip, and
    every metric family carries exactly one # HELP + # TYPE header."""
    reg = obs_metrics.MetricsRegistry()
    nasty = {"path": 'C:\\tmp\\"x"', "note": 'line1\nline2',
             "plain": "ok"}
    reg.counter("pager.hits", **nasty).inc(3)
    reg.counter("pager.hits", plain="other").inc(1)
    reg.gauge("depth", q='say "when"').set(2.5)
    reg.histogram("wait.s", tenant="a\\b").observe(0.004)
    text = reg.to_prometheus()

    helps, types, samples = {}, {}, []
    for line in text.splitlines():
        if not line:
            continue
        if line.startswith("# HELP "):
            fam = line.split(" ", 3)[2]
            helps[fam] = helps.get(fam, 0) + 1
        elif line.startswith("# TYPE "):
            fam = line.split(" ", 3)[2]
            types[fam] = types.get(fam, 0) + 1
            assert fam in helps, f"# TYPE {fam} before its # HELP"
        else:
            m = _PROM_SAMPLE.match(line)
            assert m, f"unparseable sample line: {line!r}"
            name, raw, value = m.groups()
            labels = _parse_prom_labels(raw) if raw else {}
            samples.append((name, labels, float(value)))
            fam = re.sub(r"_(bucket|sum|count)$", "", name)
            assert fam in types or name in types, \
                f"sample {name} precedes its # TYPE"
    # exactly one header pair per family, names sanitized (dots -> _)
    assert helps == {"pager_hits": 1, "depth": 1, "wait_s": 1}
    assert types == helps
    assert types and all(n == 1 for n in types.values())
    # bit-exact label round-trip through the escapes
    got = [ls for n, ls, v in samples
           if n == "pager_hits" and v == 3.0]
    assert got == [nasty]
    assert any(n == "depth" and ls == {"q": 'say "when"'} and v == 2.5
               for n, ls, v in samples)
    assert any(n == "wait_s_count" and ls == {"tenant": "a\\b"}
               for n, ls, _ in samples)
    # cumulative le series end at +Inf with the family labels intact
    infs = [ls for n, ls, _ in samples
            if n == "wait_s_bucket" and ls.get("le") == "+Inf"]
    assert infs == [{"tenant": "a\\b", "le": "+Inf"}]


# -- interleave stress: recorder + traces under concurrency (PR 10) ----------


def test_interleave_recorder_traces_pinned_vs_twin(tmp_path):
    """Flight recorder + TraceRing + live maintenance daemon under
    multi-threaded FrontDoor.submit(trace=True): every answer is
    bit-identical to a single-threaded twin engine, the concurrent
    capture replays cleanly on that twin, traced callers all reach the
    ring, and the daemon survives the churn."""
    import repro.obs.recorder as obs_recorder

    eng, X = _mk(tmp_path, "il-mt", seed=5)
    twin, _ = _mk(tmp_path, "il-st", seed=5)    # same build, no threads
    spec = Q.knn(k=5, n_probe=4)
    n_threads, per = 4, 6
    probes = [[X[(t * per + j) % len(X)] + 0.01 for j in range(per)]
              for t in range(n_threads)]
    results = [[None] * per for _ in range(n_threads)]
    errors = []
    cap = str(tmp_path / "cap.db")

    with obs_recorder.recording(cap) as rec:
        with FrontDoor(eng, window_s=0.002, maintenance=True) as fd:
            def caller(t):
                try:
                    for j in range(per):
                        results[t][j] = fd.query(
                            probes[t][j], spec,
                            trace=(t % 2 == 0), timeout=60)
                except BaseException as e:  # noqa: BLE001
                    errors.append(e)

            threads = [threading.Thread(target=caller, args=(t,))
                       for t in range(n_threads)]
            for th in threads:
                th.start()
            for th in threads:
                th.join(120)
            assert not errors, errors
            assert eng.scheduler.daemon_alive
            # maintenance events interleave with the capture stream
            eng.upsert(np.arange(400, 440),
                       clustered_data(n=40, dim=DIM, seed=6))
            eng.maintain(until_idle=True)
            assert any(e.kind == "step" for e in eng.traces.events())
        assert rec.recorded == n_threads * per

    # single-threaded twin: identical probes, identical bits
    for t in range(n_threads):
        for j in range(per):
            solo = twin.query(probes[t][j], spec)
            np.testing.assert_array_equal(
                np.asarray(results[t][j].ids), np.asarray(solo.ids))
            np.testing.assert_array_equal(
                np.asarray(results[t][j].scores),
                np.asarray(solo.scores))
    # traced callers reached the ring; untraced stayed out of it
    for t in range(n_threads):
        for rs in results[t]:
            if t % 2 == 0:
                assert rs.trace is not None \
                    and rs.trace in eng.traces.traces()
            else:
                assert rs.trace is None
    # the concurrent capture replays deterministically on the twin
    # (front-door records are digestless: double-run self-check)
    rep = obs_recorder.replay(cap, engine=twin, strict=True)
    assert rep.ok and rep.self_checked == n_threads * per
    eng.store.close()
    twin.store.close()
