"""Incremental split/merge maintenance subsystem (PR 5): the vectorized
running-mean update, deterministic local repair planning, the monitor's
prioritized work queue, the budgeted scheduler's quantum contract,
resident-vs-paged repair parity, and crash safety of the codes-then-
generation-swap durability ordering."""
import dataclasses
import shutil

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import delta, ivf, maintenance
from repro.core.monitor import IndexMonitor, MonitorConfig
from repro.core.types import IVFConfig, pairwise_scores
from repro.storage import MicroNN
from tests.conftest import clustered_data


# -- running_mean_update vectorization (satellite) ---------------------------


def _running_mean_loop(cent, csizes, dx, assign, touched):
    """The pre-vectorization per-partition loop, kept verbatim as the
    regression reference: the np.add.at scatter must reproduce it
    bit-for-bit (axis-0 float32 sums accumulate sequentially in row
    order, exactly like the scatter)."""
    for p in touched:
        m = int((assign == p).sum())
        v = csizes[p]
        cent[p] = (v * cent[p] + dx[assign == p].sum(0)) / max(v + m, 1.0)
        csizes[p] = v + m


@pytest.mark.parametrize("m", [5, 63, 400, 1500])
def test_running_mean_update_bitwise_matches_loop(m):
    rng = np.random.default_rng(m)
    k, d = 11, 24
    cent0 = rng.normal(size=(k, d)).astype(np.float32)
    csz0 = rng.integers(1, 200, k).astype(np.float32)
    dx = rng.normal(size=(m, d)).astype(np.float32)
    assign = rng.integers(0, k - 2, m)        # leave some untouched
    touched = np.unique(assign)
    c_loop, s_loop = cent0.copy(), csz0.copy()
    _running_mean_loop(c_loop, s_loop, dx, assign, touched)
    c_vec, s_vec = cent0.copy(), csz0.copy()
    drift = np.zeros(k, np.float32)
    maintenance.running_mean_update(c_vec, s_vec, dx, assign, touched,
                                    drift=drift)
    np.testing.assert_array_equal(c_loop, c_vec)
    np.testing.assert_array_equal(s_loop, s_vec)
    # drift accumulated exactly the displacement of the touched centroids
    np.testing.assert_allclose(
        drift[touched], np.linalg.norm(c_vec[touched] - cent0[touched],
                                       axis=-1), rtol=1e-6)
    assert (drift[np.setdiff1d(np.arange(k), touched)] == 0).all()


def test_flush_accumulates_drift_and_repair_resets_it():
    X = clustered_data(n=900, dim=16, seed=2)
    cfg = IVFConfig(dim=16, target_partition_size=40, kmeans_iters=10,
                    delta_capacity=128)
    idx = ivf.build_index(X, cfg=cfg)
    assert (np.asarray(idx.drift) == 0).all()
    nv = (np.asarray(idx.centroids)[0]
          + np.random.default_rng(0).normal(size=(30, 16)) * 3
          ).astype(np.float32)
    idx = delta.upsert(idx, jnp.asarray(nv),
                       jnp.arange(9000, 9030, dtype=jnp.int32),
                       jnp.zeros((30, 0)))
    idx, _ = maintenance.flush_delta(idx)
    assert float(np.asarray(idx.drift).max()) > 0


# -- deterministic 2-means + planning ----------------------------------------


def test_two_means_separates_two_blobs_deterministically():
    rng = np.random.default_rng(0)
    a = rng.normal(size=(40, 8)).astype(np.float32)
    b = rng.normal(size=(50, 8)).astype(np.float32) + 30.0
    rows = np.concatenate([a, b])
    cents, assign = maintenance.two_means(rows)
    cents2, assign2 = maintenance.two_means(rows.copy())
    np.testing.assert_array_equal(assign, assign2)
    np.testing.assert_array_equal(cents, cents2)
    # each blob lands wholly on one side
    assert len(np.unique(assign[:40])) == 1
    assert len(np.unique(assign[40:])) == 1
    assert assign[0] != assign[-1]


def test_two_means_degenerate_rows_yield_one_side():
    rows = np.ones((16, 4), np.float32)
    _, assign = maintenance.two_means(rows)
    assert (assign == assign[0]).all()


def test_partial_flush_keeps_deferred_rows_searchable():
    X = clustered_data(n=800, dim=16, seed=5)
    cfg = IVFConfig(dim=16, target_partition_size=40, kmeans_iters=10,
                    delta_capacity=256)
    idx = ivf.build_index(X, cfg=cfg)
    rng = np.random.default_rng(3)
    nv = rng.normal(size=(100, 16)).astype(np.float32)
    idx = delta.upsert(idx, jnp.asarray(nv),
                       jnp.arange(9000, 9100, dtype=jnp.int32),
                       jnp.zeros((100, 0)))
    idx2, st = maintenance.flush_delta(idx, max_rows=30)
    assert st.rows_moved == 30
    assert int(idx2.delta.valid.sum()) == 70
    assert int(idx2.delta.count) == 70            # compacted to the front
    # a deferred row is still found via the delta scan
    from repro.core import search
    r = search.ann_search(idx2, jnp.asarray(nv[99:100]), 1, n_probe=2)
    assert int(np.asarray(r.ids)[0, 0]) == 9099
    # draining the rest in quanta converges to an empty delta
    idx3, st2 = maintenance.flush_delta(idx2, max_rows=50)
    idx4, st3 = maintenance.flush_delta(idx3, max_rows=50)
    assert (st2.rows_moved, st3.rows_moved) == (50, 20)
    assert int(idx4.delta.valid.sum()) == 0
    # every row ended in the main tier
    r = search.ann_search(idx4, jnp.asarray(nv[:5]), 1, n_probe=idx4.k)
    assert list(np.asarray(r.ids)[:, 0]) == list(range(9000, 9005))


# -- monitor work queue ------------------------------------------------------


def _engine(tmp_path, name="m.db", n=1200, quantize="none", n_attr=0,
            delta_cap=128, target=40, budget=None, max_rows=4096):
    X = clustered_data(n=n, dim=16, seed=3)
    cfg = IVFConfig(dim=16, target_partition_size=target, kmeans_iters=15,
                    delta_capacity=delta_cap, quantize=quantize)
    eng = MicroNN(dim=16, n_attr=n_attr, path=str(tmp_path / name),
                  config=cfg, memory_budget_mb=budget,
                  max_rows_per_step=max_rows)
    attrs = np.ones((n, n_attr), np.float32) if n_attr else None
    eng.upsert(np.arange(n), X, attrs)
    eng.build()
    return eng, X


def test_work_queue_prioritizes_flush_then_split(tmp_path):
    eng, X = _engine(tmp_path, delta_cap=64)
    mon = eng.monitor
    assert mon.work_queue(eng.index) == [] or all(
        it.action in ("split", "merge", "recluster")
        for it in mon.work_queue(eng.index))
    # overfill one partition AND the delta: flush must outrank the split
    c0 = np.asarray(eng.index.centroids)[0]
    nv = (c0 + np.random.default_rng(0).normal(size=(50, 16)) * 0.3
          ).astype(np.float32)
    eng.upsert(np.arange(9000, 9050), nv)
    q = mon.work_queue(eng.index)
    assert q[0].action == "flush"
    eng.maintain(force="flush")
    q = mon.work_queue(eng.index)
    assert q[0].action == "split"
    big = int(np.asarray(eng.index.counts).argmax())
    assert q[0].pids == (big,)
    assert q[0].rows == int(np.asarray(eng.index.counts)[big])


def test_work_queue_emits_merge_for_underfull_siblings(tmp_path):
    eng, X = _engine(tmp_path)
    counts = np.asarray(eng.index.counts)
    victim = int(counts.argmax())
    vids = np.asarray(eng.index.ids)[victim][
        np.asarray(eng.index.valid)[victim]]
    eng.delete(vids[: len(vids) - 5])      # leave 5 rows: deep underfull
    items = [it for it in eng.monitor.work_queue(eng.index)
             if it.action == "merge"]
    assert any(victim in it.pids for it in items)
    it = next(it for it in items if victim in it.pids)
    assert it.pids[1] == victim            # merged INTO a sibling
    counts = np.asarray(eng.index.counts)
    bar = eng.monitor.cfg.split_threshold * eng.config.target_partition_size
    assert counts[it.pids[0]] + counts[it.pids[1]] <= bar


def test_work_queue_emits_recluster_on_drift(tmp_path):
    eng, X = _engine(tmp_path)
    k = eng.index.k
    drift = np.zeros((k,), np.float32)
    drift[3] = 1e6                          # absurd accumulated drift
    eng.index = dataclasses.replace(eng.index, drift=jnp.asarray(drift))
    items = eng.monitor.work_queue(eng.index)
    assert any(it.action == "recluster" and it.pids == (3,)
               for it in items)
    # executing the item resets the signal
    r = eng.maintain_step()
    assert r is not None and r.action == "recluster" and 3 in r.pids
    assert float(np.asarray(eng.index.drift)[3]) == 0.0
    assert not any(it.action == "recluster"
                   for it in eng.monitor.work_queue(eng.index))


def test_work_queue_emits_repack_for_tombstones(tmp_path):
    eng, X = _engine(tmp_path)
    p = int(np.asarray(eng.index.counts).argmax())
    vids = np.asarray(eng.index.ids)[p][np.asarray(eng.index.valid)[p]]
    # tombstone ~40% of the partition (stays above the merge bar)
    kill = vids[: int(len(vids) * 0.45)]
    eng.delete(kill)
    items = eng.monitor.work_queue(eng.index)
    assert any(it.action == "repack" and p in it.pids for it in items)
    ia, pa, _ = eng.store.all_rows()
    reports = eng.maintain(until_idle=True)
    if all(r.action == "repack" for r in reports):
        # a repack-only drain must leave the durable tier untouched
        ia2, pa2, _ = eng.store.all_rows()
        np.testing.assert_array_equal(ia, ia2)
        np.testing.assert_array_equal(pa, pa2)
    dead = ((np.asarray(eng.index.ids)[p] != -1)
            & ~np.asarray(eng.index.valid)[p]).sum()
    assert dead == 0                       # repack dropped the tombstones
    # ... at ZERO durable cost (the paged mode has no tombstones, so the
    # two modes' durable states must not diverge)
    repacks = [r for r in reports if r.action == "repack"]
    assert repacks and all(r.bytes_written == 0 for r in repacks)
    # survivors still searchable, packed ascending by id
    vids2 = np.asarray(eng.index.ids)[p][np.asarray(eng.index.valid)[p]]
    assert (np.diff(vids2) > 0).all()
    r = eng.search(X[vids2[0]][None], k=1)
    assert int(np.asarray(r.ids)[0, 0]) == vids2[0]


# -- scheduler: quantum contract + mixed-state queries -----------------------


def test_scheduler_respects_max_rows_per_step(tmp_path):
    eng, X = _engine(tmp_path, delta_cap=256, max_rows=64)
    rng = np.random.default_rng(7)
    c0 = np.asarray(eng.index.centroids)[0]
    nv = (c0 + rng.normal(size=(200, 16)) * 0.5).astype(np.float32)
    eng.upsert(np.arange(9000, 9200), nv)
    reports = eng.maintain(until_idle=True)
    assert reports, "churn produced no maintenance work"
    for r in reports:
        assert r.rows <= 64, (r, "quantum violated")
    # flushes were split into partial quanta
    flushes = [r for r in reports if r.action == "flush"]
    assert len(flushes) >= 3
    assert sum(r.rows for r in flushes) == 200


def test_queries_correct_between_steps_mixed_state(tmp_path):
    # the quantum must exceed the largest single partition for splits to
    # fit (the scheduler defers indivisible items larger than it); churn
    # spread across partitions keeps each one under ~120 rows
    eng, X = _engine(tmp_path, delta_cap=256, max_rows=120)
    rng = np.random.default_rng(11)
    nv = (X[rng.integers(0, len(X), 150)]
          + rng.normal(size=(150, 16)).astype(np.float32) * 0.2)
    new_ids = np.arange(9000, 9150)
    eng.upsert(new_ids, nv)
    dele = np.arange(0, 40)
    eng.delete(dele)
    live_vecs = {**{i: X[i] for i in range(40, len(X))},
                 **{9000 + j: nv[j] for j in range(150)}}
    steps = 0
    while True:
        # between every step: exact search must agree with brute force
        # over the true live set, on the mixed old/new partition state
        q = jnp.asarray(np.stack([nv[steps % 150], X[500]]))
        r = eng.search(np.asarray(q), k=3, exact=True)
        ids_all = np.asarray(sorted(live_vecs))
        vecs_all = np.stack([live_vecs[i] for i in ids_all])
        d = np.asarray(pairwise_scores(q, jnp.asarray(vecs_all), "l2"))
        gt = ids_all[np.argsort(d, axis=1)[:, :3]]
        np.testing.assert_array_equal(np.sort(np.asarray(r.ids), 1),
                                      np.sort(gt, 1))
        rep = eng.maintain_step()
        if rep is None:
            break
        assert rep.rows <= 120
        steps += 1
        assert steps < 200, "scheduler failed to converge"
    assert steps > 0
    # steady state: no oversized partition, nothing pending
    counts = np.asarray(eng.index.counts)
    assert counts.max() <= eng.monitor.cfg.split_threshold * 40
    assert eng.scheduler.pending() == []


def test_split_retires_growth_rebuild(tmp_path):
    """The steady-state claim: under growth that would trip the legacy
    rebuild trigger, the scheduler's splits keep the monitor's global
    growth signal below the rebuild bar -- full_rebuild never runs."""
    eng, X = _engine(tmp_path, delta_cap=512)
    rng = np.random.default_rng(13)
    next_id = 20000
    for _ in range(4):
        nv = (X[rng.integers(0, len(X), 300)]
              + rng.normal(size=(300, 16)).astype(np.float32) * 0.1)
        eng.upsert(np.arange(next_id, next_id + 300), nv)
        next_id += 300
        eng.maintain(until_idle=True)
    assert not any(s.kind == "full" for s in eng.maintenance_log)
    health = eng.monitor.check(eng.index)
    assert health.action != "rebuild"
    assert health.growth < eng.monitor.cfg.growth_rebuild_threshold


# -- resident vs paged parity ------------------------------------------------


@pytest.fixture(params=["none", "int8"])
def repair_pair(request, tmp_path):
    """(resident, paged) engines over identical durable copies, churned
    identically -- split/merge decisions and results must bit-match."""
    quant = request.param
    X = clustered_data(n=1500, dim=16, seed=8)
    cfg = IVFConfig(dim=16, target_partition_size=50, kmeans_iters=15,
                    delta_capacity=64, quantize=quant, rerank_factor=4)
    path = str(tmp_path / f"{quant}.db")
    eng = MicroNN(dim=16, n_attr=1, path=path, config=cfg)
    eng.upsert(np.arange(len(X)), X, np.ones((len(X), 1), np.float32))
    eng.build()
    eng.store.db.commit()
    eng.store.close()
    shutil.copy(path, path + ".res")
    shutil.copy(path, path + ".pag")
    res = MicroNN(dim=16, n_attr=1, path=path + ".res", config=cfg)
    res.recover()
    pag = MicroNN(dim=16, n_attr=1, path=path + ".pag", config=cfg,
                  memory_budget_mb=0.05)
    pag.recover()
    return res, pag, X


def test_split_merge_identical_resident_vs_paged(repair_pair):
    res, pag, X = repair_pair
    rng = np.random.default_rng(5)
    c0 = np.asarray(res.index.centroids)[0]
    for wave in range(3):
        nv = (c0 + rng.normal(size=(60, 16)) * 0.3).astype(np.float32)
        ids = np.arange(9000 + wave * 60, 9060 + wave * 60)
        dele = np.arange(wave * 100, wave * 100 + 60)
        for e in (res, pag):
            e.upsert(ids, nv, np.ones((60, 1), np.float32))
            e.delete(dele)
        r1 = res.maintain(until_idle=True)
        r2 = pag.maintain(until_idle=True)
        # repack steps are resident-only (device tombstones) and durably
        # no-ops; every durable-effect step must match exactly
        assert [(r.action, r.pids, r.rows) for r in r1
                if r.action != "repack"] == \
               [(r.action, r.pids, r.rows) for r in r2]
    assert any(r.kind in ("split", "merge") for r in res.maintenance_log)
    # identical durable state ...
    ia, pa, _ = res.store.all_rows()
    ib, pb, _ = pag.store.all_rows()
    np.testing.assert_array_equal(ia, ib)
    np.testing.assert_array_equal(pa, pb)
    np.testing.assert_array_equal(np.asarray(res.index.centroids),
                                  np.asarray(pag.index.centroids))
    np.testing.assert_array_equal(np.asarray(res.index.counts),
                                  np.asarray(pag.index.counts))
    # ... and bit-identical search results on both backends
    q = X[:16]
    for backend in ("xla", "pallas"):
        a = res.search(q, k=10, n_probe=8, backend=backend)
        b = pag.search(q, k=10, n_probe=8, backend=backend)
        np.testing.assert_array_equal(np.asarray(a.ids),
                                      np.asarray(b.ids))
        np.testing.assert_array_equal(np.asarray(a.scores),
                                      np.asarray(b.scores))


# -- crash safety ------------------------------------------------------------


@pytest.mark.parametrize("budget", [None, 0.05])
def test_crash_between_codes_and_swap_serves_old_generation(
        tmp_path, budget):
    """Kill the engine between the repair's code persist and its
    generation swap: recover() must serve the old generation
    bit-identically, and re-running maintenance must converge."""
    X = clustered_data(n=900, dim=16, seed=4)
    cfg = IVFConfig(dim=16, target_partition_size=40, kmeans_iters=10,
                    delta_capacity=64, quantize="int8")
    path = str(tmp_path / "crash.db")
    eng = MicroNN(dim=16, path=path, config=cfg,
                  memory_budget_mb=budget)
    eng.upsert(np.arange(len(X)), X)
    eng.build()
    c0 = np.asarray(eng.index.centroids)[0]
    nv = (c0 + np.random.default_rng(1).normal(size=(50, 16)) * 0.3
          ).astype(np.float32)
    eng.upsert(np.arange(9000, 9050), nv)
    eng.maintain(force="flush")
    assert eng.scheduler.pending(), "flush should have left split work"
    gen = eng.store.generation

    # checkpoint the WAL so the bare .db copy sees every committed page
    eng.store.db.execute("PRAGMA wal_checkpoint(TRUNCATE)")
    shutil.copy(path, path + ".pre")
    pre = MicroNN(dim=16, path=path + ".pre", config=cfg,
                  memory_budget_mb=budget)
    pre.recover()
    q = X[:8]
    r_pre = pre.search(q, k=10)

    # the kill: codes have persisted, the repair transaction never commits
    def power_loss(*a, **k):
        raise RuntimeError("power loss")
    eng.store.apply_repair = power_loss
    with pytest.raises(RuntimeError):
        eng.maintain_step()
    assert eng.store.generation == gen     # old clustering intact
    eng.store.db.commit()
    eng.store.close()

    eng2 = MicroNN(dim=16, path=path, config=cfg,
                   memory_budget_mb=budget)
    eng2.recover()
    r_post = eng2.search(q, k=10)
    np.testing.assert_array_equal(np.asarray(r_pre.ids),
                                  np.asarray(r_post.ids))
    np.testing.assert_array_equal(np.asarray(r_pre.scores),
                                  np.asarray(r_post.scores))
    # re-run maintenance: converges and clears the backlog
    eng2.maintain(until_idle=True)
    assert eng2.scheduler.pending() == []
    counts = np.asarray(eng2.index.counts)
    assert counts.max() <= eng2.monitor.cfg.split_threshold * 40
    r = eng2.search(nv[:4], k=1)
    assert list(np.asarray(r.ids)[:, 0]) == [9000, 9001, 9002, 9003]


def test_split_reuses_empty_slot_before_appending(tmp_path):
    eng, X = _engine(tmp_path)
    # empty a partition completely, then force a split elsewhere
    counts = np.asarray(eng.index.counts)
    victim = int(np.nonzero(counts > 0)[0][0])
    vids = np.asarray(eng.index.ids)[victim][
        np.asarray(eng.index.valid)[victim]]
    eng.delete(vids)
    assert int(np.asarray(eng.index.counts)[victim]) == 0
    c1 = np.asarray(eng.index.centroids)[
        int(np.asarray(eng.index.counts).argmax())]
    nv = (c1 + np.random.default_rng(2).normal(size=(60, 16)) * 0.3
          ).astype(np.float32)
    eng.upsert(np.arange(9000, 9060), nv)
    eng.maintain(force="flush")
    reports = eng.maintain(until_idle=True)
    splits = [r for r in reports if r.action == "split"]
    assert splits
    # the new half lands in the freed slot (plan.pids puts it last), so
    # the first split does not grow k
    assert splits[0].pids[-1] == victim
    assert int(np.asarray(eng.index.counts)[victim]) > 0


# -- bin-packing merge partners + durable maintenance signals (PR 6) ---------


def test_choose_merge_partner_best_fit_deterministic():
    """Best-fit bin packing: the partner minimizing post-merge slack wins
    even when a much closer centroid exists; ties break by distance then
    pid, so the plan is a pure function of (centroids, counts)."""
    cents = np.zeros((4, 2), np.float32)
    cents[0] = (0, 0)                     # victim, count 10
    cents[1] = (100, 0)                   # far but fullest: slack 10
    cents[2] = (1, 0)                     # nearest but small: slack 75
    cents[3] = (50, 0)                    # empty -- never a partner
    counts = np.array([10, 80, 15, 0])
    bar = 100.0
    assert maintenance.choose_merge_partner(cents, counts, 0, bar) == 1
    # exclusion (partner already claimed this cycle) falls back to the
    # next-best fit, not to None
    assert maintenance.choose_merge_partner(
        cents, counts, 0, bar, exclude=(1,)) == 2
    # equal slack -> centroid distance decides
    counts_tie = np.array([10, 15, 15, 0])
    assert maintenance.choose_merge_partner(
        cents, counts_tie, 0, bar) == 2
    # equal slack AND distance -> lowest pid (full determinism)
    cents_sym = cents.copy()
    cents_sym[1] = (1, 0)
    cents_sym[2] = (-1, 0)
    assert maintenance.choose_merge_partner(
        cents_sym, counts_tie, 0, bar) == 1
    # nothing fits under the split bar -> no merge at all
    assert maintenance.choose_merge_partner(
        cents, np.array([10, 95, 95, 0]), 0, bar) is None


def test_recover_restores_maintenance_signals(tmp_path):
    """PR 5 leftover: drift / base_mean_size now live in the SQLite meta
    table, so a restart resumes maintenance with the signals it had --
    the restored engine's work queue is identical, not amnesiac."""
    eng, X = _engine(tmp_path, name="persist.db", delta_cap=64)
    c0 = np.asarray(eng.index.centroids)[0]
    nv = (c0 + np.random.default_rng(1).normal(size=(50, 16)) * 0.5
          ).astype(np.float32)
    eng.upsert(np.arange(9100, 9150), nv)
    # flush through the scheduler quantum (the durable flush -- the
    # legacy force="flush" path is device-only and defers durability)
    r = eng.maintain_step()
    assert r is not None and r.action == "flush"
    assert int(np.asarray(eng.index.delta.valid).sum()) == 0
    drift0 = np.asarray(eng.index.drift).copy()
    base0 = float(np.asarray(eng.index.base_mean_size))
    assert drift0.max() > 0               # the flush accumulated drift
    eng.store.db.commit()

    eng2 = MicroNN(dim=16, path=str(tmp_path / "persist.db"),
                   config=eng.config)
    eng2.recover()
    np.testing.assert_allclose(np.asarray(eng2.index.drift), drift0,
                               rtol=1e-6)
    assert float(np.asarray(eng2.index.base_mean_size)) == \
        pytest.approx(base0)
    # restored signals drive the same maintenance decisions
    q1 = [(it.action, it.pids) for it in eng.monitor.work_queue(eng.index)]
    q2 = [(it.action, it.pids)
          for it in eng2.monitor.work_queue(eng2.index)]
    assert q1 == q2
    # and maintenance actually runs on the recovered engine
    eng2.maintain(until_idle=True)
    assert eng2.scheduler.pending() == []
