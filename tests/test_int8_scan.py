"""Integer-domain SQ scan (PR 6 tentpole): bitwise XLA-vs-Pallas parity,
recall non-regression against the dequantize-then-f32 scan it replaced,
the small-Q gather specialization, and the code_norms invariant.

Parity chain: the Pallas kernel accumulates int8 x int8 -> int32 on the
MXU; the XLA reference accumulates the cast integers in f32 at HIGHEST
precision -- every product and partial sum is an exact integer < 2^24
for d <= 1024, so the two accumulators hold IDENTICAL values. The f32
affine epilogue (alpha * acc, summed across the two terms) is written in
the same op order in both, but the compiler may fuse it into fma with
different rounding per program, so raw scan scores can differ by ~1 ulp.
The pinned contract is therefore: candidate SELECTION identical (ids
bitwise), scan scores equal to a couple of ulp, and the end-to-end
SearchResult bitwise identical across backends -- the exact-f32 rerank
rescores the identical candidate set with one shared jitted expression.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import executor, ivf, quantize
from repro.core.query import Q
from repro.core.types import IVFConfig
from repro.kernels import sq_scan


def _mk_index(n=1200, d=24, seed=0, metric="l2", **cfg_kw):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(10, d)).astype(np.float32) * 5
    X = (centers[rng.integers(0, 10, n)]
         + rng.normal(size=(n, d))).astype(np.float32)
    cfg = IVFConfig(dim=d, metric=metric, target_partition_size=64,
                    kmeans_iters=8, quantize="int8", rerank_factor=4,
                    **cfg_kw)
    return ivf.build_index(X, cfg=cfg), X


def _cand_recall(cand, ref, k):
    hits = 0
    for a, b in zip(cand, ref[:, :k]):
        real = set(int(x) for x in b if x >= 0)
        hits += len(set(int(x) for x in a if x >= 0) & real)
    return hits / max(1, ref.shape[0] * k)


# -- the two-term query fold --------------------------------------------------


def test_fold_queries_two_term_shapes_and_precision():
    rng = np.random.default_rng(1)
    d, q_n = 32, 6
    lo = rng.normal(size=d).astype(np.float32)
    scale = (rng.random(d).astype(np.float32) + 0.1) / 50
    stats = quantize.QuantStats(lo=jnp.asarray(lo), scale=jnp.asarray(scale))
    q = jnp.asarray(rng.normal(size=(q_n, d)).astype(np.float32))
    q_i8, alpha, beta = quantize.fold_queries(stats, q)
    assert q_i8.shape == (2 * q_n, d) and q_i8.dtype == jnp.int8
    assert alpha.shape == (2 * q_n,) and beta.shape == (q_n,)
    # reconstruct q.scale from the stacked two-term encoding: the
    # residual term must leave only ~2^-15 relative error
    w = np.asarray(q) * scale[None, :]
    rec = (np.asarray(alpha)[:q_n, None]
           * np.asarray(q_i8, np.float32)[:q_n]
           + np.asarray(alpha)[q_n:, None]
           * np.asarray(q_i8, np.float32)[q_n:])
    err = np.abs(rec - w).max()
    assert err <= 2.0 ** -14 * np.abs(w).max() + 1e-12


# -- bitwise XLA vs Pallas(interpret) parity ---------------------------------


@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("with_norms", [True, False])
def test_int8_scan_xla_matches_pallas_interpret_bitwise(metric, with_norms):
    idx, X = _mk_index(metric=metric)
    rng = np.random.default_rng(7)
    q = jnp.asarray(X[:5])
    plan = executor.plan_ann(idx, q, k=16, n_probe=4)
    norms = idx.code_norms if with_norms else None
    kprime = 48
    s_x, i_x = executor._xla_sq_scan(
        plan.queries, idx.codes, idx.qstats, idx.valid, idx.ids,
        plan.part_ids, kprime, metric=metric, qsel=plan.qsel, norms=norms)
    s_p, i_p = sq_scan.sq_scan_topk(
        plan.queries, idx.codes, idx.qstats.lo, idx.qstats.scale,
        idx.valid, idx.ids, plan.part_ids, kprime, metric=metric,
        qsel=plan.qsel, norms=norms, interpret=True)
    np.testing.assert_array_equal(np.asarray(i_x), np.asarray(i_p))
    # scores: identical integer accumulators, epilogue within fma noise
    np.testing.assert_allclose(np.asarray(s_x), np.asarray(s_p),
                               rtol=0, atol=1e-3)


def test_execute_plan_quantized_bitwise_across_backends():
    # the end-to-end pin: same plan through the Pallas(interpret) and XLA
    # backends must return bit-identical ids AND scores -- the quantized
    # path's exact-f32 rerank rescores the (identical) candidate set
    # through one shared jitted expression
    idx, X = _mk_index(n=1500, d=16)
    q = jnp.asarray(X[:6])
    plan = executor.plan_ann(idx, q, k=12, n_probe=4)
    r_x = executor.execute_plan(idx, plan, backend="xla", quantized=True)
    r_p = executor.execute_plan(idx, plan, backend="pallas", quantized=True)
    np.testing.assert_array_equal(np.asarray(r_x.ids), np.asarray(r_p.ids))
    assert np.array_equal(np.asarray(r_x.scores), np.asarray(r_p.scores))


def test_int8_scan_norms_fallback_bitwise_matches_precomputed():
    # the in-scan decode-and-reduce fallback (paged frames carry no
    # code_norms tier) must reproduce the precomputed tier exactly
    idx, X = _mk_index()
    q = jnp.asarray(X[:4])
    plan = executor.plan_ann(idx, q, k=8, n_probe=3)
    s_n, i_n = executor._xla_sq_scan(
        plan.queries, idx.codes, idx.qstats, idx.valid, idx.ids,
        plan.part_ids, 32, metric="l2", qsel=plan.qsel,
        norms=idx.code_norms)
    s_f, i_f = executor._xla_sq_scan(
        plan.queries, idx.codes, idx.qstats, idx.valid, idx.ids,
        plan.part_ids, 32, metric="l2", qsel=plan.qsel, norms=None)
    np.testing.assert_array_equal(np.asarray(i_n), np.asarray(i_f))
    assert np.array_equal(np.asarray(s_n), np.asarray(s_f))


# -- recall non-regression vs the dequantize-then-f32 scan -------------------


@pytest.mark.parametrize("rerank_factor", [1, 2, 4])
def test_int8_domain_candidate_recall_not_below_dequant(rerank_factor):
    idx, X = _mk_index(n=2000, d=32)
    k, n_probe = 20, 4
    q = jnp.asarray(X[:16])
    ref = np.asarray(executor.run(
        idx, q, Q.knn(k=k, n_probe=n_probe).quantized(False)).ids)
    plan = executor.plan_ann(idx, q, k=k, n_probe=n_probe)
    kprime = min(rerank_factor * k, int(idx.valid.sum()))
    _, i_i8 = executor._xla_sq_scan(
        plan.queries, idx.codes, idx.qstats, idx.valid, idx.ids,
        plan.part_ids, kprime, metric="l2", qsel=plan.qsel,
        norms=idx.code_norms)
    _, i_dq = executor._xla_sq_scan_dequant(
        plan.queries, idx.codes, idx.qstats, idx.valid, idx.ids,
        plan.part_ids, kprime, metric="l2", qsel=plan.qsel)
    rec_i8 = _cand_recall(np.asarray(i_i8), ref, k)
    rec_dq = _cand_recall(np.asarray(i_dq), ref, k)
    assert rec_i8 + 1e-12 >= rec_dq, \
        f"int8-domain recall {rec_i8:.3f} < dequant {rec_dq:.3f} " \
        f"at rerank_factor={rerank_factor}"


# -- small-Q gather specialization -------------------------------------------


@pytest.mark.parametrize("quantized", [False, True])
def test_small_q_gather_matches_shared_union(quantized):
    idx, X = _mk_index(n=1500, d=16)
    q = jnp.asarray(X[:4])
    k, n_probe = 12, 3
    r_g = executor.execute_plan(
        idx, executor.plan_ann_gather(idx, q, k, n_probe),
        quantized=quantized)
    r_u = executor.execute_plan(
        idx, executor.plan_ann(idx, q, k, n_probe), quantized=quantized)
    np.testing.assert_array_equal(np.asarray(r_g.ids), np.asarray(r_u.ids))
    np.testing.assert_allclose(np.asarray(r_g.scores),
                               np.asarray(r_u.scores), rtol=1e-5, atol=1e-5)


def test_small_q_bucket_shares_one_trace():
    # Q=5/7/8 all bucket to 8 <= SMALL_Q_GATHER_MAX: the gather selection
    # is static per (spec, bucket), so no retrace across the bucket
    idx, X = _mk_index(n=1000, d=16)
    spec = Q.knn(k=10, n_probe=3)
    executor.run(idx, jnp.asarray(X[:5]), spec)         # warm bucket 8
    t0 = executor.trace_count()
    r7 = executor.run(idx, jnp.asarray(X[:7]), spec)
    r8 = executor.run(idx, jnp.asarray(X[:8]), spec)
    assert executor.trace_count() == t0, \
        "same (spec, Q-bucket) must not retrace"
    assert np.asarray(r7.ids).shape[0] == 7
    assert np.asarray(r8.ids).shape[0] == 8


def test_run_routes_small_q_through_gather_same_results():
    # end-to-end: run() on a small batch (gather path) agrees with the
    # forced shared-union plan on ids
    idx, X = _mk_index(n=1500, d=16)
    q = jnp.asarray(X[:3])
    spec = Q.knn(k=10, n_probe=4)
    r = executor.run(idx, q, spec)
    plan = executor.plan_ann(idx, q, k=10, n_probe=4)
    r_u = executor.execute_plan(idx, plan)
    np.testing.assert_array_equal(np.asarray(r.ids), np.asarray(r_u.ids))


# -- code_norms invariant -----------------------------------------------------


def test_code_norms_tracks_codes_through_build_and_grow():
    idx, X = _mk_index()
    assert idx.code_norms is not None
    np.testing.assert_array_equal(
        np.asarray(idx.code_norms),
        np.asarray(quantize.row_norms(idx.qstats, idx.codes)))
    grown = ivf.grow_layout(idx, idx.vectors.shape[1] + 32)
    np.testing.assert_array_equal(
        np.asarray(grown.code_norms),
        np.asarray(quantize.row_norms(grown.qstats, grown.codes)))


def test_code_norms_tracks_codes_through_flush():
    from repro.core import delta, maintenance
    idx, X = _mk_index(n=800, d=16, delta_capacity=128)
    rng = np.random.default_rng(3)
    nv = jnp.asarray(rng.normal(size=(60, 16)).astype(np.float32))
    ids = jnp.arange(10_000, 10_060, dtype=jnp.int32)
    idx = delta.upsert(idx, nv, ids, jnp.zeros((60, 0)))
    idx, _ = maintenance.flush_delta(idx)
    np.testing.assert_array_equal(
        np.asarray(idx.code_norms),
        np.asarray(quantize.row_norms(idx.qstats, idx.codes)))
