"""Distributed search + sharded train step (8 fake CPU devices).

These run in a subprocess so the 8-device XLA flag never leaks into the
main pytest process (smoke tests must see 1 device).
"""
import json
import subprocess
import sys

import pytest

SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
import dataclasses
from repro.core import ivf, search
from repro.core.types import IVFConfig
from repro.distributed.sharded_index import distributed_search, index_shardings

if jax.device_count() < 8:   # XLA flag ignored (e.g. real accelerator host)
    print("RESULT SKIP single-device host")
    raise SystemExit(0)
out = {}
rng = np.random.default_rng(0)
centers = rng.normal(size=(16, 32)) * 5
X = (centers[rng.integers(0, 16, 2048)] + rng.normal(size=(2048, 32))).astype(np.float32)
cfg = IVFConfig(dim=32, target_partition_size=64, kmeans_iters=40, delta_capacity=128)
idx = ivf.build_index(X, cfg=cfg)
try:    # jax >= 0.5 wants explicit axis types; 0.4.x has neither kwarg
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
except (AttributeError, TypeError):
    mesh = jax.make_mesh((2, 4), ("data", "model"))
Q = jnp.asarray(X[:8] + 0.05 * rng.normal(size=(8, 32)).astype(np.float32))
ref = search.ann_search(idx, Q, 10, n_probe=6)
for merge in ("tournament", "allgather"):
    res = distributed_search(idx, Q, 10, 6, mesh, merge=merge)
    out[f"match_{merge}"] = float(
        (np.asarray(res.ids) == np.asarray(ref.ids)).mean())

# index shardings place partitions over model
sh = index_shardings(idx, mesh)
out["vec_spec"] = str(sh.vectors.spec)

# sharded tiny train step lowers + runs on the 8-device mesh
from repro.configs import get_arch
from repro.configs.smoke import smoke_config
from repro.configs.base import ShapeConfig
from repro.launch import steps
arch = get_arch("llama3-8b")
arch = dataclasses.replace(arch, config=smoke_config(arch.config))
shape = ShapeConfig("t", "train", 32, 8)
lw = steps.train_lowerable(arch, shape, mesh, scan=False)
lowered = steps.lower(lw, mesh)
compiled = lowered.compile()
ca = compiled.cost_analysis()
if isinstance(ca, (list, tuple)):   # jax 0.4.x returns [dict]
    ca = ca[0]
out["train_flops"] = ca["flops"]

# run it with real (randomly initialised) values
from repro.models import init_model
from repro.train import optim as optim_lib
from repro.configs.inputs import batch_specs, materialize
params, _ = init_model(arch.config, jax.random.PRNGKey(0))
opt = optim_lib.init(params)
batch = materialize(batch_specs(arch.config, shape))
p2, o2, metrics = jax.jit(lw.fn)(params, opt, batch)
out["loss"] = float(metrics["loss"])
print("RESULT " + json.dumps(out))
'''


@pytest.fixture(scope="module")
def dist_result():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=520, env={**__import__("os").environ,
                          "PYTHONPATH": "src"})
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, proc.stdout
    payload = line[-1][len("RESULT "):]
    if payload.startswith("SKIP"):
        pytest.skip(payload)
    return json.loads(payload)


def test_distributed_matches_single_device(dist_result):
    assert dist_result["match_tournament"] == 1.0
    assert dist_result["match_allgather"] == 1.0


def test_partitions_sharded_over_model(dist_result):
    assert "model" in dist_result["vec_spec"]


def test_sharded_train_step_runs(dist_result):
    assert dist_result["train_flops"] > 0
    import math
    assert math.isfinite(dist_result["loss"])
