"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("dim", [16, 64, 128])
@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_scan_topk_sweep(dim, metric):
    rng = np.random.default_rng(dim)
    k, p_max, Q, n, K = 10, 24, 5, 4, 8
    vectors = jnp.asarray(rng.normal(size=(k, p_max, dim)).astype(np.float32))
    valid = jnp.asarray(rng.random((k, p_max)) > 0.25)
    ids = jnp.arange(k * p_max, dtype=jnp.int32).reshape(k, p_max)
    queries = jnp.asarray(rng.normal(size=(Q, dim)).astype(np.float32))
    part_ids = jnp.asarray(rng.choice(k, n, replace=False).astype(np.int32))
    s_k, i_k = ops.scan_topk(queries, vectors, valid, ids, part_ids, K,
                             metric=metric)
    s_r, i_r = ref.ivf_scan_ref(queries, vectors, valid, ids, part_ids, K,
                                metric=metric)
    assert (np.asarray(i_k) == np.asarray(i_r)).all()
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_scan_topk_dtypes(dtype):
    rng = np.random.default_rng(3)
    k, p_max, dim, Q, n, K = 6, 16, 32, 3, 3, 5
    vectors = jnp.asarray(rng.normal(size=(k, p_max, dim))).astype(dtype)
    valid = jnp.ones((k, p_max), bool)
    ids = jnp.arange(k * p_max, dtype=jnp.int32).reshape(k, p_max)
    queries = jnp.asarray(rng.normal(size=(Q, dim))).astype(dtype)
    part_ids = jnp.arange(n, dtype=jnp.int32)
    s_k, i_k = ops.scan_topk(queries, vectors, valid, ids, part_ids, K)
    s_r, i_r = ref.ivf_scan_ref(queries.astype(jnp.float32),
                                vectors.astype(jnp.float32), valid, ids,
                                part_ids, K)
    # bf16 rounding can swap near-ties; compare sets + scores loosely
    tol = 1e-4 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=tol, atol=tol)


def test_scan_topk_mqo_mask():
    rng = np.random.default_rng(5)
    k, p_max, dim, Q, n, K = 8, 16, 32, 6, 5, 6
    vectors = jnp.asarray(rng.normal(size=(k, p_max, dim)).astype(np.float32))
    valid = jnp.asarray(rng.random((k, p_max)) > 0.1)
    ids = jnp.arange(k * p_max, dtype=jnp.int32).reshape(k, p_max)
    queries = jnp.asarray(rng.normal(size=(Q, dim)).astype(np.float32))
    part_ids = jnp.asarray(rng.choice(k, n, replace=False).astype(np.int32))
    qsel = jnp.asarray(rng.random((Q, n)) > 0.4)
    s_k, i_k = ops.scan_topk_mqo(queries, vectors, valid, ids, part_ids,
                                 qsel, K)
    s_r, i_r = ref.ivf_scan_ref(queries, vectors, valid, ids, part_ids, K,
                                qsel=qsel)
    assert (np.asarray(i_k) == np.asarray(i_r)).all()


@pytest.mark.parametrize("k_cent,tile", [(100, 32), (256, 128), (300, 256)])
def test_kmeans_assign_sweep(k_cent, tile):
    rng = np.random.default_rng(k_cent)
    s, d = 48, 24
    batch = jnp.asarray(rng.normal(size=(s, d)).astype(np.float32))
    cents = jnp.asarray(rng.normal(size=(k_cent, d)).astype(np.float32))
    counts = jnp.asarray(rng.integers(0, 300, k_cent).astype(np.float32))
    a_k, d_k = ops.assign_nearest(batch, cents, counts, balance_weight=1.5,
                                  target_size=100, scale=4.0, tile_k=tile)
    a_r, d_r = ref.kmeans_assign_ref(batch, cents, counts, 1.5, 100, 4.0)
    assert (np.asarray(a_k) == np.asarray(a_r)).all()
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r),
                               rtol=1e-3, atol=1e-3)


def test_kernel_topk_handles_all_masked():
    """Partitions with zero valid rows must yield INVALID_ID fills."""
    k, p_max, dim, Q = 4, 8, 16, 2
    vectors = jnp.zeros((k, p_max, dim))
    valid = jnp.zeros((k, p_max), bool)
    ids = jnp.arange(k * p_max, dtype=jnp.int32).reshape(k, p_max)
    queries = jnp.ones((Q, dim))
    s, i = ops.scan_topk(queries, vectors, valid, ids,
                         jnp.arange(2, dtype=jnp.int32), 5)
    assert (np.asarray(i) == -1).all()
