"""Partition pager: buffer-pool semantics (clock eviction, pin-on-scan,
byte budget), batched store reads, cache invalidation through the paged
engine's write path, and paged-vs-resident search parity.

Parity contract (the PR's acceptance pin): with any memory budget, a
paged engine recovered from the same durable state as a resident engine
returns BIT-IDENTICAL SearchResults (ids and scores) on both backends --
the frame pool + disk-gather rerank only changes where bytes live, never
what the search computes.
"""
import dataclasses
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.types import IVFConfig, effective_pad_to
from repro.storage import MicroNN, VectorStore
from repro.storage.pager import PartitionCache
from tests.conftest import clustered_data


def _mk_store(tmp_path, name="p.db", n=200, d=8, k=10, n_attr=0, seed=0):
    """A store with a hand-made clustering: n rows over k partitions."""
    rng = np.random.default_rng(seed)
    st = VectorStore(str(tmp_path / name), dim=d, n_attr=n_attr)
    X = rng.normal(size=(n, d)).astype(np.float32)
    attrs = rng.integers(0, 4, (n, n_attr)).astype(np.float32) \
        if n_attr else None
    st.upsert(list(range(n)), X, attrs)
    assign = rng.integers(0, k, n)
    st.set_partitions(np.arange(n), assign,
                      rng.normal(size=(k, d)).astype(np.float32),
                      np.zeros(k))
    return st, X, assign


# -- batched store reads (satellite: no per-row round-trips) -----------------


def test_scan_partitions_matches_per_pid_scan(tmp_path):
    st, X, assign = _mk_store(tmp_path, n_attr=2)
    p_max = int(np.bincount(assign).max())
    pids = [3, 0, 7]
    blocks = st.scan_partitions(pids, p_max, with_attrs=True)
    for j, pid in enumerate(pids):
        ids, vecs = st.scan_partition(pid)
        m = len(ids)
        assert blocks.valid[j].sum() == m
        np.testing.assert_array_equal(blocks.ids[j, :m], ids)
        np.testing.assert_array_equal(blocks.vecs[j, :m], vecs)
        assert (blocks.ids[j, m:] == -1).all()
        np.testing.assert_array_equal(blocks.attrs[j, :m],
                                      st.attributes_for(ids))


def test_scan_partitions_codes_ride_along(tmp_path):
    st, X, assign = _mk_store(tmp_path)
    codes = np.clip(X * 10, -128, 127).astype(np.int8)
    # leave one asset without a durable code
    st.set_code_tier(np.arange(1, len(X)), codes[1:],
                     np.zeros(8, np.float32), np.ones(8, np.float32))
    p_max = int(np.bincount(assign).max())
    blocks = st.scan_partitions([int(assign[0])], p_max, with_codes=True)
    row = np.nonzero(blocks.ids[0] == 0)[0][0]
    assert not blocks.code_ok[0, row]           # missing code flagged
    other = np.nonzero(blocks.valid[0] & blocks.code_ok[0])[0]
    for r in other:
        np.testing.assert_array_equal(blocks.codes[0, r],
                                      codes[blocks.ids[0, r]])
    with pytest.raises(AssertionError):
        st.scan_partitions([1, 1], p_max)       # duplicate pids rejected


def test_attributes_for_batched_with_duplicates(tmp_path):
    st, _, _ = _mk_store(tmp_path, n_attr=2)
    want = np.array([5, 3, 5, 9999])            # dup + missing id
    got = st.attributes_for(want)
    np.testing.assert_array_equal(got[0], got[2])
    np.testing.assert_array_equal(got[3], np.zeros(2))
    single = np.concatenate([st.attributes_for(np.array([int(a)]))
                             for a in want[:3]])
    np.testing.assert_array_equal(got[:3], single.reshape(3, 2))


def test_vectors_for_batched_gather(tmp_path):
    st, X, _ = _mk_store(tmp_path)
    want = [7, 3, 12345, 7]
    out, found = st.vectors_for(want)
    np.testing.assert_array_equal(found, [True, True, False, True])
    np.testing.assert_array_equal(out[0], X[7])
    np.testing.assert_array_equal(out[1], X[3])
    np.testing.assert_array_equal(out[3], X[7])


# -- buffer pool: budget, clock eviction, pins -------------------------------


def _mk_cache(st, assign, n_frames, **kw):
    p_max = int(np.bincount(assign).max())
    fb = PartitionCache.compute_frame_bytes(p_max, st.dim)
    return PartitionCache(st, p_max=p_max, budget_bytes=n_frames * fb, **kw)


def test_budget_too_small_for_one_frame_raises(tmp_path):
    st, _, assign = _mk_store(tmp_path)
    with pytest.raises(ValueError):
        PartitionCache(st, p_max=int(np.bincount(assign).max()),
                       budget_bytes=8)


def test_hit_miss_counters_and_frame_content(tmp_path):
    st, X, assign = _mk_store(tmp_path)
    cache = _mk_cache(st, assign, 4)
    f = cache.fault([2, 5])
    cache.unpin(f)
    assert (cache.hits, cache.misses) == (0, 2)
    f2 = cache.fault([5, 2, 6])
    cache.unpin(f2)
    assert (cache.hits, cache.misses) == (2, 3)
    # frame content matches a direct partition scan
    ids, vecs = st.scan_partition(5)
    j = int(f2[0])
    m = len(ids)
    np.testing.assert_array_equal(np.asarray(cache.ids_pool)[j, :m], ids)
    np.testing.assert_array_equal(np.asarray(cache.payload_pool)[j, :m], vecs)
    assert not np.asarray(cache.valid_pool)[j, m:].any()


def test_clock_eviction_order_second_chance(tmp_path):
    st, _, assign = _mk_store(tmp_path)
    cache = _mk_cache(st, assign, 3)
    cache.unpin(cache.fault([0, 1, 2]))     # fill: frames 0,1,2 all ref'd
    # cold fault: the sweep clears every ref bit, wraps, and reclaims the
    # first frame past the hand -- pid 0 (FIFO when everything is warm)
    cache.unpin(cache.fault([3]))
    assert cache.evictions == 1
    assert set(cache._pid_frame) == {1, 2, 3}
    cache.unpin(cache.fault([1]))           # re-reference pid 1 ...
    cache.unpin(cache.fault([4]))
    resident = set(cache._pid_frame)
    # ... so its ref bit buys it a second chance: the cold pid 2 goes
    assert 1 in resident and 4 in resident and 2 not in resident
    assert cache.evictions == 2


def test_pin_semantics_block_eviction(tmp_path):
    st, _, assign = _mk_store(tmp_path)
    cache = _mk_cache(st, assign, 2)
    pinned = cache.fault([3, 4])                # both frames pinned
    with pytest.raises(RuntimeError):
        cache.fault([5])                        # no victim available
    with pytest.raises(ValueError):
        cache.fault([1, 2, 3])                  # probe set > pool
    cache.unpin(pinned[:1])
    f = cache.fault([5])                        # now a victim exists
    cache.unpin(f)
    assert 5 in cache._pid_frame
    cache.unpin(pinned[1:])


def test_budget_never_exceeded_randomized_workload(tmp_path):
    st, _, assign = _mk_store(tmp_path, n=400, k=20, seed=3)
    cache = _mk_cache(st, assign, 3)
    budget = cache.budget_bytes
    rng = np.random.default_rng(0)
    for _ in range(50):
        pids = rng.choice(20, size=rng.integers(1, 4), replace=False)
        f = cache.fault(list(pids))
        assert cache.resident_bytes <= budget
        cache.unpin(f)
    assert cache.resident_bytes <= budget
    assert cache.evictions > 0 and cache.hits > 0
    s = cache.stats()
    assert s["resident_bytes"] == cache.resident_bytes
    assert s["capacity_frames"] == 3


def test_fault_failure_rolls_back_registrations(tmp_path, monkeypatch):
    """A failed fetch (e.g. a transient 'database is locked') must leave
    no pinned frames and no pid -> frame mappings for data that never
    arrived -- otherwise the next fault counts zero-filled frames as
    hits and pins starve the pool."""
    st, _, assign = _mk_store(tmp_path)
    cache = _mk_cache(st, assign, 4)
    cache.unpin(cache.fault([0]))

    def boom(*a, **k):
        raise RuntimeError("database is locked")
    monkeypatch.setattr(st, "scan_partitions", boom)
    with pytest.raises(RuntimeError):
        cache.fault([0, 1])         # hit(0) + miss(1): the fetch fails
    assert (cache._pins == 0).all()             # no leaked pins
    assert 1 not in cache._pid_frame            # no phantom mapping
    assert 0 in cache._pid_frame                # the real frame survives
    monkeypatch.undo()
    f = cache.fault([0, 1])                     # pool fully usable again
    ids, _ = st.scan_partition(1)
    assert np.asarray(cache.valid_pool)[f[1]].sum() == len(ids)
    cache.unpin(f)


def test_resize_failure_keeps_old_geometry(tmp_path):
    st, _, assign = _mk_store(tmp_path)
    cache = _mk_cache(st, assign, 2)
    p_max, fb, cap = cache.p_max, cache.frame_bytes, cache.capacity
    with pytest.raises(ValueError):
        cache.resize(p_max * 1000)              # budget cannot seat it
    # validation happens before mutation: old geometry fully intact
    assert (cache.p_max, cache.frame_bytes, cache.capacity) == \
        (p_max, fb, cap)
    cache.unpin(cache.fault([0]))               # still serviceable


def test_invalidate_forces_refetch(tmp_path):
    st, X, assign = _mk_store(tmp_path)
    cache = _mk_cache(st, assign, 4)
    cache.unpin(cache.fault([1]))
    # overwrite a row durably, then invalidate: next fault sees new bytes
    victim = int(np.nonzero(assign == 1)[0][0])
    newv = np.full((1, 8), 42.0, np.float32)
    st.upsert([victim], newv, partition_id=1)
    cache.invalidate([1])
    f = cache.fault([1])
    j = int(f[0])
    ids = np.asarray(cache.ids_pool)[j]
    row = np.nonzero(ids == victim)[0][0]
    np.testing.assert_array_equal(np.asarray(cache.payload_pool)[j, row],
                                  newv[0])
    cache.unpin(f)
    assert cache.misses == 2                    # invalidation -> refetch


# -- paged engine: parity + invalidation through the write path --------------


@pytest.fixture(scope="module", params=["none", "int8"])
def paged_pair(request, tmp_path_factory):
    """(resident, paged) engines recovered from the same durable state."""
    quant = request.param
    X = clustered_data(n=1500, dim=16, seed=8)
    path = str(tmp_path_factory.mktemp("pager") / f"{quant}.db")
    cfg = IVFConfig(dim=16, target_partition_size=50, kmeans_iters=15,
                    delta_capacity=64, quantize=quant, rerank_factor=4)
    eng = MicroNN(dim=16, n_attr=1, path=path, config=cfg)
    eng.upsert(np.arange(len(X)), X, np.ones((len(X), 1), np.float32))
    eng.build()
    eng.store.db.commit()
    res = MicroNN(dim=16, n_attr=1, path=path, config=cfg)
    res.recover()
    pag = MicroNN(dim=16, n_attr=1, path=path, config=cfg,
                  memory_budget_mb=0.05)
    pag.recover()
    return res, pag, X


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_paged_matches_resident_bitwise(paged_pair, backend):
    res, pag, X = paged_pair
    # the budget forces paging: the pool holds only a fraction of the tier
    assert pag.index.cache.capacity < pag.index.k
    q = X[:16]
    r1 = res.search(q, k=10, n_probe=8, backend=backend)
    r2 = pag.search(q, k=10, n_probe=8, backend=backend)
    np.testing.assert_array_equal(np.asarray(r1.ids), np.asarray(r2.ids))
    np.testing.assert_array_equal(np.asarray(r1.scores),
                                  np.asarray(r2.scores))


def test_paged_exact_streams_whole_collection(paged_pair):
    res, pag, X = paged_pair
    q = X[:4]
    r2 = pag.search(q, k=10, exact=True)
    if pag.index.quantized:
        # int8 pool: full-probe SQ scan + rerank is a near-oracle
        r1 = res.search(q, k=10, exact=True)
        hits = sum(len(set(a) & set(b)) for a, b in
                   zip(np.asarray(r1.ids), np.asarray(r2.ids)))
        assert hits / r2.ids.size >= 0.95
    else:
        r1 = res.search(q, k=10, exact=True)
        np.testing.assert_array_equal(np.asarray(r1.ids), np.asarray(r2.ids))
        np.testing.assert_array_equal(np.asarray(r1.scores),
                                      np.asarray(r2.scores))


def test_paged_budget_held_and_stats_surface(paged_pair):
    _, pag, X = paged_pair
    budget = int(0.05 * 2 ** 20)
    for i in range(6):
        pag.search(X[i * 8:(i + 1) * 8], k=10, n_probe=8)
        assert pag.index.cache.resident_bytes <= budget
    s = pag.stats()
    assert s["paged"] and s["misses"] > 0 and s["evictions"] > 0
    assert s["resident_bytes"] <= budget == s["budget_bytes"]


def test_paged_flush_invalidates_and_stays_consistent(tmp_path):
    X = clustered_data(n=800, dim=16, seed=9)
    cfg = IVFConfig(dim=16, target_partition_size=40, kmeans_iters=10,
                    delta_capacity=32, quantize="int8")
    eng = MicroNN(dim=16, path=str(tmp_path / "f.db"), config=cfg,
                  memory_budget_mb=0.05)
    eng.upsert(np.arange(800), X)
    eng.build()
    nv = np.random.default_rng(3).normal(size=(8, 16)).astype(np.float32)
    eng.upsert(np.arange(9000, 9008), nv)
    eng.search(nv, k=1)                     # warm the touched partitions
    misses0 = eng.index.cache.misses
    assert eng.maintain(force="flush") == "flush"
    assert int(eng.index.delta.valid.sum()) == 0
    r = eng.search(nv[:4], k=1)             # now served from main frames
    assert list(np.asarray(r.ids)[:, 0]) == [9000, 9001, 9002, 9003]
    assert eng.index.cache.misses > misses0     # frames were invalidated
    # durable move happened: rows left the delta partition
    pids, _ = eng.store.scan_partition(-1)
    assert len(pids) == 0


def test_paged_rebuild_invalidates_everything(tmp_path):
    X = clustered_data(n=600, dim=16, seed=10)
    cfg = IVFConfig(dim=16, target_partition_size=40, kmeans_iters=10,
                    quantize="int8")
    eng = MicroNN(dim=16, path=str(tmp_path / "r.db"), config=cfg,
                  memory_budget_mb=0.05)
    eng.upsert(np.arange(600), X)
    eng.build()
    eng.search(X[:8], k=5)
    counters = (eng.index.cache.hits, eng.index.cache.misses)
    assert eng.maintain(force="rebuild") == "rebuild"
    assert len(eng.index.cache._pid_frame) == 0     # cold pool
    # counters are cumulative across the rebuild
    assert (eng.index.cache.hits, eng.index.cache.misses) == counters
    r = eng.search(X[:4], k=1)
    assert list(np.asarray(r.ids)[:, 0]) == [0, 1, 2, 3]


def test_paged_upsert_delete_invalidate_old_partitions(tmp_path):
    X = clustered_data(n=500, dim=16, seed=12)
    cfg = IVFConfig(dim=16, target_partition_size=40, kmeans_iters=10)
    eng = MicroNN(dim=16, path=str(tmp_path / "u.db"), config=cfg,
                  memory_budget_mb=0.1)
    eng.upsert(np.arange(500), X)
    eng.build()
    counts0 = int(eng.index.counts.sum())
    r = eng.search(X[:1], k=1)
    assert int(np.asarray(r.ids)[0, 0]) == 0
    # move row 0 far away: the old main-tier copy must stop matching
    eng.upsert(np.asarray([0]), np.full((1, 16), 50.0, np.float32))
    r = eng.search(X[:1], k=1)
    assert int(np.asarray(r.ids)[0, 0]) != 0
    assert int(eng.index.counts.sum()) == counts0 - 1
    r = eng.search(np.full((1, 16), 50.0, np.float32), k=1)
    assert int(np.asarray(r.ids)[0, 0]) == 0        # delta copy wins
    eng.delete(np.asarray([1]))
    r = eng.search(X[1:2], k=5)
    assert 1 not in np.asarray(r.ids)[0]
    assert int(eng.index.counts.sum()) == counts0 - 2


def test_paged_predicate_on_cold_cache(tmp_path):
    """Regression: the frame pools are rebound by fault()'s functional
    scatter, so the scan must read them AFTER faulting -- a pre-fault
    reference scans stale (zero/evicted) attr frames and silently
    mis-filters. A predicate query against a completely cold cache is the
    sharpest probe: every frame is faulted inside the search itself."""
    from repro.core.hybrid import Pred
    rng = np.random.default_rng(4)
    n, d = 2000, 16
    X = (rng.normal(size=(n, d)) * 3).astype(np.float32)
    attrs = rng.integers(0, 4, (n, 1)).astype(np.float32)
    cfg = IVFConfig(dim=16, target_partition_size=50, kmeans_iters=10,
                    quantize="int8")
    eng = MicroNN(dim=16, n_attr=1, path=str(tmp_path / "pred.db"),
                  config=cfg, memory_budget_mb=0.05)
    eng.upsert(np.arange(n), X, attrs)
    eng.build()
    r = eng.search(X[:8], k=10, predicate=Pred(0, "eq", 3.0))
    ids = np.asarray(r.ids)
    real = ids[(ids >= 0) & (ids < n)]
    assert len(real) > 0, "cold-cache predicate search returned nothing"
    assert (attrs[real, 0] == 3.0).all()
    # and on a warm cache with churn (frames replaced mid-search)
    r2 = eng.search(X[8:16], k=10, predicate=Pred(0, "eq", 1.0))
    ids2 = np.asarray(r2.ids)
    real2 = ids2[(ids2 >= 0) & (ids2 < n)]
    assert len(real2) > 0 and (attrs[real2, 0] == 1.0).all()


# -- admission policy: scan-resistant faults (satellite) ---------------------


def test_scan_resistant_fault_preserves_hot_set(tmp_path):
    """A full-collection stream faulted with admit=False must cycle
    through the scan ring and leave every admitted (hot) frame resident."""
    st, _, assign = _mk_store(tmp_path)
    cache = _mk_cache(st, assign, 8)
    assert cache.scan_frames == 2
    hot = [0, 1, 2, 3, 4, 5]
    cache.unpin(cache.fault(hot))               # admitted working set
    for s in range(0, 10, cache.scan_frames):   # one-off full scan
        pids = list(range(s, min(s + cache.scan_frames, 10)))
        cache.unpin(cache.fault(pids, admit=False))
    h0, m0 = cache.hits, cache.misses
    cache.unpin(cache.fault(hot))               # hot set still resident
    assert (cache.hits, cache.misses) == (h0 + len(hot), m0)
    # the stream dirtied at most the ring, never the admitted frames
    assert cache._transient.sum() <= cache.scan_frames


def test_scan_ring_promotion_on_admitted_hit(tmp_path):
    st, _, assign = _mk_store(tmp_path)
    cache = _mk_cache(st, assign, 6)
    f = cache.fault([7], admit=False)           # lands in the scan ring
    cache.unpin(f)
    fr = int(f[0])
    assert cache._transient[fr] and fr in cache._ring
    f2 = cache.fault([7])                       # admitted hit -> promote
    cache.unpin(f2)
    assert int(f2[0]) == fr
    assert not cache._transient[fr] and fr not in cache._ring


def test_admitted_fault_reclaims_ring_first(tmp_path):
    st, _, assign = _mk_store(tmp_path)
    cache = _mk_cache(st, assign, 8)
    cache.unpin(cache.fault([0, 1, 2, 3]))      # hot admitted frames
    ring = cache.fault([8], admit=False)        # one transient frame
    cache.unpin(ring)
    f_new = cache.fault([9])                    # admitted miss
    cache.unpin(f_new)
    # the transient frame is the preferred victim -- hot frames intact
    assert int(f_new[0]) == int(ring[0])
    for p in (0, 1, 2, 3):
        assert p in cache._pid_frame


def test_paged_exact_stream_keeps_hot_frames(tmp_path):
    """Engine-level: a one-off exact scan through a paged engine must not
    evict the ANN working set (ROADMAP open item)."""
    X = clustered_data(n=1500, dim=16, seed=15)
    cfg = IVFConfig(dim=16, target_partition_size=50, kmeans_iters=10)
    eng = MicroNN(dim=16, path=str(tmp_path / "adm.db"), config=cfg,
                  memory_budget_mb=0.08)
    eng.upsert(np.arange(len(X)), X)
    eng.build()
    cache = eng.index.cache
    assert cache.capacity < eng.index.k     # pool can't seat everything
    for i in range(4):                      # warm an ANN working set
        eng.search(X[i * 8:(i + 1) * 8], k=10, n_probe=4)
    hot = {p for p, f in cache._pid_frame.items() if not cache._transient[f]}
    assert hot
    r_exact = eng.search(X[:4], k=10, exact=True)   # one-off full stream
    # the stream may displace at most the scan ring's worth of frames
    # (ring bootstrap when the pool is fully hot), never the whole pool
    survivors = hot & set(cache._pid_frame)
    evicted = len(hot) - len(survivors)
    assert evicted <= cache.scan_frames, \
        f"exact scan evicted {evicted} hot frames " \
        f"(> scan ring {cache.scan_frames})"
    # and the stream still computed the true oracle
    res = MicroNN(dim=16, path=str(tmp_path / "adm.db"), config=cfg)
    res.recover()
    r_res = res.search(X[:4], k=10, exact=True)
    np.testing.assert_array_equal(np.asarray(r_exact.ids),
                                  np.asarray(r_res.ids))


# -- thread safety + deferred invalidation (PR 5 satellites) -----------------


def test_invalidate_pinned_frame_defers_release(tmp_path):
    """Invalidating a partition whose frame a scan still pins must not
    blow up (the scheduler and queries may interleave): the mapping drops
    immediately, the frame is freed at the last unpin."""
    st, _, assign = _mk_store(tmp_path)
    cache = _mk_cache(st, assign, 4)
    f = cache.fault([2])                  # pinned by an in-flight scan
    cache.invalidate([2])                 # scheduler moves partition 2
    assert 2 not in cache._pid_frame      # next fault refetches
    assert cache._stale[int(f[0])]
    f2 = cache.fault([2])                 # concurrent refetch: new frame
    assert int(f2[0]) != int(f[0])
    cache.unpin(f)                        # scan ends -> deferred release
    assert not cache._stale[int(f[0])]
    assert cache._frame_pid[int(f[0])] == -1
    cache.unpin(f2)


def test_partition_cache_thread_safe_interleaving(tmp_path):
    """Satellite: RLock around fault/evict/invalidate -- hammer the cache
    from several threads (as the background scheduler + query threads
    would) and assert counters/pins/mappings stay consistent."""
    import threading
    st, _, assign = _mk_store(tmp_path, n=400, k=20, seed=3)
    # pool must seat every thread's worst-case pinned set at once
    # (3 threads x 3 pins); capacity bounds pins, not thread safety
    cache = _mk_cache(st, assign, 12)
    errors = []

    def worker(seed):
        rng = np.random.default_rng(seed)
        try:
            for i in range(40):
                pids = rng.choice(20, size=int(rng.integers(1, 4)),
                                  replace=False)
                f = cache.fault(list(pids))
                np.asarray(cache.payload_pool)   # a "scan"
                cache.unpin(f)
                if i % 7 == 0:
                    cache.invalidate([int(rng.integers(0, 20))])
        except Exception as e:               # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(s,)) for s in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert (cache._pins == 0).all()
    assert cache.resident_bytes <= cache.budget_bytes
    # frame table and pid map are exact inverses
    for p, f in cache._pid_frame.items():
        assert cache._frame_pid[f] == p
    assert (cache.hits + cache.misses) >= 3 * 40


def test_fault_scatter_donates_pool_no_extra_allocation(tmp_path):
    """Satellite: the batched fault scatters through a donated jit -- the
    old pool buffers are consumed (updated in place), and the compiled
    scatter aliases its outputs to its inputs instead of allocating a
    second pool-sized buffer."""
    import jax
    from repro.storage import pager as pager_mod
    st, _, assign = _mk_store(tmp_path)
    cache = _mk_cache(st, assign, 4)
    old_payload, old_ids = cache.payload_pool, cache.ids_pool
    cache.unpin(cache.fault([0, 1]))
    # donation consumed the old buffers (no copy of the pool exists)
    assert old_payload.is_deleted() and old_ids.is_deleted()
    # compiled memory analysis: outputs alias the donated pools; temp
    # scratch stays far below one pool payload
    m = len([2])
    args = (cache.payload_pool, cache.ids_pool, cache.valid_pool,
            jnp.zeros((m,), jnp.int32),
            jnp.zeros((m, cache.p_max, st.dim), cache.payload_pool.dtype),
            jnp.zeros((m, cache.p_max), jnp.int32),
            jnp.zeros((m, cache.p_max), bool))
    mem = pager_mod._scatter_frames.lower(*args).compile() \
        .memory_analysis()
    pool_bytes = int(cache.payload_pool.nbytes + cache.ids_pool.nbytes
                     + cache.valid_pool.nbytes)
    assert mem.alias_size_in_bytes >= pool_bytes
    assert mem.temp_size_in_bytes < cache.payload_pool.nbytes
    # with foreign pins outstanding the fault must NOT donate (a
    # concurrent scan may still read the old arrays)
    pinned = cache.fault([3])
    held = cache.payload_pool
    cache.unpin(cache.fault([4, 5]))       # other partitions, pins held
    assert not held.is_deleted()
    np.asarray(held)                       # old snapshot still readable
    cache.unpin(pinned)


# -- read-ahead staging (PR 6 double-buffered faults) ------------------------


def test_stage_then_fault_consumes_staged_blocks(tmp_path, monkeypatch):
    """stage() pre-packs host blocks; the following fault must consume
    them WITHOUT another SQL round-trip and land the exact same bytes a
    cold fault would have."""
    st, _, assign = _mk_store(tmp_path)
    cache = _mk_cache(st, assign, 4)
    cache.stage([2, 5])
    assert set(cache._staged) == {2, 5}
    # the staged fault must never touch SQLite again
    def boom(*a, **k):                       # pragma: no cover
        raise AssertionError("staged fault re-fetched from the store")
    monkeypatch.setattr(st, "scan_partitions", boom)
    f = cache.fault([2, 5])
    assert not cache._staged                 # consumed, not copied
    assert (cache.hits, cache.misses) == (0, 2)   # still counted as misses
    monkeypatch.undo()
    for j, pid in zip(f, (2, 5)):
        ids, vecs = st.scan_partition(pid)
        m = len(ids)
        np.testing.assert_array_equal(
            np.asarray(cache.ids_pool)[int(j), :m], ids)
        np.testing.assert_array_equal(
            np.asarray(cache.payload_pool)[int(j), :m], vecs)
    cache.unpin(f)


def test_stage_skips_resident_partitions(tmp_path):
    st, _, assign = _mk_store(tmp_path)
    cache = _mk_cache(st, assign, 4)
    cache.unpin(cache.fault([1]))
    cache.stage([1, 4])                      # 1 is already resident
    assert set(cache._staged) == {4}
    # staging is advisory: faulting a staged pid is still a miss, a
    # resident one is still a hit
    cache.unpin(cache.fault([1, 4]))
    assert (cache.hits, cache.misses) == (1, 2)


def test_invalidate_drops_staged_blocks(tmp_path):
    """A durable write between stage() and fault() must not let the next
    fault consume the stale pre-write bytes."""
    st, X, assign = _mk_store(tmp_path)
    cache = _mk_cache(st, assign, 4)
    cache.stage([1])
    victim = int(np.nonzero(assign == 1)[0][0])
    newv = np.full((1, 8), 42.0, np.float32)
    st.upsert([victim], newv, partition_id=1)
    cache.invalidate([1])                    # write path always invalidates
    assert 1 not in cache._staged
    f = cache.fault([1])
    j = int(f[0])
    row = np.nonzero(np.asarray(cache.ids_pool)[j] == victim)[0][0]
    np.testing.assert_array_equal(np.asarray(cache.payload_pool)[j, row],
                                  newv[0])
    cache.unpin(f)


def test_invalidate_mid_fetch_discards_whole_stage_batch(tmp_path):
    """The generation counter: an invalidate that lands while a stage()
    is off-lock inside its SQLite fetch must poison the ENTIRE in-flight
    batch -- the stage read a mix of pre- and post-write rows and cannot
    tell which, so nothing it fetched may be inserted."""
    st, _, assign = _mk_store(tmp_path)
    cache = _mk_cache(st, assign, 4)
    real = st.scan_partitions

    def racing(*a, **k):
        blocks = real(*a, **k)
        cache.invalidate([9])        # a writer commits mid-fetch
        return blocks

    st.scan_partitions = racing
    try:
        cache.stage([3, 4])
    finally:
        st.scan_partitions = real
    assert not cache._staged         # whole batch dropped, not just pid 9
    cache.unpin(cache.fault([3, 4]))     # next fault re-reads fresh bytes
    assert cache.misses == 2


def test_paged_exact_prefetch_on_off_bitwise(paged_pair):
    """Engine-level pin: the read-ahead pipeline must never change what
    an exact paged scan computes -- ids AND scores bit-identical with
    prefetch forced off."""
    from repro.core import executor
    _, pag, X = paged_pair
    q = X[:4]
    before = executor.PAGED_PREFETCH
    try:
        executor.PAGED_PREFETCH = False
        r_off = pag.search(q, k=10, exact=True)
        executor.PAGED_PREFETCH = True
        r_on = pag.search(q, k=10, exact=True)
    finally:
        executor.PAGED_PREFETCH = before
    np.testing.assert_array_equal(np.asarray(r_off.ids),
                                  np.asarray(r_on.ids))
    np.testing.assert_array_equal(np.asarray(r_off.scores),
                                  np.asarray(r_on.scores))


# -- dtype-aware tile padding (satellite) ------------------------------------


def test_effective_pad_to_dtype_aware():
    f32 = IVFConfig(dim=8, pad_to=8)
    sq = IVFConfig(dim=8, pad_to=8, quantize="int8")
    assert effective_pad_to(f32, backend="tpu") == 8
    assert effective_pad_to(sq, backend="tpu") == 32
    assert effective_pad_to(sq, backend="cpu") == 8
    wide = IVFConfig(dim=8, pad_to=64, quantize="int8")
    assert effective_pad_to(wide, backend="tpu") == 64


def test_sq_kernel_asserts_tile_padding():
    from repro.kernels import sq_scan
    q = jnp.zeros((1, 8))
    codes = jnp.zeros((2, 8, 8), jnp.int8)      # p_max=8: not 32-aligned
    ok = jnp.ones((2, 8), bool)
    ids = jnp.zeros((2, 8), jnp.int32)
    with pytest.raises(AssertionError):
        sq_scan.sq_scan_topk(q, codes, jnp.zeros(8), jnp.ones(8), ok, ids,
                             jnp.arange(2, dtype=jnp.int32), 4,
                             interpret=False)
