"""Streaming updates: delta-store, upsert/delete, maintenance, monitor."""
import jax.numpy as jnp
import numpy as np

from repro.core import delta, maintenance, monitor, search
from repro.core.types import IVFConfig
from repro.core import ivf
from tests.conftest import clustered_data


def _mk(n=1500, delta_cap=128):
    X = clustered_data(n=n, seed=7)
    cfg = IVFConfig(dim=32, target_partition_size=50, kmeans_iters=30,
                    delta_capacity=delta_cap)
    return ivf.build_index(X, cfg=cfg), X


def test_insert_visible_immediately():
    idx, X = _mk()
    rng = np.random.default_rng(1)
    nv = rng.normal(size=(8, 32)).astype(np.float32)
    idx2 = delta.upsert(idx, jnp.asarray(nv),
                        jnp.arange(9000, 9008, dtype=jnp.int32),
                        jnp.zeros((8, 0)))
    r = search.ann_search(idx2, jnp.asarray(nv[:4]), 1, n_probe=2)
    assert (np.asarray(r.ids)[:, 0] == np.arange(9000, 9004)).all()


def test_upsert_replaces_old_copy():
    idx, X = _mk()
    vid = int(idx.ids[0, 0])
    old_vec = np.array(idx.vectors[0, 0])
    new_vec = old_vec + 100.0
    idx2 = delta.upsert(idx, jnp.asarray(new_vec[None]),
                        jnp.asarray([vid], dtype=jnp.int32),
                        jnp.zeros((1, 0)))
    # searching at the new location finds the fresh copy
    r = search.exact_search(idx2, jnp.asarray(new_vec[None]), 1)
    assert int(r.ids[0, 0]) == vid
    # the old copy is tombstoned: vid no longer matches near old location
    r2 = search.exact_search(idx2, jnp.asarray(old_vec[None]), 5)
    assert vid not in np.asarray(r2.ids)[0]
    assert not bool(idx2.valid[0, 0])


def test_delete_removes_everywhere():
    idx, X = _mk()
    victim = int(idx.ids[1, 0])
    idx2 = delta.delete(idx, jnp.asarray([victim], dtype=jnp.int32))
    r = search.exact_search(idx2, jnp.asarray(X[victim][None]), 3)
    assert victim not in np.asarray(r.ids)[0]
    assert int(idx2.num_live()) == int(idx.num_live()) - 1


def test_flush_preserves_searchability():
    idx, X = _mk()
    rng = np.random.default_rng(2)
    nv = rng.normal(size=(20, 32)).astype(np.float32)
    idx2 = delta.upsert(idx, jnp.asarray(nv),
                        jnp.arange(9100, 9120, dtype=jnp.int32),
                        jnp.zeros((20, 0)))
    idx3, stats = maintenance.flush_delta(idx2)
    assert stats.rows_moved == 20
    assert int(idx3.delta.valid.sum()) == 0
    r = search.ann_search(idx3, jnp.asarray(nv[:5]), 1, n_probe=idx3.k)
    assert (np.asarray(r.ids)[:, 0] == np.arange(9100, 9105)).all()
    # incremental flush writes far less than a full rebuild
    _, full_stats = maintenance.full_rebuild(idx2)
    assert stats.bytes_written < 0.25 * full_stats.bytes_written


def test_flush_updates_centroids_running_mean():
    idx, X = _mk()
    rng = np.random.default_rng(3)
    nv = rng.normal(size=(10, 32)).astype(np.float32) + 50.0  # far outliers
    idx2 = delta.upsert(idx, jnp.asarray(nv),
                        jnp.arange(9200, 9210, dtype=jnp.int32),
                        jnp.zeros((10, 0)))
    idx3, _ = maintenance.flush_delta(idx2)
    assert not np.allclose(np.asarray(idx3.centroids),
                           np.asarray(idx.centroids))


def test_monitor_triggers():
    idx, X = _mk(delta_cap=64)
    mon = monitor.IndexMonitor()
    assert mon.check(idx).action == "none"
    rng = np.random.default_rng(4)
    nv = rng.normal(size=(60, 32)).astype(np.float32)
    idx2 = delta.upsert(idx, jnp.asarray(nv),
                        jnp.arange(9300, 9360, dtype=jnp.int32),
                        jnp.zeros((60, 0)))
    assert mon.check(idx2).action == "flush"   # delta nearly full


def test_rebuild_trigger_on_growth():
    idx, X = _mk()
    mon = monitor.IndexMonitor(monitor.MonitorConfig(
        growth_rebuild_threshold=0.1))
    rng = np.random.default_rng(5)
    cur = idx
    for batch in range(4):
        nv = (clustered_data(n=200, seed=10 + batch))
        cur = delta.upsert(cur, jnp.asarray(nv),
                           jnp.arange(10000 + 200 * batch,
                                      10200 + 200 * batch, dtype=jnp.int32),
                           jnp.zeros((200, 0)))
        cur, _ = maintenance.flush_delta(cur)
    health = mon.check(cur)
    assert health.growth > 0.1
    assert health.action == "rebuild"
    rebuilt, _ = maintenance.full_rebuild(cur)
    assert int(rebuilt.num_live()) == int(cur.num_live())
    assert mon.check(rebuilt).growth < 0.1
