"""Fleet mode (PR 9): many tenants, one FramePool, one budget.

Pins the subsystem's contracts:
  * tenant isolation -- a tenant's answers through the SHARED pool are
    bit-identical (ids + scores) to a solo engine on the same durable
    state, on both scan backends (eviction policy never changes
    results);
  * the fleet-wide byte budget is never exceeded under a randomized
    multi-tenant workload (it is preallocated, so <= budget BY
    CONSTRUCTION -- asserted against live faulting anyway);
  * global CLOCK fairness -- a hot tenant's re-referenced working set
    stays resident while a cold tenant's stream recycles its own
    frames;
  * the fleet maintenance scheduler's deficit round robin bounds
    starvation -- every backlogged tenant steps within one round;
  * spill/reopen -- the live-handle LRU closes an idle tenant's SQLite
    connections and drops its frames; a later get() recovers an
    equivalent engine (same answers, cumulative counters).
"""
import os
import shutil

import numpy as np
import pytest

from repro.core import executor
from repro.core.query import Q
from repro.core.types import IVFConfig
from repro.fleet import Fleet, FramePool
from repro.storage import MicroNN, VectorStore
from repro.storage.pager import PartitionCache
from tests.conftest import clustered_data

DIM = 16
CFG = dict(dim=DIM, target_partition_size=50, kmeans_iters=10,
           delta_capacity=64)


def _build_tenant(fleet, name, seed, n=600):
    X = clustered_data(n=n, dim=DIM, seed=seed)
    eng = fleet.get(name)
    eng.upsert(np.arange(n), X)
    eng.build()
    eng.store.db.commit()
    # fold the WAL into the main db file so shutil.copy captures it all
    eng.store.db.execute("PRAGMA wal_checkpoint(TRUNCATE)")
    return X


@pytest.fixture(scope="module")
def fleet_root(tmp_path_factory):
    """One fleet, three distinct tenants, budget far below the sum of
    their scan tiers -- plus a byte-identical twin of t0 for the shared
    compile-cache assertion."""
    root = str(tmp_path_factory.mktemp("fleet"))
    fleet = Fleet(root, dim=DIM, budget_mb=0.04, max_live=8,
                  config=IVFConfig(**CFG))
    data = {n: _build_tenant(fleet, n, seed)
            for seed, n in enumerate(("t0", "t1", "t2"))}
    shutil.copy(os.path.join(root, "t0.db"),
                os.path.join(root, "twin.db"))
    yield fleet, root, data
    fleet.close()


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_tenant_isolation_bitwise_vs_solo(fleet_root, backend, tmp_path):
    """Every tenant's fleet answers == a solo paged engine's on a copy
    of its durable state, bit for bit, while all three interleave on
    ONE pool tight enough to force cross-tenant eviction."""
    fleet, root, data = fleet_root
    # the shared pool seats fewer frames than ONE tenant's partitions
    assert fleet.pool.capacity < fleet.get("t0").index.k
    solo_rs = {}
    for name, X in data.items():
        dst = str(tmp_path / f"{name}-{backend}.db")
        shutil.copy(os.path.join(root, f"{name}.db"), dst)
        solo = MicroNN(dim=DIM, path=dst, config=IVFConfig(**CFG),
                       memory_budget_mb=0.04)
        solo.recover()
        solo_rs[name] = solo.search(X[:8], k=10, n_probe=8,
                                    backend=backend)
    # interleave tenants so their frames genuinely compete
    for _ in range(2):
        for name, X in data.items():
            r = fleet.get(name).search(X[:8], k=10, n_probe=8,
                                       backend=backend)
            np.testing.assert_array_equal(np.asarray(r.ids),
                                          np.asarray(solo_rs[name].ids))
            np.testing.assert_array_equal(
                np.asarray(r.scores), np.asarray(solo_rs[name].scores))


def test_shared_pool_budget_and_eviction_pressure(fleet_root):
    fleet, _, data = fleet_root
    budget = fleet.pool.budget_bytes
    rng = np.random.default_rng(0)
    for _ in range(12):
        name = ("t0", "t1", "t2")[rng.integers(0, 3)]
        X = data[name]
        fleet.get(name).search(X[rng.integers(0, len(X), 4)],
                               k=5, n_probe=8)
        assert fleet.pool.resident_bytes <= budget
    s = fleet.stats()
    assert s["resident_bytes"] <= s["budget_bytes"]
    # the tight budget forced cross-tenant competition
    pool_stats = s["pool"]
    assert pool_stats["resident_partitions"] <= fleet.pool.capacity
    assert sum(t["resident_frames"]
               for t in pool_stats["tenants"].values()) \
        == pool_stats["resident_partitions"]


def test_shared_compile_cache_zero_retrace_across_tenants(fleet_root):
    """Specs are tenant-agnostic by construction: a twin tenant with
    byte-identical durable state (=> identical shapes) reuses t0's
    compiled executables -- zero new jit traces for its first query."""
    fleet, _, data = fleet_root
    q = data["t0"][:8]
    spec = Q.knn(k=10).probe(8)
    fleet.get("t0").query(q, spec)
    fleet.get("t0").query(q, spec)          # warmed + stable
    t0 = executor.trace_count()
    r_twin = fleet.get("twin").query(q, spec)
    assert executor.trace_count() == t0
    r_t0 = fleet.get("t0").query(q, spec)
    np.testing.assert_array_equal(np.asarray(r_twin.ids),
                                  np.asarray(r_t0.ids))


# -- raw pool-level contracts (no engines) -----------------------------------


def _mk_store(tmp_path, name, n=160, d=8, k=16, seed=0, id_base=0):
    rng = np.random.default_rng(seed)
    st = VectorStore(str(tmp_path / name), dim=d, n_attr=0)
    X = rng.normal(size=(n, d)).astype(np.float32)
    st.upsert(list(range(id_base, id_base + n)), X)
    assign = rng.integers(0, k, n)
    st.set_partitions(np.arange(id_base, id_base + n), assign,
                      rng.normal(size=(k, d)).astype(np.float32),
                      np.zeros(k))
    return st, int(np.bincount(assign, minlength=k).max())


def _mk_views(tmp_path, n_frames, names=("a", "b"), k=16):
    p_max = 0
    stores = {}
    for i, name in enumerate(names):
        st, pm = _mk_store(tmp_path, f"{name}.db", seed=i, k=k,
                           id_base=10_000 * i)
        stores[name] = st
        p_max = max(p_max, pm)
    fb = PartitionCache.compute_frame_bytes(p_max, 8)
    pool = FramePool(dim=8, p_max=p_max, budget_bytes=n_frames * fb)
    views = {name: PartitionCache(st, p_max=p_max, budget_bytes=0,
                                  pool=pool, tenant=name)
             for name, st in stores.items()}
    return pool, views, stores


def test_randomized_multitenant_faults_hold_budget_and_isolation(tmp_path):
    pool, views, stores = _mk_views(tmp_path, n_frames=6,
                                    names=("a", "b", "c"))
    budget = pool.budget_bytes
    rng = np.random.default_rng(1)
    names = list(views)
    for _ in range(60):
        name = names[rng.integers(0, 3)]
        cache = views[name]
        pids = rng.choice(16, size=rng.integers(1, 4), replace=False)
        f = cache.fault(list(pids))
        assert pool.resident_bytes <= budget
        assert len(pool._key_frame) <= pool.capacity
        # isolation: the frames just pinned hold THIS tenant's rows
        lo = 10_000 * names.index(name)
        ids = np.asarray(cache.ids_pool)[np.asarray(f)]
        live = ids[ids >= 0]
        assert ((live >= lo) & (live < lo + 10_000)).all()
        cache.unpin(f)
    assert (pool._pins == 0).all()
    # per-tenant accounting reconciles with the global frame table
    for name, cache in views.items():
        assert pool.resident_count(cache._tid) == len(cache._pid_frame)
    assert sum(pool.resident_count(v._tid) for v in views.values()) \
        == len(pool._key_frame)


def test_hot_tenant_stays_resident_under_cold_stream(tmp_path):
    """Global CLOCK fairness: tenant a's re-referenced working set keeps
    its reference bits fresh, so tenant b's cold single-partition
    stream recycles b's own cold frames instead of flushing a."""
    pool, views, _ = _mk_views(tmp_path, n_frames=8)
    a, b = views["a"], views["b"]
    hot = [0, 1, 2, 3, 4]
    a.unpin(a.fault(hot))                   # warm the hot working set
    for i in range(5):                      # ride out the first-sweep
        b.unpin(b.fault([i % 16]))          # transient (all ref bits set
        a.unpin(a.fault(hot))               # -> hand evicts blindly once)
    warm_misses = a.misses
    for i in range(5, 30):
        b.unpin(b.fault([i % 16]))          # cold stream, one at a time
        a.unpin(a.fault(hot))               # hot set re-referenced
    assert a.misses == warm_misses, \
        "cold tenant's stream evicted the hot tenant's working set"
    assert pool.resident_count(a._tid) == len(hot)
    assert b.misses > b.hits                # the cold stream kept missing


def test_tenant_invalidation_is_scoped(tmp_path):
    """One tenant's write invalidation must not drop a co-tenant's
    frame for the same partition id."""
    pool, views, _ = _mk_views(tmp_path, n_frames=8)
    a, b = views["a"], views["b"]
    a.unpin(a.fault([3]))
    b.unpin(b.fault([3]))
    a.invalidate([3])
    assert 3 not in a._pid_frame
    assert 3 in b._pid_frame                # b's frame 3 survives
    b.invalidate_all()
    assert not b._pid_frame
    assert pool.resident_count(b._tid) == 0


# -- fleet scheduler + spill/reopen ------------------------------------------


def _fleet_with_backlog(tmp_path, names, n=400):
    fleet = Fleet(str(tmp_path / "fl"), dim=DIM, budget_mb=0.05,
                  max_live=8, config=IVFConfig(**CFG),
                  max_rows_per_step=256)
    rng = np.random.default_rng(7)
    for name in names:
        X = clustered_data(n=n, dim=DIM, seed=3)
        eng = fleet.get(name)
        eng.upsert(np.arange(n), X)
        eng.build()
        # overflow the delta threshold: flush work lands in the queue
        extra = rng.normal(size=(64, DIM)).astype(np.float32)
        eng.upsert(np.arange(9000, 9064), extra)
    return fleet


def test_deficit_round_robin_serves_every_backlogged_tenant(tmp_path):
    fleet = _fleet_with_backlog(tmp_path, ("churn", "steady"))
    churn, steady = fleet.get("churn"), fleet.get("steady")
    assert churn.stats()["scheduler_depth"] > 0
    assert steady.stats()["scheduler_depth"] > 0
    fleet.scheduler.step_round()
    # ONE round: both tenants stepped -- the churning tenant could not
    # absorb the whole round (the starvation bound)
    assert churn.scheduler.daemon_steps >= 1
    assert steady.scheduler.daemon_steps >= 1
    # keep churn backlogged; steady must still drain within bounded rounds
    rng = np.random.default_rng(8)
    for r in range(10):
        churn.upsert(np.arange(9500 + 64 * r, 9564 + 64 * r),
                     rng.normal(size=(64, DIM)).astype(np.float32))
        fleet.scheduler.step_round()
        if steady.stats()["scheduler_depth"] == 0:
            break
    assert steady.stats()["scheduler_depth"] == 0, \
        "churning tenant starved its neighbor's maintenance"
    fleet.close()


def test_fleet_daemon_drains_all_tenants(tmp_path):
    fleet = _fleet_with_backlog(tmp_path, ("x", "y"))
    fleet.start_maintenance()
    try:
        deadline = 30.0
        import time
        t0 = time.monotonic()
        while any(fleet.get(n).stats()["scheduler_depth"] > 0
                  for n in ("x", "y")):
            assert time.monotonic() - t0 < deadline
            time.sleep(0.01)
    finally:
        fleet.stop_maintenance()
    for n in ("x", "y"):
        eng = fleet.get(n)
        assert int(eng.index.delta.count) == 0
        assert eng.scheduler.daemon_steps >= 1
    fleet.close()


def test_spill_reopen_round_trip(tmp_path):
    """max_live=1: opening tenant b spills tenant a (store closed,
    frames dropped); re-opening a recovers an equivalent engine with
    cumulative per-tenant counters."""
    fleet = Fleet(str(tmp_path / "fl"), dim=DIM, budget_mb=0.05,
                  max_live=1, config=IVFConfig(**CFG))
    Xa = _build_tenant(fleet, "a", seed=0, n=400)
    q = Xa[:4]
    before = fleet.query("a", q, Q.knn(k=5).probe(6))
    hits_before = fleet.get("a").index.cache.hits
    a_ref = fleet.get("a")
    _build_tenant(fleet, "b", seed=1, n=400)    # evicts a (max_live=1)
    assert fleet.live_tenants() == ["b"]
    assert a_ref.index is None                  # spilled: pytree dropped
    assert fleet.stats()["pool"]["tenants"]["a"]["resident_frames"] == 0
    again = fleet.query("a", q, Q.knn(k=5).probe(6))    # lazy reopen
    np.testing.assert_array_equal(np.asarray(before.ids),
                                  np.asarray(again.ids))
    np.testing.assert_array_equal(np.asarray(before.scores),
                                  np.asarray(again.scores))
    assert fleet.get("a") is not a_ref
    # tenant-labeled series are cumulative across spill/reopen
    assert fleet.get("a").index.cache.hits >= hits_before
    assert fleet.stats()["tenant_spills"] >= 2
    fleet.close()
