"""Training loop: learning, checkpoint-resume, crash restart, stragglers."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.tokens import TokenStream
from repro.models import init_model
from repro.train import Trainer, TrainerConfig, optim

TINY = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                   num_heads=2, num_kv_heads=1, head_dim=32, d_ff=128,
                   vocab_size=256, pattern=("attn",), tie_embeddings=True,
                   remat=False)


def _data(batch=4, seq=64, vocab=256):
    stream = TokenStream(vocab=vocab, batch=batch, seq=seq)

    def it(start):
        for b in stream.iter_from(start):
            yield {"tokens": jnp.asarray(b["tokens"])}
    return it


def test_loss_decreases():
    params, _ = init_model(TINY, jax.random.PRNGKey(0))
    tcfg = TrainerConfig(opt=optim.AdamWConfig(lr=3e-3, warmup_steps=5,
                                               total_steps=40))
    tr = Trainer(TINY, tcfg)
    tr.fit(params, _data(), 40)
    losses = [m["loss"] for m in tr.history]
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses[:3]


def test_checkpoint_resume_exact(tmp_path):
    """20 straight steps == 10 steps + restart + 10 steps (same stream)."""
    opt_cfg = optim.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
    params, _ = init_model(TINY, jax.random.PRNGKey(0))

    tr1 = Trainer(TINY, TrainerConfig(opt=opt_cfg))
    p_full, _ = tr1.fit(params, _data(), 20)

    d = str(tmp_path / "ck")
    tcfg = TrainerConfig(opt=opt_cfg, checkpoint_every=10, ckpt_dir=d)
    tr2 = Trainer(TINY, tcfg)
    tr2.fit(params, _data(), 10)          # writes step_10
    tr3 = Trainer(TINY, tcfg)             # fresh process analogue
    p_resumed, _ = tr3.fit(params, _data(), 20)   # resumes at 10
    assert tr3.history[0]["step"] == 10
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_resumed)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-2, rtol=2e-2)


def test_microbatch_accumulation_matches_full_batch():
    params, _ = init_model(TINY, jax.random.PRNGKey(0))
    from repro.train.trainer import make_train_step
    t1 = make_train_step(TINY, TrainerConfig(microbatches=1), donate=False)
    t4 = make_train_step(TINY, TrainerConfig(microbatches=4), donate=False)
    opt = optim.init(params)
    batch = next(_data(batch=8)(0))
    p1, _, m1 = t1(params, opt, batch)
    p4, _, m4 = t4(params, opt, batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-3, rtol=5e-3)


def test_straggler_detection():
    from repro.train.trainer import StragglerStats
    st = StragglerStats()
    flagged = [st.observe(dt, z=3.0)
               for dt in [1.0] * 20 + [5.0] + [1.0] * 5]
    assert any(flagged), "slow step not flagged"
    assert sum(flagged) <= 2, "over-flagging"


def test_grad_clip_bounds_update():
    params = {"w": jnp.ones((4, 4), jnp.float32)}
    grads = {"w": jnp.full((4, 4), 1e6, jnp.float32)}
    cfg = optim.AdamWConfig(lr=1e-2, grad_clip=1.0, weight_decay=0.0)
    state = optim.init(params)
    new_p, _, metrics = optim.update(cfg, grads, state, params)
    assert float(metrics["grad_norm"]) > 1e5
    # post-clip update magnitude is bounded by lr * O(1)
    delta = np.abs(np.asarray(new_p["w"]) - 1.0).max()
    assert delta < 0.1
