"""Hybrid queries: predicates, selectivity estimation, plan choice."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ivf, search
from repro.core.hybrid import And, AttributeStats, Or, Pred, compile_filter
from repro.core.optimizer import HybridOptimizer
from repro.core.types import IVFConfig
from tests.conftest import clustered_data


@pytest.fixture(scope="module")
def hybrid_index():
    X = clustered_data(n=3000, seed=11)
    rng = np.random.default_rng(11)
    attrs = np.stack([
        rng.integers(0, 10, 3000),        # categorical
        rng.normal(size=3000) * 10,       # continuous
        rng.integers(0, 2 ** 8, 3000),    # tag bitset
    ], axis=1).astype(np.float32)
    cfg = IVFConfig(dim=32, target_partition_size=50, kmeans_iters=40)
    idx = ivf.build_index(X, attrs=attrs, cfg=cfg)
    stats = AttributeStats(attrs, bitset_cols=(2,))
    return idx, X, attrs, stats


def test_predicate_eval_matches_numpy(hybrid_index):
    idx, X, attrs, stats = hybrid_index
    cases = [
        (Pred(0, "eq", 3.0), attrs[:, 0] == 3),
        (Pred(1, "gt", 0.0), attrs[:, 1] > 0),
        (Pred(1, "le", -5.0), attrs[:, 1] <= -5),
        (And((Pred(0, "eq", 3.0), Pred(1, "gt", 0.0))),
         (attrs[:, 0] == 3) & (attrs[:, 1] > 0)),
        (Or((Pred(0, "eq", 1.0), Pred(0, "eq", 2.0))),
         (attrs[:, 0] == 1) | (attrs[:, 0] == 2)),
        (Pred(2, "match", 5.0),
         (attrs[:, 2].astype(np.uint32) & 5) == 5),
    ]
    for pred, expect in cases:
        got = np.asarray(compile_filter(pred)(jnp.asarray(attrs)))
        assert (got == expect).all(), pred


def test_selectivity_estimates_reasonable(hybrid_index):
    _, _, attrs, stats = hybrid_index
    n = len(attrs)
    for pred, true_frac in [
        (Pred(0, "eq", 3.0), (attrs[:, 0] == 3).mean()),
        (Pred(1, "gt", 0.0), (attrs[:, 1] > 0).mean()),
        (Pred(1, "lt", -25.0), (attrs[:, 1] < -25).mean()),
    ]:
        est = stats.selectivity_factor(pred)
        assert 0.0 <= est <= 1.0
        assert abs(est - true_frac) < 0.15, (pred, est, true_frac)


def test_conjunction_min_disjunction_sum(hybrid_index):
    _, _, attrs, stats = hybrid_index
    a, b = Pred(0, "eq", 3.0), Pred(1, "gt", 0.0)
    ca, cb = stats.cardinality(a), stats.cardinality(b)
    assert stats.cardinality(And((a, b))) == min(ca, cb)
    assert stats.cardinality(Or((a, b))) == min(ca + cb, stats.n_rows)


def test_optimizer_plan_choice(hybrid_index):
    idx, X, attrs, stats = hybrid_index
    opt = HybridOptimizer(stats)
    selective = And((Pred(0, "eq", 3.0), Pred(1, "gt", 15.0)))
    broad = Pred(1, "gt", -100.0)
    assert opt.choose(idx, selective, n_probe=8).plan == "pre"
    assert opt.choose(idx, broad, n_probe=8).plan == "post"


def test_prefilter_100pct_recall(hybrid_index):
    idx, X, attrs, stats = hybrid_index
    opt = HybridOptimizer(stats)
    pred = And((Pred(0, "eq", 3.0), Pred(1, "gt", 15.0)))
    q = jnp.asarray(X[:16])
    res, dec = opt.execute(idx, q, pred, 10, n_probe=8)
    assert dec.plan == "pre"
    f = compile_filter(pred)
    exact = search.exact_search(idx, q, 10, attr_filter=f)
    assert float(search.recall_at_k(res, exact, 10)) == 1.0


def test_postfilter_results_satisfy_predicate(hybrid_index):
    idx, X, attrs, stats = hybrid_index
    pred = Pred(0, "ne", 3.0)
    f = compile_filter(pred)
    res = search.ann_search(idx, jnp.asarray(X[:8]), 10, n_probe=8,
                            attr_filter=f)
    ids = np.asarray(res.ids)
    for row in ids:
        for i in row[row >= 0]:
            assert attrs[i, 0] != 3
