"""Fleet flight recorder + SLO health + exposition endpoint (PR 10).

Pins the tentpole contracts:

  * **capture -> replay is bit-exact** -- a workload captured by the
    flight recorder (resident xla + pallas, paged, and multi-tenant
    through Fleet) replays to bit-identical ids AND exact-f32 scores;
  * **bounded + sampled** -- max_records caps the file, sample_every=N
    keeps exactly every Nth call, recording-off captures nothing;
  * **noisy-neighbor attribution** -- every cross-tenant CLOCK eviction
    lands in the (victim, evictor) matrix and its registry counters,
    and 1000 synthetic tenants stay inside the registry's per-name
    cardinality guard;
  * **SLO health** -- Fleet.health() has a pinned schema, burns error
    budget off the per-tenant latency histograms, and flips tenants to
    "degraded" exactly when their burn rate exceeds 1;
  * **manifest** -- the tenant directory is the SQLite manifest, not
    the filesystem: create/drop are transactional, recover() reports
    orphan files and missing stores, health() surfaces both;
  * **exposition endpoint** -- /metrics, /healthz, /traces, /events
    serve well-formed output during a live workload without taking the
    engine write mutex and without perturbing results.
"""
import json
import os
import shutil
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.core.query import Q
from repro.core.types import IVFConfig
from repro.fleet import Fleet, FramePool, TenantSLO
from repro.obs import metrics as obs_metrics
from repro.obs import recorder as obs_recorder
from repro.obs.http import ExpositionServer
from repro.storage import MicroNN
from tests.conftest import clustered_data

DIM = 16


def _mk(tmp_path, name, *, paged=False, n=400, seed=0, **eng_kw):
    cfg = IVFConfig(dim=DIM, target_partition_size=50, kmeans_iters=8,
                    delta_capacity=64)
    eng = MicroNN(dim=DIM, path=str(tmp_path / f"{name}.db"), config=cfg,
                  memory_budget_mb=0.05 if paged else None, **eng_kw)
    X = clustered_data(n=n, dim=DIM, seed=seed)
    eng.upsert(np.arange(n), X)
    eng.build()
    return eng, X


def _mk_fleet(tmp_path, *, tenants=("a", "b"), n=300, budget_mb=0.5,
              **kw):
    cfg = IVFConfig(dim=DIM, target_partition_size=50, kmeans_iters=4)
    fleet = Fleet(str(tmp_path / "fleet"), dim=DIM, budget_mb=budget_mb,
                  config=cfg, **kw)
    X = clustered_data(n=n, dim=DIM, seed=3)
    for t in tenants:
        eng = fleet.get(t)
        with eng.session() as s:
            s.upsert(np.arange(n), X)
        eng.build()
    return fleet, X


def _get(url, timeout=10):
    with urllib.request.urlopen(url, timeout=timeout) as r:
        return r.status, r.headers.get("Content-Type", ""), r.read()


# -- capture / replay --------------------------------------------------------


@pytest.mark.parametrize("paged", [False, True])
def test_replay_bit_identical_engine(tmp_path, paged):
    eng, X = _mk(tmp_path, f"rep{paged}", paged=paged)
    cap = str(tmp_path / "cap.db")
    specs = [Q.knn(k=5, n_probe=4).backend("xla"),
             Q.knn(k=3, n_probe=4).backend("pallas"),
             Q.knn(k=7, n_probe=4)]
    with obs_recorder.recording(cap) as rec:
        for i, spec in enumerate(specs):
            eng.query(X[i:i + 2], spec)
        assert rec.recorded == len(specs)
    rep = obs_recorder.replay(cap, engine=eng, strict=True)
    assert rep.ok and rep.replayed == len(specs) \
        and rep.matched == len(specs)
    eng.store.close()


def test_replay_detects_divergence(tmp_path):
    """A store mutated between capture and replay MUST be caught: the
    digest compare is the whole point, not a formality."""
    eng, X = _mk(tmp_path, "div")
    cap = str(tmp_path / "cap.db")
    with obs_recorder.recording(cap):
        eng.query(X[:2], Q.knn(k=3, n_probe=4))
    # shift every stored vector: same ids, different scores
    eng.upsert(np.arange(200), X[:200] + 1.0)
    eng.maintain(force="flush")
    rep = obs_recorder.replay(cap, engine=eng)
    assert not rep.ok and rep.mismatches
    with pytest.raises(AssertionError):
        obs_recorder.replay(cap, engine=eng, strict=True)
    eng.store.close()


def test_replay_multi_tenant_fleet(tmp_path):
    fleet, X = _mk_fleet(tmp_path, tenants=("a", "b", "c"))
    cap = str(tmp_path / "cap.db")
    with obs_recorder.recording(cap):
        for i in range(6):
            fleet.query(f"{'abc'[i % 3]}", X[i:i + 2],
                        Q.knn(k=4, n_probe=4))
    recs = obs_recorder.load(cap)
    # every engine.query capture carries its tenant + digest; the
    # fleet.get touches interleave as events
    sites = {r.site for r in recs}
    assert obs_recorder.SITE_ENGINE in sites
    assert obs_recorder.SITE_FLEET_GET in sites
    rep = obs_recorder.replay(cap, fleet=fleet, strict=True)
    assert rep.ok and rep.replayed == 6 and rep.events == 6
    fleet.close()


def test_recorder_bounded_and_sampled(tmp_path):
    eng, X = _mk(tmp_path, "bnd")
    spec = Q.knn(k=3, n_probe=4)
    cap1 = str(tmp_path / "cap1.db")
    with obs_recorder.recording(cap1, sample_every=3) as rec:
        for i in range(9):
            eng.query(X[i:i + 1], spec)
    assert rec.recorded == 3                    # every 3rd call
    assert len(obs_recorder.load(cap1)) == 3
    cap2 = str(tmp_path / "cap2.db")
    with obs_recorder.recording(cap2, max_records=4) as rec:
        for i in range(10):
            eng.query(X[i:i + 1], spec)
        assert rec.stats()["full"]
    assert len(obs_recorder.load(cap2)) == 4    # capped, not crashed
    # recording off: nothing captured, hooks take the one-branch path
    assert obs_recorder.active() is None
    eng.query(X[:1], spec)
    assert len(obs_recorder.load(cap2)) == 4
    eng.store.close()


def test_recorder_unpicklable_spec_dropped(tmp_path):
    eng, X = _mk(tmp_path, "unp")
    cap = str(tmp_path / "cap.db")

    class Opaque:                               # lambda-style: no pickle
        def __reduce__(self):
            raise TypeError("not picklable")

    with obs_recorder.recording(cap) as rec:
        rec.record(obs_recorder.SITE_ENGINE, None, X[:1], Opaque())
        eng.query(X[:1], Q.knn(k=3, n_probe=4))
        st = rec.stats()
        assert st["dropped"] == 1
    recs = obs_recorder.load(cap)               # only the sound record
    assert len(recs) == 1 and recs[0].digest is not None
    assert obs_recorder.replay(cap, engine=eng, strict=True).ok
    eng.store.close()


def test_frontdoor_capture_replays(tmp_path):
    from repro.serving import FrontDoor
    eng, X = _mk(tmp_path, "fd")
    cap = str(tmp_path / "cap.db")
    fd = FrontDoor(eng)
    spec = Q.knn(k=5, n_probe=4)
    with obs_recorder.recording(cap):
        futs = [fd.submit(X[i:i + 1], spec) for i in range(6)]
        for f in futs:
            f.result(timeout=30)
    fd.close()
    recs = obs_recorder.load(cap, sites=[obs_recorder.SITE_FRONTDOOR])
    assert len(recs) == 6 and all(r.digest is None for r in recs)
    # digestless records self-check by double execution -- coalesced
    # admission replayed solo is still bit-stable (PR 7 parity)
    rep = obs_recorder.replay(cap, engine=eng, strict=True)
    assert rep.ok and rep.self_checked == 6
    eng.store.close()


# -- noisy-neighbor attribution ----------------------------------------------


def test_eviction_matrix_attributes_cross_tenant(tmp_path):
    # budget ~4 frames: two tenants with disjoint hot sets MUST evict
    # each other; the matrix has to say so, by name
    fleet, X = _mk_fleet(tmp_path, tenants=("alice", "bob"),
                         budget_mb=0.02)
    spec = Q.knn(k=4, n_probe=8)
    for i in range(12):
        fleet.query("alice", X[i:i + 1], spec)
        fleet.query("bob", X[i + 1:i + 2], spec)
    st = fleet.pool.stats()
    matrix = st["eviction_matrix"]
    assert matrix, "no evictions recorded under a 4-frame budget"
    pairs = {(v, e) for v, row in matrix.items() for e in row}
    assert any(v != e for v, e in pairs), \
        f"expected cross-tenant evictions, got {pairs}"
    total = sum(n for row in matrix.values() for n in row.values())
    top = fleet.pool.top_evictors(3)
    assert top and top[0]["evictions"] <= total
    assert {"evictor", "victim", "evictions"} <= set(top[0])
    # the registry counters carry the same attribution
    snap = obs_metrics.default_registry().snapshot()["counters"]
    attributed = {k: v for k, v in snap.items()
                  if k.startswith("evictions_attributed")
                  and ("alice" in k or "bob" in k)}
    assert sum(attributed.values()) >= total > 0
    fleet.close()


def test_attribution_cardinality_bounded_1000_tenants():
    """1000 synthetic tenants evicting each other must not grow the
    registry without bound: the per-name LRU guard caps the series and
    the pool matrix folds overflow pairs into one bucket."""
    reg = obs_metrics.default_registry()
    evicted0 = reg.counter("obs_series_evicted").value
    pool = FramePool(dim=4, p_max=8, budget_bytes=1 << 16)
    with pool._lock:
        for i in range(1000):
            pool._note_eviction(i, (i + 1) % 1000)
    with reg._lock:
        n_series = len(reg._by_name.get("evictions_attributed", ()))
    assert n_series <= reg.max_series_per_name
    evicted = reg.counter("obs_series_evicted").value - evicted0
    assert evicted >= 1000 - reg.max_series_per_name
    st = pool.stats()
    n_pairs = sum(len(r) for r in st["eviction_matrix"].values())
    assert n_pairs + st["eviction_matrix_overflow"] == 1000
    assert n_pairs <= pool.attr_max_pairs


# -- SLO layer + health ------------------------------------------------------


def test_health_schema_and_slo_verdicts(tmp_path):
    fleet, X = _mk_fleet(tmp_path, tenants=("fast", "slow"))
    for i in range(8):
        fleet.query("fast", X[i:i + 1], Q.knn(k=3, n_probe=4))
        fleet.query("slow", X[i:i + 1], Q.knn(k=3, n_probe=4))
    # generous objective: inside budget; absurd objective: every query
    # (compile included) violates it -> burn >> 1 -> degraded
    fleet.set_slo("fast", p99_ms=600_000.0, target=0.5)
    fleet.set_slo("slow", p99_ms=1e-6, target=0.99)
    h = fleet.health()
    # pinned schema (the /healthz document)
    assert set(h) == {"schema", "status", "tenants", "degraded", "pool",
                      "daemon_alive", "live_tenants", "noisy_neighbors",
                      "manifest"}
    assert h["schema"] == 1
    assert set(h["pool"]) == {"budget_bytes", "resident_bytes",
                              "pressure"}
    assert set(h["manifest"]) == {"orphans", "missing"}
    t = h["tenants"]["fast"]
    assert set(t) == {"verdict", "queries", "p99_ms", "objective_ms",
                      "target", "violation_fraction", "burn_rate"}
    assert t["verdict"] == "ok" and t["burn_rate"] <= 1.0
    assert t["queries"] >= 8
    s = h["tenants"]["slow"]
    assert s["verdict"] == "degraded" and s["burn_rate"] > 1.0
    assert "slow" in h["degraded"] and h["status"] == "degraded"
    assert 0.0 < h["pool"]["pressure"] <= 1.0
    assert json.dumps(h)                       # JSON-serializable as-is
    fleet.close()


def test_slo_default_and_override(tmp_path):
    fleet, _ = _mk_fleet(tmp_path, tenants=("a",),
                         slo=TenantSLO(p99_ms=123.0, target=0.9))
    assert fleet.slo_for("a").p99_ms == 123.0
    fleet.set_slo("a", p99_ms=7.0, target=0.95)
    assert fleet.slo_for("a") == TenantSLO(p99_ms=7.0, target=0.95)
    assert fleet.slo_for("other").p99_ms == 123.0   # default applies
    # an idle tenant burns nothing
    assert fleet._tenant_health("ghost")["verdict"] == "ok"
    fleet.close()


# -- manifest ----------------------------------------------------------------


def test_manifest_is_the_tenant_directory(tmp_path):
    fleet, _ = _mk_fleet(tmp_path, tenants=("a", "b"))
    assert fleet.tenants() == ["a", "b"]
    fleet.close()
    # a new Fleet over the same root reads the durable manifest
    cfg = IVFConfig(dim=DIM, target_partition_size=50, kmeans_iters=4)
    f2 = Fleet(str(tmp_path / "fleet"), dim=DIM, budget_mb=0.5,
               config=cfg)
    assert f2.tenants() == ["a", "b"]
    # drop: one transaction + file removal; survives reopen
    f2.drop("a")
    assert f2.tenants() == ["b"]
    assert not os.path.exists(os.path.join(f2.root, "a.db"))
    f2.close()
    f3 = Fleet(str(tmp_path / "fleet"), dim=DIM, budget_mb=0.5,
               config=cfg)
    assert f3.tenants() == ["b"]
    f3.close()


def test_manifest_reconciles_orphans_and_missing(tmp_path):
    fleet, _ = _mk_fleet(tmp_path, tenants=("a", "b"))
    # orphan: a db file the manifest never registered (spill "a" first
    # so the copied main file is checkpointed + self-contained)
    fleet.close(name="a")
    shutil.copy(os.path.join(fleet.root, "a.db"),
                os.path.join(fleet.root, "stray.db"))
    # missing: registered tenant whose files vanished out-of-band
    fleet.close(name="b")
    for suffix in ("", "-wal", "-shm"):
        try:
            os.remove(os.path.join(fleet.root, "b.db" + suffix))
        except FileNotFoundError:
            pass
    drift = fleet.recover()
    assert drift == {"orphans": ["stray"], "missing": ["b"]}
    assert fleet.health()["manifest"] == drift
    assert "stray" not in fleet.tenants()       # manifest is authority
    # touching the orphan adopts it: registered + no longer drifting
    fleet.get("stray")
    assert "stray" in fleet.tenants()
    assert fleet.recover()["orphans"] == []
    fleet.close()


# -- exposition endpoint -----------------------------------------------------


def test_http_endpoints_engine(tmp_path):
    eng, X = _mk(tmp_path, "http", paged=True)
    eng.query(X[:2], Q.knn(k=3, n_probe=4), trace=True)
    srv = ExpositionServer.for_target(eng).start()
    try:
        code, ctype, body = _get(srv.url + "/metrics")
        assert code == 200 and ctype.startswith("text/plain")
        assert b"# TYPE " in body and b"# HELP " in body
        code, ctype, body = _get(srv.url + "/healthz")
        doc = json.loads(body)
        assert code == 200 and ctype.startswith("application/json")
        assert "hits" in doc and "misses" in doc     # MicroNN.stats()
        code, _, body = _get(srv.url + "/traces")
        traces = json.loads(body)
        assert code == 200 and len(traces) == 1 \
            and "spans" in traces[0]
        for path in ("/slow", "/events"):
            code, _, body = _get(srv.url + path)
            assert code == 200 and isinstance(json.loads(body), list)
        assert _get(srv.url + "/metrics")[2]         # repeat scrape ok
        try:
            _get(srv.url + "/nope")
            assert False, "404 expected"
        except urllib.error.HTTPError as e:
            assert e.code == 404
    finally:
        srv.stop()
        eng.store.close()


def test_http_serves_while_engine_mutex_held(tmp_path):
    """The endpoint must never need the engine write mutex: a scrape
    issued while a writer holds eng.lock still answers."""
    eng, X = _mk(tmp_path, "mutex")
    srv = ExpositionServer.for_target(eng).start()
    try:
        with eng.lock:
            assert _get(srv.url + "/metrics", timeout=10)[0] == 200
            assert _get(srv.url + "/healthz", timeout=10)[0] == 200
            assert _get(srv.url + "/traces", timeout=10)[0] == 200
    finally:
        srv.stop()
        eng.store.close()


def test_http_live_workload_unperturbed(tmp_path):
    """Concurrent scraping of every endpoint during a live fleet
    workload (daemon on) returns well-formed output and leaves query
    results bit-identical to the quiet run."""
    fleet, X = _mk_fleet(tmp_path, tenants=("a", "b"))
    spec = Q.knn(k=5, n_probe=4)
    quiet = [fleet.query("a", X[i:i + 2], spec).to_numpy()
             for i in range(6)]
    srv = ExpositionServer.for_target(fleet).start()
    fleet.start_maintenance()
    stop = threading.Event()
    errs = []

    def scrape():
        paths = ("/metrics", "/healthz", "/traces", "/events", "/slow")
        i = 0
        while not stop.is_set():
            try:
                code, _, body = _get(srv.url + paths[i % len(paths)])
                assert code == 200 and body
            except Exception as e:      # pragma: no cover
                errs.append(e)
                return
            i += 1

    threads = [threading.Thread(target=scrape) for _ in range(3)]
    for t in threads:
        t.start()
    try:
        live = [fleet.query("a", X[i:i + 2], spec).to_numpy()
                for i in range(6)]
        for _ in range(4):
            fleet.query("b", X[:3], spec)
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=10)
        fleet.stop_maintenance()
        srv.stop()
    assert not errs, errs
    for (qi, qs), (li, ls) in zip(quiet, live):
        np.testing.assert_array_equal(qi, li)
        np.testing.assert_array_equal(qs, ls)
    # the health doc stayed schema-valid mid-workload
    assert fleet.health()["schema"] == 1
    fleet.close()
