"""SQLite tier + engine durability + checkpoint protocol."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.types import IVFConfig
from repro.storage import MicroNN, VectorStore, checkpoint
from tests.conftest import clustered_data


def test_store_upsert_delete(tmp_path):
    st = VectorStore(str(tmp_path / "v.db"), dim=8, n_attr=1)
    vecs = np.arange(24, dtype=np.float32).reshape(3, 8)
    st.upsert([1, 2, 3], vecs, np.ones((3, 1)))
    assert st.count() == 3
    st.upsert([2], vecs[:1] + 9)   # upsert replaces
    assert st.count() == 3
    ids, got = st.scan_partition(-1)
    assert set(ids) == {1, 2, 3}
    st.delete([1])
    assert st.count() == 2


def test_clustered_scan_order(tmp_path):
    st = VectorStore(str(tmp_path / "v.db"), dim=4)
    vecs = np.random.default_rng(0).normal(size=(10, 4)).astype(np.float32)
    st.upsert(list(range(10)), vecs)
    st.set_partitions(np.arange(10), np.array([2, 0, 1] * 3 + [2]),
                      np.zeros((3, 4), np.float32), np.zeros(3))
    ids, parts, _ = st.all_rows()
    assert (np.diff(parts) >= 0).all()   # physically clustered
    assert st.generation == 1


def test_wal_mode_enabled(tmp_path):
    st = VectorStore(str(tmp_path / "v.db"), dim=4)
    mode = st.db.execute("PRAGMA journal_mode").fetchone()[0]
    assert mode == "wal"


def test_engine_recovery_with_pending_delta(tmp_path):
    X = clustered_data(n=800, seed=21, dim=16)
    path = str(tmp_path / "e.db")
    cfg = IVFConfig(dim=16, target_partition_size=50, kmeans_iters=20,
                    delta_capacity=64)
    eng = MicroNN(dim=16, n_attr=0, path=path, config=cfg)
    eng.upsert(np.arange(800), X)
    eng.build()
    nv = np.random.default_rng(1).normal(size=(5, 16)).astype(np.float32)
    eng.upsert(np.arange(9000, 9005), nv)   # lands in delta, durable
    eng.store.db.commit()

    eng2 = MicroNN(dim=16, n_attr=0, path=path, config=cfg)
    eng2.recover()
    r = eng2.search(nv[:2], k=1)
    assert list(np.asarray(r.ids)[:, 0]) == [9000, 9001]


def test_checkpoint_atomic_and_elastic(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.int32)}}
    d = str(tmp_path / "ck")
    checkpoint.save_checkpoint(d, 10, tree, extra={"note": "x"})
    checkpoint.save_checkpoint(d, 20, tree)
    assert checkpoint.latest_step(d) == 20
    restored, step, extra = checkpoint.restore_checkpoint(d, tree, step=10)
    assert step == 10 and extra["note"] == "x"
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    # a crashed (partial tmp) save never corrupts the latest pointer
    os.makedirs(os.path.join(d, "step_30.tmp"), exist_ok=True)
    assert checkpoint.latest_step(d) == 20


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    tree = {"w": jnp.ones((4, 4))}
    d = str(tmp_path / "ck2")
    checkpoint.save_checkpoint(d, 1, tree)
    bad = {"w": jnp.ones((2, 2))}
    with pytest.raises(AssertionError):
        checkpoint.restore_checkpoint(d, bad)
