"""SQLite tier + engine durability + checkpoint protocol."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.types import IVFConfig
from repro.storage import MicroNN, VectorStore, checkpoint
from tests.conftest import clustered_data


def test_store_upsert_delete(tmp_path):
    st = VectorStore(str(tmp_path / "v.db"), dim=8, n_attr=1)
    vecs = np.arange(24, dtype=np.float32).reshape(3, 8)
    st.upsert([1, 2, 3], vecs, np.ones((3, 1)))
    assert st.count() == 3
    st.upsert([2], vecs[:1] + 9)   # upsert replaces
    assert st.count() == 3
    ids, got = st.scan_partition(-1)
    assert set(ids) == {1, 2, 3}
    st.delete([1])
    assert st.count() == 2


def test_clustered_scan_order(tmp_path):
    st = VectorStore(str(tmp_path / "v.db"), dim=4)
    vecs = np.random.default_rng(0).normal(size=(10, 4)).astype(np.float32)
    st.upsert(list(range(10)), vecs)
    st.set_partitions(np.arange(10), np.array([2, 0, 1] * 3 + [2]),
                      np.zeros((3, 4), np.float32), np.zeros(3))
    ids, parts, _ = st.all_rows()
    assert (np.diff(parts) >= 0).all()   # physically clustered
    assert st.generation == 1


def test_wal_mode_enabled(tmp_path):
    st = VectorStore(str(tmp_path / "v.db"), dim=4)
    mode = st.db.execute("PRAGMA journal_mode").fetchone()[0]
    assert mode == "wal"


def test_engine_recovery_with_pending_delta(tmp_path):
    X = clustered_data(n=800, seed=21, dim=16)
    path = str(tmp_path / "e.db")
    cfg = IVFConfig(dim=16, target_partition_size=50, kmeans_iters=20,
                    delta_capacity=64)
    eng = MicroNN(dim=16, n_attr=0, path=path, config=cfg)
    eng.upsert(np.arange(800), X)
    eng.build()
    nv = np.random.default_rng(1).normal(size=(5, 16)).astype(np.float32)
    eng.upsert(np.arange(9000, 9005), nv)   # lands in delta, durable
    eng.store.db.commit()

    eng2 = MicroNN(dim=16, n_attr=0, path=path, config=cfg)
    eng2.recover()
    r = eng2.search(nv[:2], k=1)
    assert list(np.asarray(r.ids)[:, 0]) == [9000, 9001]


def test_checkpoint_atomic_and_elastic(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.int32)}}
    d = str(tmp_path / "ck")
    checkpoint.save_checkpoint(d, 10, tree, extra={"note": "x"})
    checkpoint.save_checkpoint(d, 20, tree)
    assert checkpoint.latest_step(d) == 20
    restored, step, extra = checkpoint.restore_checkpoint(d, tree, step=10)
    assert step == 10 and extra["note"] == "x"
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    # a crashed (partial tmp) save never corrupts the latest pointer
    os.makedirs(os.path.join(d, "step_30.tmp"), exist_ok=True)
    assert checkpoint.latest_step(d) == 20


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    tree = {"w": jnp.ones((4, 4))}
    d = str(tmp_path / "ck2")
    checkpoint.save_checkpoint(d, 1, tree)
    bad = {"w": jnp.ones((2, 2))}
    with pytest.raises(AssertionError):
        checkpoint.restore_checkpoint(d, bad)


@pytest.mark.parametrize("quantize,metric", [
    ("none", "l2"), ("int8", "l2"), ("int8", "cosine")])
def test_crash_recovery_build_upsert_delete_cycle(tmp_path, quantize, metric):
    """Crash-recovery contract: build -> upsert -> delete -> recover()
    into a *fresh* MicroNN on the same SQLite file must answer searches
    identically to the live engine -- delta rows replayed, tombstones
    honoured and (when quantized) the int8 code tier restored from the
    durable side table rather than re-encoded. The cosine case pins that
    recovery re-normalises the raw durable rows before packing, keeping
    the f32 and code tiers consistent with the live engine."""
    X = clustered_data(n=700, seed=5, dim=16)
    path = str(tmp_path / f"cycle_{quantize}_{metric}.db")
    cfg = IVFConfig(dim=16, metric=metric, target_partition_size=40,
                    kmeans_iters=15, delta_capacity=64, quantize=quantize)
    eng = MicroNN(dim=16, n_attr=1, path=path, config=cfg)
    eng.upsert(np.arange(700), X, np.ones((700, 1), np.float32))
    eng.build()
    rng = np.random.default_rng(9)
    nv = rng.normal(size=(6, 16)).astype(np.float32)
    eng.upsert(np.arange(9000, 9006), nv, np.zeros((6, 1), np.float32))
    eng.delete(np.arange(0, 15))
    eng.store.db.commit()

    eng2 = MicroNN(dim=16, n_attr=1, path=path, config=cfg)
    eng2.recover()

    q = np.concatenate([X[:8], nv[:2]])
    r_live = eng.search(q, k=20, n_probe=8)
    r_rec = eng2.search(q, k=20, n_probe=8)
    np.testing.assert_array_equal(np.asarray(r_live.ids),
                                  np.asarray(r_rec.ids))
    np.testing.assert_array_equal(np.asarray(r_live.scores),
                                  np.asarray(r_rec.scores))
    # deleted rows stay deleted, replayed delta rows stay findable
    assert not (np.asarray(r_rec.ids) < 15).any() or \
        not np.isin(np.arange(15), np.asarray(r_rec.ids)).any()
    assert np.isin(np.arange(9000, 9002), np.asarray(r_rec.ids)).any()
    if quantize == "int8":
        # the restored main-tier codes are byte-identical per asset id
        def codes_by_id(idx):
            val = np.asarray(idx.valid)
            return dict(zip(np.asarray(idx.ids)[val].tolist(),
                            map(bytes, np.asarray(idx.codes)[val])))
        assert codes_by_id(eng2.index) == codes_by_id(eng.index)
        assert eng2.index.qstats is not None


def test_recover_on_empty_centroids_clears_stale_state(tmp_path):
    """recover() on a store without a durable clustering must drop BOTH
    the index and the hybrid optimizer -- a stale optimizer from a
    previous build must not keep answering predicate queries."""
    from repro.core.hybrid import Pred
    X = clustered_data(n=400, seed=11, dim=16)
    path = str(tmp_path / "stale.db")
    cfg = IVFConfig(dim=16, target_partition_size=40, kmeans_iters=10)
    eng = MicroNN(dim=16, n_attr=1, path=path, config=cfg)
    eng.upsert(np.arange(400), X, np.ones((400, 1), np.float32))
    eng.build()
    assert eng.optimizer is not None
    # simulate a crash that wiped the centroid table mid-rebuild
    with eng.store.db:
        eng.store.db.execute("DELETE FROM centroids")
    eng.recover()
    assert eng.index is None and eng.optimizer is None
    with pytest.raises(AssertionError):
        eng.search(X[:1], k=5, predicate=Pred(0, "eq", 1.0))


def test_recover_replays_more_delta_rows_than_capacity(tmp_path):
    """The store can hold more pending (partition=-1) rows than the delta
    can seat -- flush never rewrites partition ids in SQLite -- so
    recover() must replay in chunks with flushes in between instead of
    silently dropping the overflow in one out-of-bounds scatter."""
    X = clustered_data(n=500, seed=13, dim=16)
    path = str(tmp_path / "over.db")
    cfg = IVFConfig(dim=16, target_partition_size=40, kmeans_iters=10,
                    delta_capacity=32)
    eng = MicroNN(dim=16, n_attr=0, path=path, config=cfg)
    eng.upsert(np.arange(500), X)
    eng.build()
    rng = np.random.default_rng(2)
    for start in (9000, 9030):   # two waves with a flush in between
        nv = rng.normal(size=(30, 16)).astype(np.float32)
        eng.upsert(np.arange(start, start + 30), nv)
        eng.maintain(force="flush")
    nv = rng.normal(size=(30, 16)).astype(np.float32)
    eng.upsert(np.arange(9060, 9090), nv)   # stays pending
    eng.store.db.commit()

    eng2 = MicroNN(dim=16, n_attr=0, path=path, config=cfg)
    eng2.recover()
    assert int(eng2.index.num_live()) == int(eng.index.num_live()) == 590
    assert int(eng2.index.delta.count) <= cfg.delta_capacity
    # every upserted row is findable after recovery
    r = eng2.search(nv[:4], k=1, n_probe=8)
    assert list(np.asarray(r.ids)[:, 0]) == [9060, 9061, 9062, 9063]
