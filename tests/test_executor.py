"""Unified query-execution layer: backend parity + plan-cache behaviour.

Parity contract: on an identical QueryPlan the Pallas (interpret) backend
and the XLA reference backend return identical ids, and both match the
exact_search oracle at full probe width (recall@k == 1.0) for ann, mqo
(batched shared-scan) and filtered plans.

Cache contract: repeated queries whose count lands in the same bucket
never retrace the jitted entry point.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import delta, executor, ivf, search
from repro.core.hybrid import And, Pred, compile_filter
from repro.core.types import INVALID_ID, IVFConfig


@pytest.fixture(scope="module")
def exec_index():
    rng = np.random.default_rng(7)
    centers = rng.normal(size=(16, 24)).astype(np.float32) * 5
    X = (centers[rng.integers(0, 16, 1500)]
         + rng.normal(size=(1500, 24))).astype(np.float32)
    attrs = np.stack([rng.integers(0, 8, 1500),
                      rng.normal(size=1500) * 10], 1).astype(np.float32)
    cfg = IVFConfig(dim=24, target_partition_size=50, kmeans_iters=30,
                    delta_capacity=128)
    idx = ivf.build_index(X, attrs=attrs, cfg=cfg)
    # live delta rows so the epilogue merge is exercised too
    nv = rng.normal(size=(10, 24)).astype(np.float32)
    idx = delta.upsert(idx, jnp.asarray(nv),
                       jnp.arange(5000, 5010, dtype=jnp.int32),
                       jnp.asarray(attrs[:10]))
    return idx, X, attrs


def _ids(res):
    return np.asarray(res.ids)


def test_backend_parity_ann(exec_index):
    idx, X, _ = exec_index
    plan = executor.plan_ann(idx, jnp.asarray(X[:8]), 10, 6)
    rx = executor.execute_plan(idx, plan, backend="xla")
    rp = executor.execute_plan(idx, plan, backend="pallas")
    assert (_ids(rx) == _ids(rp)).all()
    np.testing.assert_allclose(np.asarray(rx.scores), np.asarray(rp.scores),
                               rtol=1e-4, atol=1e-4)


def test_backend_parity_mqo_plan(exec_index):
    idx, X, _ = exec_index
    plan = executor.plan_ann(idx, jnp.asarray(X[:32]), 10, 4, u_max=24)
    rx = executor.execute_plan(idx, plan, backend="xla")
    rp = executor.execute_plan(idx, plan, backend="pallas")
    assert (_ids(rx) == _ids(rp)).all()


def test_backend_parity_filtered(exec_index):
    idx, X, attrs = exec_index
    f = compile_filter(And((Pred(0, "eq", 3.0), Pred(1, "gt", 0.0))))
    plan = executor.plan_ann(idx, jnp.asarray(X[:8]), 10, 8, attr_filter=f)
    rx = executor.execute_plan(idx, plan, backend="xla")
    rp = executor.execute_plan(idx, plan, backend="pallas")
    assert (_ids(rx) == _ids(rp)).all()
    # fused predicate honoured (ids < 5000 index the attrs table)
    for i in _ids(rx).ravel():
        if 0 <= i < 5000:
            assert attrs[i, 0] == 3 and attrs[i, 1] > 0


def test_backend_parity_exact_and_prefilter(exec_index):
    idx, X, _ = exec_index
    f = compile_filter(Pred(0, "eq", 3.0))
    for plan in (executor.plan_exact(idx, jnp.asarray(X[:4]), 10),
                 executor.plan_prefilter(idx, jnp.asarray(X[:4]), 10, f,
                                         cap=512)):
        rx = executor.execute_plan(idx, plan, backend="xla")
        rp = executor.execute_plan(idx, plan, backend="pallas")
        assert (_ids(rx) == _ids(rp)).all(), plan.kind


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_full_probe_matches_exact_oracle(exec_index, backend):
    idx, X, _ = exec_index
    q = jnp.asarray(X[:8])
    oracle = search.exact_search(idx, q, 10)
    plan = executor.plan_ann(idx, q, 10, idx.k)
    res = executor.execute_plan(idx, plan, backend=backend)
    assert float(search.recall_at_k(res, oracle, 10)) == 1.0
    assert (_ids(res) == _ids(oracle)).all()


def test_filtered_plan_matches_filtered_oracle(exec_index):
    idx, X, _ = exec_index
    f = compile_filter(Pred(0, "ne", 3.0))
    q = jnp.asarray(X[:8])
    oracle = search.exact_search(idx, q, 10, attr_filter=f)
    plan = executor.plan_ann(idx, q, 10, idx.k, attr_filter=f)
    for backend in ("xla", "pallas"):
        res = executor.execute_plan(idx, plan, backend=backend)
        assert float(search.recall_at_k(res, oracle, 10)) == 1.0


def test_no_retrace_within_bucket(exec_index):
    idx, X, _ = exec_index
    executor.search(idx, jnp.asarray(X[:5]), k=10, n_probe=6)   # warm bucket 8
    c0 = executor.trace_count()
    executor.search(idx, jnp.asarray(X[:5]), k=10, n_probe=6)   # same shape
    executor.search(idx, jnp.asarray(X[:7]), k=10, n_probe=6)   # same bucket
    executor.search(idx, jnp.asarray(X[:8]), k=10, n_probe=6)   # bucket edge
    assert executor.trace_count() == c0
    executor.search(idx, jnp.asarray(X[:9]), k=10, n_probe=6)   # new bucket
    assert executor.trace_count() == c0 + 1


def test_no_retrace_repeated_predicate(exec_index):
    idx, X, _ = exec_index
    q = jnp.asarray(X[:4])
    pred = And((Pred(0, "eq", 2.0), Pred(1, "le", 5.0)))
    executor.search(idx, q, k=5, n_probe=4,
                    attr_filter=compile_filter(pred))
    c0 = executor.trace_count()
    # a structurally equal predicate compiles to the *same* callable, so
    # the jit cache key (predicate_id) is stable across calls
    pred2 = And((Pred(0, "eq", 2.0), Pred(1, "le", 5.0)))
    executor.search(idx, q, k=5, n_probe=4,
                    attr_filter=compile_filter(pred2))
    assert executor.trace_count() == c0


def test_bucket_padding_is_invisible(exec_index):
    """Results for Q queries must not depend on bucket padding rows."""
    idx, X, _ = exec_index
    q5 = jnp.asarray(X[:5])
    res5 = executor.search(idx, q5, k=10, n_probe=6)            # bucket 8
    res5_nb = executor.search(idx, q5, k=10, n_probe=6, bucket=False)
    assert res5.ids.shape[0] == 5
    assert (_ids(res5) == _ids(res5_nb)).all()


def test_invalid_fill_when_under_k(exec_index):
    idx, X, _ = exec_index
    f = compile_filter(And((Pred(0, "eq", 3.0), Pred(1, "gt", 25.0))))
    res = executor.search(idx, jnp.asarray(X[:2]), k=50, kind="exact",
                          attr_filter=f)
    ids = _ids(res)
    n_match = (ids >= 0).sum(axis=1)
    # highly selective predicate: fewer than k matches, rest INVALID
    assert (ids != INVALID_ID).any()
    assert ((ids == INVALID_ID) == (np.asarray(res.scores) >= 1e37)).all()
    assert (n_match < 50).all()
