"""Declarative query API: QuerySpec hashability / jit-cache identity,
ResultSet semantics, the deprecation shims, and write sessions.

Cache contract (the tentpole's acceptance): the QuerySpec IS the
executor's jit cache key, so two structurally-equal specs -- built
independently, with structurally-equal predicate trees -- trigger
exactly ONE trace, while unequal specs get their own entries. Paged and
resident engines must return bit-identical results through the new
ResultSet path on both backends.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import executor, ivf
from repro.core.hybrid import And, Or, Pred, compile_filter
from repro.core.query import Q, QuerySpec, ResultSet
from repro.core.types import INVALID_ID, IVFConfig
from repro.storage import MicroNN
from tests.conftest import clustered_data


@pytest.fixture(scope="module")
def spec_index():
    rng = np.random.default_rng(3)
    centers = rng.normal(size=(12, 16)).astype(np.float32) * 5
    X = (centers[rng.integers(0, 12, 1200)]
         + rng.normal(size=(1200, 16))).astype(np.float32)
    attrs = np.stack([rng.integers(0, 4, 1200),
                      rng.normal(size=1200) * 10], 1).astype(np.float32)
    cfg = IVFConfig(dim=16, target_partition_size=50, kmeans_iters=20,
                    delta_capacity=64)
    return ivf.build_index(X, attrs=attrs, cfg=cfg), X, attrs


# -- spec construction / hashability ----------------------------------------


def test_spec_equality_and_hash():
    a = Q.knn(k=100).probe(8).where(Pred(0, "==", 3)).backend("xla")
    b = Q.knn(k=100).probe(8).where(Pred(0, "eq", 3.0)).backend("xla")
    assert a == b
    assert hash(a) == hash(b)
    assert len({a, b}) == 1             # usable as a dict/cache key


def test_spec_unequal_variants():
    base = Q.knn(k=10, n_probe=4)
    others = [base.top(11), base.probe(5), base.exact(),
              base.union_cap(8), base.quantized(False),
              base.backend("xla"), base.where(Pred(0, "eq", 1.0)),
              base.where(Pred(0, "eq", 2.0))]
    assert len({base, *others}) == len(others) + 1


def test_where_chaining_accumulates():
    """Chained .where() calls AND together -- a fluent chain never
    silently drops an earlier filter."""
    chained = Q.knn().where(Pred(0, "==", 2.0)).where(Pred(1, ">=", 5.0))
    at_once = Q.knn().where(Pred(0, "eq", 2.0), Pred(1, "ge", 5.0))
    assert chained == at_once
    assert chained.predicate == And((Pred(0, "eq", 2.0),
                                     Pred(1, "ge", 5.0)))
    # accumulation flattens: three chained calls == one flat And, so the
    # jit cache key is identical however the chain was spelled
    three = (Q.knn().where(Pred(0, "eq", 1.0)).where(Pred(1, "gt", 2.0))
             .where(Pred(1, "lt", 9.0)))
    flat = Q.knn().where(Pred(0, "eq", 1.0), Pred(1, "gt", 2.0),
                         Pred(1, "lt", 9.0))
    assert three == flat and hash(three) == hash(flat)


def test_resultset_equality_does_not_raise(spec_index):
    idx, X, _ = spec_index
    rs = executor.run(idx, jnp.asarray(X[:2]), Q.knn(k=3, n_probe=2))
    assert rs == rs and rs in [rs]      # identity-eq; no array ambiguity


def test_spec_structural_predicate_equality():
    t1 = And((Pred(0, "eq", 2.0), Or((Pred(1, "lt", 3.0),
                                      Pred(1, "ge", 9.0)))))
    t2 = And((Pred(0, "=", 2.0), Or((Pred(1, "<", 3.0),
                                     Pred(1, ">=", 9.0)))))
    assert Q.knn().where(t1) == Q.knn().where(t2)
    # a compiled filter round-trips back to its tree
    assert Q.knn().where(compile_filter(t1)) == Q.knn().where(t2)


def test_spec_is_frozen():
    s = Q.knn(k=5)
    with pytest.raises(dataclasses.FrozenInstanceError):
        s.k = 6
    assert s.top(6).k == 6 and s.k == 5   # builder returns new specs


def test_spec_builder_permutations_property():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    ops = {
        "top": lambda s: s.top(17),
        "probe": lambda s: s.probe(3),
        "union": lambda s: s.union_cap(16),
        "where": lambda s: s.where(Pred(0, "eq", 1.0)),
        "backend": lambda s: s.backend("xla"),
        "quant": lambda s: s.quantized(False),
        "post": lambda s: s.postfilter(),
        "attrs": lambda s: s.with_attrs(),
    }

    @settings(max_examples=50, deadline=None)
    @given(st.permutations(sorted(ops)))
    def check(order):
        built = Q.knn()
        for name in order:
            built = ops[name](built)
        ref = Q.knn()
        for name in sorted(ops):
            ref = ops[name](ref)
        # independent builder fields commute: any order, same frozen
        # spec, same hash -> same jit cache entry
        assert built == ref and hash(built) == hash(ref)

    check()


# -- the spec as the jit cache key ------------------------------------------


def test_equal_specs_share_one_trace(spec_index):
    idx, X, _ = spec_index
    s1 = Q.knn(k=7, n_probe=3).where(And((Pred(0, "eq", 2.0),
                                          Pred(1, "le", 5.0))))
    executor.run(idx, jnp.asarray(X[:4]), s1)       # warm (traces once)
    c0 = executor.trace_count()
    s2 = Q.knn(k=7, n_probe=3).where(And((Pred(0, "==", 2.0),
                                          Pred(1, "<=", 5.0))))
    assert s1 is not s2 and s1 == s2
    r1 = executor.run(idx, jnp.asarray(X[:4]), s1)
    r2 = executor.run(idx, jnp.asarray(X[:4]), s2)
    assert executor.trace_count() == c0             # exactly one trace
    np.testing.assert_array_equal(np.asarray(r1.ids), np.asarray(r2.ids))
    executor.run(idx, jnp.asarray(X[:3]), s2)       # same Q bucket
    assert executor.trace_count() == c0


def test_unequal_specs_do_not_collide(spec_index):
    idx, X, _ = spec_index
    q = jnp.asarray(X[:4])
    base = Q.knn(k=9, n_probe=3)
    executor.run(idx, q, base)
    c0 = executor.trace_count()
    for variant in (base.top(10), base.probe(4), base.exact(),
                    base.where(Pred(0, "eq", 1.0))):
        executor.run(idx, q, variant)
    assert executor.trace_count() == c0 + 4         # one entry each
    # and the cache keeps serving all of them without retracing
    for variant in (base, base.top(10), base.probe(4), base.exact()):
        executor.run(idx, q, variant)
    assert executor.trace_count() == c0 + 4


def test_compile_cache_size_grows_with_distinct_specs(spec_index):
    idx, X, _ = spec_index
    q = jnp.asarray(X[:2])
    n0 = executor.compile_cache_size()
    executor.run(idx, q, Q.knn(k=31, n_probe=5))   # specs no other test
    executor.run(idx, q, Q.knn(k=37, n_probe=5))   # in this session uses
    assert executor.compile_cache_size() >= n0 + 2


# -- ResultSet ---------------------------------------------------------------


def test_resultset_iteration_and_numpy(spec_index):
    idx, X, _ = spec_index
    rs = executor.run(idx, jnp.asarray(X[:5]),
                      Q.exact(k=50).where(And((Pred(0, "eq", 3.0),
                                               Pred(1, "gt", 20.0)))))
    assert len(rs) == 5 and rs.k == 50
    ids, scores = rs.to_numpy()
    assert ids.shape == scores.shape == (5, 50)
    for qi, hit in enumerate(rs):
        # iteration trims INVALID padding; scores stay aligned
        assert (hit.ids != INVALID_ID).all()
        assert len(hit.ids) == (ids[qi] != INVALID_ID).sum()
        assert len(hit) == len(hit.scores)
    first = rs[0]
    assert np.array_equal(first.ids, next(iter(rs)).ids)


def test_resultset_merge_matches_unfiltered_topk(spec_index):
    """Merging per-predicate candidate streams reproduces the global
    top-k -- the sharded/chunked reduction contract."""
    idx, X, _ = spec_index
    q = jnp.asarray(X[:6])
    lo = executor.run(idx, q, Q.exact(k=10).where(Pred(0, "lt", 2.0)))
    hi = executor.run(idx, q, Q.exact(k=10).where(Pred(0, "ge", 2.0)))
    merged = lo.merge(hi, k=10)
    full = executor.run(idx, q, Q.exact(k=10))
    np.testing.assert_array_equal(np.asarray(merged.ids),
                                  np.asarray(full.ids))
    np.testing.assert_allclose(np.asarray(merged.scores),
                               np.asarray(full.scores), rtol=1e-5)


def test_resultset_merge_dedups_overlap(spec_index):
    idx, X, _ = spec_index
    q = jnp.asarray(X[:3])
    rs = executor.run(idx, q, Q.exact(k=8))
    merged = rs.merge(rs, k=8)          # fully overlapping candidates
    np.testing.assert_array_equal(np.asarray(merged.ids),
                                  np.asarray(rs.ids))


# -- engine: query() + the search() deprecation shim ------------------------


@pytest.fixture(scope="module")
def engine(tmp_path_factory):
    X = clustered_data(n=900, dim=16, seed=5)
    attrs = np.stack(
        [np.random.default_rng(0).integers(0, 4, len(X))],
        1).astype(np.float32)
    eng = MicroNN(dim=16, n_attr=1,
                  path=str(tmp_path_factory.mktemp("query") / "q.db"),
                  config=IVFConfig(dim=16, target_partition_size=50,
                                   kmeans_iters=15, delta_capacity=64))
    eng.upsert(np.arange(len(X)), X, attrs)
    eng.build()
    return eng, X, attrs


def test_search_shim_matches_query(engine):
    """Satellite: MicroNN.search is a thin wrapper over spec construction
    -- identical ids + scores vs the explicit query() path."""
    eng, X, _ = engine
    cases = [
        (dict(k=20, n_probe=4), Q.knn(k=20, n_probe=4)),
        (dict(k=10, exact=True), Q.exact(k=10)),
        (dict(k=10, n_probe=4, predicate=Pred(0, "eq", 2.0)),
         Q.knn(k=10, n_probe=4).where(Pred(0, "eq", 2.0))),
        (dict(k=10, n_probe=4, backend="xla"),
         Q.knn(k=10, n_probe=4).backend("xla")),
    ]
    for kwargs, spec in cases:
        r_old = eng.search(X[:6], **kwargs)
        r_new = eng.query(X[:6], spec)
        np.testing.assert_array_equal(np.asarray(r_old.ids),
                                      np.asarray(r_new.ids))
        np.testing.assert_array_equal(np.asarray(r_old.scores),
                                      np.asarray(r_new.scores))


def test_search_shim_batch_mqo_deprecation(engine):
    eng, X, _ = engine
    with pytest.warns(DeprecationWarning, match="batch_mqo"):
        eng.search(X[:4], k=5, batch_mqo=True)


def test_query_gathers_attrs(engine):
    eng, X, attrs = engine
    rs = eng.query(X[:4], Q.knn(k=5, n_probe=4).with_attrs())
    assert rs.attrs is not None and rs.attrs.shape == (4, 5, 1)
    ids = np.asarray(rs.ids)
    got = ids != INVALID_ID
    np.testing.assert_array_equal(rs.attrs[got][:, 0], attrs[ids[got], 0])


def test_stats_uniform_observability(engine, tmp_path):
    """Satellite: resident stats() reports the executor compile-cache
    next to the pager counters, same keys in both modes."""
    eng, X, _ = engine
    eng.query(X[:2], Q.knn(k=3, n_probe=2))
    s = eng.stats()
    for key in ("paged", "hits", "misses", "evictions", "resident_bytes",
                "budget_bytes", "trace_count", "compile_cache_size"):
        assert key in s, key
    assert not s["paged"] and s["resident_bytes"] > 0
    assert s["trace_count"] >= 1 and s["compile_cache_size"] >= 1

    pag = MicroNN(dim=16, path=str(tmp_path / "p.db"),
                  config=IVFConfig(dim=16, target_partition_size=40,
                                   kmeans_iters=8),
                  memory_budget_mb=0.05)
    pag.upsert(np.arange(400), clustered_data(n=400, dim=16, seed=6))
    pag.build()
    pag.query(clustered_data(n=4, dim=16, seed=7), Q.knn(k=3, n_probe=2))
    sp = pag.stats()
    for key in ("paged", "hits", "misses", "evictions", "resident_bytes",
                "budget_bytes", "trace_count", "compile_cache_size"):
        assert key in sp, key
    assert sp["paged"] and sp["misses"] > 0


# -- paged vs resident parity through the new path --------------------------


@pytest.fixture(scope="module", params=["none", "int8"])
def paged_pair(request, tmp_path_factory):
    quant = request.param
    X = clustered_data(n=1200, dim=16, seed=11)
    path = str(tmp_path_factory.mktemp("qparity") / f"{quant}.db")
    cfg = IVFConfig(dim=16, target_partition_size=50, kmeans_iters=12,
                    delta_capacity=64, quantize=quant, rerank_factor=4)
    eng = MicroNN(dim=16, path=path, config=cfg)
    eng.upsert(np.arange(len(X)), X)
    eng.build()
    res = MicroNN(dim=16, path=path, config=cfg)
    res.recover()
    pag = MicroNN(dim=16, path=path, config=cfg, memory_budget_mb=0.05)
    pag.recover()
    return res, pag, X


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_query_paged_matches_resident_bitwise(paged_pair, backend):
    """Acceptance: the SAME QuerySpec routed to a resident and a paged
    engine returns bit-identical ResultSets on both backends."""
    res, pag, X = paged_pair
    spec = Q.knn(k=10, n_probe=8).backend(backend)
    r1 = res.query(X[:12], spec)
    r2 = pag.query(X[:12], spec)
    assert isinstance(r1, ResultSet) and isinstance(r2, ResultSet)
    np.testing.assert_array_equal(np.asarray(r1.ids), np.asarray(r2.ids))
    np.testing.assert_array_equal(np.asarray(r1.scores),
                                  np.asarray(r2.scores))


def test_query_paged_rejects_prefilter(paged_pair):
    res, pag, X = paged_pair
    with pytest.raises(ValueError, match="paged"):
        pag.query(X[:2], Q.knn(k=5).where(Pred(0, "eq", 0.0)).prefilter(64))


def test_query_paged_rejects_union_cap(paged_pair):
    """A capped union would silently diverge from the resident plan --
    refused explicitly rather than dropped."""
    _, pag, X = paged_pair
    with pytest.raises(ValueError, match="union_cap"):
        pag.query(X[:2], Q.knn(k=5).union_cap(4))


def test_handwritten_filter_callable_runs_as_postfilter(engine):
    """An opaque callable predicate (no tree) skips the optimizer and
    runs fused -- matching the equivalent tree predicate's results."""
    eng, X, attrs = engine

    def fn(a):
        return a[..., 0] == 2.0

    r_fn = eng.query(X[:4], Q.knn(k=10, n_probe=8).where(fn))
    r_tree = eng.query(X[:4], Q.knn(k=10, n_probe=8)
                       .where(Pred(0, "eq", 2.0)).postfilter())
    np.testing.assert_array_equal(np.asarray(r_fn.ids),
                                  np.asarray(r_tree.ids))
    with pytest.raises(TypeError, match="sole"):
        Q.knn().where(fn, Pred(0, "eq", 1.0))   # callables don't compose


def test_merge_propagates_attrs(engine):
    eng, X, attrs = engine
    spec = Q.exact(k=6).with_attrs()
    lo = eng.query(X[:3], spec.where(Pred(0, "lt", 2.0)))
    hi = eng.query(X[:3], spec.where(Pred(0, "ge", 2.0)))
    merged = lo.merge(hi, k=6)
    assert merged.attrs is not None
    ids = np.asarray(merged.ids)
    got = ids != INVALID_ID
    np.testing.assert_array_equal(merged.attrs[got][:, 0],
                                  attrs[ids[got], 0])


# -- write sessions ----------------------------------------------------------


def _mk_engine(path, n=500, seed=13, paged=False, n_attr=1):
    X = clustered_data(n=n, dim=16, seed=seed)
    eng = MicroNN(dim=16, n_attr=n_attr, path=path,
                  config=IVFConfig(dim=16, target_partition_size=40,
                                   kmeans_iters=8, delta_capacity=64),
                  memory_budget_mb=0.05 if paged else None)
    eng.upsert(np.arange(n), X, np.ones((n, n_attr), np.float32))
    eng.build()
    return eng, X


@pytest.mark.parametrize("paged", [False, True])
def test_session_matches_sequential_ops(tmp_path, paged):
    """A session commit leaves the same durable + device state as the
    equivalent sequence of individual upsert/delete calls."""
    eng_a, X = _mk_engine(str(tmp_path / "a.db"), paged=paged)
    eng_b, _ = _mk_engine(str(tmp_path / "b.db"), paged=paged)
    rng = np.random.default_rng(0)
    nv = rng.normal(size=(6, 16)).astype(np.float32)
    na = np.full((6, 1), 7.0, np.float32)

    eng_a.upsert(np.arange(9000, 9006), nv, na)
    eng_a.delete(np.asarray([9001, 3]))

    with eng_b.session() as s:
        s.upsert(np.arange(9000, 9006), nv, na)
        s.delete(np.asarray([9001, 3]))

    assert eng_a.store.count() == eng_b.store.count()
    q = np.concatenate([nv[:3], X[:3]])
    ra = eng_a.query(q, Q.knn(k=5, n_probe=8))
    rb = eng_b.query(q, Q.knn(k=5, n_probe=8))
    np.testing.assert_array_equal(np.asarray(ra.ids), np.asarray(rb.ids))
    np.testing.assert_array_equal(np.asarray(ra.scores),
                                  np.asarray(rb.scores))


def test_session_coalesces_last_write_wins(tmp_path):
    eng, X = _mk_engine(str(tmp_path / "c.db"))
    v1 = np.full((1, 16), 30.0, np.float32)
    v2 = np.full((1, 16), -30.0, np.float32)
    with eng.session() as s:
        s.upsert(np.asarray([7777]), v1)
        s.delete(np.asarray([7777]))
        s.upsert(np.asarray([7777]), v2)   # the surviving write
        s.upsert(np.asarray([8888]), v1)
        s.delete(np.asarray([8888]))       # 8888 never lands
    r = eng.query(v2, Q.knn(k=1))
    assert int(np.asarray(r.ids)[0, 0]) == 7777
    ids = eng.store.partitions_for(np.asarray([7777, 8888]))
    assert ids[0] == -1 and ids[1] == -2   # delta row / absent


def test_session_discard_on_exception(tmp_path):
    eng, X = _mk_engine(str(tmp_path / "d.db"))
    n0 = eng.store.count()
    with pytest.raises(RuntimeError):
        with eng.session() as s:
            s.upsert(np.asarray([5555]), np.zeros((1, 16), np.float32))
            raise RuntimeError("abort")
    assert eng.store.count() == n0                    # nothing landed
    assert eng.store.partitions_for(np.asarray([5555]))[0] == -2


def test_session_durable_recovery(tmp_path):
    """Session writes are durable: a fresh engine recovered from the same
    file sees exactly the committed net effect."""
    path = str(tmp_path / "r.db")
    eng, X = _mk_engine(path)
    nv = np.random.default_rng(2).normal(size=(4, 16)).astype(np.float32)
    with eng.session() as s:
        s.upsert(np.arange(9100, 9104), nv, np.zeros((4, 1), np.float32))
        s.delete(np.asarray([9100]))
    eng2 = MicroNN(dim=16, n_attr=1, path=path, config=eng.config)
    eng2.recover()
    r = eng2.query(nv[1:3], Q.knn(k=1))
    assert list(np.asarray(r.ids)[:, 0]) == [9101, 9102]
    assert 9100 not in np.asarray(eng2.query(nv[:1], Q.knn(k=3)).ids)
