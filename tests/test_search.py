"""Alg. 2: ANN search, exact search, MQO, recall properties."""
import jax.numpy as jnp
import numpy as np

from repro.core import mqo, search
from repro.core.types import INVALID_ID, IVFConfig
from repro.core import ivf
from tests.conftest import clustered_data


def test_full_probe_equals_exact(small_index):
    idx, X = small_index
    q = jnp.asarray(X[:16])
    exact = search.exact_search(idx, q, 10)
    full = search.ann_search(idx, q, 10, n_probe=idx.k)
    assert (np.asarray(exact.ids) == np.asarray(full.ids)).all()


def test_recall_monotone_in_probes(small_index):
    idx, X = small_index
    rng = np.random.default_rng(0)
    q = jnp.asarray(X[:32] + 0.1 * rng.normal(size=(32, 32)).astype(np.float32))
    exact = search.exact_search(idx, q, 10)
    recalls = []
    for n in (1, 2, 4, 8, idx.k):
        r = search.ann_search(idx, q, 10, n_probe=n)
        recalls.append(float(search.recall_at_k(r, exact, 10)))
    assert all(b >= a - 0.02 for a, b in zip(recalls, recalls[1:])), recalls
    assert recalls[-1] == 1.0


def test_self_query_returns_self(small_index):
    idx, X = small_index
    r = search.ann_search(idx, jnp.asarray(X[:8]), 1, n_probe=4)
    assert (np.asarray(r.ids)[:, 0] == np.arange(8)).all()


def test_mqo_equals_naive(small_index):
    idx, X = small_index
    q = jnp.asarray(X[:64])
    a = search.ann_search(idx, q, 10, n_probe=6)
    b = mqo.mqo_search(idx, q, 10, n_probe=6)
    assert (np.asarray(a.ids) == np.asarray(b.ids)).all()


def test_mqo_io_amortisation(small_index):
    idx, _ = small_index
    io_naive = mqo.gathered_bytes(idx, 128, 8, mqo=False)
    io_mqo = mqo.gathered_bytes(idx, 128, 8, mqo=True)
    assert io_mqo < io_naive  # partition reads amortise over the batch


def test_cosine_metric():
    X = clustered_data(n=1000, seed=5)
    cfg = IVFConfig(dim=32, metric="cosine", target_partition_size=50,
                    kmeans_iters=30)
    idx = ivf.build_index(X, cfg=cfg)
    q = jnp.asarray(X[:8] * 3.0)   # scaling must not matter for cosine
    r = search.ann_search(idx, q, 1, n_probe=idx.k)
    assert (np.asarray(r.ids)[:, 0] == np.arange(8)).all()


def test_scores_sorted_and_padded(small_index):
    idx, X = small_index
    r = search.ann_search(idx, jnp.asarray(X[:4]), 10, n_probe=2)
    s = np.asarray(r.scores)
    for row in s:
        real = row[row < 1e37]
        assert (np.diff(real) >= -1e-5).all()


def test_scan_kernel_matches_core(small_index):
    """Pallas fused scan (interpret) == core search on the same probes."""
    from repro.kernels import ops
    idx, X = small_index
    q = jnp.asarray(X[:4])
    parts = search.find_nearest_centroids(idx, q, 4)
    # single shared probe list for determinism
    plist = parts[0]
    s_k, i_k = ops.scan_topk(q, idx.vectors, idx.valid, idx.ids, plist, 8)
    from repro.kernels import ref
    s_r, i_r = ref.ivf_scan_ref(q, idx.vectors, idx.valid, idx.ids, plist, 8)
    assert (np.asarray(i_k) == np.asarray(i_r)).all()
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=1e-4, atol=1e-4)
