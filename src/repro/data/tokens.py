"""Deterministic, resumable synthetic LM token pipeline.

Zipf-distributed tokens with a repeating n-gram structure so a ~100M model
has learnable signal (loss visibly drops in examples/train_lm.py). The
stream is indexed by step -- `iter_from(step)` resumes exactly where a
restored checkpoint left off (data-state is part of fault tolerance).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass
class TokenStream:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    zipf_a: float = 1.2
    ngram: int = 8

    def _batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        # zipf base stream
        raw = rng.zipf(self.zipf_a, size=(self.batch, self.seq))
        toks = (raw % (self.vocab - 2)) + 2
        # inject learnable n-gram repeats: copy shifted windows
        for b in range(self.batch):
            n_rep = self.seq // (4 * self.ngram)
            src = rng.integers(0, self.seq - 2 * self.ngram, size=n_rep)
            for s in src:
                toks[b, s + self.ngram: s + 2 * self.ngram] = \
                    toks[b, s: s + self.ngram]
        return {"tokens": toks.astype(np.int32)}

    def iter_from(self, step: int) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self._batch_at(step)
            step += 1
