from . import synthetic, tokens
