"""Synthetic vector workloads mirroring the paper's Table 2.

The container is offline, so public sets (SIFT/GIST/GLOVE/...) are
re-synthesised at matching dimensionality/metric as clustered Gaussian
mixtures; `scale` shrinks row counts for CPU benches while keeping the
geometry. Exact ground truth is computed by chunked brute force.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

# name -> (dim, n_vectors, n_queries, metric)   [paper Table 2]
TABLE2 = {
    "mnist": (784, 60_000, 10_000, "l2"),
    "nytimes": (256, 290_000, 10_000, "cosine"),
    "sift": (128, 1_000_000, 10_000, "l2"),
    "glove": (200, 1_183_514, 10_000, "l2"),
    "gist": (960, 1_000_000, 1_000, "l2"),
    "deepimage": (96, 10_000_000, 10_000, "cosine"),
    "internala": (512, 150_000, 1_000, "cosine"),
}


@dataclasses.dataclass
class Dataset:
    name: str
    metric: str
    X: np.ndarray          # [n, d]
    Q: np.ndarray          # [q, d]
    gt: Optional[np.ndarray] = None   # [q, k_gt] exact neighbour row idx

    @property
    def dim(self) -> int:
        return self.X.shape[1]


def make(name: str, scale: float = 0.01, k_gt: int = 100,
         seed: int = 0, with_gt: bool = True,
         n_clusters: Optional[int] = None) -> Dataset:
    dim, n, q, metric = TABLE2[name]
    n = max(1000, int(n * scale))
    q = max(32, min(int(q * scale), 512))
    rng = np.random.default_rng(seed)
    n_clusters = n_clusters or max(16, n // 500)
    centers = rng.normal(size=(n_clusters, dim)).astype(np.float32) * 4.0
    asg = rng.integers(0, n_clusters, n)
    X = centers[asg] + rng.normal(size=(n, dim)).astype(np.float32)
    qi = rng.integers(0, n, q)
    Q = X[qi] + 0.1 * rng.normal(size=(q, dim)).astype(np.float32)
    gt = exact_gt(X, Q, k_gt, metric) if with_gt else None
    return Dataset(name=name, metric=metric, X=X, Q=Q, gt=gt)


def exact_gt(X: np.ndarray, Q: np.ndarray, k: int, metric: str,
             chunk: int = 4096) -> np.ndarray:
    """Chunked brute-force ground truth (row indices into X)."""
    if metric == "cosine":
        Xn = X / np.maximum(np.linalg.norm(X, axis=1, keepdims=True), 1e-12)
        Qn = Q / np.maximum(np.linalg.norm(Q, axis=1, keepdims=True), 1e-12)
        scores = np.empty((len(Q), len(X)), np.float32)
        for i in range(0, len(X), chunk):
            scores[:, i:i + chunk] = -(Qn @ Xn[i:i + chunk].T)
    else:
        x2 = np.sum(X * X, axis=1)
        scores = np.empty((len(Q), len(X)), np.float32)
        for i in range(0, len(X), chunk):
            scores[:, i:i + chunk] = \
                x2[None, i:i + chunk] - 2.0 * (Q @ X[i:i + chunk].T)
    return np.argsort(scores, axis=1)[:, :k]


def recall(ids: np.ndarray, gt_rows: np.ndarray, row_ids: np.ndarray,
           k: int) -> float:
    """recall@k of result asset ids vs ground-truth rows (mapped to ids)."""
    gt_ids = row_ids[gt_rows[:, :k]]
    hits = 0
    for a, b in zip(ids[:, :k], gt_ids):
        hits += len(set(int(x) for x in a if x >= 0) & set(map(int, b)))
    return hits / (len(gt_ids) * k)
