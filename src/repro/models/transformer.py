"""Model assembly: one composable stack covering all 10 assigned archs.

Layer kinds (cfg.pattern): attn | local | rglru | mlstm | slstm | xattn
(xattn = decoder layer with cross-attention; used when encoder_layers > 0).

Storage: params["stack"]["p<j>"] holds the j-th period position stacked
over `stack_count` repeats -- a single representation serving both the
scanned path (fast compile; used by runnable examples) and the unrolled
path (exact HLO cost analysis; used by the dry-run). Decode caches use the
same stacked layout.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from . import attention as attn_lib
from . import moe as moe_lib
from . import recurrent as rec_lib
from . import sharding as shard_lib
from . import xlstm as xlstm_lib
from .layers import (InitCtx, apply_norm, init_embed, init_mlp, init_norm,
                     init_unembed, mlp, module, softcap, unembed_logits)


def _is_axes(x):
    return isinstance(x, tuple) and all(
        isinstance(a, (str, type(None))) for a in x)


def tree_slice(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


# ---------------------------------------------------------------------------
# Per-layer init
# ---------------------------------------------------------------------------

def init_layer(ctx: InitCtx, cfg: ModelConfig, kind: str):
    d = cfg.d_model
    mods: Dict[str, Any] = {"norm1": init_norm(ctx, cfg.norm, d)}
    if kind in ("attn", "local", "xattn"):
        mods["attn"] = attn_lib.init_attention(
            ctx, d, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
            bias=cfg.attn_bias)
        if kind == "xattn":
            mods["normx"] = init_norm(ctx, cfg.norm, d)
            mods["cross"] = attn_lib.init_attention(
                ctx, d, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
                bias=cfg.attn_bias)
    elif kind == "rglru":
        mods["rnn"] = rec_lib.init_rglru_block(
            ctx, d, cfg.d_rnn or d, cfg.conv_width)
    elif kind == "mlstm":
        mods["cell"] = xlstm_lib.init_mlstm_block(
            ctx, d, cfg.num_heads, cfg.mlstm_proj_factor)
    elif kind == "slstm":
        mods["cell"] = xlstm_lib.init_slstm_block(ctx, d, cfg.num_heads)
    else:
        raise ValueError(kind)

    if kind in ("attn", "local", "xattn", "rglru") and cfg.d_ff > 0:
        mods["norm2"] = init_norm(ctx, cfg.norm, d)
        if cfg.n_experts:
            mods["moe"] = moe_lib.init_moe(ctx, d, cfg.d_ff, cfg.n_experts,
                                           cfg.mlp_act)
        else:
            mods["mlp"] = init_mlp(ctx, d, cfg.d_ff, cfg.mlp_act,
                                   bias=cfg.attn_bias)
    if cfg.post_norm:
        mods["norm1_post"] = init_norm(ctx, cfg.norm, d)
        if "norm2" in mods:
            mods["norm2_post"] = init_norm(ctx, cfg.norm, d)
    return module(mods)


def _init_stack(ctx: InitCtx, cfg: ModelConfig, kinds, count: int):
    """Stacked init: leading dim = count per period position."""
    stack_p, stack_s = {}, {}
    for j, kind in enumerate(kinds):
        tmpl_p, tmpl_s = init_layer(
            InitCtx(None, ctx.param_dtype, abstract=True), cfg, kind)
        if ctx.abstract:
            params = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((count,) + s.shape, s.dtype),
                tmpl_p)
        else:
            keys = jax.random.split(ctx.split().key, count)
            params = jax.vmap(
                lambda k: init_layer(InitCtx(k, ctx.param_dtype), cfg, kind)[0]
            )(keys)
        specs = jax.tree.map(lambda ax: ("layers",) + ax, tmpl_s,
                             is_leaf=_is_axes)
        stack_p[f"p{j}"], stack_s[f"p{j}"] = params, specs
    return stack_p, stack_s


def init_model(cfg: ModelConfig, key: Optional[jax.Array] = None,
               abstract: bool = False):
    """-> (params, logical_specs). abstract=True yields ShapeDtypeStructs."""
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    ctx = InitCtx(key, dtype, abstract=abstract)
    mods: Dict[str, Any] = {
        "embed": init_embed(ctx, cfg.vocab_size, cfg.d_model),
        "final_norm": init_norm(ctx, cfg.norm, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        mods["unembed"] = init_unembed(ctx, cfg.vocab_size, cfg.d_model)
    if cfg.pos_kind == "learned":
        mods["pos_emb"] = module({"table": ctx.param(
            (cfg.max_position, cfg.d_model), (None, "embed"), scale=0.02)})
    if cfg.encoder_layers:
        enc_cfg = dataclasses.replace(cfg, n_experts=0)
        stack_p, stack_s = _init_stack(
            ctx, enc_cfg, ("attn",), cfg.encoder_layers)
        mods["enc_stack"] = (stack_p, stack_s)
        mods["enc_norm"] = init_norm(ctx, cfg.norm, cfg.d_model)
        mods["enc_pos"] = module({"table": ctx.param(
            (cfg.enc_seq, cfg.d_model), (None, "embed"), scale=0.02)})
    stack_p, stack_s = _init_stack(ctx, cfg, cfg.stack_period,
                                   cfg.stack_count)
    mods["stack"] = (stack_p, stack_s)
    if cfg.tail_kinds:
        tail_p, tail_s = {}, {}
        for j, kind in enumerate(cfg.tail_kinds):
            tail_p[f"t{j}"], tail_s[f"t{j}"] = init_layer(ctx, cfg, kind)
        mods["tail"] = (tail_p, tail_s)
    return module(mods)


# ---------------------------------------------------------------------------
# Full-sequence layer application (train / prefill)
# ---------------------------------------------------------------------------

def apply_layer(cfg: ModelConfig, kind: str, p, x, positions,
                enc_out=None) -> Tuple[jax.Array, jax.Array]:
    """-> (x, aux). x: [B, S, D]."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(cfg.norm, p["norm1"], x)
    if kind in ("attn", "local", "xattn"):
        core = attn_lib.attention(
            p["attn"], h, positions,
            theta=cfg.rope_theta, causal=True,
            window=cfg.window if kind == "local" else None,
            attn_softcap=cfg.attn_softcap,
            use_rope=cfg.pos_kind == "rope",
            q_scale=cfg.q_scale)
    elif kind == "rglru":
        core = rec_lib.rglru_block(p["rnn"], h)
    elif kind == "mlstm":
        core = xlstm_lib.mlstm_block_chunked(
            p["cell"], h, min(cfg.mlstm_chunk, h.shape[1]))
    elif kind == "slstm":
        core = xlstm_lib.slstm_block(p["cell"], h, cfg.num_heads)
    if cfg.post_norm:
        core = apply_norm(cfg.norm, p["norm1_post"], core)
    x = x + core

    if kind == "xattn":
        hx = apply_norm(cfg.norm, p["normx"], x)
        x = x + attn_lib.attention(
            p["cross"], hx, positions, kv_x=enc_out, use_rope=False,
            causal=False)

    if "norm2" in p:
        h2 = apply_norm(cfg.norm, p["norm2"], x)
        if cfg.n_experts and "moe" in p:
            ff, aux = moe_lib.moe(p["moe"], h2, top_k=cfg.top_k,
                                  capacity_factor=cfg.capacity_factor,
                                  act=cfg.mlp_act)
        else:
            ff = mlp(p["mlp"], h2, cfg.mlp_act)
        if cfg.post_norm:
            ff = apply_norm(cfg.norm, p["norm2_post"], ff)
        x = x + ff
    return x, aux


def _run_stack(cfg: ModelConfig, params, x, positions, enc_out=None,
               scan: Optional[bool] = None, remat: Optional[bool] = None):
    stack = params["stack"]
    kinds = cfg.stack_period
    count = cfg.stack_count
    scan = cfg.scan_layers if scan is None else scan
    remat = cfg.remat if remat is None else remat

    def period_body(x_aux, period_params):
        x, aux = x_aux
        x = shard_lib.constrain_residual(x)
        for j, kind in enumerate(kinds):
            x, a = apply_layer(cfg, kind, period_params[f"p{j}"], x,
                               positions, enc_out)
            aux = aux + a
        # pin the carry layout at exit too: entry/exit mismatch makes the
        # SPMD partitioner "involuntarily fully rematerialise" the carry
        # (a replicated f32 copy) every scan iteration
        x = shard_lib.constrain_residual(x)
        return (x, aux), None

    body = period_body
    if remat:
        # REPRO_REMAT_POLICY: nothing (default, min memory / +2ND FLOPs) |
        # dots (save matmul outputs: no matmul recompute, more memory)
        import os as _os
        policy = jax.checkpoint_policies.nothing_saveable \
            if _os.environ.get("REPRO_REMAT_POLICY", "nothing") != "dots" \
            else jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        body = jax.checkpoint(
            lambda carry, pp: period_body(carry, pp), policy=policy)

    carry = (x, jnp.zeros((), jnp.float32))
    if scan and count > 1:
        carry, _ = jax.lax.scan(body, carry, stack)
    else:
        for r in range(count):
            carry, _ = body(carry, tree_slice(stack, r))

    # unrolled tail layers (num_layers % len(pattern) != 0)
    def tail_body(carry, _):
        x, aux = carry
        x = shard_lib.constrain_residual(x)
        for j, kind in enumerate(cfg.tail_kinds):
            x, a = apply_layer(cfg, kind, params["tail"][f"t{j}"], x,
                               positions, enc_out)
            aux = aux + a
        return (x, aux), None

    if cfg.tail_kinds:
        tb = jax.checkpoint(tail_body,
                            policy=jax.checkpoint_policies.nothing_saveable) \
            if remat else tail_body
        carry, _ = tb(carry, None)
    return carry


def _encode(cfg: ModelConfig, params, frames):
    """Whisper encoder over stub frame embeddings [B, T, D]."""
    x = frames.astype(params["embed"]["table"].dtype) \
        + params["enc_pos"]["table"][None, :frames.shape[1]].astype(
            frames.dtype)
    pos = jnp.broadcast_to(
        jnp.arange(frames.shape[1], dtype=jnp.int32)[None], frames.shape[:2])
    enc_cfg = dataclasses.replace(cfg, n_experts=0)

    def enc_body(x_aux, layer_p):
        x, aux = x_aux
        h = apply_norm(cfg.norm, layer_p["norm1"], x)
        core = attn_lib.attention(layer_p["attn"], h, pos, causal=False,
                                  use_rope=False)
        x = x + core
        h2 = apply_norm(cfg.norm, layer_p["norm2"], x)
        x = x + mlp(layer_p["mlp"], h2, cfg.mlp_act)
        return (x, aux), None

    carry = (x, jnp.zeros((), jnp.float32))
    if cfg.scan_layers:
        carry, _ = jax.lax.scan(enc_body, carry, params["enc_stack"]["p0"])
    else:
        for r in range(cfg.encoder_layers):
            carry, _ = enc_body(carry,
                                tree_slice(params["enc_stack"]["p0"], r))
    return apply_norm(cfg.norm, params["enc_norm"], carry[0])


def embed_inputs(cfg: ModelConfig, params, batch: Dict[str, jax.Array]):
    """-> (x [B,S,D], positions [B,S], enc_out or None, text_offset)."""
    emb = params["embed"]["table"]
    tok = shard_lib.constrain_tokens(batch["tokens"])
    # pin the gather output to the residual sharding immediately: left to
    # itself XLA shards the embedding output on D (from the table) with S
    # fully replicated -- ~17 GB of f32 casts per device at prefill_32k
    x = shard_lib.constrain_residual(emb[tok])
    if cfg.emb_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    offset = 0
    if cfg.num_img_tokens and "img" in batch:
        img = batch["img"].astype(x.dtype)
        x = jnp.concatenate([img, x], axis=1)
        offset = img.shape[1]
    enc_out = None
    if cfg.encoder_layers:
        enc_out = _encode(cfg, params, batch["frames"])
    s = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                 (x.shape[0], s))
    if cfg.pos_kind == "learned":
        x = x + params["pos_emb"]["table"][None, :s].astype(x.dtype)
    return x, positions, enc_out, offset


def forward(cfg: ModelConfig, params, batch,
            scan: Optional[bool] = None, remat: Optional[bool] = None,
            last_logits_only: bool = False):
    """Full-sequence forward -> (logits, aux, hidden [B,S,D], offset).

    last_logits_only=True computes the unembedding for the final position
    only (prefill: avoids materialising [B, S, V] logits at 32k seq)."""
    x, positions, enc_out, offset = embed_inputs(cfg, params, batch)
    x = shard_lib.constrain_residual(x)
    x, aux = _run_stack(cfg, params, x, positions, enc_out,
                        scan=scan, remat=remat)
    x = shard_lib.constrain_residual(x)
    x = apply_norm(cfg.norm, params["final_norm"], x)
    h = x[:, -1:, :] if last_logits_only else x
    if cfg.tie_embeddings:
        logits = unembed_logits(params["embed"], h)
    else:
        logits = h @ params["unembed"]["w"]
    logits = softcap(logits, cfg.logit_softcap)
    return logits, aux, x, offset


def loss_fn(cfg: ModelConfig, params, batch,
            scan: Optional[bool] = None, remat: Optional[bool] = None):
    """Next-token CE over the text region. -> (loss, metrics).

    No slicing of the logits' S axis: position i is masked instead, so
    the [B, S, V] f32 log-probs stay sequence-sharded under SP (a slice
    to S-1 would force an all-gather + a full replicated buffer)."""
    logits, aux, _, offset = forward(cfg, params, batch, scan, remat)
    tok = batch["tokens"]
    s_total = logits.shape[1]
    s_text = tok.shape[1]
    # logits at seq position offset+j predict token j+1
    tidx = jnp.arange(s_total) - offset + 1          # target token index
    ok = (tidx >= 1) & (tidx <= s_text - 1)
    tgt = jnp.take(tok, jnp.clip(tidx, 0, s_text - 1), axis=1)  # [B, S]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * ok[None, :]) / (ok.sum() * tok.shape[0])
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux": aux,
                   "ppl": jnp.exp(jnp.minimum(loss, 20.0))}
