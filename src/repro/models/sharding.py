"""Logical-axis -> mesh-axis sharding rules (MaxText-style).

Parameters carry logical axis names ("embed", "ff", "heads", "experts",
...); a rule set maps them onto the physical mesh ("data", "model" and the
multi-pod "pod" axis). Divisibility is checked per-leaf: an axis whose dim
doesn't divide by the mapped mesh size falls back to replication (e.g.
kv_heads=8 on a 16-way model axis), keeping every arch lowerable on every
mesh without per-arch special cases.

Parallelism coverage:
  DP    batch over ("pod","data")
  FSDP  "embed" (and friends) over "data" -- ZeRO-style param+opt sharding
  TP    "ff"/"heads"/"vocab" over "model"
  EP    "experts" over "model" (phi3.5: 16e on 16-way axis)
  SP    decode KV-cache *sequence* over "model" when heads don't divide --
        flash-decoding-style partial-softmax with XLA-inserted reductions
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]


def make_rules(*, fsdp: bool = False, multi_pod: bool = False,
               shard_experts: bool = True,
               fsdp_over_pod: bool = False,
               sp: bool = True) -> Dict[str, Axis]:
    dp: Axis = ("pod", "data") if multi_pod else ("data",)
    fsdp_ax: Axis = None
    if fsdp:
        fsdp_ax = ("pod", "data") if (fsdp_over_pod and multi_pod) \
            else ("data",)
    return {
        "batch": dp,
        "vocab": "model",
        "embed": fsdp_ax,
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "ff": "model",
        "experts": "model" if shard_experts else None,
        "rnn": "model",
        "rnn_out": None,
        "layers": None,
        # sequence parallelism: residual-stream S dim over `model`.
        # Without this, activations replicate 16x over the model axis and
        # per-layer remat checkpoints alone blow the HBM budget (measured:
        # llama3 train_4k 57 GB/device -> see EXPERIMENTS.md §Perf).
        "act_seq": "model" if sp else None,
        None: None,
    }


def _axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]


def logical_to_pspec(axes: Tuple[Optional[str], ...],
                     shape: Tuple[int, ...],
                     rules: Dict[str, Axis], mesh: Mesh) -> P:
    used = set()
    out = []
    for dim, ax in zip(shape, axes):
        phys = rules.get(ax)
        if phys is None:
            out.append(None)
            continue
        cand = phys if isinstance(phys, tuple) else (phys,)
        cand = tuple(p for p in cand if p not in used)
        size = math.prod(_axis_size(mesh, p) for p in cand) if cand else 1
        if cand and dim % size == 0:
            out.append(cand if len(cand) > 1 else cand[0])
            used.update(cand)
        else:
            out.append(None)
    return P(*out)


def _is_axes(x):
    return isinstance(x, tuple) and all(
        isinstance(a, (str, type(None))) for a in x)


def param_shardings(specs_tree, params_tree, rules: Dict[str, Axis],
                    mesh: Mesh):
    """-> pytree of NamedSharding matching params_tree."""
    flat_specs = jax.tree.leaves(specs_tree, is_leaf=_is_axes)
    flat_params, treedef = jax.tree.flatten(params_tree)
    assert len(flat_specs) == len(flat_params), \
        (len(flat_specs), len(flat_params))
    out = [NamedSharding(mesh, logical_to_pspec(ax, p.shape, rules, mesh))
           for ax, p in zip(flat_specs, flat_params)]
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Batch / cache shardings
# ---------------------------------------------------------------------------

def batch_shardings(batch_tree, rules, mesh):
    dp = rules["batch"]
    dp_size = math.prod(
        _axis_size(mesh, a) for a in (dp if isinstance(dp, tuple) else (dp,)))

    def spec(leaf):
        b = dp if leaf.shape and leaf.shape[0] % dp_size == 0 and \
            leaf.shape[0] >= dp_size else None
        rest = (None,) * (len(leaf.shape) - 1)
        return NamedSharding(mesh, P(b, *rest) if leaf.shape
                             else P())
    return jax.tree.map(spec, batch_tree)


def cache_shardings(cache_tree, rules, mesh, cfg):
    """Leaf-shape-driven cache sharding (see module docstring, SP item).

    Handles both stacked ("p<j>", leading stack dim) and tail ("t<j>",
    no stack dim) cache entries; every axis assignment is divisibility-
    checked (batch=1 cells like long_500k fall back to replication).
    """
    dp = rules["batch"]
    model = "model"
    msize = _axis_size(mesh, model)
    dp_size = math.prod(
        _axis_size(mesh, a) for a in (dp if isinstance(dp, tuple) else (dp,)))

    def div(dim, ax, size):
        return ax if dim % size == 0 and dim >= size else None

    def spec(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        top = path[0].key if hasattr(path[0], "key") else str(path[0])
        stacked = top.startswith("p")
        shp = leaf.shape[1:] if stacked else leaf.shape
        prefix = (None,) if stacked else ()

        b = shp[0]
        bspec = div(b, dp, dp_size)
        if name in ("k", "v", "xk", "xv"):
            _, w, kv, hd = shp
            if kv % msize == 0:
                rest = (None, model, None)
            elif w % msize == 0:
                rest = (model, None, None)
            else:
                rest = (None, None, None)
            return NamedSharding(mesh, P(*prefix, bspec, *rest))
        if name == "pos":
            _, w = shp
            kvh = cfg.num_kv_heads
            if kvh % msize != 0 and w % msize == 0:
                return NamedSharding(mesh, P(*prefix, bspec, model))
            return NamedSharding(mesh, P(*prefix, bspec))
        # recurrent states: shard the widest trailing dim if divisible
        rest = []
        used_model = False
        for d in shp[1:]:
            ax = div(d, model, msize)
            if not used_model and ax is not None:
                rest.append(ax)
                used_model = True
            else:
                rest.append(None)
        return NamedSharding(mesh, P(*prefix, bspec, *rest))

    return jax.tree.map_with_path(spec, cache_tree)


def constrain(x, rules, mesh, *axes):
    shape = x.shape
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, logical_to_pspec(axes, shape, rules, mesh)))


# ---------------------------------------------------------------------------
# Activation-sharding context (attention score tensors)
# ---------------------------------------------------------------------------
# The [B, H, S, T] attention score tensor dominates training HBM. We pin
# its sharding explicitly: heads over `model` when divisible, else the
# q-seq axis (sequence parallelism) -- without this, XLA can leave scores
# replicated over `model` (e.g. minitron's 24 heads on a 16-way axis) and
# the step needs ~20x more temp memory than HBM has.
import contextlib
import threading

_CTX = threading.local()


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, rules: Dict[str, Axis]):
    prev = getattr(_CTX, "val", None)
    _CTX.val = (mesh, rules)
    try:
        yield
    finally:
        _CTX.val = prev


def attn_exact_mode() -> bool:
    """True when the cost probes want the exact single-block attention
    (compile-only; see attention._attn_block)."""
    ctx = getattr(_CTX, "val", None)
    if ctx is None:
        return False
    _, rules = ctx
    return bool(rules.get("attn_exact", False))


def sp_active(seq_len: Optional[int] = None) -> bool:
    """True when sequence-parallel residuals are in effect (and divisible)."""
    ctx = getattr(_CTX, "val", None)
    if ctx is None:
        return False
    mesh, rules = ctx
    if rules.get("act_seq") is None:
        return False
    if seq_len is not None and seq_len % _axis_size(mesh, "model"):
        return False
    return True


def constrain_residual(x):
    """Residual stream [B, S, D]: shard S over model under SP rules."""
    ctx = getattr(_CTX, "val", None)
    if ctx is None or x.ndim != 3:
        return x
    mesh, rules = ctx
    ax = rules.get("act_seq")
    if ax is None or x.shape[1] % _axis_size(mesh, "model") or \
            x.shape[1] < _axis_size(mesh, "model"):
        return x
    dp = rules["batch"]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(dp, ax, None)))


def constrain_feature(x):
    """RNN-state activations [B, S, R]: shard the feature dim over model
    (the scan over S is elementwise in R, so it stays fully local)."""
    ctx = getattr(_CTX, "val", None)
    if ctx is None or x.ndim != 3:
        return x
    mesh, rules = ctx
    if x.shape[2] % _axis_size(mesh, "model"):
        return x
    dp = rules["batch"]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(dp, None, "model")))


def moe_group_count(seq_len: int) -> int:
    """Routing groups for MoE dispatch: one group per SP shard of the
    sequence (1 when SP is off / indivisible)."""
    ctx = getattr(_CTX, "val", None)
    if ctx is None:
        return 1
    mesh, rules = ctx
    m = _axis_size(mesh, "model")
    if rules.get("act_seq") is None or seq_len % m or seq_len < m:
        return 1
    return m


def constrain_moe(x, phase: str):
    """MoE dispatch/combine tensors [B, G, E, C, D].

    phase="group":  pin G to the model axis -- routing stays local to the
                    SP shard that owns those tokens;
    phase="expert": pin E to the model axis (expert parallelism) -- the
                    group->expert reshard is the canonical MoE all-to-all.
    Archs whose E doesn't divide the axis (grok-1: E=8 on 16) skip the
    expert pin; the expert FFN dim is model-sharded instead, and the
    group pin alone keeps dispatch local."""
    ctx = getattr(_CTX, "val", None)
    if ctx is None or x.ndim != 5:
        return x
    mesh, rules = ctx
    dp = rules["batch"]
    m = _axis_size(mesh, "model")
    b, g, e, c, d = x.shape
    if phase == "group":
        if g % m == 0 and g >= m:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(dp, "model", None, None, None)))
        return x
    if rules.get("experts") is None or e % m:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(dp, None, "model", None, None)))


def gather_fsdp(w, axes: Tuple[Optional[str], ...]):
    """ZeRO semantics at point-of-use: all-gather the FSDP ('embed'->data)
    shard of a weight, keeping its TP/EP axes. Without this pin XLA can
    choose to keep the contraction dim sharded and all-reduce the *much
    larger activation* instead (measured on grok-1: 6.2 TB/step of
    all-reduce -> see EXPERIMENTS.md §Perf)."""
    ctx = getattr(_CTX, "val", None)
    if ctx is None:
        return w
    mesh, rules = ctx
    if rules.get("embed") is None or not rules.get("gather_fsdp", True):
        # decode: activations are tiny, so partial-sum + small psum beats
        # gathering GB-scale expert weights every layer
        return w
    rules2 = dict(rules)
    rules2["embed"] = None
    spec = logical_to_pspec(axes, w.shape, rules2, mesh)
    return jax.lax.with_sharding_constraint(w, NamedSharding(mesh, spec))


def constrain_tokens(tokens):
    """Token batch [B, S]: pin S over model under SP *before* the
    embedding gather -- otherwise the gather from the vocab-sharded table
    materialises (and all-reduces) the full [B, S, D] embedding output
    replicated per device (measured: 36 GB at prefill_32k before this
    pin; see EXPERIMENTS.md §Perf)."""
    ctx = getattr(_CTX, "val", None)
    if ctx is None or tokens.ndim != 2:
        return tokens
    mesh, rules = ctx
    dp = rules["batch"]
    dp_size = math.prod(
        _axis_size(mesh, a) for a in (dp if isinstance(dp, tuple) else (dp,)))
    b = dp if tokens.shape[0] % dp_size == 0 and tokens.shape[0] >= dp_size \
        else None
    s_ax = rules.get("act_seq")
    if s_ax is not None and tokens.shape[1] % _axis_size(mesh, "model") == 0 \
            and tokens.shape[1] >= _axis_size(mesh, "model"):
        return jax.lax.with_sharding_constraint(
            tokens, NamedSharding(mesh, P(b, s_ax)))
    return jax.lax.with_sharding_constraint(
        tokens, NamedSharding(mesh, P(b, None)))


def constrain_seq_replicated(x):
    """Pin [B, S, D] batch-sharded with S *replicated*: used by blocks
    whose time recurrence must scan the full sequence locally (sLSTM)."""
    ctx = getattr(_CTX, "val", None)
    if ctx is None or x.ndim != 3:
        return x
    mesh, rules = ctx
    dp = rules["batch"]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(dp, None, None)))


def constrain_scores(scores, kv_heads: Optional[int] = None):
    """scores: [B, H, S, T] -- pick the best available model-axis dim.

    Decode (S == 1): follow the KV-cache layout -- when kv_heads don't
    divide the axis the cache is *sequence*-sharded, so scores must be
    T-sharded; pinning heads instead forces the partitioner to reshard
    (replicate!) the whole multi-GB cache every layer (measured: ~100x
    byte inflation on llama3 decode_32k)."""
    ctx = getattr(_CTX, "val", None)
    if ctx is None:
        return scores
    mesh, rules = ctx
    dp = rules["batch"]
    msize = _axis_size(mesh, "model")
    b, h, s, t = scores.shape
    if s > 1 and rules.get("act_seq") is not None and s % msize == 0:
        # SP: scores inherit the q S-sharding; pin it explicitly
        return jax.lax.with_sharding_constraint(
            scores, NamedSharding(mesh, P(dp, None, "model", None)))
    cache_seq_sharded = (s == 1 and kv_heads is not None
                         and kv_heads % msize != 0 and t % msize == 0
                         and t >= msize)
    if cache_seq_sharded:
        spec = P(dp, None, None, "model")
    elif h % msize == 0:
        spec = P(dp, "model", None, None)
    elif s % msize == 0 and s > 1:          # SP over query positions
        spec = P(dp, None, "model", None)
    elif t % msize == 0 and t >= msize:     # SP over key positions
        spec = P(dp, None, None, "model")
    else:
        spec = P(dp, None, None, None)
    dp_size = math.prod(_axis_size(mesh, a) for a in
                        (dp if isinstance(dp, tuple) else (dp,)))
    if b % dp_size or b < dp_size:
        spec = P(None, *tuple(spec)[1:])
    return jax.lax.with_sharding_constraint(
        scores, NamedSharding(mesh, spec))
