"""xLSTM blocks: mLSTM (matrix memory, parallelisable) + sLSTM (scalar
memory with recurrent mixing).

mLSTM -- parallel quadratic form for train/prefill (exact, stabilised in
log space), O(1)-state recurrent form for decode:

    C_t = f_t C_{t-1} + i_t k_t v_t^T        (matrix memory, per head)
    n_t = f_t n_{t-1} + i_t k_t
    h_t = C_t^T q_t / max(|n_t^T q_t|, 1)

with exponential input gate i = exp(~i), sigmoid-in-log-space forget gate,
and the max-stabiliser m_t of the xLSTM paper. The decode state
(C [hd x hd] per head) is independent of sequence length -- that is what
makes the long_500k cell runnable for this arch.

sLSTM -- scalar memory with *recurrent* gate mixing (R·h_{t-1} inside the
gates) makes it inherently sequential: a lax.scan over time. Its per-step
FLOPs (4 block-diagonal [hd x hd] matvecs) are negligible next to the
mLSTM/projection matmuls; the dry-run roofline adds the analytic
scan-body x trip-count correction (see launch/costs.py) since XLA's
cost analysis counts while-bodies once.

Block layout follows xLSTM: pre-LN, mLSTM block = up-proj x2 -> cell
gated by SiLU branch -> down-proj (no separate MLP); sLSTM block = cell ->
GLU projection (factor 4/3).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .layers import InitCtx, module


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm_block(ctx: InitCtx, dim: int, n_heads: int, proj_factor: float = 2.0):
    d_inner = int(dim * proj_factor)
    hd = d_inner // n_heads
    return module({
        "w_up": ctx.param((dim, d_inner), ("embed", "rnn")),
        "w_gate": ctx.param((dim, d_inner), ("embed", "rnn")),
        "wq": ctx.param((d_inner, n_heads, hd), ("rnn", "heads", "head_dim")),
        "wk": ctx.param((d_inner, n_heads, hd), ("rnn", "heads", "head_dim")),
        "wv": ctx.param((d_inner, n_heads, hd), ("rnn", "heads", "head_dim")),
        "wi": ctx.param((d_inner, n_heads), ("rnn", "heads"), scale=0.02,
                        dtype=jnp.float32),
        "bi": ctx.param((n_heads,), ("heads",), zeros=True, dtype=jnp.float32),
        "wf": ctx.param((d_inner, n_heads), ("rnn", "heads"), scale=0.02,
                        dtype=jnp.float32),
        "bf": ctx.param((n_heads,), ("heads",), ones=True, dtype=jnp.float32),
        "gn_scale": ctx.param((d_inner,), ("rnn",), ones=True,
                              dtype=jnp.float32),
        "w_down": ctx.param((d_inner, dim), ("rnn", "embed")),
    })


def _mlstm_qkvif(p, x):
    u = x @ p["w_up"]                                   # [B,S,di]
    q = jnp.einsum("bsd,dhk->bshk", u, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", u, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", u, p["wv"])
    uf = u.astype(jnp.float32)
    log_i = uf @ p["wi"] + p["bi"]                      # [B,S,H]
    log_f = jax.nn.log_sigmoid(uf @ p["wf"] + p["bf"])  # [B,S,H]
    gate = jax.nn.silu(x @ p["w_gate"])
    return u, q, k, v, log_i, log_f, gate


def _groupnorm(p, h, n_heads: int):
    """Per-head group norm over the flattened head outputs."""
    b, s, di = h.shape
    hd = di // n_heads
    hf = h.astype(jnp.float32).reshape(b, s, n_heads, hd)
    mu = hf.mean(-1, keepdims=True)
    var = hf.var(-1, keepdims=True)
    hf = (hf - mu) * jax.lax.rsqrt(var + 1e-6)
    return (hf.reshape(b, s, di) * p["gn_scale"]).astype(h.dtype)


def mlstm_block(p, x) -> jax.Array:
    """Parallel (quadratic) exact form. x: [B, S, D]."""
    b, s, d = x.shape
    n_heads = p["wi"].shape[1]
    u, q, k, v, log_i, log_f, gate = _mlstm_qkvif(p, x)
    hd = q.shape[-1]

    F = jnp.cumsum(log_f, axis=1)                       # [B,S,H]
    # log weight of source s' at target t:  F_t - F_s' + log_i_s'  (t >= s')
    logw = F[:, :, None, :] - F[:, None, :, :] + log_i[:, None, :, :]
    tri = jnp.tril(jnp.ones((s, s), bool))
    logw = jnp.where(tri[None, :, :, None], logw, -jnp.inf)  # [B,T,S',H]
    m = jnp.max(logw, axis=2, keepdims=True)            # stabiliser [B,T,1,H]
    w = jnp.exp(logw - m)                               # [B,T,S',H]

    scores = jnp.einsum("bthk,bshk->btsh", q, k,
                        preferred_element_type=jnp.float32) * (hd ** -0.5)
    scores = scores * w
    denom = jnp.maximum(jnp.abs(scores.sum(axis=2)), jnp.exp(-m[:, :, 0, :]))
    hidden = jnp.einsum("btsh,bshk->bthk", scores.astype(v.dtype), v)
    hidden = hidden / jnp.maximum(denom[..., None], 1e-6).astype(hidden.dtype)

    hidden = hidden.reshape(b, s, -1)
    hidden = _groupnorm(p, hidden, n_heads) * gate
    return hidden @ p["w_down"]


def mlstm_block_chunked(p, x, chunk: int = 256) -> jax.Array:
    """Chunkwise-parallel mLSTM: O(S*chunk) time / O(S*chunk) memory
    instead of the quadratic form's O(S^2). Exact (same stabilised math;
    validated against mlstm_block in tests). Within-chunk: quadratic
    parallel form; across chunks: stabilised linear recurrence on the
    (C, n) state via `associative_scan` over chunk index.

    This is the TPU-native adaptation that makes prefill_32k fit HBM for
    the ssm arch (the quadratic form would need ~34 GB/device).
    """
    b, s, d = x.shape
    n_heads = p["wi"].shape[1]
    u, q, k, v, log_i, log_f, gate = _mlstm_qkvif(p, x)
    hd = q.shape[-1]
    if s % chunk:
        raise ValueError(f"seq {s} not divisible by chunk {chunk}")
    nc = s // chunk

    def cs(a, tail_shape):  # [B,S,...] -> [B,nc,L,...]
        return a.reshape((b, nc, chunk) + tail_shape)

    qc = cs(q, (n_heads, hd)) * (hd ** -0.5)
    kc = cs(k, (n_heads, hd))
    vc = cs(v, (n_heads, hd))
    lic = cs(log_i.astype(jnp.float32), (n_heads,))
    lfc = cs(log_f.astype(jnp.float32), (n_heads,))

    F = jnp.cumsum(lfc, axis=2)                        # [B,nc,L,H] within-chunk
    a_tot = F[:, :, -1, :]                             # total decay per chunk

    # --- per-chunk state contribution, stabilised by mloc ---
    # contribution weight of source t: exp(a_tot - F_t + log_i_t)
    w_src = a_tot[:, :, None, :] - F + lic             # [B,nc,L,H]
    mloc = jnp.max(w_src, axis=2)                      # [B,nc,H]
    wsrc = jnp.exp(w_src - mloc[:, :, None, :])
    kf = kc.astype(jnp.float32)
    C_con = jnp.einsum("bnlh,bnlhk,bnlhv->bnhkv", wsrc, kf,
                       vc.astype(jnp.float32))
    n_con = jnp.einsum("bnlh,bnlhk->bnhk", wsrc, kf)

    # --- associative scan over chunks: stabilised linear recurrence ---
    def combine(e1, e2):
        a1, m1, C1, n1 = e1
        a2, m2, C2, n2 = e2
        a = a1 + a2
        m = jnp.maximum(m1 + a2, m2)
        s1 = jnp.exp(m1 + a2 - m)
        s2 = jnp.exp(m2 - m)
        C = s1[..., None, None] * C1 + s2[..., None, None] * C2
        n = s1[..., None] * n1 + s2[..., None] * n2
        return a, m, C, n

    A, M, Cs, Ns = jax.lax.associative_scan(
        combine, (a_tot, mloc, C_con, n_con), axis=1)
    # state *entering* chunk j = scan result of chunk j-1 (shift right)
    pad = lambda t, fill: jnp.concatenate(
        [jnp.full_like(t[:, :1], fill), t[:, :-1]], axis=1)
    M_in = pad(M, -jnp.inf)
    C_in = pad(Cs, 0.0)
    N_in = pad(Ns, 0.0)

    # --- combine inter-chunk state with local quadratic part ---
    logw = F[:, :, :, None, :] - F[:, :, None, :, :] + lic[:, :, None, :, :]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    logw = jnp.where(tri[None, None, :, :, None], logw, -jnp.inf)
    mrow = jnp.max(logw, axis=3)                       # [B,nc,L,H]
    # total stabiliser per target position
    m_state = M_in[:, :, None, :] + F                  # [B,nc,L,H]
    m_tot = jnp.maximum(mrow, m_state)
    w_loc = jnp.exp(logw - m_tot[:, :, :, None, :])
    w_sta = jnp.exp(m_state - m_tot)

    scores = jnp.einsum("bnthk,bnshk->bntsh", qc, kc,
                        preferred_element_type=jnp.float32) * w_loc
    num_loc = jnp.einsum("bntsh,bnshv->bnthv", scores.astype(jnp.float32),
                         vc.astype(jnp.float32))
    den_loc = scores.sum(axis=3)                       # [B,nc,L,H]
    qf = qc.astype(jnp.float32)
    num_sta = jnp.einsum("bnthk,bnhkv->bnthv", qf, C_in) * \
        w_sta[..., None]
    den_sta = jnp.einsum("bnthk,bnhk->bnth", qf, N_in) * w_sta

    num = num_loc + num_sta
    den = jnp.maximum(jnp.abs(den_loc + den_sta), jnp.exp(-m_tot))
    hidden = (num / jnp.maximum(den[..., None], 1e-6)).reshape(b, s, -1)
    hidden = _groupnorm(p, hidden.astype(x.dtype), n_heads) * gate
    return hidden @ p["w_down"]


def init_mlstm_state(batch: int, dim: int, n_heads: int,
                     proj_factor: float = 2.0, abstract: bool = False):
    d_inner = int(dim * proj_factor)
    hd = d_inner // n_heads
    mk = (lambda s: jax.ShapeDtypeStruct(s, jnp.float32)) if abstract \
        else (lambda s: jnp.zeros(s, jnp.float32))
    return {"C": mk((batch, n_heads, hd, hd)),
            "n": mk((batch, n_heads, hd)),
            "m": mk((batch, n_heads))}


def mlstm_decode(p, x, state) -> Tuple[jax.Array, dict]:
    """One-token recurrent step. x: [B, 1, D]."""
    n_heads = p["wi"].shape[1]
    u, q, k, v, log_i, log_f, gate = _mlstm_qkvif(p, x)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                 # [B,H,hd]
    log_i, log_f = log_i[:, 0], log_f[:, 0]             # [B,H]
    hd = q.shape[-1]

    m_new = jnp.maximum(log_f + state["m"], log_i)
    fp = jnp.exp(log_f + state["m"] - m_new)
    ip = jnp.exp(log_i - m_new)
    kf = k.astype(jnp.float32) * (hd ** -0.5)
    C = fp[..., None, None] * state["C"] + \
        ip[..., None, None] * jnp.einsum("bhk,bhv->bhkv", kf,
                                         v.astype(jnp.float32))
    n = fp[..., None] * state["n"] + ip[..., None] * kf

    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhkv,bhk->bhv", C, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf)),
                      jnp.exp(-m_new))
    h = (num / jnp.maximum(den[..., None], 1e-6)).reshape(x.shape[0], 1, -1)
    h = _groupnorm(p, h.astype(x.dtype), n_heads) * gate
    return h @ p["w_down"], {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm_block(ctx: InitCtx, dim: int, n_heads: int,
                     ff_factor: float = 4.0 / 3.0):
    hd = dim // n_heads
    d_ff = int(dim * ff_factor)
    gates = {}
    for g in ("z", "i", "f", "o"):
        gates[f"w_{g}"] = ctx.param((dim, dim), ("embed", "rnn"))
        gates[f"r_{g}"] = ctx.param((n_heads, hd, hd),
                                    ("heads", "head_dim", "head_dim"),
                                    scale=0.5 / jnp.sqrt(hd))
        gates[f"b_{g}"] = ctx.param((dim,), ("rnn",), zeros=True,
                                    dtype=jnp.float32)
    gates.update({
        "gn_scale": ctx.param((dim,), ("rnn",), ones=True, dtype=jnp.float32),
        "w_up": ctx.param((dim, d_ff), ("embed", "ff")),
        "w_gate": ctx.param((dim, d_ff), ("embed", "ff")),
        "w_down": ctx.param((d_ff, dim), ("ff", "embed")),
    })
    return module(gates)


def _slstm_scan(p, wx, n_heads: int, state):
    """wx: dict of precomputed W·x [B,S,D] per gate; sequential over S."""
    b, s, d = wx["z"].shape
    hd = d // n_heads

    def rmat(name, h):
        # h: [B,H,hd] -> [B,H,hd] block-diagonal recurrent mixing
        return jnp.einsum("bhk,hkj->bhj", h, p[name].astype(jnp.float32))

    def step(carry, xs):
        c, n, hprev, m = carry
        z_in, i_in, f_in, o_in = xs
        hview = hprev
        z = jnp.tanh(z_in + rmat("r_z", hview).reshape(b, d) + p["b_z"])
        log_i = (i_in + rmat("r_i", hview).reshape(b, d) + p["b_i"])
        log_f = jax.nn.log_sigmoid(
            f_in + rmat("r_f", hview).reshape(b, d) + p["b_f"])
        o = jax.nn.sigmoid(o_in + rmat("r_o", hview).reshape(b, d) + p["b_o"])
        m_new = jnp.maximum(log_f + m, log_i)
        fp = jnp.exp(log_f + m - m_new)
        ip = jnp.exp(log_i - m_new)
        c_new = fp * c + ip * z
        n_new = fp * n + ip
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        return ((c_new, n_new, h_new.reshape(b, n_heads, hd), m_new),
                h_new)

    xs = tuple(jnp.moveaxis(wx[g].astype(jnp.float32), 1, 0)
               for g in ("z", "i", "f", "o"))
    carry, hs = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(hs, 0, 1), carry               # [B,S,D], state


def init_slstm_state(batch: int, dim: int, n_heads: int,
                     abstract: bool = False):
    hd = dim // n_heads
    mk = (lambda s: jax.ShapeDtypeStruct(s, jnp.float32)) if abstract \
        else (lambda s: jnp.zeros(s, jnp.float32))
    return (mk((batch, dim)), mk((batch, dim)),
            mk((batch, n_heads, hd)), mk((batch, dim)))


def slstm_block(p, x, n_heads: int) -> jax.Array:
    from .sharding import constrain_seq_replicated
    x = constrain_seq_replicated(x)   # time scan needs the full sequence
    b, s, d = x.shape
    wx = {g: x @ p[f"w_{g}"] for g in ("z", "i", "f", "o")}
    h, _ = _slstm_scan(p, wx, n_heads, init_slstm_state(b, d, n_heads))
    h = _slstm_norm(p, h, n_heads).astype(x.dtype)
    up = jax.nn.gelu(h @ p["w_up"]) * (h @ p["w_gate"])
    return up @ p["w_down"]


def slstm_decode(p, x, state, n_heads: int):
    b, _, d = x.shape
    wx = {g: x @ p[f"w_{g}"] for g in ("z", "i", "f", "o")}
    h, new_state = _slstm_scan(p, wx, n_heads, state)
    h = _slstm_norm(p, h, n_heads).astype(x.dtype)
    up = jax.nn.gelu(h @ p["w_up"]) * (h @ p["w_gate"])
    return up @ p["w_down"], new_state


def _slstm_norm(p, h, n_heads: int):
    b, s, d = h.shape
    hd = d // n_heads
    hf = h.astype(jnp.float32).reshape(b, s, n_heads, hd)
    mu = hf.mean(-1, keepdims=True)
    var = hf.var(-1, keepdims=True)
    hf = (hf - mu) * jax.lax.rsqrt(var + 1e-6)
    return hf.reshape(b, s, d) * p["gn_scale"]


def slstm_analytic_flops(batch: int, seq: int, dim: int, n_heads: int) -> float:
    """Analytic FLOPs of the scan body x trip count (roofline correction:
    XLA counts while-loop bodies once)."""
    hd = dim // n_heads
    per_step = 4 * (2 * n_heads * hd * hd) * batch   # 4 recurrent matvecs
    return float(per_step * seq)
