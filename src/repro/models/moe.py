"""Token-choice top-k Mixture-of-Experts (phi3.5-moe: 16e top-2;
grok-1: 8e top-2).

Sort-based capacity dispatch, vmapped per batch row:
  * routing/sort/gather stay *local* to the data shard (no global sort
    collectives -- the batch dim is sharded over `data`);
  * each expert processes a fixed capacity C = S*k/E * capacity_factor
    per row (static shapes, TPU requirement); overflow tokens drop, which
    the aux load-balancing loss actively discourages;
  * expert FLOPs are E*C*D*F ~ *active* params -- unlike a dense
    all-experts formulation (E/k x waste) or GShard one-hot dispatch
    einsums (~2x waste in pure dispatch matmuls), keeping the
    MODEL_FLOPS/HLO_FLOPS roofline ratio honest.

Expert weights carry the "experts" logical axis -> expert parallelism when
the arch's sharding rules map it to a mesh axis (phi3.5: 16 experts over a
16-way model axis); grok-1 (E=8) shards "ff" inside each expert instead.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .layers import InitCtx, module


def init_moe(ctx: InitCtx, dim: int, d_ff: int, n_experts: int,
             act: str = "silu_glu"):
    d = {
        "router": ctx.param((dim, n_experts), ("embed", "experts"),
                            dtype=jnp.float32),
        "wi": ctx.param((n_experts, dim, d_ff), ("experts", "embed", "ff")),
        "wo": ctx.param((n_experts, d_ff, dim), ("experts", "ff", "embed")),
    }
    if act.endswith("_glu"):
        d["wg"] = ctx.param((n_experts, dim, d_ff), ("experts", "embed", "ff"))
    return module(d)


def _dispatch_row(xt, router, top_k: int, cap: int):
    """xt: [S, D] -> (xe [E*C, D], slot, keep, gates, tok_of, aux)."""
    s, d = xt.shape
    e = router.shape[1]
    logits = xt.astype(jnp.float32) @ router                  # [S, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)         # [S, k]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Switch-style load-balance loss over this row
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)

    flat_e = gate_idx.reshape(-1)                             # [S*k]
    tok_of = jnp.tile(jnp.arange(s)[:, None], (1, top_k)).reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st = flat_e[order], tok_of[order]
    counts = jnp.bincount(flat_e, length=e)
    offsets = jnp.cumsum(counts) - counts                     # exclusive
    idx_in_e = jnp.arange(s * top_k) - offsets[se]
    keep = idx_in_e < cap
    slot = jnp.clip(se * cap + idx_in_e, 0, e * cap - 1)

    gates = gate_vals.reshape(-1)[order] * keep
    xg = jnp.where(keep[:, None], xt[st], 0.0)
    xe = jnp.zeros((e * cap, d), xt.dtype).at[slot].add(xg)
    return xe, slot, keep, gates.astype(jnp.float32), st, aux


def _combine_row(ye_flat, slot, keep, gates, st, s: int):
    """ye_flat: [E*C, D] -> y [S, D]."""
    d = ye_flat.shape[-1]
    contrib = ye_flat[slot] * (gates * keep)[:, None].astype(ye_flat.dtype)
    return jnp.zeros((s, d), ye_flat.dtype).at[st].add(contrib)


def moe(p, x, *, top_k: int = 2, capacity_factor: float = 1.25,
        act: str = "silu_glu") -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, D] -> ([B, S, D], aux_loss scalar).

    Routing groups: under sequence parallelism the S axis is sharded over
    `model`, so tokens are grouped per SP shard (GShard's groups) -- the
    sort/gather/scatter of dispatch runs entirely shard-local, and the
    only cross-device movement is the dispatch-tensor reshard at the
    expert boundary (an all-to-all pair when E divides the model axis).
    Without grouping, XLA partitions the dispatch gather over the sharded
    S axis and emits ~2 GB all-reduces per layer (measured; see
    EXPERIMENTS.md §Perf)."""
    from .sharding import moe_group_count, constrain_moe, gather_fsdp
    p = dict(p)
    for w in ("wi", "wo", "wg"):
        if w in p:
            axes = ("experts", "embed", "ff") if w != "wo" \
                else ("experts", "ff", "embed")
            p[w] = gather_fsdp(p[w], axes)
    b, s, d = x.shape
    e = p["router"].shape[1]
    g = moe_group_count(s)
    s_loc = s // g
    cap = int(max(1, round(s_loc * top_k / e * capacity_factor)))

    xg4 = x.reshape(b, g, s_loc, d)
    dispatch = jax.vmap(jax.vmap(
        lambda row: _dispatch_row(row, p["router"], top_k, cap)))
    xe, slot, keep, gates, st, aux = dispatch(xg4)
    xe = constrain_moe(xe.reshape(b, g, e, cap, d), "group")  # local pin
    xe = constrain_moe(xe, "expert")                          # a2a in

    h = jnp.einsum("bgecd,edf->bgecf", xe, p["wi"])
    if "wg" in p:
        hg = jnp.einsum("bgecd,edf->bgecf", xe, p["wg"])
        h = (jax.nn.silu(hg) * h) if act == "silu_glu" \
            else (jax.nn.gelu(hg) * h)
    else:
        h = jax.nn.gelu(h)
    ye = jnp.einsum("bgecf,efd->bgecd", h, p["wo"])           # [B,G,E,C,D]
    ye = constrain_moe(ye, "expert")
    ye = constrain_moe(ye, "group")                           # a2a out

    combine = jax.vmap(jax.vmap(_combine_row, in_axes=(0, 0, 0, 0, 0, None)),
                       in_axes=(0, 0, 0, 0, 0, None))
    y = combine(ye.reshape(b, g, e * cap, d), slot, keep, gates, st, s_loc)
    return y.reshape(b, s, d), jnp.mean(aux)
