"""Attention: GQA + RoPE + local windows + softcap + cross-attn + KV cache.

One implementation covers the whole zoo:
  * llama3 / starcoder2 / minitron / phi3.5 / grok / pixtral: causal GQA
  * gemma2: alternating local/global + attn-logit softcapping
  * recurrentgemma: local (sliding window) attention layers
  * whisper: non-causal encoder self-attn + decoder cross-attn

Decode caches are *ring buffers*: a cache of W slots holds the last W
(rotated) keys/values plus their absolute positions; full attention uses
W = S_max (ring never wraps), local attention uses W = window -- which is
what makes recurrentgemma's long_500k cell O(window) instead of O(S).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import InitCtx, apply_rope, module, softcap

NEG_INF = -2.0e38


def init_attention(ctx: InitCtx, dim: int, n_q: int, n_kv: int,
                   head_dim: int, bias: bool = False):
    d = {
        "wq": ctx.param((dim, n_q, head_dim), ("embed", "heads", "head_dim")),
        "wk": ctx.param((dim, n_kv, head_dim), ("embed", "kv_heads", "head_dim")),
        "wv": ctx.param((dim, n_kv, head_dim), ("embed", "kv_heads", "head_dim")),
        "wo": ctx.param((n_q, head_dim, dim), ("heads", "head_dim", "embed")),
    }
    if bias:
        d["bq"] = ctx.param((n_q, head_dim), ("heads", "head_dim"), zeros=True)
        d["bk"] = ctx.param((n_kv, head_dim), ("kv_heads", "head_dim"), zeros=True)
        d["bv"] = ctx.param((n_kv, head_dim), ("kv_heads", "head_dim"), zeros=True)
        d["bo"] = ctx.param((dim,), ("embed",), zeros=True)
    return module(d)


def _qkv(p, x):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return q, k, v


def _expand_kv(x, hq: int):
    """[B,T,Hkv,hd] -> [B,T,Hq,hd] (GQA group broadcast).

    Keeping scores in [B, Hq, S, T] layout lets the q-head axis carry the
    model-axis sharding even when kv_heads < mesh size (kv stays
    replicated -- it is small); see sharding.constrain_scores."""
    hkv = x.shape[2]
    if hkv == hq:
        return x
    return jnp.repeat(x, hq // hkv, axis=2)


def _gqa_scores(q, k, scale, cap):
    """q: [B,S,Hq,hd], k: [B,T,Hkv,hd] -> [B,Hq,S,T] f32 scores.

    Grouped contraction (no materialised K expansion): the [B,T,Hq,hd]
    repeat would read 4x the cache bytes at decode."""
    from .sharding import constrain_scores
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, s, hkv, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    scores = softcap(scores, cap).reshape(b, hq, s, k.shape[1])
    return constrain_scores(scores, kv_heads=hkv)


def _gqa_out(p, scores, v):
    """softmaxed scores [B,Hq,S,T], v [B,T,Hkv,hd] -> [B,S,D]."""
    b, hq, s, t = scores.shape
    hkv = v.shape[2]
    g = hq // hkv
    sg = scores.reshape(b, hkv, g, s, t)
    ctx = jnp.einsum("bkgst,btkh->bskgh", sg.astype(v.dtype), v)
    ctx = ctx.reshape(b, s, hq, v.shape[-1])
    out = jnp.einsum("bshk,hkd->bsd", ctx, p["wo"])
    if "bo" in p:
        out = out + p["bo"]
    return out


# Query-chunk size for the chunked (flash-style) full-sequence path. Each
# chunk materialises a [B, H, CHUNK, T] score block; the output buffer's
# dynamic_update_slice chain forces sequential scheduling so XLA reuses
# one block's buffers across chunks -- peak attention temp drops from
# O(S^2) to O(CHUNK*S) per layer (e.g. llama3 train_4k: 8.6 GB -> 0.6 GB
# per device-layer). Chunks are python-unrolled (not lax.scan) so HLO cost
# analysis counts every chunk -- required by the roofline methodology.
ATTN_CHUNK = 512


# KV-chunk size for the online-softmax (flash-style) accumulation below.
KV_CHUNK = 2048


def _attn_block(p, q, k, v, qpos, kpos, *, scale, cap, causal, window,
                is_cross):
    """One q-chunk: q [B,Sc,Hq,hd] vs full k/v [B,T,Hkv,hd] -> [B,Sc,D].

    KV-chunked online softmax: score blocks are [B, Hq, Sc, KV_CHUNK]
    instead of [B, Hq, Sc, T] -- exact (running max/denominator rescaling,
    the flash-attention recurrence) and compatible with sequence-parallel
    q (the T axis is chunked, not the sharded S axis). Chunks are
    python-unrolled so HLO cost analysis counts every block."""
    t = k.shape[1]
    hq = q.shape[2]
    kx = _expand_kv(k, hq)
    vx = _expand_kv(v, hq)

    def block_scores(k_blk, kp_blk):
        s = jnp.einsum("bshk,bthk->bhst", q, k_blk,
                       preferred_element_type=jnp.float32) * scale
        s = softcap(s, cap)
        if not is_cross:
            qp = qpos[:, None, :, None]
            kp = kp_blk[:, None, None, :]
            ok = jnp.ones((1, 1) + s.shape[-2:], bool)
            if causal:
                ok = ok & (kp <= qp)
            if window:
                ok = ok & (qp - kp < window)
            s = jnp.where(ok, s, NEG_INF)
        return s

    from .sharding import attn_exact_mode, constrain_scores
    if t <= KV_CHUNK or t % KV_CHUNK or attn_exact_mode():
        # exact single-block path: used for short T, and by the dry-run's
        # depth-1/2 cost probes (compile-only -- no memory is allocated,
        # and the HLO counts every attention FLOP/byte exactly, which an
        # inner scan would hide)
        scores = constrain_scores(block_scores(kx, kpos))
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhst,bthk->bshk", probs.astype(vx.dtype), vx)
        return _out_proj(p, ctx)

    # online-softmax over KV chunks via lax.scan: the while-loop body
    # guarantees ONE chunk's buffers are live at a time. (A python-unrolled
    # loop chained through optimization_barrier does NOT work: XLA CPU
    # strips the barriers and schedules all 16 chunk blocks concurrently
    # -- measured 34 GB live at prefill_32k.)
    b, sc = q.shape[0], q.shape[1]
    nc = t // KV_CHUNK
    hd_v = vx.shape[-1]
    kxt = jnp.moveaxis(kx.reshape(b, nc, KV_CHUNK, hq, -1), 1, 0)
    vxt = jnp.moveaxis(vx.reshape(b, nc, KV_CHUNK, hq, hd_v), 1, 0)
    kpt = jnp.moveaxis(kpos.reshape(b, nc, KV_CHUNK), 1, 0)

    def body(carry, inp):
        m, l, acc = carry
        k_blk, v_blk, kp_blk = inp
        s = block_scores(k_blk, kp_blk)                 # [B,H,Sc,Tc]
        m_new = jnp.maximum(m, jnp.maximum(s.max(axis=-1), -1e30))
        r = jnp.exp(m - m_new)
        pexp = jnp.exp(s - m_new[..., None])            # -inf -> 0
        l = l * r + pexp.sum(axis=-1)
        blk = jnp.einsum("bhst,bthk->bshk", pexp.astype(v_blk.dtype),
                         v_blk).astype(jnp.float32)
        acc = acc * jnp.moveaxis(r, 1, 2)[..., None] + blk
        return (m_new, l, acc), None

    init = (jnp.full((b, hq, sc), -1e30, jnp.float32),
            jnp.zeros((b, hq, sc), jnp.float32),
            jnp.zeros((b, sc, hq, hd_v), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init, (kxt, vxt, kpt))
    ctx = acc / jnp.maximum(jnp.moveaxis(l, 1, 2)[..., None], 1e-30)
    return _out_proj(p, ctx.astype(vx.dtype))


def _out_proj(p, ctx):
    out = jnp.einsum("bshk,hkd->bsd", ctx, p["wo"])
    if "bo" in p:
        out = out + p["bo"]
    return out


def attention(
    p, x, positions, *,
    theta: float = 1e4,
    causal: bool = True,
    window: Optional[int] = None,
    attn_softcap: float = 0.0,
    use_rope: bool = True,
    kv_x: Optional[jax.Array] = None,      # cross-attention source
    q_scale: Optional[float] = None,
    chunk: Optional[int] = None,
) -> jax.Array:
    """Full-sequence attention (train / prefill). x: [B,S,D]."""
    if kv_x is None:
        q, k, v = _qkv(p, x)
        kv_pos = positions
    else:  # cross-attn: q from x, k/v from encoder output
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"])
        if "bq" in p:
            q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
        kv_pos = jnp.broadcast_to(
            jnp.arange(kv_x.shape[1], dtype=jnp.int32)[None],
            kv_x.shape[:2])
    hd = q.shape[-1]
    if use_rope and kv_x is None:
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, kv_pos, theta)
    scale = q_scale if q_scale is not None else hd ** -0.5

    from .sharding import sp_active
    b, s, hq, _ = q.shape
    chunk = chunk or ATTN_CHUNK
    kw = dict(scale=scale, cap=attn_softcap, causal=causal, window=window,
              is_cross=kv_x is not None)
    if s <= chunk or s % chunk or sp_active(s):
        # under sequence parallelism the scores' S dim is already sharded
        # 16-way -- one unchunked block is small and avoids cross-shard
        # slicing
        return _attn_block(p, q, k, v, positions, kv_pos, **kw)

    d_out = p["wo"].shape[-1]
    out = jnp.zeros((b, s, d_out), x.dtype)
    for c0 in range(0, s, chunk):
        piece = _attn_block(p, q[:, c0:c0 + chunk],
                            k, v, positions[:, c0:c0 + chunk], kv_pos, **kw)
        out = jax.lax.dynamic_update_slice(out, piece.astype(out.dtype),
                                           (0, c0, 0))
    return out


# ---------------------------------------------------------------------------
# Decode with ring-buffer KV cache
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class KVCacheSpec:
    slots: int          # W: S_max for full attention, window for local
    n_kv: int
    head_dim: int


def init_kv_cache(batch: int, spec: KVCacheSpec, dtype=jnp.bfloat16,
                  abstract: bool = False):
    mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract else \
         (lambda s, d: jnp.zeros(s, d) if d != jnp.int32
          else jnp.full(s, -1, d))
    return {
        "k": mk((batch, spec.slots, spec.n_kv, spec.head_dim), dtype),
        "v": mk((batch, spec.slots, spec.n_kv, spec.head_dim), dtype),
        "pos": mk((batch, spec.slots), jnp.int32),   # -1 = empty slot
    }


def attention_decode(
    p, x, cache, pos, *,
    theta: float = 1e4,
    window: Optional[int] = None,
    attn_softcap: float = 0.0,
    use_rope: bool = True,
    q_scale: Optional[float] = None,
) -> Tuple[jax.Array, dict]:
    """One-token decode. x: [B,1,D]; pos: [] int32 (shared across batch).

    Keys are stored rotated at their absolute position; RoPE's relative
    property makes q.k correct without re-rotation at read time.
    """
    b = x.shape[0]
    w = cache["k"].shape[1]
    q, k, v = _qkv(p, x)
    posb = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)
    if use_rope:
        q = apply_rope(q, posb, theta)
        k = apply_rope(k, posb, theta)

    slot = (pos % w).astype(jnp.int32)
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))
    cpos = jax.lax.dynamic_update_slice(cache["pos"], posb, (0, slot))

    hd = q.shape[-1]
    scale = q_scale if q_scale is not None else hd ** -0.5
    scores = _gqa_scores(q, ck, scale, attn_softcap)   # [B,Hq,1,W]
    kp = cpos[:, None, None, :]
    ok = (kp >= 0) & (kp <= pos)
    if window:
        ok = ok & (pos - kp < window)
    scores = jnp.where(ok, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(p, probs, cv)
    return out, {"k": ck, "v": cv, "pos": cpos}


def init_cross_cache(enc_kv: Tuple[jax.Array, jax.Array]):
    """Whisper decoder: precomputed encoder K/V act as a static cache."""
    return {"k": enc_kv[0], "v": enc_kv[1]}


def cross_attention_decode(p, x, cross_cache, q_scale=None):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    k, v = cross_cache["k"], cross_cache["v"]
    hd = q.shape[-1]
    scale = q_scale if q_scale is not None else hd ** -0.5
    scores = _gqa_scores(q, k, scale, 0.0)
    probs = jax.nn.softmax(scores, axis=-1)
    return _gqa_out(p, probs, v)


def precompute_cross_kv(p, enc_out):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    return k, v
