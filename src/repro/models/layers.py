"""Minimal functional module system + common layers.

Design (MaxText-style, no flax):
  * a module is an `init_*(ctx, ...) -> (params, specs)` pair of pytrees --
    `params` holds arrays (or ShapeDtypeStructs in abstract mode, used by
    the dry-run so no host memory is ever allocated for 314B-param models),
    `specs` holds *logical* axis-name tuples per leaf;
  * `apply_*` functions are pure;
  * logical axes map to mesh axes through per-arch sharding rules
    (models/sharding.py), giving DP/FSDP/TP/EP without touching model code.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class InitCtx:
    """Carries RNG + dtype; abstract=True yields ShapeDtypeStructs."""
    key: Optional[jax.Array]
    param_dtype: Any = jnp.bfloat16
    abstract: bool = False

    def split(self) -> "InitCtx":
        if self.abstract:
            return InitCtx(None, self.param_dtype, True)
        self.key, sub = jax.random.split(self.key)
        return InitCtx(sub, self.param_dtype, False)

    def param(self, shape: Sequence[int], axes: Tuple[Optional[str], ...],
              scale: Optional[float] = None, zeros: bool = False,
              ones: bool = False, dtype: Any = None):
        dtype = dtype or self.param_dtype
        assert len(shape) == len(axes), (shape, axes)
        if self.abstract:
            return jax.ShapeDtypeStruct(tuple(shape), dtype), axes
        sub = self.split().key
        if zeros:
            v = jnp.zeros(shape, dtype)
        elif ones:
            v = jnp.ones(shape, dtype)
        else:
            if scale is None:
                fan_in = shape[0] if len(shape) else 1
                scale = 1.0 / np.sqrt(max(1, fan_in))
            v = (jax.random.truncated_normal(sub, -2.0, 2.0, tuple(shape),
                                             jnp.float32) * scale).astype(dtype)
        return v, axes


def module(d: dict) -> Tuple[dict, dict]:
    """Split a dict of (leaf, axes) / (sub_params, sub_specs) into trees."""
    params, specs = {}, {}
    for k, v in d.items():
        if isinstance(v, tuple) and len(v) == 2 and isinstance(v[1], tuple) \
                and all(isinstance(a, (str, type(None))) for a in v[1]):
            params[k], specs[k] = v
        else:  # nested (params, specs) pair
            params[k], specs[k] = v
    return params, specs


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(ctx: InitCtx, dim: int):
    return module({"scale": ctx.param((dim,), ("embed",), ones=True,
                                      dtype=jnp.float32)})


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * p["scale"]).astype(dt)


def init_layernorm(ctx: InitCtx, dim: int):
    return module({
        "scale": ctx.param((dim,), ("embed",), ones=True, dtype=jnp.float32),
        "bias": ctx.param((dim,), ("embed",), zeros=True, dtype=jnp.float32),
    })


def layernorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * p["scale"]
            + p["bias"]).astype(dt)


def apply_norm(kind: str, p, x):
    return rmsnorm(p, x) if kind == "rmsnorm" else layernorm(p, x)


def init_norm(ctx: InitCtx, kind: str, dim: int):
    return init_rmsnorm(ctx, dim) if kind == "rmsnorm" \
        else init_layernorm(ctx, dim)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(ctx: InitCtx, vocab: int, dim: int):
    return module({"table": ctx.param((vocab, dim), ("vocab", "embed"),
                                      scale=1.0)})


def embed(p, tokens, dim: int):
    # scale by sqrt(dim) as gemma/whisper do not; keep plain lookup, models
    # that need scaling do it at the call site.
    return p["table"][tokens]


def unembed_logits(p, x):
    """Tied unembedding: [.., D] @ [V, D]^T -> [.., V]."""
    return jnp.einsum("...d,vd->...v", x, p["table"])


def init_unembed(ctx: InitCtx, vocab: int, dim: int):
    return module({"w": ctx.param((dim, vocab), ("embed", "vocab"))})


# ---------------------------------------------------------------------------
# Dense / MLP
# ---------------------------------------------------------------------------

def init_dense(ctx: InitCtx, d_in: int, d_out: int,
               axes=("embed", "ff"), bias: bool = False):
    d = {"w": ctx.param((d_in, d_out), axes)}
    if bias:
        d["b"] = ctx.param((d_out,), (axes[1],), zeros=True)
    return module(d)


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def init_mlp(ctx: InitCtx, dim: int, d_ff: int, act: str, bias: bool = False):
    """act: silu_glu (llama) | gelu_glu (gemma) | gelu (gpt/whisper)."""
    mods = {
        "wi": init_dense(ctx, dim, d_ff, ("embed", "ff"), bias=bias),
        "wo": init_dense(ctx, d_ff, dim, ("ff", "embed"), bias=bias),
    }
    if act.endswith("_glu"):
        mods["wg"] = init_dense(ctx, dim, d_ff, ("embed", "ff"), bias=bias)
    return module(mods)


def mlp(p, x, act: str):
    h = dense(p["wi"], x)
    if act == "silu_glu":
        h = jax.nn.silu(dense(p["wg"], x)) * h
    elif act == "gelu_glu":
        h = jax.nn.gelu(dense(p["wg"], x)) * h
    elif act == "relu2":  # nemotron/minitron squared ReLU
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    return dense(p["wo"], h)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, hd]; positions: [B, S] absolute token positions."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, hd/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """gemma2 logit soft-capping: cap * tanh(x / cap)."""
    if cap and cap > 0:
        return cap * jnp.tanh(x.astype(jnp.float32) / cap)
    return x
