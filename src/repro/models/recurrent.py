"""RG-LRU recurrent block (Griffin / recurrentgemma).

Block: x -> [gate branch: GeLU(W_y x)] ⊙ [main: W_x x -> causal depthwise
conv1d(w=4) -> RG-LRU] -> W_o -> out.

RG-LRU (Real-Gated Linear Recurrent Unit):
    r_t = sigmoid(W_a u_t + b_a)                  (recurrence gate)
    i_t = sigmoid(W_i u_t + b_i)                  (input gate)
    log a_t = -c * softplus(Lambda) * r_t         (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t^2) ⊙ (i_t ⊙ u_t)

Train/prefill uses `jax.lax.associative_scan` (log-depth, fully counted by
HLO cost analysis -- unlike `lax.scan` whose while-body is counted once);
decode is a single fused step with O(D_rnn) state. This O(1)-in-seq state
(+ the window-sized local-attention ring caches) is what makes the
long_500k cell runnable for this arch.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .layers import InitCtx, module

RGLRU_C = 8.0


def init_rglru_block(ctx: InitCtx, dim: int, d_rnn: int, conv_width: int = 4):
    return module({
        "wy": ctx.param((dim, d_rnn), ("embed", "rnn")),      # gate branch
        "wx": ctx.param((dim, d_rnn), ("embed", "rnn")),      # main branch
        "conv_w": ctx.param((conv_width, d_rnn), (None, "rnn"),
                            scale=1.0 / conv_width),
        "conv_b": ctx.param((d_rnn,), ("rnn",), zeros=True),
        "wa": ctx.param((d_rnn, d_rnn), ("rnn", "rnn_out")),  # recurrence gate
        "ba": ctx.param((d_rnn,), ("rnn",), zeros=True),
        "wi": ctx.param((d_rnn, d_rnn), ("rnn", "rnn_out")),  # input gate
        "bi": ctx.param((d_rnn,), ("rnn",), zeros=True),
        "lam": ctx.param((d_rnn,), ("rnn",), scale=1.0, dtype=jnp.float32),
        "wo": ctx.param((d_rnn, dim), ("rnn", "embed")),
    })


def _gates(p, u):
    r = jax.nn.sigmoid(u @ p["wa"] + p["ba"])
    i = jax.nn.sigmoid(u @ p["wi"] + p["bi"])
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"]) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) \
        * (i * u).astype(jnp.float32)
    return a, b


def _conv_full(p, u):
    """Causal depthwise conv over [B, S, D_rnn]."""
    w = p["conv_w"]
    width = w.shape[0]
    out = jnp.zeros_like(u)
    for j in range(width):
        shifted = jnp.pad(u, ((0, 0), (width - 1 - j, 0), (0, 0)))[
            :, :u.shape[1], :]
        out = out + shifted * w[j]
    return out + p["conv_b"]


def rglru_block(p, x) -> jax.Array:
    """Full-sequence forward. x: [B, S, D] -> [B, S, D].

    RNN-state activations shard on the *feature* dim (the time scan is
    elementwise in R, so the associative scan stays device-local)."""
    from .sharding import constrain_feature
    y = jax.nn.gelu(x @ p["wy"])
    u = constrain_feature(_conv_full(p, x @ p["wx"]))
    a, b = _gates(p, u)
    a, b = constrain_feature(a), constrain_feature(b)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return ((y.astype(jnp.float32) * h).astype(x.dtype) @ p["wo"])


def init_rglru_state(batch: int, d_rnn: int, conv_width: int = 4,
                     abstract: bool = False):
    mk = (lambda s: jax.ShapeDtypeStruct(s, jnp.float32)) if abstract \
        else (lambda s: jnp.zeros(s, jnp.float32))
    return {"h": mk((batch, d_rnn)),
            "conv": mk((batch, conv_width - 1, d_rnn))}


def rglru_decode(p, x, state) -> Tuple[jax.Array, dict]:
    """One-token step. x: [B, 1, D] -> ([B, 1, D], new state)."""
    y = jax.nn.gelu(x @ p["wy"])                      # [B, 1, R]
    u_raw = (x @ p["wx"])[:, 0, :].astype(jnp.float32)  # [B, R]
    w = p["conv_w"]
    width = w.shape[0]
    hist = jnp.concatenate([state["conv"], u_raw[:, None, :]], axis=1)
    u = jnp.einsum("bwr,wr->br", hist, w.astype(hist.dtype)) + p["conv_b"]
    a, b = _gates(p, u)
    h = a * state["h"] + b
    out = (y[:, 0, :].astype(jnp.float32) * h).astype(x.dtype) @ p["wo"]
    return out[:, None, :], {"h": h, "conv": hist[:, 1:, :]}
