"""Serving path: KV/state cache init, prefill, single-token decode.

Cache layout mirrors the stacked param layout: cache["p<j>"] has a leading
stack_count dim per period position, so scan and unrolled execution share
one representation.

Cache kinds per layer:
  attn   -> ring KV cache, W = s_max slots
  local  -> ring KV cache, W = min(window, s_max)  (O(window) for long ctx)
  xattn  -> ring KV cache + static cross-attn K/V from the encoder
  rglru  -> h state [B, d_rnn] + conv tail
  mlstm  -> (C, n, m) matrix-memory state -- O(1) in sequence length
  slstm  -> (c, n, h, m) scalar state
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import attention as attn_lib
from . import recurrent as rec_lib
from . import xlstm as xlstm_lib
from .layers import apply_norm, mlp, softcap, unembed_logits
from .transformer import tree_slice, _encode


def _layer_cache(cfg: ModelConfig, kind: str, batch: int, s_max: int,
                 abstract: bool, dtype=jnp.bfloat16):
    if kind in ("attn", "xattn"):
        slots = s_max
    elif kind == "local":
        slots = min(cfg.window, s_max)
    if kind in ("attn", "local", "xattn"):
        c = attn_lib.init_kv_cache(
            batch, attn_lib.KVCacheSpec(slots, cfg.num_kv_heads,
                                        cfg.head_dim),
            dtype=dtype, abstract=abstract)
        if kind == "xattn":
            shape = (batch, cfg.enc_seq, cfg.num_kv_heads, cfg.head_dim)
            mk = (lambda: jax.ShapeDtypeStruct(shape, dtype)) if abstract \
                else (lambda: jnp.zeros(shape, dtype))
            c["xk"], c["xv"] = mk(), mk()
        return c
    if kind == "rglru":
        return rec_lib.init_rglru_state(batch, cfg.d_rnn or cfg.d_model,
                                        cfg.conv_width, abstract=abstract)
    if kind == "mlstm":
        return xlstm_lib.init_mlstm_state(batch, cfg.d_model, cfg.num_heads,
                                          cfg.mlstm_proj_factor,
                                          abstract=abstract)
    if kind == "slstm":
        return xlstm_lib.init_slstm_state(batch, cfg.d_model, cfg.num_heads,
                                          abstract=abstract)
    raise ValueError(kind)


def _stack_tree(tree, count: int, abstract: bool):
    if abstract:
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((count,) + s.shape, s.dtype), tree)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (count,) + a.shape), tree)


def init_cache(cfg: ModelConfig, batch: int, s_max: int,
               abstract: bool = False, dtype=jnp.bfloat16):
    cache: Dict[str, Any] = {}
    for j, kind in enumerate(cfg.stack_period):
        one = _layer_cache(cfg, kind, batch, s_max, abstract, dtype)
        cache[f"p{j}"] = _stack_tree(one, cfg.stack_count, abstract)
    for j, kind in enumerate(cfg.tail_kinds):
        cache[f"t{j}"] = _layer_cache(cfg, kind, batch, s_max, abstract,
                                      dtype)
    return cache


def decode_layer(cfg: ModelConfig, kind: str, p, x, cache, pos):
    """x: [B,1,D] -> (x, new_cache)."""
    h = apply_norm(cfg.norm, p["norm1"], x)
    if kind in ("attn", "local", "xattn"):
        kv = {k: cache[k] for k in ("k", "v", "pos")}
        core, kv = attn_lib.attention_decode(
            p["attn"], h, kv, pos,
            theta=cfg.rope_theta,
            window=cfg.window if kind == "local" else None,
            attn_softcap=cfg.attn_softcap,
            use_rope=cfg.pos_kind == "rope",
            q_scale=cfg.q_scale)
        new_cache = dict(cache, **kv)
    elif kind == "rglru":
        core, new_cache = rec_lib.rglru_decode(p["rnn"], h, cache)
    elif kind == "mlstm":
        core, new_cache = xlstm_lib.mlstm_decode(p["cell"], h, cache)
    elif kind == "slstm":
        core, new_cache = xlstm_lib.slstm_decode(p["cell"], h, cache,
                                                 cfg.num_heads)
    if cfg.post_norm:
        core = apply_norm(cfg.norm, p["norm1_post"], core)
    x = x + core

    if kind == "xattn":
        hx = apply_norm(cfg.norm, p["normx"], x)
        x = x + attn_lib.cross_attention_decode(
            p["cross"], hx, {"k": cache["xk"], "v": cache["xv"]})

    if "norm2" in p:
        h2 = apply_norm(cfg.norm, p["norm2"], x)
        if cfg.n_experts and "moe" in p:
            from . import moe as moe_lib
            ff, _ = moe_lib.moe(p["moe"], h2, top_k=cfg.top_k,
                                capacity_factor=cfg.capacity_factor,
                                act=cfg.mlp_act)
        else:
            ff = mlp(p["mlp"], h2, cfg.mlp_act)
        if cfg.post_norm:
            ff = apply_norm(cfg.norm, p["norm2_post"], ff)
        x = x + ff
    return x, new_cache


def decode_step(cfg: ModelConfig, params, cache, token, pos,
                scan: Optional[bool] = None):
    """One decode step. token: [B,1] int32, pos: [] int32.

    -> (logits [B,V], hidden [B,D] (RAG query vector), new cache)
    """
    scan = cfg.scan_layers if scan is None else scan
    emb = params["embed"]["table"]
    x = emb[token]
    if cfg.emb_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.pos_kind == "learned":
        x = x + params["pos_emb"]["table"][pos][None, None].astype(x.dtype)

    kinds = cfg.stack_period
    body_cache = {k: v for k, v in cache.items() if k.startswith("p")}

    def period_body(x, period_params, period_cache):
        new_cache = {}
        for j, kind in enumerate(kinds):
            x, new_cache[f"p{j}"] = decode_layer(
                cfg, kind, period_params[f"p{j}"], x, period_cache[f"p{j}"],
                pos)
        return x, new_cache

    if scan and cfg.stack_count > 1:
        def body(x, pc):
            pp, pcache = pc
            x, nc = period_body(x, pp, pcache)
            return x, nc
        x, new_cache = jax.lax.scan(body, x, (params["stack"], body_cache))
    else:
        ncs = []
        for r in range(cfg.stack_count):
            x, nc = period_body(x, tree_slice(params["stack"], r),
                                tree_slice(body_cache, r))
            ncs.append(nc)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
    for j, kind in enumerate(cfg.tail_kinds):
        x, new_cache[f"t{j}"] = decode_layer(
            cfg, kind, params["tail"][f"t{j}"], x, cache[f"t{j}"], pos)

    x = apply_norm(cfg.norm, params["final_norm"], x)
    hidden = x[:, 0, :]
    if cfg.tie_embeddings:
        logits = unembed_logits(params["embed"], x)
    else:
        logits = x @ params["unembed"]["w"]
    logits = softcap(logits, cfg.logit_softcap)
    return logits[:, 0, :], hidden, new_cache


def prefill(cfg: ModelConfig, params, batch, s_max: int,
            scan: Optional[bool] = None):
    """Run the full prompt, producing a primed cache + last-position logits.

    Implemented as full-sequence forward (efficient, parallel) followed by
    cache construction from the per-layer K/V -- for attention layers we
    recompute K/V into the ring layout; recurrent layers replay their
    final state. For simplicity and static shapes the prompt must be
    <= s_max.
    """
    from .transformer import forward  # local import to avoid cycle
    logits, _, hidden, _ = forward(cfg, params, batch, scan=scan,
                                   remat=False)
    # Prefill cache fill: run decode_layer over positions via scan per
    # layer would be O(S) sequential; instead attention caches are filled
    # directly from projected K/V of the parallel forward.
    cache = fill_cache_from_forward(cfg, params, batch, s_max)
    return logits[:, -1, :], hidden[:, -1, :], cache


def fill_cache_from_forward(cfg: ModelConfig, params, batch, s_max: int):
    """Project K/V for every attention layer in parallel and scatter into
    ring caches; recompute recurrent final states with their parallel
    forms. Exactness is validated against step-by-step decode in tests."""
    from .transformer import embed_inputs, apply_layer
    x, positions, enc_out, _ = embed_inputs(cfg, params, batch)
    b, s, _ = x.shape
    kinds = cfg.stack_period
    cache = init_cache(cfg, b, s_max, abstract=False,
                       dtype=x.dtype)

    new_cache = {f"p{j}": [] for j in range(len(kinds))}
    for r in range(cfg.stack_count):
        for j, kind in enumerate(kinds):
            p = tree_slice(params["stack"][f"p{j}"], r)
            layer_cache = tree_slice(cache[f"p{j}"], r)
            new_cache[f"p{j}"].append(_fill_one(
                cfg, kind, p, layer_cache, x, positions, enc_out))
            x, _ = apply_layer(cfg, kind, p, x, positions, enc_out)
    out = {k: jax.tree.map(lambda *xs: jnp.stack(xs), *v)
           for k, v in new_cache.items()}
    for j, kind in enumerate(cfg.tail_kinds):
        p = params["tail"][f"t{j}"]
        out[f"t{j}"] = _fill_one(cfg, kind, p, cache[f"t{j}"], x,
                                 positions, enc_out)
        x, _ = apply_layer(cfg, kind, p, x, positions, enc_out)
    return out


def _write_ring(kv_cache, k, v, pos_vec, b, s):
    w = kv_cache["k"].shape[1]
    keep = s if s <= w else w
    slots = (pos_vec[-keep:] % w)
    ck = kv_cache["k"].at[:, slots].set(k[:, -keep:].astype(
        kv_cache["k"].dtype))
    cv = kv_cache["v"].at[:, slots].set(v[:, -keep:].astype(
        kv_cache["v"].dtype))
    cp = kv_cache["pos"].at[:, slots].set(
        jnp.broadcast_to(pos_vec[None, -keep:], (b, keep)))
    return dict(kv_cache, k=ck, v=cv, pos=cp)


def _fill_one(cfg, kind, p, layer_cache, x, positions, enc_out):
    """Fill one layer's decode cache from the parallel-forward inputs."""
    b, s, _ = x.shape
    h = apply_norm(cfg.norm, p["norm1"], x)
    if kind in ("attn", "local", "xattn"):
        k = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wv"])
        if "bk" in p["attn"]:
            k, v = k + p["attn"]["bk"], v + p["attn"]["bv"]
        if cfg.pos_kind == "rope":
            k = attn_lib.apply_rope(k, positions, cfg.rope_theta)
        layer_cache = _write_ring(layer_cache, k, v, positions[0], b, s)
        if kind == "xattn":
            xk, xv = attn_lib.precompute_cross_kv(p["cross"], enc_out)
            layer_cache["xk"] = xk.astype(layer_cache["xk"].dtype)
            layer_cache["xv"] = xv.astype(layer_cache["xv"].dtype)
        return layer_cache
    if kind == "rglru":
        return _rglru_final_state(p["rnn"], h)
    if kind == "mlstm":
        return _mlstm_final_state(p["cell"], h, cfg)
    wx = {g: h @ p["cell"][f"w_{g}"] for g in "zifo"}
    _, state = xlstm_lib._slstm_scan(
        p["cell"], wx, cfg.num_heads,
        xlstm_lib.init_slstm_state(b, cfg.d_model, cfg.num_heads))
    return state


def _rglru_final_state(p, x):
    b = x.shape[0]
    u = rec_lib._conv_full(p, x @ p["wx"])
    a, bb = rec_lib._gates(p, u)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2
    aa, hh = jax.lax.associative_scan(combine, (a, bb), axis=1)
    raw = x @ p["wx"]
    w = p["conv_w"].shape[0]
    conv_tail = jnp.pad(raw.astype(jnp.float32),
                        ((0, 0), (w - 1, 0), (0, 0)))[:, -(w - 1):, :] \
        if raw.shape[1] >= 1 else jnp.zeros((b, w - 1, raw.shape[-1]))
    return {"h": hh[:, -1, :], "conv": conv_tail}


def _mlstm_final_state(p, x, cfg: ModelConfig):
    u, q, k, v, log_i, log_f, gate = xlstm_lib._mlstm_qkvif(p, x)
    hd = q.shape[-1]
    F = jnp.cumsum(log_f, axis=1)
    w_src = F[:, -1:, :] - F + log_i                    # [B,S,H]
    m = jnp.max(w_src, axis=1)                          # [B,H]
    w = jnp.exp(w_src - m[:, None, :])
    kf = k.astype(jnp.float32) * (hd ** -0.5)
    C = jnp.einsum("bsh,bshk,bshv->bhkv", w, kf, v.astype(jnp.float32))
    n = jnp.einsum("bsh,bshk->bhk", w, kf)
    return {"C": C, "n": n, "m": m}
