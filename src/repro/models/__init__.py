"""Model zoo: composable JAX layers covering all 10 assigned archs."""
from . import (attention, decode, layers, moe, recurrent, sharding,
               transformer, xlstm)
from .transformer import forward, init_model, loss_fn
from .decode import decode_step, init_cache, prefill

__all__ = ["attention", "decode", "layers", "moe", "recurrent", "sharding",
           "transformer", "xlstm", "forward", "init_model", "loss_fn",
           "decode_step", "init_cache", "prefill"]
