"""Fused int8 scalar-quantized IVF scan + running top-k Pallas TPU kernel.

Same contract and grid structure as kernels/ivf_scan.py (one grid step per
probed partition, scalar-prefetched partition ids, VMEM running top-k),
but the partition payload streamed from HBM is the *int8 code tier* -- 4x
fewer bytes on the scan's bandwidth-bound axis -- and the distance
accumulation itself runs in the INTEGER domain on the MXU:

    queries are folded ONCE per scan (core/quantize.fold_queries) into a
    stacked two-term int8 encoding (primary + rounding residual)
        q_i8  = [q1; q2]                            [2Q, d] int8
        alpha = [alpha1; alpha2]                    [2Q] f32
        beta  = rank-1 epilogue constants           [Q] f32
    the kernel accumulates  acc = q_i8 . c_i8  with
        preferred_element_type=jnp.int32   (the int8 MXU path)
    and applies the affine (lo, scale) correction as the epilogue
        dots ~= (alpha * acc)[:Q] + (alpha * acc)[Q:] + beta.
    The residual term costs one extra query row in the bandwidth-bound
    matmul and buys ~2^-15 relative query precision, so candidate
    selection matches the dequantize-then-f32 scan.

The int8 codes are never dequantized on the matmul path -- the 4x
bandwidth win of the code tier becomes a FLOP win too. For l2 the
per-row constant ||decode(c)||^2 comes from the precomputed
IVFIndex.code_norms tier (an extra [1, p_max] f32 block per partition);
when the caller has no norms resident (paged frame scans) the kernel
falls back to the decode-and-reduce expression in-register, which is
bitwise-identical to how code_norms was precomputed.

This is the *candidate* stage of the paper's low-memory design: callers
over-fetch k' = rerank_factor * k rows here and rerank them at float32
(core/executor.py), so the `ids` input is typically the flat row index
(partition * p_max + slot) rather than the asset id -- whatever the
caller needs to gather rerank rows. MQO selection masks and fused
attribute predicates behave exactly as in ivf_scan. The query-side
quantization error only moves *candidate selection*, never reported
scores (the f32 rerank contract).

On a real TPU the int8 tile minimum is (32, 128); p_max must be a
multiple of 32 when running compiled (core/types.effective_pad_to bumps
the build-time padding automatically; sq_scan_topk asserts it so a
mis-padded layout fails loud instead of mis-compiling). The folded query
block is int8 too, so compiled runs pad Q up to the 32-sublane minimum
internally and slice the outputs back. Interpret mode (anything that is
not a TPU backend) has no such constraint.

Frame-indirect entry (storage/pager.py): `codes` may be the pager's
frame *pool* [F, p_max, d] rather than the full code tier, with
`part_ids` carrying frame indices -- the kernel is layout-agnostic, it
streams whichever blocks the scalar-prefetched probe list names, so the
paged and resident scans share this one implementation.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core import quantize
from .ivf_scan import MASKED, _merge_topk, default_interpret

# Minimum second-to-last tile dimension for int8 operands on real TPU
# hardware (the (32, 128) tile); interpret mode is unconstrained.
INT8_SUBLANE_MIN = 32


def _sq_scan_kernel(part_ids_ref,              # scalar prefetch [n]
                    *refs,
                    k_out: int, metric: str, mqo: bool, attr_filter,
                    has_norms: bool):
    refs = list(refs)
    q_ref, alpha_ref, beta_ref, lo_ref, scale_ref, c_ref, valid_ref, \
        ids_ref, qsel_ref = refs[:9]
    rest = refs[9:]
    norms_ref = rest.pop(0) if has_norms else None
    attrs_ref = rest.pop(0) if attr_filter is not None else None
    out_s_ref, out_i_ref, run_s, run_i = rest
    i = pl.program_id(0)
    n = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        run_s[...] = jnp.full_like(run_s, MASKED)
        run_i[...] = jnp.full_like(run_i, -1)

    # integer-domain accumulation: int8 x int8 -> int32 on the MXU over
    # the stacked [q1; q2] two-term query block
    acc = jax.lax.dot_general(q_ref[...], c_ref[0],
                              (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.int32)
    # rank-1 affine epilogue: dots ~= alpha1*(q1.c) + alpha2*(q2.c) + beta
    terms = alpha_ref[...] * acc.astype(jnp.float32)   # [2*q_pad, p_max]
    qp = terms.shape[0] // 2
    dots = terms[:qp] + terms[qp:] + beta_ref[...]
    if metric == "l2":
        if has_norms:
            v2 = norms_ref[0]                        # precomputed tier
        else:
            # paged fallback: decode-and-reduce, the exact expression
            # code_norms was precomputed with (bitwise-identical values)
            c = c_ref[0].astype(jnp.float32)
            v = (c + 128.0) * scale_ref[0][None, :] + lo_ref[0][None, :]
            v2 = jnp.sum(v * v, axis=-1)
        scores = v2[None, :] - 2.0 * dots
    else:
        scores = -dots
    ok = valid_ref[0][None, :] != 0                  # [1, p_max]
    if attr_filter is not None:
        ok = ok & attr_filter(attrs_ref[0])[None, :]
    if mqo:
        ok = ok & (qsel_ref[:, i][:, None] != 0)     # [Q, 1]
    scores = jnp.where(ok, scores, MASKED)
    cand_i = jnp.broadcast_to(ids_ref[0][None, :], scores.shape)
    cand_i = jnp.where(scores >= MASKED, -1, cand_i)

    new_s, new_i = _merge_topk(run_s[...], run_i[...], scores, cand_i,
                               k_out)
    run_s[...] = new_s
    run_i[...] = new_i

    @pl.when(i == n - 1)
    def _out():
        out_s_ref[...] = run_s[...]
        out_i_ref[...] = run_i[...]


def sq_scan_topk(
    queries: jax.Array,          # [Q, d] f32 (normalised)
    codes: jax.Array,            # [k, p_max, d] int8
    lo: jax.Array,               # [d] f32 quantizer minima
    scale: jax.Array,            # [d] f32 quantizer scales
    valid: jax.Array,            # [k, p_max] bool/int8
    ids: jax.Array,              # [k, p_max] int32 (asset or flat row ids)
    part_ids: jax.Array,         # [n] int32 -- partitions to stream
    k_out: int,
    metric: str = "l2",
    qsel: Optional[jax.Array] = None,   # [Q, n] bool (MQO mask)
    attrs: Optional[jax.Array] = None,  # [k, p_max, n_attr] f32
    attr_filter=None,                   # compiled predicate (hybrid.py)
    norms: Optional[jax.Array] = None,  # [k, p_max] f32 ||decode(c)||^2
    interpret: Optional[bool] = None,   # None: auto by backend
) -> Tuple[jax.Array, jax.Array]:
    if interpret is None:
        interpret = default_interpret()
    kp, p_max, d = codes.shape
    assert interpret or p_max % INT8_SUBLANE_MIN == 0, \
        f"compiled int8 scan needs p_max % {INT8_SUBLANE_MIN} == 0 " \
        f"(got {p_max}); build with pad_to=32 (types.effective_pad_to)"
    q_n = queries.shape[0]
    n = part_ids.shape[0]
    mqo = qsel is not None
    if qsel is None:
        qsel = jnp.ones((q_n, n), jnp.int8)

    # fold the query block into the int8 domain ONCE per scan; the fold
    # is the stacked two-term form ([q1; q2], [alpha1; alpha2], beta)
    stats = quantize.QuantStats(lo=jnp.asarray(lo, jnp.float32),
                                scale=jnp.asarray(scale, jnp.float32))
    q_i8, alpha, beta = quantize.fold_queries(stats, queries)

    # compiled int8 operands tile at 32 sublanes: pad Q up, slice back.
    # Each term's half pads independently so the kernel's [:qp]/[qp:]
    # split still lands on the term boundary.
    q_pad = q_n
    if not interpret and q_n % INT8_SUBLANE_MIN:
        q_pad = -(-q_n // INT8_SUBLANE_MIN) * INT8_SUBLANE_MIN
        padw = [(0, q_pad - q_n), (0, 0)]
        q_i8 = jnp.concatenate([jnp.pad(q_i8[:q_n], padw),
                                jnp.pad(q_i8[q_n:], padw)])
        alpha = jnp.concatenate([jnp.pad(alpha[:q_n], padw[:1]),
                                 jnp.pad(alpha[q_n:], padw[:1])])
        beta = jnp.pad(beta, padw[:1])
        qsel = jnp.pad(qsel, padw)

    has_norms = norms is not None and metric == "l2"
    in_specs = [
        pl.BlockSpec((2 * q_pad, d), lambda i, pids: (0, 0)),
        pl.BlockSpec((2 * q_pad, 1), lambda i, pids: (0, 0)),
        pl.BlockSpec((q_pad, 1), lambda i, pids: (0, 0)),
        pl.BlockSpec((1, d), lambda i, pids: (0, 0)),
        pl.BlockSpec((1, d), lambda i, pids: (0, 0)),
        pl.BlockSpec((1, p_max, d), lambda i, pids: (pids[i], 0, 0)),
        pl.BlockSpec((1, p_max), lambda i, pids: (pids[i], 0)),
        pl.BlockSpec((1, p_max), lambda i, pids: (pids[i], 0)),
        pl.BlockSpec((q_pad, n), lambda i, pids: (0, 0)),
    ]
    inputs = [q_i8.astype(jnp.int8),
              alpha.reshape(2 * q_pad, 1).astype(jnp.float32),
              beta.reshape(q_pad, 1).astype(jnp.float32),
              lo.reshape(1, d).astype(jnp.float32),
              scale.reshape(1, d).astype(jnp.float32),
              codes.astype(jnp.int8), valid.astype(jnp.int8),
              ids.astype(jnp.int32), qsel.astype(jnp.int8)]
    if has_norms:
        in_specs.append(pl.BlockSpec((1, p_max), lambda i, pids: (pids[i], 0)))
        inputs.append(norms.astype(jnp.float32))
    if attr_filter is not None:
        assert attrs is not None, "attr_filter needs the attrs tensor"
        n_attr = attrs.shape[-1]
        in_specs.append(
            pl.BlockSpec((1, p_max, n_attr), lambda i, pids: (pids[i], 0, 0)))
        inputs.append(attrs.astype(jnp.float32))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((q_pad, k_out), lambda i, pids: (0, 0)),
            pl.BlockSpec((q_pad, k_out), lambda i, pids: (0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((q_pad, k_out), jnp.float32),
            pltpu.VMEM((q_pad, k_out), jnp.int32),
        ],
    )
    kernel = pl.pallas_call(
        functools.partial(_sq_scan_kernel, k_out=k_out, metric=metric,
                          mqo=mqo, attr_filter=attr_filter,
                          has_norms=has_norms),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((q_pad, k_out), jnp.float32),
            jax.ShapeDtypeStruct((q_pad, k_out), jnp.int32),
        ],
        interpret=interpret,
    )
    out_s, out_i = kernel(part_ids.astype(jnp.int32), *inputs)
    if q_pad != q_n:
        out_s, out_i = out_s[:q_n], out_i[:q_n]
    return out_s, out_i
