"""Fused int8 scalar-quantized IVF scan + running top-k Pallas TPU kernel.

Same contract and grid structure as kernels/ivf_scan.py (one grid step per
probed partition, scalar-prefetched partition ids, VMEM running top-k),
but the partition payload streamed from HBM is the *int8 code tier* -- 4x
fewer bytes on the scan's bandwidth-bound axis -- and the per-dimension
dequantization

    v = (code + 128) * scale + lo

is fused into the distance accumulation: codes are widened to float32 in
VREGs, the affine decode runs on the VPU, and the [Q, d] x [d, p_max]
distance matmul hits the MXU, so the reconstruction never round-trips to
HBM. The quantizer stats (core/quantize.QuantStats) ride along as two
[1, d] VMEM blocks.

This is the *candidate* stage of the paper's low-memory design: callers
over-fetch k' = rerank_factor * k rows here and rerank them at float32
(core/executor.py), so the `ids` input is typically the flat row index
(partition * p_max + slot) rather than the asset id -- whatever the
caller needs to gather rerank rows. MQO selection masks and fused
attribute predicates behave exactly as in ivf_scan.

On a real TPU the int8 tile minimum is (32, 128); p_max must be a
multiple of 32 when running compiled (core/types.effective_pad_to bumps
the build-time padding automatically; sq_scan_topk asserts it so a
mis-padded layout fails loud instead of mis-compiling). Interpret mode
(anything that is not a TPU backend) has no such constraint.

Frame-indirect entry (storage/pager.py): `codes` may be the pager's
frame *pool* [F, p_max, d] rather than the full code tier, with
`part_ids` carrying frame indices -- the kernel is layout-agnostic, it
streams whichever blocks the scalar-prefetched probe list names, so the
paged and resident scans share this one implementation.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ivf_scan import MASKED, _merge_topk, default_interpret

# Minimum second-to-last tile dimension for int8 operands on real TPU
# hardware (the (32, 128) tile); interpret mode is unconstrained.
INT8_SUBLANE_MIN = 32


def _sq_scan_kernel(part_ids_ref,              # scalar prefetch [n]
                    *refs,
                    k_out: int, metric: str, mqo: bool, attr_filter):
    if attr_filter is not None:
        (q_ref, lo_ref, scale_ref, c_ref, valid_ref, ids_ref, qsel_ref,
         attrs_ref, out_s_ref, out_i_ref, run_s, run_i) = refs
    else:
        (q_ref, lo_ref, scale_ref, c_ref, valid_ref, ids_ref, qsel_ref,
         out_s_ref, out_i_ref, run_s, run_i) = refs
    i = pl.program_id(0)
    n = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        run_s[...] = jnp.full_like(run_s, MASKED)
        run_i[...] = jnp.full_like(run_i, -1)

    q = q_ref[...].astype(jnp.float32)               # [Q, d]
    # fused dequantization: int8 codes -> f32 reconstruction in-register
    c = c_ref[0].astype(jnp.float32)                 # [p_max, d]
    v = (c + 128.0) * scale_ref[0][None, :] + lo_ref[0][None, :]
    dots = jax.lax.dot_general(q, v, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    if metric == "l2":
        v2 = jnp.sum(v * v, axis=-1)
        scores = v2[None, :] - 2.0 * dots
    else:
        scores = -dots
    ok = valid_ref[0][None, :] != 0                  # [1, p_max]
    if attr_filter is not None:
        ok = ok & attr_filter(attrs_ref[0])[None, :]
    if mqo:
        ok = ok & (qsel_ref[:, i][:, None] != 0)     # [Q, 1]
    scores = jnp.where(ok, scores, MASKED)
    cand_i = jnp.broadcast_to(ids_ref[0][None, :], scores.shape)
    cand_i = jnp.where(scores >= MASKED, -1, cand_i)

    new_s, new_i = _merge_topk(run_s[...], run_i[...], scores, cand_i,
                               k_out)
    run_s[...] = new_s
    run_i[...] = new_i

    @pl.when(i == n - 1)
    def _out():
        out_s_ref[...] = run_s[...]
        out_i_ref[...] = run_i[...]


def sq_scan_topk(
    queries: jax.Array,          # [Q, d] f32 (normalised)
    codes: jax.Array,            # [k, p_max, d] int8
    lo: jax.Array,               # [d] f32 quantizer minima
    scale: jax.Array,            # [d] f32 quantizer scales
    valid: jax.Array,            # [k, p_max] bool/int8
    ids: jax.Array,              # [k, p_max] int32 (asset or flat row ids)
    part_ids: jax.Array,         # [n] int32 -- partitions to stream
    k_out: int,
    metric: str = "l2",
    qsel: Optional[jax.Array] = None,   # [Q, n] bool (MQO mask)
    attrs: Optional[jax.Array] = None,  # [k, p_max, n_attr] f32
    attr_filter=None,                   # compiled predicate (hybrid.py)
    interpret: Optional[bool] = None,   # None: auto by backend
) -> Tuple[jax.Array, jax.Array]:
    if interpret is None:
        interpret = default_interpret()
    kp, p_max, d = codes.shape
    assert interpret or p_max % INT8_SUBLANE_MIN == 0, \
        f"compiled int8 scan needs p_max % {INT8_SUBLANE_MIN} == 0 " \
        f"(got {p_max}); build with pad_to=32 (types.effective_pad_to)"
    q_n = queries.shape[0]
    n = part_ids.shape[0]
    mqo = qsel is not None
    if qsel is None:
        qsel = jnp.ones((q_n, n), jnp.int8)

    in_specs = [
        pl.BlockSpec((q_n, d), lambda i, pids: (0, 0)),
        pl.BlockSpec((1, d), lambda i, pids: (0, 0)),
        pl.BlockSpec((1, d), lambda i, pids: (0, 0)),
        pl.BlockSpec((1, p_max, d), lambda i, pids: (pids[i], 0, 0)),
        pl.BlockSpec((1, p_max), lambda i, pids: (pids[i], 0)),
        pl.BlockSpec((1, p_max), lambda i, pids: (pids[i], 0)),
        pl.BlockSpec((q_n, n), lambda i, pids: (0, 0)),
    ]
    inputs = [queries, lo.reshape(1, d).astype(jnp.float32),
              scale.reshape(1, d).astype(jnp.float32),
              codes.astype(jnp.int8), valid.astype(jnp.int8),
              ids.astype(jnp.int32), qsel.astype(jnp.int8)]
    if attr_filter is not None:
        assert attrs is not None, "attr_filter needs the attrs tensor"
        n_attr = attrs.shape[-1]
        in_specs.append(
            pl.BlockSpec((1, p_max, n_attr), lambda i, pids: (pids[i], 0, 0)))
        inputs.append(attrs.astype(jnp.float32))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((q_n, k_out), lambda i, pids: (0, 0)),
            pl.BlockSpec((q_n, k_out), lambda i, pids: (0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((q_n, k_out), jnp.float32),
            pltpu.VMEM((q_n, k_out), jnp.int32),
        ],
    )
    kernel = pl.pallas_call(
        functools.partial(_sq_scan_kernel, k_out=k_out, metric=metric,
                          mqo=mqo, attr_filter=attr_filter),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((q_n, k_out), jnp.float32),
            jax.ShapeDtypeStruct((q_n, k_out), jnp.int32),
        ],
        interpret=interpret,
    )
    return tuple(kernel(part_ids.astype(jnp.int32), *inputs))
