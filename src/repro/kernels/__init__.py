"""Pallas TPU kernels for MicroNN's compute hot-spots (+ ops/ref pairs).

  ivf_scan      -- fused partition scan + running top-k (Alg. 2 hot loop)
  kmeans_assign -- penalised nearest-centroid assignment (Alg. 1 NEAREST)

Validated in interpret mode against ref.py oracles (tests/test_kernels.py);
BlockSpecs target real TPU VMEM tiling.
"""
from . import ivf_scan, kmeans_assign, ops, ref

__all__ = ["ivf_scan", "kmeans_assign", "ops", "ref"]
