"""Pallas TPU kernels for MicroNN's compute hot-spots (+ ops/ref pairs).

  ivf_scan      -- fused partition scan + running top-k (Alg. 2 hot loop)
  sq_scan       -- int8 scalar-quantized scan, dequantization fused into
                   the distance accumulation (the low-memory tier)
  kmeans_assign -- penalised nearest-centroid assignment (Alg. 1 NEAREST)

Validated in interpret mode against ref.py oracles (tests/test_kernels.py,
tests/test_quantize.py); BlockSpecs target real TPU VMEM tiling.
"""
from . import ivf_scan, kmeans_assign, ops, ref, sq_scan

__all__ = ["ivf_scan", "kmeans_assign", "ops", "ref", "sq_scan"]
