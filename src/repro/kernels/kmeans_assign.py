"""Penalised nearest-centroid assignment Pallas TPU kernel (Alg. 1's
NEAREST, batch-parallel form).

Streams centroid tiles HBM->VMEM, computes the [s, kt] distance block on
the MXU, adds the balance penalty (lambda * scale * count/target), and
keeps a running (best, argbest) per batch row across tiles.

The within-batch sequential count accumulation of Alg. 1 lives in the
pure-JAX path (core/kmeans.assign_minibatch, a lax.scan); this kernel is
the high-throughput variant used for the *final* assignment pass (Alg. 1
line 16, penalty weight 0) and for balanced re-assignment during
maintenance, where counts are frozen for the duration of a batch.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _assign_kernel(x_ref, c_ref, penalty_ref, out_i_ref, out_d_ref,
                   best_d, best_i, *, kt: int):
    t = pl.program_id(0)
    nt = pl.num_programs(0)

    @pl.when(t == 0)
    def _init():
        best_d[...] = jnp.full_like(best_d, jnp.finfo(jnp.float32).max)
        best_i[...] = jnp.zeros_like(best_i)

    x = x_ref[...].astype(jnp.float32)              # [s, d]
    c = c_ref[...].astype(jnp.float32)              # [kt, d]
    dots = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    c2 = jnp.sum(c * c, axis=-1)
    d2 = x2 + c2[None, :] - 2.0 * dots              # [s, kt]
    pen = d2 + penalty_ref[...][None, :]

    tile_best = jnp.min(pen, axis=1)
    tile_arg = jnp.argmin(pen, axis=1).astype(jnp.int32) + t * kt
    better = tile_best < best_d[...]
    best_d[...] = jnp.where(better, tile_best, best_d[...])
    best_i[...] = jnp.where(better, tile_arg, best_i[...])

    @pl.when(t == nt - 1)
    def _out():
        out_i_ref[...] = best_i[...]
        out_d_ref[...] = best_d[...]


def kmeans_assign(
    batch: jax.Array,        # [s, d]
    centroids: jax.Array,    # [k, d]
    counts: jax.Array,       # [k] f32
    *,
    balance_weight: float = 0.0,
    target_size: int = 100,
    scale: float = 1.0,
    tile_k: int = 256,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """-> (assign [s] int32, best penalised cost [s] f32).

    The balance penalty (lambda * scale * count / target, Alg. 1 NEAREST)
    is folded into a per-centroid penalty vector on the host side so the
    kernel streams exactly two operand tiles per grid step.
    """
    s, d = batch.shape
    k = centroids.shape[0]
    penalty = counts.astype(jnp.float32) * (
        jnp.asarray(balance_weight, jnp.float32)
        * jnp.asarray(scale, jnp.float32) / target_size)
    pad = (-k) % tile_k
    if pad:
        centroids = jnp.pad(centroids, ((0, pad), (0, 0)))
        penalty = jnp.pad(penalty, (0, pad),
                          constant_values=jnp.float32(1e18))  # repel padding
    kp = centroids.shape[0]
    nt = kp // tile_k

    kernel = pl.pallas_call(
        functools.partial(_assign_kernel, kt=tile_k),
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((s, d), lambda t: (0, 0)),
            pl.BlockSpec((tile_k, d), lambda t: (t, 0)),
            pl.BlockSpec((tile_k,), lambda t: (t,)),
        ],
        out_specs=[
            pl.BlockSpec((s,), lambda t: (0,)),
            pl.BlockSpec((s,), lambda t: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s,), jnp.int32),
            jax.ShapeDtypeStruct((s,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((s,), jnp.float32),
            pltpu.VMEM((s,), jnp.int32),
        ],
        interpret=interpret,
    )
    return tuple(kernel(batch, centroids, penalty))
