"""Fused IVF partition scan + running top-k Pallas TPU kernel.

The paper's hot loop (Alg. 2 lines 4-10): stream the n probed partitions,
compute query-to-vector distances, keep a running top-k. The TPU-native
realisation (DESIGN.md §2):

  * HBM -> VMEM streaming via *scalar-prefetched* partition ids: the
    BlockSpec index_map reads `part_ids[i]` so only the probed partitions
    ever leave HBM -- the analogue of "only read probed pages from disk";
  * distances on the MXU: scores = ||v||^2 - 2 q.v as one [Q,d]x[d,p_max]
    matmul per grid step (the paper's SIMD batch, on a systolic array);
  * the per-thread heap becomes a VMEM running top-k scratch, merged with
    each tile via K rounds of masked min-extraction (a heap has no
    vector-unit analogue; K-round selection keeps everything in VREGs --
    a production kernel could swap in a bitonic partial sort, same
    semantics);
  * the MQO variant takes a per-(query, partition) selection mask, giving
    the batch path (paper §3.4) the same single-pass-over-HBM property;
  * attribute-filter fusion (paper §3.5): when a compiled predicate is
    passed, the partition's attrs block streams alongside the vectors and
    the predicate is evaluated *inside* the kernel, masking rows before
    they ever enter the running top-k -- "filtered before being considered
    in the top-K computation", with no separate XLA gather pass.

Grid: one step per probed partition; queries/outputs live fully in VMEM.
VMEM per step ~ Q*d + p_max*d + p_max*n_attr + 2*Q*K floats -- p_max
(balanced!) and Q tile sizes are chosen so this fits the ~16 MB/core
budget.

`interpret` is auto-selected from the runtime backend (interpret mode
everywhere except real TPU); callers can still force it either way.
This module is the Pallas backend of core/executor.py -- the engine
never calls it directly.

Frame-indirect entry (storage/pager.py): the paged executor passes the
pager's frame *pool* [F, p_max, d] as `vectors` and frame indices as
`part_ids` -- the scalar-prefetched index_map streams whichever blocks
the probe list names, so a 10 MB pool serves the same kernel that a
full-resident tier does (HBM traffic stays "probed frames only").
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

MASKED = jnp.finfo(jnp.float32).max


def _merge_topk(run_s, run_i, cand_s, cand_i, k_out: int):
    """K rounds of masked min-extraction merging candidates into the
    running buffer. run_*: [Q, K]; cand_*: [Q, C]."""
    s = jnp.concatenate([run_s, cand_s], axis=1)     # [Q, K+C]
    i = jnp.concatenate([run_i, cand_i], axis=1)

    def body(j, carry):
        s, i, out_s, out_i = carry
        m = jnp.min(s, axis=1)                        # [Q]
        am = jnp.argmin(s, axis=1)                    # [Q]
        mid = jnp.take_along_axis(i, am[:, None], axis=1)[:, 0]
        out_s = out_s.at[:, j].set(m)
        out_i = out_i.at[:, j].set(mid)
        s = s.at[jnp.arange(s.shape[0]), am].set(MASKED)
        return s, i, out_s, out_i

    out_s = jnp.full_like(run_s, MASKED)
    out_i = jnp.full_like(run_i, -1)
    _, _, out_s, out_i = jax.lax.fori_loop(
        0, k_out, body, (s, i, out_s, out_i))
    return out_s, out_i


def _scan_kernel(part_ids_ref,               # scalar prefetch [n]
                 *refs,
                 k_out: int, metric: str, mqo: bool, attr_filter):
    if attr_filter is not None:
        (q_ref, v_ref, valid_ref, ids_ref, qsel_ref, attrs_ref,
         out_s_ref, out_i_ref, run_s, run_i) = refs
    else:
        (q_ref, v_ref, valid_ref, ids_ref, qsel_ref,
         out_s_ref, out_i_ref, run_s, run_i) = refs
    i = pl.program_id(0)
    n = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        run_s[...] = jnp.full_like(run_s, MASKED)
        run_i[...] = jnp.full_like(run_i, -1)

    q = q_ref[...].astype(jnp.float32)               # [Q, d]
    v = v_ref[0].astype(jnp.float32)                 # [p_max, d]
    dots = jax.lax.dot_general(q, v, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    if metric == "l2":
        v2 = jnp.sum(v * v, axis=-1)
        scores = v2[None, :] - 2.0 * dots
    else:
        scores = -dots
    ok = valid_ref[0][None, :] != 0                  # [1, p_max]
    if attr_filter is not None:
        # fused predicate: [p_max, n_attr] attrs block -> [p_max] keep mask
        ok = ok & attr_filter(attrs_ref[0])[None, :]
    if mqo:
        ok = ok & (qsel_ref[:, i][:, None] != 0)     # [Q, 1]
    scores = jnp.where(ok, scores, MASKED)
    cand_i = jnp.broadcast_to(ids_ref[0][None, :], scores.shape)
    cand_i = jnp.where(scores >= MASKED, -1, cand_i)

    new_s, new_i = _merge_topk(run_s[...], run_i[...], scores, cand_i,
                               k_out)
    run_s[...] = new_s
    run_i[...] = new_i

    @pl.when(i == n - 1)
    def _out():
        out_s_ref[...] = run_s[...]
        out_i_ref[...] = run_i[...]


def default_interpret() -> bool:
    """Interpret everywhere except a real TPU backend (auto-selection)."""
    return jax.default_backend() != "tpu"


def ivf_scan_topk(
    queries: jax.Array,          # [Q, d]
    vectors: jax.Array,          # [k, p_max, d]
    valid: jax.Array,            # [k, p_max] bool/int8
    ids: jax.Array,              # [k, p_max] int32
    part_ids: jax.Array,         # [n] int32 -- partitions to stream
    k_out: int,
    metric: str = "l2",
    qsel: Optional[jax.Array] = None,   # [Q, n] bool (MQO mask)
    attrs: Optional[jax.Array] = None,  # [k, p_max, n_attr] f32
    attr_filter=None,                   # compiled predicate (hybrid.py)
    interpret: Optional[bool] = None,   # None: auto by backend
) -> Tuple[jax.Array, jax.Array]:
    if interpret is None:
        interpret = default_interpret()
    kp, p_max, d = vectors.shape
    q_n = queries.shape[0]
    n = part_ids.shape[0]
    mqo = qsel is not None
    if qsel is None:
        qsel = jnp.ones((q_n, n), jnp.int8)

    in_specs = [
        pl.BlockSpec((q_n, d), lambda i, pids: (0, 0)),
        pl.BlockSpec((1, p_max, d), lambda i, pids: (pids[i], 0, 0)),
        pl.BlockSpec((1, p_max), lambda i, pids: (pids[i], 0)),
        pl.BlockSpec((1, p_max), lambda i, pids: (pids[i], 0)),
        pl.BlockSpec((q_n, n), lambda i, pids: (0, 0)),
    ]
    inputs = [queries, vectors, valid.astype(jnp.int8),
              ids.astype(jnp.int32), qsel.astype(jnp.int8)]
    if attr_filter is not None:
        assert attrs is not None, "attr_filter needs the attrs tensor"
        n_attr = attrs.shape[-1]
        in_specs.append(
            pl.BlockSpec((1, p_max, n_attr), lambda i, pids: (pids[i], 0, 0)))
        inputs.append(attrs.astype(jnp.float32))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((q_n, k_out), lambda i, pids: (0, 0)),
            pl.BlockSpec((q_n, k_out), lambda i, pids: (0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((q_n, k_out), jnp.float32),
            pltpu.VMEM((q_n, k_out), jnp.int32),
        ],
    )
    kernel = pl.pallas_call(
        functools.partial(_scan_kernel, k_out=k_out, metric=metric, mqo=mqo,
                          attr_filter=attr_filter),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((q_n, k_out), jnp.float32),
            jax.ShapeDtypeStruct((q_n, k_out), jnp.int32),
        ],
        interpret=interpret,
    )
    return tuple(kernel(part_ids.astype(jnp.int32), *inputs))
