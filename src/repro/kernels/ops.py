"""Jit'd public wrappers around the Pallas kernels.

`interpret=None` auto-selects by backend (interpret everywhere except a
real TPU -- this container is CPU-only; interpret mode executes the
kernel bodies exactly). On TPU hardware the BlockSpecs/grids are written
for real VMEM tiling and compile natively.

These wrappers are the Pallas backend of core/executor.py's fused scan;
engine code routes through the executor, not through this module.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ivf_scan as _ivf
from . import kmeans_assign as _km
from ..core.types import IVFIndex


@partial(jax.jit, static_argnames=("k_out", "metric", "attr_filter",
                                   "interpret"))
def scan_topk(queries, vectors, valid, ids, part_ids, k_out: int,
              metric: str = "l2", attrs=None, attr_filter=None,
              interpret: Optional[bool] = None):
    """Fused partition-scan + top-k (Alg. 2 hot loop), optional fused
    attribute predicate (paper §3.5)."""
    return _ivf.ivf_scan_topk(queries, vectors, valid, ids, part_ids,
                              k_out, metric=metric, attrs=attrs,
                              attr_filter=attr_filter, interpret=interpret)


@partial(jax.jit, static_argnames=("k_out", "metric", "attr_filter",
                                   "interpret"))
def scan_topk_mqo(queries, vectors, valid, ids, part_ids, qsel,
                  k_out: int, metric: str = "l2", attrs=None,
                  attr_filter=None, interpret: Optional[bool] = None):
    """MQO variant: qsel [Q, n] masks which query wants which partition."""
    return _ivf.ivf_scan_topk(queries, vectors, valid, ids, part_ids,
                              k_out, metric=metric, qsel=qsel, attrs=attrs,
                              attr_filter=attr_filter, interpret=interpret)


@partial(jax.jit, static_argnames=("balance_weight", "target_size",
                                   "tile_k", "interpret"))
def assign_nearest(batch, centroids, counts, *, balance_weight: float = 0.0,
                   target_size: int = 100, scale: float = 1.0,
                   tile_k: int = 256, interpret: bool = True):
    """Penalised nearest-centroid assignment (Alg. 1 NEAREST, batch form)."""
    return _km.kmeans_assign(batch, centroids, counts,
                             balance_weight=balance_weight,
                             target_size=target_size, scale=scale,
                             tile_k=tile_k, interpret=interpret)


def index_scan_topk(index: IVFIndex, queries: jax.Array, k_out: int,
                    n_probe: int, interpret: Optional[bool] = None):
    """Kernel-backed Alg. 2 over an IVFIndex (no delta / no filters --
    the full integration lives in core.executor which handles those)."""
    from ..core.executor import find_nearest_centroids
    parts = find_nearest_centroids(index, queries, n_probe)
    # kernel scans one shared probe list; per-query probe sets use the MQO
    # mask over the union
    uniq = parts.reshape(-1)
    return scan_topk(queries, index.vectors, index.valid, index.ids,
                     uniq, k_out, metric=index.config.metric,
                     interpret=interpret)
