"""Jit'd public wrappers around the Pallas kernels.

`interpret` defaults to True (this container is CPU-only; interpret mode
executes the kernel bodies exactly). On TPU hardware pass interpret=False
-- the BlockSpecs/grids are written for real VMEM tiling.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ivf_scan as _ivf
from . import kmeans_assign as _km
from ..core.types import IVFIndex


@partial(jax.jit, static_argnames=("k_out", "metric", "interpret"))
def scan_topk(queries, vectors, valid, ids, part_ids, k_out: int,
              metric: str = "l2", interpret: bool = True):
    """Fused partition-scan + top-k (Alg. 2 hot loop)."""
    return _ivf.ivf_scan_topk(queries, vectors, valid, ids, part_ids,
                              k_out, metric=metric, interpret=interpret)


@partial(jax.jit, static_argnames=("k_out", "metric", "interpret"))
def scan_topk_mqo(queries, vectors, valid, ids, part_ids, qsel,
                  k_out: int, metric: str = "l2", interpret: bool = True):
    """MQO variant: qsel [Q, n] masks which query wants which partition."""
    return _ivf.ivf_scan_topk(queries, vectors, valid, ids, part_ids,
                              k_out, metric=metric, qsel=qsel,
                              interpret=interpret)


@partial(jax.jit, static_argnames=("balance_weight", "target_size",
                                   "tile_k", "interpret"))
def assign_nearest(batch, centroids, counts, *, balance_weight: float = 0.0,
                   target_size: int = 100, scale: float = 1.0,
                   tile_k: int = 256, interpret: bool = True):
    """Penalised nearest-centroid assignment (Alg. 1 NEAREST, batch form)."""
    return _km.kmeans_assign(batch, centroids, counts,
                             balance_weight=balance_weight,
                             target_size=target_size, scale=scale,
                             tile_k=tile_k, interpret=interpret)


def index_scan_topk(index: IVFIndex, queries: jax.Array, k_out: int,
                    n_probe: int, interpret: bool = True):
    """Kernel-backed Alg. 2 over an IVFIndex (no delta / no filters --
    integration helpers live in core.search which handles those)."""
    from ..core.search import find_nearest_centroids
    parts = find_nearest_centroids(index, queries, n_probe)
    # kernel scans one shared probe list; per-query probe sets use the MQO
    # mask over the union
    uniq = parts.reshape(-1)
    return scan_topk(queries, index.vectors, index.valid, index.ids,
                     uniq, k_out, metric=index.config.metric,
                     interpret=interpret)
