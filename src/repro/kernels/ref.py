"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests).

Scores follow the ranking convention of core/: smaller is better, and the
L2 path drops the per-query ||q||^2 constant (rank-invariant), i.e.
    score(q, v) = ||v||^2 - 2 q.v          (l2)
    score(q, v) = -q.v                     (ip / cosine on normalised data)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

MASKED = jnp.finfo(jnp.float32).max


def scores_ref(q: jax.Array, v: jax.Array, metric: str) -> jax.Array:
    """q: [Q, d], v: [N, d] -> [Q, N]."""
    dots = q.astype(jnp.float32) @ v.astype(jnp.float32).T
    if metric in ("ip", "cosine"):
        return -dots
    v2 = jnp.sum(v.astype(jnp.float32) ** 2, axis=-1)
    return v2[None, :] - 2.0 * dots


def ivf_scan_ref(queries, vectors, valid, ids, part_ids, k_out,
                 metric: str = "l2", qsel=None):
    """Oracle for the fused partition scan + top-k kernel.

    queries [Q, d]; vectors [k, p_max, d]; valid [k, p_max] bool;
    ids [k, p_max] int32; part_ids [n] int32 partitions to scan;
    qsel [Q, n] bool or None (MQO: which query wants which partition).
    -> (scores [Q, k_out], ids [Q, k_out]) sorted ascending.
    """
    Q = queries.shape[0]
    pv = vectors[part_ids]                        # [n, p_max, d]
    pid = ids[part_ids]                           # [n, p_max]
    pok = valid[part_ids]                         # [n, p_max]
    n, p_max, d = pv.shape
    s = scores_ref(queries, pv.reshape(n * p_max, d), metric)
    ok = jnp.broadcast_to(pok.reshape(1, n * p_max), s.shape)
    if qsel is not None:
        ok = ok & jnp.repeat(qsel, p_max, axis=1)
    s = jnp.where(ok, s, MASKED)
    neg, idx = jax.lax.top_k(-s, k_out)
    out_ids = jnp.take_along_axis(
        jnp.broadcast_to(pid.reshape(1, -1), s.shape), idx, axis=1)
    out_ids = jnp.where(-neg >= MASKED, -1, out_ids)
    return -neg, out_ids


def kmeans_assign_ref(batch, centroids, counts, balance_weight: float,
                      target_size: int, scale: float):
    """Oracle for the penalised-nearest assignment kernel.

    batch [s, d]; centroids [k, d]; counts [k] f32.
    -> (assign [s] int32, dist [s] f32 = penalised cost of the argmin)
    """
    d2 = scores_ref(batch, centroids, "l2") \
        + jnp.sum(batch.astype(jnp.float32) ** 2, -1, keepdims=True)
    pen = d2 + balance_weight * scale * counts[None, :] / target_size
    a = jnp.argmin(pen, axis=-1).astype(jnp.int32)
    return a, jnp.min(pen, axis=-1)
