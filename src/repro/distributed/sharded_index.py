"""Distributed MicroNN: the paper's ANN search at pod scale.

Index layout on the production mesh (DESIGN.md §6):
  * centroids        replicated (small -- the paper scans them anyway)
  * partitions       [k, p_max, d] sharded on k over the `model` axis
  * queries          sharded over the data axes, replicated over `model`

Search is Alg. 2 run as 4 SPMD phases inside one `shard_map`:
  1. local centroid scoring        [Q, k/m] matmul per device
  2. global top-n probe selection  log-depth tournament over `model`
     (exact: the union of per-device candidates contains the global top-n)
  3. owned-partition scan          each device issues a local plan to the
     unified executor's fused scan primitive (core/executor.fused_scan)
     over the probed partitions it owns (fixed-cap probe list,
     selection-masked) -- the same primitive as single-device search
  4. global top-k result merge     hypercube tournament over `model`
     (the paper's parallel heap merge, on ICI)

Collective bytes per query batch: phase 2 moves n ids+scores per device,
phase 4 moves k results per device -- both O(log m) rounds; partition data
never crosses devices. That locality is the paper's disk-efficiency
argument transplanted to ICI.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core import executor
from ..core import topk as topk_lib
from ..core.query import Q, QuerySpec, ResultSet
from ..core.types import IVFIndex, SearchResult, normalize_if_cosine


def _shard_map(f, mesh, in_specs, out_specs):
    """jax.shard_map (>=0.5, check_vma) vs experimental shard_map
    (0.4.x, check_rep) compatibility."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
        except TypeError:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def index_shardings(index: IVFIndex, mesh: Mesh, model_axis: str = "model"):
    """NamedShardings for an IVFIndex pytree: partitions over `model`."""
    m = model_axis

    def ns(*spec):
        return NamedSharding(mesh, P(*spec))

    from ..core.types import DeltaStore

    # The template must mirror the index's pytree structure: the quantized
    # tier (codes on the model axis next to the vectors, small qstats
    # replicated) is present iff the index carries it.
    quantized = isinstance(index, IVFIndex) and index.codes is not None
    qstats_ns = None
    if quantized:
        qstats_ns = jax.tree.map(lambda _: ns(None), index.qstats)
    return IVFIndex(
        centroids=ns(m, None),
        csizes=ns(m),
        vectors=ns(m, None, None),
        ids=ns(m, None),
        attrs=ns(m, None, None),
        valid=ns(m, None),
        counts=ns(m),
        delta=DeltaStore(
            vectors=ns(None, None), ids=ns(None), attrs=ns(None, None),
            valid=ns(None), count=ns(),
            codes=ns(None, None) if quantized else None),
        base_mean_size=ns(),
        codes=ns(m, None, None) if quantized else None,
        qstats=qstats_ns,
        code_norms=ns(m, None) if quantized else None,
        config=index.config if not isinstance(index, IVFIndex) else
        index.config,
    )


def distributed_query(
    index: IVFIndex,
    queries: jax.Array,              # [Q, d] sharded over data axes
    spec: QuerySpec,
    mesh: Mesh,
    *,
    data_axes: Tuple[str, ...] = ("data",),
    model_axis: str = "model",
    local_cap: Optional[int] = None,
    merge: str = "tournament",       # tournament | allgather
) -> ResultSet:
    """Exact-distributed Alg. 2 driven by a QuerySpec (bitwise same
    results as single-device ann_search up to float association,
    validated in tests) -- the sharded route of the declarative query
    API. The per-device phase-3 scan merges into a global top-k, which
    is exactly ResultSet.merge()'s reduction run on ICI instead of host.
    """
    assert spec.kind == "ann", "sharded execution serves ANN specs " \
        "(exact = n_probe >= k_partitions)"
    assert spec.predicate is None, \
        "sharded hybrid predicates are not wired yet (ROADMAP)"
    # refuse what the sharded path cannot honor rather than silently
    # diverging from the same spec run through executor.run
    assert spec.u_max is None and spec.cap is None, \
        "union_cap/prefilter are not supported in sharded execution"
    assert spec.use_quantized in (None, False), \
        "sharded scan is float32 (no sharded code tier yet)"
    assert spec.on_backend in (None, "xla"), \
        "shard_map bodies run the XLA backend"
    cfg = index.config
    k, n_probe = spec.k, spec.n_probe
    m_size = mesh.devices.shape[list(mesh.axis_names).index(model_axis)]
    cap = local_cap or n_probe        # worst case: all probes on one shard

    def local(centroids, csizes, vectors, ids, attrs, valid, counts,
              dvec, dids, dattrs, dvalid, dcount, base, q):
        del csizes, attrs, dattrs, base
        me = jax.lax.axis_index(model_axis)
        k_local = vectors.shape[0]
        q = normalize_if_cosine(q.astype(jnp.float32), cfg.metric)

        # -- phase 1: local centroid scores --------------------------------
        from ..core.types import pairwise_scores
        cd = pairwise_scores(q, centroids, cfg.metric)       # [Q, k_local]
        cd = jnp.where(counts[None, :] > 0, cd, jnp.finfo(jnp.float32).max)
        n_local = min(n_probe, k_local)
        local_s, local_i = jax.lax.top_k(-cd, n_local)
        local_s = -local_s
        gids = (local_i + me * k_local).astype(jnp.int32)

        # -- phase 2: global top-n probe ids --------------------------------
        if merge == "tournament":
            gs, gi = topk_lib.tournament_merge(local_s, gids, n_probe,
                                               model_axis)
        else:
            gs, gi = topk_lib.allgather_merge(local_s, gids, n_probe,
                                              model_axis)

        # -- phase 3: scan owned probed partitions --------------------------
        mine = (gi // k_local) == me                          # [Q, n]
        lid = jnp.where(mine, gi % k_local, 0)
        # fixed-cap compaction of this device's probe list over the batch
        want = jnp.zeros((k_local,), bool).at[
            jnp.where(mine, lid, 0).reshape(-1)].set(
            mine.reshape(-1), mode="drop")
        (plist,) = jnp.nonzero(want, size=cap, fill_value=0)
        pvalid_probe = jnp.take(want, plist)

        # per-query selection: query q wants local partition plist[j]?
        sel = (gi[:, None, :] == (plist[None, :, None] + me * k_local)
               ).any(-1) & mine.any(-1, keepdims=True)        # [Q, cap]

        # local plan -> the unified fused scan primitive (XLA backend:
        # shard_map bodies are already device-local XLA; scores stay in
        # the executor's rank convention, which is rank-equal)
        k_scan = min(k, cap * vectors.shape[1])
        ls0, li0 = executor.fused_scan(
            q, vectors, valid, ids, plist, k_scan, metric=cfg.metric,
            qsel=sel & pvalid_probe[None, :], backend="xla")

        # delta partition: replicated, scanned once on shard 0 of the axis
        ddots = q @ dvec.T
        dsc = -ddots if cfg.metric in ("ip", "cosine") else \
            jnp.sum(dvec * dvec, -1)[None] - 2.0 * ddots
        dok = dvalid[None, :] & (me == 0)
        dsc = jnp.where(dok, dsc, jnp.finfo(jnp.float32).max)

        ls, li = topk_lib.merge_topk(
            ls0, li0, dsc, jnp.broadcast_to(dids[None], dsc.shape),
            min(k, k_scan + dsc.shape[-1]))
        ls = jnp.where(li < 0, jnp.finfo(jnp.float32).max, ls)

        # -- phase 4: global result merge ------------------------------------
        if merge == "tournament":
            fs, fi = topk_lib.tournament_merge(ls, li, k, model_axis)
        else:
            fs, fi = topk_lib.allgather_merge(ls, li, k, model_axis)
        return fs, fi

    dp = P(data_axes if len(data_axes) > 1 else data_axes[0], None)
    mp = model_axis
    in_specs = (
        P(mp, None), P(mp), P(mp, None, None), P(mp, None),
        P(mp, None, None), P(mp, None), P(mp),
        P(None, None), P(None), P(None, None), P(None), P(),
        P(), dp,
    )
    fs, fi = _shard_map(
        local, mesh, in_specs, (dp, dp),
    )(index.centroids, index.csizes, index.vectors, index.ids, index.attrs,
      index.valid, index.counts, index.delta.vectors, index.delta.ids,
      index.delta.attrs, index.delta.valid, index.delta.count,
      index.base_mean_size, queries)
    return ResultSet(ids=fi, scores=fs, spec=spec)


def distributed_search(
    index: IVFIndex,
    queries: jax.Array,
    k: int,
    n_probe: int,
    mesh: Mesh,
    **kwargs,
) -> ResultSet:
    """Kwarg shim over distributed_query (API compat)."""
    return distributed_query(index, queries, Q.knn(k=k, n_probe=n_probe),
                             mesh, **kwargs)
