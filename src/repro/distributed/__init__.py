from .sharded_index import distributed_search, index_shardings
