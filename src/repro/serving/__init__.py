"""Serving tier: the concurrent front door over MicroNN (frontdoor.py)
and the continuous-batching LM decode engine (engine.py).

`ServeEngine`/`Request` pull in the full model stack, so they load
lazily (PEP 562) -- the storage layer can import the light FrontDoor
module without dragging transformer weights into every embedded-engine
process.
"""
from .frontdoor import FrontDoor, FrontDoorConfig, empty_stats

_LAZY = ("Request", "ServeEngine")


def __getattr__(name):
    if name in _LAZY:
        from . import engine as _engine
        return getattr(_engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_LAZY))
