"""Batched serving engine with continuous batching + optional RAG.

Static-shape serving for TPU: a fixed pool of batch slots; finished
sequences are swapped for queued prompts (continuous batching) without
recompiling -- slot state lives in the cache pytree's batch dimension.

The RAG hook wires MicroNN in as a first-class serving feature: each
decode step's hidden state queries the datastore and the kNN distribution
interpolates into the LM logits (core/rag.py). Because the datastore is
the *updatable* MicroNN index, documents upserted while serving become
retrievable on the next step -- the paper's freshness story, at the
serving tier.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.query import QuerySpec
from ..core.rag import RagConfig, RagDatastore, rag_decode_logits
from ..models import decode as decode_lib


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: int = -1          # -1: run to max_new_tokens
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 s_max: int = 256, rag: Optional[RagDatastore] = None,
                 rag_cfg: Optional[RagConfig] = None,
                 rag_spec: Optional[QuerySpec] = None):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.s_max = s_max
        self.rag = rag
        self.rag_cfg = rag_cfg or RagConfig()
        # the retrieval QuerySpec every decode step issues (one frozen
        # spec == one executor compile-cache entry for the whole session);
        # pass a custom spec to e.g. fuse an attribute predicate over the
        # datastore or pin a backend
        self.rag_spec = rag_spec if rag_spec is not None \
            else self.rag_cfg.spec()
        self.queue: deque[Request] = deque()
        self.active: List[Optional[Request]] = [None] * slots
        self.cache = decode_lib.init_cache(cfg, slots, s_max)
        self.slot_pos = np.zeros(slots, np.int64)
        self.slot_tok = np.zeros((slots, 1), np.int32)
        self._decode = jax.jit(partial(self._decode_impl, cfg))

    @staticmethod
    def _decode_impl(cfg, params, cache, token, pos):
        return decode_lib.decode_step(cfg, params, cache, token, pos)

    # -- client API ----------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.popleft()
                self.active[s] = req
                # prefill the slot token-by-token (slot-local; a production
                # engine prefetches with the parallel prefill path)
                self._reset_slot(s)
                for t, tok in enumerate(req.prompt[:-1]):
                    self._step_slot(s, tok, t)
                self.slot_tok[s, 0] = req.prompt[-1]
                self.slot_pos[s] = len(req.prompt) - 1

    def _reset_slot(self, s: int):
        fresh = decode_lib.init_cache(self.cfg, 1, self.s_max)

        def put(old, new):
            return jax.lax.dynamic_update_slice_in_dim(old, new, s, axis=1)
        self.cache = jax.tree.map(put, self.cache, fresh)

    def _step_slot(self, s: int, tok: int, pos: int):
        """Feed one prompt token through slot s only (others masked by
        running the full batch then restoring -- single-process demo;
        multi-slot prefill is batched in production)."""
        toks = self.slot_tok.copy()
        toks[s, 0] = tok
        _, _, new_cache = self._decode(
            self.params, self.cache, jnp.asarray(toks),
            jnp.asarray(pos, jnp.int32))
        # keep only slot s's cache updates
        def mix(old, new):
            sl = jax.lax.dynamic_slice_in_dim(new, s, 1, axis=1)
            return jax.lax.dynamic_update_slice_in_dim(old, sl, s, axis=1)
        self.cache = jax.tree.map(mix, self.cache, new_cache)

    # -- decode loop ----------------------------------------------------------
    def step(self) -> Dict[int, int]:
        """One decode step for all active slots. -> {uid: new_token}."""
        self._admit()
        live = [s for s, r in enumerate(self.active) if r is not None]
        if not live:
            return {}
        pos = int(max(self.slot_pos[s] for s in live))
        logits, hidden, new_cache = self._decode(
            self.params, self.cache, jnp.asarray(self.slot_tok),
            jnp.asarray(pos, jnp.int32))
        if self.rag is not None:
            logits = rag_decode_logits(self.rag, logits, hidden,
                                       self.rag_cfg, spec=self.rag_spec)
        self.cache = new_cache
        toks = np.asarray(jnp.argmax(logits, axis=-1))
        out = {}
        for s in live:
            req = self.active[s]
            tok = int(toks[s])
            req.out.append(tok)
            out[req.uid] = tok
            self.slot_tok[s, 0] = tok
            self.slot_pos[s] += 1
            if tok == req.eos_id or len(req.out) >= req.max_new_tokens:
                req.done = True
                self.active[s] = None
        return out

    def run(self, max_steps: int = 64) -> List[Request]:
        finished: List[Request] = []
        for _ in range(max_steps):
            if not self.queue and all(r is None for r in self.active):
                break
            self.step()
        return finished
