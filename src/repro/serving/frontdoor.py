"""Concurrent serving front door for MicroNN: admission queue,
cross-request micro-batching, and daemonized maintenance (PR 7).

The paper's production story is an *embedded engine under live traffic*
-- queries, upserts, and index maintenance interleaving continuously --
but a bare `MicroNN` serves everything synchronously on the caller's
thread. `FrontDoor` is the serving subsystem in front of it:

    eng = MicroNN(dim=64, path="db.sqlite")
    ...build...
    with FrontDoor(eng, maintenance=True) as fd:
        rs = fd.query(vec, Q.knn(k=10))        # any thread, blocking
        fut = fd.submit(vec, Q.knn(k=10))      # ... or async via Future

Three mechanisms:

  * **Admission queue.** Caller threads `submit()` `(vecs, spec)` pairs
    and block on a `concurrent.futures.Future`; a single dispatcher
    thread owns execution, so query-side work is naturally serialized
    without locking the engine.

  * **Cross-request micro-batching.** Within a bounded window
    (`window_s`, default 2 ms) the dispatcher drains the queue and
    coalesces SAME-spec requests into one fused call through
    `MicroNN.query_batched` -> `executor.run_coalesced`: the chunks
    concatenate, the existing Q-bucketed executor pads to the bucket
    and runs ONE fused scan, and `ResultSet.split` hands each caller
    its own row range back. Because the frozen `QuerySpec` IS the jit
    cache key (PR 4), equal specs from N different callers provably
    compile once per Q-bucket -- and per-query scores are elementwise
    (each query masks onto its own probe set inside the shared union),
    so every caller's slice is bit-identical (ids + scores) to the solo
    `query()` it replaced. Distinct specs in one drain each get their
    own fused call; `max_batch_rows` caps a fused call's row count so
    bucket padding stays bounded.

  * **Daemonized maintenance.** `maintenance=True` promotes the
    engine's `MaintenanceScheduler` to a background daemon thread that
    drains bounded quanta whenever this queue is idle, each quantum
    under the engine-level write mutex (`MicroNN.lock`) -- so
    sessions/upserts/repairs serialize while reads proceed against
    consistent snapshots (immutable resident index pytrees; the RLock'd
    pager with deferred pinned-frame invalidation; the store's WAL
    snapshot read connection).

Consistency note: when the engine's store has no snapshot read
connection (`:memory:` databases are private to one connection), the
dispatcher executes paged and attr-gathering queries under the engine
write mutex instead -- a read on the shared connection could otherwise
observe another thread's open transaction mid-flight. File-backed
stores keep reads fully unserialized.

Observability (PR 8): latency accounting lives in the process metrics
registry (obs.metrics) -- the private sample reservoirs + percentile
helper this module used to carry are gone; queue-wait / execute / total
are shared mergeable histograms under this front door's registry scope,
and `stats()` derives the same keys as before from them. Traced submits
(`submit(..., trace=True)` / `query(..., trace=True)`) get a per-caller
QueryTrace that records the request's own queue_wait and its slice of
the coalesced batch (`split`), then ADOPTS the shared fused-call trace
the dispatcher recorded -- so N coalesced callers each see the one
fused scan they shared, plus their private admission latency.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Dict, List, Optional

import numpy as np

from ..core.query import QuerySpec, ResultSet
from ..obs import metrics as obs_metrics
from ..obs import recorder as obs_recorder
from ..obs import trace as obs_trace

_STAT_KEYS = ("queued", "inflight", "submitted", "completed", "failed",
              "coalesced", "batches", "solo", "batch_occupancy",
              "queue_wait_p50_ms", "queue_wait_p99_ms",
              "execute_p50_ms", "execute_p99_ms",
              "total_p50_ms", "total_p99_ms",
              "window_ms", "arrival_ewma_ms")

_FLOAT_KEYS = ("batch_occupancy", "window_ms", "arrival_ewma_ms")


def empty_stats() -> Dict:
    """The zeroed counter dict MicroNN.stats() reports when no front
    door is attached -- same keys as FrontDoor.stats(), so dashboards
    and tests read one uniform shape in every mode."""
    return {k: 0 if k not in _FLOAT_KEYS else 0.0 for k in _STAT_KEYS}


@dataclasses.dataclass
class _Request:
    """One admitted query: the caller blocks on `future`."""

    vecs: np.ndarray          # [q, d] float32 (q >= 1 rows)
    spec: QuerySpec
    future: Future
    t_submit: float           # monotonic seconds at admission
    n: int                    # rows (q)
    trace: Optional[obs_trace.QueryTrace] = None   # traced submit


@dataclasses.dataclass(frozen=True)
class FrontDoorConfig:
    """Serving knobs (all times in seconds).

    window_s         micro-batching window: after the first request is
                     seen the dispatcher waits up to this long for more
                     same-spec arrivals before executing (0 disables
                     coalescing -- every request executes alone, the
                     one-request-at-a-time baseline arm of bench_serve)
    max_batch_rows   cap on one fused call's total query rows; a drain
                     larger than this executes in several fused calls
                     (bounds bucket padding and per-call latency)
    maintenance      start the engine's maintenance scheduler as a
                     daemon thread, draining quanta while this queue is
                     idle
    daemon_interval_s  the daemon's poll cadence
    adaptive_window  size the coalescing window from the OBSERVED
                     arrival rate instead of the fixed window_s: an
                     EWMA of inter-arrival gaps picks the wait that
                     coalesces ~coalesce_target requests, clamped to
                     [0, window_s] -- sparse traffic pays ~zero added
                     latency (window collapses to 0 when the next
                     arrival is unlikely inside window_s), dense
                     traffic still batches up to the cap
    coalesce_target  requests the adaptive window aims to coalesce
                     per fused call (the EWMA gap multiplier)
    """

    window_s: float = 0.002
    max_batch_rows: int = 64
    maintenance: bool = False
    daemon_interval_s: float = 0.002
    adaptive_window: bool = False
    coalesce_target: int = 8


class FrontDoor:
    """Admission queue + micro-batching dispatcher over one MicroNN."""

    def __init__(self, engine, config: Optional[FrontDoorConfig] = None,
                 **overrides):
        """`FrontDoor(eng)` with defaults, or pass a FrontDoorConfig /
        kwarg overrides (`FrontDoor(eng, window_s=0.005,
        maintenance=True)`)."""
        cfg = config or FrontDoorConfig()
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        self.engine = engine
        self.config = cfg
        self._queue: deque = deque()
        self._cv = threading.Condition()
        self._stop = False
        self._closed = False
        self._inflight = 0          # requests handed to the executor
        # -- registry metrics (PR 8) ---------------------------------------
        # Each front door gets its own `fd` instance label: a closed and
        # re-opened front door on the same engine starts its serving
        # counters at zero (stats() is per-front-door, not cumulative
        # across attachments), while still living in the ONE process
        # registry for snapshot()/to_prometheus().
        base = getattr(engine, "metrics", None)
        if base is None:
            base = obs_metrics.default_registry().scope(
                inst=obs_metrics.next_instance())
        metrics = base.scope(component="frontdoor",
                             fd=obs_metrics.next_instance())
        self.metrics = metrics
        self._c_submitted = metrics.counter("submitted")
        self._c_completed = metrics.counter("completed")
        self._c_failed = metrics.counter("failed")
        self._c_coalesced = metrics.counter("coalesced")
        self._c_batches = metrics.counter("batches")
        self._c_solo = metrics.counter("solo")
        self._c_occupancy = metrics.counter("batch_occupancy_sum")
        self._h_wait = metrics.histogram("queue_wait_s")
        self._h_exec = metrics.histogram("execute_s")
        self._h_total = metrics.histogram("total_s")
        # adaptive coalescing window (PR 9): EWMA of inter-arrival gaps
        # observed at submit(), and the effective window the dispatcher
        # last used -- both surfaced as registry gauges + stats() keys
        self._ewma_gap_s: Optional[float] = None
        self._last_arrival_s: Optional[float] = None
        self._window_s = cfg.window_s
        self._g_window = metrics.gauge("window_s")
        self._g_window.set(cfg.window_s)
        self._g_ewma = metrics.gauge("arrival_ewma_s")
        # -- threads -------------------------------------------------------
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="micronn-frontdoor",
            daemon=True)
        self._dispatcher.start()
        self._owns_daemon = False
        if cfg.maintenance:
            engine.scheduler.start_daemon(
                idle=self.queue_idle, interval_s=cfg.daemon_interval_s)
            self._owns_daemon = True
        engine._frontdoor = self

    # -- client API ----------------------------------------------------------
    def submit(self, vecs: np.ndarray,
               spec: Optional[QuerySpec] = None, *,
               trace: bool = False) -> Future:
        """Admit one query (a [q, d] batch or a single [d] vector) and
        return a Future resolving to its ResultSet. Thread-safe.

        `trace=True` attaches a per-caller QueryTrace to the resolved
        ResultSet (`rs.trace`): the caller's own queue_wait + its slice
        of the coalesced batch, adopting the shared fused-call spans."""
        spec = QuerySpec() if spec is None else spec
        v = np.atleast_2d(np.asarray(vecs, np.float32))
        # flight-recorder hook (PR 10): one global load + branch when
        # recording is off. Captured at admission (the Future has not
        # resolved, so no result digest -- replay double-executes these)
        rec = obs_recorder._ACTIVE
        if rec is not None:
            rec.record(obs_recorder.SITE_FRONTDOOR, self.engine.tenant,
                       v, spec)
        tr = None
        if trace and obs_trace.enabled():
            tr = obs_trace.QueryTrace(
                mode="paged" if self.engine.paged else "resident")
            tr.n_queries = int(v.shape[0])
        req = _Request(vecs=v, spec=spec, future=Future(),
                       t_submit=time.monotonic(), n=int(v.shape[0]),
                       trace=tr)
        with self._cv:
            if self._closed:
                raise RuntimeError("FrontDoor is closed")
            self._queue.append(req)
            self._c_submitted.inc()
            if self.config.adaptive_window:
                # EWMA of inter-arrival gaps (alpha=0.2): the signal the
                # dispatcher sizes its coalescing window from
                last = self._last_arrival_s
                if last is not None:
                    gap = req.t_submit - last
                    e = self._ewma_gap_s
                    self._ewma_gap_s = gap if e is None \
                        else 0.2 * gap + 0.8 * e
                    self._g_ewma.set(self._ewma_gap_s)
                self._last_arrival_s = req.t_submit
            self._cv.notify_all()
        return req.future

    def submit_async(self, vecs: np.ndarray,
                     spec: Optional[QuerySpec] = None, *,
                     trace: bool = False) -> "asyncio.Future":
        """`submit()` for asyncio callers: the same admission queue and
        coalescing, returned as an awaitable asyncio Future bound to the
        RUNNING event loop (call from a coroutine / loop context). The
        dispatcher thread resolves the underlying concurrent Future and
        asyncio marshals the result back onto the loop -- no thread may
        block the loop, so one async server task per request coalesces
        exactly like N caller threads would."""
        import asyncio
        return asyncio.wrap_future(self.submit(vecs, spec, trace=trace))

    async def query_async(self, vecs: np.ndarray,
                          spec: Optional[QuerySpec] = None, *,
                          trace: bool = False) -> ResultSet:
        """Awaitable `query()`: the drop-in replacement for
        `engine.query(vecs, spec)` inside a coroutine."""
        return await self.submit_async(vecs, spec, trace=trace)

    def query(self, vecs: np.ndarray, spec: Optional[QuerySpec] = None,
              timeout: Optional[float] = None, *,
              trace: bool = False) -> ResultSet:
        """Blocking submit: the drop-in replacement for
        `engine.query(vecs, spec)` from any caller thread."""
        return self.submit(vecs, spec, trace=trace).result(timeout)

    def queue_idle(self) -> bool:
        """True when no request is queued or executing -- the daemon
        scheduler's back-pressure probe."""
        return not self._queue and self._inflight == 0

    def drain(self, timeout: float = 10.0):
        """Block until every admitted request has completed (test/bench
        quiesce point)."""
        deadline = time.monotonic() + timeout
        while not self.queue_idle():
            if time.monotonic() > deadline:
                raise TimeoutError("front door did not drain in time")
            time.sleep(0.0005)

    def close(self, timeout: float = 10.0):
        """Stop the dispatcher (after finishing queued requests) and the
        maintenance daemon this front door started. Idempotent."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
            self._stop = True
            self._cv.notify_all()
        self._dispatcher.join(timeout)
        if self._owns_daemon:
            self.engine.scheduler.stop_daemon()
        if getattr(self.engine, "_frontdoor", None) is self:
            self.engine._frontdoor = None

    def __enter__(self) -> "FrontDoor":
        return self

    def __exit__(self, exc_type, exc, tb):
        self.close()
        return False

    # -- dispatcher ----------------------------------------------------------
    def _effective_window(self) -> float:
        """The coalescing wait for this drain. Fixed mode: window_s.
        Adaptive mode: enough EWMA inter-arrival gaps to gather
        ~coalesce_target requests, clamped to [0, window_s] -- and 0
        outright when even ONE more arrival is unlikely inside window_s
        (waiting would add latency and coalesce nothing)."""
        cfg = self.config
        if not cfg.adaptive_window:
            return cfg.window_s
        gap = self._ewma_gap_s
        if gap is None:                 # no signal yet: fixed behavior
            w = cfg.window_s
        elif gap >= cfg.window_s:
            w = 0.0
        else:
            w = min(cfg.window_s,
                    gap * max(cfg.coalesce_target - 1, 1))
        self._window_s = w
        self._g_window.set(w)
        return w

    def _dispatch_loop(self):
        cfg = self.config
        while True:
            with self._cv:
                while not self._queue and not self._stop:
                    self._cv.wait()
                if self._stop and not self._queue:
                    return
                # micro-batching window: wait (woken per arrival) until
                # the window closes or enough rows queued for a full call
                window = self._effective_window() if cfg.window_s > 0 \
                    else 0.0
                if window > 0:
                    deadline = time.monotonic() + window
                    while not self._stop:
                        if sum(r.n for r in self._queue) \
                                >= cfg.max_batch_rows:
                            break
                        left = deadline - time.monotonic()
                        if left <= 0:
                            break
                        self._cv.wait(left)
                batch = list(self._queue)
                self._queue.clear()
                self._inflight += len(batch)
            # group by spec, preserving arrival order within each group;
            # the spec is frozen + hashable (it IS the jit cache key), so
            # the grouping key and the compile key coincide by design
            groups: Dict[QuerySpec, List[_Request]] = {}
            for r in batch:
                groups.setdefault(r.spec, []).append(r)
            for spec, reqs in groups.items():
                # cap fused-call size: chunk the group at max_batch_rows
                start, rows = 0, 0
                for i, r in enumerate(reqs):
                    if rows and rows + r.n > cfg.max_batch_rows:
                        self._execute(spec, reqs[start:i])
                        start, rows = i, 0
                    rows += r.n
                self._execute(spec, reqs[start:])

    def _exec_guard(self, spec: QuerySpec):
        """Serialize execution against writers ONLY when reads cannot be
        snapshot-isolated: an in-memory store shares one connection, so
        paged faults / attr gathers there must not observe an open write
        transaction. File-backed stores read through the WAL snapshot
        connection and need no lock."""
        eng = self.engine
        if not eng.store.snapshot_reads and (eng.paged or spec.gather_attrs):
            return eng.lock
        return contextlib.nullcontext()

    def _execute(self, spec: QuerySpec, reqs: List[_Request]):
        if not reqs:
            return
        # Any traced caller in the batch? Record ONE shared trace around
        # the fused call (activated thread-locally on this dispatcher
        # thread, so the plan/probe/fault/scan spans every layer records
        # land in it), then hand each traced caller a per-caller view.
        shared = None
        if obs_trace.enabled() and any(r.trace is not None for r in reqs):
            shared = obs_trace.QueryTrace(
                mode="paged" if self.engine.paged else "resident")
        t0 = time.monotonic()
        try:
            with self._exec_guard(spec), obs_trace.activate(shared):
                if len(reqs) == 1:
                    results = [self.engine.query(reqs[0].vecs, spec)]
                else:
                    results = self.engine.query_batched(
                        [r.vecs for r in reqs], spec)
        except BaseException as e:  # noqa: BLE001 -- fail the callers
            self._c_failed.inc(len(reqs))
            for r in reqs:
                r.future.set_exception(e)
            with self._cv:
                self._inflight -= len(reqs)
            return
        t1 = time.monotonic()
        if shared is not None:
            shared.finish()
        if len(reqs) > 1:
            self._c_batches.inc()
            self._c_coalesced.inc(len(reqs))
            self._c_occupancy.inc(len(reqs))
        else:
            self._c_solo.inc()
        self._c_completed.inc(len(reqs))
        for r in reqs:
            self._h_wait.observe(t0 - r.t_submit)
            self._h_exec.observe(t1 - t0)
            self._h_total.observe(t1 - r.t_submit)
        ring = getattr(self.engine, "traces", None)
        for r, rs in zip(reqs, results):
            if r.trace is not None and shared is not None:
                tr = r.trace
                tr.record(obs_trace.STAGE_QUEUE,
                          (t0 - r.t_submit) * 1e3, rows=r.n)
                if len(reqs) > 1:
                    tr.record(obs_trace.STAGE_SPLIT, 0.0,
                              callers=len(reqs), rows=r.n,
                              batch_rows=sum(x.n for x in reqs))
                tr.adopt(shared)
                tr.finish()
                tr.result = rs
                rs.trace = tr
                if ring is not None:
                    ring.append(tr)
            r.future.set_result(rs)
        with self._cv:
            self._inflight -= len(reqs)
        # queue just (possibly) went idle: let the maintenance daemon
        # use the gap rather than waiting out its poll interval
        if self._owns_daemon and self.queue_idle():
            self.engine.scheduler.kick()

    # -- observability --------------------------------------------------------
    def stats(self) -> Dict:
        """Serving counters + latency percentiles (ms). Keys match
        empty_stats(); MicroNN.stats() embeds this dict under
        "frontdoor", so resident and paged engines report uniformly.
        All values are derived views over this front door's registry
        series (one source of truth for stats(), BENCH snapshots, and
        the Prometheus exporter)."""
        batches = self._c_batches.value
        out = {
            "queued": len(self._queue),
            "inflight": self._inflight,
            "submitted": self._c_submitted.value,
            "completed": self._c_completed.value,
            "failed": self._c_failed.value,
            "coalesced": self._c_coalesced.value,
            "batches": batches,
            "solo": self._c_solo.value,
            "batch_occupancy": (self._c_occupancy.value / batches)
            if batches else 0.0,
        }
        for name, h in (("queue_wait", self._h_wait),
                        ("execute", self._h_exec),
                        ("total", self._h_total)):
            out[f"{name}_p50_ms"] = h.quantile(0.50) * 1e3
            out[f"{name}_p99_ms"] = h.quantile(0.99) * 1e3
        out["window_ms"] = self._window_s * 1e3
        out["arrival_ewma_ms"] = (self._ewma_gap_s or 0.0) * 1e3
        return out
