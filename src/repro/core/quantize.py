"""Per-dimension int8 scalar quantization (the paper's low-memory tier).

The paper's headline memory number (top-100 @ 90% recall in <7 ms using
~10 MB on a million-scale benchmark) relies on scanning *compact codes*
and reranking a small candidate set at full precision. This module is the
code side of that design:

  * training: per-dimension min/max over the stored vectors (streamed from
    the durable tier -- never the full dataset in memory), giving an
    asymmetric affine code  c = round((x - lo) / scale) - 128  in int8;
  * `encode` / `decode` are pure jittable maps; encoding is deterministic,
    so re-encoding a row always reproduces the stored code (maintenance
    relies on this when it moves rows between tiers);
  * `QuantStats` is a pytree carried on `IVFIndex`, so the quantized index
    remains one jit-compatible value (the stats ride along with the codes
    through updates, flushes and sharding).

Distance contract (asymmetric distance computation, Faiss-style): queries
stay float32, codes are dequantized in-register inside the scan kernel
(kernels/sq_scan.py) and distances accumulate in float32. The scan
over-fetches `k' = rerank_factor * k` candidates; core/executor.py then
recomputes exact float32 distances for just those rows (the rerank stage)
before the final top-k -- recall loss from quantization is confined to
candidate *selection*, never to the reported scores.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .types import normalize_if_cosine, register_dataclass

# Number of representable levels: codes span [-128, 127] <-> [0, 255].
LEVELS = 255
# Guard against zero-width dimensions (constant columns).
MIN_SCALE = 1e-12


@register_dataclass
@dataclasses.dataclass
class QuantStats:
    """Per-dimension affine int8 quantizer parameters (a pytree)."""

    lo: jax.Array      # [d] f32 -- per-dimension minimum
    scale: jax.Array   # [d] f32 -- (hi - lo) / LEVELS, floored at MIN_SCALE

    @property
    def dim(self) -> int:
        return self.lo.shape[0]


def train(X: jax.Array) -> QuantStats:
    """Fit per-dimension min/max stats from a [n, d] sample.

    The caller is responsible for metric normalisation (cosine indexes
    store L2-normalised rows, so stats must be trained on those).
    """
    X = jnp.asarray(X, jnp.float32)
    if X.shape[0] == 0:
        return QuantStats(lo=jnp.zeros((X.shape[1],), jnp.float32),
                          scale=jnp.ones((X.shape[1],), jnp.float32))
    lo = X.min(axis=0)
    hi = X.max(axis=0)
    scale = jnp.maximum((hi - lo) / LEVELS, MIN_SCALE)
    return QuantStats(lo=lo, scale=scale)


def train_from_store(store, metric: str = "l2",
                     batch_size: int = 4096) -> QuantStats:
    """Streaming min/max over the durable tier (storage.VectorStore) --
    one pass of `iter_batches`, never the full dataset in host memory."""
    lo: Optional[np.ndarray] = None
    hi: Optional[np.ndarray] = None
    for batch in store.iter_batches(batch_size):
        b = np.asarray(
            normalize_if_cosine(jnp.asarray(batch, jnp.float32), metric))
        blo, bhi = b.min(axis=0), b.max(axis=0)
        lo = blo if lo is None else np.minimum(lo, blo)
        hi = bhi if hi is None else np.maximum(hi, bhi)
    if lo is None:
        lo = np.zeros((store.dim,), np.float32)
        hi = lo
    scale = np.maximum((hi - lo) / LEVELS, MIN_SCALE)
    return QuantStats(lo=jnp.asarray(lo, jnp.float32),
                      scale=jnp.asarray(scale, jnp.float32))


def encode(stats: QuantStats, x: jax.Array) -> jax.Array:
    """[..., d] float32 -> [..., d] int8 codes (deterministic round)."""
    q = jnp.round((jnp.asarray(x, jnp.float32) - stats.lo) / stats.scale)
    return (jnp.clip(q, 0, LEVELS) - 128).astype(jnp.int8)


def decode(stats: QuantStats, codes: jax.Array) -> jax.Array:
    """[..., d] int8 codes -> [..., d] float32 reconstruction."""
    return (codes.astype(jnp.float32) + 128.0) * stats.scale + stats.lo


def encode_np(stats: QuantStats, x: np.ndarray) -> np.ndarray:
    """Host-side encode (used by the pack/repack maintenance paths)."""
    return np.asarray(encode(stats, jnp.asarray(x, jnp.float32)))


def fold_queries(stats: QuantStats, q: jax.Array):
    """Fold f32 queries into the int8 distance domain (the MXU scan's
    query-side preparation, done ONCE per scan).

    The dequantized dot against a code row c expands as

        q . v = q . ((c + 128) * scale + lo)
              = (q * scale) . c + 128 * sum(q * scale) + q . lo

    so with w = q * scale the whole affine correction collapses to a
    rank-1 epilogue around the integer product w~ . c. The query weights
    are encoded in TWO int8 terms (primary + residual):

        q1 = round(w * 127 / A1),  A1 = max|w|
        q2 = round(r * 127 / A2),  r = w - (A1/127) q1, A2 = max|r|
        w~ = alpha1 q1 + alpha2 q2,  alpha_i = A_i / 127

    The residual term costs one extra row per query in the (bandwidth-
    bound) int8 matmul but drops the query-side rounding error from
    ~2^-8 to ~2^-15 relative -- small enough that candidate selection
    matches the dequantize-then-f32 scan on real data (the recall pin at
    rerank_factor=1), while the arithmetic stays pure int8 x int8 on the
    MXU. The epilogue is then

        q . v ~= alpha1 (q1 . c) + alpha2 (q2 . c) + beta,
        beta  = 128 (alpha1 sum(q1) + alpha2 sum(q2)) + q . lo.

    Returns the STACKED form consumed by the scan backends:
    (q_i8 [2Q, d] int8 = [q1; q2], alpha [2Q] f32 = [alpha1; alpha2],
    beta [Q] f32). Consumers compute acc = q_i8 . c as one [2Q, m]
    integer matmul and reduce dots = (alpha * acc)[:Q] + (alpha *
    acc)[Q:] + beta. Both scan backends call this one helper, so they
    fold identical values by construction.
    """
    q = jnp.asarray(q, jnp.float32)
    w = q * stats.scale[None, :]                       # [Q, d]
    a1 = jnp.maximum(jnp.max(jnp.abs(w), axis=-1), MIN_SCALE)  # [Q]
    q1 = jnp.round(w * (127.0 / a1[:, None])).astype(jnp.int8)
    alpha1 = a1 / 127.0
    r = w - alpha1[:, None] * q1.astype(jnp.float32)   # rounding residual
    a2 = jnp.maximum(jnp.max(jnp.abs(r), axis=-1), MIN_SCALE)
    q2 = jnp.round(r * (127.0 / a2[:, None])).astype(jnp.int8)
    alpha2 = a2 / 127.0
    q_i8 = jnp.concatenate([q1, q2], axis=0)           # [2Q, d]
    alpha = jnp.concatenate([alpha1, alpha2], axis=0)  # [2Q]
    beta = 128.0 * (alpha1 * jnp.sum(q1.astype(jnp.float32), axis=-1)
                    + alpha2 * jnp.sum(q2.astype(jnp.float32), axis=-1)) \
        + q @ stats.lo
    return q_i8, alpha, beta


def row_norms(stats: QuantStats, codes: jax.Array) -> jax.Array:
    """[..., p, d] int8 codes -> [..., p] f32 squared reconstruction norms
    ||decode(c)||^2 -- the l2 scan's per-row constant, precomputed once at
    (re)pack time so the int8-domain scan never re-decodes the code tier
    (IVFIndex.code_norms). The in-scan fallback (paged frames) computes
    the same decode-then-reduce expression, so the two agree bitwise."""
    v = decode(stats, codes)
    return jnp.sum(v * v, axis=-1)


def stats_to_arrays(stats: QuantStats):
    return np.asarray(stats.lo, np.float32), np.asarray(stats.scale, np.float32)


def stats_from_arrays(lo: np.ndarray, scale: np.ndarray) -> QuantStats:
    return QuantStats(lo=jnp.asarray(lo, jnp.float32),
                      scale=jnp.asarray(scale, jnp.float32))
