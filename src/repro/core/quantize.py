"""Per-dimension int8 scalar quantization (the paper's low-memory tier).

The paper's headline memory number (top-100 @ 90% recall in <7 ms using
~10 MB on a million-scale benchmark) relies on scanning *compact codes*
and reranking a small candidate set at full precision. This module is the
code side of that design:

  * training: per-dimension min/max over the stored vectors (streamed from
    the durable tier -- never the full dataset in memory), giving an
    asymmetric affine code  c = round((x - lo) / scale) - 128  in int8;
  * `encode` / `decode` are pure jittable maps; encoding is deterministic,
    so re-encoding a row always reproduces the stored code (maintenance
    relies on this when it moves rows between tiers);
  * `QuantStats` is a pytree carried on `IVFIndex`, so the quantized index
    remains one jit-compatible value (the stats ride along with the codes
    through updates, flushes and sharding).

Distance contract (asymmetric distance computation, Faiss-style): queries
stay float32, codes are dequantized in-register inside the scan kernel
(kernels/sq_scan.py) and distances accumulate in float32. The scan
over-fetches `k' = rerank_factor * k` candidates; core/executor.py then
recomputes exact float32 distances for just those rows (the rerank stage)
before the final top-k -- recall loss from quantization is confined to
candidate *selection*, never to the reported scores.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .types import normalize_if_cosine, register_dataclass

# Number of representable levels: codes span [-128, 127] <-> [0, 255].
LEVELS = 255
# Guard against zero-width dimensions (constant columns).
MIN_SCALE = 1e-12


@register_dataclass
@dataclasses.dataclass
class QuantStats:
    """Per-dimension affine int8 quantizer parameters (a pytree)."""

    lo: jax.Array      # [d] f32 -- per-dimension minimum
    scale: jax.Array   # [d] f32 -- (hi - lo) / LEVELS, floored at MIN_SCALE

    @property
    def dim(self) -> int:
        return self.lo.shape[0]


def train(X: jax.Array) -> QuantStats:
    """Fit per-dimension min/max stats from a [n, d] sample.

    The caller is responsible for metric normalisation (cosine indexes
    store L2-normalised rows, so stats must be trained on those).
    """
    X = jnp.asarray(X, jnp.float32)
    if X.shape[0] == 0:
        return QuantStats(lo=jnp.zeros((X.shape[1],), jnp.float32),
                          scale=jnp.ones((X.shape[1],), jnp.float32))
    lo = X.min(axis=0)
    hi = X.max(axis=0)
    scale = jnp.maximum((hi - lo) / LEVELS, MIN_SCALE)
    return QuantStats(lo=lo, scale=scale)


def train_from_store(store, metric: str = "l2",
                     batch_size: int = 4096) -> QuantStats:
    """Streaming min/max over the durable tier (storage.VectorStore) --
    one pass of `iter_batches`, never the full dataset in host memory."""
    lo: Optional[np.ndarray] = None
    hi: Optional[np.ndarray] = None
    for batch in store.iter_batches(batch_size):
        b = np.asarray(
            normalize_if_cosine(jnp.asarray(batch, jnp.float32), metric))
        blo, bhi = b.min(axis=0), b.max(axis=0)
        lo = blo if lo is None else np.minimum(lo, blo)
        hi = bhi if hi is None else np.maximum(hi, bhi)
    if lo is None:
        lo = np.zeros((store.dim,), np.float32)
        hi = lo
    scale = np.maximum((hi - lo) / LEVELS, MIN_SCALE)
    return QuantStats(lo=jnp.asarray(lo, jnp.float32),
                      scale=jnp.asarray(scale, jnp.float32))


def encode(stats: QuantStats, x: jax.Array) -> jax.Array:
    """[..., d] float32 -> [..., d] int8 codes (deterministic round)."""
    q = jnp.round((jnp.asarray(x, jnp.float32) - stats.lo) / stats.scale)
    return (jnp.clip(q, 0, LEVELS) - 128).astype(jnp.int8)


def decode(stats: QuantStats, codes: jax.Array) -> jax.Array:
    """[..., d] int8 codes -> [..., d] float32 reconstruction."""
    return (codes.astype(jnp.float32) + 128.0) * stats.scale + stats.lo


def encode_np(stats: QuantStats, x: np.ndarray) -> np.ndarray:
    """Host-side encode (used by the pack/repack maintenance paths)."""
    return np.asarray(encode(stats, jnp.asarray(x, jnp.float32)))


def stats_to_arrays(stats: QuantStats):
    return np.asarray(stats.lo, np.float32), np.asarray(stats.scale, np.float32)


def stats_from_arrays(lo: np.ndarray, scale: np.ndarray) -> QuantStats:
    return QuantStats(lo=jnp.asarray(lo, jnp.float32),
                      scale=jnp.asarray(scale, jnp.float32))
