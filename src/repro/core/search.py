"""ANN / exact KNN search (paper Alg. 2): kwarg shims over QuerySpecs.

The public object model lives in core/query.py: a frozen `QuerySpec`
built with the fluent `Q` builder is THE query representation (and the
executor's jit cache key), and `ResultSet` is the typed result every
path returns. The entry points here survive as thin shims that compile
their arguments into a spec and hand it to `executor.run`, which builds
the QueryPlan (probe set + per-query selection mask + optional fused
attribute predicate + k) and runs one fused scan primitive on either the
Pallas TPU kernel or the shape-identical XLA reference backend.

Faithful structure (now encoded as specs -> plans):
  1. scan centroids, pick the n nearest partitions          (FindNearestCentroids)
  2. always include the delta partition                     (§3.6)
  3. scan chosen partitions, batched distance via matmul    (SIMD -> MXU)
  4. maintain top-k (heaps -> masked running top-k buffers) (§3.3)
  5. merge + sort final results

Attribute post-filtering is fused *before* the top-k, reproducing the
paper's optimization: "vectors in the requested partitions that don't
satisfy the predicate filter are filtered before being considered in the
top-K computation" (§3.5) -- inside the kernel on the Pallas backend.
"""
from __future__ import annotations

from typing import Optional

import jax

from . import executor
from .executor import AttrFilter, find_nearest_centroids  # noqa: F401 (re-export)
from .query import Q, QuerySpec, ResultSet  # noqa: F401 (re-export)
from .types import INVALID_ID, IVFIndex

import jax.numpy as jnp


def ann_search(
    index: IVFIndex,
    queries: jax.Array,            # [Q, d]
    k: int,
    n_probe: int,
    attr_filter: Optional[AttrFilter] = None,
    backend: Optional[str] = None,
) -> ResultSet:
    """Alg. 2 as an ANN spec: per-query probe sets scanned as one shared
    union with a selection mask (no per-query partition gather)."""
    spec = Q.knn(k=k, n_probe=n_probe).backend(backend)
    if attr_filter is not None:
        spec = spec.where(attr_filter).postfilter()
    return executor.run(index, queries, spec)


def exact_search(
    index: IVFIndex,
    queries: jax.Array,
    k: int,
    attr_filter: Optional[AttrFilter] = None,
    backend: Optional[str] = None,
) -> ResultSet:
    """Brute-force KNN over every live row (paper: 'trivial but resource
    intensive'); also the 100%-recall oracle for tests/benchmarks.
    Spec: kind "exact" -- probe set = all partitions, no selection mask."""
    spec = Q.exact(k=k).backend(backend)
    if attr_filter is not None:
        spec = spec.where(attr_filter)
    return executor.run(index, queries, spec)


def prefilter_search(
    index: IVFIndex,
    queries: jax.Array,
    k: int,
    attr_filter: AttrFilter,
    cap: int,
    backend: Optional[str] = None,
) -> ResultSet:
    """Pre-filtering spec (paper §3.5): evaluate the predicate first, fetch
    only qualifying rows, brute-force over that subset (100% recall).

    `cap` is the static gather budget; the optimizer sizes it from the
    selectivity estimate (x safety margin). Cost scales with `cap`, i.e.
    with predicate selectivity -- matching the paper's latency behaviour.
    """
    spec = Q.knn(k=k).where(attr_filter).prefilter(cap).backend(backend)
    return executor.run(index, queries, spec)


def recall_at_k(approx, exact, k: int) -> jax.Array:
    """recall@k: |approx top-k  ∩  exact top-k| / k (paper's metric)."""
    a = approx.ids[:, :k]
    e = exact.ids[:, :k]
    hits = (a[:, :, None] == e[:, None, :]) & (a[:, :, None] != INVALID_ID)
    # denominator: number of real results in the exact set (handles tiny dbs)
    denom = jnp.maximum((e != INVALID_ID).sum(-1), 1)
    return (hits.any(-1).sum(-1) / denom).mean()
