"""ANN / exact KNN search (paper Alg. 2) over the device-resident index.

Faithful structure:
  1. scan centroids, pick the n nearest partitions          (FindNearestCentroids)
  2. always include the delta partition                     (§3.6)
  3. scan chosen partitions, batched distance via matmul    (SIMD -> MXU)
  4. maintain top-k (heaps -> masked running top-k buffers) (§3.3)
  5. merge + sort final results

Attribute post-filtering is fused *before* the top-k, reproducing the
paper's optimization: "vectors in the requested partitions that don't
satisfy the predicate filter are filtered before being considered in the
top-K computation" (§3.5).

All functions are jit-compatible with static (k, n_probe); the batch-MQO
variant lives in core/mqo.py and the Pallas-tiled single-pass scan in
kernels/ivf_scan.py.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .topk import dedup_by_id, mask_scores, topk_smallest
from .types import (INVALID_ID, IVFIndex, SearchResult, normalize_if_cosine,
                    pairwise_scores)

# attr_filter: [..., n_attr] float32 -> [...] bool  (from hybrid.compile_filter)
AttrFilter = Callable[[jax.Array], jax.Array]


def find_nearest_centroids(index: IVFIndex, q: jax.Array, n_probe: int):
    """[Q, d] -> [Q, n_probe] partition ids (line 3 of Alg. 2)."""
    cd = pairwise_scores(q, index.centroids, index.config.metric)
    # Empty partitions can never contribute; push them out of the probe set.
    cd = jnp.where(index.counts[None, :] > 0, cd, jnp.finfo(cd.dtype).max)
    n_probe = min(n_probe, index.k)
    _, parts = jax.lax.top_k(-cd, n_probe)
    return parts


def _delta_scores(index: IVFIndex, q: jax.Array, attr_filter: Optional[AttrFilter]):
    """Score the delta partition (always scanned, §3.6)."""
    d = index.delta
    scores = pairwise_scores(q, d.vectors, index.config.metric)  # [Q, cap]
    ok = d.valid
    if attr_filter is not None:
        ok = ok & attr_filter(d.attrs)
    return mask_scores(scores, ok[None, :]), jnp.broadcast_to(
        d.ids[None, :], scores.shape)


@partial(jax.jit, static_argnames=("k", "n_probe", "attr_filter"))
def ann_search(
    index: IVFIndex,
    queries: jax.Array,            # [Q, d]
    k: int,
    n_probe: int,
    attr_filter: Optional[AttrFilter] = None,
) -> SearchResult:
    """Alg. 2: per-query partition gather + fused scan. Best for small Q;
    large batches should use mqo.mqo_search (paper §3.4)."""
    cfg = index.config
    q = normalize_if_cosine(queries.astype(jnp.float32), cfg.metric)
    parts = find_nearest_centroids(index, q, n_probe)      # [Q, n]

    pv = index.vectors[parts]                              # [Q, n, p_max, d]
    pid = index.ids[parts]                                 # [Q, n, p_max]
    pok = index.valid[parts]
    if attr_filter is not None:
        pok = pok & attr_filter(index.attrs[parts])

    dots = jnp.einsum("qd,qnpd->qnp", q, pv)
    if cfg.metric in ("ip", "cosine"):
        scores = -dots
    else:
        q2 = jnp.sum(q * q, axis=-1)[:, None, None]
        v2 = jnp.sum(pv * pv, axis=-1)
        scores = q2 + v2 - 2.0 * dots
    scores = mask_scores(scores, pok)

    Q = q.shape[0]
    flat_s = scores.reshape(Q, -1)
    flat_i = pid.reshape(Q, -1)

    ds, di = _delta_scores(index, q, attr_filter)
    all_s = jnp.concatenate([flat_s, ds], axis=-1)
    all_i = jnp.concatenate([flat_i, di], axis=-1)
    s, i = topk_smallest(all_s, all_i, min(k, all_s.shape[-1]))
    s, i = dedup_by_id(s, i)
    return SearchResult(ids=i, scores=s)


@partial(jax.jit, static_argnames=("k", "attr_filter"))
def exact_search(
    index: IVFIndex,
    queries: jax.Array,
    k: int,
    attr_filter: Optional[AttrFilter] = None,
) -> SearchResult:
    """Brute-force KNN over every live row (paper: 'trivial but resource
    intensive'); also the 100%-recall oracle for tests/benchmarks."""
    cfg = index.config
    q = normalize_if_cosine(queries.astype(jnp.float32), cfg.metric)
    kp, p_max, d = index.vectors.shape
    flat_v = index.vectors.reshape(kp * p_max, d)
    flat_id = index.ids.reshape(-1)
    ok = index.valid.reshape(-1)
    if attr_filter is not None:
        ok = ok & attr_filter(index.attrs.reshape(kp * p_max, -1))
    scores = pairwise_scores(q, flat_v, cfg.metric)
    scores = mask_scores(scores, ok[None, :])

    ds, di = _delta_scores(index, q, attr_filter)
    all_s = jnp.concatenate([scores, ds], axis=-1)
    all_i = jnp.concatenate([jnp.broadcast_to(flat_id[None, :], scores.shape), di],
                            axis=-1)
    s, i = topk_smallest(all_s, all_i, min(k, all_s.shape[-1]))
    s, i = dedup_by_id(s, i)
    return SearchResult(ids=i, scores=s)


@partial(jax.jit, static_argnames=("k", "cap", "attr_filter"))
def prefilter_search(
    index: IVFIndex,
    queries: jax.Array,
    k: int,
    attr_filter: AttrFilter,
    cap: int,
) -> SearchResult:
    """Pre-filtering plan (paper §3.5): evaluate the predicate first, fetch
    only qualifying rows, brute-force over that subset (100% recall).

    `cap` is the static gather budget; the optimizer sizes it from the
    selectivity estimate (x safety margin). Cost scales with `cap`, i.e.
    with predicate selectivity -- matching the paper's latency behaviour.
    """
    cfg = index.config
    q = normalize_if_cosine(queries.astype(jnp.float32), cfg.metric)
    kp, p_max, d = index.vectors.shape
    n_attr = index.attrs.shape[-1]

    ok = index.valid.reshape(-1) & attr_filter(index.attrs.reshape(-1, n_attr))
    # Fixed-size compaction of qualifying row indices (device analogue of
    # the SQLite b-tree row-id fetch).
    (rows,) = jnp.nonzero(ok, size=cap, fill_value=kp * p_max)
    got = rows < kp * p_max
    rows = jnp.minimum(rows, kp * p_max - 1)
    sub_v = index.vectors.reshape(-1, d)[rows]
    sub_i = jnp.where(got, index.ids.reshape(-1)[rows], INVALID_ID)

    scores = pairwise_scores(q, sub_v, cfg.metric)
    scores = mask_scores(scores, got[None, :])

    ds, di = _delta_scores(index, q, attr_filter)
    all_s = jnp.concatenate([scores, ds], axis=-1)
    all_i = jnp.concatenate([jnp.broadcast_to(sub_i[None, :], scores.shape), di],
                            axis=-1)
    s, i = topk_smallest(all_s, all_i, min(k, all_s.shape[-1]))
    s, i = dedup_by_id(s, i)
    return SearchResult(ids=i, scores=s)


def recall_at_k(approx: SearchResult, exact: SearchResult, k: int) -> jax.Array:
    """recall@k: |approx top-k  ∩  exact top-k| / k (paper's metric)."""
    a = approx.ids[:, :k]
    e = exact.ids[:, :k]
    hits = (a[:, :, None] == e[:, None, :]) & (a[:, :, None] != INVALID_ID)
    # denominator: number of real results in the exact set (handles tiny dbs)
    denom = jnp.maximum((e != INVALID_ID).sum(-1), 1)
    return (hits.any(-1).sum(-1) / denom).mean()
