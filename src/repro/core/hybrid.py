"""Hybrid queries: attribute predicates + selectivity estimation (paper §3.5).

Attributes are float32 columns aligned to the vector layout (ints coerce
losslessly below 2^24; the storage layer keeps the typed originals).
Predicates support the paper's operators (>, <, >=, <=, =, !=) plus MATCH
(token-set membership -- the FTS5 stand-in, see DESIGN.md §2 item 7) and
arbitrary AND/OR trees.

Selectivity estimation (paper §3.5.1): per-column equi-width histograms +
distinct counts; conjunctions take the min of child cardinalities,
disjunctions the (clamped) sum -- exactly the paper's independence
simplification (Eq. 3).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Predicate language
# ---------------------------------------------------------------------------

_OPS = ("lt", "le", "gt", "ge", "eq", "ne", "match")
# symbolic spellings accepted by the query builder; canonicalised at
# construction so structurally-equal predicates stay hash-equal
_OP_ALIASES = {"<": "lt", "<=": "le", ">": "gt", ">=": "ge",
               "==": "eq", "=": "eq", "!=": "ne"}


@dataclasses.dataclass(frozen=True)
class Pred:
    """Leaf predicate: attrs[..., col] <op> value.

    `op` accepts the canonical names ("lt", ..., "match") or symbolic
    aliases ("<", "==", ...), canonicalised at construction. `match`
    treats the column as a token bitset (each row holds an int bitmask of
    tags; value is the required tag bitmask) -- our stand-in for the
    paper's FTS MATCH over tag strings.
    """
    col: int
    op: str
    value: float

    def __post_init__(self):
        op = _OP_ALIASES.get(self.op, self.op)
        if op != self.op:
            object.__setattr__(self, "op", op)
        assert self.op in _OPS, self.op


@dataclasses.dataclass(frozen=True)
class And:
    children: Tuple["Node", ...]


@dataclasses.dataclass(frozen=True)
class Or:
    children: Tuple["Node", ...]


Node = Union[Pred, And, Or]


def _leaf_mask(p: Pred, attrs: jax.Array) -> jax.Array:
    col = attrs[..., p.col]
    v = p.value
    if p.op == "lt":
        return col < v
    if p.op == "le":
        return col <= v
    if p.op == "gt":
        return col > v
    if p.op == "ge":
        return col >= v
    if p.op == "eq":
        return col == v
    if p.op == "ne":
        return col != v
    # match: all tag bits of v present in the row bitset
    bits = jnp.uint32(int(v))
    return (col.astype(jnp.uint32) & bits) == bits


def eval_predicate(node: Node, attrs: jax.Array) -> jax.Array:
    """[..., n_attr] -> [...] bool."""
    if isinstance(node, Pred):
        return _leaf_mask(node, attrs)
    masks = [eval_predicate(c, attrs) for c in node.children]
    out = masks[0]
    for m in masks[1:]:
        out = (out & m) if isinstance(node, And) else (out | m)
    return out


# Compiled predicates are memoized on the frozen tree so the *same* node
# always yields the *same* callable object: jit caches key static args by
# identity/hash, so repeated hybrid queries with an equal predicate hit the
# executor's compile cache instead of retracing (predicate_id in the plan
# cache key of core/executor.py). FIFO-bounded: ad-hoc one-off predicates
# from a long-lived service must not grow memory forever (evicting a live
# predicate only costs a retrace on its next use, never correctness).
_FILTER_CACHE: Dict[tuple, "object"] = {}
_FILTER_CACHE_MAX = 1024


def compile_filter(node: Node):
    """Predicate tree -> hashable callable usable as a static jit arg."""
    key = _freeze(node)
    cached = _FILTER_CACHE.get(key)
    if cached is not None:
        return cached
    if len(_FILTER_CACHE) >= _FILTER_CACHE_MAX:
        _FILTER_CACHE.pop(next(iter(_FILTER_CACHE)))

    def fn(attrs: jax.Array) -> jax.Array:
        return eval_predicate(node, attrs)
    # make it stable under jit static-arg hashing
    fn.__name__ = f"filter_{hash(key) & 0xFFFFFFFF:x}"
    fn.predicate_id = fn.__name__
    # the source tree rides along so a QuerySpec built from a compiled
    # filter recovers the structurally-hashable predicate (core/query.py)
    fn.predicate = node
    _FILTER_CACHE[key] = fn
    return fn


def _freeze(node: Node):
    if isinstance(node, Pred):
        return (node.col, node.op, node.value)
    tag = "and" if isinstance(node, And) else "or"
    return (tag,) + tuple(_freeze(c) for c in node.children)


# ---------------------------------------------------------------------------
# Histograms & selectivity estimation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ColumnStats:
    lo: float
    hi: float
    counts: np.ndarray      # [bins]
    n_distinct: int
    n_rows: int
    is_bitset: bool = False  # MATCH columns: per-bit population counts
    bit_counts: np.ndarray | None = None  # [32]

    @property
    def bins(self) -> int:
        return len(self.counts)


class AttributeStats:
    """Per-column equi-width histograms over the live attribute rows."""

    def __init__(self, attrs: np.ndarray, bins: int = 64,
                 bitset_cols: Sequence[int] = ()):
        attrs = np.asarray(attrs, np.float64)
        self.n_rows = attrs.shape[0]
        self.cols: Dict[int, ColumnStats] = {}
        for c in range(attrs.shape[1]):
            col = attrs[:, c]
            lo, hi = (float(col.min()), float(col.max())) if len(col) else (0, 1)
            if hi <= lo:
                hi = lo + 1.0
            counts, _ = np.histogram(col, bins=bins, range=(lo, hi))
            bit_counts = None
            if c in bitset_cols:
                u = col.astype(np.uint32)
                bit_counts = np.array(
                    [int(((u >> b) & 1).sum()) for b in range(32)])
            self.cols[c] = ColumnStats(
                lo=lo, hi=hi, counts=counts,
                n_distinct=int(len(np.unique(col))) if len(col) else 1,
                n_rows=self.n_rows,
                is_bitset=c in bitset_cols,
                bit_counts=bit_counts)

    # -- cardinality of a leaf ------------------------------------------------
    def _leaf_card(self, p: Pred) -> float:
        st = self.cols[p.col]
        n = st.n_rows
        if n == 0:
            return 0.0
        if p.op == "match" and st.is_bitset:
            # independence across tag bits (paper's string-match estimator
            # analogue): sel = prod_b (bit_count_b / n) over required bits
            sel = 1.0
            bits = int(p.value)
            for b in range(32):
                if bits >> b & 1:
                    sel *= st.bit_counts[b] / n
            return sel * n
        if p.op in ("eq", "ne"):
            # skew-aware: the histogram bin's mass upper-bounds the value's
            # count; take the sharper of (uniform 1/n_distinct, bin mass)
            uniform = n / max(1, st.n_distinct)
            card = uniform
            width = (st.hi - st.lo) / st.bins
            if st.lo <= p.value <= st.hi and width > 0:
                bin_i = min(int((p.value - st.lo) / width), st.bins - 1)
                card = min(uniform, float(st.counts[bin_i]))
            return card if p.op == "eq" else n - card
        # range predicates: fractional histogram mass strictly below v
        width = (st.hi - st.lo) / st.bins
        if p.value <= st.lo:
            below = 0.0
        elif p.value >= st.hi:
            below = float(n)
        else:
            bin_i = min(int((p.value - st.lo) / width), st.bins - 1)
            frac = (p.value - (st.lo + bin_i * width)) / width
            below = float(st.counts[:bin_i].sum()
                          + st.counts[bin_i] * np.clip(frac, 0.0, 1.0))
        if p.op in ("lt", "le"):
            return below
        return n - below

    def cardinality(self, node: Node) -> float:
        """|sigma_filters(R)| estimate -- min over AND, sum over OR (Eq. 3)."""
        if isinstance(node, Pred):
            return self._leaf_card(node)
        cards = [self.cardinality(c) for c in node.children]
        if isinstance(node, And):
            return min(cards)
        return min(sum(cards), self.n_rows)

    def selectivity_factor(self, node: Node) -> float:
        """F_hat_filters (Eq. 3): min(card, |R|) / |R|."""
        if self.n_rows == 0:
            return 0.0
        return min(self.cardinality(node), self.n_rows) / self.n_rows
