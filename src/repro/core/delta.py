"""Streaming updates: upsert / delete via the delta-store (paper §3.6).

Semantics (faithful):
  * insert with upsert semantics -- a new vector for an existing asset id
    replaces the old one everywhere;
  * deletes tombstone rows (valid=False) without moving data;
  * newly inserted vectors live in the delta partition until maintenance
    flushes them into the IVF layout (core/maintenance.py);
  * every query always scans the delta partition, so readers see updates
    immediately (the consistency requirement of §2.1).

All update ops are pure jitted functions IVFIndex -> IVFIndex, so they
compose with pjit sharding; the host wrapper (storage.MicroNN) serialises
writers, mirrors each op durably in SQLite, and triggers flushes when the
delta cursor approaches capacity -- reproducing the paper's single-writer /
multi-reader regime.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from . import quantize
from .types import DeltaStore, INVALID_ID, IVFIndex, normalize_if_cosine


def _tombstone_main(index: IVFIndex, ids: jax.Array):
    """Invalidate any main-partition rows whose id appears in `ids`."""
    hit = (index.ids[:, :, None] == ids[None, None, :]).any(-1)  # [k, p_max]
    hit = hit & index.valid
    new_valid = index.valid & ~hit
    new_counts = index.counts - hit.sum(-1).astype(index.counts.dtype)
    return new_valid, new_counts


def _tombstone_delta(delta: DeltaStore, ids: jax.Array):
    hit = (delta.ids[:, None] == ids[None, :]).any(-1) & delta.valid
    return delta.valid & ~hit


@jax.jit
def upsert(index: IVFIndex, vecs: jax.Array, ids: jax.Array,
           attrs: jax.Array) -> IVFIndex:
    """Insert a batch of [B] rows with upsert semantics.

    Precondition (enforced by the host wrapper, which flushes first if
    needed): delta.count + B <= delta capacity.
    """
    cfg = index.config
    vecs = normalize_if_cosine(vecs.astype(jnp.float32), cfg.metric)
    B = vecs.shape[0]
    d = index.delta

    # 1. upsert semantics: tombstone any existing copies
    new_valid, new_counts = _tombstone_main(index, ids)
    dvalid = _tombstone_delta(d, ids)

    # 2. append at the write cursor (quantized tier: encode on insert, so
    # flush_delta can move codes verbatim instead of re-deriving them)
    slots = d.count + jnp.arange(B, dtype=jnp.int32)
    new_codes = d.codes
    if index.qstats is not None and d.codes is not None:
        new_codes = d.codes.at[slots].set(quantize.encode(index.qstats, vecs))
    new_delta = DeltaStore(
        vectors=d.vectors.at[slots].set(vecs),
        ids=d.ids.at[slots].set(ids.astype(jnp.int32)),
        attrs=d.attrs.at[slots].set(attrs.astype(jnp.float32)),
        valid=dvalid.at[slots].set(True),
        count=d.count + B,
        codes=new_codes,
    )
    return dataclasses.replace(index, valid=new_valid, counts=new_counts,
                               delta=new_delta)


@jax.jit
def delete(index: IVFIndex, ids: jax.Array) -> IVFIndex:
    """Tombstone a batch of asset ids (no-op for unknown ids)."""
    new_valid, new_counts = _tombstone_main(index, ids)
    dvalid = _tombstone_delta(index.delta, ids)
    return dataclasses.replace(
        index, valid=new_valid, counts=new_counts,
        delta=dataclasses.replace(index.delta, valid=dvalid))


def delta_only_upsert(delta: DeltaStore, vecs: jax.Array, ids: jax.Array,
                      attrs: jax.Array, metric: str,
                      qstats=None) -> DeltaStore:
    """Paged-mode insert: append into the delta store alone. The main tier
    lives in SQLite, so stale main-tier copies are handled durably by the
    engine (store upsert + frame invalidation) instead of via a device
    tombstone; only an existing *delta* copy needs tombstoning here."""
    vecs = normalize_if_cosine(vecs.astype(jnp.float32), metric)
    B = vecs.shape[0]
    dvalid = _tombstone_delta(delta, ids)
    slots = delta.count + jnp.arange(B, dtype=jnp.int32)
    new_codes = delta.codes
    if qstats is not None and delta.codes is not None:
        new_codes = delta.codes.at[slots].set(quantize.encode(qstats, vecs))
    return DeltaStore(
        vectors=delta.vectors.at[slots].set(vecs),
        ids=delta.ids.at[slots].set(ids.astype(jnp.int32)),
        attrs=delta.attrs.at[slots].set(attrs.astype(jnp.float32)),
        valid=dvalid.at[slots].set(True),
        count=delta.count + B,
        codes=new_codes,
    )


def delta_only_delete(delta: DeltaStore, ids: jax.Array) -> DeltaStore:
    """Paged-mode delete: tombstone any delta copy of the given asset ids
    (main-tier copies are deleted durably + invalidated by the engine)."""
    return dataclasses.replace(delta, valid=_tombstone_delta(delta, ids))


def delta_free_slots(index: IVFIndex) -> int:
    return int(index.delta.capacity - index.delta.count)


def delta_live(index: IVFIndex) -> int:
    return int(index.delta.valid.sum())
