"""Mini-batch k-means with flexible balance constraints (paper Alg. 1).

Faithful reproduction of MicroNN's indexing algorithm:

  * k = |X| / target_cluster_size                       (line 1)
  * centroids seeded from random data points            (line 2)
  * per iteration: a uniform random mini-batch M        (line 6)
  * NEAREST assigns each x in M to the closest centroid *under a balance
    penalty* so large clusters repel new members        (lines 7-8, [22])
  * per-centre counts v and learning rate eta = 1/v[c]  (lines 10-13)
  * final pass assigns every x to its plain nearest centre (lines 15-16)

Vectorisation note (exactness, not approximation): Alg. 1 updates a centroid
sequentially for each assigned sample with eta = 1/v[c]. For samples
x_1..x_m joining a centroid with prior count v and position c, that
recurrence telescopes to the running mean

    c' = (v * c + sum_i x_i) / (v + m)

so the grouped update below reproduces the sequential loop bit-for-bit (up
to float associativity). The *assignment* loop, however, is order-dependent
(counts move within a batch), so we keep it as a lax.scan over the batch --
distances are precomputed with one [s, k] matmul (the paper's SIMD batching;
here the MXU), and the scan only does the penalised argmin + count bump.

Memory: only the [s, d] mini-batch, [k, d] centroids and [s, k] distance
block are live -- never the full dataset. This is the property Fig. 6b/8b
measure; `benchmarks/bench_minibatch.py` reproduces them.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .types import IVFConfig, normalize_if_cosine, pairwise_scores


@partial(jax.jit, static_argnames=("balance_weight", "target_size"))
def assign_minibatch(
    centroids: jax.Array,     # [k, d]
    counts: jax.Array,        # [k] float32 running per-centre counts (v)
    batch: jax.Array,         # [s, d]
    *,
    balance_weight: float,
    target_size: int,
):
    """Lines 6-13 of Alg. 1 for one mini-batch.

    Returns (new_centroids, new_counts, assignments [s]).
    """
    s = batch.shape[0]
    # One matmul for the whole batch (SIMD/MXU batching, paper §3.1).
    dist = pairwise_scores(batch, centroids, "l2")  # [s, k]

    # NEAREST with balance penalty: cost = ||x - c||^2 + lambda*scale*v[c]/t.
    # `scale` (mean nearest-centroid distance in this batch) makes the
    # penalty invariant to the data's distance scale -- Liu et al. [22]
    # leave lambda a free parameter; anchoring it to the batch distance
    # scale keeps one default working across datasets (MNIST..GIST dims).
    scale = jnp.mean(jnp.min(dist, axis=-1)) + 1e-12

    # Counts advance *within* the batch (d accumulates in Alg. 1's first
    # loop), so the argmin is a sequential scan over batch elements.
    def step(carry, row):
        v = carry
        penalized = row + balance_weight * scale * v / target_size
        c = jnp.argmin(penalized)
        return v.at[c].add(1.0), c

    _, assign = jax.lax.scan(step, counts, dist)

    # Grouped running-mean update (telescoped lines 10-13).
    onehot = jax.nn.one_hot(assign, centroids.shape[0], dtype=batch.dtype)  # [s, k]
    batch_counts = onehot.sum(axis=0)                       # m_c
    batch_sums = onehot.T @ batch                           # [k, d]
    new_counts = counts + batch_counts
    denom = jnp.maximum(new_counts, 1.0)[:, None]
    new_centroids = (counts[:, None] * centroids + batch_sums) / denom
    # Centres with no prior mass and no batch members stay put.
    new_centroids = jnp.where(new_counts[:, None] > 0, new_centroids, centroids)
    return new_centroids, new_counts, assign.astype(jnp.int32)


@partial(jax.jit, static_argnames=("balance_weight", "target_size", "balanced"))
def final_assign(
    centroids: jax.Array,
    counts: jax.Array,
    batch: jax.Array,
    *,
    balance_weight: float,
    target_size: int,
    balanced: bool,
):
    """Lines 15-16: P[x] <- q(C, x) (plain nearest by default).

    `balanced=True` is a beyond-paper knob: it reuses the penalised
    assignment for the final pass too, which tightens the p_max bound of the
    padded device layout (see DESIGN.md §2 item 2).
    """
    if not balanced:
        dist = pairwise_scores(batch, centroids, "l2")
        return counts, jnp.argmin(dist, axis=-1).astype(jnp.int32)
    new_c, new_v, assign = assign_minibatch(
        centroids, counts, batch,
        balance_weight=balance_weight, target_size=target_size)
    del new_c
    return new_v, assign


class MiniBatchKMeans:
    """Host-side driver. Streams mini-batches; device does the math.

    Works from an in-memory array *or* any callable yielding batches (the
    storage layer passes a SQLite cursor reader), so the full dataset is
    never required in memory -- the paper's core constraint.
    """

    def __init__(self, cfg: IVFConfig, k: Optional[int] = None):
        self.cfg = cfg
        self.k = k
        self.centroids: Optional[np.ndarray] = None
        self.counts: Optional[np.ndarray] = None
        # peak number of float32s resident at once (for Fig. 6b/8b repro)
        self.peak_live_floats = 0

    def _track(self, *arrs):
        live = sum(int(np.prod(a.shape)) for a in arrs)
        self.peak_live_floats = max(self.peak_live_floats, live)

    def fit(
        self,
        sample_batch: Callable[[int, np.random.Generator], np.ndarray],
        n_total: int,
        rng: Optional[np.random.Generator] = None,
    ) -> np.ndarray:
        """sample_batch(size, rng) -> [size, d] float32 uniform random rows."""
        cfg = self.cfg
        rng = rng or np.random.default_rng(cfg.seed)
        k = self.k or max(1, n_total // cfg.target_partition_size)
        self.k = k

        # Line 2: seed centroids with random data points.
        seed_rows = sample_batch(k, rng)
        seed_rows = np.asarray(
            normalize_if_cosine(jnp.asarray(seed_rows), cfg.metric))
        centroids = jnp.asarray(seed_rows, jnp.float32)
        counts = jnp.zeros((k,), jnp.float32)

        for _ in range(cfg.kmeans_iters):
            batch = sample_batch(cfg.minibatch_size, rng).astype(np.float32)
            batch = np.asarray(normalize_if_cosine(jnp.asarray(batch), cfg.metric))
            self._track(batch, seed_rows[:0], np.zeros((k, cfg.dim)),
                        np.zeros((cfg.minibatch_size, k)))
            centroids, counts, _ = assign_minibatch(
                centroids, counts, jnp.asarray(batch),
                balance_weight=cfg.balance_weight,
                target_size=cfg.target_partition_size)

        self.centroids = np.asarray(centroids)
        self.counts = np.asarray(counts)
        return self.centroids

    def assign(
        self,
        batch_iter: Iterator[np.ndarray],
    ) -> np.ndarray:
        """Final full-data assignment pass, streamed in batches."""
        cfg = self.cfg
        assert self.centroids is not None, "fit() first"
        centroids = jnp.asarray(self.centroids)
        counts = jnp.asarray(self.counts)
        out = []
        for batch in batch_iter:
            batch = np.asarray(
                normalize_if_cosine(jnp.asarray(batch, jnp.float32), cfg.metric))
            counts, assign = final_assign(
                centroids, counts, jnp.asarray(batch),
                balance_weight=cfg.balance_weight,
                target_size=cfg.target_partition_size,
                balanced=cfg.balanced_final_assign)
            out.append(np.asarray(assign))
        self.counts = np.asarray(counts)
        return np.concatenate(out) if out else np.zeros((0,), np.int32)


def fit_in_memory(X: np.ndarray, cfg: IVFConfig, k: Optional[int] = None):
    """Convenience wrapper: fit + assign over an in-memory array."""
    km = MiniBatchKMeans(cfg, k=k)

    def sample(size: int, rng: np.random.Generator) -> np.ndarray:
        idx = rng.integers(0, X.shape[0], size=size)
        return X[idx]

    km.fit(sample, X.shape[0])
    bs = max(cfg.minibatch_size, 4096)
    assign = km.assign(X[i:i + bs] for i in range(0, X.shape[0], bs))
    return km.centroids, km.counts, assign
