"""Batch multi-query optimization (paper §3.4, after HQI [27]).

Naive batch execution re-reads a partition once per query that probes it.
MQO inverts the mapping: group queries by partition, read each partition
once, and score *all* interested queries against it with a single matmul.

Fixed-shape realisation for TPU:
  * selection matrix  sel[Q, k]   -- which query probes which partition
  * vote counts       votes[k]    -- how many queries probe each partition
  * the u_max most-voted partitions form the shared scan set (the true
    union has |U| <= min(k, Q*n_probe) members; unioned-out slots carry
    zero votes and are masked)

I/O amortisation: bytes gathered drop from  Q * n_probe * p_max * d  (naive)
to  u_max * p_max * d  (shared) -- the quantity benchmarks/bench_mqo.py
tracks to reproduce Fig. 9.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .search import AttrFilter, _delta_scores, find_nearest_centroids
from .topk import dedup_by_id, mask_scores, topk_smallest
from .types import IVFIndex, SearchResult, normalize_if_cosine


@partial(jax.jit, static_argnames=("k", "n_probe", "u_max", "attr_filter"))
def mqo_search(
    index: IVFIndex,
    queries: jax.Array,           # [Q, d]
    k: int,
    n_probe: int,
    u_max: Optional[int] = None,
    attr_filter: Optional[AttrFilter] = None,
) -> SearchResult:
    """Partition-major shared scan for a query batch."""
    cfg = index.config
    q = normalize_if_cosine(queries.astype(jnp.float32), cfg.metric)
    Q = q.shape[0]
    kp, p_max, d = index.vectors.shape
    n_probe = min(n_probe, kp)
    if u_max is None:
        u_max = min(kp, Q * n_probe)

    parts = find_nearest_centroids(index, q, n_probe)        # [Q, n]
    sel = jnp.zeros((Q, kp), bool).at[
        jnp.arange(Q)[:, None], parts].set(True)             # [Q, k]
    votes = sel.sum(axis=0)                                  # [k]

    # Shared scan set: most-voted partitions first; zero-vote slots are
    # padding and masked out below.
    vote_top, upart = jax.lax.top_k(votes, u_max)            # [u_max]
    uv = index.vectors[upart]                                # [u_max, p_max, d]
    uid = index.ids[upart]
    uok = index.valid[upart]
    if attr_filter is not None:
        uok = uok & attr_filter(index.attrs[upart])
    uok = uok & (vote_top > 0)[:, None]

    # One matmul scores the whole batch against the whole shared set --
    # the paper's "distances ... calculated via a single matrix
    # multiplication" per partition, fused across partitions.
    flat_v = uv.reshape(u_max * p_max, d)
    dots = q @ flat_v.T                                      # [Q, u_max*p_max]
    if cfg.metric in ("ip", "cosine"):
        scores = -dots
    else:
        q2 = jnp.sum(q * q, axis=-1, keepdims=True)
        v2 = jnp.sum(flat_v * flat_v, axis=-1)
        scores = q2 + v2[None, :] - 2.0 * dots
    scores = scores.reshape(Q, u_max, p_max)

    qsel = jnp.take_along_axis(sel, upart[None, :], axis=1)  # [Q, u_max]
    ok = uok[None, :, :] & qsel[:, :, None]
    scores = mask_scores(scores, ok).reshape(Q, -1)
    flat_i = jnp.broadcast_to(uid.reshape(1, -1), scores.shape)

    ds, di = _delta_scores(index, q, attr_filter)
    all_s = jnp.concatenate([scores, ds], axis=-1)
    all_i = jnp.concatenate([flat_i, di], axis=-1)
    s, i = topk_smallest(all_s, all_i, min(k, all_s.shape[-1]))
    s, i = dedup_by_id(s, i)
    return SearchResult(ids=i, scores=s)


def gathered_bytes(index: IVFIndex, batch: int, n_probe: int,
                   u_max: Optional[int] = None, mqo: bool = True) -> int:
    """Partition bytes read per batch -- the I/O-amortisation metric."""
    kp, p_max, d = index.vectors.shape
    row = d * 4
    if mqo:
        u = u_max if u_max is not None else min(kp, batch * min(n_probe, kp))
        return u * p_max * row
    return batch * min(n_probe, kp) * p_max * row
