"""Batch multi-query optimization (paper §3.4, after HQI [27]).

Naive batch execution re-reads a partition once per query that probes it.
MQO inverts the mapping: group queries by partition, read each partition
once, and score *all* interested queries against it with a single matmul.

In the unified execution layer this is not a separate implementation:
an MQO batch is exactly an ANN QuerySpec -- the shared probe union is
the compiled plan's `part_ids` and the query-by-partition selection
matrix is its `qsel` mask -- so `mqo_search` is a thin shim that builds
`Q.knn(...).union_cap(u_max)` and runs it. The only extra knob is
`u_max`, a static cap on the scan union (the true union has
|U| <= min(k, Q*n_probe) members; unioned-out slots carry zero votes
and are masked).

I/O amortisation: bytes gathered drop from  Q * n_probe * p_max * d  (naive)
to  u_max * p_max * d  (shared) -- the quantity benchmarks/bench_mqo.py
tracks to reproduce Fig. 9.
"""
from __future__ import annotations

from typing import Optional

import jax

from . import executor
from .executor import AttrFilter
from .query import Q, ResultSet
from .types import IVFIndex


def mqo_search(
    index: IVFIndex,
    queries: jax.Array,           # [Q, d]
    k: int,
    n_probe: int,
    u_max: Optional[int] = None,
    attr_filter: Optional[AttrFilter] = None,
    backend: Optional[str] = None,
) -> ResultSet:
    """Partition-major shared scan for a query batch."""
    spec = Q.knn(k=k, n_probe=n_probe).union_cap(u_max).backend(backend)
    if attr_filter is not None:
        spec = spec.where(attr_filter).postfilter()
    return executor.run(index, queries, spec)


def gathered_bytes(index: IVFIndex, batch: int, n_probe: int,
                   u_max: Optional[int] = None, mqo: bool = True) -> int:
    """Partition bytes read per batch -- the I/O-amortisation metric."""
    kp, p_max, d = index.vectors.shape
    row = d * 4
    if mqo:
        u = u_max if u_max is not None else min(kp, batch * min(n_probe, kp))
        return u * p_max * row
    return batch * min(n_probe, kp) * p_max * row
