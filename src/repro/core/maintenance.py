"""Index maintenance: incremental delta flush + full rebuild (paper §3.6).

Incremental flush ([1]-style, as the paper implements): each live delta
vector is assigned to the partition with the nearest centroid; centroids
update by the running-mean rule  c' = (v*c + sum x) / (v + m)  (the same
telescoped form as Alg. 1's eta=1/v update, see core/kmeans.py).

A flush only rewrites the partitions it touches -- the I/O win over a full
rebuild that Fig. 10d quantifies. We account bytes for both paths
(`MaintenanceStats`) so benchmarks/bench_updates.py can reproduce the
figure.

The flush itself is a host-side repack (it changes row placement --
the 'SSD reorganisation' tier); the nearest-centroid assignment runs on
device.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ivf, quantize
from .types import (DeltaStore, INVALID_ID, IVFConfig, IVFIndex,
                    effective_pad_to, pairwise_scores)


@dataclasses.dataclass
class MaintenanceStats:
    kind: str                 # "incremental" | "full"
    rows_moved: int
    partitions_touched: int
    bytes_written: int        # host-tier write I/O (flash-wear metric)
    p_max_before: int
    p_max_after: int


def assign_nearest_centroid(dx: np.ndarray, centroids) -> np.ndarray:
    """Nearest-centroid assignment for a flush batch (device matmul) --
    shared by the resident and paged flush so both agree on placement.
    Always l2 over the (metric-normalised) rows: for cosine data rows and
    centroids are unit-norm, so l2 order == cosine order."""
    return np.asarray(jnp.argmin(
        pairwise_scores(jnp.asarray(dx), centroids, "l2"), axis=-1))


def running_mean_update(cent: np.ndarray, csizes: np.ndarray,
                        dx: np.ndarray, assign: np.ndarray,
                        touched: np.ndarray):
    """The paper's telescoped running-mean rule c' = (v*c + sum x)/(v+m)
    per touched partition (in place) -- shared by both flush paths so the
    resident and paged centroid trajectories stay numerically identical."""
    for p in touched:
        m = int((assign == p).sum())
        v = csizes[p]
        cent[p] = (v * cent[p] + dx[assign == p].sum(0)) / max(v + m, 1.0)
        csizes[p] = v + m


def _row_bytes(index: IVFIndex) -> int:
    d = index.dim
    n_attr = index.n_attr
    # vector + id + attrs + valid (+ the int8 code tier when quantized)
    codes = d if index.codes is not None else 0
    return 4 * d + 4 + 4 * n_attr + 1 + codes


def flush_delta(index: IVFIndex) -> Tuple[IVFIndex, MaintenanceStats]:
    """Incrementally fold live delta rows into the IVF partitions."""
    cfg = index.config
    k, p_max, d = index.vectors.shape

    quantized = index.codes is not None
    dvalid = np.asarray(index.delta.valid)
    live = np.nonzero(dvalid)[0]
    if live.size == 0:
        empty = DeltaStore.empty(index.delta.capacity, d, index.n_attr,
                                 quantized=quantized)
        new = dataclasses.replace(index, delta=empty)
        return new, MaintenanceStats("incremental", 0, 0, 0, p_max, p_max)

    dx = np.asarray(index.delta.vectors)[live]
    dids = np.asarray(index.delta.ids)[live]
    dattrs = np.asarray(index.delta.attrs)[live]
    if quantized:
        # Delta rows were encoded on insert; re-encode only as a fallback
        # (e.g. an index assembled by hand without a code-backed delta).
        dcod = (np.asarray(index.delta.codes)[live]
                if index.delta.codes is not None
                else quantize.encode_np(index.qstats, dx))

    # nearest-centroid assignment on device
    assign = assign_nearest_centroid(dx, index.centroids)

    vec = np.array(index.vectors)
    vid = np.array(index.ids)
    vat = np.array(index.attrs)
    val = np.array(index.valid)
    counts = np.array(index.counts)
    csizes = np.array(index.csizes)
    cent = np.array(index.centroids)
    cod = np.array(index.codes) if quantized else None

    # grow p_max if some partition would overflow (compaction first: reuse
    # tombstoned slots)
    add = np.bincount(assign, minlength=k)
    need = val.sum(-1) + add
    new_p_max = int(need.max())
    pad = effective_pad_to(cfg)   # int8-on-TPU pads to the (32,128) tile
    new_p_max = max(p_max, -(-new_p_max // pad) * pad)
    if new_p_max > p_max:
        grow = new_p_max - p_max
        vec = np.pad(vec, [(0, 0), (0, grow), (0, 0)])
        vid = np.pad(vid, [(0, 0), (0, grow)], constant_values=INVALID_ID)
        vat = np.pad(vat, [(0, 0), (0, grow), (0, 0)])
        val = np.pad(val, [(0, 0), (0, grow)])
        if quantized:
            cod = np.pad(cod, [(0, 0), (0, grow), (0, 0)])

    touched = np.unique(assign)
    for p in touched:
        keep = np.nonzero(val[p])[0]
        newv = np.concatenate([vec[p][keep], dx[assign == p]])
        newi = np.concatenate([vid[p][keep], dids[assign == p]])
        newa = np.concatenate([vat[p][keep], dattrs[assign == p]])
        m = len(newv)
        vec[p, :m] = newv; vec[p, m:] = 0.0
        vid[p, :m] = newi; vid[p, m:] = INVALID_ID
        vat[p, :m] = newa; vat[p, m:] = 0.0
        val[p, :m] = True; val[p, m:] = False
        if quantized:
            newc = np.concatenate([cod[p][keep], dcod[assign == p]])
            cod[p, :m] = newc; cod[p, m:] = 0
        counts[p] = m
    running_mean_update(cent, csizes, dx, assign, touched)

    stats = MaintenanceStats(
        kind="incremental",
        rows_moved=int(live.size),
        partitions_touched=int(len(touched)),
        # host-tier write I/O: a clustered B-tree append touches only the
        # pages of the inserted rows (not the whole partition) -- count
        # moved rows + the touched partitions' centroid rewrites. This is
        # the paper's "<2% of full rebuild" metric (Fig. 10d).
        bytes_written=int(live.size * _row_bytes(index)
                          + len(touched) * d * 4),
        p_max_before=p_max, p_max_after=new_p_max)

    new_index = IVFIndex(
        centroids=jnp.asarray(cent),
        csizes=jnp.asarray(csizes),
        vectors=jnp.asarray(vec), ids=jnp.asarray(vid),
        attrs=jnp.asarray(vat), valid=jnp.asarray(val),
        counts=jnp.asarray(counts),
        delta=DeltaStore.empty(index.delta.capacity, d, index.n_attr,
                               quantized=quantized),
        base_mean_size=index.base_mean_size,
        codes=jnp.asarray(cod) if quantized else None,
        qstats=index.qstats,
        config=cfg)
    return new_index, stats


def live_rows(index: IVFIndex):
    """Extract all live rows (main + delta) back to host arrays."""
    val = np.asarray(index.valid)
    vec = np.asarray(index.vectors)[val]
    vid = np.asarray(index.ids)[val]
    vat = np.asarray(index.attrs)[val]
    dval = np.asarray(index.delta.valid)
    if dval.any():
        vec = np.concatenate([vec, np.asarray(index.delta.vectors)[dval]])
        vid = np.concatenate([vid, np.asarray(index.delta.ids)[dval]])
        vat = np.concatenate([vat, np.asarray(index.delta.attrs)[dval]])
    return vec, vid, vat


def full_rebuild(index: IVFIndex,
                 cfg: Optional[IVFConfig] = None
                 ) -> Tuple[IVFIndex, MaintenanceStats]:
    """Re-cluster everything from scratch (the paper's fallback when
    average partition growth crosses the threshold)."""
    cfg = cfg or index.config
    vec, vid, vat = live_rows(index)
    p_max_before = index.p_max
    new = ivf.build_index(vec, vid, vat, cfg=cfg)
    stats = MaintenanceStats(
        kind="full",
        rows_moved=int(len(vec)),
        partitions_touched=int(new.k),
        bytes_written=int(len(vec) * _row_bytes(index) + new.k * new.dim * 4),
        p_max_before=p_max_before, p_max_after=new.p_max)
    return new, stats
