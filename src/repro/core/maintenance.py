"""Index maintenance: incremental delta flush, LIRE-style local repair
(split / merge / recluster), and the legacy full rebuild (paper §3.6).

Incremental flush ([1]-style, as the paper implements): each live delta
vector is assigned to the partition with the nearest centroid; centroids
update by the running-mean rule  c' = (v*c + sum x) / (v + m)  (the same
telescoped form as Alg. 1's eta=1/v update, see core/kmeans.py).

Local repair (the paper's Fig. 10d updatability claim, made incremental):
instead of retraining the world when partitions drift out of shape, a
repair touches only a *neighbourhood* of partitions -- an oversized
partition is 2-means-split, underfull siblings are merged, and only rows
in the touched centroid neighbourhood are reassigned. Quantized codes are
re-encoded with the *existing* quantizer (deterministic, so codes stay
byte-stable everywhere; in practice no code bytes change at all). The
planning half (`plan_split` / `plan_merge` / `plan_local_recluster`) is a
pure host computation over a `RowBlock` fetch callback, shared by the
resident and paged engines so both modes make bit-identical decisions;
`apply_plan` rewrites the resident packed layout, while both engines
persist the plan durably through one atomic repair transaction
(VectorStore.apply_repair) -- the paged engine additionally invalidates
exactly the touched pager frames.

A flush/repair only rewrites the partitions it touches -- the I/O win
over a full rebuild that Fig. 10d quantifies. We account bytes for every
path (`MaintenanceStats`) so benchmarks/bench_updates.py can reproduce
the figure.

The flush itself is a host-side repack (it changes row placement --
the 'SSD reorganisation' tier); the nearest-centroid assignment runs on
device.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ivf, quantize
from .types import (DeltaStore, INVALID_ID, IVFConfig, IVFIndex,
                    effective_pad_to, pairwise_scores)


@dataclasses.dataclass
class MaintenanceStats:
    kind: str                 # "incremental" | "full" | "split" | "merge"
    #                            | "recluster"
    rows_moved: int
    partitions_touched: int
    bytes_written: int        # host-tier write I/O (flash-wear metric)
    p_max_before: int
    p_max_after: int


def assign_nearest_centroid(dx: np.ndarray, centroids) -> np.ndarray:
    """Nearest-centroid assignment for a flush batch (device matmul) --
    shared by the resident and paged flush so both agree on placement.
    Always l2 over the (metric-normalised) rows: for cosine data rows and
    centroids are unit-norm, so l2 order == cosine order."""
    return np.asarray(jnp.argmin(
        pairwise_scores(jnp.asarray(dx), centroids, "l2"), axis=-1))


def running_mean_update(cent: np.ndarray, csizes: np.ndarray,
                        dx: np.ndarray, assign: np.ndarray,
                        touched: np.ndarray,
                        drift: Optional[np.ndarray] = None):
    """The paper's telescoped running-mean rule c' = (v*c + sum x)/(v+m)
    per touched partition (in place) -- shared by both flush paths so the
    resident and paged centroid trajectories stay numerically identical.

    Vectorized as one np.add.at scatter over the whole batch: bitwise
    identical to the per-partition loop it replaced, because an axis-0
    float32 sum accumulates rows sequentially in row order exactly like
    the scatter does (pinned by tests/test_maintenance.py).

    When `drift` is given, each touched partition's centroid displacement
    accumulates into it (in place) -- the monitor's recluster signal.
    """
    sums = np.zeros_like(cent)
    np.add.at(sums, assign, dx)
    m = np.bincount(assign, minlength=cent.shape[0]).astype(csizes.dtype)
    t = np.asarray(touched)
    old = cent[t].copy() if drift is not None else None
    v = csizes[t]
    cent[t] = (v[:, None] * cent[t] + sums[t]) \
        / np.maximum(v + m[t], 1.0)[:, None]
    csizes[t] = v + m[t]
    if drift is not None:
        drift[t] += np.linalg.norm(cent[t] - old, axis=-1)


def _row_bytes(index: IVFIndex) -> int:
    d = index.dim
    n_attr = index.n_attr
    # vector + id + attrs + valid (+ the int8 code tier when quantized)
    codes = d if index.codes is not None else 0
    return 4 * d + 4 + 4 * n_attr + 1 + codes


def compact_delta(d: DeltaStore, keep: np.ndarray, n_attr: int,
                  quantized: bool, qstats=None) -> DeltaStore:
    """Compact the delta rows listed in `keep` into a fresh DeltaStore --
    the tail of a *partial* flush (the scheduler's bounded work quantum
    flushes only `max_rows` rows per step and must not drop the rest).
    Shared by the resident and paged flush paths."""
    cap, dim = d.capacity, d.vectors.shape[1]
    out = DeltaStore.empty(cap, dim, n_attr, quantized=quantized)
    if keep.size == 0:
        return out
    r = keep.size
    vec = np.zeros((cap, dim), np.float32)
    vec[:r] = np.asarray(d.vectors)[keep]
    ids = np.full((cap,), INVALID_ID, np.int32)
    ids[:r] = np.asarray(d.ids)[keep]
    attrs = np.zeros((cap, n_attr), np.float32)
    attrs[:r] = np.asarray(d.attrs)[keep]
    valid = np.zeros((cap,), bool)
    valid[:r] = True
    codes = None
    if quantized:
        codes = np.zeros((cap, dim), np.int8)
        if d.codes is not None:
            codes[:r] = np.asarray(d.codes)[keep]
        else:           # hand-assembled code-less delta: re-encode
            codes[:r] = quantize.encode_np(qstats, vec[:r])
        codes = jnp.asarray(codes)
    return DeltaStore(vectors=jnp.asarray(vec), ids=jnp.asarray(ids),
                      attrs=jnp.asarray(attrs), valid=jnp.asarray(valid),
                      count=jnp.asarray(r, jnp.int32), codes=codes)


def flush_delta(index: IVFIndex, max_rows: Optional[int] = None,
                assign: Optional[np.ndarray] = None
                ) -> Tuple[IVFIndex, MaintenanceStats]:
    """Incrementally fold live delta rows into the IVF partitions.

    `max_rows` bounds the work quantum (storage/scheduler.py): only the
    first `max_rows` live rows (slot order) are flushed; the rest stay in
    the delta, compacted to the front, and remain searchable. A caller
    that already computed the flushed rows' nearest-centroid assignment
    (the engine's durable flush step mirrors the moves to SQLite) passes
    it via `assign` to skip the second identical device computation."""
    cfg = index.config
    k, p_max, d = index.vectors.shape

    quantized = index.codes is not None
    dvalid = np.asarray(index.delta.valid)
    live = np.nonzero(dvalid)[0]
    deferred = np.zeros((0,), np.int64)
    if max_rows is not None and live.size > max_rows:
        live, deferred = live[:max_rows], live[max_rows:]
    if live.size == 0:
        new = dataclasses.replace(
            index, delta=compact_delta(index.delta, deferred, index.n_attr,
                                       quantized, index.qstats))
        return new, MaintenanceStats("incremental", 0, 0, 0, p_max, p_max)

    dx = np.asarray(index.delta.vectors)[live]
    dids = np.asarray(index.delta.ids)[live]
    dattrs = np.asarray(index.delta.attrs)[live]
    if quantized:
        # Delta rows were encoded on insert; re-encode only as a fallback
        # (e.g. an index assembled by hand without a code-backed delta).
        dcod = (np.asarray(index.delta.codes)[live]
                if index.delta.codes is not None
                else quantize.encode_np(index.qstats, dx))

    # nearest-centroid assignment on device (unless the caller already
    # computed it for the durable mirror of these moves)
    if assign is None:
        assign = assign_nearest_centroid(dx, index.centroids)
    assert len(assign) == live.size

    vec = np.array(index.vectors)
    vid = np.array(index.ids)
    vat = np.array(index.attrs)
    val = np.array(index.valid)
    counts = np.array(index.counts)
    csizes = np.array(index.csizes)
    cent = np.array(index.centroids)
    cod = np.array(index.codes) if quantized else None

    # grow p_max if some partition would overflow (compaction first: reuse
    # tombstoned slots)
    add = np.bincount(assign, minlength=k)
    need = val.sum(-1) + add
    new_p_max = int(need.max())
    pad = effective_pad_to(cfg)   # int8-on-TPU pads to the (32,128) tile
    new_p_max = max(p_max, -(-new_p_max // pad) * pad)
    if new_p_max > p_max:
        grow = new_p_max - p_max
        vec = np.pad(vec, [(0, 0), (0, grow), (0, 0)])
        vid = np.pad(vid, [(0, 0), (0, grow)], constant_values=INVALID_ID)
        vat = np.pad(vat, [(0, 0), (0, grow), (0, 0)])
        val = np.pad(val, [(0, 0), (0, grow)])
        if quantized:
            cod = np.pad(cod, [(0, 0), (0, grow), (0, 0)])

    touched = np.unique(assign)
    for p in touched:
        keep = np.nonzero(val[p])[0]
        newv = np.concatenate([vec[p][keep], dx[assign == p]])
        newi = np.concatenate([vid[p][keep], dids[assign == p]])
        newa = np.concatenate([vat[p][keep], dattrs[assign == p]])
        m = len(newv)
        vec[p, :m] = newv; vec[p, m:] = 0.0
        vid[p, :m] = newi; vid[p, m:] = INVALID_ID
        vat[p, :m] = newa; vat[p, m:] = 0.0
        val[p, :m] = True; val[p, m:] = False
        if quantized:
            newc = np.concatenate([cod[p][keep], dcod[assign == p]])
            cod[p, :m] = newc; cod[p, m:] = 0
        counts[p] = m
    drift = np.asarray(index.drift, np.float32).copy() \
        if index.drift is not None else np.zeros((k,), np.float32)
    running_mean_update(cent, csizes, dx, assign, touched, drift=drift)

    stats = MaintenanceStats(
        kind="incremental",
        rows_moved=int(live.size),
        partitions_touched=int(len(touched)),
        # host-tier write I/O: a clustered B-tree append touches only the
        # pages of the inserted rows (not the whole partition) -- count
        # moved rows + the touched partitions' centroid rewrites. This is
        # the paper's "<2% of full rebuild" metric (Fig. 10d).
        bytes_written=int(live.size * _row_bytes(index)
                          + len(touched) * d * 4),
        p_max_before=p_max, p_max_after=new_p_max)

    new_index = IVFIndex(
        centroids=jnp.asarray(cent),
        csizes=jnp.asarray(csizes),
        vectors=jnp.asarray(vec), ids=jnp.asarray(vid),
        attrs=jnp.asarray(vat), valid=jnp.asarray(val),
        counts=jnp.asarray(counts),
        delta=compact_delta(index.delta, deferred, index.n_attr, quantized,
                            index.qstats),
        base_mean_size=index.base_mean_size,
        codes=jnp.asarray(cod) if quantized else None,
        qstats=index.qstats,
        code_norms=quantize.row_norms(index.qstats, jnp.asarray(cod))
        if quantized else None,
        drift=jnp.asarray(drift),
        config=cfg)
    return new_index, stats


# ---------------------------------------------------------------------------
# LIRE-style local repair: split / merge / recluster over a partition
# neighbourhood. Planning is a pure host computation shared by the resident
# and paged engines (both feed it the same row bytes, sorted by asset id,
# so the two modes produce bit-identical repairs); application is
# mode-specific (apply_plan rewrites the packed layout; the paged engine
# applies durably + invalidates frames).
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RowBlock:
    """Live rows of one partition, sorted ascending by asset id (the order
    both the packed resident layout after repack and SQLite's clustered
    scan agree on). `attrs`/`codes` ride along only where the fetcher has
    them resident (the paged apply re-reads them from SQLite instead)."""

    ids: np.ndarray                       # [m] int32
    vecs: np.ndarray                      # [m, d] f32, metric-normalised
    attrs: Optional[np.ndarray] = None    # [m, n_attr] f32
    codes: Optional[np.ndarray] = None    # [m, d] int8

# fetch callback: pids -> {pid: RowBlock} (one batched read per repair)
RowFetch = Callable[[Sequence[int]], Dict[int, "RowBlock"]]


@dataclasses.dataclass
class RepairPlan:
    """One planned local repair: which partitions are touched, where every
    affected row lands, and the neighbourhood's new centroid state. The
    plan is pure data -- the engine persists it durably (codes first, then
    one generation-swap transaction) and applies it to device state."""

    kind: str                 # "split" | "merge" | "recluster"
    pids: np.ndarray          # [L] int64 -- touched partitions (split: the
    #                           new slot is last)
    new_pid: Optional[int]    # slot a split allocated (reused empty slot,
    #                           or == k_before when appending)
    k_after: int              # partition count after the repair
    row_ids: np.ndarray       # [m] int32 -- every live row in the
    #                           neighbourhood (block order per pids)
    row_vecs: np.ndarray      # [m, d] f32 metric-normalised
    row_attrs: Optional[np.ndarray]   # [m, n_attr] (resident fetch only)
    row_codes: Optional[np.ndarray]   # [m, d] int8 (resident fetch only)
    src: np.ndarray           # [m] int64 -- current partition per row
    assign: np.ndarray        # [m] int64 -- new partition per row
    centroids: np.ndarray     # [L, d] f32 -- new centroids for `pids`
    csizes: np.ndarray        # [L] f32 -- restarted running counts

    @property
    def rows(self) -> int:
        return int(self.row_ids.size)

    @property
    def moved(self) -> np.ndarray:
        return self.assign != self.src


def two_means(rows: np.ndarray, iters: int = 8
              ) -> Tuple[np.ndarray, np.ndarray]:
    """Deterministic 2-means over [m, d] float32 rows: farthest-point init
    from the partition mean, fixed Lloyd iterations, ties to side 0. No
    RNG and no order sensitivity beyond the caller's (sorted-by-id) row
    order, so the resident and paged planners split identically."""
    mu = rows.mean(0)
    c1 = rows[int(((rows - mu) ** 2).sum(-1).argmax())]
    c2 = rows[int(((rows - c1) ** 2).sum(-1).argmax())]
    assign = np.zeros((rows.shape[0],), np.int64)
    for _ in range(iters):
        d1 = ((rows - c1) ** 2).sum(-1)
        d2 = ((rows - c2) ** 2).sum(-1)
        new = (d2 < d1).astype(np.int64)
        if (new == 0).all() or (new == 1).all():
            assign = new
            break
        c1n, c2n = rows[new == 0].mean(0), rows[new == 1].mean(0)
        done = np.array_equal(new, assign)
        assign = new
        if done:
            break
        c1, c2 = c1n, c2n
    return np.stack([c1, c2]), assign


def neighborhood(centroids: np.ndarray, counts: np.ndarray,
                 seeds: Sequence[int], row_budget: Optional[int],
                 n_extra: int) -> List[int]:
    """The touched centroid neighbourhood of a repair: the seed partitions
    plus up to `n_extra` nearest non-empty partitions whose rows still fit
    the row budget (the scheduler's work quantum). Deterministic: ordered
    by centroid distance to the first seed, ties by partition id."""
    base = [int(p) for p in seeds]
    used = int(counts[base].sum())
    if n_extra <= 0:
        return base
    ref = centroids[base[0]]
    dist = ((centroids - ref) ** 2).sum(-1)
    order = np.lexsort((np.arange(len(centroids)), dist))
    out = list(base)
    for q in order:
        if len(out) - len(base) >= n_extra:
            break
        q = int(q)
        if q in base or counts[q] <= 0:
            continue
        if row_budget is not None and used + int(counts[q]) > row_budget:
            continue
        out.append(q)
        used += int(counts[q])
    return out


def _gather_blocks(blocks: Dict[int, RowBlock], pids: Sequence[int]):
    """Concatenate the neighbourhood's RowBlocks in pid-list order."""
    ids = [blocks[p].ids for p in pids if p in blocks]
    if not ids:
        d = 0
        return (np.zeros((0,), np.int32), np.zeros((0, d), np.float32),
                None, None, np.zeros((0,), np.int64))
    vecs = np.concatenate([blocks[p].vecs for p in pids if p in blocks])
    src = np.concatenate([np.full((len(blocks[p].ids),), p, np.int64)
                          for p in pids if p in blocks])
    have_attrs = all(blocks[p].attrs is not None
                     for p in pids if p in blocks)
    have_codes = all(blocks[p].codes is not None
                     for p in pids if p in blocks)
    attrs = np.concatenate([blocks[p].attrs for p in pids if p in blocks]) \
        if have_attrs else None
    codes = np.concatenate([blocks[p].codes for p in pids if p in blocks]) \
        if have_codes else None
    return np.concatenate(ids), vecs, attrs, codes, src


def _finalize_plan(kind, local, new_pid, k_after, row_ids, row_vecs,
                   row_attrs, row_codes, src, local_cents
                   ) -> Optional[RepairPlan]:
    """Shared tail of every planner: reassign the neighbourhood's rows to
    their nearest local centroid, then restate each touched partition's
    centroid as the mean of its new members (running-mean restart).
    Partitions left empty keep their (masked-by-count) old centroid."""
    d2 = ((row_vecs[:, None, :] - local_cents[None, :, :]) ** 2).sum(-1)
    pick = d2.argmin(axis=1)                      # ties -> lowest index
    assign = np.asarray(local, np.int64)[pick]
    cents = local_cents.copy().astype(np.float32)
    csz = np.zeros((len(local),), np.float32)
    for j in range(len(local)):
        sel = pick == j
        m = int(sel.sum())
        csz[j] = m
        if m:
            cents[j] = row_vecs[sel].mean(0)
    return RepairPlan(
        kind=kind, pids=np.asarray(local, np.int64), new_pid=new_pid,
        k_after=k_after, row_ids=row_ids, row_vecs=row_vecs,
        row_attrs=row_attrs, row_codes=row_codes, src=src, assign=assign,
        centroids=cents, csizes=csz)


def plan_split(centroids: np.ndarray, csizes: np.ndarray,
               counts: np.ndarray, pid: int, fetch: RowFetch, *,
               row_budget: Optional[int] = None, n_local: int = 2
               ) -> Optional[RepairPlan]:
    """2-means split of an oversized partition + local reassignment of the
    touched neighbourhood. The freed half lands in a reused empty slot
    when one exists (keeping k stable under churn), else in a new slot k.
    Returns None when the partition is degenerate (all rows identical)."""
    k = centroids.shape[0]
    pid = int(pid)
    nbrs = neighborhood(centroids, counts, [pid], row_budget, n_local)
    blocks = fetch(nbrs)
    seed = blocks.get(pid)
    if seed is None or len(seed.ids) < 2:
        return None
    (c1, c2), halves = two_means(seed.vecs)
    if (halves == 0).all() or (halves == 1).all():
        return None                      # degenerate: nothing to split
    if (halves == 1).sum() > (halves == 0).sum():
        # the larger half stays in place (fewer durable row moves)
        c1, c2 = c2, c1
    empty = [int(p) for p in np.nonzero(counts == 0)[0] if p not in nbrs]
    new_pid = empty[0] if empty else k
    k_after = max(k, new_pid + 1)
    local = nbrs + [new_pid]
    row_ids, row_vecs, row_attrs, row_codes, src = _gather_blocks(
        blocks, nbrs)
    local_cents = np.concatenate(
        [np.stack([c1]), centroids[nbrs[1:]], np.stack([c2])]) \
        .astype(np.float32)
    plan = _finalize_plan("split", local, new_pid, k_after, row_ids,
                          row_vecs, row_attrs, row_codes, src, local_cents)
    if plan is None or not plan.moved.any():
        return None
    return plan


def choose_merge_partner(centroids: np.ndarray, counts: np.ndarray,
                         victim: int, split_bar: float,
                         exclude: Sequence[int] = ()) -> Optional[int]:
    """Bin-packing partner selection for a merge: among the non-empty
    partitions whose post-merge size still fits under the split bar, pick
    the one that *minimizes the post-merge slack* (best-fit decreasing --
    the classic bin-packing heuristic), NOT merely the nearest centroid.
    Nearest-centroid partnering tends to pour small partitions into other
    small partitions, leaving many half-empty bins that each trigger a
    later merge; best-fit packs the victim into the fullest partition it
    still fits, retiring a bin per merge. Ties on slack break by centroid
    distance to the victim (locality still matters for recall), then by
    partition id (determinism). Returns None when nothing fits."""
    victim = int(victim)
    counts = np.asarray(counts)
    k = centroids.shape[0]
    merged = counts + counts[victim]
    dist = ((centroids - centroids[victim]) ** 2).sum(-1)
    ok = (counts > 0) & (merged <= split_bar)
    ok[victim] = False
    for p in exclude:
        if 0 <= int(p) < k:
            ok[int(p)] = False
    if not ok.any():
        return None
    slack = np.where(ok, split_bar - merged, np.inf)
    # lexsort: last key is primary -> (slack, distance, pid)
    order = np.lexsort((np.arange(k), dist, slack))
    return int(order[0])


def plan_merge(centroids: np.ndarray, csizes: np.ndarray,
               counts: np.ndarray, into: int, victim: int, fetch: RowFetch
               ) -> Optional[RepairPlan]:
    """Merge an underfull partition into a sibling: every row of `victim`
    moves to `into`, whose centroid restarts at the merged rows' mean.
    The victim keeps its (masked-by-count) centroid slot -- reusable by a
    later split, so k never needs global renumbering."""
    into, victim = int(into), int(victim)
    local = [into, victim]
    blocks = fetch(local)
    row_ids, row_vecs, row_attrs, row_codes, src = _gather_blocks(
        blocks, local)
    if row_ids.size == 0:
        return None
    assign = np.full((row_ids.size,), into, np.int64)
    cents = np.stack([row_vecs.mean(0),
                      centroids[victim]]).astype(np.float32)
    csz = np.asarray([row_ids.size, 0.0], np.float32)
    plan = RepairPlan(
        kind="merge", pids=np.asarray(local, np.int64), new_pid=None,
        k_after=centroids.shape[0], row_ids=row_ids, row_vecs=row_vecs,
        row_attrs=row_attrs, row_codes=row_codes, src=src, assign=assign,
        centroids=cents, csizes=csz)
    return plan


def plan_local_recluster(centroids: np.ndarray, csizes: np.ndarray,
                         counts: np.ndarray, pid: int, fetch: RowFetch, *,
                         row_budget: Optional[int] = None, n_local: int = 2
                         ) -> Optional[RepairPlan]:
    """Local repair of a drifted (or tombstone-heavy) partition: reassign
    only the rows in its centroid neighbourhood to their nearest local
    centroid and restart those centroids at their members' means. Always
    returns a plan (even a no-move one: the repack drops tombstones and
    the apply resets the drift signal)."""
    nbrs = neighborhood(centroids, counts, [int(pid)], row_budget, n_local)
    blocks = fetch(nbrs)
    row_ids, row_vecs, row_attrs, row_codes, src = _gather_blocks(
        blocks, nbrs)
    if row_ids.size == 0:
        return None
    return _finalize_plan("recluster", nbrs, None, centroids.shape[0],
                          row_ids, row_vecs, row_attrs, row_codes, src,
                          centroids[nbrs].astype(np.float32))


def apply_plan(index: IVFIndex, plan: RepairPlan) -> IVFIndex:
    """Rewrite the resident packed layout per a RepairPlan: only the
    touched partitions' slots change (rows packed ascending by asset id,
    matching what a recover() from the repaired durable state would pack),
    k/p_max grow as needed, codes move byte-stable with their rows, and
    the touched partitions' drift resets."""
    cfg = index.config
    k, p_max, d = index.vectors.shape
    quantized = index.codes is not None
    assert plan.row_attrs is not None, "resident apply needs attrs"
    assert (not quantized) or plan.row_codes is not None

    vec = np.array(index.vectors)
    vid = np.array(index.ids)
    vat = np.array(index.attrs)
    val = np.array(index.valid)
    counts = np.array(index.counts)
    cent = np.array(index.centroids)
    csz = np.array(index.csizes)
    cod = np.array(index.codes) if quantized else None
    drift = np.asarray(index.drift, np.float32).copy() \
        if index.drift is not None else np.zeros((k,), np.float32)

    if plan.k_after > k:
        grow = plan.k_after - k
        vec = np.pad(vec, [(0, grow), (0, 0), (0, 0)])
        vid = np.pad(vid, [(0, grow), (0, 0)], constant_values=INVALID_ID)
        vat = np.pad(vat, [(0, grow), (0, 0), (0, 0)])
        val = np.pad(val, [(0, grow), (0, 0)])
        counts = np.pad(counts, (0, grow))
        cent = np.pad(cent, [(0, grow), (0, 0)])
        csz = np.pad(csz, (0, grow))
        drift = np.pad(drift, (0, grow))
        if quantized:
            cod = np.pad(cod, [(0, grow), (0, 0), (0, 0)])

    sizes = np.asarray([(plan.assign == p).sum() for p in plan.pids])
    pad = effective_pad_to(cfg)
    new_p_max = max(p_max, -(-int(max(sizes.max(), 1)) // pad) * pad)
    if new_p_max > p_max:
        grow = new_p_max - p_max
        vec = np.pad(vec, [(0, 0), (0, grow), (0, 0)])
        vid = np.pad(vid, [(0, 0), (0, grow)], constant_values=INVALID_ID)
        vat = np.pad(vat, [(0, 0), (0, grow), (0, 0)])
        val = np.pad(val, [(0, 0), (0, grow)])
        if quantized:
            cod = np.pad(cod, [(0, 0), (0, grow), (0, 0)])

    for j, p in enumerate(plan.pids):
        sel = plan.assign == p
        order = np.argsort(plan.row_ids[sel], kind="stable")
        m = int(sel.sum())
        vec[p] = 0.0
        vid[p] = INVALID_ID
        vat[p] = 0.0
        val[p] = False
        if quantized:
            cod[p] = 0
        if m:
            vec[p, :m] = plan.row_vecs[sel][order]
            vid[p, :m] = plan.row_ids[sel][order]
            vat[p, :m] = plan.row_attrs[sel][order]
            val[p, :m] = True
            if quantized:
                cod[p, :m] = plan.row_codes[sel][order]
        counts[p] = m
        cent[p] = plan.centroids[j]
        csz[p] = plan.csizes[j]
        drift[p] = 0.0

    return dataclasses.replace(
        index,
        centroids=jnp.asarray(cent), csizes=jnp.asarray(csz),
        vectors=jnp.asarray(vec), ids=jnp.asarray(vid),
        attrs=jnp.asarray(vat), valid=jnp.asarray(val),
        counts=jnp.asarray(counts),
        codes=jnp.asarray(cod) if quantized else None,
        code_norms=quantize.row_norms(index.qstats, jnp.asarray(cod))
        if quantized else None,
        drift=jnp.asarray(drift))


def repack_partition(index: IVFIndex, pid: int) -> IVFIndex:
    """Device-only tombstone repack of one partition: live rows re-pack
    ascending by asset id (the order paged frames and recover() use) and
    dead slots clear. No centroid, drift, or durable change -- the paged
    engine has no tombstones, so the two modes' durable states stay
    identical; write I/O is zero (the flash never sees it)."""
    pid = int(pid)
    vec = np.array(index.vectors[pid])
    vid = np.array(index.ids[pid])
    vat = np.array(index.attrs[pid])
    val = np.array(index.valid[pid])
    cod = np.array(index.codes[pid]) if index.codes is not None else None
    sel = np.nonzero(val)[0]
    order = np.argsort(vid[sel], kind="stable")
    m = len(sel)
    rows = sel[order]

    def repacked(buf, live, fill):
        out = np.full_like(buf, fill)
        out[:m] = live
        return out

    new = dataclasses.replace(
        index,
        vectors=index.vectors.at[pid].set(repacked(vec, vec[rows], 0.0)),
        ids=index.ids.at[pid].set(repacked(vid, vid[rows], INVALID_ID)),
        attrs=index.attrs.at[pid].set(repacked(vat, vat[rows], 0.0)),
        valid=index.valid.at[pid].set(
            np.concatenate([np.ones(m, bool),
                            np.zeros(len(val) - m, bool)])),
    )
    if cod is not None:
        new_codes = index.codes.at[pid].set(repacked(cod, cod[rows], 0))
        norms = index.code_norms if index.code_norms is not None \
            else quantize.row_norms(index.qstats, index.codes)
        new = dataclasses.replace(
            new, codes=new_codes,
            code_norms=norms.at[pid].set(
                quantize.row_norms(index.qstats, new_codes[pid])))
    return new


def live_rows(index: IVFIndex):
    """Extract all live rows (main + delta) back to host arrays."""
    val = np.asarray(index.valid)
    vec = np.asarray(index.vectors)[val]
    vid = np.asarray(index.ids)[val]
    vat = np.asarray(index.attrs)[val]
    dval = np.asarray(index.delta.valid)
    if dval.any():
        vec = np.concatenate([vec, np.asarray(index.delta.vectors)[dval]])
        vid = np.concatenate([vid, np.asarray(index.delta.ids)[dval]])
        vat = np.concatenate([vat, np.asarray(index.delta.attrs)[dval]])
    return vec, vid, vat


def full_rebuild(index: IVFIndex,
                 cfg: Optional[IVFConfig] = None
                 ) -> Tuple[IVFIndex, MaintenanceStats]:
    """Re-cluster everything from scratch (the paper's fallback when
    average partition growth crosses the threshold)."""
    cfg = cfg or index.config
    vec, vid, vat = live_rows(index)
    p_max_before = index.p_max
    new = ivf.build_index(vec, vid, vat, cfg=cfg)
    stats = MaintenanceStats(
        kind="full",
        rows_moved=int(len(vec)),
        partitions_touched=int(new.k),
        bytes_written=int(len(vec) * _row_bytes(index) + new.k * new.dim * 4),
        p_max_before=p_max_before, p_max_after=new.p_max)
    return new, stats
