"""Top-k maintenance & merging.

The paper (§3.3) keeps one max-heap per worker thread and merges heaps when
all threads finish. TPUs have no efficient random-access heap; the
semantically identical primitive is an associative *top-k merge*:

    merge(topk(A), topk(B)) == topk(A ++ B)

which lets us (a) keep a running top-k while scanning partition tiles and
(b) reduce per-device partial results across a mesh axis in log depth
(`tournament_merge`). Scores are "smaller is better" everywhere.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .types import INVALID_ID, MASKED_SCORE


def topk_smallest(scores: jax.Array, ids: jax.Array, k: int):
    """Top-k smallest scores along the last axis. Returns (scores, ids).

    Entries carrying MASKED_SCORE are no-results: their ids are
    invalidated so fewer-than-k matches never surface phantom ids."""
    neg, idx = jax.lax.top_k(-scores, k)
    out_s = -neg
    out_i = jnp.take_along_axis(ids, idx, axis=-1)
    out_i = jnp.where(out_s >= MASKED_SCORE, INVALID_ID, out_i)
    return out_s, out_i


def merge_topk(s_a, i_a, s_b, i_b, k: int):
    """Associative merge of two (scores, ids) top-k buffers -> top-k of union."""
    s = jnp.concatenate([s_a, s_b], axis=-1)
    i = jnp.concatenate([i_a, i_b], axis=-1)
    return topk_smallest(s, i, k)


def running_topk_init(batch_shape, k: int):
    s = jnp.full(batch_shape + (k,), MASKED_SCORE, jnp.float32)
    i = jnp.full(batch_shape + (k,), INVALID_ID, jnp.int32)
    return s, i


def mask_scores(scores: jax.Array, valid: jax.Array) -> jax.Array:
    """Push masked rows past any real score so they never enter a top-k."""
    return jnp.where(valid, scores, MASKED_SCORE)


def dedup_by_id(scores: jax.Array, ids: jax.Array):
    """Mask duplicate ids (keep best-scoring occurrence).

    Needed when a row exists both in a main partition (stale, tombstoned
    lazily) and the delta-store (fresh upsert): upsert semantics say the
    delta copy wins. Inputs are sorted ascending by score, so the first
    occurrence of an id is the one to keep.
    """
    order = jnp.argsort(scores, axis=-1)
    s = jnp.take_along_axis(scores, order, axis=-1)
    i = jnp.take_along_axis(ids, order, axis=-1)
    # first occurrence mask: id differs from every earlier id
    eq = i[..., :, None] == i[..., None, :]  # [.., K, K]
    earlier = jnp.tril(jnp.ones(eq.shape[-2:], bool), k=-1)
    dup = jnp.any(eq & earlier, axis=-1) & (i != INVALID_ID)
    s = jnp.where(dup, MASKED_SCORE, s)
    i = jnp.where(dup, INVALID_ID, i)
    return topk_smallest(s, i, s.shape[-1])


def tournament_merge(scores: jax.Array, ids: jax.Array, k: int, axis_name: str):
    """Log-depth cross-device top-k reduction along a mesh axis.

    Inside `shard_map`: every device holds a local [.., k] buffer; after the
    tournament every device holds the global top-k. Uses ppermute halving
    (hypercube exchange) so each round moves k rows instead of all-gathering
    world_size * k rows -- the TPU analogue of the paper's "efficient
    parallel heap merge", and cheaper on ICI than a flat all-gather when
    world size is large.
    """
    size = (jax.lax.axis_size(axis_name) if hasattr(jax.lax, "axis_size")
            else int(jax.lax.psum(1, axis_name)))  # 0.4.x: constant-folds
    assert size & (size - 1) == 0, "hypercube merge needs a power-of-2 axis"
    step = 1
    while step < size:
        perm = [(i, i ^ step) for i in range(size)]
        peer_s = jax.lax.ppermute(scores, axis_name, perm)
        peer_i = jax.lax.ppermute(ids, axis_name, perm)
        scores, ids = merge_topk(scores, ids, peer_s, peer_i, k)
        step <<= 1
    return scores, ids


def allgather_merge(scores: jax.Array, ids: jax.Array, k: int, axis_name: str):
    """Flat all-gather + local top-k (baseline collective schedule)."""
    s = jax.lax.all_gather(scores, axis_name, axis=scores.ndim - 1, tiled=True)
    i = jax.lax.all_gather(ids, axis_name, axis=ids.ndim - 1, tiled=True)
    return topk_smallest(s, i, k)
