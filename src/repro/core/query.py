"""First-class declarative query API: `QuerySpec` in, `ResultSet` out.

The public surface of MicroNN is two objects (after the Faiss library
paper's stable index/query object model, and the filtered-ANN argument
that hybrid predicates belong *in* the query object):

    spec = Q.knn(k=100).probe(8).where(Pred(0, "==", 3)).backend("xla")
    rs   = db.query(vecs, spec)          # ResultSet
    for hit in rs: ...                   # per-query iteration

`QuerySpec` is a frozen, hashable dataclass -- it IS the executor's jit
cache key (core/executor._run_spec takes the spec as its only static
argument), so two structurally-equal specs -- including structurally
equal `Pred` trees, which hash by value -- provably share one
compile-cache entry, and `executor.trace_count()` is pinned against the
spec rather than an ad-hoc kwarg tuple. Every fluent method returns a new
spec (dataclasses.replace), so specs can be built once, stored, and
shared across threads/sessions.

`ResultSet` is the typed result every path returns (resident, paged,
hybrid-optimized, sharded): ids + exact-f32 scores, optional gathered
attribute rows, per-query iteration, `merge()` for sharded/chunked top-k
reduction, and `to_numpy()` for host handoff.

Pipeline:  QuerySpec --(executor.run)--> QueryPlan --> fused scan -->
ResultSet.  Plan construction is an executor-internal detail; callers
never see plans.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .hybrid import Node
from .topk import dedup_by_id, merge_topk
from .types import INVALID_ID, SearchResult

_KINDS = ("ann", "exact")
_HYBRID = ("auto", "pre", "post")
_BACKENDS = (None, "pallas", "xla")

# A predicate slot holds either a frozen Pred/And/Or tree (preferred:
# hashes structurally, so equal trees share a jit entry) or an already
# compiled filter callable (hashes by identity -- the escape hatch for
# hand-written filters).
Predicate = Union[Node, Any]


@dataclasses.dataclass(frozen=True)
class QuerySpec:
    """One declarative search. Frozen + hashable: the jit cache key.

    Fields (all static; the builder methods below are the intended API):
      kind          "ann" (probe n_probe partitions) | "exact" (oracle)
      k             top-k width
      n_probe       partitions probed per query (ann)
      u_max         optional cap on the batched shared-scan union (MQO)
      cap           prefilter gather budget (hybrid == "pre"); None lets
                    the engine's optimizer size it from selectivity
      predicate     attribute predicate tree (Pred/And/Or), fused into
                    the scan or routed to pre-filtering
      hybrid        predicate strategy: "auto" (optimizer decides) |
                    "pre" (filter-then-brute-force) | "post" (fused)
      use_quantized scan-tier override: None auto (codes when present),
                    False forces f32, True requires codes
      on_backend    None auto | "pallas" | "xla"
      gather_attrs  gather result rows' attribute columns into the
                    ResultSet (engine-level; needs the durable store)
    """

    kind: str = "ann"
    k: int = 10
    n_probe: int = 8
    u_max: Optional[int] = None
    cap: Optional[int] = None
    predicate: Optional[Predicate] = None
    hybrid: str = "auto"
    use_quantized: Optional[bool] = None
    on_backend: Optional[str] = None
    gather_attrs: bool = False

    def __post_init__(self):
        assert self.kind in _KINDS, self.kind
        assert self.hybrid in _HYBRID, self.hybrid
        assert self.on_backend in _BACKENDS, self.on_backend
        assert self.k >= 1, self.k
        assert self.n_probe >= 1, self.n_probe

    # -- fluent builder (each call returns a NEW frozen spec) ---------------
    def top(self, k: int) -> "QuerySpec":
        return dataclasses.replace(self, k=k)

    def probe(self, n_probe: int) -> "QuerySpec":
        return dataclasses.replace(self, n_probe=n_probe)

    def union_cap(self, u_max: Optional[int]) -> "QuerySpec":
        """Cap the batched shared-scan union (the MQO knob, paper §3.4)."""
        return dataclasses.replace(self, u_max=u_max)

    def where(self, *predicates: Predicate) -> "QuerySpec":
        """Attach an attribute predicate. Several arguments AND together,
        and chained `.where()` calls ACCUMULATE (AND with the spec's
        existing predicate) -- a fluent chain never silently drops an
        earlier filter. Accepts Pred/And/Or trees or a compiled filter
        callable (the tree is recovered from `fn.predicate` when
        present, keeping the spec structurally hashable). A bare
        callable without a tree can only stand alone -- it cannot be
        AND-combined with other predicates (no tree to compose)."""
        from .hybrid import And, Or, Pred
        nodes = tuple(getattr(p, "predicate", p) for p in predicates)
        if self.predicate is not None:
            nodes = (self.predicate,) + nodes
        if len(nodes) == 1:
            node = nodes[0]
        else:
            bare = [n for n in nodes if not isinstance(n, (Pred, And, Or))]
            if bare:
                raise TypeError(
                    "where() can AND-combine predicate trees only; a "
                    "hand-written filter callable must be the sole "
                    f"predicate (got {len(bare)} callable(s) among "
                    f"{len(nodes)} predicates)")
            # flatten top-level Ands so .where(a).where(b).where(c) and
            # .where(a, b, c) build the SAME tree -- structurally equal
            # specs must share one jit cache entry however they were
            # chained
            flat = []
            for n in nodes:
                flat.extend(n.children if isinstance(n, And) else (n,))
            node = And(tuple(flat))
        return dataclasses.replace(self, predicate=node)

    @property
    def predicate_tree(self) -> Optional[Node]:
        """The predicate as a Pred/And/Or tree, or None when the spec
        carries no predicate OR an opaque hand-written callable (which
        selectivity estimation cannot inspect)."""
        from .hybrid import And, Or, Pred
        p = self.predicate
        return p if isinstance(p, (Pred, And, Or)) else None

    def exact(self) -> "QuerySpec":
        """100%-recall oracle: probe every partition."""
        return dataclasses.replace(self, kind="exact")

    def ann(self) -> "QuerySpec":
        return dataclasses.replace(self, kind="ann")

    def prefilter(self, cap: Optional[int] = None) -> "QuerySpec":
        """Force pre-filtering (evaluate the predicate first, brute-force
        the qualifiers). `cap` is the static gather budget; None lets the
        engine's optimizer size it from the selectivity estimate."""
        return dataclasses.replace(self, hybrid="pre", cap=cap)

    def postfilter(self) -> "QuerySpec":
        """Force post-filtering (predicate fused into the ANN scan)."""
        return dataclasses.replace(self, hybrid="post")

    def quantized(self, flag: Optional[bool] = True) -> "QuerySpec":
        return dataclasses.replace(self, use_quantized=flag)

    def backend(self, name: Optional[str]) -> "QuerySpec":
        return dataclasses.replace(self, on_backend=name)

    def with_attrs(self, flag: bool = True) -> "QuerySpec":
        return dataclasses.replace(self, gather_attrs=flag)


class Q:
    """Entry points of the fluent builder: `Q.knn(...)`, `Q.exact(...)`."""

    @staticmethod
    def knn(k: int = 10, n_probe: int = 8) -> QuerySpec:
        return QuerySpec(kind="ann", k=k, n_probe=n_probe)

    @staticmethod
    def exact(k: int = 10) -> QuerySpec:
        return QuerySpec(kind="exact", k=k)


# ---------------------------------------------------------------------------
# ResultSet
# ---------------------------------------------------------------------------


@dataclasses.dataclass(eq=False)     # array fields: no element-wise __eq__
class QueryResult:
    """One query's hits, trimmed of INVALID padding (host arrays)."""

    ids: np.ndarray                    # [m] int32
    scores: np.ndarray                 # [m] float32 (exact f32 distances)
    attrs: Optional[np.ndarray] = None  # [m, n_attr] if gathered

    def __len__(self) -> int:
        return len(self.ids)


@dataclasses.dataclass(eq=False)     # array fields: no element-wise __eq__
class ResultSet:
    """Typed top-k result batch -- what every search path returns.

    `ids`/`scores` keep the executor's device layout ([Q, k], INVALID_ID
    marks missing hits, scores are exact float32 distances -- smaller is
    better); iteration and `to_numpy()` move to host lazily. `merge()`
    is the associative top-k reduction used for sharded / chunked
    execution: merging per-shard ResultSets of the same query batch
    yields the global top-k (duplicate ids deduped, best score kept).
    """

    ids: jax.Array                      # [Q, k] int32
    scores: jax.Array                   # [Q, k] float32
    spec: Optional[QuerySpec] = None
    attrs: Optional[np.ndarray] = None  # [Q, k, n_attr] if gathered
    # obs.trace.QueryTrace when the query ran traced (engine.query(
    # trace=True) / explain() / a traced front-door submit); None on the
    # untraced hot path
    trace: Optional[Any] = dataclasses.field(
        default=None, repr=False, compare=False)
    # memoized host copy (one device->host transfer however often the
    # set is iterated/indexed)
    _np: Optional[Tuple[np.ndarray, np.ndarray]] = dataclasses.field(
        default=None, repr=False, compare=False)

    @staticmethod
    def of(res: SearchResult, spec: Optional[QuerySpec] = None,
           attrs: Optional[np.ndarray] = None) -> "ResultSet":
        return ResultSet(ids=res.ids, scores=res.scores, spec=spec,
                         attrs=attrs)

    @property
    def num_queries(self) -> int:
        return int(self.ids.shape[0])

    @property
    def k(self) -> int:
        return int(self.ids.shape[1])

    def __len__(self) -> int:
        return self.num_queries

    def __iter__(self) -> Iterator[QueryResult]:
        for qi in range(self.num_queries):
            yield self[qi]

    def __getitem__(self, qi: int) -> QueryResult:
        ids, scores = self.to_numpy()
        got = ids[qi] != INVALID_ID
        return QueryResult(
            ids=ids[qi][got], scores=scores[qi][got],
            attrs=None if self.attrs is None else self.attrs[qi][got])

    def to_numpy(self) -> Tuple[np.ndarray, np.ndarray]:
        if self._np is None:
            self._np = (np.asarray(self.ids), np.asarray(self.scores))
        return self._np

    def split(self, sizes: Sequence[int]) -> List["ResultSet"]:
        """Partition the batch dimension back into per-caller ResultSets
        -- the inverse of the serving front door's request coalescing
        (executor.run_coalesced concatenates per-caller chunks into one
        fused scan; this slices the [Q, k] result rows back out). Purely
        mechanical: each slice carries the same spec, its own row range
        of ids/scores, and its rows of any gathered attrs, so a
        coalesced execution followed by split() is indistinguishable
        from per-caller solo runs. `sizes` must sum to num_queries."""
        sizes = [int(s) for s in sizes]
        assert all(s >= 1 for s in sizes), sizes
        assert sum(sizes) == self.num_queries, \
            f"split sizes {sizes} != batch {self.num_queries}"
        out: List[ResultSet] = []
        off = 0
        for s in sizes:
            out.append(ResultSet(
                ids=self.ids[off:off + s], scores=self.scores[off:off + s],
                spec=self.spec,
                attrs=None if self.attrs is None
                else self.attrs[off:off + s]))
            off += s
        return out

    def merge(self, other: "ResultSet", k: Optional[int] = None
              ) -> "ResultSet":
        """Associative top-k merge of two candidate sets for the SAME
        query batch (sharded search / chunked streams). Duplicated ids
        (overlapping shards, re-sent chunks) are deduped keeping the
        best score."""
        assert self.ids.shape[0] == other.ids.shape[0], \
            "merge() needs the same query batch on both sides"
        k_out = k if k is not None else max(self.k, other.k)
        k_out = min(k_out, self.k + other.k)
        # merge at 2x width before deduping: an id appears at most once
        # per side, so 2*k_out candidates always cover the true top-k_out
        # even under full overlap
        k_wide = min(2 * k_out, self.k + other.k)
        s, i = merge_topk(jnp.asarray(self.scores), jnp.asarray(self.ids),
                          jnp.asarray(other.scores), jnp.asarray(other.ids),
                          k_wide)
        s, i = dedup_by_id(s, i)
        i, s = i[:, :k_out], s[:, :k_out]
        attrs = None
        if self.attrs is not None and other.attrs is not None:
            # realign gathered attr rows to the merged ids (id -> row,
            # per query; both sides must carry attrs or none survive)
            ids_m = np.asarray(i)
            n_attr = self.attrs.shape[-1]
            attrs = np.zeros(ids_m.shape + (n_attr,), np.float32)
            a_ids, _ = self.to_numpy()
            b_ids, _ = other.to_numpy()
            for qi in range(ids_m.shape[0]):
                lut = {int(r): self.attrs[qi, j]
                       for j, r in enumerate(a_ids[qi]) if r != INVALID_ID}
                lut.update({int(r): other.attrs[qi, j]
                            for j, r in enumerate(b_ids[qi])
                            if r != INVALID_ID})
                for j, r in enumerate(ids_m[qi]):
                    if r != INVALID_ID:
                        attrs[qi, j] = lut[int(r)]
        return ResultSet(ids=i, scores=s, spec=self.spec or other.spec,
                         attrs=attrs)
