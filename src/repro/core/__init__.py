"""MicroNN core: the paper's contributions C1-C6 as composable JAX modules.

  kmeans       -- Alg. 1: mini-batch balanced k-means (C1)
  ivf          -- index build + padded partition-major device layout (C2)
  query        -- the declarative API: QuerySpec/Q builder + ResultSet
  search       -- Alg. 2: ANN / exact / pre-filter search (C3)
  mqo          -- batch multi-query optimization (C4)
  hybrid       -- predicates, histograms, selectivity estimation (C5)
  optimizer    -- pre/post-filter plan chooser (C5)
  delta        -- streaming upsert / delete via delta-store (C6)
  maintenance  -- incremental flush + full rebuild (C6)
  monitor      -- index-quality tracking + maintenance triggers (C6)
  quantize     -- int8 scalar-quantization tier (codes + rerank contract)
  topk         -- running top-k + cross-device tournament merge
  rag          -- kNN-LM integration with the model zoo
"""
from . import (delta, hybrid, ivf, kmeans, maintenance, monitor, mqo,
               optimizer, quantize, query, rag, search, topk)
from .query import Q, QuerySpec, ResultSet
from .types import (DeltaStore, IVFConfig, IVFIndex, SearchResult,
                    INVALID_ID, pairwise_scores, normalize_if_cosine)

__all__ = [
    "delta", "hybrid", "ivf", "kmeans", "maintenance", "monitor", "mqo",
    "optimizer", "quantize", "query", "rag", "search", "topk",
    "Q", "QuerySpec", "ResultSet",
    "DeltaStore", "IVFConfig", "IVFIndex", "SearchResult", "INVALID_ID",
    "pairwise_scores", "normalize_if_cosine",
]
