"""Core datatypes for the MicroNN index.

The device-resident index is a pytree of fixed-shape arrays (TPU requires
static shapes). The paper's disk-resident layout (SQLite rows clustered by
partition id) maps to a partition-major padded tensor layout:

    vectors [k, p_max, d]   -- partition-major, padded to p_max per partition
    ids     [k, p_max]      -- asset ids, -1 marks padding / tombstones
    valid   [k, p_max]      -- live-row mask (False = padding or deleted)
    counts  [k]             -- live rows per partition

The delta-store (paper §3.6: "a reserved partition identifier") is carried
as a separate fixed-capacity block scanned by every query.

Balanced clustering (Alg. 1) bounds p_max, which bounds padding waste --
on TPU the paper's balance constraint is load-bearing for the memory
roofline, not just tail latency (see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

# Distances are "smaller is better" throughout. L2 uses squared distance;
# ip/cosine negate the dot product. Cosine vectors are L2-normalised at
# ingest so cosine == ip on the stored data.
METRICS = ("l2", "ip", "cosine")

# Sentinel id for padding / tombstoned rows.
INVALID_ID = -1
# Score assigned to masked rows so they never enter a top-k.
MASKED_SCORE = jnp.finfo(jnp.float32).max


def register_dataclass(cls):
    """Register a dataclass as a JAX pytree, splitting data vs meta fields."""
    data = [f.name for f in dataclasses.fields(cls) if not f.metadata.get("static")]
    meta = [f.name for f in dataclasses.fields(cls) if f.metadata.get("static")]
    jax.tree_util.register_dataclass(cls, data_fields=data, meta_fields=meta)
    return cls


def static_field(**kw):
    return dataclasses.field(metadata={"static": True}, **kw)


@register_dataclass
@dataclasses.dataclass
class IVFConfig:
    """Index construction / search configuration (paper §3.1, §3.3)."""

    dim: int = static_field(default=128)
    metric: str = static_field(default="l2")
    target_partition_size: int = static_field(default=100)  # paper default
    minibatch_size: int = static_field(default=256)
    kmeans_iters: int = static_field(default=20)
    balance_weight: float = static_field(default=1.0)  # lambda in NEAREST penalty
    balanced_final_assign: bool = static_field(default=False)  # beyond-paper knob
    delta_capacity: int = static_field(default=1024)
    # Partition padding granularity; p_max is rounded up to a multiple of
    # this so Pallas tiles stay MXU-aligned.
    pad_to: int = static_field(default=8)
    # Rebuild trigger: fraction growth of mean partition size (paper: 0.5).
    rebuild_growth_threshold: float = static_field(default=0.5)
    # Scalar-quantization tier: "none" keeps the float32-only index;
    # "int8" adds per-dimension SQ codes scanned by kernels/sq_scan.py
    # with a float32 rerank over k' = rerank_factor * k candidates
    # (core/quantize.py).
    quantize: str = static_field(default="none")  # "none" | "int8"
    rerank_factor: int = static_field(default=4)
    seed: int = static_field(default=0)


def effective_pad_to(cfg: "IVFConfig", backend: Optional[str] = None) -> int:
    """Dtype-aware Pallas tile padding for the partition axis.

    Real TPU hardware tiles int8 at a (32, 128) minimum, so a compiled SQ
    scan needs p_max to be a multiple of 32; float32 tiles at (8, 128) and
    interpret mode has no constraint. `backend` defaults to the runtime
    backend, so CPU/GPU tests keep the configured (small) padding while a
    TPU run of a quantized index is bumped automatically."""
    if backend is None:
        backend = jax.default_backend()
    if cfg.quantize == "int8" and backend == "tpu":
        return max(cfg.pad_to, 32)
    return cfg.pad_to


@register_dataclass
@dataclasses.dataclass
class DeltaStore:
    """Fixed-capacity staging area for streaming inserts (paper §3.6)."""

    vectors: jax.Array  # [cap, d]
    ids: jax.Array      # [cap] int32, INVALID_ID where empty
    attrs: jax.Array    # [cap, n_attr] float32
    valid: jax.Array    # [cap] bool
    count: jax.Array    # [] int32 -- number of live rows
    # int8 SQ codes mirroring `vectors`, present iff the owning index is
    # quantized (encoded at insert, moved verbatim by flush_delta).
    codes: Optional[jax.Array] = None  # [cap, d] int8

    @property
    def capacity(self) -> int:
        return self.vectors.shape[0]

    @staticmethod
    def empty(cap: int, dim: int, n_attr: int,
              quantized: bool = False) -> "DeltaStore":
        return DeltaStore(
            vectors=jnp.zeros((cap, dim), jnp.float32),
            ids=jnp.full((cap,), INVALID_ID, jnp.int32),
            attrs=jnp.zeros((cap, n_attr), jnp.float32),
            valid=jnp.zeros((cap,), bool),
            count=jnp.zeros((), jnp.int32),
            codes=jnp.zeros((cap, dim), jnp.int8) if quantized else None,
        )


@register_dataclass
@dataclasses.dataclass
class IVFIndex:
    """Device-resident IVF index state (paper Fig. 2 schema, tensorised)."""

    centroids: jax.Array   # [k, d] float32
    csizes: jax.Array      # [k] int32 -- kmeans running counts (for updates)
    vectors: jax.Array     # [k, p_max, d] float32
    ids: jax.Array         # [k, p_max] int32
    attrs: jax.Array       # [k, p_max, n_attr] float32
    valid: jax.Array       # [k, p_max] bool
    counts: jax.Array      # [k] int32 live rows per partition
    delta: DeltaStore
    # Mean partition size at last (re)build -- the monitor compares the
    # current mean against this to trigger rebuilds (paper §3.6).
    base_mean_size: jax.Array  # [] float32
    # Scalar-quantization tier (config.quantize == "int8"): per-row int8
    # codes mirroring `vectors` plus the per-dimension quantizer stats
    # (core/quantize.QuantStats pytree). None on a float32-only index.
    codes: Optional[jax.Array] = None   # [k, p_max, d] int8
    qstats: Optional[Any] = None        # quantize.QuantStats
    # Precomputed ||decode(codes)||^2 per row (quantize.row_norms) -- the
    # l2 epilogue constant of the int8-domain scan. Invariant: whenever
    # `codes` is present and mutated, code_norms is recomputed alongside
    # it, so code_norms == quantize.row_norms(qstats, codes) always holds
    # (kernels read it instead of re-decoding the code tier per query).
    code_norms: Optional[jax.Array] = None  # [k, p_max] f32
    # Per-partition drift state (paper §3.6 / LIRE-style local repair):
    # cumulative centroid displacement since the partition was last
    # (re)clustered, accumulated by maintenance.running_mean_update and
    # reset by split/merge/local_recluster and rebuilds. The monitor
    # compares it against the centroid spacing to queue "recluster" work
    # for partitions whose running mean has wandered from their rows.
    # None on hand-assembled indexes (treated as zero drift).
    drift: Optional[jax.Array] = None   # [k] float32
    config: IVFConfig = static_field(default_factory=IVFConfig)

    @property
    def k(self) -> int:
        return self.centroids.shape[0]

    @property
    def p_max(self) -> int:
        return self.vectors.shape[1]

    @property
    def dim(self) -> int:
        return self.centroids.shape[1]

    @property
    def n_attr(self) -> int:
        return self.attrs.shape[-1]

    @property
    def quantized(self) -> bool:
        return self.codes is not None

    def num_live(self) -> jax.Array:
        # delta.count is the write cursor; valid tracks live rows
        return self.counts.sum() + self.delta.valid.sum()


@dataclasses.dataclass
class PagedIndex:
    """Memory-budgeted *paged* view of the index (the paper's actual
    disk-resident mode): only metadata is resident -- centroids, csizes,
    live counts, the delta store, and the quantizer stats. The scan tier
    (int8 codes when quantized, float32 vectors otherwise) stays in SQLite
    and is faulted on demand into a storage/pager.PartitionCache frame
    pool; core/executor.paged_search drives fault -> frame scan -> disk
    rerank. Deliberately NOT a jax pytree: execution is host-driven and
    the cache is a stateful host object."""

    centroids: jax.Array       # [k, d] float32
    csizes: jax.Array          # [k] float32 (kmeans running counts)
    counts: Any                # [k] int64 host array -- live rows/partition
    delta: DeltaStore          # resident staging area (small, fixed cap)
    cache: Any                 # storage.pager.PartitionCache
    base_mean_size: float
    qstats: Optional[Any] = None    # quantize.QuantStats (int8 mode)
    # Per-partition drift state (host array, same signal as IVFIndex.drift;
    # session-local -- recovery starts it at zero).
    drift: Any = None               # [k] float32 np.ndarray
    config: IVFConfig = dataclasses.field(default_factory=IVFConfig)

    @property
    def k(self) -> int:
        return self.centroids.shape[0]

    @property
    def dim(self) -> int:
        return self.centroids.shape[1]

    @property
    def p_max(self) -> int:
        return self.cache.p_max

    @property
    def n_attr(self) -> int:
        return self.delta.attrs.shape[-1]

    @property
    def quantized(self) -> bool:
        return self.qstats is not None and self.cache.payload == "int8"

    def num_live(self):
        return int(self.counts.sum()) + int(self.delta.valid.sum())


@register_dataclass
@dataclasses.dataclass
class SearchResult:
    """Top-k result batch. ids are INVALID_ID where fewer than k matches."""

    ids: jax.Array        # [Q, K] int32
    scores: jax.Array     # [Q, K] float32 (smaller is better)


def normalize_if_cosine(x: jax.Array, metric: str) -> jax.Array:
    if metric == "cosine":
        n = jnp.linalg.norm(x, axis=-1, keepdims=True)
        return x / jnp.maximum(n, 1e-12)
    return x


def pairwise_scores(q: jax.Array, v: jax.Array, metric: str) -> jax.Array:
    """[Q, d] x [N, d] -> [Q, N] scores, smaller is better.

    L2 uses the matmul expansion ||q-v||^2 = ||q||^2 + ||v||^2 - 2 q.v so the
    MXU does the heavy lifting (paper §3.3's SIMD batching, TPU-native).
    """
    dots = q @ v.T
    if metric in ("ip", "cosine"):
        return -dots
    q2 = jnp.sum(q * q, axis=-1, keepdims=True)
    v2 = jnp.sum(v * v, axis=-1)
    return q2 + v2[None, :] - 2.0 * dots
