"""IVF index construction and the padded partition-major device layout.

Build path (paper §3.1-3.2): cluster with mini-batch balanced k-means, then
lay vectors out partition-major. On disk (SQLite) the layout is a clustered
primary index on (partition_id, asset_id); on device it is the padded
[k, p_max, d] tensor described in core/types.py. `p_max` is the post-build
max partition size rounded up to `cfg.pad_to` -- balanced clustering keeps
the padding overhead small (measured in benchmarks/bench_build.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import kmeans, quantize
from .types import (DeltaStore, INVALID_ID, IVFConfig, IVFIndex,
                    effective_pad_to, normalize_if_cosine)


def pack_partitions(
    X: np.ndarray,            # [n, d] float32
    ids: np.ndarray,          # [n] int32
    attrs: Optional[np.ndarray],  # [n, n_attr] float32 or None
    assign: np.ndarray,       # [n] int32 partition per row
    k: int,
    pad_to: int = 8,
    p_max: Optional[int] = None,
    codes: Optional[np.ndarray] = None,  # [n, d] int8 SQ codes or None
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray,
           Optional[np.ndarray]]:
    """Repack rows into the padded partition-major layout (host-side op --
    this is the 'disk reorganisation' tier; SQLite does the same job with a
    clustered index ORDER BY partition_id). When `codes` is given the int8
    code tier is packed row-for-row with the vectors (same slots), so the
    SQ scan and the rerank gather agree on row placement."""
    n, d = X.shape
    n_attr = 0 if attrs is None else attrs.shape[1]
    attrs = np.zeros((n, 0), np.float32) if attrs is None else attrs
    counts = np.bincount(assign, minlength=k).astype(np.int32)
    if p_max is None:
        p_max = int(counts.max()) if n else pad_to
        p_max = max(pad_to, -(-p_max // pad_to) * pad_to)

    vec = np.zeros((k, p_max, d), np.float32)
    vid = np.full((k, p_max), INVALID_ID, np.int32)
    vat = np.zeros((k, p_max, n_attr), np.float32)
    val = np.zeros((k, p_max), bool)
    cod = None if codes is None else np.zeros((k, p_max, d), np.int8)

    order = np.argsort(assign, kind="stable")
    slot = np.zeros(k, np.int64)
    for row in order:
        p = assign[row]
        s = slot[p]
        if s >= p_max:  # overflow can only happen on incremental appends
            raise ValueError(f"partition {p} overflows p_max={p_max}")
        vec[p, s] = X[row]
        vid[p, s] = ids[row]
        vat[p, s] = attrs[row]
        val[p, s] = True
        if cod is not None:
            cod[p, s] = codes[row]
        slot[p] = s + 1
    return vec, vid, vat, val, counts, cod


def build_index(
    X: np.ndarray,
    ids: Optional[np.ndarray] = None,
    attrs: Optional[np.ndarray] = None,
    cfg: Optional[IVFConfig] = None,
    k: Optional[int] = None,
    qstats: Optional[quantize.QuantStats] = None,
) -> IVFIndex:
    """Full index build: Alg. 1 clustering + partition-major packing.

    With cfg.quantize == "int8" the build also trains the scalar quantizer
    (unless pre-trained stats are passed, e.g. streamed from the durable
    store) and encodes every row into the code tier.
    """
    cfg = cfg or IVFConfig(dim=X.shape[1])
    X = np.asarray(
        normalize_if_cosine(jnp.asarray(X, jnp.float32), cfg.metric))
    n = X.shape[0]
    ids = np.arange(n, dtype=np.int32) if ids is None else ids.astype(np.int32)

    codes = None
    if cfg.quantize == "int8":
        if qstats is None:
            qstats = quantize.train(jnp.asarray(X))
        codes = quantize.encode_np(qstats, X)
    else:
        qstats = None

    centroids, csizes, assign = kmeans.fit_in_memory(X, cfg, k=k)
    k = centroids.shape[0]
    # dtype-aware tile padding: int8 partitions on real TPU pad to the
    # (32, 128) minimum tile; f32 / interpret keep cfg.pad_to
    vec, vid, vat, val, counts, cod = pack_partitions(
        X, ids, attrs, assign, k, pad_to=effective_pad_to(cfg), codes=codes)

    n_attr = vat.shape[-1]
    return IVFIndex(
        centroids=jnp.asarray(centroids),
        csizes=jnp.asarray(csizes, jnp.float32),
        vectors=jnp.asarray(vec),
        ids=jnp.asarray(vid),
        attrs=jnp.asarray(vat),
        valid=jnp.asarray(val),
        counts=jnp.asarray(counts),
        delta=DeltaStore.empty(cfg.delta_capacity, X.shape[1], n_attr,
                               quantized=cod is not None),
        base_mean_size=jnp.asarray(counts.mean() if n else 0.0, jnp.float32),
        codes=None if cod is None else jnp.asarray(cod),
        qstats=qstats,
        code_norms=None if cod is None else quantize.row_norms(
            qstats, jnp.asarray(cod)),
        drift=jnp.zeros((k,), jnp.float32),
        config=cfg,
    )


def grow_layout(index: IVFIndex, new_p_max: int) -> IVFIndex:
    """Grow p_max (host-side maintenance; keeps device shapes static
    between maintenance points)."""
    k, p_max, d = index.vectors.shape
    assert new_p_max >= p_max
    pad = new_p_max - p_max

    def pad2(a, fill):
        widths = [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2)
        return jnp.pad(a, widths, constant_values=fill)

    return dataclasses.replace(
        index,
        vectors=pad2(index.vectors, 0.0),
        ids=pad2(index.ids, INVALID_ID),
        attrs=pad2(index.attrs, 0.0),
        valid=pad2(index.valid, False),
        codes=None if index.codes is None else pad2(index.codes, 0),
        # recompute (not pad) so the padded slots carry decode-of-zero
        # norms, preserving code_norms == row_norms(qstats, codes)
        code_norms=None if index.codes is None else quantize.row_norms(
            index.qstats, pad2(index.codes, 0)),
    )
