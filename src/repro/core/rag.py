"""Retrieval-augmented generation: MicroNN as a first-class LM feature.

kNN-LM-style decode (Khandelwal et al. style, adapted): the backbone's last
hidden state is the query vector; the MicroNN index stores (context
embedding -> next-token id) pairs; retrieved neighbour tokens form a
distance-weighted distribution that is interpolated with the LM softmax:

    p(w) = lam * p_knn(w) + (1 - lam) * p_lm(w)

The index here is the *same* updatable IVF structure as everywhere else --
streaming upserts let the datastore grow during deployment, the paper's
whole point. For multi-pod serving the datastore partitions shard over the
`model` axis and the per-device partial top-k merges with the tournament
reduction (core/topk.py); see distributed/sharded_index.py.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from . import executor
from .query import Q, QuerySpec
from .types import IVFIndex, static_field, register_dataclass


@register_dataclass
@dataclasses.dataclass
class RagConfig:
    k: int = static_field(default=16)           # neighbours per decode step
    n_probe: int = static_field(default=8)
    lam: float = static_field(default=0.25)     # kNN interpolation weight
    temperature: float = static_field(default=10.0)  # distance -> weight

    def spec(self) -> QuerySpec:
        """The retrieval QuerySpec this config denotes: one frozen spec
        per config, so every decode step of a serving session hits the
        same executor compile-cache entry."""
        return Q.knn(k=self.k, n_probe=self.n_probe)


@register_dataclass
@dataclasses.dataclass
class RagDatastore:
    """IVF index + neighbour payload (next-token per stored vector id)."""
    index: IVFIndex
    # payload token for each asset id; ids index this table directly
    next_token: jax.Array     # [max_id] int32


def knn_logits(
    ds: RagDatastore,
    hidden: jax.Array,        # [B, d] query embeddings (LM hidden states)
    vocab: int,
    cfg: RagConfig,
    spec: Optional[QuerySpec] = None,
) -> jax.Array:
    """[B, vocab] log-probabilities from the retrieved neighbourhood.

    `spec` overrides the retrieval QuerySpec (e.g. a hybrid predicate
    over document attributes, or a backend pin); defaults to cfg.spec().
    """
    res = executor.run(ds.index, hidden, spec if spec is not None
                       else cfg.spec())
    ok = res.ids >= 0
    toks = ds.next_token[jnp.maximum(res.ids, 0)]            # [B, K]
    w = jax.nn.softmax(
        jnp.where(ok, -res.scores * cfg.temperature, -jnp.inf), axis=-1)
    probs = jnp.zeros((hidden.shape[0], vocab), jnp.float32)
    probs = probs.at[jnp.arange(hidden.shape[0])[:, None], toks].add(
        jnp.where(ok, w, 0.0))
    # guard fully-empty retrievals
    any_ok = ok.any(-1, keepdims=True)
    probs = jnp.where(any_ok, probs, 1.0 / vocab)
    return jnp.log(jnp.maximum(probs, 1e-20))


def interpolate(
    lm_logits: jax.Array,     # [B, vocab]
    knn_logp: jax.Array,      # [B, vocab]
    lam: float,
) -> jax.Array:
    """log( lam * p_knn + (1-lam) * p_lm ) computed stably."""
    lm_logp = jax.nn.log_softmax(lm_logits.astype(jnp.float32), axis=-1)
    return jnp.logaddexp(jnp.log1p(-lam) + lm_logp, jnp.log(lam) + knn_logp)


def rag_decode_logits(
    ds: RagDatastore,
    lm_logits: jax.Array,
    hidden: jax.Array,
    cfg: RagConfig,
    spec: Optional[QuerySpec] = None,
) -> jax.Array:
    vocab = lm_logits.shape[-1]
    return interpolate(lm_logits, knn_logits(ds, hidden, vocab, cfg, spec),
                       cfg.lam)
