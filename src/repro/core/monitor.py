"""Index monitor (paper Fig. 1, §3.6): tracks quality signals on updates
and decides when to run incremental maintenance vs a full rebuild.

Signals tracked (after [26]):
  * delta pressure: live delta rows / capacity -- high pressure raises
    query latency (the delta partition is always scanned);
  * partition growth: mean live partition size vs size at last rebuild --
    the paper triggers a full rebuild at +50% growth;
  * tombstone ratio: dead rows inflate scan cost without contributing
    results.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .types import IVFIndex


@dataclasses.dataclass
class MonitorConfig:
    delta_flush_fraction: float = 0.75   # flush when delta is this full
    growth_rebuild_threshold: float = 0.5  # paper: 50% mean-size growth
    tombstone_rebuild_fraction: float = 0.3


@dataclasses.dataclass
class IndexHealth:
    n_live: int
    delta_pressure: float
    mean_partition_size: float
    growth: float            # relative growth vs base_mean_size
    tombstone_fraction: float
    action: str              # "none" | "flush" | "rebuild"


class IndexMonitor:
    def __init__(self, cfg: MonitorConfig | None = None):
        self.cfg = cfg or MonitorConfig()
        self.history: list[IndexHealth] = []

    def check(self, index: IVFIndex) -> IndexHealth:
        cfg = self.cfg
        counts = np.asarray(index.counts)
        valid = np.asarray(index.valid)
        live_main = int(valid.sum())
        delta_live = int(np.asarray(index.delta.valid).sum())
        delta_cursor = int(index.delta.count)
        nonempty = max(1, int((counts > 0).sum()))
        mean_size = live_main / nonempty
        base = float(index.base_mean_size) or 1.0
        growth = mean_size / base - 1.0
        # tombstones: occupied slots (cursor-written or once-valid) now dead
        dead_main = int((np.asarray(index.ids) != -1).sum()) - live_main
        tomb = dead_main / max(1, live_main + dead_main)

        if growth >= cfg.growth_rebuild_threshold or \
           tomb >= cfg.tombstone_rebuild_fraction:
            action = "rebuild"
        elif delta_cursor >= cfg.delta_flush_fraction * index.delta.capacity:
            action = "flush"
        else:
            action = "none"

        health = IndexHealth(
            n_live=live_main + delta_live,
            delta_pressure=delta_cursor / max(1, index.delta.capacity),
            mean_partition_size=mean_size,
            growth=growth,
            tombstone_fraction=tomb,
            action=action)
        self.history.append(health)
        return health
