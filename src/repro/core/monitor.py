"""Index monitor (paper Fig. 1, §3.6): tracks quality signals on updates
and decides what maintenance the index needs.

Two APIs, one set of signals:

  * `check(index)` -- the legacy single-verdict API ("none" | "flush" |
    "rebuild"), kept for callers that still run whole-index maintenance;
  * `work_queue(index)` -- the incremental API (PR 5): per-partition
    size/drift signals become a PRIORITIZED queue of `WorkItem(action,
    pids)` entries drained by storage/scheduler.MaintenanceScheduler in
    bounded work quanta. This is what retires the full rebuild as the
    steady-state path: oversized partitions split, underfull siblings
    merge, drifted or tombstone-heavy neighbourhoods recluster locally.

Signals tracked (after [26]):
  * delta pressure: live delta rows / capacity -- high pressure raises
    query latency (the delta partition is always scanned);
  * per-partition size vs the clustering target -- the split/merge
    triggers (the global mean-growth signal is what the legacy rebuild
    verdict uses);
  * per-partition drift: cumulative centroid displacement since the last
    local repair (maintenance.running_mean_update accumulates it),
    normalised by the centroid spacing -- the recall-killer under churn
    is a running mean that no longer sits among its rows;
  * tombstone ratio: dead rows inflate scan cost without contributing
    results (per-partition in work_queue, so one churned partition
    triggers a local repack instead of a global rebuild).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from . import maintenance
from .types import IVFIndex


@dataclasses.dataclass
class MonitorConfig:
    delta_flush_fraction: float = 0.75   # flush when delta is this full
    growth_rebuild_threshold: float = 0.5  # paper: 50% mean-size growth
    tombstone_rebuild_fraction: float = 0.3
    # -- incremental (work_queue) triggers ----------------------------------
    # split a partition past split_threshold * target_partition_size rows;
    # 2.0 is the B-tree doubling point: a split yields two target-sized
    # halves, so split write I/O amortizes to <= 0.5 moved rows per insert
    split_threshold: float = 2.0
    # merge a partition below merge_threshold * target_partition_size rows
    # (into its nearest sibling, if the pair stays under the split bar)
    merge_threshold: float = 0.4
    # recluster a partition whose accumulated centroid drift exceeds this
    # fraction of the mean nearest-centroid spacing
    drift_recluster_threshold: float = 0.5
    # how many nearest neighbours a drift/tombstone recluster pulls into
    # its reassignment neighbourhood (maintenance.neighborhood)
    repair_neighbors: int = 2
    # how many neighbours a *split* reassigns besides the split partition
    # itself; 0 keeps split write-I/O at one partition's rows (boundary
    # repair is the drift recluster's job, triggered only when warranted)
    split_neighbors: int = 0


@dataclasses.dataclass
class IndexHealth:
    n_live: int
    delta_pressure: float
    mean_partition_size: float
    growth: float            # relative growth vs base_mean_size
    tombstone_fraction: float
    action: str              # "none" | "flush" | "rebuild"


@dataclasses.dataclass(frozen=True)
class WorkItem:
    """One unit of incremental maintenance. `pids` is () for a flush, a
    1-tuple for split/recluster/repack, ("into", "victim") for a merge.
    `rows` estimates the rows the item touches (the scheduler budgets on
    it)."""

    action: str        # "flush" | "split" | "merge" | "recluster" | "repack"
    pids: Tuple[int, ...]
    rows: int
    priority: float


class IndexMonitor:
    def __init__(self, cfg: MonitorConfig | None = None):
        self.cfg = cfg or MonitorConfig()
        self.history: list[IndexHealth] = []

    def check(self, index: IVFIndex) -> IndexHealth:
        cfg = self.cfg
        counts = np.asarray(index.counts)
        valid = np.asarray(index.valid)
        live_main = int(valid.sum())
        delta_live = int(np.asarray(index.delta.valid).sum())
        delta_cursor = int(index.delta.count)
        nonempty = max(1, int((counts > 0).sum()))
        mean_size = live_main / nonempty
        base = float(index.base_mean_size) or 1.0
        growth = mean_size / base - 1.0
        # tombstones: occupied slots (cursor-written or once-valid) now dead
        dead_main = int((np.asarray(index.ids) != -1).sum()) - live_main
        tomb = dead_main / max(1, live_main + dead_main)

        if growth >= cfg.growth_rebuild_threshold or \
           tomb >= cfg.tombstone_rebuild_fraction:
            action = "rebuild"
        elif delta_cursor >= cfg.delta_flush_fraction * index.delta.capacity:
            action = "flush"
        else:
            action = "none"

        health = IndexHealth(
            n_live=live_main + delta_live,
            delta_pressure=delta_cursor / max(1, index.delta.capacity),
            mean_partition_size=mean_size,
            growth=growth,
            tombstone_fraction=tomb,
            action=action)
        self.history.append(health)
        return health

    # -- incremental maintenance (PR 5) -------------------------------------
    def work_queue(self, index) -> List[WorkItem]:
        """Per-partition signals -> a prioritized list of maintenance work.

        Works against a resident IVFIndex or a PagedIndex (both expose
        counts / delta / centroids / drift); per-partition tombstone
        repacks only apply to the resident packed layout (the durable
        tier deletes rows eagerly). Priorities order flushes (the delta
        gates the write path) ahead of splits (recall + p_max pressure)
        ahead of merges (scan waste) ahead of drift reclustering.
        """
        cfg = self.cfg
        target = max(1, int(index.config.target_partition_size))
        counts = np.asarray(index.counts)
        k = counts.shape[0]
        items: List[WorkItem] = []

        delta_cursor = int(index.delta.count)
        delta_live = int(np.asarray(index.delta.valid).sum())
        if delta_cursor >= cfg.delta_flush_fraction * index.delta.capacity:
            pressure = delta_cursor / max(1, index.delta.capacity)
            items.append(WorkItem("flush", (), delta_live,
                                  100.0 + pressure))
        elif delta_live:
            # below the pressure bar the flush is still *pending* work --
            # "idle" means an empty delta -- just the lowest priority
            items.append(WorkItem("flush", (), delta_live, 0.5))

        split_bar = cfg.split_threshold * target
        for p in np.nonzero(counts > split_bar)[0]:
            items.append(WorkItem("split", (int(p),), int(counts[p]),
                                  10.0 + counts[p] / split_bar))

        merge_bar = cfg.merge_threshold * target
        if k > 1:
            cents = np.asarray(index.centroids)
            small = np.nonzero((counts > 0) & (counts < merge_bar))[0]
            taken: set = set()
            for q in small:
                q = int(q)
                if q in taken:
                    continue
                # bin-packing partner choice (best-fit): the partner that
                # minimizes post-merge slack under the split bar, ties by
                # centroid distance then pid (maintenance.choose_merge_partner)
                into = maintenance.choose_merge_partner(
                    cents, counts, q, split_bar, exclude=taken)
                if into is None:
                    continue
                taken.update((q, into))
                items.append(WorkItem(
                    "merge", (into, q), int(counts[into] + counts[q]),
                    5.0 + (1.0 - counts[q] / merge_bar)))

        # drift: a running mean that wandered a good fraction of the
        # centroid spacing no longer represents its rows -> local repair
        drift = getattr(index, "drift", None)
        if drift is not None and k > 1:
            drift = np.asarray(drift)
            cents = np.asarray(index.centroids)
            live = counts > 0
            if live.sum() > 1:
                d2 = ((cents[:, None, :] - cents[None, :, :]) ** 2).sum(-1)
                d2[~live, :] = np.inf
                d2[:, ~live] = np.inf
                np.fill_diagonal(d2, np.inf)
                spacing = float(np.sqrt(d2.min(axis=1)[live]).mean())
                bar = cfg.drift_recluster_threshold * max(spacing, 1e-12)
                for p in np.nonzero(live & (drift[:k] >= bar))[0]:
                    items.append(WorkItem(
                        "recluster", (int(p),), int(counts[p]),
                        1.0 + float(drift[p]) / bar))

        # per-partition tombstone repack: ONLY the resident packed layout
        # carries tombstones (the durable tier and the paged frames delete
        # eagerly), so this is a device-only repack with NO durable
        # effect -- the resident and paged durable states stay identical
        ids = getattr(index, "ids", None)
        if ids is not None:
            ids = np.asarray(ids)
            valid = np.asarray(index.valid)
            dead = ((ids != -1) & ~valid).sum(-1)
            occ = dead + valid.sum(-1)
            frac = dead / np.maximum(occ, 1)
            hit = (frac >= cfg.tombstone_rebuild_fraction) & (dead > 0)
            for p in np.nonzero(hit)[0]:
                items.append(WorkItem(
                    "repack", (int(p),), int(counts[p]),
                    3.0 + float(frac[p])))

        items.sort(key=lambda it: (-it.priority, it.action, it.pids))
        return items
