"""Unified query-execution layer: every search is a QueryPlan run by one
fused scan primitive (the repo's single implementation of paper Alg. 2).

Module map -- who builds plans, who runs them:

    core/search.py      thin plan-builders: ann_search / exact_search /
                        prefilter_search (public API preserved)
    core/mqo.py         thin plan-builder: mqo_search (same shared-scan
                        plan as ANN, explicit union cap)
    core/optimizer.py   hybrid pre/post plan choice (paper Eqs. 1-3),
                        both arms issued through this executor
    core/rag.py         kNN-LM retrieval -> ANN plans
    storage/engine.py   MicroNN.search -> plans (ann/exact/predicate/mqo)
    distributed/        sharded_index phase 3 calls fused_scan directly
                        on each device's local partition shard
    kernels/ivf_scan.py the Pallas TPU backend of fused_scan
    benchmarks/bench_executor.py   backend + plan-cache latency

Plan model (paper Alg. 2 generalised):
    probe set         part_ids [n]  -- shared partition scan list
    selection mask    qsel [Q, n]   -- which query wants which partition
                                       (MQO §3.4; ANN is the batch union)
    fused predicate   attr_filter   -- compiled hybrid predicate, masked
                                       *before* top-k (§3.5)
    k                 running top-k width (§3.3)
Exact = probe everything; pre-filter = compact qualifying rows into
virtual partitions and probe those (§3.5, cost ~ the gather cap).

Two interchangeable backends execute the same plan shape-identically:
    "pallas"  fused kernel (kernels/ivf_scan.py); interpret mode is
              auto-selected off-TPU
    "xla"     reference path for CPU/GPU -- one shared [n*p_max] matmul
Neither materialises the seed's per-query [Q, n_probe, p_max, d] gather:
the probe union is scanned once and queries mask into it.

Plan/compile cache: the `search` facade buckets the query count to the
next power of two and routes through one jitted entry point whose cache
key is (Q_bucket, kind, k, n_probe/u_max/cap, predicate_id, backend) --
repeated same-shape (or same-bucket) queries never retrace.
`trace_count()` exposes the retrace counter for tests/benchmarks.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from .topk import dedup_by_id, mask_scores, merge_topk, topk_smallest
from .types import (INVALID_ID, MASKED_SCORE, IVFIndex, SearchResult,
                    normalize_if_cosine, pairwise_scores, register_dataclass,
                    static_field)

# attr_filter: [..., n_attr] float32 -> [...] bool  (hybrid.compile_filter;
# memoized there so equal predicates are identical objects / cache keys)
AttrFilter = Callable[[jax.Array], jax.Array]

# Retrace counter: incremented each time the jitted entry point actually
# traces. Stable counter == plan-cache hit.
_TRACE_COUNT = 0


def trace_count() -> int:
    return _TRACE_COUNT


def default_backend() -> str:
    """Pallas kernel on real TPU, shape-identical XLA path elsewhere."""
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def find_nearest_centroids(index: IVFIndex, q: jax.Array, n_probe: int):
    """[Q, d] -> [Q, n_probe] partition ids (line 3 of Alg. 2)."""
    cd = pairwise_scores(q, index.centroids, index.config.metric)
    # Empty partitions can never contribute; push them out of the probe set.
    cd = jnp.where(index.counts[None, :] > 0, cd, jnp.finfo(cd.dtype).max)
    n_probe = min(n_probe, index.k)
    _, parts = jax.lax.top_k(-cd, n_probe)
    return parts


# ---------------------------------------------------------------------------
# QueryPlan + builders
# ---------------------------------------------------------------------------


@register_dataclass
@dataclasses.dataclass
class QueryPlan:
    """One compiled search: probe set + per-query mask + predicate + k.

    `queries` are already metric-normalised. For kind "prefilter" the probe
    set is replaced by `rows`, a fixed-cap compaction of qualifying row
    indices that execute_plan repacks into virtual partitions.
    """

    queries: jax.Array                    # [Q, d] f32
    part_ids: Optional[jax.Array]         # [n] int32 (None for prefilter)
    qsel: Optional[jax.Array]             # [Q, n] bool (None: all queries)
    rows: Optional[jax.Array]             # [cap] int32 (prefilter only)
    k: int = static_field(default=10)
    kind: str = static_field(default="ann")   # ann | exact | prefilter
    attr_filter: Optional[AttrFilter] = static_field(default=None)


def plan_ann(index: IVFIndex, queries: jax.Array, k: int, n_probe: int,
             attr_filter: Optional[AttrFilter] = None,
             u_max: Optional[int] = None,
             qmask: Optional[jax.Array] = None) -> QueryPlan:
    """ANN / batched-MQO plan: per-query probe sets, shared scan union.

    The union is the u_max most-voted partitions (default covers the whole
    batch exactly: u_max = min(k_parts, Q * n_probe)); `qsel` masks each
    query back onto its own probes -- paper §3.4's partition-major shared
    scan, which is also how single-query ANN avoids a per-query gather.
    `qmask` marks which query rows are real (False rows = bucket padding:
    they cast no votes and select nothing).
    """
    cfg = index.config
    q = normalize_if_cosine(queries.astype(jnp.float32), cfg.metric)
    Q = q.shape[0]
    kp = index.k
    n_probe = min(n_probe, kp)
    if u_max is None:
        u_max = min(kp, Q * n_probe)
    parts = find_nearest_centroids(index, q, n_probe)          # [Q, n]
    sel = jnp.zeros((Q, kp), bool).at[
        jnp.arange(Q)[:, None], parts].set(True)               # [Q, kp]
    if qmask is not None:
        sel = sel & qmask[:, None]
    votes = sel.sum(axis=0)                                    # [kp]
    vote_top, upart = jax.lax.top_k(votes, u_max)              # [u_max]
    qsel = jnp.take_along_axis(sel, upart[None, :], axis=1)    # [Q, u_max]
    qsel = qsel & (vote_top > 0)[None, :]
    return QueryPlan(queries=q, part_ids=upart.astype(jnp.int32), qsel=qsel,
                     rows=None, k=k, kind="ann", attr_filter=attr_filter)


def plan_exact(index: IVFIndex, queries: jax.Array, k: int,
               attr_filter: Optional[AttrFilter] = None) -> QueryPlan:
    """Exact plan: probe set = every partition, no selection mask."""
    q = normalize_if_cosine(queries.astype(jnp.float32), index.config.metric)
    return QueryPlan(queries=q,
                     part_ids=jnp.arange(index.k, dtype=jnp.int32),
                     qsel=None, rows=None, k=k, kind="exact",
                     attr_filter=attr_filter)


def plan_prefilter(index: IVFIndex, queries: jax.Array, k: int,
                   attr_filter: AttrFilter, cap: int) -> QueryPlan:
    """Pre-filtering plan (paper §3.5): evaluate the predicate first and
    compact qualifying row indices into a static `cap` budget (the device
    analogue of the SQLite b-tree row-id fetch); execution brute-forces
    over just those rows, so cost scales with predicate selectivity."""
    cfg = index.config
    q = normalize_if_cosine(queries.astype(jnp.float32), cfg.metric)
    kp, p_max, _ = index.vectors.shape
    n_attr = index.attrs.shape[-1]
    ok = index.valid.reshape(-1) & attr_filter(
        index.attrs.reshape(kp * p_max, n_attr))
    (rows,) = jnp.nonzero(ok, size=cap, fill_value=kp * p_max)
    return QueryPlan(queries=q, part_ids=None, qsel=None,
                     rows=rows.astype(jnp.int32), k=k, kind="prefilter",
                     attr_filter=attr_filter)


# ---------------------------------------------------------------------------
# The fused scan primitive (two backends, one shape)
# ---------------------------------------------------------------------------


def fused_scan(
    queries: jax.Array,          # [Q, d] f32 (normalised)
    vectors: jax.Array,          # [kp, p_max, d]
    valid: jax.Array,            # [kp, p_max] bool
    ids: jax.Array,              # [kp, p_max] int32
    part_ids: jax.Array,         # [n] int32 probe list
    k_out: int,
    *,
    metric: str = "l2",
    qsel: Optional[jax.Array] = None,      # [Q, n] bool
    attrs: Optional[jax.Array] = None,     # [kp, p_max, n_attr]
    attr_filter: Optional[AttrFilter] = None,
    backend: Optional[str] = None,         # "pallas" | "xla" | None=auto
) -> Tuple[jax.Array, jax.Array]:
    """Alg. 2 hot loop: stream probed partitions, batched distances,
    running top-k, with the attribute predicate fused before top-k.

    Returns (scores [Q, k_out], ids [Q, k_out]) ascending, rank
    convention (l2 drops the per-query ||q||^2 constant).
    """
    if backend is None:
        backend = default_backend()
    if backend == "pallas":
        from ..kernels import ivf_scan
        return ivf_scan.ivf_scan_topk(
            queries, vectors, valid, ids, part_ids, k_out, metric=metric,
            qsel=qsel, attrs=attrs, attr_filter=attr_filter, interpret=None)
    assert backend == "xla", backend
    return _xla_scan(queries, vectors, valid, ids, part_ids, k_out,
                     metric=metric, qsel=qsel, attrs=attrs,
                     attr_filter=attr_filter)


def _xla_scan(queries, vectors, valid, ids, part_ids, k_out, *, metric,
              qsel=None, attrs=None, attr_filter=None):
    """Shape-identical XLA reference backend: gather the probe union once
    ([n, p_max, d] -- NOT per query), one [Q, d] x [d, n*p_max] matmul."""
    pv = vectors[part_ids]                          # [n, p_max, d]
    pid = ids[part_ids]                             # [n, p_max]
    pok = valid[part_ids]
    if attr_filter is not None:
        pok = pok & attr_filter(attrs[part_ids])
    n, p_max, d = pv.shape
    flat_v = pv.reshape(n * p_max, d)
    dots = queries @ flat_v.T                       # [Q, n*p_max]
    if metric in ("ip", "cosine"):
        scores = -dots
    else:
        v2 = jnp.sum(flat_v * flat_v, axis=-1)
        scores = v2[None, :] - 2.0 * dots
    ok = jnp.broadcast_to(pok.reshape(1, n * p_max), scores.shape)
    if qsel is not None:
        ok = ok & jnp.repeat(qsel, p_max, axis=1)
    scores = mask_scores(scores, ok)
    return topk_smallest(
        scores, jnp.broadcast_to(pid.reshape(1, -1), scores.shape), k_out)


# ---------------------------------------------------------------------------
# Plan execution (scan + delta merge + dedup epilogue)
# ---------------------------------------------------------------------------


def _delta_candidates(index: IVFIndex, q: jax.Array,
                      attr_filter: Optional[AttrFilter]):
    """Delta partition, always scanned (§3.6), in rank convention."""
    d = index.delta
    dots = q @ d.vectors.T                           # [Q, cap]
    if index.config.metric in ("ip", "cosine"):
        scores = -dots
    else:
        scores = jnp.sum(d.vectors * d.vectors, axis=-1)[None, :] - 2.0 * dots
    ok = d.valid
    if attr_filter is not None:
        ok = ok & attr_filter(d.attrs)
    return mask_scores(scores, ok[None, :]), jnp.broadcast_to(
        d.ids[None, :], scores.shape)


def execute_plan(index: IVFIndex, plan: QueryPlan,
                 backend: Optional[str] = None) -> SearchResult:
    """Run a QueryPlan through the fused scan primitive + delta epilogue."""
    cfg = index.config
    q = plan.queries
    kp, p_max, d = index.vectors.shape
    f = plan.attr_filter

    if plan.kind == "prefilter":
        # Repack the qualifying rows into virtual partitions so the same
        # primitive scans them; predicate already applied at compaction.
        total = kp * p_max
        got = plan.rows < total
        rows = jnp.minimum(plan.rows, total - 1)
        cap = rows.shape[0]
        vparts = -(-cap // p_max)
        pad = vparts * p_max - cap
        sub_v = jnp.pad(index.vectors.reshape(total, d)[rows],
                        ((0, pad), (0, 0)))
        sub_i = jnp.pad(jnp.where(got, index.ids.reshape(-1)[rows],
                                  INVALID_ID), (0, pad),
                        constant_values=INVALID_ID)
        sub_ok = jnp.pad(got, (0, pad))
        k_scan = min(plan.k, vparts * p_max)
        s, i = fused_scan(
            q, sub_v.reshape(vparts, p_max, d), sub_ok.reshape(vparts, p_max),
            sub_i.reshape(vparts, p_max),
            jnp.arange(vparts, dtype=jnp.int32), k_scan,
            metric=cfg.metric, backend=backend)
    else:
        n = plan.part_ids.shape[0]
        k_scan = min(plan.k, n * p_max)
        s, i = fused_scan(
            q, index.vectors, index.valid, index.ids, plan.part_ids, k_scan,
            metric=cfg.metric, qsel=plan.qsel,
            attrs=index.attrs if f is not None else None,
            attr_filter=f, backend=backend)

    ds, di = _delta_candidates(index, q, f)
    k_final = min(plan.k, k_scan + ds.shape[-1])
    s, i = merge_topk(s, i, ds, di, k_final)
    s, i = dedup_by_id(s, i)
    if cfg.metric == "l2":
        # restore full squared distances (the scan drops the rank-invariant
        # per-query ||q||^2); masked slots stay at the sentinel
        q2 = jnp.sum(q * q, axis=-1, keepdims=True)
        s = jnp.where(i == INVALID_ID, MASKED_SCORE, s + q2)
    return SearchResult(ids=i, scores=s)


# ---------------------------------------------------------------------------
# Cached entry point (the engine-facing facade)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("kind", "k", "n_probe", "u_max", "cap",
                                   "attr_filter", "backend"))
def _run(index, queries, qmask, kind, k, n_probe, u_max, cap, attr_filter,
         backend):
    global _TRACE_COUNT
    _TRACE_COUNT += 1          # executes only while tracing
    if kind == "exact":
        plan = plan_exact(index, queries, k, attr_filter)
    elif kind == "prefilter":
        plan = plan_prefilter(index, queries, k, attr_filter, cap)
    else:
        plan = plan_ann(index, queries, k, n_probe, attr_filter,
                        u_max=u_max, qmask=qmask)
    return execute_plan(index, plan, backend=backend)


def _bucket(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def search(
    index: IVFIndex,
    queries: jax.Array,
    *,
    k: int,
    kind: str = "ann",                 # ann | exact | prefilter
    n_probe: int = 8,
    u_max: Optional[int] = None,       # MQO union cap (None: exact union)
    cap: Optional[int] = None,         # prefilter gather budget
    attr_filter: Optional[AttrFilter] = None,
    backend: Optional[str] = None,
    bucket: bool = True,
) -> SearchResult:
    """Build + execute a QueryPlan with query-count bucketing.

    Q is padded to the next power of two so the jit cache is keyed on
    (Q_bucket, kind, k, n_probe/u_max/cap, predicate_id, backend) -- a
    stream of variable-size batches compiles once per bucket, not once
    per batch size. Padding queries are masked out of the plan (qmask)
    and their result rows sliced off.
    """
    if kind == "prefilter":
        assert cap is not None, "kind='prefilter' needs a static cap " \
            "(the optimizer sizes it from the selectivity estimate)"
        assert attr_filter is not None, "kind='prefilter' needs attr_filter"
    q = jnp.asarray(queries, jnp.float32)
    Q = q.shape[0]
    b = _bucket(Q) if bucket else Q
    if b != Q:
        q = jnp.concatenate([q, jnp.zeros((b - Q, q.shape[1]), q.dtype)])
    qmask = jnp.arange(b) < Q
    res = _run(index, q, qmask, kind, k, n_probe, u_max, cap, attr_filter,
               backend)
    if b != Q:
        res = SearchResult(ids=res.ids[:Q], scores=res.scores[:Q])
    return res
