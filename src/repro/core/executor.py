"""Unified query-execution layer: every search is a QueryPlan run by one
fused scan primitive (the repo's single implementation of paper Alg. 2).

Module map -- who builds specs, who runs them:

    core/query.py       the public object model: QuerySpec (frozen,
                        hashable -- the jit cache key) and ResultSet
    core/search.py      kwarg shims: ann_search / exact_search /
                        prefilter_search -> QuerySpec (public API kept)
    core/mqo.py         kwarg shim: mqo_search -> spec with a union cap
    core/optimizer.py   hybrid pre/post spec choice (paper Eqs. 1-3),
                        both arms issued through this executor
    core/rag.py         kNN-LM retrieval -> ANN specs
    storage/engine.py   MicroNN.query(vecs, QuerySpec) -> run()
    distributed/        sharded_index phase 3 calls fused_scan directly
                        on each device's local partition shard
    kernels/ivf_scan.py the Pallas TPU backend of fused_scan
    kernels/sq_scan.py  the Pallas backend of fused_sq_scan (int8 codes,
                        dequantize fused into the distance accumulation)
    storage/pager.py    the partition frame pool behind paged_search
                        (PR 3: disk-resident mode on a memory budget)
    benchmarks/bench_executor.py   backend + plan-cache latency
    benchmarks/bench_quantized.py  int8-vs-f32 recall / memory / latency
    benchmarks/bench_paged.py      resident bytes / recall / latency vs
                                   memory budget; cache hit rates

Quantized two-stage execution (core/quantize.py): on an index carrying
int8 codes, ann/exact plans scan the code tier for k' = rerank_factor * k
candidate rows, then _rerank_float32 rescores exactly those rows at full
precision before the final top-k; prefilter plans and the delta epilogue
stay float32.

Plan model (paper Alg. 2 generalised):
    probe set         part_ids [n]  -- shared partition scan list
    selection mask    qsel [Q, n]   -- which query wants which partition
                                       (MQO §3.4; ANN is the batch union)
    fused predicate   attr_filter   -- compiled hybrid predicate, masked
                                       *before* top-k (§3.5)
    k                 running top-k width (§3.3)
Exact = probe everything; pre-filter = compact qualifying rows into
virtual partitions and probe those (§3.5, cost ~ the gather cap).

Two interchangeable backends execute the same plan shape-identically:
    "pallas"  fused kernel (kernels/ivf_scan.py); interpret mode is
              auto-selected off-TPU
    "xla"     reference path for CPU/GPU -- one shared [n*p_max] matmul
Neither materialises the seed's per-query [Q, n_probe, p_max, d] gather:
the probe union is scanned once and queries mask into it.

Plan/compile cache: the `run` facade buckets the query count to the next
power of two and routes through one jitted entry point whose static
cache key IS the QuerySpec (core/query.py) -- a frozen, structurally
hashable dataclass, so two equal specs (including structurally-equal
predicate trees) provably share one compile-cache entry and a stream of
variable-size batches compiles once per (Q_bucket, spec).
`trace_count()` is the retrace counter; `compile_cache_size()` the
number of live entries -- both surface through MicroNN.stats().
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import quantize
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from .hybrid import compile_filter
from .query import QuerySpec, ResultSet
from .topk import dedup_by_id, mask_scores, merge_topk, topk_smallest
from .types import (INVALID_ID, MASKED_SCORE, IVFIndex, PagedIndex,
                    SearchResult, normalize_if_cosine, pairwise_scores,
                    register_dataclass, static_field)

# attr_filter: [..., n_attr] float32 -> [...] bool  (hybrid.compile_filter;
# memoized there so equal predicates are identical objects / cache keys)
AttrFilter = Callable[[jax.Array], jax.Array]

# Retrace counter: incremented each time the jitted entry point actually
# traces. Stable counter == plan-cache hit.
_TRACE_COUNT = 0


def trace_count() -> int:
    return _TRACE_COUNT


def default_backend() -> str:
    """Pallas kernel on real TPU, shape-identical XLA path elsewhere."""
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _centroid_scores(centroids, counts, metric, q):
    """[Q, d] -> [Q, k] centroid distances with empty partitions pushed
    out of any probe set (they can never contribute)."""
    cd = pairwise_scores(q, centroids, metric)
    return jnp.where(counts[None, :] > 0, cd, jnp.finfo(cd.dtype).max)


def find_nearest_centroids(index: IVFIndex, q: jax.Array, n_probe: int):
    """[Q, d] -> [Q, n_probe] partition ids (line 3 of Alg. 2)."""
    cd = _centroid_scores(index.centroids, index.counts,
                          index.config.metric, q)
    n_probe = min(n_probe, index.k)
    _, parts = jax.lax.top_k(-cd, n_probe)
    return parts


def _probe_union(centroids, counts, metric, q, n_probe,
                 u_max: Optional[int] = None,
                 qmask: Optional[jax.Array] = None):
    """Shared probe-set + vote/union construction (paper §3.4): the union
    is the u_max most-voted partitions (default covers the batch exactly)
    and `qsel` masks each query back onto its own probes. Used by BOTH
    plan_ann and the paged planner, so the resident and paged scans visit
    partitions in the same order -- the structural half of the paged
    bit-parity contract."""
    kp = centroids.shape[0]
    Q = q.shape[0]
    n_probe = min(n_probe, kp)
    if u_max is None:
        u_max = min(kp, Q * n_probe)
    cd = _centroid_scores(centroids, counts, metric, q)
    _, parts = jax.lax.top_k(-cd, n_probe)                     # [Q, n]
    sel = jnp.zeros((Q, kp), bool).at[
        jnp.arange(Q)[:, None], parts].set(True)               # [Q, kp]
    if qmask is not None:
        sel = sel & qmask[:, None]
    votes = sel.sum(axis=0)                                    # [kp]
    vote_top, upart = jax.lax.top_k(votes, u_max)              # [u_max]
    qsel = jnp.take_along_axis(sel, upart[None, :], axis=1)    # [Q, u_max]
    qsel = qsel & (vote_top > 0)[None, :]
    return upart.astype(jnp.int32), qsel


# ---------------------------------------------------------------------------
# QueryPlan + builders
# ---------------------------------------------------------------------------


@register_dataclass
@dataclasses.dataclass
class QueryPlan:
    """One compiled search: probe set + per-query mask + predicate + k.

    `queries` are already metric-normalised. For kind "prefilter" the probe
    set is replaced by `rows`, a fixed-cap compaction of qualifying row
    indices that execute_plan repacks into virtual partitions.
    """

    queries: jax.Array                    # [Q, d] f32
    part_ids: Optional[jax.Array]         # [n] int32 (None for prefilter)
    qsel: Optional[jax.Array]             # [Q, n] bool (None: all queries)
    rows: Optional[jax.Array]             # [cap] int32 (prefilter only)
    parts_pq: Optional[jax.Array] = None  # [Q, n] int32 (ann_gather only)
    k: int = static_field(default=10)
    kind: str = static_field(default="ann")  # ann | exact | prefilter
    #                                          | ann_gather
    attr_filter: Optional[AttrFilter] = static_field(default=None)


def plan_ann(index: IVFIndex, queries: jax.Array, k: int, n_probe: int,
             attr_filter: Optional[AttrFilter] = None,
             u_max: Optional[int] = None,
             qmask: Optional[jax.Array] = None) -> QueryPlan:
    """ANN / batched-MQO plan: per-query probe sets, shared scan union.

    The union is the u_max most-voted partitions (default covers the whole
    batch exactly: u_max = min(k_parts, Q * n_probe)); `qsel` masks each
    query back onto its own probes -- paper §3.4's partition-major shared
    scan, which is also how single-query ANN avoids a per-query gather.
    `qmask` marks which query rows are real (False rows = bucket padding:
    they cast no votes and select nothing).
    """
    cfg = index.config
    q = normalize_if_cosine(queries.astype(jnp.float32), cfg.metric)
    upart, qsel = _probe_union(index.centroids, index.counts, cfg.metric,
                               q, n_probe, u_max=u_max, qmask=qmask)
    return QueryPlan(queries=q, part_ids=upart, qsel=qsel,
                     rows=None, k=k, kind="ann", attr_filter=attr_filter)


# Largest (bucketed) query count routed to the per-query gather variant.
# Small batches pay more for the shared union's vote/top-k plumbing and
# its n_union = Q * n_probe scan width than a direct [Q, n_probe] gather
# costs (the PR 1 regression on CPU); past ~8 queries probe overlap makes
# the shared union the winner again. The selection is static per
# (spec, Q-bucket), i.e. it lives inside the existing jit cache key.
SMALL_Q_GATHER_MAX = 8


def plan_ann_gather(index: IVFIndex, queries: jax.Array, k: int,
                    n_probe: int,
                    attr_filter: Optional[AttrFilter] = None) -> QueryPlan:
    """Small-Q ANN plan: per-query probe lists, NO shared union.

    Execution gathers each query's own [n_probe, p_max] probe block and
    scores it directly -- the seed's formulation, which beats the shared
    union below SMALL_Q_GATHER_MAX queries on CPU (no vote/top-k union
    plumbing, no scan over other queries' partitions). Same candidate
    set as plan_ann at equal n_probe, so recall is identical; parity is
    pinned by tests (ids equal, scores allclose -- a differently-shaped
    matmul is not bitwise-identical to the union scan)."""
    cfg = index.config
    q = normalize_if_cosine(queries.astype(jnp.float32), cfg.metric)
    parts = find_nearest_centroids(index, q, n_probe)      # [Q, n]
    return QueryPlan(queries=q, part_ids=None, qsel=None, rows=None,
                     parts_pq=parts.astype(jnp.int32), k=k,
                     kind="ann_gather", attr_filter=attr_filter)


def plan_exact(index: IVFIndex, queries: jax.Array, k: int,
               attr_filter: Optional[AttrFilter] = None) -> QueryPlan:
    """Exact plan: probe set = every partition, no selection mask."""
    q = normalize_if_cosine(queries.astype(jnp.float32), index.config.metric)
    return QueryPlan(queries=q,
                     part_ids=jnp.arange(index.k, dtype=jnp.int32),
                     qsel=None, rows=None, k=k, kind="exact",
                     attr_filter=attr_filter)


def plan_prefilter(index: IVFIndex, queries: jax.Array, k: int,
                   attr_filter: AttrFilter, cap: int) -> QueryPlan:
    """Pre-filtering plan (paper §3.5): evaluate the predicate first and
    compact qualifying row indices into a static `cap` budget (the device
    analogue of the SQLite b-tree row-id fetch); execution brute-forces
    over just those rows, so cost scales with predicate selectivity."""
    cfg = index.config
    q = normalize_if_cosine(queries.astype(jnp.float32), cfg.metric)
    kp, p_max, _ = index.vectors.shape
    n_attr = index.attrs.shape[-1]
    ok = index.valid.reshape(-1) & attr_filter(
        index.attrs.reshape(kp * p_max, n_attr))
    (rows,) = jnp.nonzero(ok, size=cap, fill_value=kp * p_max)
    return QueryPlan(queries=q, part_ids=None, qsel=None,
                     rows=rows.astype(jnp.int32), k=k, kind="prefilter",
                     attr_filter=attr_filter)


# ---------------------------------------------------------------------------
# The fused scan primitive (two backends, one shape)
# ---------------------------------------------------------------------------


def fused_scan(
    queries: jax.Array,          # [Q, d] f32 (normalised)
    vectors: jax.Array,          # [kp, p_max, d]
    valid: jax.Array,            # [kp, p_max] bool
    ids: jax.Array,              # [kp, p_max] int32
    part_ids: jax.Array,         # [n] int32 probe list
    k_out: int,
    *,
    metric: str = "l2",
    qsel: Optional[jax.Array] = None,      # [Q, n] bool
    attrs: Optional[jax.Array] = None,     # [kp, p_max, n_attr]
    attr_filter: Optional[AttrFilter] = None,
    backend: Optional[str] = None,         # "pallas" | "xla" | None=auto
) -> Tuple[jax.Array, jax.Array]:
    """Alg. 2 hot loop: stream probed partitions, batched distances,
    running top-k, with the attribute predicate fused before top-k.

    Returns (scores [Q, k_out], ids [Q, k_out]) ascending, rank
    convention (l2 drops the per-query ||q||^2 constant).
    """
    if backend is None:
        backend = default_backend()
    if backend == "pallas":
        from ..kernels import ivf_scan
        return ivf_scan.ivf_scan_topk(
            queries, vectors, valid, ids, part_ids, k_out, metric=metric,
            qsel=qsel, attrs=attrs, attr_filter=attr_filter, interpret=None)
    assert backend == "xla", backend
    return _xla_scan(queries, vectors, valid, ids, part_ids, k_out,
                     metric=metric, qsel=qsel, attrs=attrs,
                     attr_filter=attr_filter)


def _xla_scan_gathered(queries, pv, pok, pid, k_out, *, metric, qsel=None,
                       pattrs=None, attr_filter=None):
    """Shared core of the XLA reference backends, over the already-
    gathered probe union ([n, p_max, d]): one [Q, d] x [d, n*p_max]
    matmul, predicate + selection masking, top-k."""
    if attr_filter is not None:
        pok = pok & attr_filter(pattrs)
    n, p_max, d = pv.shape
    flat_v = pv.reshape(n * p_max, d)
    dots = queries @ flat_v.T                       # [Q, n*p_max]
    if metric in ("ip", "cosine"):
        scores = -dots
    else:
        v2 = jnp.sum(flat_v * flat_v, axis=-1)
        scores = v2[None, :] - 2.0 * dots
    ok = jnp.broadcast_to(pok.reshape(1, n * p_max), scores.shape)
    if qsel is not None:
        ok = ok & jnp.repeat(qsel, p_max, axis=1)
    scores = mask_scores(scores, ok)
    return topk_smallest(
        scores, jnp.broadcast_to(pid.reshape(1, -1), scores.shape), k_out)


def _xla_scan(queries, vectors, valid, ids, part_ids, k_out, *, metric,
              qsel=None, attrs=None, attr_filter=None):
    """Shape-identical XLA reference backend: gather the probe union once
    ([n, p_max, d] -- NOT per query), then the shared scan core."""
    return _xla_scan_gathered(
        queries, vectors[part_ids], valid[part_ids], ids[part_ids], k_out,
        metric=metric, qsel=qsel,
        pattrs=None if attr_filter is None else attrs[part_ids],
        attr_filter=attr_filter)


def fused_sq_scan(
    queries: jax.Array,          # [Q, d] f32 (normalised)
    codes: jax.Array,            # [kp, p_max, d] int8
    qstats,                      # quantize.QuantStats
    valid: jax.Array,            # [kp, p_max] bool
    ids: jax.Array,              # [kp, p_max] int32 (flat row ids here)
    part_ids: jax.Array,         # [n] int32 probe list
    k_out: int,
    *,
    metric: str = "l2",
    qsel: Optional[jax.Array] = None,
    attrs: Optional[jax.Array] = None,
    attr_filter: Optional[AttrFilter] = None,
    norms: Optional[jax.Array] = None,   # [kp, p_max] precomputed norms
    backend: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Candidate stage of the quantized two-stage search: the fused scan
    over the int8 code tier, with the distance accumulation in the
    INTEGER domain (quantize.fold_queries + int8 x int8 -> int32 matmul
    + rank-1 affine epilogue; see kernels/sq_scan.py). Same plan shape
    as fused_scan; scores are approximate (quantized reconstruction plus
    the query-side fold) and only used to *select* the k_out candidates
    that _rerank_float32 rescores exactly. `norms` is the precomputed
    IVFIndex.code_norms tier; when None (paged frame scans) both
    backends fall back to decode-and-reduce in-scan."""
    if backend is None:
        backend = default_backend()
    if backend == "pallas":
        from ..kernels import sq_scan
        return sq_scan.sq_scan_topk(
            queries, codes, qstats.lo, qstats.scale, valid, ids, part_ids,
            k_out, metric=metric, qsel=qsel, attrs=attrs,
            attr_filter=attr_filter, norms=norms, interpret=None)
    assert backend == "xla", backend
    return _xla_sq_scan(queries, codes, qstats, valid, ids, part_ids, k_out,
                        metric=metric, qsel=qsel, attrs=attrs,
                        attr_filter=attr_filter, norms=norms)


def _int_domain_dots(q_i8, alpha, beta, flat_c):
    """Two-term affine epilogue over [2Q, d] x [m, d] int8 operands:
    (alpha * (q_i8 . c))[:Q] + (alpha * (q_i8 . c))[Q:] + beta, with
    q_i8/alpha in quantize.fold_queries' stacked [q1; q2] form.

    For d <= 1024 the accumulation runs as an f32 gemm over the *cast*
    integer operands: every product (|q_i8| <= 127, |c| <= 128) and every
    partial sum (< 127 * 128 * 1024 < 2^24) is exactly representable in
    f32, so this is bitwise-identical to int32 accumulation -- and much
    faster than XLA's int8 gemm on CPU backends, where int32 matmul units
    don't exist. Wider vectors keep the exact int32 path. The Pallas
    kernel always accumulates in int32 (preferred_element_type) -- the
    actual MXU int8 path -- and holds accumulator values identical to
    this reference; its f32 epilogue agrees to ~1 ulp (the compiler may
    fma-fuse the affine correction differently per program), so candidate
    selection is identical and post-rerank results are bitwise."""
    d = q_i8.shape[-1]
    if d <= 1024:
        acc = jax.lax.dot_general(
            q_i8.astype(jnp.float32), flat_c.astype(jnp.float32),
            (((1,), (1,)), ((), ())), precision=jax.lax.Precision.HIGHEST)
    else:
        acc = jax.lax.dot_general(
            q_i8, flat_c, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32).astype(jnp.float32)
    terms = alpha[:, None] * acc                     # [2Q, m]
    q_n = beta.shape[0]
    return terms[:q_n] + terms[q_n:] + beta[:, None]


def _xla_sq_scan(queries, codes, qstats, valid, ids, part_ids, k_out, *,
                 metric, qsel=None, attrs=None, attr_filter=None,
                 norms=None):
    """Shape-identical XLA reference for the int8-domain SQ scan: gather
    the probe union's codes, integer-domain matmul + affine epilogue
    (same fold, same op order as the Pallas kernel -- bitwise parity),
    then the same masking + top-k tail as the float32 reference."""
    q_i8, alpha, beta = quantize.fold_queries(qstats, queries)
    pc = codes[part_ids]                             # [n, p_max, d] int8
    n, p_max, d = pc.shape
    pok = valid[part_ids]
    if attr_filter is not None:
        pok = pok & attr_filter(attrs[part_ids])
    dots = _int_domain_dots(q_i8, alpha, beta, pc.reshape(n * p_max, d))
    if metric in ("ip", "cosine"):
        scores = -dots
    else:
        if norms is not None:
            v2 = norms[part_ids].reshape(n * p_max)
        else:   # paged/hand-built fallback: decode-and-reduce in-scan
            v2 = quantize.row_norms(qstats, pc).reshape(n * p_max)
        scores = v2[None, :] - 2.0 * dots
    ok = jnp.broadcast_to(pok.reshape(1, n * p_max), scores.shape)
    if qsel is not None:
        ok = ok & jnp.repeat(qsel, p_max, axis=1)
    scores = mask_scores(scores, ok)
    pid = ids[part_ids]
    return topk_smallest(
        scores, jnp.broadcast_to(pid.reshape(1, -1), scores.shape), k_out)


def _xla_sq_scan_dequant(queries, codes, qstats, valid, ids, part_ids,
                         k_out, *, metric, qsel=None, attrs=None,
                         attr_filter=None):
    """The pre-int8-domain reference (gather, dequantize to f32, f32
    matmul) -- kept as the recall/latency baseline the int8-domain scan
    is pinned against (tests + benchmarks/bench_quantized.py)."""
    return _xla_scan_gathered(
        queries, quantize.decode(qstats, codes[part_ids]),
        valid[part_ids], ids[part_ids], k_out,
        metric=metric, qsel=qsel,
        pattrs=None if attr_filter is None else attrs[part_ids],
        attr_filter=attr_filter)


# ---------------------------------------------------------------------------
# Plan execution (scan + delta merge + dedup epilogue)
# ---------------------------------------------------------------------------


def _delta_candidates_from(delta, metric: str, q: jax.Array,
                           attr_filter: Optional[AttrFilter]):
    """Delta partition, always scanned (§3.6), in rank convention. Shared
    by the resident and the paged epilogue (the delta stays resident in
    both modes -- it is small and write-hot)."""
    dots = q @ delta.vectors.T                       # [Q, cap]
    if metric in ("ip", "cosine"):
        scores = -dots
    else:
        scores = jnp.sum(delta.vectors * delta.vectors,
                         axis=-1)[None, :] - 2.0 * dots
    ok = delta.valid
    if attr_filter is not None:
        ok = ok & attr_filter(delta.attrs)
    return mask_scores(scores, ok[None, :]), jnp.broadcast_to(
        delta.ids[None, :], scores.shape)


def _delta_candidates(index: IVFIndex, q: jax.Array,
                      attr_filter: Optional[AttrFilter]):
    return _delta_candidates_from(index.delta, index.config.metric, q,
                                  attr_filter)


def _merge_epilogue(delta, metric: str, q, s, i, k: int, k_scan: int,
                    attr_filter: Optional[AttrFilter],
                    qmask: Optional[jax.Array] = None):
    """Shared tail of every search: delta merge + dedup + l2 restore --
    one op sequence for the resident and paged paths (bit-parity)."""
    ds, di = _delta_candidates_from(delta, metric, q, attr_filter)
    if qmask is not None:
        ds = mask_scores(ds, qmask[:, None])
    k_final = min(k, k_scan + ds.shape[-1])
    s, i = merge_topk(s, i, ds, di, k_final)
    s, i = dedup_by_id(s, i)
    if metric == "l2":
        # restore full squared distances (the scan drops the rank-invariant
        # per-query ||q||^2); masked slots stay at the sentinel
        q2 = jnp.sum(q * q, axis=-1, keepdims=True)
        s = jnp.where(i == INVALID_ID, MASKED_SCORE, s + q2)
    return s, i


def _rescore_exact(q, v, got, ids, k_out: int, metric: str):
    """Shared exact-rescore stage of both rerank paths (resident device
    gather and paged disk gather): one op sequence, so XLA emits the same
    floats for both -- the other structural half of paged bit-parity."""
    dots = jnp.einsum("qd,qcd->qc", q, v)
    if metric in ("ip", "cosine"):
        s = -dots
    else:
        s = jnp.sum(v * v, axis=-1) - 2.0 * dots
    s = mask_scores(s, got)
    ids = jnp.where(got, ids, INVALID_ID)
    return topk_smallest(s, ids, k_out)


def _rerank_float32(index: IVFIndex, q: jax.Array, rows: jax.Array,
                    k_out: int):
    """Stage 2 of the quantized path: gather the candidate rows' float32
    vectors (the durable-precision tier) and recompute exact distances.

    `rows` are flat row indices (partition * p_max + slot) emitted by the
    SQ scan, INVALID_ID where the scan found fewer than k' candidates.
    Gather cost is O(Q * k' * d) -- independent of the scan width, which
    is the point of scanning codes.
    """
    kp, p_max, d = index.vectors.shape
    total = kp * p_max
    got = rows != INVALID_ID
    r = jnp.clip(rows, 0, total - 1)
    v = index.vectors.reshape(total, d)[r]           # [Q, k', d]
    ids = index.ids.reshape(total)[r]                # [Q, k']
    return _rescore_exact(q, v, got, ids, k_out, index.config.metric)


def execute_plan(index: IVFIndex, plan: QueryPlan,
                 backend: Optional[str] = None,
                 quantized: Optional[bool] = None) -> SearchResult:
    """Run a QueryPlan through the fused scan primitive + delta epilogue.

    `quantized` selects the scan tier on an index carrying int8 codes:
    None (default) auto-uses the codes when present; False forces the
    float32 scan (parity tests / benchmarks); True asserts codes exist.
    The quantized path is two-stage: the SQ scan over-fetches
    k' = rerank_factor * k candidate *rows*, then _rerank_float32
    rescores exactly before the final top-k. Only "ann" plans use the
    code tier: prefilter plans already gather float32 rows, and "exact"
    plans keep their 100%-recall oracle contract (brute force over the
    float32 tier) even on a quantized index.
    """
    cfg = index.config
    q = plan.queries
    kp, p_max, d = index.vectors.shape
    f = plan.attr_filter
    if quantized is None:
        quantized = index.codes is not None
    elif quantized:
        assert index.codes is not None, "quantized=True needs index codes"
    use_sq = quantized and plan.kind in ("ann", "ann_gather")

    if plan.kind == "prefilter":
        # Repack the qualifying rows into virtual partitions so the same
        # primitive scans them; predicate already applied at compaction.
        total = kp * p_max
        got = plan.rows < total
        rows = jnp.minimum(plan.rows, total - 1)
        cap = rows.shape[0]
        vparts = -(-cap // p_max)
        pad = vparts * p_max - cap
        sub_v = jnp.pad(index.vectors.reshape(total, d)[rows],
                        ((0, pad), (0, 0)))
        sub_i = jnp.pad(jnp.where(got, index.ids.reshape(-1)[rows],
                                  INVALID_ID), (0, pad),
                        constant_values=INVALID_ID)
        sub_ok = jnp.pad(got, (0, pad))
        k_scan = min(plan.k, vparts * p_max)
        s, i = fused_scan(
            q, sub_v.reshape(vparts, p_max, d), sub_ok.reshape(vparts, p_max),
            sub_i.reshape(vparts, p_max),
            jnp.arange(vparts, dtype=jnp.int32), k_scan,
            metric=cfg.metric, backend=backend)
    elif plan.kind == "ann_gather":
        # Small-Q specialization: per-query [n_probe, p_max] gather, no
        # shared union (see plan_ann_gather). Quantized indexes still run
        # the two-stage contract: int8-domain gathered scan -> f32 rerank.
        parts = plan.parts_pq                         # [Q, n]
        npb = parts.shape[1]
        pok = index.valid[parts]                      # [Q, n, p_max]
        if f is not None:
            pok = pok & f(index.attrs[parts])
        if use_sq:
            k_cand = min(max(plan.k, plan.k * cfg.rerank_factor),
                         npb * p_max)
            q_i8, alpha, beta = quantize.fold_queries(index.qstats, q)
            # stacked two-term fold ([q1; q2], see fold_queries): expose
            # the term axis so ONE contraction pass over the gathered
            # codes computes both integer products per query
            q_n = q.shape[0]
            qt = q_i8.reshape(2, q_n, d)
            at = alpha.reshape(2, q_n)
            pc = index.codes[parts]                   # [Q, n, p_max, d]
            if d <= 1024:
                acc = jnp.einsum("tqd,qnpd->tqnp", qt.astype(jnp.float32),
                                 pc.astype(jnp.float32),
                                 precision=jax.lax.Precision.HIGHEST)
            else:
                acc = jnp.einsum("tqd,qnpd->tqnp", qt, pc,
                                 preferred_element_type=jnp.int32
                                 ).astype(jnp.float32)
            terms = at[:, :, None, None] * acc        # [2, Q, n, p_max]
            dots = terms[0] + terms[1] + beta[:, None, None]
            if cfg.metric in ("ip", "cosine"):
                scores = -dots
            else:
                v2 = index.code_norms[parts] if index.code_norms is not None \
                    else quantize.row_norms(index.qstats, pc)
                scores = v2 - 2.0 * dots
            scores = mask_scores(scores.reshape(q.shape[0], npb * p_max),
                                 pok.reshape(q.shape[0], npb * p_max))
            # flat row ids (partition * p_max + slot) feed the f32 rerank
            rid = (parts[:, :, None] * p_max
                   + jnp.arange(p_max, dtype=jnp.int32)[None, None, :])
            cand_s, cand_rows = topk_smallest(
                scores, rid.reshape(q.shape[0], npb * p_max), k_cand)
            cand_rows = jnp.where(cand_s >= MASKED_SCORE, INVALID_ID,
                                  cand_rows)
            k_scan = min(plan.k, k_cand)
            s, i = _rerank_float32(index, q, cand_rows, k_scan)
        else:
            pv = index.vectors[parts]                 # [Q, n, p_max, d]
            dots = jnp.einsum("qd,qnpd->qnp", q, pv)
            if cfg.metric in ("ip", "cosine"):
                scores = -dots
            else:
                scores = jnp.sum(pv * pv, axis=-1) - 2.0 * dots
            scores = mask_scores(scores.reshape(q.shape[0], npb * p_max),
                                 pok.reshape(q.shape[0], npb * p_max))
            k_scan = min(plan.k, npb * p_max)
            s, i = topk_smallest(
                scores, index.ids[parts].reshape(q.shape[0], npb * p_max),
                k_scan)
    elif use_sq:
        # Two-stage quantized search: (1) fused SQ scan over int8 codes
        # selects k' = rerank_factor * k candidate rows; (2) exact f32
        # rerank over just those rows.
        n = plan.part_ids.shape[0]
        k_cand = min(max(plan.k, plan.k * cfg.rerank_factor), n * p_max)
        row_ids = jnp.arange(kp * p_max, dtype=jnp.int32).reshape(kp, p_max)
        cand_s, cand_rows = fused_sq_scan(
            q, index.codes, index.qstats, index.valid, row_ids,
            plan.part_ids, k_cand, metric=cfg.metric, qsel=plan.qsel,
            attrs=index.attrs if f is not None else None,
            attr_filter=f, norms=index.code_norms, backend=backend)
        # fewer than k' qualifying rows: the Pallas running-merge re-emits
        # an already-extracted row id (argmin over an all-MASKED buffer)
        # for the exhausted rounds. The f32 path neutralises those via
        # topk_smallest's score-based invalidation; here the rows feed the
        # rerank directly, so invalidate by score first or the rerank
        # would resurrect them as real (duplicate) candidates.
        cand_rows = jnp.where(cand_s >= MASKED_SCORE, INVALID_ID, cand_rows)
        k_scan = min(plan.k, k_cand)
        s, i = _rerank_float32(index, q, cand_rows, k_scan)
    else:
        n = plan.part_ids.shape[0]
        k_scan = min(plan.k, n * p_max)
        s, i = fused_scan(
            q, index.vectors, index.valid, index.ids, plan.part_ids, k_scan,
            metric=cfg.metric, qsel=plan.qsel,
            attrs=index.attrs if f is not None else None,
            attr_filter=f, backend=backend)

    s, i = _merge_epilogue(index.delta, cfg.metric, q, s, i, plan.k, k_scan,
                           f)
    return SearchResult(ids=i, scores=s)


# ---------------------------------------------------------------------------
# Cached entry point (the engine-facing facade): the QuerySpec IS the key
# ---------------------------------------------------------------------------


def _spec_filter(spec: QuerySpec) -> Optional[AttrFilter]:
    """Spec predicate -> fused filter callable. Predicate trees compile
    through the memoized hybrid.compile_filter (structurally-equal trees
    share one callable); pre-compiled callables pass through."""
    if spec.predicate is None:
        return None
    if callable(spec.predicate):
        return spec.predicate
    return compile_filter(spec.predicate)


@partial(jax.jit, static_argnames=("spec",))
def _run_spec(index, queries, qmask, spec: QuerySpec):
    """THE jitted entry point: its only static argument is the QuerySpec,
    so the spec (plus the query-count bucket and the index pytree
    structure) is the entire compile-cache key -- equal specs share one
    trace by construction."""
    global _TRACE_COUNT
    _TRACE_COUNT += 1          # executes only while tracing
    f = _spec_filter(spec)
    if spec.kind == "exact":
        plan = plan_exact(index, queries, spec.k, f)
    elif f is not None and spec.hybrid == "pre":
        assert spec.cap is not None, \
            "pre-filtering needs a static gather cap: use " \
            "spec.prefilter(cap) or let MicroNN.query size it from the " \
            "selectivity estimate"
        plan = plan_prefilter(index, queries, spec.k, f, spec.cap)
    elif (queries.shape[0] <= SMALL_Q_GATHER_MAX and spec.u_max is None
          and (spec.on_backend or default_backend()) != "pallas"):
        # small (bucketed) batches skip the shared union: the per-query
        # gather variant wins on CPU below ~8 queries (the PR 1
        # regression). Static per (spec, Q-bucket) -- no new cache key
        # dimension, no retrace beyond the existing bucket one.
        plan = plan_ann_gather(index, queries, spec.k, spec.n_probe, f)
    else:
        plan = plan_ann(index, queries, spec.k, spec.n_probe, f,
                        u_max=spec.u_max, qmask=qmask)
    return execute_plan(index, plan, backend=spec.on_backend,
                        quantized=spec.use_quantized)


def compile_cache_size() -> int:
    """Live jit cache entries of the spec entry point (observability:
    MicroNN.stats() reports it next to trace_count())."""
    try:
        return int(_run_spec._cache_size())
    except AttributeError:      # older jax without _cache_size
        return trace_count()


def _bucket(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def _record_resident_probe(tr, index, q: jax.Array, spec: QuerySpec):
    """Probe span for a traced resident query. The real probe runs fused
    inside the jitted entry point, so tracing re-derives it eagerly from
    the same centroids (identical math -- find_nearest_centroids is what
    both plan variants call); the duplicate work only happens on
    explicitly traced queries."""
    kp = index.centroids.shape[0]
    if spec.kind == "exact":
        tr.record(obs_trace.STAGE_PROBE, 0.0, partitions=int(kp),
                  n_probe=int(kp), kind="exact")
        return
    if spec.predicate is not None and spec.hybrid == "pre":
        tr.record(obs_trace.STAGE_PROBE, 0.0, partitions=0,
                  rows_cap=int(spec.cap or 0), kind="prefilter")
        return
    t0 = time.perf_counter()
    qn = normalize_if_cosine(q.astype(jnp.float32), index.config.metric)
    parts = np.unique(np.asarray(
        find_nearest_centroids(index, qn, spec.n_probe)))
    tr.record(obs_trace.STAGE_PROBE, (time.perf_counter() - t0) * 1e3,
              partitions=int(parts.size), n_probe=int(min(spec.n_probe, kp)),
              kind="ann")


def _record_resident_scan(tr, index, spec: QuerySpec, b: int,
                          dt_ms: float, compiled: int):
    """Scan/rerank/merge spans for a traced resident query: one fused
    jitted call covers all three stages, so rerank and merge are recorded
    as fused markers (dur folded into the scan span)."""
    kp, p_max, _ = index.vectors.shape
    backend = spec.on_backend or default_backend()
    quantized = spec.use_quantized
    if quantized is None:
        quantized = index.codes is not None
    use_sq = bool(quantized) and spec.kind == "ann" and \
        spec.hybrid != "pre"
    n_parts = tr.counter(obs_trace.STAGE_PROBE, "partitions",
                         default=int(kp))
    tr.record(obs_trace.STAGE_SCAN, dt_ms,
              partitions=n_parts, rows=n_parts * p_max, chunks=1,
              backend=backend, q_bucket=b, quantized=use_sq,
              compiled=compiled, cache_hit=(compiled == 0), fused=1)
    if use_sq:
        rf = index.config.rerank_factor
        tr.record(obs_trace.STAGE_RERANK, 0.0, fused=1, rf=int(rf),
                  candidates=b * min(max(spec.k, spec.k * rf),
                                     n_parts * p_max))
    tr.record(obs_trace.STAGE_MERGE, 0.0, fused=1)


def run(index, queries: jax.Array, spec: QuerySpec, *,
        bucket: bool = True) -> ResultSet:
    """Execute a QuerySpec against a resident IVFIndex or a PagedIndex --
    the single query entry point every public path routes through.

    Resident execution buckets the query count to the next power of two
    (padding queries are masked out of the plan and sliced off the
    result), so the jit cache is keyed on (Q_bucket, spec): a stream of
    variable-size batches compiles once per bucket, and equal specs
    share one entry. `spec.use_quantized` is the scan-tier dimension of
    the key (the index pytree structure -- codes present or not -- is
    itself part of jit's implicit key). Paged execution streams the
    probe set through the frame pool (paged_search).
    """
    if isinstance(index, PagedIndex):
        if spec.predicate is not None and spec.hybrid == "pre":
            raise ValueError(
                "paged mode fuses predicates into the frame scan "
                "(post-filtering); pre-filtering needs the resident "
                "float32 tier")
        if spec.u_max is not None:
            # refuse rather than silently diverge: a capped union changes
            # which partitions are scanned, and the paged probe union is
            # pinned to the resident plan_ann ordering (bit-parity)
            raise ValueError(
                "union_cap is not supported in paged mode (the paged "
                "probe union mirrors the resident plan exactly)")
        return paged_search(
            index, queries, k=spec.k, kind=spec.kind,
            n_probe=spec.n_probe, attr_filter=_spec_filter(spec),
            backend=spec.on_backend, quantized=spec.use_quantized,
            spec=spec)
    q = jnp.atleast_2d(jnp.asarray(queries, jnp.float32))
    Q = q.shape[0]
    b = _bucket(Q) if bucket else Q
    if b != Q:
        q = jnp.concatenate([q, jnp.zeros((b - Q, q.shape[1]), q.dtype)])
    qmask = jnp.arange(b) < Q
    tr = obs_trace.current()
    if tr is None:
        res = _run_spec(index, q, qmask, spec)
    else:
        _record_resident_probe(tr, index, q[:Q], spec)
        tc0 = _TRACE_COUNT
        t0 = time.perf_counter()
        res = _run_spec(index, q, qmask, spec)
        jax.block_until_ready(res.scores)
        _record_resident_scan(tr, index, spec, b,
                              (time.perf_counter() - t0) * 1e3,
                              _TRACE_COUNT - tc0)
    if b != Q:
        res = SearchResult(ids=res.ids[:Q], scores=res.scores[:Q])
    return ResultSet.of(res, spec)


def run_coalesced(index, chunks, spec: QuerySpec):
    """Batch-split entry point for cross-request micro-batching (the
    serving front door): concatenate per-caller query chunks that share
    one spec, execute a SINGLE bucketed `run()` -- one fused scan, one
    jit cache entry per (Q_bucket, spec) -- and split the ResultSet back
    into per-caller slices.

    Bit-parity contract: per-query scores are elementwise (each query
    masks onto its OWN probe set inside the shared union), so a caller's
    slice of the coalesced result carries exactly the ids + scores its
    solo `run()` would have returned -- pinned by tests/test_frontdoor
    and the gather-vs-union parity tests."""
    assert len(chunks) >= 1, "run_coalesced needs at least one chunk"
    qs = [jnp.atleast_2d(jnp.asarray(c, jnp.float32)) for c in chunks]
    sizes = [int(q.shape[0]) for q in qs]
    if len(qs) == 1:
        return [run(index, qs[0], spec)]
    rs = run(index, jnp.concatenate(qs, axis=0), spec)
    return rs.split(sizes)


def search(
    index: IVFIndex,
    queries: jax.Array,
    *,
    k: int,
    kind: str = "ann",                 # ann | exact | prefilter
    n_probe: int = 8,
    u_max: Optional[int] = None,       # MQO union cap (None: exact union)
    cap: Optional[int] = None,         # prefilter gather budget
    attr_filter: Optional[AttrFilter] = None,
    backend: Optional[str] = None,
    quantized: Optional[bool] = None,  # None: auto (codes present)
    bucket: bool = True,
) -> ResultSet:
    """Kwarg-style shim over the QuerySpec entry point (API compat).

    Builds the equivalent spec and routes through `run`, so repeated
    calls with equal kwargs -- or a hand-built equal spec -- share the
    same compile-cache entry.
    """
    if kind == "prefilter":
        assert cap is not None, "kind='prefilter' needs a static cap " \
            "(the optimizer sizes it from the selectivity estimate)"
        assert attr_filter is not None, "kind='prefilter' needs attr_filter"
    pred = None if attr_filter is None else \
        getattr(attr_filter, "predicate", attr_filter)
    spec = QuerySpec(
        kind="exact" if kind == "exact" else "ann", k=k, n_probe=n_probe,
        u_max=u_max, cap=cap, predicate=pred,
        hybrid="pre" if kind == "prefilter" else
        ("post" if pred is not None else "auto"),
        use_quantized=quantized, on_backend=backend)
    return run(index, queries, spec, bucket=bucket)


# ---------------------------------------------------------------------------
# Paged execution (PR 3): scan the memory-budgeted frame pool instead of a
# full-resident tier; the rerank gathers f32 rows from the durable store.
# ---------------------------------------------------------------------------
#
# A PagedIndex (core/types.py) keeps only metadata resident; the scan tier
# is faulted on demand into a storage/pager.PartitionCache. Execution is
# host-driven: (1) pick the probe set from the resident centroids with the
# SAME vote/union ordering as plan_ann -- this is what pins paged-vs-
# resident parity bit-for-bit; (2) fault each probe chunk (<= pool
# capacity) and run the fused scan over the pool with *frame* indices as
# the scalar-prefetched probe list (the frame -> partition indirection --
# both kernels are layout-agnostic, they just stream whichever blocks the
# probe list names); (3) merge chunk top-k's associatively (streaming scan:
# an exact search over a 1 GB tier runs in a 10 MB pool); (4) on a
# quantized index, gather the k' = rerank_factor * k candidate rows from
# SQLite (_rerank_from_store) and rescore at exact f32 -- the float32 tier
# is never materialised; (5) the resident-delta merge + dedup epilogue.


@partial(jax.jit, static_argnames=("k_out", "metric"))
def _paged_rerank(q, v, got, cand, *, k_out, metric):
    """Jitted rescore stage of the paged rerank: literally _rescore_exact
    (the resident rerank's core), so the reported scores are bit-identical
    to the resident path's -- XLA compiles the identical-shape expression
    the same way in both programs."""
    return _rescore_exact(q, v, got, cand, k_out, metric)


def _rerank_from_store(store, q: jax.Array, cand_ids: jax.Array,
                       k_out: int, metric: str):
    """Sibling of _rerank_float32 for the paged path: gather exactly the
    candidate rows' float32 vectors from the durable SQLite tier (batched
    IN (...) -- the disk analogue of the device gather) and recompute
    exact distances. `cand_ids` are *asset* ids ([Q, k'], INVALID_ID
    holes) -- paged frames carry asset ids, and the durable tier is keyed
    by them. Disk-gather cost is O(unique candidates), independent of the
    scan width, which is the point of scanning codes."""
    tr = obs_trace.current()
    t0 = time.perf_counter() if tr is not None else 0.0
    cand = np.asarray(cand_ids)
    got = cand != INVALID_ID
    Q, kc = cand.shape
    d = store.dim
    v = np.zeros((Q, kc, d), np.float32)
    n_uniq = 0
    if got.any():
        uniq = np.unique(cand[got])
        n_uniq = int(uniq.size)
        rows, found = store.vectors_for(uniq)
        rows = np.asarray(normalize_if_cosine(
            jnp.asarray(rows, jnp.float32), metric))
        idx = np.searchsorted(uniq, np.where(got, cand, uniq[0]))
        idx = np.clip(idx, 0, len(uniq) - 1)
        got = got & (uniq[idx] == cand) & found[idx]
        v[got] = rows[idx[got]]
    out = _paged_rerank(q, jnp.asarray(v), jnp.asarray(got),
                        jnp.asarray(cand), k_out=k_out, metric=metric)
    if tr is not None:
        jax.block_until_ready(out[0])
        tr.record(obs_trace.STAGE_RERANK,
                  (time.perf_counter() - t0) * 1e3,
                  candidates=Q * kc, rows_gathered=n_uniq, k_out=k_out)
    return out


def _paged_probes(pindex, q: jax.Array, n_probe: int,
                  qmask: Optional[jax.Array] = None):
    """plan_ann's probe construction over a PagedIndex's resident metadata
    -- literally _probe_union (shared with plan_ann), so paged and
    resident searches agree on the probe order."""
    counts = jnp.asarray(np.asarray(pindex.counts), jnp.int32)
    upart, qsel = _probe_union(pindex.centroids, counts,
                               pindex.config.metric, q, n_probe,
                               qmask=qmask)
    return np.asarray(upart, np.int64), qsel


@partial(jax.jit, static_argnames=("k", "k_scan", "metric", "attr_filter"))
def _paged_epilogue(q, s_m, i_m, delta, qmask, *, k, k_scan, metric,
                    attr_filter):
    """Jitted wrapper over _merge_epilogue (execute_plan's shared tail):
    bit-parity with the resident path by construction."""
    return _merge_epilogue(delta, metric, q, s_m, i_m, k, k_scan,
                           attr_filter, qmask=qmask)


# Double-buffered fault pipeline (PR 6): while the fused scan chews on
# chunk N, a single worker thread STAGES chunk N+1 -- the SQLite fetch +
# host-side block packing (PartitionCache.stage) -- so the disk latency
# overlaps the scan and the next fault() only pays the frame scatter.
# Staging takes no frames, no pins, and never rebinds a pool, so the
# chunking is identical to the serial loop and results are bit-identical
# by construction (same probe order, same per-chunk top-k merge). Set
# False to force the serial fetch->scan loop (the before/after axis of
# bench_paged.py).
PAGED_PREFETCH = True

_PREFETCHER = None


def _prefetcher():
    global _PREFETCHER
    if _PREFETCHER is None:
        from concurrent.futures import ThreadPoolExecutor
        _PREFETCHER = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="micronn-prefetch")
    return _PREFETCHER


@partial(jax.jit, static_argnames=("k_out", "metric", "backend",
                                   "attr_filter"))
def _scan_frames(q, payload, valid, ids, frame_ids, qsel, attrs, *,
                 k_out, metric, backend, attr_filter):
    """Jitted frame-pool scan chunk (f32 payload): the fused kernel runs
    over the pool with frame indices as its probe list."""
    return fused_scan(q, payload, valid, ids, frame_ids, k_out,
                      metric=metric, qsel=qsel, attrs=attrs,
                      attr_filter=attr_filter, backend=backend)


@partial(jax.jit, static_argnames=("k_out", "metric", "backend",
                                   "attr_filter"))
def _scan_frames_sq(q, payload, qstats, valid, ids, frame_ids, qsel, attrs,
                    *, k_out, metric, backend, attr_filter):
    """Jitted frame-pool scan chunk (int8 payload + fused dequantize)."""
    return fused_sq_scan(q, payload, qstats, valid, ids, frame_ids, k_out,
                         metric=metric, qsel=qsel, attrs=attrs,
                         attr_filter=attr_filter, backend=backend)


def paged_search(
    pindex,
    queries: jax.Array,
    *,
    k: int,
    kind: str = "ann",                 # ann | exact
    n_probe: int = 8,
    attr_filter: Optional[AttrFilter] = None,
    backend: Optional[str] = None,
    quantized: Optional[bool] = None,
    spec: Optional[QuerySpec] = None,  # carried onto the ResultSet
) -> ResultSet:
    """Run a search against a PagedIndex through the budgeted frame pool.

    The probe union is processed in chunks of at most the pool's frame
    capacity: each chunk is faulted (pinned), scanned by the fused kernel
    over the pool, unpinned, and its top-k merged into the running result
    -- so resident scan-tier bytes never exceed the budget even for an
    exact scan of the whole collection. Hybrid predicates are fused into
    the frame scan (the cache carries attrs frames); a quantized index
    scans int8 frames and reranks candidates straight from SQLite.
    """
    cfg = pindex.config
    cache = pindex.cache
    q = normalize_if_cosine(
        jnp.atleast_2d(jnp.asarray(queries, jnp.float32)), cfg.metric)
    Q = q.shape[0]
    b = _bucket(Q)
    if b != Q:
        q = jnp.concatenate([q, jnp.zeros((b - Q, q.shape[1]), q.dtype)])
    qmask = jnp.arange(b) < Q

    # the pool payload dictates the scan: an int8 pool can only run the SQ
    # scan (there are no f32 frames to brute-force -- paged "exact" on a
    # quantized index scans every partition's codes and reranks, a
    # full-probe near-oracle rather than the resident f32 oracle)
    use_sq = pindex.cache.payload == "int8"
    if quantized is not None:
        assert quantized == use_sq, \
            f"paged scan tier is fixed by the frame pool payload " \
            f"({pindex.cache.payload}); cannot force quantized={quantized}"

    tr = obs_trace.current()
    t_probe = time.perf_counter() if tr is not None else 0.0
    if kind == "exact":
        counts = np.asarray(pindex.counts)
        upart = np.nonzero(counts > 0)[0]
        qsel = jnp.broadcast_to(qmask[:, None], (b, len(upart)))
    else:
        assert kind == "ann", kind
        upart, qsel = _paged_probes(pindex, q, n_probe, qmask=qmask)

    n = len(upart)
    if tr is not None:
        tr.record(obs_trace.STAGE_PROBE,
                  (time.perf_counter() - t_probe) * 1e3,
                  partitions=int(n), n_probe=int(n_probe), kind=kind)
    p_max = cache.p_max
    if use_sq:
        k_run = min(max(k, k * cfg.rerank_factor), max(n * p_max, 1))
    else:
        k_run = min(k, max(n * p_max, 1))
    run_s = jnp.full((b, k_run), MASKED_SCORE, jnp.float32)
    run_i = jnp.full((b, k_run), INVALID_ID, jnp.int32)

    if attr_filter is not None:
        assert cache.attrs_pool is not None, \
            "attribute predicate needs an attr-backed frame pool " \
            "(store built with n_attr > 0)"
    # Scan-resistant admission (ROADMAP open item): a paged exact search
    # reads every partition exactly once, so admitting its stream would
    # flush the hot ANN working set out of the pool. Exact faults run
    # with admit=False -- they cycle through a small reusable scan ring
    # inside the pool (budget unchanged) -- and chunk to the ring size.
    admit = kind != "exact"
    ring = cache.capacity if admit else cache.scan_frames
    chunk = ring
    # Double-buffering: while the fused scan chews on chunk N, the worker
    # thread STAGES chunk N+1 -- the SQLite fetch + host block packing
    # land in the pager's staging dict (PartitionCache.stage), so the
    # next fault() only pays the frame scatter. Staging takes no frames
    # and no pins, so chunking is unchanged (results trivially
    # bit-identical with prefetch off) and the fault keeps its donated
    # in-place scatter (no foreign pins outstanding). Single-chunk probe
    # lists keep the serial path -- nothing to overlap.
    prefetch = PAGED_PREFETCH and n > chunk
    starts = list(range(0, n, chunk))
    pending = None          # in-flight stage future for the next chunk
    try:
        for ci_, s in enumerate(starts):
            cpids = upart[s:s + chunk]
            if pending is not None:
                try:
                    pending.result()    # staged blocks ready to consume
                except Exception:
                    pass                # advisory: fault() re-reads SQLite
                pending = None
            frames = cache.fault(cpids, admit=admit)
            if prefetch and ci_ + 1 < len(starts):
                s2 = starts[ci_ + 1]
                pending = _prefetcher().submit(
                    cache.stage, upart[s2:s2 + chunk])
            try:
                # read the pools AFTER fault(): the batched scatter rebinds
                # them (functional .at[].set), so a reference captured
                # before the fault would scan stale frame contents. A
                # concurrent prefetch fault may rebind them again, but the
                # current chunk's frames are pinned, so every binding holds
                # identical contents for them (copy-on-write scatter).
                attrs_pool = cache.attrs_pool if attr_filter is not None \
                    else None
                fidx = jnp.asarray(frames.astype(np.int32))
                cq = qsel[:, s:s + chunk]
                k_chunk = min(k_run, len(cpids) * p_max)
                t_scan = time.perf_counter() if tr is not None else 0.0
                if use_sq:
                    cs, ci = _scan_frames_sq(
                        q, cache.payload_pool, pindex.qstats,
                        cache.valid_pool, cache.ids_pool, fidx, cq,
                        attrs_pool, k_out=k_chunk, metric=cfg.metric,
                        backend=backend, attr_filter=attr_filter)
                else:
                    cs, ci = _scan_frames(
                        q, cache.payload_pool, cache.valid_pool,
                        cache.ids_pool, fidx, cq, attrs_pool,
                        k_out=k_chunk, metric=cfg.metric, backend=backend,
                        attr_filter=attr_filter)
                if tr is not None:
                    jax.block_until_ready(cs)
                    tr.record(obs_trace.STAGE_SCAN,
                              (time.perf_counter() - t_scan) * 1e3,
                              chunks=1, partitions=len(cpids),
                              rows=len(cpids) * p_max,
                              backend=backend or default_backend(),
                              quantized=use_sq, q_bucket=b)
            finally:
                cache.unpin(frames)
            run_s, run_i = merge_topk(run_s, run_i, cs, ci, k_run)
    finally:
        if pending is not None:     # scan raised: let the stage land (it
            try:                    # holds no pins; entries age out)
                pending.result()
            except Exception:
                pass

    if use_sq:
        # the frame scan emits asset ids; invalidate re-emitted rows from
        # exhausted merge rounds by score (as execute_plan does), then
        # gather + rescore the survivors from the durable tier
        cand = jnp.where(run_s >= MASKED_SCORE, INVALID_ID, run_i)
        k_scan = min(k, k_run)
        s_m, i_m = _rerank_from_store(cache.store, q, cand, k_scan,
                                      cfg.metric)
    else:
        k_scan = k_run if n else 0
        s_m, i_m = (run_s, run_i) if n else (
            jnp.zeros((b, 0), jnp.float32), jnp.zeros((b, 0), jnp.int32))

    t_merge = time.perf_counter() if tr is not None else 0.0
    s_f, i_f = _paged_epilogue(q, s_m, i_m, pindex.delta, qmask,
                               k=k, k_scan=k_scan, metric=cfg.metric,
                               attr_filter=attr_filter)
    if tr is not None:
        jax.block_until_ready(s_f)
        tr.record(obs_trace.STAGE_MERGE,
                  (time.perf_counter() - t_merge) * 1e3,
                  k=int(k), k_scan=int(k_scan), fused=0)
    if b != Q:
        s_f, i_f = s_f[:Q], i_f[:Q]
    return ResultSet(ids=i_f, scores=s_f, spec=spec)


# -- registry wiring (PR 8): the compile-cache instruments surface through
# the process metrics registry next to the pager / front door / scheduler,
# so one snapshot carries the whole telemetry state.
_OBS = obs_metrics.default_registry().scope(component="executor")
_OBS.gauge("trace_count", fn=trace_count)
_OBS.gauge("compile_cache_size", fn=compile_cache_size)
