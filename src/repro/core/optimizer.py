"""Hybrid query optimizer (paper §3.5.1, Eqs. 1-3).

Chooses between:
  * pre-filtering  -- evaluate predicate, brute-force over qualifiers
                      (100% recall; cost ~ predicate cardinality)
  * post-filtering -- ANN scan with the predicate fused before top-k
                      (cost ~ n_probe * p_target; recall can drop for
                      highly selective predicates)

Decision rule: pre-filter iff  F_hat_filters < F_hat_IVF  where
F_hat_IVF = n_probe * p_target / |R|   (Eq. 2).

Both arms are QuerySpec rewrites over core/executor.py: `plan_spec`
resolves a spec's `hybrid="auto"` into a concrete "pre" (with a sized
gather cap) or "post" spec, and the same fused scan primitive executes
either -- which is what makes the two plans' costs comparable in the
first place. `execute` survives as a kwarg shim over the spec path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import executor
from .hybrid import AttributeStats, Node
from .query import Q, QuerySpec, ResultSet
from .types import IVFIndex


@dataclasses.dataclass
class PlanDecision:
    plan: str                  # "pre" | "post"
    f_filters: float           # estimated predicate selectivity factor
    f_ivf: float               # IVF pseudo-predicate selectivity factor
    prefilter_cap: int         # static gather budget when plan == "pre"


class HybridOptimizer:
    """Plan chooser + executor. Stats refresh on (re)build / maintenance."""

    def __init__(self, stats: AttributeStats, *,
                 cap_safety: float = 2.0, cap_round: int = 256,
                 max_prefilter_cap: Optional[int] = None):
        self.stats = stats
        self.cap_safety = cap_safety
        self.cap_round = cap_round
        self.max_prefilter_cap = max_prefilter_cap

    def choose(self, index: IVFIndex, predicate: Node, n_probe: int) -> PlanDecision:
        n_rows = max(1, int(index.num_live()))
        f_filters = self.stats.selectivity_factor(predicate)
        f_ivf = min(1.0, n_probe * index.config.target_partition_size / n_rows)
        est_rows = f_filters * n_rows
        cap = int(est_rows * self.cap_safety) + self.cap_round
        cap = min(cap, n_rows, *( [self.max_prefilter_cap]
                                  if self.max_prefilter_cap else [] ))
        cap = max(self.cap_round, -(-cap // self.cap_round) * self.cap_round)
        plan = "pre" if f_filters < f_ivf else "post"
        return PlanDecision(plan=plan, f_filters=f_filters, f_ivf=f_ivf,
                            prefilter_cap=cap)

    def plan_spec(self, index: IVFIndex, spec: QuerySpec
                  ) -> Tuple[QuerySpec, PlanDecision]:
        """Resolve a hybrid spec into a concrete executable one: pick
        "pre" vs "post" for `hybrid='auto'` (Eq. 2) and size the
        prefilter gather cap when the caller left it to us. The rewrite
        keeps the spec the jit cache key -- equal input specs always
        resolve to equal output specs while the stats stand."""
        tree = spec.predicate_tree
        assert tree is not None, \
            "plan_spec needs an inspectable predicate tree (opaque " \
            "filter callables have no selectivity estimate)"
        decision = self.choose(index, tree, spec.n_probe)
        plan = decision.plan if spec.hybrid == "auto" else spec.hybrid
        if plan == "pre":
            cap = spec.cap if spec.cap is not None else decision.prefilter_cap
            out = spec.prefilter(cap)
        else:
            out = spec.postfilter()
        return out, dataclasses.replace(decision, plan=plan)

    def execute(
        self,
        index: IVFIndex,
        queries: jax.Array,
        predicate: Node,
        k: int,
        n_probe: int,
        force_plan: Optional[str] = None,
        use_mqo: bool = False,      # kept for API compat: ANN == MQO plan now
        backend: Optional[str] = None,
    ) -> tuple[ResultSet, PlanDecision]:
        """Kwarg shim over the spec path (API compat)."""
        del use_mqo
        spec = Q.knn(k=k, n_probe=n_probe).where(predicate).backend(backend)
        if force_plan is not None:
            spec = dataclasses.replace(spec, hybrid=force_plan)
        spec, decision = self.plan_spec(index, spec)
        return executor.run(index, queries, spec), decision
