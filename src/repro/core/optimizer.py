"""Hybrid query optimizer (paper §3.5.1, Eqs. 1-3).

Chooses between:
  * pre-filtering  -- evaluate predicate, brute-force over qualifiers
                      (100% recall; cost ~ predicate cardinality)
  * post-filtering -- ANN scan with the predicate fused before top-k
                      (cost ~ n_probe * p_target; recall can drop for
                      highly selective predicates)

Decision rule: pre-filter iff  F_hat_filters < F_hat_IVF  where
F_hat_IVF = n_probe * p_target / |R|   (Eq. 2).

Both arms are plan-builders over core/executor.py: the decision picks the
plan *kind* ("prefilter" vs "ann" with the predicate fused), and the same
fused scan primitive executes either -- which is what makes the two plans'
costs comparable in the first place.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from . import executor
from .hybrid import AttributeStats, Node, compile_filter
from .types import IVFIndex, SearchResult


@dataclasses.dataclass
class PlanDecision:
    plan: str                  # "pre" | "post"
    f_filters: float           # estimated predicate selectivity factor
    f_ivf: float               # IVF pseudo-predicate selectivity factor
    prefilter_cap: int         # static gather budget when plan == "pre"


class HybridOptimizer:
    """Plan chooser + executor. Stats refresh on (re)build / maintenance."""

    def __init__(self, stats: AttributeStats, *,
                 cap_safety: float = 2.0, cap_round: int = 256,
                 max_prefilter_cap: Optional[int] = None):
        self.stats = stats
        self.cap_safety = cap_safety
        self.cap_round = cap_round
        self.max_prefilter_cap = max_prefilter_cap

    def choose(self, index: IVFIndex, predicate: Node, n_probe: int) -> PlanDecision:
        n_rows = max(1, int(index.num_live()))
        f_filters = self.stats.selectivity_factor(predicate)
        f_ivf = min(1.0, n_probe * index.config.target_partition_size / n_rows)
        est_rows = f_filters * n_rows
        cap = int(est_rows * self.cap_safety) + self.cap_round
        cap = min(cap, n_rows, *( [self.max_prefilter_cap]
                                  if self.max_prefilter_cap else [] ))
        cap = max(self.cap_round, -(-cap // self.cap_round) * self.cap_round)
        plan = "pre" if f_filters < f_ivf else "post"
        return PlanDecision(plan=plan, f_filters=f_filters, f_ivf=f_ivf,
                            prefilter_cap=cap)

    def execute(
        self,
        index: IVFIndex,
        queries: jax.Array,
        predicate: Node,
        k: int,
        n_probe: int,
        force_plan: Optional[str] = None,
        use_mqo: bool = False,      # kept for API compat: ANN == MQO plan now
        backend: Optional[str] = None,
    ) -> tuple[SearchResult, PlanDecision]:
        del use_mqo
        decision = self.choose(index, predicate, n_probe)
        plan = force_plan or decision.plan
        attr_filter = compile_filter(predicate)
        if plan == "pre":
            res = executor.search(index, queries, k=k, kind="prefilter",
                                  attr_filter=attr_filter,
                                  cap=decision.prefilter_cap, backend=backend)
        else:
            res = executor.search(index, queries, k=k, kind="ann",
                                  n_probe=n_probe, attr_filter=attr_filter,
                                  backend=backend)
        return res, dataclasses.replace(decision, plan=plan)
