"""Training loop with fault tolerance & straggler tracking.

Production behaviours implemented (and unit-tested):
  * train_step builder: loss -> grad -> clip -> AdamW, with optional
    gradient-accumulation microbatching (jax.lax.scan over microbatches,
    so HBM sees one microbatch of activations at a time);
  * checkpoint every N steps via storage.checkpoint (atomic, elastic);
  * automatic restart: `fit` resumes from the newest complete checkpoint,
    including after a mid-run crash (simulated in tests by killing the
    loop);
  * straggler mitigation: per-step wall-time EWMA + z-score flagging; on a
    real pod the hook triggers hot-spare swap / rebalance -- here it logs
    and (configurably) re-executes the step, which is the single-process
    analogue;
  * data-state is part of the checkpoint (step -> stream position), so
    restart does not replay or skip batches.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import transformer
from ..storage import checkpoint as ckpt_lib
from . import optim


@dataclasses.dataclass
class TrainerConfig:
    opt: optim.AdamWConfig = dataclasses.field(default_factory=optim.AdamWConfig)
    microbatches: int = 1
    checkpoint_every: int = 50
    ckpt_dir: Optional[str] = None
    straggler_zscore: float = 3.0
    straggler_ewma: float = 0.9
    max_step_retries: int = 1


def make_train_step(cfg: ModelConfig, tcfg: TrainerConfig,
                    scan: Optional[bool] = None,
                    remat: Optional[bool] = None,
                    donate: bool = True):
    """-> jitted (params, opt_state, batch) -> (params, opt_state, metrics)."""

    def loss(params, batch):
        return transformer.loss_fn(cfg, params, batch, scan=scan,
                                   remat=remat)

    def step(params, opt_state, batch):
        if tcfg.microbatches > 1:
            def micro(carry, mb):
                acc = carry
                (l, metrics), g = jax.value_and_grad(loss, has_aux=True)(
                    params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return acc, metrics
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mbs = jax.tree.map(
                lambda x: x.reshape((tcfg.microbatches,
                                     x.shape[0] // tcfg.microbatches)
                                    + x.shape[1:]), batch)
            grads, metrics = jax.lax.scan(micro, zero, mbs)
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(
                params, batch)
        params, opt_state, opt_metrics = optim.update(
            tcfg.opt, grads, opt_state, params)
        return params, opt_state, {**metrics, **opt_metrics}

    return jax.jit(step, donate_argnums=(0, 1) if donate else ())


@dataclasses.dataclass
class StragglerStats:
    ewma: float = 0.0
    var: float = 0.0
    n: int = 0
    flagged: int = 0

    def observe(self, dt: float, z: float) -> bool:
        if self.n < 3:  # warmup
            self.ewma = dt if self.n == 0 else \
                0.5 * (self.ewma + dt)
            self.n += 1
            return False
        slow = dt > self.ewma + z * max(self.var, 1e-9) ** 0.5 and \
            dt > 1.5 * self.ewma
        d = dt - self.ewma
        self.ewma += 0.1 * d
        self.var = 0.9 * (self.var + 0.1 * d * d)
        self.n += 1
        if slow:
            self.flagged += 1
        return slow


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig,
                 scan: Optional[bool] = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.step_fn = make_train_step(cfg, tcfg, scan=scan)
        self.straggler = StragglerStats()
        self.history: list[Dict[str, float]] = []

    def fit(self, params, data_iter_fn: Callable[[int], Iterator],
            steps: int, opt_state: Optional[optim.OptState] = None):
        """data_iter_fn(start_step) -> iterator of batches (resumable)."""
        tcfg = self.tcfg
        start = 0
        if tcfg.ckpt_dir:
            latest = ckpt_lib.latest_step(tcfg.ckpt_dir)
            if latest is not None:
                state_tmpl = {"params": params,
                              "opt": opt_state or optim.init(params)}
                restored, start, _ = ckpt_lib.restore_checkpoint(
                    tcfg.ckpt_dir, state_tmpl)
                params, opt_state = restored["params"], restored["opt"]
        opt_state = opt_state or optim.init(params)
        # the jitted step donates params/opt buffers; copy so the caller's
        # pytree survives (and can seed another run)
        params = jax.tree.map(jnp.array, params)
        opt_state = jax.tree.map(jnp.array, opt_state)

        it = data_iter_fn(start)
        for step in range(start, steps):
            batch = next(it)
            t0 = time.perf_counter()
            params, opt_state, metrics = self.step_fn(
                params, opt_state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            slow = self.straggler.observe(dt, tcfg.straggler_zscore)
            metrics.update(step=step, dt=dt, straggler=int(slow))
            self.history.append(metrics)
            if tcfg.ckpt_dir and (step + 1) % tcfg.checkpoint_every == 0:
                ckpt_lib.save_checkpoint(
                    tcfg.ckpt_dir, step + 1,
                    {"params": params, "opt": opt_state},
                    extra={"data_step": step + 1})
        return params, opt_state
