from . import optim
from .trainer import Trainer, TrainerConfig, make_train_step
