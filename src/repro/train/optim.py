"""AdamW + gradient clipping, pure JAX (no optax dependency).

Optimizer state mirrors the parameter pytree leaf-for-leaf, so the same
logical->physical sharding rules apply: under FSDP rules the f32 master
copy, m and v shard over the data axis -- the ZeRO-style partitioning that
lets grok-1's 314B states fit 512 x 16 GB.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    mu: Any        # f32, like params
    nu: Any        # f32, like params
    count: jax.Array


def init(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(mu=jax.tree.map(zeros, params),
                    nu=jax.tree.map(zeros, params),
                    count=jnp.zeros((), jnp.int32))


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads, state: OptState, params
           ) -> Tuple[Any, OptState, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    count = state.count + 1
    lr = schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def leaf(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        upd = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (standard practice)
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * upd).astype(p.dtype), m, v

    out = jax.tree.map(leaf, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(new_mu, new_nu, count), \
        {"grad_norm": gnorm, "lr": lr}
