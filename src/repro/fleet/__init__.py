"""Fleet mode (PR 9): many per-tenant MicroNN engines behind ONE
global memory budget and one maintenance daemon.

  * `pool`    -- FramePool: the process-global, budget-bounded frame
                 pool shared by every tenant's pager view (global CLOCK
                 eviction, per-tenant pin accounting).
  * `manager` -- Fleet: open/get/close tenants with lazy recover, an
                 LRU of live engine handles that spills idle tenants,
                 and FleetScheduler: one deficit-round-robin
                 maintenance daemon for the whole fleet.

`manager` imports the full engine stack, so it loads lazily (PEP 562)
-- the pager can import `fleet.pool` without a circular import through
`storage.engine`.
"""
from .pool import FramePool, compute_frame_bytes

_LAZY = ("Fleet", "FleetScheduler", "TenantSLO")


def __getattr__(name):
    if name in _LAZY:
        from . import manager as _manager
        return getattr(_manager, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_LAZY))


__all__ = ["FramePool", "compute_frame_bytes", "Fleet", "FleetScheduler",
           "TenantSLO", "pool"]
