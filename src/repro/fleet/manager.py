"""Fleet manager (PR 9): many per-tenant MicroNN engines under ONE
global memory budget, one live-handle LRU, and one maintenance daemon.

The paper's deployment is one on-device index per user; the server-side
mirror is one process hosting thousands of per-user/per-corpus indexes
(RAG stores, chat-session memory, semantic caches). `Fleet` is that
process's front door:

    fleet = Fleet(root, dim=64, budget_mb=8.0, max_live=64)
    eng = fleet.get("alice")          # lazy open + recover()
    with eng.session() as s: s.upsert(ids, vecs)
    eng.build()
    rs = fleet.query("alice", q, Q.knn(k=10))
    fleet.start_maintenance()         # ONE daemon for every tenant

Resource governance, in three shared pieces:

  * **One frame pool.** Every tenant's pager view is registered into a
    single `FramePool` (fleet/pool.py): fleet-wide resident bytes <=
    `budget_mb` BY CONSTRUCTION, and the pool's global CLOCK lets hot
    tenants' working sets grow at cold tenants' expense -- no per-tenant
    quota tuning, and strictly better capacity use than naive
    equal-split per-tenant pools (gated by benchmarks/bench_fleet.py).

  * **One live-handle LRU.** SQLite connections, index metadata
    pytrees, and the optimizer are per-engine host state; `max_live`
    bounds how many tenants keep theirs open. The LRU victim is
    *spilled*: its frames invalidated, its store closed, its engine
    dropped -- everything durable already lives in SQLite, so the next
    `get()` simply re-opens and `recover()`s (paged recovery is
    metadata-only; partitions fault back on first probe). Per-tenant
    metrics are labeled by tenant NAME, so a reopened tenant resumes
    its cumulative series.

  * **One maintenance daemon.** `FleetScheduler` runs deficit round
    robin over the live tenants' `MaintenanceScheduler`s: each round a
    tenant may spend up to `quantum_rows` of maintenance work (debt
    from an oversized step carries into its next round), so a churning
    tenant cannot starve the rest -- every tenant with pending work
    makes progress within a bounded number of rounds
    (tests/test_fleet.py pins the bound).

The executor's jit compile cache is process-global and keyed by the
frozen QuerySpec + shapes (PR 4), never by engine identity -- so N
tenants with a shared geometry compile once per (spec, Q-bucket) with
no code here at all; tests assert the zero-retrace property.
"""
from __future__ import annotations

import dataclasses
import os
import re
import sqlite3
import threading
import time
from collections import OrderedDict
from typing import Dict, List, Optional

from ..core.types import IVFConfig, PagedIndex, effective_pad_to
from ..obs import metrics as obs_metrics
from ..obs import recorder as obs_recorder
from ..storage.engine import MicroNN
from .pool import FramePool

_NAME_RE = re.compile(r"^[A-Za-z0-9][A-Za-z0-9._-]*$")

# manifest filename: starts with '_' so it can never collide with a
# tenant db (_NAME_RE requires a leading alphanumeric)
_MANIFEST = "_manifest.db"


@dataclasses.dataclass(frozen=True)
class TenantSLO:
    """Per-tenant latency objective: `target` fraction of queries must
    complete within `p99_ms`. Error-budget burn = (observed fraction
    above the objective) / (allowed fraction, 1 - target): burn <= 1.0
    means the tenant is inside its budget ("ok"), > 1.0 means the
    budget is burning faster than allotted ("degraded")."""

    p99_ms: float = 50.0
    target: float = 0.99

    def __post_init__(self):
        assert self.p99_ms > 0, self.p99_ms
        assert 0.0 < self.target < 1.0, self.target


class FleetScheduler:
    """Deficit-round-robin maintenance across a fleet's live tenants.

    One daemon thread serves every tenant's `MaintenanceScheduler`: each
    round visits the live tenants in order, granting each `quantum_rows`
    of credit; a tenant steps (bounded quanta, under ITS engine lock)
    until its credit runs out or its queue idles. Unused credit is NOT
    banked (an idle tenant starts the next round at zero), while
    overdraft from a final oversized step carries as debt -- the classic
    DRR fairness bound: over any window, every backlogged tenant gets
    within one max-step of its 1/N share, so a churning tenant cannot
    starve the rest."""

    # idle-fleet wait multiplier: with no actionable work anywhere the
    # daemon sleeps interval_s * _IDLE_BACKOFF between polls (woken
    # early by kick())
    _IDLE_BACKOFF = 8

    def __init__(self, fleet: "Fleet", *, quantum_rows: Optional[int] = None,
                 interval_s: float = 0.002, metrics=None):
        self.fleet = fleet
        self.quantum_rows = int(quantum_rows or fleet.max_rows_per_step)
        self.interval_s = float(interval_s)
        self._deficit: Dict[str, float] = {}
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._wake = threading.Event()
        if metrics is None:
            metrics = fleet.metrics.scope(component="fleet_scheduler")
        self._c_rounds = metrics.counter("rounds")
        self._c_steps = metrics.counter("steps")

    def step_round(self) -> int:
        """One full rotation over the live tenants; returns the number
        of maintenance steps executed. Callable without the daemon (the
        hand-cranked test/drain path)."""
        with self.fleet._lock:
            items = list(self.fleet._live.items())
        steps = 0
        for name, eng in items:
            credit = self._deficit.get(name, 0.0) + self.quantum_rows
            while credit > 0:
                # per-step engine lock (never the fleet lock): queries on
                # other tenants, and snapshot reads on this one, proceed
                with eng.lock:
                    if getattr(eng, "_spilled", False):
                        report = None
                    else:
                        report = eng.scheduler.step(daemon=True)
                if report is None:
                    credit = 0.0        # queue idle: no banked credit
                    break
                steps += 1
                credit -= max(int(report.rows), 1)
            self._deficit[name] = min(credit, 0.0)   # carry only debt
        self._c_rounds.inc()
        if steps:
            self._c_steps.inc(steps)
        return steps

    def drain(self, timeout: float = 30.0) -> int:
        """Hand-crank rounds until no tenant has actionable work."""
        deadline = time.monotonic() + timeout
        total = 0
        while True:
            did = self.step_round()
            total += did
            if not did:
                return total
            if time.monotonic() > deadline:
                raise TimeoutError("fleet maintenance did not drain")

    # -- daemon --------------------------------------------------------------
    @property
    def alive(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def start(self):
        if self.alive:
            return
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._loop, name="micronn-fleet-maintenance",
            daemon=True)
        self._thread.start()

    def stop(self, timeout: float = 5.0):
        if self._thread is None:
            return
        self._stop.set()
        self._wake.set()
        self._thread.join(timeout)
        self._thread = None

    def kick(self):
        """Wake the daemon early (a writer just queued work)."""
        self._wake.set()

    def _loop(self):
        while not self._stop.is_set():
            did = self.step_round()
            wait = self.interval_s if did \
                else self.interval_s * self._IDLE_BACKOFF
            self._wake.wait(wait)
            self._wake.clear()


class Fleet:
    """Open/get/close many per-tenant MicroNN engines over one shared
    FramePool, one live-handle LRU, and one maintenance daemon."""

    def __init__(self, root: str, *, dim: int, n_attr: int = 0,
                 budget_mb: float = 8.0, max_live: int = 64,
                 config: Optional[IVFConfig] = None,
                 quantize: Optional[str] = None,
                 rerank_factor: Optional[int] = None,
                 max_rows_per_step: int = 4096,
                 maintenance_interval_s: float = 0.002,
                 slo: Optional[TenantSLO] = None):
        assert budget_mb > 0, budget_mb
        assert max_live >= 1, max_live
        cfg = config or IVFConfig(dim=dim)
        if quantize is not None:
            cfg = dataclasses.replace(cfg, quantize=quantize)
        if rerank_factor is not None:
            cfg = dataclasses.replace(cfg, rerank_factor=rerank_factor)
        self.root = str(root)
        os.makedirs(self.root, exist_ok=True)
        self.dim = int(dim)
        self.n_attr = int(n_attr)
        self.budget_mb = float(budget_mb)
        self.max_live = int(max_live)
        self.config = cfg
        self.max_rows_per_step = int(max_rows_per_step)
        # ONE pool for the whole fleet, allocated eagerly at the global
        # budget (resident bytes <= budget from the first fault onward);
        # geometry starts at the config's pad and grows to the largest
        # tenant via the ordinary resize path on registration
        self.pool = FramePool(
            dim=self.dim, p_max=effective_pad_to(cfg),
            budget_bytes=int(self.budget_mb * 2 ** 20),
            payload="int8" if cfg.quantize == "int8" else "f32",
            n_attr=self.n_attr)
        self._lock = threading.RLock()
        self._live: "OrderedDict[str, MicroNN]" = OrderedDict()
        self._closed = False
        # crash-consistent tenant directory (PR 10): the manifest, not
        # the filesystem listing, is the authority on which tenants
        # exist. create/drop are single SQLite transactions; recover()
        # reconciles manifest vs disk and health() reports the drift
        self._manifest = sqlite3.connect(
            os.path.join(self.root, _MANIFEST),
            check_same_thread=False, isolation_level=None)
        self._manifest.execute("PRAGMA journal_mode=WAL")
        self._manifest.execute("PRAGMA synchronous=NORMAL")
        self._manifest.execute(
            "CREATE TABLE IF NOT EXISTS tenants ("
            "name TEXT PRIMARY KEY, created_ts REAL NOT NULL)")
        # per-tenant SLO objectives (default applies to every tenant
        # without an explicit override)
        self.default_slo = slo or TenantSLO()
        self._slos: Dict[str, TenantSLO] = {}
        self._orphans: List[str] = []
        self._missing: List[str] = []
        self.recover()
        self.metrics = obs_metrics.default_registry().scope(
            component="fleet", inst=str(obs_metrics.next_instance()))
        self._c_opens = self.metrics.counter("tenant_opens")
        self._c_spills = self.metrics.counter("tenant_spills")
        self.metrics.gauge("resident_bytes",
                           fn=lambda: self.pool.resident_bytes)
        self.metrics.gauge("live_tenants", fn=lambda: len(self._live))
        self.scheduler = FleetScheduler(
            self, interval_s=maintenance_interval_s,
            quantum_rows=max_rows_per_step)

    # -- tenant lifecycle ----------------------------------------------------
    def _path(self, name: str) -> str:
        assert _NAME_RE.match(name), \
            f"tenant name {name!r} must match {_NAME_RE.pattern}"
        return os.path.join(self.root, f"{name}.db")

    def get(self, name: str) -> MicroNN:
        """The tenant's live engine: opened + `recover()`ed lazily on
        first touch, then LRU-cached up to `max_live` handles (the LRU
        victim is spilled -- see _spill). A first-ever touch REGISTERS
        the tenant in the durable manifest (one transaction) before its
        db file exists, so a crash in between leaves a reconcilable
        manifest entry, never an unaccounted file."""
        # flight-recorder hook (PR 10): one global load + branch when
        # off; captures the tenant touch order so replay drives the
        # live-handle LRU (opens + spills) exactly as production did
        rec = obs_recorder._ACTIVE
        if rec is not None:
            rec.record(obs_recorder.SITE_FLEET_GET, name, None)
        with self._lock:
            assert not self._closed, "Fleet is closed"
            eng = self._live.get(name)
            if eng is not None:
                self._live.move_to_end(name)
                return eng
            self._manifest.execute(
                "INSERT OR IGNORE INTO tenants VALUES (?, ?)",
                (name, time.time()))
            if name in self._orphans:
                self._orphans.remove(name)   # adopted on access
            eng = MicroNN(
                self.dim, self.n_attr, path=self._path(name),
                config=self.config,
                memory_budget_mb=self.budget_mb,
                max_rows_per_step=self.max_rows_per_step,
                frame_pool=self.pool, tenant=name)
            eng.recover()
            self._live[name] = eng
            self._c_opens.inc()
            while len(self._live) > self.max_live:
                victim = next(iter(self._live))
                if victim == name:
                    break
                self._spill(victim)
            return eng

    open = get

    def _spill(self, name: str):
        """Evict one live handle: invalidate its frames (they describe
        an engine that is about to vanish), close its SQLite
        connections, and drop the engine. Everything durable -- rows,
        clustering, codes, pending delta (partition -1), maintenance
        signals -- already lives in SQLite, so a later get() re-opens
        and recover()s to an equivalent engine."""
        eng = self._live.pop(name)
        with eng.lock:
            # flag checked under the engine lock by the fleet daemon: a
            # step scheduled against a spilled engine becomes a no-op
            # instead of touching a closed connection
            eng._spilled = True
            if isinstance(eng.index, PagedIndex):
                eng.index.cache.invalidate_all()
            eng.index = None
            eng.optimizer = None
            eng.store.close()
        self._deficit_forget(name)
        self._c_spills.inc()

    def _deficit_forget(self, name: str):
        self.scheduler._deficit.pop(name, None)

    def drop(self, name: str):
        """Destroy a tenant: spill its handle, delete its manifest row
        (ONE transaction -- the durable point of no return), then
        remove its db files. A crash after the commit but before the
        unlink leaves an orphan file that recover() reports and a
        re-`get()` would recreate from scratch -- never a half-deleted
        tenant the manifest still claims."""
        path = self._path(name)
        with self._lock:
            if name in self._live:
                self._spill(name)
            self._manifest.execute(
                "DELETE FROM tenants WHERE name = ?", (name,))
            self._slos.pop(name, None)
            for suffix in ("", "-wal", "-shm"):
                try:
                    os.remove(path + suffix)
                except FileNotFoundError:
                    pass

    def recover(self) -> Dict[str, List[str]]:
        """Reconcile the durable manifest against the filesystem.
        Returns (and caches for health()) the drift: `orphans` are db
        files with no manifest row (a crash mid-drop, or a foreign
        file), `missing` are manifest rows whose db file vanished (a
        crash between registration and first write is benign -- the
        file appears on first get() -- but an externally deleted store
        also lands here). Neither is auto-repaired: get() adopts an
        orphan on access, and the operator decides on missing rows."""
        on_disk = {f[:-3] for f in os.listdir(self.root)
                   if f.endswith(".db") and not f.startswith("_")}
        with self._lock:
            manifest = {r[0] for r in self._manifest.execute(
                "SELECT name FROM tenants")}
            # a registered-but-never-written tenant has no file yet;
            # only count it missing if it is not live either
            self._orphans = sorted(on_disk - manifest)
            self._missing = sorted(m for m in manifest - on_disk
                                   if m not in self._live)
            return {"orphans": list(self._orphans),
                    "missing": list(self._missing)}

    def close(self, name: Optional[str] = None):
        """Close one tenant (spill it), or -- with no name -- stop the
        maintenance daemon and spill every live tenant."""
        if name is not None:
            with self._lock:
                if name in self._live:
                    self._spill(name)
            return
        self.scheduler.stop()
        with self._lock:
            for n in list(self._live):
                self._spill(n)
            self._manifest.close()
            self._closed = True

    def __enter__(self) -> "Fleet":
        return self

    def __exit__(self, exc_type, exc, tb):
        self.close()
        return False

    # -- convenience ---------------------------------------------------------
    def query(self, name: str, vecs, spec=None, **kwargs):
        return self.get(name).query(vecs, spec, **kwargs)

    def tenants(self) -> List[str]:
        """Every tenant known to this fleet: the durable MANIFEST union
        the live handles -- not the filesystem listing (PR 10). An
        unregistered db file in the root is an orphan: visible in
        `recover()` / `health()`, not in the directory."""
        with self._lock:
            rows = {r[0] for r in self._manifest.execute(
                "SELECT name FROM tenants")}
            return sorted(rows | set(self._live))

    def live_tenants(self) -> List[str]:
        with self._lock:
            return list(self._live)

    # -- maintenance ---------------------------------------------------------
    def start_maintenance(self):
        self.scheduler.start()

    def stop_maintenance(self):
        self.scheduler.stop()

    def maintain(self, until_idle: bool = True) -> int:
        """Foreground maintenance: one deficit round (or rounds until
        every tenant idles)."""
        if until_idle:
            return self.scheduler.drain()
        return self.scheduler.step_round()

    # -- observability -------------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            live = list(self._live)
        return {"budget_bytes": self.pool.budget_bytes,
                "resident_bytes": self.pool.resident_bytes,
                "capacity_frames": self.pool.capacity,
                "live_tenants": live,
                "tenant_opens": self._c_opens.value,
                "tenant_spills": self._c_spills.value,
                "daemon_alive": self.scheduler.alive,
                "pool": self.pool.stats()}

    # -- SLO layer (PR 10) ---------------------------------------------------
    def set_slo(self, name: str, *, p99_ms: float,
                target: float = 0.99) -> TenantSLO:
        """Override the latency objective for one tenant."""
        slo = TenantSLO(p99_ms=p99_ms, target=target)
        with self._lock:
            self._slos[name] = slo
        return slo

    def slo_for(self, name: str) -> TenantSLO:
        with self._lock:
            return self._slos.get(name, self.default_slo)

    def _tenant_health(self, name: str) -> dict:
        """One tenant's SLO verdict from its cumulative query-latency
        histogram (engine scope `component=engine, tenant=<name>` --
        the series survives spills, so burn is over the tenant's whole
        history, not its current handle)."""
        slo = self.slo_for(name)
        h = obs_metrics.default_registry().histogram(
            "query_s", component="engine", tenant=name)
        n = h.count
        observed = h.fraction_above(slo.p99_ms / 1e3)
        allowed = 1.0 - slo.target
        burn = observed / allowed if allowed > 0 else float("inf")
        return {"verdict": "ok" if (n == 0 or burn <= 1.0)
                else "degraded",
                "queries": n,
                "p99_ms": h.quantile(0.99) * 1e3,
                "objective_ms": slo.p99_ms,
                "target": slo.target,
                "violation_fraction": observed,
                "burn_rate": burn}

    def health(self) -> dict:
        """Structured fleet health (the /healthz document): per-tenant
        SLO verdicts + error-budget burn, pool pressure, maintenance
        daemon liveness, the top noisy neighbors from the eviction
        matrix, and the manifest/disk drift from recover(). Takes only
        the fleet lock briefly for directory state -- never an engine
        lock, so a health probe cannot stall queries or writers."""
        drift = self.recover()
        names = self.tenants()
        tenants = {n: self._tenant_health(n) for n in names}
        degraded = sorted(n for n, t in tenants.items()
                          if t["verdict"] != "ok")
        budget = self.pool.budget_bytes
        resident = self.pool.resident_bytes
        return {"schema": 1,
                "status": "degraded" if degraded else "ok",
                "tenants": tenants,
                "degraded": degraded,
                "pool": {"budget_bytes": budget,
                         "resident_bytes": resident,
                         "pressure": resident / budget if budget else 0.0},
                "daemon_alive": self.scheduler.alive,
                "live_tenants": self.live_tenants(),
                "noisy_neighbors": self.pool.top_evictors(5),
                "manifest": drift}
