"""Process-global frame pool: ONE budget-bounded buffer pool of
partition frames shared by MANY tenants (PR 9's fleet mode).

PR 6's `storage.pager.PartitionCache` owned its pool outright: one
engine, one budget, one frame table. The server-side mirror of the
paper's per-device story -- thousands of per-user indexes in one
process -- needs the opposite ownership: the POOL is the process-wide
singleton and each engine holds only a *view* into it. This module is
that pool, extracted from the pager with one key change: the frame
table is keyed by `(tenant, pid)` instead of `pid`.

Everything else is the PR 6 design, globalized:

  * F frames are preallocated up front from the byte budget (the pool
    never grows, so FLEET-wide resident bytes <= the budget by
    construction -- no per-tenant quota tuning can violate it);
  * eviction is one global CLOCK sweep across all tenants' frames: a
    hot tenant's frames keep their reference bits refreshed and stay
    resident, a cold tenant's frames go cold and get reclaimed --
    tenant working sets size themselves to the traffic, which is the
    whole point over naive equal-split per-tenant pools;
  * the scan-resistant admission ring is likewise global: ONE tenant's
    one-off exact scan is capped at `scan_frames` frames and cannot
    flush any tenant's hot working set;
  * pins are per-frame with per-tenant accounting (`pinned_count`), so
    a fleet can report who holds what and tests can bound each
    tenant's footprint;
  * read-ahead staging blocks are keyed `(tenant, pid)` with the same
    generation counter, so one tenant's invalidation storm drops only
    advisory state.

One pool = one frame GEOMETRY. Every registered tenant must share the
payload dtype, vector dim, and attr width (a fleet of same-embedding
per-user stores, the common production shape); `p_max` is unified to
the largest registered tenant via the ordinary resize path (which
drops all frames -- resident state is a cache, correctness is
unaffected). Heterogeneous fleets run one pool per geometry.

Eviction policy NEVER changes results -- a fault re-reads the durable
tier -- so a tenant's answers through a shared pool are bit-identical
to the same engine running solo (asserted by tests/test_fleet.py and
gated by benchmarks/bench_fleet.py).

The per-tenant view (fault/stage/unpin/invalidate + counters) remains
`storage.pager.PartitionCache`; in solo mode it simply constructs a
private single-tenant FramePool, so a standalone engine's behavior --
down to the donated-scatter aliasing and the hit/miss counting order
pinned by tests/test_pager.py -- is unchanged.
"""
from __future__ import annotations

import itertools
import threading
import time
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.types import INVALID_ID
from ..obs import metrics as obs_metrics


@partial(jax.jit, donate_argnums=(0, 1, 2))
def _scatter_frames(payload_pool, ids_pool, valid_pool, fidx, payload,
                    ids, valid):
    """Donated in-place scatter of freshly fetched frames into the pool:
    the three pool buffers are aliased input->output, so the update costs
    O(fetched frames) writes, not a pool-sized copy."""
    return (payload_pool.at[fidx].set(payload),
            ids_pool.at[fidx].set(ids),
            valid_pool.at[fidx].set(valid))


@partial(jax.jit, donate_argnums=(0,))
def _scatter_one(pool, fidx, block):
    """Donated single-pool scatter (the optional attrs pool)."""
    return pool.at[fidx].set(block)


def compute_frame_bytes(p_max: int, dim: int, payload: str = "f32",
                        n_attr: int = 0) -> int:
    """Bytes one partition frame costs: payload + ids + valid + attrs."""
    per_row = (1 if payload == "int8" else 4) * dim + 4 + 1 + 4 * n_attr
    return p_max * per_row


class FramePool:
    """Budget-bounded pool of partition frames shared across tenants.

    Tenants are `storage.pager.PartitionCache` views registered via
    `register(view, name, p_max)`; the view supplies the per-tenant
    fetch path (`_fetch_blocks` over ITS VectorStore, with its metric
    normalisation and quantizer stats) and the per-tenant cumulative
    counters; the pool owns frames, eviction, pins, and staging.
    """

    def __init__(self, *, dim: int, p_max: int, budget_bytes: int,
                 payload: str = "f32", n_attr: int = 0):
        assert payload in ("f32", "int8"), payload
        self.dim = int(dim)
        self.payload = payload
        self.n_attr = int(n_attr)
        self.budget_bytes = int(budget_bytes)
        # guards every public method: tenants' query threads, the fleet
        # maintenance daemon, and prefetch threads all interleave here
        self._lock = threading.RLock()
        # tenant bookkeeping: name -> stable integer tid (stable across
        # re-registration, so a rebuilt engine keeps its identity), tid
        # -> live view, and per-tenant pin / resident-frame accounting
        self._tid_by_name: Dict[str, int] = {}
        self._name_by_tid: Dict[int, str] = {}
        self._tenants: Dict[int, object] = {}
        self._tids = itertools.count()
        self._t_pins: Dict[int, int] = {}
        self._t_resident: Dict[int, int] = {}
        # noisy-neighbor attribution (PR 10): every CLOCK eviction
        # charges (victim, evictor). Host-side matrix bounded at
        # `attr_max_pairs` distinct pairs (overflow folds into one
        # bucket); the registry series behind it ride the per-name LRU
        # cardinality guard, so 1000 synthetic tenants cannot grow the
        # registry without bound (pinned by tests/test_flight.py)
        self.attr_max_pairs = 4096
        self._evict_pairs: Dict[Tuple[int, int], int] = {}
        self._evict_pair_counters: Dict[Tuple[int, int], object] = {}
        self._evict_overflow = 0
        self._metrics = obs_metrics.default_registry().scope(
            component="frame_pool", inst=obs_metrics.next_instance())
        self._alloc(p_max)

    # -- registration --------------------------------------------------------
    def register(self, view, name: str, p_max: int) -> int:
        """Attach a tenant view; returns its tid. One pool = one frame
        geometry: payload dtype / dim / attr width must match; a larger
        p_max grows the pool for everyone (dropping all frames, like any
        resize). Re-registering a name (a paged rebuild re-attaching)
        drops the old view's frames and rebinds the tid."""
        assert view.payload == self.payload, \
            f"pool holds {self.payload} frames, tenant {name!r} wants " \
            f"{view.payload}"
        assert view.store.dim == self.dim, \
            f"pool geometry is dim={self.dim}, tenant {name!r} has " \
            f"dim={view.store.dim}"
        n_attr = view.store.n_attr if view.with_attrs else 0
        assert n_attr == self.n_attr, \
            f"pool geometry is n_attr={self.n_attr}, tenant {name!r} " \
            f"has n_attr={n_attr}"
        with self._lock:
            tid = self._tid_by_name.get(name)
            if tid is None:
                tid = next(self._tids)
                self._tid_by_name[name] = tid
                self._name_by_tid[tid] = name
            else:
                # re-attachment: the old view's frames describe an index
                # generation that no longer exists
                self._invalidate_tenant_locked(tid)
            self._tenants[tid] = view
            self._t_pins.setdefault(tid, 0)
            self._t_resident.setdefault(tid, 0)
        if p_max > self.p_max:
            self.resize(p_max)
        return tid

    # -- pool allocation ----------------------------------------------------
    def _alloc(self, p_max: int):
        # validate before mutating any state: a failed resize must leave
        # the pool fully usable at its old geometry
        frame_bytes = compute_frame_bytes(p_max, self.dim, self.payload,
                                          self.n_attr)
        cap = self.budget_bytes // frame_bytes
        if cap < 1:
            raise ValueError(
                f"memory budget {self.budget_bytes}B cannot seat one "
                f"partition frame ({frame_bytes}B at p_max={p_max})")
        self.p_max = int(p_max)
        self.frame_bytes = frame_bytes
        self.capacity = int(cap)
        shape = (self.capacity, self.p_max, self.dim)
        if self.payload == "int8":
            self.payload_pool = jnp.zeros(shape, jnp.int8)
        else:
            self.payload_pool = jnp.zeros(shape, jnp.float32)
        self.ids_pool = jnp.full((self.capacity, self.p_max), INVALID_ID,
                                 jnp.int32)
        self.valid_pool = jnp.zeros((self.capacity, self.p_max), bool)
        self.attrs_pool = (
            jnp.zeros((self.capacity, self.p_max, self.n_attr), jnp.float32)
            if self.n_attr else None)
        # host-side frame table (frame -> (tenant, partition) indirection)
        self._frame_pid = np.full(self.capacity, -1, np.int64)
        self._frame_tid = np.full(self.capacity, -1, np.int64)
        self._key_frame: Dict[Tuple[int, int], int] = {}
        self._ref = np.zeros(self.capacity, bool)
        self._pins = np.zeros(self.capacity, np.int64)
        # invalidated-while-pinned frames: freed at the last unpin
        self._stale = np.zeros(self.capacity, bool)
        self._hand = 0
        # scan-resistant admission: ring of frames owned by non-admitted
        # (one-off stream) faults; scan_frames bounds how much of the
        # pool a full scan may dirty
        self.scan_frames = max(1, self.capacity // 4)
        self._transient = np.zeros(self.capacity, bool)
        self._ring: List[int] = []
        self._ring_hand = 0
        # read-ahead staging: (tid, pid) -> (payload, ids, valid, attrs)
        # host blocks prefetched by stage(); the generation counter lets
        # invalidate()/resize() discard stages still in flight
        self._staged: Dict[Tuple[int, int], tuple] = {}
        self._stage_gen = getattr(self, "_stage_gen", 0) + 1
        for tid in self._t_resident:
            self._t_resident[tid] = 0

    def resize(self, p_max: int):
        """Reallocate the pool for a larger partition size. Drops every
        tenant's frames -- resident state is a cache -- but keeps the
        byte budget and each tenant's cumulative counters. Waits for
        in-flight scans (any tenant) to unpin first: _alloc rebuilds the
        pin table (and may shrink the frame count), so reallocating
        under a live pin would corrupt a concurrent scan's unpin
        bookkeeping."""
        deadline = time.monotonic() + 30.0
        while True:
            with self._lock:
                if not self._pins.any():
                    self._alloc(p_max)
                    return
            if time.monotonic() > deadline:
                raise RuntimeError(
                    "resize timed out waiting for pinned frames -- a scan "
                    "leaked a pin (missing unpin())")
            time.sleep(0.001)

    # -- budget accounting ---------------------------------------------------
    @property
    def resident_bytes(self) -> int:
        pools = [self.payload_pool, self.ids_pool, self.valid_pool]
        if self.attrs_pool is not None:
            pools.append(self.attrs_pool)
        return int(sum(p.nbytes for p in pools))

    def resident_count(self, tid: int) -> int:
        with self._lock:
            return self._t_resident.get(tid, 0)

    def pinned_count(self, tid: int) -> int:
        with self._lock:
            return self._t_pins.get(tid, 0)

    def _note_eviction(self, victim_tid: int, evictor_tid: int):
        """Charge one CLOCK eviction to (victim, evictor). Called with
        the pool lock held, on the fault MISS path only (an eviction is
        followed by an SQL fetch, so the registry get-or-create on a
        pair's first sighting is noise). Both the host matrix and the
        registry counters are cardinality-bounded: the matrix folds
        pairs past `attr_max_pairs` into one overflow bucket, and the
        registry applies its per-name LRU series guard."""
        key = (victim_tid, evictor_tid)
        n = self._evict_pairs.get(key)
        if n is None and len(self._evict_pairs) >= self.attr_max_pairs:
            self._evict_overflow += 1
            return
        self._evict_pairs[key] = 1 if n is None else n + 1
        c = self._evict_pair_counters.get(key)
        if c is None:
            c = self._metrics.counter(
                "evictions_attributed",
                victim=self._name_by_tid.get(victim_tid, str(victim_tid)),
                evictor=self._name_by_tid.get(evictor_tid,
                                              str(evictor_tid)))
            self._evict_pair_counters[key] = c
        c.inc()

    def eviction_matrix(self) -> Dict[str, Dict[str, int]]:
        """victim name -> {evictor name -> evictions} (host matrix)."""
        with self._lock:
            out: Dict[str, Dict[str, int]] = {}
            for (vt, et), n in self._evict_pairs.items():
                v = self._name_by_tid.get(vt, str(vt))
                e = self._name_by_tid.get(et, str(et))
                out.setdefault(v, {})[e] = n
            return out

    def top_evictors(self, n: int = 5) -> List[dict]:
        """The heaviest (evictor, victim) pairs -- the noisy-neighbor
        shortlist Fleet.health() surfaces."""
        with self._lock:
            pairs = sorted(self._evict_pairs.items(),
                           key=lambda kv: -kv[1])[:max(n, 0)]
            return [{"evictor": self._name_by_tid.get(et, str(et)),
                     "victim": self._name_by_tid.get(vt, str(vt)),
                     "evictions": c}
                    for (vt, et), c in pairs]

    def stats(self) -> dict:
        """Fleet-wide pool view: geometry + per-tenant frame footprint
        + the noisy-neighbor eviction matrix."""
        with self._lock:
            by_name = {}
            for name, tid in self._tid_by_name.items():
                by_name[name] = {"resident_frames": self._t_resident
                                 .get(tid, 0),
                                 "pinned_frames": self._t_pins.get(tid, 0)}
            matrix: Dict[str, Dict[str, int]] = {}
            for (vt, et), n in self._evict_pairs.items():
                v = self._name_by_tid.get(vt, str(vt))
                e = self._name_by_tid.get(et, str(et))
                matrix.setdefault(v, {})[e] = n
            return {"budget_bytes": self.budget_bytes,
                    "resident_bytes": self.resident_bytes,
                    "capacity_frames": self.capacity,
                    "frame_bytes": self.frame_bytes,
                    "p_max": self.p_max,
                    "resident_partitions": len(self._key_frame),
                    "tenants": by_name,
                    "eviction_matrix": matrix,
                    "eviction_matrix_overflow": self._evict_overflow}

    # -- clock eviction ------------------------------------------------------
    def _release_ring(self, f: int):
        """Remove a frame from the scan ring (promotion or reclaim)."""
        self._transient[f] = False
        if f in self._ring:
            self._ring.remove(f)
            self._ring_hand = 0

    def _clock_victim(self) -> int:
        """Second-chance sweep across ALL tenants' frames: skip pinned
        frames, clear reference bits, reclaim the first cold unpinned
        frame (transient scan-ring frames carry no reference bit, so
        they fall out first)."""
        for _ in range(3 * self.capacity):
            f = self._hand
            self._hand = (self._hand + 1) % self.capacity
            if self._pins[f] > 0:
                continue
            if self._ref[f] and not self._transient[f]:
                self._ref[f] = False
                continue
            if self._transient[f]:
                self._release_ring(f)
            return f
        raise RuntimeError(
            "all cache frames pinned -- probe chunk exceeds pool capacity")

    def _victim(self) -> int:
        """Victim for an *admitted* fault: scan-ring frames first (a
        one-off stream must never force out hot admitted frames), then
        the CLOCK sweep."""
        for f in self._ring:
            if self._pins[f] == 0:
                self._release_ring(f)
                return f
        return self._clock_victim()

    def _scan_victim(self) -> int:
        """Victim for a NON-admitted (scan-resistant) fault: reuse ring
        frames round-robin; grow the ring (via the normal sweep) only up
        to scan_frames."""
        for _ in range(len(self._ring)):
            f = self._ring[self._ring_hand % len(self._ring)]
            self._ring_hand += 1
            if self._pins[f] == 0:
                return f
        if len(self._ring) < self.scan_frames:
            f = self._clock_victim()
            self._ring.append(f)
            self._transient[f] = True
            return f
        raise RuntimeError(
            "scan ring exhausted -- chunk a non-admitted scan to at most "
            f"scan_frames={self.scan_frames} missing partitions")

    # -- staging -------------------------------------------------------------
    def stage(self, tid: int, pids: Sequence[int]):
        """Read ahead for one tenant: fetch + pack the listed partitions'
        blocks into the host-side staging dict so the tenant's next
        fault() skips its SQL round-trip. Takes no frames and no pins,
        and never rebinds a pool -- safe on a prefetch thread while any
        tenant scans. Advisory only: a concurrent invalidate() bumps the
        generation and the whole in-flight stage is discarded."""
        view = self._tenants[tid]
        with self._lock:
            gen = self._stage_gen
            want = [int(p) for p in pids
                    if (tid, int(p)) not in self._key_frame
                    and (tid, int(p)) not in self._staged]
        if not want:
            return
        payload, ids, valid, attrs = view._fetch_blocks(want)
        view._c_bytes_staged.inc(
            payload.nbytes + ids.nbytes + valid.nbytes +
            (0 if attrs is None else attrs.nbytes))
        with self._lock:
            if gen != self._stage_gen:
                return          # a writer invalidated mid-fetch: drop all
            # bound leftover entries (a scan that raised mid-stream never
            # consumes its staged chunk) -- the dict may never outgrow a
            # few chunks of host blocks
            if len(self._staged) > 2 * self.capacity:
                self._staged.clear()
            for i, p in enumerate(want):
                if (tid, p) in self._key_frame:  # faulted while we fetched
                    continue
                self._staged[(tid, p)] = (payload[i], ids[i], valid[i],
                                          None if attrs is None
                                          else attrs[i])

    # -- fault / pin / invalidate -------------------------------------------
    def fault(self, tid: int, pids: Sequence[int],
              admit: bool = True) -> np.ndarray:
        with self._lock:
            return self._fault_locked(tid, pids, admit)

    def _fault_locked(self, tid: int, pids: Sequence[int],
                      admit: bool) -> np.ndarray:
        view = self._tenants[tid]
        # pins held by in-flight scans (ANY tenant) at entry decide
        # whether the scatter may donate the pool buffers: donation
        # invalidates the old arrays, which a concurrent scan -- no
        # matter whose -- may still be reading
        foreign_pins = int(self._pins.sum())
        want = [int(p) for p in pids]
        if len(want) > self.capacity:
            raise ValueError(
                f"probe set of {len(want)} partitions exceeds the pool's "
                f"{self.capacity} frames -- chunk the scan")
        frames = np.empty(len(want), np.int32)
        missing = []
        hit_frames = []
        for j, p in enumerate(want):
            f = self._key_frame.get((tid, p))
            if f is not None:
                if admit:
                    self._ref[f] = True
                    if self._transient[f]:
                        # an admitted hit proves the frame hot: promote
                        # it out of the scan ring into the admitted set
                        self._release_ring(f)
                self._pins[f] += 1
                self._t_pins[tid] += 1
                frames[j] = f
                hit_frames.append(f)
            else:
                missing.append((j, p))
        if hit_frames:
            view._c_hits.inc(len(hit_frames))
        if not missing:
            view._last_fault = (len(hit_frames), 0, 0, 0)
            return frames
        new_frames = []
        n_evicted = 0
        for j, p in missing:
            f = self._victim() if admit else self._scan_victim()
            old_pid = int(self._frame_pid[f])
            if old_pid >= 0:
                old_tid = int(self._frame_tid[f])
                del self._key_frame[(old_tid, old_pid)]
                self._t_resident[old_tid] -= 1
                n_evicted += 1
                self._note_eviction(old_tid, tid)
            self._frame_pid[f] = p
            self._frame_tid[f] = tid
            self._key_frame[(tid, p)] = f
            self._t_resident[tid] += 1
            self._ref[f] = admit
            self._pins[f] += 1
            self._t_pins[tid] += 1
            frames[j] = f
            new_frames.append(f)
        # counted BEFORE the fetch: a failed fetch still paid the miss
        # (and already evicted its victims) -- pinned by tests/test_pager
        view._c_misses.inc(len(missing))
        if n_evicted:
            view._c_evictions.inc(n_evicted)
        n_bytes = 0
        try:
            # consume staged read-ahead blocks first; anything not staged
            # is fetched in one batched SQL round-trip as before
            staged = {p: self._staged.pop((tid, p))
                      for _, p in missing if (tid, p) in self._staged}
            n_staged = len(staged)
            if n_staged:
                view._c_staged_consumed.inc(n_staged)
            fetch = [p for _, p in missing if p not in staged]
            if fetch:
                f_pay, f_ids, f_val, f_att = view._fetch_blocks(fetch)
                n_bytes = f_pay.nbytes + f_ids.nbytes + f_val.nbytes + \
                    (0 if f_att is None else f_att.nbytes)
                view._c_bytes_read.inc(n_bytes)
                for i, p in enumerate(fetch):
                    staged[p] = (f_pay[i], f_ids[i], f_val[i],
                                 None if f_att is None else f_att[i])
            order = [staged[p] for _, p in missing]
            payload = jnp.asarray(np.stack([e[0] for e in order]))
            bids = jnp.asarray(np.stack([e[1] for e in order]))
            bval = jnp.asarray(np.stack([e[2] for e in order]))
            battrs = None if self.attrs_pool is None else \
                jnp.asarray(np.stack([e[3] for e in order]))
            fidx = jnp.asarray(np.asarray(new_frames, np.int32))
            if foreign_pins == 0:
                # no concurrent scan can be reading the old pool objects:
                # donate them -- the scatter updates the buffers in place
                # instead of writing a second pool-sized copy
                self.payload_pool, self.ids_pool, self.valid_pool = \
                    _scatter_frames(self.payload_pool, self.ids_pool,
                                    self.valid_pool, fidx, payload,
                                    bids, bval)
                if self.attrs_pool is not None:
                    self.attrs_pool = _scatter_one(
                        self.attrs_pool, fidx, battrs)
            else:
                # a scan may still hold the old arrays: copy-on-write
                self.payload_pool = self.payload_pool.at[fidx].set(payload)
                self.ids_pool = self.ids_pool.at[fidx].set(bids)
                self.valid_pool = self.valid_pool.at[fidx].set(bval)
                if self.attrs_pool is not None:
                    self.attrs_pool = self.attrs_pool.at[fidx].set(battrs)
        except BaseException:
            # roll back the provisional registrations: the frames never
            # received data, so a later fault must not count them as hits
            # (and their pins must not leak until _victim starves); hit
            # pins are released too -- the caller gets no frames to unpin
            for (j, p), f in zip(missing, new_frames):
                if self._key_frame.pop((tid, p), None) is not None:
                    self._t_resident[tid] -= 1
                self._frame_pid[f] = -1
                self._frame_tid[f] = -1
                self._ref[f] = False
                self._pins[f] -= 1
                self._t_pins[tid] -= 1
            for f in hit_frames:
                self._pins[f] -= 1
                self._t_pins[tid] -= 1
            raise
        view._last_fault = (len(hit_frames), len(missing), n_staged,
                            n_bytes)
        return frames

    def _free_frame(self, f: int):
        self._frame_pid[f] = -1
        self._frame_tid[f] = -1
        self._ref[f] = False
        self._stale[f] = False

    def unpin(self, frames: np.ndarray):
        with self._lock:
            for f in np.asarray(frames, np.int64):
                assert self._pins[f] > 0, f"frame {f} not pinned"
                self._pins[f] -= 1
                tid = int(self._frame_tid[f])
                if tid >= 0:
                    self._t_pins[tid] -= 1
                if self._pins[f] == 0 and self._stale[f]:
                    # invalidated while this scan was reading it: the
                    # deferred release happens at the last unpin
                    self._free_frame(f)

    def invalidate(self, tid: int, pids: Sequence[int]):
        """Drop one tenant's listed frames (durable rows changed); the
        next fault re-reads them from SQLite. A frame pinned by an
        in-flight scan is released lazily at its last unpin -- the scan
        keeps its pre-invalidation snapshot, the mapping is gone at
        once."""
        with self._lock:
            # discard staged read-ahead for the changed partitions, and
            # bump the generation so an in-flight stage() that read them
            # mid-write drops its whole batch instead of inserting
            self._stage_gen += 1
            for p in pids:
                self._staged.pop((tid, int(p)), None)
                f = self._key_frame.pop((tid, int(p)), None)
                if f is None:
                    continue
                self._t_resident[tid] -= 1
                if self._pins[f] > 0:
                    self._stale[f] = True
                    continue
                self._free_frame(f)

    def _invalidate_tenant_locked(self, tid: int):
        self.invalidate(tid, [p for (t, p) in list(self._key_frame)
                              if t == tid])
        self._staged = {k: v for k, v in self._staged.items()
                        if k[0] != tid}

    def invalidate_tenant(self, tid: int):
        """Drop every frame and staged block a tenant holds (rebuild,
        spill, or close)."""
        with self._lock:
            self._invalidate_tenant_locked(tid)

    # -- per-tenant views ----------------------------------------------------
    def tenant_frames(self, tid: int) -> Dict[int, int]:
        """pid -> frame mapping for one tenant (test/introspection view;
        the hot path uses the keyed dict directly)."""
        with self._lock:
            return {p: f for (t, p), f in self._key_frame.items()
                    if t == tid}

    def tenant_staged(self, tid: int) -> Dict[int, tuple]:
        with self._lock:
            return {p: v for (t, p), v in self._staged.items()
                    if t == tid}
