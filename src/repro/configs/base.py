"""Architecture + shape + index configuration schema.

Every assigned architecture is an instance of `ModelConfig`; the four
assigned input shapes are `ShapeConfig`s. Configs are frozen/hashable so
they can ride along as jit static args.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # Per-layer block kinds, cycled: attn | local | rglru | mlstm | slstm.
    pattern: Tuple[str, ...] = ("attn",)
    window: int = 0                # local attention window
    rope_theta: float = 10000.0
    pos_kind: str = "rope"         # rope | learned
    max_position: int = 0          # learned positions table size
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    mlp_act: str = "silu_glu"      # silu_glu | gelu_glu | gelu
    post_norm: bool = False        # gemma2-style extra post-norms
    logit_softcap: float = 0.0
    attn_softcap: float = 0.0
    attn_bias: bool = False
    q_scale: Optional[float] = None  # gemma2 query_pre_attn_scalar^-0.5
    tie_embeddings: bool = False
    emb_scale: bool = False        # multiply embeddings by sqrt(d_model)
    # MoE
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    # recurrent / ssm
    d_rnn: int = 0
    conv_width: int = 4
    mlstm_proj_factor: float = 2.0
    mlstm_chunk: int = 256
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    enc_seq: int = 0               # stub frontend: precomputed frames
    # vlm (pixtral): stub frontend provides patch embeddings
    num_img_tokens: int = 0
    # runtime
    scan_layers: bool = False
    remat: bool = True
    dtype: str = "bfloat16"

    # ---- derived -----------------------------------------------------
    def layer_kinds(self) -> Tuple[str, ...]:
        reps = -(-self.num_layers // len(self.pattern))
        return (self.pattern * reps)[: self.num_layers]

    @property
    def stack_period(self) -> Tuple[str, ...]:
        """Kinds of one stacked period; stack count = L // len(period).
        Layers beyond count*period form the unrolled `tail` (e.g.
        recurrentgemma's 26 = 8 x (rglru, rglru, local) + (rglru, rglru))."""
        return self.pattern

    @property
    def stack_count(self) -> int:
        return self.num_layers // len(self.pattern)

    @property
    def tail_kinds(self) -> Tuple[str, ...]:
        return self.layer_kinds()[self.stack_count * len(self.pattern):]

    @property
    def supports_long_context(self) -> bool:
        """True iff no layer needs a full-sequence KV cache (sub-quadratic)."""
        return all(k != "attn" for k in self.layer_kinds()) \
            and self.encoder_layers == 0

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has a decode path

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        n = v * d                                   # embedding
        if not self.tie_embeddings:
            n += v * d
        if self.pos_kind == "learned":
            n += self.max_position * d
        attn = d * self.num_heads * self.head_dim \
            + 2 * d * self.num_kv_heads * self.head_dim \
            + self.num_heads * self.head_dim * d
        glu = 3 if self.mlp_act.endswith("_glu") else 2
        mlp = glu * d * f
        moe_ = self.n_experts * glu * d * f + d * self.n_experts
        d_rnn = self.d_rnn or d
        rglru = 2 * d * d_rnn + 2 * d_rnn * d_rnn + d_rnn * d \
            + self.conv_width * d_rnn
        di = int(d * self.mlstm_proj_factor)
        mlstm = 2 * d * di + 3 * di * di // max(1, self.num_heads) * \
            self.num_heads + di * d   # approx: q,k,v are di x hd x H = di*di
        mlstm = 2 * d * di + 3 * di * (di // max(1, self.num_heads)) * \
            self.num_heads + di * d
        hd = d // max(1, self.num_heads)
        slstm = 4 * (d * d + self.num_heads * hd * hd) \
            + 3 * d * int(d * 4 / 3)
        for kind in self.layer_kinds():
            if kind in ("attn", "local"):
                n += attn + (moe_ if self.n_experts else mlp)
            elif kind == "rglru":
                n += rglru + mlp
            elif kind == "mlstm":
                n += mlstm
            elif kind == "slstm":
                n += slstm
        if self.encoder_layers:
            n += self.encoder_layers * (attn + mlp) + self.enc_seq * d
        return int(n)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        glu = 3 if self.mlp_act.endswith("_glu") else 2
        dense_share = self.param_count() - \
            self.num_layers * (self.n_experts * glu * d * f)
        return int(dense_share + self.num_layers * self.top_k * glu * d * f)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                      # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                      # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Skip rules from the assignment (recorded in EXPERIMENTS.md)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "full attention layers: O(S) KV cache at 500k infeasible"
    return True, ""
