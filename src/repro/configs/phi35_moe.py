"""phi3.5-moe-42b-a6.6b [moe] -- 16 experts top-2
[hf:microsoft/Phi-3.5-MoE-instruct; hf].

32L d_model=4096 32H (GQA kv=8) head_dim=128 d_ff=6400 vocab=32064,
MoE 16e top-2, SwiGLU experts, RMSNorm. 16 experts shard 1:1 over the
16-way model axis (expert parallelism).
"""
from .base import ModelConfig
from .registry import ArchSpec

ARCH = ArchSpec(
    config=ModelConfig(
        name="phi3.5-moe-42b-a6.6b",
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=6400,
        vocab_size=32064,
        pattern=("attn",),
        mlp_act="silu_glu",
        norm="rmsnorm",
        n_experts=16,
        top_k=2,
        rope_theta=10000.0,
        tie_embeddings=False,
    ),
    fsdp=True,
    shard_experts=True,
)
