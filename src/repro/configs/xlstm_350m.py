"""xlstm-350m [ssm] -- sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

24L d_model=1024 4H d_ff=0 (blocks carry their own projections)
vocab=50304. Pattern mLSTM:sLSTM = 7:1 (xLSTM[7:1]); mLSTM uses the
chunkwise-parallel linear-time form, giving O(1)-in-seq decode state ->
runs long_500k.
"""
from .base import ModelConfig
from .registry import ArchSpec

ARCH = ArchSpec(
    config=ModelConfig(
        name="xlstm-350m",
        family="ssm",
        num_layers=24,
        d_model=1024,
        num_heads=4,
        num_kv_heads=4,
        head_dim=256,
        d_ff=0,
        vocab_size=50304,
        pattern=("mlstm",) * 7 + ("slstm",),
        mlstm_proj_factor=2.0,
        mlstm_chunk=256,
        norm="layernorm",
        tie_embeddings=True,
    ),
)
