"""Reduced configs of the same family for CPU smoke tests.

Every reduction keeps the structural character of the arch (pattern,
GQA grouping, MoE routing, enc-dec, modality stubs) while shrinking
width/depth/vocab so one forward/train step runs on a single CPU device.
"""
from __future__ import annotations

import dataclasses

from .base import ModelConfig, ShapeConfig


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    period = len(cfg.pattern)
    # keep >= one full pattern period, at most two
    layers = period if cfg.num_layers % period == 0 else cfg.num_layers
    layers = min(layers, 2 * period) if cfg.num_layers % period == 0 \
        else min(cfg.num_layers, 4)
    heads = min(4, cfg.num_heads)
    kv = max(1, min(cfg.num_kv_heads, heads))
    while heads % kv:
        kv -= 1
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=layers,
        d_model=128,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=32,
        d_ff=0 if cfg.d_ff == 0 else 256,
        vocab_size=512,
        window=8 if cfg.window else 0,
        d_rnn=128 if cfg.d_rnn else 0,
        n_experts=min(4, cfg.n_experts) if cfg.n_experts else 0,
        encoder_layers=2 if cfg.encoder_layers else 0,
        enc_seq=16 if cfg.enc_seq else 0,
        num_img_tokens=8 if cfg.num_img_tokens else 0,
        max_position=128 if cfg.pos_kind == "learned" else 0,
        mlstm_chunk=8,
        remat=False,
    )


SMOKE_SHAPE = ShapeConfig("smoke", "train", 32, 2)
SMOKE_DECODE = ShapeConfig("smoke_decode", "decode", 32, 2)
