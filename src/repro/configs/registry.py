"""Architecture registry: --arch <id> -> (ModelConfig, parallelism prefs).

Every assigned architecture from the public pool, with its exact geometry.
`[source; tier]` per the assignment; geometry notes inline.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional

from .base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    config: ModelConfig
    # parallelism preferences for the production mesh
    fsdp: bool = False             # shard "embed" over data (ZeRO-style)
    fsdp_over_pod: bool = False    # extend FSDP across the pod axis
    shard_experts: bool = True     # EP when experts divide the model axis
    sp: bool = True                # sequence-parallel residual activations
    microbatches: int = 1          # gradient-accumulation microbatches


_ARCH_MODULES = [
    "recurrentgemma_2b", "starcoder2_15b", "llama3_8b", "gemma2_27b",
    "minitron_4b", "phi35_moe", "grok1_314b", "pixtral_12b",
    "xlstm_350m", "whisper_medium",
]

_ALIASES = {
    "recurrentgemma-2b": "recurrentgemma_2b",
    "starcoder2-15b": "starcoder2_15b",
    "llama3-8b": "llama3_8b",
    "gemma2-27b": "gemma2_27b",
    "minitron-4b": "minitron_4b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "phi3.5-moe": "phi35_moe",
    "grok-1-314b": "grok1_314b",
    "pixtral-12b": "pixtral_12b",
    "xlstm-350m": "xlstm_350m",
    "whisper-medium": "whisper_medium",
}


def _load() -> Dict[str, ArchSpec]:
    out = {}
    for mod_name in _ARCH_MODULES:
        mod = importlib.import_module(f"repro.configs.{mod_name}")
        out[mod.ARCH.config.name] = mod.ARCH
    return out


_REGISTRY: Optional[Dict[str, ArchSpec]] = None


def registry() -> Dict[str, ArchSpec]:
    global _REGISTRY
    if _REGISTRY is None:
        _REGISTRY = _load()
    return _REGISTRY


def get_arch(name: str) -> ArchSpec:
    reg = registry()
    if name in reg:
        return reg[name]
    key = _ALIASES.get(name)
    if key:
        for spec in reg.values():
            if spec.config.name in (name,) or key in spec.config.name.replace(
                    "-", "_").replace(".", ""):
                return spec
        mod = importlib.import_module(f"repro.configs.{key}")
        return mod.ARCH
    raise KeyError(f"unknown arch {name!r}; known: {sorted(reg)}")


def arch_names():
    return sorted(registry().keys())
