"""starcoder2-15b [dense] -- GQA + RoPE code model [arXiv:2402.19173; hf].

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152, LayerNorm,
biased projections, plain-GELU MLP, rope_theta=1e5.
"""
from .base import ModelConfig
from .registry import ArchSpec

ARCH = ArchSpec(
    config=ModelConfig(
        name="starcoder2-15b",
        family="dense",
        num_layers=40,
        d_model=6144,
        num_heads=48,
        num_kv_heads=4,
        head_dim=128,
        d_ff=24576,
        vocab_size=49152,
        pattern=("attn",),
        mlp_act="gelu",
        norm="layernorm",
        attn_bias=True,
        rope_theta=100000.0,
        tie_embeddings=False,
    ),
    fsdp=True,
)
