"""gemma2-27b [dense] -- local/global alternating attention + logit
softcaps [arXiv:2408.00118; hf].

46L d_model=4608 32H (GQA kv=16) head_dim=128 d_ff=36864 vocab=256000,
window=4096 on local layers, attn softcap 50, final logit softcap 30,
pre+post RMSNorm, GeGLU, q_scale=(4608/32)^-0.5, tied+scaled embeddings.
"""
from .base import ModelConfig
from .registry import ArchSpec

ARCH = ArchSpec(
    config=ModelConfig(
        name="gemma2-27b",
        family="dense",
        num_layers=46,
        d_model=4608,
        num_heads=32,
        num_kv_heads=16,
        head_dim=128,
        d_ff=36864,
        vocab_size=256000,
        pattern=("local", "attn"),
        window=4096,
        mlp_act="gelu_glu",
        norm="rmsnorm",
        post_norm=True,
        attn_softcap=50.0,
        logit_softcap=30.0,
        q_scale=(4608 / 32) ** -0.5,
        rope_theta=10000.0,
        tie_embeddings=True,
        emb_scale=True,
    ),
    fsdp=True,
)
