"""llama3-8b [dense] -- GQA, 128k vocab [arXiv:2407.21783; unverified].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256, SwiGLU,
RMSNorm, rope_theta=5e5, untied embeddings.
"""
from .base import ModelConfig
from .registry import ArchSpec

ARCH = ArchSpec(
    config=ModelConfig(
        name="llama3-8b",
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=128256,
        pattern=("attn",),
        mlp_act="silu_glu",
        norm="rmsnorm",
        rope_theta=500000.0,
        tie_embeddings=False,
    ),
    fsdp=True,
)
