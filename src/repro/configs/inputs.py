"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, zero allocation -- the dry-run lowers
against these. Modality frontends are STUBS per the assignment: [vlm]
gets precomputed patch embeddings, [audio] gets precomputed frame
embeddings.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .base import ModelConfig, ShapeConfig


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Inputs for train/prefill steps (full-sequence forward)."""
    b, s = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {}
    if cfg.num_img_tokens:
        s_text = s - cfg.num_img_tokens
        out["tokens"] = _sds((b, s_text), jnp.int32)
        out["img"] = _sds((b, cfg.num_img_tokens, cfg.d_model), jnp.bfloat16)
    else:
        out["tokens"] = _sds((b, s), jnp.int32)
    if cfg.encoder_layers:
        out["frames"] = _sds((b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    return out


def decode_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Inputs for one serve_step: token + position + seq_len-sized cache."""
    from ..models import decode as decode_lib
    b, s = shape.global_batch, shape.seq_len
    return {
        "token": _sds((b, 1), jnp.int32),
        "pos": _sds((), jnp.int32),
        "cache": decode_lib.init_cache(cfg, b, s, abstract=True),
    }


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    if shape.kind == "decode":
        return decode_specs(cfg, shape)
    return batch_specs(cfg, shape)


def materialize(specs: Dict[str, Any], seed: int = 0) -> Dict[str, Any]:
    """Turn input specs into small real arrays (smoke tests / examples)."""
    key = jax.random.PRNGKey(seed)

    def mk(s):
        nonlocal key
        key, sub = jax.random.split(key)
        if s.dtype == jnp.int32 and len(s.shape) <= 2 and s.shape:
            return jax.random.randint(sub, s.shape, 0, 64).astype(jnp.int32)
        if s.dtype == jnp.int32:
            return jnp.zeros(s.shape, jnp.int32)
        if s.shape == ():
            return jnp.zeros((), s.dtype)
        return (jax.random.normal(sub, s.shape) * 0.1).astype(s.dtype)

    return jax.tree.map(mk, specs)
