"""pixtral-12b [vlm] -- pixtral-ViT + mistral-nemo backbone
[hf:mistralai/Pixtral-12B-2409; unverified].

40L d_model=5120 32H (GQA kv=8) head_dim=128 d_ff=14336 vocab=131072.
The vision frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings [B, 1024, d_model] prepended to the token
sequence (1D RoPE over the concatenated sequence -- a documented
simplification of pixtral's 2D rope).
"""
from .base import ModelConfig
from .registry import ArchSpec

ARCH = ArchSpec(
    config=ModelConfig(
        name="pixtral-12b",
        family="vlm",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=131072,
        pattern=("attn",),
        mlp_act="silu_glu",
        norm="rmsnorm",
        rope_theta=1000000.0,
        num_img_tokens=1024,
        tie_embeddings=False,
    ),
    fsdp=True,
)
