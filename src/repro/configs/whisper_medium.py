"""whisper-medium [audio] -- encoder-decoder, conv frontend STUB
[arXiv:2212.04356; unverified].

24 decoder layers (+24 encoder), d_model=1024 16H (kv=16) head_dim=64
d_ff=4096 vocab=51865, LayerNorm + biases, GELU MLP, learned positions.
The conv/mel frontend is a STUB per the assignment: input_specs()
provides precomputed frame embeddings [B, 1500, d_model]. Decoder
learned-position table is extended to the assigned decode shapes
(32768 >> whisper's native 448) so decode_32k is well-defined.
"""
from .base import ModelConfig
from .registry import ArchSpec

ARCH = ArchSpec(
    config=ModelConfig(
        name="whisper-medium",
        family="audio",
        num_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab_size=51865,
        pattern=("xattn",),
        mlp_act="gelu",
        norm="layernorm",
        attn_bias=True,
        pos_kind="learned",
        max_position=32768,
        encoder_layers=24,
        enc_seq=1500,
        tie_embeddings=True,
    ),
)
