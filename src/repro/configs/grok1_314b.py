"""grok-1-314b [moe] -- 8 experts top-2 [hf:xai-org/grok-1; unverified].

64L d_model=6144 48H (GQA kv=8) head_dim=128 d_ff=32768 vocab=131072,
MoE 8e top-2, attention logit cap 30 (tanh), tied embeddings.
E=8 < 16-way model axis -> experts replicate, "ff" shards inside each
expert (TP); params+optimizer shard over data AND pod (ZeRO-3 analogue)
so 314B fits 512 x 16 GB HBM.
"""
from .base import ModelConfig
from .registry import ArchSpec

ARCH = ArchSpec(
    config=ModelConfig(
        name="grok-1-314b",
        family="moe",
        num_layers=64,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=32768,
        vocab_size=131072,
        pattern=("attn",),
        mlp_act="gelu_glu",
        norm="rmsnorm",
        n_experts=8,
        top_k=2,
        attn_softcap=30.0,
        logit_softcap=30.0,
        rope_theta=10000.0,
        tie_embeddings=True,
        emb_scale=True,
    ),
    fsdp=True,
    fsdp_over_pod=True,
    shard_experts=False,
)
