from .base import ModelConfig, ShapeConfig, SHAPES, shape_applicable
from .registry import ArchSpec, arch_names, get_arch, registry

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "shape_applicable",
           "ArchSpec", "arch_names", "get_arch", "registry"]
