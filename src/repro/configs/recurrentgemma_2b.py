"""recurrentgemma-2b [hybrid] -- RG-LRU + local attention, 1 attn : 2
recurrent [arXiv:2402.19427; hf].

26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000, window=2048,
d_rnn=2560 (lru_width), GeGLU MLP, RMSNorm, tied + scaled embeddings.
Sub-quadratic (local attn windows + O(1) RNN state) -> runs long_500k.
"""
from .base import ModelConfig
from .registry import ArchSpec

ARCH = ArchSpec(
    config=ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        num_layers=26,
        d_model=2560,
        num_heads=10,
        num_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256000,
        pattern=("rglru", "rglru", "local"),
        window=2048,
        d_rnn=2560,
        conv_width=4,
        mlp_act="gelu_glu",
        norm="rmsnorm",
        rope_theta=10000.0,
        tie_embeddings=True,
        emb_scale=True,
    ),
)
