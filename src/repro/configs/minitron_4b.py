"""minitron-4b [dense] -- pruned nemotron [arXiv:2407.14679; hf].

32L d_model=3072 24H (GQA kv=8) head_dim=128 d_ff=9216 vocab=256000,
squared-ReLU MLP (nemotron family), RMSNorm, untied.
"""
from .base import ModelConfig
from .registry import ArchSpec

ARCH = ArchSpec(
    config=ModelConfig(
        name="minitron-4b",
        family="dense",
        num_layers=32,
        d_model=3072,
        num_heads=24,
        num_kv_heads=8,
        head_dim=128,
        d_ff=9216,
        vocab_size=256000,
        pattern=("attn",),
        mlp_act="relu2",
        norm="rmsnorm",
        rope_theta=10000.0,
        tie_embeddings=False,
    ),
    fsdp=True,
)
