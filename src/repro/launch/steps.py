"""Step builders + abstract state for lowering on the production mesh.

One place defines, per (arch x shape x mesh):
  * the step function   (train_step / prefill_step / serve_step)
  * abstract inputs     (ShapeDtypeStructs -- no allocation)
  * in/out shardings    (logical rules -> NamedShardings)

Used by dryrun.py (lower+compile, deliverable e), the roofline pass
(deliverable g) and the real train/serve launchers.
"""
from __future__ import annotations

import contextlib
import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..configs.inputs import batch_specs, decode_specs
from ..configs.registry import ArchSpec
from ..models import decode as decode_lib
from ..models import sharding as shard_lib
from ..models import transformer
from ..models.layers import InitCtx
from ..train import optim


@dataclasses.dataclass
class Lowerable:
    """Everything needed to call jit(...).lower(*args)."""
    fn: Any
    args: Tuple
    in_shardings: Tuple
    donate_argnums: Tuple[int, ...] = ()
    name: str = ""
    rules: Any = None


def rules_for(arch: ArchSpec, mesh: Mesh) -> Dict[str, Any]:
    multi_pod = "pod" in mesh.axis_names
    return shard_lib.make_rules(
        fsdp=arch.fsdp, multi_pod=multi_pod,
        shard_experts=arch.shard_experts,
        fsdp_over_pod=arch.fsdp_over_pod,
        sp=arch.sp)


def abstract_params(cfg: ModelConfig):
    return transformer.init_model(cfg, abstract=True)


def abstract_opt_state(params):
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return optim.OptState(
        mu=jax.tree.map(f32, params),
        nu=jax.tree.map(f32, params),
        count=jax.ShapeDtypeStruct((), jnp.int32))


def train_lowerable(arch: ArchSpec, shape: ShapeConfig, mesh: Mesh,
                    scan: bool = False, remat: bool = True,
                    opt_cfg: Optional[optim.AdamWConfig] = None,
                    microbatches: Optional[int] = None) -> Lowerable:
    cfg = arch.config
    rules = rules_for(arch, mesh)
    params, specs = abstract_params(cfg)
    p_shard = shard_lib.param_shardings(specs, params, rules, mesh)
    opt_state = abstract_opt_state(params)
    o_shard = optim.OptState(mu=p_shard, nu=p_shard,
                             count=NamedSharding(mesh, P()))
    batch = batch_specs(cfg, shape)
    b_shard = shard_lib.batch_shardings(batch, rules, mesh)
    ocfg = opt_cfg or optim.AdamWConfig()
    mb = arch.microbatches if microbatches is None else microbatches

    def train_step(params, opt_state, batch):
        def loss(p, b):
            return transformer.loss_fn(cfg, p, b, scan=scan, remat=remat)
        if mb > 1:
            # unrolled gradient accumulation (python loop, NOT lax.scan:
            # HLO cost analysis must count every microbatch; the grad
            # add-chain serialises microbatches so activation buffers are
            # reused; grad sync collectives still fire once per microbatch
            # -- the deferred-sync variant is a §Perf iteration)
            n = shape.global_batch // mb
            grads, metrics = None, None
            for i in range(mb):
                b_i = jax.tree.map(lambda x: x[i * n:(i + 1) * n], batch)
                (_, metrics), g = jax.value_and_grad(
                    loss, has_aux=True)(params, b_i)
                grads = g if grads is None else \
                    jax.tree.map(jnp.add, grads, g)
            grads = jax.tree.map(lambda g: g / mb, grads)
        else:
            (_, metrics), grads = jax.value_and_grad(
                loss, has_aux=True)(params, batch)
        params, opt_state, om = optim.update(ocfg, grads, opt_state, params)
        return params, opt_state, {**metrics, **om}

    return Lowerable(
        fn=train_step,
        args=(params, opt_state, batch),
        in_shardings=(p_shard, o_shard, b_shard),
        donate_argnums=(0, 1),
        name=f"train:{cfg.name}:{shape.name}",
        rules=rules)


def prefill_lowerable(arch: ArchSpec, shape: ShapeConfig, mesh: Mesh,
                      scan: bool = False) -> Lowerable:
    cfg = arch.config
    rules = rules_for(arch, mesh)
    params, specs = abstract_params(cfg)
    p_shard = shard_lib.param_shardings(specs, params, rules, mesh)
    batch = batch_specs(cfg, shape)
    b_shard = shard_lib.batch_shardings(batch, rules, mesh)

    def prefill_step(params, batch):
        logits, _, hidden, _ = transformer.forward(
            cfg, params, batch, scan=scan, remat=False,
            last_logits_only=True)
        return logits[:, 0, :], hidden[:, -1, :]

    return Lowerable(
        fn=prefill_step,
        args=(params, batch),
        in_shardings=(p_shard, b_shard),
        name=f"prefill:{cfg.name}:{shape.name}",
        rules=rules)


def decode_lowerable(arch: ArchSpec, shape: ShapeConfig, mesh: Mesh,
                     scan: bool = False) -> Lowerable:
    cfg = arch.config
    rules = dict(rules_for(arch, mesh), gather_fsdp=False)
    params, specs = abstract_params(cfg)
    p_shard = shard_lib.param_shardings(specs, params, rules, mesh)
    dspec = decode_specs(cfg, shape)
    c_shard = shard_lib.cache_shardings(dspec["cache"], rules, mesh, cfg)
    dp = rules["batch"]
    import math as _math
    dp_size = _math.prod(dict(zip(mesh.axis_names,
                                  mesh.devices.shape))[a] for a in dp)
    b = shape.global_batch
    t_spec = (dp if len(dp) > 1 else dp[0]) if b % dp_size == 0 and \
        b >= dp_size else None
    t_shard = NamedSharding(mesh, P(t_spec, None))
    pos_shard = NamedSharding(mesh, P())

    def serve_step(params, cache, token, pos):
        logits, hidden, new_cache = decode_lib.decode_step(
            cfg, params, cache, token, pos, scan=scan)
        return logits, hidden, new_cache

    return Lowerable(
        fn=serve_step,
        args=(params, dspec["cache"], dspec["token"], dspec["pos"]),
        in_shardings=(p_shard, c_shard, t_shard, pos_shard),
        donate_argnums=(1,),
        name=f"decode:{cfg.name}:{shape.name}",
        rules=rules)


def build(arch: ArchSpec, shape: ShapeConfig, mesh: Mesh,
          scan: bool = False, exact_attn: bool = False) -> Lowerable:
    if shape.kind == "train":
        lw = train_lowerable(arch, shape, mesh, scan=scan)
    elif shape.kind == "prefill":
        lw = prefill_lowerable(arch, shape, mesh, scan=scan)
    else:
        lw = decode_lowerable(arch, shape, mesh, scan=scan)
    if exact_attn:
        lw.rules = dict(lw.rules, attn_exact=True)
    return lw


def lower(lw: Lowerable, mesh: Mesh):
    ctx = shard_lib.activation_sharding(mesh, lw.rules) if lw.rules \
        else contextlib.nullcontext()
    with mesh, ctx:
        jitted = jax.jit(lw.fn, in_shardings=lw.in_shardings,
                         donate_argnums=lw.donate_argnums)
        return jitted.lower(*lw.args)
