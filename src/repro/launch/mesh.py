"""Production mesh definitions.

Defined as functions (not module constants) so importing this module never
touches jax device state -- the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax
init, and smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(shape=None, axes=("data", "model")):
    """Mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    if shape is None:
        shape = (1, n)
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
