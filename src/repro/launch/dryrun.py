import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
        --shape train_4k --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --micronn

Per cell: prints compiled.memory_analysis() (proves it fits) and
cost_analysis() (FLOPs/bytes for the roofline), parses the HLO collective
schedule, and appends a JSON record to --out (default
results/dryrun.json). Skip rules (long_500k on full-attention archs) are
recorded as explicit skip rows.
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from ..configs import SHAPES, arch_names, get_arch, shape_applicable
from . import costs, steps
from .mesh import make_production_mesh


def _depth_arch(arch, j: int):
    """Same arch at j period-repeats of depth (+ the unrolled tail, which
    belongs to the intercept), for cost slope fitting."""
    cfg = arch.config
    period = len(cfg.stack_period)
    tail = len(cfg.tail_kinds)
    enc_per = cfg.encoder_layers // cfg.stack_count if cfg.encoder_layers \
        else 0
    return dataclasses.replace(
        arch, config=dataclasses.replace(
            cfg, num_layers=j * period + tail,
            encoder_layers=j * enc_per,
            scan_layers=False))


def _compile_cell(arch, shape, mesh, scan: bool, exact_attn: bool = False):
    lw = steps.build(arch, shape, mesh, scan=scan, exact_attn=exact_attn)
    lowered = steps.lower(lw, mesh)
    return lowered.compile()


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             scan: bool = None, verbose: bool = True) -> dict:
    """Lower+compile one cell and extract exact roofline terms.

    XLA counts while-bodies once, so the scanned-stack compile (the real
    runnable artifact: memory analysis, collective schedule) is paired
    with depth-1 and depth-2 *unrolled* compiles; the per-period slope
    (U2 - U1) recovers exact totals:  total = U1 + (count-1)*(U2-U1).
    Archs whose stack_count == 1 compile fully unrolled (already exact).
    """
    arch = get_arch(arch_name)
    cfg = arch.config
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    rec = {
        "arch": arch_name, "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "n_chips": n_chips, "kind": shape.kind,
    }
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=why)
        if verbose:
            print(f"[skip] {arch_name} x {shape_name}: {why}")
        return rec
    count = cfg.stack_count
    use_scan = count > 1 if scan is None else scan
    try:
        t0 = time.time()
        compiled = _compile_cell(arch, shape, mesh, scan=use_scan)
        t1 = time.time()
        mem = costs.memory_dict(compiled)
        corr = costs.slstm_correction(cfg, shape, n_chips)
        if use_scan and count > 1:
            u1 = costs.extract(_compile_cell(_depth_arch(arch, 1), shape,
                                             mesh, scan=False,
                                             exact_attn=True))
            u2 = costs.extract(_compile_cell(_depth_arch(arch, 2), shape,
                                             mesh, scan=False,
                                             exact_attn=True))
            terms = costs.RooflineTerms(
                flops=u1.flops + (count - 1) * (u2.flops - u1.flops),
                bytes_accessed=u1.bytes_accessed + (count - 1) *
                (u2.bytes_accessed - u1.bytes_accessed),
                coll_bytes=u1.coll_bytes + (count - 1) *
                (u2.coll_bytes - u1.coll_bytes),
                coll_breakdown={
                    k: int(u1.coll_breakdown[k] + (count - 1) *
                           (u2.coll_breakdown[k] - u1.coll_breakdown[k]))
                    for k in u1.coll_breakdown},
                flops_correction=corr)
            rec["cost_method"] = "scan+slope(U1,U2)"
        else:
            terms = costs.extract(compiled, flops_correction=corr)
            rec["cost_method"] = "unrolled-exact"
        t2 = time.time()
        mf = costs.model_flops(cfg, shape, n_chips)
        total_flops = terms.flops + terms.flops_correction
        rec.update(
            status="ok",
            compile_s=round(t1 - t0, 2), slope_s=round(t2 - t1, 2),
            memory=mem,
            roofline=terms.as_dict(),
            model_flops=mf,
            useful_flops_ratio=(mf / total_flops) if total_flops else 0.0,
            hbm_ok=bool(mem["peak_bytes_est"] < 16e9),
        )
        if verbose:
            print(f"[ok] {arch_name} x {shape_name} mesh={rec['mesh']}  "
                  f"compile={rec['compile_s']}s"
                  f" (+{rec['slope_s']}s slope, {rec['cost_method']})")
            print(f"     memory/device: args={mem['argument_bytes']/1e9:.2f}G"
                  f" temp={mem['temp_bytes']/1e9:.2f}G"
                  f" peak~{mem['peak_bytes_est']/1e9:.2f}G"
                  f" (<16G: {rec['hbm_ok']})")
            r = rec["roofline"]
            print(f"     roofline/device: compute={r['t_compute_s']*1e3:.2f}ms"
                  f" memory={r['t_memory_s']*1e3:.2f}ms"
                  f" collective={r['t_collective_s']*1e3:.2f}ms"
                  f" -> {r['bottleneck']}-bound;"
                  f" useful={rec['useful_flops_ratio']:.2f}")
    except Exception as e:  # lowering/compile failures are system bugs
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[ERR] {arch_name} x {shape_name}: {rec['error']}")
    return rec


def run_micronn(multi_pod: bool, verbose: bool = True,
                optimized: bool = False) -> dict:
    """Dry-run the paper's own workload: distributed ANN search over a
    pod-sharded IVF index (1.05M x 512d, batch 4096 queries MQO).

    optimized=True applies the §Perf hillclimb variant: bf16 vector
    storage + expected-load probe cap (16 vs worst-case 64)."""
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from ..core.types import DeltaStore, IVFConfig, IVFIndex
    from ..distributed.sharded_index import distributed_search, \
        index_shardings
    from .mesh import data_axes

    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {"arch": "micronn-search" + ("-opt" if optimized else ""),
           "shape": "batch4096",
           "mesh": "x".join(map(str, mesh.devices.shape)),
           "n_chips": mesh.devices.size, "kind": "search"}
    try:
        dim, k_parts, p_max, dcap, n_attr = 512, 8192, 128, 8192, 0
        Q, topk, n_probe = 4096, 100, 64
        vdt = jnp.bfloat16 if optimized else jnp.float32
        local_cap = 16 if optimized else None
        cfg = IVFConfig(dim=dim, delta_capacity=dcap)
        sds = lambda s, d=jnp.float32: jax.ShapeDtypeStruct(s, d)
        index = IVFIndex(
            centroids=sds((k_parts, dim)), csizes=sds((k_parts,)),
            vectors=sds((k_parts, p_max, dim), vdt),
            ids=sds((k_parts, p_max), jnp.int32),
            attrs=sds((k_parts, p_max, n_attr), vdt),
            valid=sds((k_parts, p_max), jnp.bool_),
            counts=sds((k_parts,), jnp.int32),
            delta=DeltaStore(
                vectors=sds((dcap, dim), vdt), ids=sds((dcap,), jnp.int32),
                attrs=sds((dcap, n_attr), vdt),
                valid=sds((dcap,), jnp.bool_),
                count=sds((), jnp.int32)),
            base_mean_size=sds(()),
            config=cfg)
        queries = sds((Q, dim))
        dax = data_axes(mesh)
        idx_shard = index_shardings(index, mesh)
        q_shard = NamedSharding(mesh, P(dax if len(dax) > 1 else dax[0],
                                        None))

        def search_step(index, queries):
            res = distributed_search(index, queries, topk, n_probe, mesh,
                                     data_axes=dax, local_cap=local_cap)
            return res.ids, res.scores

        t0 = time.time()
        with mesh:
            lowered = jax.jit(
                search_step,
                in_shardings=(idx_shard, q_shard)).lower(index, queries)
            compiled = lowered.compile()
        t1 = time.time()
        terms = costs.extract(compiled)
        mem = costs.memory_dict(compiled)
        rec.update(status="ok", compile_s=round(t1 - t0, 2), memory=mem,
                   roofline=terms.as_dict(),
                   hbm_ok=bool(mem["peak_bytes_est"] < 16e9))
        if verbose:
            r = rec["roofline"]
            print(f"[ok] micronn-search mesh={rec['mesh']}"
                  f" compile={rec['compile_s']}s peak~"
                  f"{mem['peak_bytes_est']/1e9:.2f}G ->"
                  f" {r['bottleneck']}-bound")
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[ERR] micronn-search: {rec['error']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--micronn", action="store_true")
    ap.add_argument("--scan", action="store_const", const=True, default=None,
                    help="force scanned stacks (default: auto per arch)")
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args()

    pods = {"single": [False], "multi": [True],
            "both": [False, True]}[args.mesh]

    def save(rec):
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        existing = []
        if os.path.exists(args.out):
            with open(args.out) as f:
                existing = json.load(f)
        key = lambda r: (r["arch"], r["shape"], r["mesh"])
        keep = [r for r in existing if key(r) != key(rec)]
        with open(args.out, "w") as f:
            json.dump(keep + [rec], f, indent=1)

    records = []
    if args.micronn or args.all:
        for mp in pods:
            records.append(run_micronn(mp))
            save(records[-1])
            records.append(run_micronn(mp, optimized=True))
            save(records[-1])
    if args.all or args.arch:
        archs = arch_names() if args.all else [args.arch]
        shapes = list(SHAPES) if args.shape is None else [args.shape]
        for a in archs:
            for s in shapes:
                for mp in pods:
                    records.append(run_cell(a, s, mp, scan=args.scan))
                    save(records[-1])
    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skipped" for r in records)
    n_err = sum(r["status"] == "error" for r in records)
    print(f"\n== dry-run: {n_ok} ok, {n_skip} skipped, {n_err} errors"
          f" -> {args.out}")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
