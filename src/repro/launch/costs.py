"""Roofline-term extraction from compiled dry-run artifacts.

Per (arch x shape x mesh):
  compute term    = HLO_FLOPs / peak_FLOPs            (197 TF/s bf16/chip)
  memory term     = HLO_bytes / HBM_bw                (819 GB/s/chip)
  collective term = collective_bytes / link_bw        (~50 GB/s/link ICI)

`cost_analysis()` on an SPMD-partitioned module is already per-device.
Collective bytes are NOT in cost_analysis: we parse the compiled HLO and
sum operand bytes of all-gather / all-reduce / reduce-scatter / all-to-all
/ collective-permute ops (all-reduce counts 2x: reduce-scatter +
all-gather phases of a ring).

Known caveat handled here: XLA counts `while`-loop bodies ONCE. The
dry-run therefore unrolls layer stacks (exact); the one remaining
sequential scan (sLSTM over time) gets an analytic body x trip-count
correction reported separately.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# TPU v5e-class hardware constants (assignment-specified)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link (per-direction)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result bytes per collective kind from compiled HLO text."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # result = <shape> <op>(...)  e.g. %ar = f32[8,128]{1,0} all-reduce(
        # (shapes may carry {layout} suffixes; tuples may nest them)
        m = re.match(r"^%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],]+(?:\{[\d,]*\})?)"
                     r"\s+([a-z\-]+)", s)
        if not m:
            continue
        op = m.group(2)
        if op.rstrip("-start").rstrip("-done") in _COLLECTIVES:
            op = op.replace("-start", "").replace("-done", "")
        if op not in _COLLECTIVES:
            continue
        if "-done" in s.split("=")[1][:64]:
            continue
        nbytes = _shape_bytes(m.group(1))
        mult = 2 if op == "all-reduce" else 1   # ring RS + AG phases
        out[op] += nbytes * mult
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float                  # per-device
    bytes_accessed: float         # per-device HBM traffic
    coll_bytes: float             # per-device collective payload
    coll_breakdown: Dict[str, int]
    flops_correction: float = 0.0  # analytic scan-body corrections

    @property
    def t_compute(self) -> float:
        return (self.flops + self.flops_correction) / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "flops_correction": self.flops_correction,
            "bytes_accessed": self.bytes_accessed,
            "coll_bytes": self.coll_bytes,
            "coll_breakdown": self.coll_breakdown,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
        }


def extract(compiled, flops_correction: float = 0.0) -> RooflineTerms:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):   # jax 0.4.x returns [dict]
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    return RooflineTerms(
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        coll_bytes=float(sum(coll.values())),
        coll_breakdown=coll,
        flops_correction=flops_correction,
    )


def memory_dict(compiled) -> dict:
    ma = compiled.memory_analysis()
    return {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "generated_code_bytes": int(ma.generated_code_size_in_bytes),
        # donated inputs alias outputs, so live = max(args, outputs) + temps
        "peak_bytes_est": int(max(ma.argument_size_in_bytes,
                                  ma.output_size_in_bytes)
                              + ma.temp_size_in_bytes),
    }


def model_flops(cfg, shape, n_chips: int) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) per *device* per step.

    Train counts fwd+bwd (6ND); prefill counts forward only (2ND);
    decode counts one token (2*N_active per sequence)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens / n_chips
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens / n_chips
    return 2.0 * n_active * shape.global_batch / n_chips


def slstm_correction(cfg, shape, n_chips: int) -> float:
    """Analytic FLOPs of sequential sLSTM scan bodies x trip count."""
    from ..models.xlstm import slstm_analytic_flops
    n_slstm = sum(1 for k in cfg.layer_kinds() if k == "slstm")
    if n_slstm == 0:
        return 0.0
    if shape.kind == "decode":
        seq = 1
    else:
        seq = shape.seq_len
    per_layer = slstm_analytic_flops(shape.global_batch, seq, cfg.d_model,
                                     cfg.num_heads)
    mult = 3.0 if shape.kind == "train" else 1.0   # fwd+bwd
    return mult * n_slstm * per_layer / n_chips
