"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
        --steps 20 --ckpt-dir /tmp/ckpt

On real hardware the same entrypoint builds the production mesh and
shards params/optimizer with the arch's rules; on this CPU container use
--smoke (reduced config, host mesh) -- examples/train_lm.py drives a
longer end-to-end run with learnable synthetic data.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from ..configs import get_arch
from ..configs.smoke import smoke_config
from ..data.tokens import TokenStream
from ..models import init_model
from ..train import Trainer, TrainerConfig, optim


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--scan", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    cfg = smoke_config(arch.config) if args.smoke else arch.config
    if args.smoke:
        cfg = dataclasses.replace(cfg, scan_layers=args.scan)

    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M")

    tcfg = TrainerConfig(
        opt=optim.AdamWConfig(lr=args.lr, warmup_steps=10,
                              total_steps=args.steps),
        microbatches=args.microbatches,
        checkpoint_every=max(10, args.steps // 4),
        ckpt_dir=args.ckpt_dir)
    trainer = Trainer(cfg, tcfg)

    stream = TokenStream(vocab=cfg.vocab_size, batch=args.batch,
                         seq=args.seq)

    def data(start):
        import jax.numpy as jnp
        for b in stream.iter_from(start):
            yield {"tokens": jnp.asarray(b["tokens"])}

    params, _ = trainer.fit(params, data, args.steps)
    first = trainer.history[0]["loss"] if trainer.history else float("nan")
    last = trainer.history[-1]["loss"] if trainer.history else float("nan")
    print(f"loss {first:.4f} -> {last:.4f} over {len(trainer.history)} steps"
          f" (stragglers flagged: {trainer.straggler.flagged})")


if __name__ == "__main__":
    main()
