"""Serving launcher: batched decode with optional MicroNN RAG.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --requests 6 --rag
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_arch
from ..configs.smoke import smoke_config
from ..core import ivf
from ..core.rag import RagConfig, RagDatastore
from ..core.types import IVFConfig
from ..models import init_model
from ..serving import Request, ServeEngine


def build_rag_datastore(cfg, n: int = 2048, seed: int = 1) -> RagDatastore:
    rng = np.random.default_rng(seed)
    vecs = rng.normal(size=(n, cfg.d_model)).astype(np.float32)
    index = ivf.build_index(vecs, cfg=IVFConfig(
        dim=cfg.d_model, target_partition_size=64, kmeans_iters=20,
        delta_capacity=256))
    next_tok = jnp.asarray(rng.integers(0, cfg.vocab_size, n + 1),
                           jnp.int32)
    return RagDatastore(index=index, next_token=next_tok)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--rag", action="store_true")
    args = ap.parse_args()

    cfg = smoke_config(get_arch(args.arch).config)
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    rag = build_rag_datastore(cfg) if args.rag else None
    eng = ServeEngine(cfg, params, slots=args.slots, s_max=64, rag=rag,
                      rag_cfg=RagConfig(k=8, n_probe=4, lam=0.3))

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=list(map(int, rng.integers(1, 64, 5))),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    for r in reqs:
        eng.submit(r)
    steps = 0
    while (eng.queue or any(s is not None for s in eng.active)) \
            and steps < 200:
        eng.step()
        steps += 1
    for r in reqs:
        print(f"req {r.uid}: prompt={r.prompt} -> out={r.out}"
              f" done={r.done}")
    print(f"served {len(reqs)} requests in {steps} engine steps"
          f" ({args.slots} slots, continuous batching"
          f"{', RAG' if args.rag else ''})")


if __name__ == "__main__":
    main()
