"""Checkpoint/restore for distributed training state (fault tolerance).

Design goals for 1000+ nodes:
  * atomic: write to <dir>.tmp, fsync, rename -- a crashed save never
    corrupts the previous checkpoint (generation counter picks the newest
    complete manifest);
  * elastic: arrays are saved *unsharded by logical leaf* (host-gathered);
    restore re-shards onto whatever mesh is live, so a job can come back
    on a different device count / topology;
  * self-describing: manifest.json carries step, leaf paths, shapes,
    dtypes; restore validates before touching device memory.

On a real multi-host pod each host would write only its addressable
shards (same manifest protocol, per-host files); on this single-process
container the gather is a no-op. The protocol -- not the I/O topology --
is what the tests pin down.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _leaf_paths(tree) -> Dict[str, Any]:
    # tree_util spelling: jax.tree.flatten_with_path only exists on newer jax
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out


def save_checkpoint(ckpt_dir: str, step: int, tree, extra: Optional[dict] = None):
    tmp = f"{ckpt_dir}/step_{step}.tmp"
    final = f"{ckpt_dir}/step_{step}"
    os.makedirs(tmp, exist_ok=True)
    leaves = _leaf_paths(tree)
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    for key, leaf in leaves.items():
        arr = np.asarray(jax.device_get(leaf))
        dtype = str(arr.dtype)
        if dtype == "bfloat16":   # numpy can't round-trip bf16; view u16
            arr = arr.view(np.uint16)
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": dtype}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp") and \
                os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, template, step: Optional[int] = None,
                       shardings=None) -> Tuple[Any, int, dict]:
    """Restore into the structure of `template` (re-sharding if shardings
    given -- elastic restart onto a different mesh)."""
    step = latest_step(ckpt_dir) if step is None else step
    assert step is not None, f"no checkpoint in {ckpt_dir}"
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    leaves = _leaf_paths(template)
    shard_leaves = _leaf_paths(shardings) if shardings is not None else {}
    out = {}
    for key, tmpl in leaves.items():
        info = manifest["leaves"].get(key)
        assert info is not None, f"checkpoint missing leaf {key}"
        arr = np.load(os.path.join(path, info["file"]))
        if info["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        assert tuple(arr.shape) == tuple(tmpl.shape), \
            f"{key}: {arr.shape} vs {tmpl.shape}"
        if key in shard_leaves:
            out[key] = jax.device_put(arr, shard_leaves[key])
        else:
            out[key] = jax.numpy.asarray(arr)
    flat, treedef = jax.tree.flatten(template)
    keys = list(_leaf_paths(template).keys())
    restored = jax.tree.unflatten(treedef, [out[k] for k in keys])
    return restored, manifest["step"], manifest.get("extra", {})
