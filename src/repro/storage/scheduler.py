"""Budgeted background maintenance scheduler (the paper's §3.6 made
incremental): drains the monitor's prioritized work queue in bounded work
quanta so no query or upsert ever pays for a rebuild.

Contract:

  * `step()` executes AT MOST ONE work item and touches at most
    `max_rows_per_step` rows -- the on-device interruptibility story: a
    foreground app can interleave queries between steps, and a step's
    wall time is bounded by its row quantum, not the collection size.
    Flush items are divisible (a partial flush moves the first
    `max_rows_per_step` live delta rows and leaves the rest searchable
    in the delta); split/merge/recluster items bound themselves at plan
    time (maintenance.neighborhood admits neighbour partitions only
    while the quantum has room). Items whose seed partition alone
    exceeds the quantum are deferred -- raise `max_rows_per_step` above
    the largest partition (>= split_threshold * target size; the default
    leaves generous headroom) to guarantee progress.
  * The queue is re-polled from the monitor before every step, so each
    step sees the post-previous-step state -- items never go stale.
  * Items that plan to a no-op (degenerate split, emptied partitions)
    are remembered and skipped until the index state changes them.

Durability ordering per step (both engine modes): quantized codes for
the touched rows persist first (byte-stable re-encode under the existing
quantizer), then the row moves + touched-centroid rewrites commit as ONE
SQLite transaction (VectorStore.apply_repair) -- a crash between the two
leaves the pre-repair clustering fully servable (codes are keyed by
asset id and identical under either state), which
tests/test_maintenance.py pins. Repair write I/O therefore scales with
the touched neighbourhood; the full generation swap remains the rebuild
path's mechanism.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass
class StepReport:
    """What one scheduler step did (surfaced by MicroNN.maintain_step)."""

    action: str               # "flush" | "split" | "merge" | "recluster"
    pids: Tuple[int, ...]     # partitions the step touched
    rows: int                 # rows the step processed (<= quantum)
    bytes_written: int        # durable write I/O of the step


class MaintenanceScheduler:
    """Drains `IndexMonitor.work_queue` against a MicroNN engine, one
    bounded quantum at a time. Owned by the engine (`engine.scheduler`);
    `MicroNN.maintain_step()` / `maintain(until_idle=True)` are the
    public entry points."""

    def __init__(self, engine, max_rows_per_step: int = 4096):
        assert max_rows_per_step >= 1, max_rows_per_step
        self.engine = engine
        self.max_rows_per_step = int(max_rows_per_step)
        # (action, pids, rows) triples that planned to a no-op within the
        # current run of fruitless polls; cleared whenever any step makes
        # progress, so changed row contents (or a remapped clustering
        # after rebuild/recover) can never be masked by a stale key
        self._skip: set = set()

    def pending(self) -> List:
        """The monitor's current prioritized queue (fresh every call)."""
        if self.engine.index is None:
            return []
        return self.engine.monitor.work_queue(self.engine.index)

    def step(self) -> Optional[StepReport]:
        """Execute the highest-priority actionable work item; None when
        the queue is idle (or nothing actionable fits the quantum)."""
        budget = self.max_rows_per_step
        for item in self.pending():
            key = (item.action, item.pids, item.rows)
            if key in self._skip:
                continue
            if item.action != "flush" and item.rows > budget:
                # indivisible neighbourhood larger than the quantum:
                # defer (see module contract)
                self._skip.add(key)
                continue
            report = self.engine._execute_work_item(item, budget)
            if report is None:
                self._skip.add(key)
                continue
            self._skip.clear()      # progress: stale no-op keys expire
            return report
        return None

    def drain(self, max_steps: Optional[int] = None) -> List[StepReport]:
        """Run steps until the queue is idle (maintain(until_idle=True)).
        `max_steps` is a runaway guard; the default scales with k."""
        out: List[StepReport] = []
        k = getattr(self.engine.index, "k", 1) if self.engine.index else 1
        limit = max_steps if max_steps is not None else 64 + 8 * k
        for _ in range(limit):
            r = self.step()
            if r is None:
                break
            out.append(r)
        return out
