"""Budgeted background maintenance scheduler (the paper's §3.6 made
incremental): drains the monitor's prioritized work queue in bounded work
quanta so no query or upsert ever pays for a rebuild.

Contract:

  * `step()` executes AT MOST ONE work item and touches at most
    `max_rows_per_step` rows -- the on-device interruptibility story: a
    foreground app can interleave queries between steps, and a step's
    wall time is bounded by its row quantum, not the collection size.
    Flush items are divisible (a partial flush moves the first
    `max_rows_per_step` live delta rows and leaves the rest searchable
    in the delta); split/merge/recluster items bound themselves at plan
    time (maintenance.neighborhood admits neighbour partitions only
    while the quantum has room). Items whose seed partition alone
    exceeds the quantum are deferred -- raise `max_rows_per_step` above
    the largest partition (>= split_threshold * target size; the default
    leaves generous headroom) to guarantee progress.
  * The queue is re-polled from the monitor before every step, so each
    step sees the post-previous-step state -- items never go stale.
  * Items that plan to a no-op (degenerate split, emptied partitions)
    are remembered and skipped until the index state changes them.

Durability ordering per step (both engine modes): quantized codes for
the touched rows persist first (byte-stable re-encode under the existing
quantizer), then the row moves + touched-centroid rewrites commit as ONE
SQLite transaction (VectorStore.apply_repair) -- a crash between the two
leaves the pre-repair clustering fully servable (codes are keyed by
asset id and identical under either state), which
tests/test_maintenance.py pins. Repair write I/O therefore scales with
the touched neighbourhood; the full generation swap remains the rebuild
path's mechanism.

Daemon mode (PR 7): `start_daemon()` promotes the scheduler from a
hand-cranked `maintain_step()` to a real background thread that drains
one bounded quantum at a time whenever the serving queue is idle. Every
step runs under the engine's write mutex (`MicroNN.lock`), so daemon
repairs serialize with sessions/upserts while reads keep executing
against consistent snapshots (resident queries hold an immutable index
pytree; paged queries go through the RLock'd PartitionCache with
deferred pinned-frame invalidation, and their SQLite reads ride the
store's WAL snapshot connection). The `idle` callable -- typically the
serving front door's queue-empty probe -- is advisory back-pressure:
the daemon yields to foreground traffic but still makes progress on a
saturated queue every `interval_s * _BUSY_BACKOFF` seconds, so
maintenance can be starved only briefly, never forever. Liveness +
progress surface through MicroNN.stats() (`daemon_alive`,
`daemon_steps`, `scheduler_depth`).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, List, Optional, Tuple

from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace


@dataclasses.dataclass
class StepReport:
    """What one scheduler step did (surfaced by MicroNN.maintain_step)."""

    action: str               # "flush" | "split" | "merge" | "recluster"
    pids: Tuple[int, ...]     # partitions the step touched
    rows: int                 # rows the step processed (<= quantum)
    bytes_written: int        # durable write I/O of the step


class MaintenanceScheduler:
    """Drains `IndexMonitor.work_queue` against a MicroNN engine, one
    bounded quantum at a time. Owned by the engine (`engine.scheduler`);
    `MicroNN.maintain_step()` / `maintain(until_idle=True)` are the
    public entry points."""

    # idle-queue wait multiplier: with nothing to do the daemon sleeps
    # interval_s * _IDLE_BACKOFF between polls (woken early by kick())
    _IDLE_BACKOFF = 8
    # busy-queue starvation bound: after this many consecutive yields to
    # foreground traffic the daemon takes one quantum anyway
    _BUSY_BACKOFF = 64

    _ACTIONS = ("flush", "split", "merge", "repack", "recluster")

    def __init__(self, engine, max_rows_per_step: int = 4096,
                 metrics=None):
        assert max_rows_per_step >= 1, max_rows_per_step
        self.engine = engine
        self.max_rows_per_step = int(max_rows_per_step)
        # registry telemetry (PR 8): closes the scheduler's observability
        # gap -- it used to expose only a queue-depth probe. The engine
        # passes a sub-scope of its own labels; a standalone scheduler
        # registers under a fresh instance label.
        if metrics is None:
            metrics = obs_metrics.default_registry().scope(
                component="scheduler",
                inst=str(obs_metrics.next_instance()))
        self.metrics = metrics
        self._c_wakeups = metrics.counter("wakeups")
        self._c_idle_probes = metrics.counter("idle_probes")
        self._c_busy_backoffs = metrics.counter("busy_backoffs")
        self._c_steps = metrics.counter("steps")
        self._c_noops = metrics.counter("noops")
        self._c_rows_moved = metrics.counter("rows_moved")
        self._c_bytes_written = metrics.counter("bytes_written")
        self._c_actions = {a: metrics.counter("action_steps", action=a)
                           for a in self._ACTIONS}
        # (action, pids, rows) triples that planned to a no-op within the
        # current run of fruitless polls; cleared whenever any step makes
        # progress, so changed row contents (or a remapped clustering
        # after rebuild/recover) can never be masked by a stale key
        self._skip: set = set()
        # -- daemon state ----------------------------------------------------
        self._daemon: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._wake = threading.Event()
        self._idle_fn: Optional[Callable[[], bool]] = None
        self._interval_s = 0.002
        self.daemon_steps = 0          # quanta the daemon has executed
        self.daemon_errors = 0         # exceptions swallowed by the loop
        self.last_daemon_error: Optional[BaseException] = None

    def pending(self) -> List:
        """The monitor's current prioritized queue (fresh every call)."""
        if self.engine.index is None:
            return []
        return self.engine.monitor.work_queue(self.engine.index)

    def queue_depth(self) -> int:
        """Number of pending maintenance work items (stats probe)."""
        return len(self.pending())

    def _emit(self, kind: str, *, action: str = "", pids=(), rows: int = 0,
              bytes_written: int = 0, dur_ms: float = 0.0, error: str = "",
              daemon: bool = False):
        """Append a structured MaintEvent to the engine's trace ring (the
        maintenance event log); no-op without a ring or with tracing
        globally disabled."""
        ring = getattr(self.engine, "traces", None)
        if ring is None or not obs_trace.enabled():
            return
        ring.append(obs_trace.MaintEvent(
            kind=kind, action=action, pids=tuple(int(p) for p in pids),
            rows=int(rows), bytes_written=int(bytes_written),
            dur_ms=dur_ms, error=error, daemon=daemon))

    def step(self, *, daemon: bool = False) -> Optional[StepReport]:
        """Execute the highest-priority actionable work item; None when
        the queue is idle (or nothing actionable fits the quantum)."""
        budget = self.max_rows_per_step
        for item in self.pending():
            key = (item.action, item.pids, item.rows)
            if key in self._skip:
                continue
            if item.action != "flush" and item.rows > budget:
                # indivisible neighbourhood larger than the quantum:
                # defer (see module contract)
                self._skip.add(key)
                continue
            self._emit("planned", action=item.action, pids=item.pids,
                       rows=item.rows, daemon=daemon)
            t0 = time.perf_counter()
            if daemon:
                # count BEFORE the item commits: an observer that polls
                # queue_depth() without the engine lock and sees the
                # post-step index (queue drained) must also see the step
                # counted -- rolled back below on noop/error
                self.daemon_steps += 1
            try:
                report = self.engine._execute_work_item(item, budget)
            except BaseException:
                if daemon:
                    self.daemon_steps -= 1
                raise
            if report is None:
                if daemon:
                    self.daemon_steps -= 1
                self._skip.add(key)
                self._c_noops.inc()
                self._emit("noop", action=item.action, pids=item.pids,
                           daemon=daemon)
                continue
            self._skip.clear()      # progress: stale no-op keys expire
            self._c_steps.inc()
            counter = self._c_actions.get(report.action)
            if counter is not None:
                counter.inc()
            self._c_rows_moved.inc(report.rows)
            self._c_bytes_written.inc(report.bytes_written)
            self._emit("step", action=report.action, pids=report.pids,
                       rows=report.rows, bytes_written=report.bytes_written,
                       dur_ms=(time.perf_counter() - t0) * 1e3,
                       daemon=daemon)
            return report
        return None

    def stats(self) -> dict:
        """The scheduler's registry-backed telemetry (surfaced through
        MicroNN.stats()['scheduler'])."""
        return {"wakeups": self._c_wakeups.value,
                "idle_probes": self._c_idle_probes.value,
                "busy_backoffs": self._c_busy_backoffs.value,
                "steps": self._c_steps.value,
                "noops": self._c_noops.value,
                "rows_moved": self._c_rows_moved.value,
                "bytes_written": self._c_bytes_written.value,
                "daemon_errors": self.daemon_errors,
                "actions": {a: c.value
                            for a, c in self._c_actions.items()}}

    def drain(self, max_steps: Optional[int] = None) -> List[StepReport]:
        """Run steps until the queue is idle (maintain(until_idle=True)).
        `max_steps` is a runaway guard; the default scales with k."""
        out: List[StepReport] = []
        k = getattr(self.engine.index, "k", 1) if self.engine.index else 1
        limit = max_steps if max_steps is not None else 64 + 8 * k
        for _ in range(limit):
            r = self.step()
            if r is None:
                break
            out.append(r)
        return out

    # -- daemon thread (PR 7) -------------------------------------------------
    @property
    def daemon_alive(self) -> bool:
        return self._daemon is not None and self._daemon.is_alive()

    def start_daemon(self, idle: Optional[Callable[[], bool]] = None,
                     interval_s: float = 0.002):
        """Promote the scheduler to a background daemon thread.

        `idle` is an advisory back-pressure probe (return False while
        foreground requests are queued -- the serving front door passes
        its queue-empty check); `interval_s` is the poll cadence. Each
        quantum runs under `engine.lock`, so daemon repairs serialize
        with every other writer. Idempotent while alive."""
        if self.daemon_alive:
            return
        self._idle_fn = idle
        self._interval_s = float(interval_s)
        self._stop.clear()
        self._wake.clear()
        self._daemon = threading.Thread(
            target=self._daemon_loop, name="micronn-maintenance",
            daemon=True)
        self._daemon.start()

    def stop_daemon(self, timeout: Optional[float] = 10.0):
        """Stop the daemon and join it (no-op when not running). The
        in-flight quantum, if any, completes -- a step is never killed
        halfway through its durability ordering."""
        if self._daemon is None:
            return
        self._stop.set()
        self._wake.set()
        self._daemon.join(timeout)
        assert not self._daemon.is_alive(), \
            "maintenance daemon failed to stop within timeout"
        self._daemon = None

    def kick(self):
        """Wake the daemon early (a writer just enqueued likely work, or
        the serving queue went idle)."""
        self._wake.set()

    def _daemon_loop(self):
        """while alive: when the serving queue is idle (or foreground
        pressure has persisted past the starvation bound), take the
        engine write mutex and drain ONE bounded quantum; back off when
        the work queue is empty. Exceptions are recorded and swallowed
        -- a failed repair plan must not kill maintenance forever."""
        yielded = 0
        while not self._stop.is_set():
            self._c_wakeups.inc()
            if self.engine.index is None:
                self._wake.wait(self._interval_s * self._IDLE_BACKOFF)
                self._wake.clear()
                continue
            busy = self._idle_fn is not None and not self._idle_fn()
            if busy and yielded < self._BUSY_BACKOFF:
                yielded += 1
                self._c_busy_backoffs.inc()
                self._wake.wait(self._interval_s)
                self._wake.clear()
                continue
            yielded = 0
            report = None
            try:
                with self.engine.lock:
                    if not self._stop.is_set():
                        # step(daemon=True) counts daemon_steps itself,
                        # before the item's index swap becomes visible
                        report = self.step(daemon=True)
            except BaseException as e:  # noqa: BLE001 -- daemon must live
                self.daemon_errors += 1
                self.last_daemon_error = e
                self._emit("daemon_error", error=repr(e), daemon=True)
            if report is None:
                # queue idle (or errored): poll again after a beat,
                # woken early by kick()
                self._c_idle_probes.inc()
                self._wake.wait(self._interval_s * self._IDLE_BACKOFF)
                self._wake.clear()
