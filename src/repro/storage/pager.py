"""Disk-resident partition pager: a memory-budgeted buffer pool of
partition frames (the paper's "~10 MB resident at million scale" made
literal -- cf. Faiss's on-disk inverted lists).

Frame layout. The pool is a fixed set of F frames preallocated up front
from the byte budget; each frame seats one partition in the same padded
layout the resident tier uses:

    payload  [F, p_max, d]   int8 codes (quantized index) or f32 vectors
    ids      [F, p_max]      asset ids, INVALID_ID marks padding
    valid    [F, p_max]      live-row mask
    attrs    [F, p_max, a]   optional, for fused attribute predicates

so the existing fused-scan kernels run over the pool unchanged: the
scalar-prefetched `part_ids` input simply carries *frame* indices instead
of partition indices (the frame -> partition indirection lives in the
pool's host-side frame table). F = budget_bytes // frame_bytes; the
pool never grows, so resident bytes are <= the budget by construction.

PR 9 splits ownership: the pool mechanics -- preallocated frames, CLOCK
eviction, scan-resistant admission ring, pins, read-ahead staging, the
donated batched scatter -- live in `fleet.pool.FramePool`, keyed by
`(tenant, pid)` so MANY engines can share ONE pool under one global
budget (fleet mode). `PartitionCache` here is the per-tenant VIEW an
engine holds: it owns the tenant-specific fetch path (its VectorStore,
metric normalisation, quantizer stats) and the tenant's cumulative
counters, and delegates frames/eviction/pins to the pool. A solo engine
(no fleet) constructs a private single-tenant pool, so its behavior --
eviction order, hit/miss accounting, donation rules, budget errors --
is exactly the PR 6 pager's (pinned by tests/test_pager.py).

Eviction is CLOCK (second chance): a fault sweeps the hand past pinned
frames and frames whose reference bit is set (clearing it), and reclaims
the first cold unpinned frame. Frames are *pinned* for the duration of a
scan chunk (fault() pins, the executor unpins after the scan), so a
concurrent fault can never steal a frame mid-scan; faulting more
partitions than the pool seats raises, which is what forces the
executor's streaming chunked scan.

Admission policy (scan resistance): `fault(pids, admit=False)` marks a
one-off stream -- a paged *exact* search reads every partition exactly
once, and admitting that stream would flush the hot ANN working set.
Non-admitted faults cycle through a small reusable *scan ring* of at
most `scan_frames` frames (a fraction of the pool; same byte budget),
never touching admitted frames' reference bits; ring frames are the
preferred eviction victims for admitted traffic, and a later admitted
hit on a ring frame promotes it out of the ring. Probes already resident
still hit (and stay hot), so a full scan reuses the warm set for free.

Fault path: all missing partitions of a probe set are fetched in ONE SQL
round-trip (VectorStore.scan_partitions -- the clustered primary key
makes each partition a sequential range read) and scattered into the
pool in one batched device write.

Invalidation contract: any write that changes a partition's durable rows
(delta flush into it, a split/merge moving rows, upsert/delete of one of
its rows, a rebuild) must call invalidate(pids) / invalidate_all(); the
next fault re-reads the partition from SQLite. Invalidating a partition
whose frame is pinned by an in-flight scan defers the release to the
last unpin -- the scan keeps its (pre-invalidation snapshot) frame, and
the mapping is dropped immediately so the next fault refetches. Counters
(hits / misses / evictions) are cumulative and surface through
MicroNN.stats().

Thread safety: every public method takes the POOL's RLock, so the
background maintenance scheduler (storage/scheduler.py), query threads,
and -- in fleet mode -- every co-tenant engine may interleave
fault/invalidate/unpin safely. Scans themselves run outside the lock:
pinned frames cannot be evicted, and the pool arrays are functionally
rebound -- a scan always reads a consistent snapshot.

Fault scatter: when no scan (of ANY tenant) holds pins, the batched
fault scatters fetched frames into the pool through a jitted donated
update (`donate_argnums`) -- XLA aliases the output to the input buffer
and updates the touched frames in place, so a fault never allocates a
second pool-sized buffer (asserted by tests/test_pager.py via the
compiled memory analysis). With foreign pins outstanding the fault
falls back to a copying scatter: donation would invalidate the buffer a
concurrent scan may still be reading.

Read-ahead staging (PR 6 double-buffering): `stage(pids)` runs the SQL
round-trip + host-side block packing for a future chunk WITHOUT taking
frames, pins, or rebinding any pool -- the processed per-partition
blocks land in a host-side staging dict that the next fault() consumes
under the lock, paying only the frame scatter. The executor's paged
loop submits stage(chunk N+1) to a worker thread while the fused scan
chews on chunk N, overlapping the disk latency with compute at
UNCHANGED chunking (so results are trivially bit-identical with
staging off). Staging is purely advisory: entries are dropped by
invalidate()/resize() (a generation counter discards in-flight stages
that raced a writer), fault() falls back to SQLite for anything not
staged, and the buffer holds at most one scan chunk of host blocks --
the classic double-buffer cost, bounded by scan_frames * frame_bytes.
"""
from __future__ import annotations

import time
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from ..core import quantize
from ..core.types import normalize_if_cosine
from ..fleet.pool import (FramePool, _scatter_frames, _scatter_one,  # noqa: F401 -- re-exported; tests compile _scatter_frames directly
                          compute_frame_bytes)
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace


class PartitionCache:
    """Per-tenant view over a FramePool of partition frames.

    Solo mode (pool=None): constructs a private single-tenant pool from
    `budget_bytes` -- the PR 6 pager, verbatim. Fleet mode: pass the
    shared `pool` and a stable `tenant` name; frames then compete under
    the fleet-wide budget via the pool's global CLOCK, and
    `budget_bytes` reflects the POOL's (fleet) budget."""

    def __init__(self, store, *, p_max: int, budget_bytes: int,
                 payload: str = "f32", metric: str = "l2",
                 qstats=None, with_attrs: bool = False,
                 metrics=None, pool: Optional[FramePool] = None,
                 tenant: Optional[str] = None):
        assert payload in ("f32", "int8"), payload
        if payload == "int8":
            assert qstats is not None, "int8 frames need quantizer stats"
        self.store = store
        self.metric = metric
        self.payload = payload
        self.qstats = qstats
        self.with_attrs = bool(with_attrs and store.n_attr)
        # counters live in the process metrics registry (PR 8). The engine
        # passes its own scope so counts survive re-attachment (the scope's
        # get-or-create hands back the SAME counter objects); standalone
        # caches get a fresh uniquely-labeled scope, so they start at zero.
        if metrics is None:
            metrics = obs_metrics.default_registry().scope(
                component="pager", inst=str(obs_metrics.next_instance()))
        self._metrics = metrics
        self._c_hits = metrics.counter("hits")
        self._c_misses = metrics.counter("misses")
        self._c_evictions = metrics.counter("evictions")
        self._c_bytes_read = metrics.counter("bytes_read")
        self._c_bytes_staged = metrics.counter("bytes_staged")
        self._c_staged_consumed = metrics.counter("staged_consumed")
        # per-fault work breakdown, for the active trace's fault span:
        # (hits, misses, staged frames consumed, bytes synchronously read)
        self._last_fault = (0, 0, 0, 0)
        self._private_pool = pool is None
        if pool is None:
            pool = FramePool(
                dim=store.dim, p_max=p_max, budget_bytes=budget_bytes,
                payload=payload,
                n_attr=store.n_attr if self.with_attrs else 0)
            tenant = "solo" if tenant is None else tenant
        else:
            assert tenant is not None, \
                "a shared FramePool view needs a stable tenant name"
        self._pool = pool
        self.tenant = str(tenant)
        self._tid = pool.register(self, self.tenant, p_max=p_max)

    # -- cumulative counters (registry-backed; plain ints out) ---------------
    @property
    def hits(self) -> int:
        return self._c_hits.value

    @hits.setter
    def hits(self, v: int):
        self._c_hits.set(int(v))

    @property
    def misses(self) -> int:
        return self._c_misses.value

    @misses.setter
    def misses(self, v: int):
        self._c_misses.set(int(v))

    @property
    def evictions(self) -> int:
        return self._c_evictions.value

    @evictions.setter
    def evictions(self, v: int):
        self._c_evictions.set(int(v))

    # -- pool geometry (delegated) -------------------------------------------
    compute_frame_bytes = staticmethod(compute_frame_bytes)

    @property
    def pool(self) -> FramePool:
        return self._pool

    @property
    def budget_bytes(self) -> int:
        return self._pool.budget_bytes

    @property
    def p_max(self) -> int:
        return self._pool.p_max

    @property
    def frame_bytes(self) -> int:
        return self._pool.frame_bytes

    @property
    def capacity(self) -> int:
        return self._pool.capacity

    @property
    def scan_frames(self) -> int:
        return self._pool.scan_frames

    @property
    def payload_pool(self):
        return self._pool.payload_pool

    @property
    def ids_pool(self):
        return self._pool.ids_pool

    @property
    def valid_pool(self):
        return self._pool.valid_pool

    @property
    def attrs_pool(self):
        return self._pool.attrs_pool

    @property
    def resident_bytes(self) -> int:
        return self._pool.resident_bytes

    # -- frame-table views (tests + introspection; pool holds the truth) ----
    @property
    def _lock(self):
        return self._pool._lock

    @property
    def _pid_frame(self) -> dict:
        return self._pool.tenant_frames(self._tid)

    @property
    def _staged(self) -> dict:
        return self._pool.tenant_staged(self._tid)

    @property
    def _frame_pid(self) -> np.ndarray:
        return self._pool._frame_pid

    @property
    def _pins(self) -> np.ndarray:
        return self._pool._pins

    @property
    def _ref(self) -> np.ndarray:
        return self._pool._ref

    @property
    def _stale(self) -> np.ndarray:
        return self._pool._stale

    @property
    def _transient(self) -> np.ndarray:
        return self._pool._transient

    @property
    def _ring(self) -> list:
        return self._pool._ring

    def resize(self, p_max: int):
        """Reallocate the pool for a larger partition size (after a flush
        or merge grows some partition past p_max). Drops every frame --
        the caller already invalidated the moved partitions -- but keeps
        the cumulative counters and the byte budget. A SHARED pool only
        ever grows: co-tenants' partitions may still need the current
        p_max."""
        if not self._private_pool:
            p_max = max(int(p_max), self._pool.p_max)
        self._pool.resize(p_max)

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "bytes_read": self._c_bytes_read.value,
                "bytes_staged": self._c_bytes_staged.value,
                "staged_consumed": self._c_staged_consumed.value,
                "resident_bytes": self.resident_bytes,
                "budget_bytes": self.budget_bytes,
                "capacity_frames": self.capacity,
                "frame_bytes": self.frame_bytes,
                "resident_partitions":
                    self._pool.resident_count(self._tid)}

    # -- fetch ---------------------------------------------------------------
    def _fetch_blocks(self, pids: Sequence[int]):
        """One batched SQL round-trip for the listed partitions, packed to
        pool layout on the host: (payload, ids, valid, attrs) numpy blocks
        of shape [len(pids), p_max, ...] (attrs is None without an attrs
        pool). int8 pools skip the f32 blobs entirely -- the fetch moves
        4x fewer bytes off disk (the point of the code tier) -- and
        backfill the rare code-less row from the f32 tier with the same
        deterministic encode the build used. Pure read: no pool, frame
        table, or counter is touched, so stage() may run it off-lock."""
        sq = self.payload == "int8"
        blocks = self.store.scan_partitions(
            list(pids), self.p_max,
            with_codes=sq, with_attrs=self.with_attrs, with_vecs=not sq)
        if sq:
            codes = blocks.codes
            stale = blocks.valid & ~blocks.code_ok
            if stale.any():
                # rare: rows without a durable code (written by a
                # pre-quantized engine) -- backfill just those rows
                # from the f32 tier and re-encode deterministically
                rows, _ = self.store.vectors_for(blocks.ids[stale])
                rows = np.asarray(normalize_if_cosine(
                    jnp.asarray(rows, jnp.float32), self.metric))
                codes[stale] = quantize.encode_np(self.qstats, rows)
            payload = codes
        else:
            payload = np.asarray(normalize_if_cosine(
                jnp.asarray(blocks.vecs, jnp.float32), self.metric))
        attrs = blocks.attrs if self.with_attrs else None
        return payload, blocks.ids, blocks.valid, attrs

    def stage(self, pids: Sequence[int]):
        """Read ahead: fetch + pack the listed partitions' blocks into the
        pool's host-side staging dict so the next fault() skips its SQL
        round trip. Takes no frames and no pins -- safe on a prefetch
        thread concurrently with any tenant's scan. Advisory only: a
        concurrent invalidate() bumps the generation and the whole
        in-flight stage is discarded (the next fault re-reads)."""
        self._pool.stage(self._tid, pids)

    # -- fault / pin / invalidate -------------------------------------------
    def fault(self, pids: Sequence[int], admit: bool = True) -> np.ndarray:
        """Ensure every listed partition is resident; returns the frame
        index per pid (aligned to input order), with each frame PINNED --
        the caller must unpin() after its scan. All missing partitions are
        fetched in one batched SQL round-trip.

        `admit=False` flags a one-off stream (paged exact scan): misses
        land in the reusable scan ring instead of the admitted set, and
        hits do not touch reference bits -- so the stream cannot evict or
        artificially refresh the hot working set."""
        tr = obs_trace.current()
        if tr is None:
            return self._pool.fault(self._tid, pids, admit)
        t0 = time.perf_counter()
        with self._pool._lock:
            frames = self._pool.fault(self._tid, pids, admit)
            h, m, st, nb = self._last_fault
        tr.record(obs_trace.STAGE_FAULT,
                  (time.perf_counter() - t0) * 1e3,
                  hits=h, misses=m, staged=st, bytes_read=nb,
                  admitted=bool(admit))
        return frames

    def unpin(self, frames: np.ndarray):
        self._pool.unpin(frames)

    def invalidate(self, pids: Sequence[int]):
        """Drop the listed partitions' frames (durable rows changed); the
        next fault re-reads them from SQLite. A frame pinned by an
        in-flight scan is released lazily at its last unpin -- the scan
        keeps its pre-invalidation snapshot, the mapping is gone at once."""
        self._pool.invalidate(self._tid, pids)

    def invalidate_all(self):
        self._pool.invalidate_tenant(self._tid)
