"""Disk-resident partition pager: a memory-budgeted buffer pool of
partition frames (the paper's "~10 MB resident at million scale" made
literal -- cf. Faiss's on-disk inverted lists).

Frame layout. The pool is a fixed set of F frames preallocated up front
from the byte budget; each frame seats one partition in the same padded
layout the resident tier uses:

    payload  [F, p_max, d]   int8 codes (quantized index) or f32 vectors
    ids      [F, p_max]      asset ids, INVALID_ID marks padding
    valid    [F, p_max]      live-row mask
    attrs    [F, p_max, a]   optional, for fused attribute predicates

so the existing fused-scan kernels run over the pool unchanged: the
scalar-prefetched `part_ids` input simply carries *frame* indices instead
of partition indices (the frame -> partition indirection lives in this
module's host-side frame table). F = budget_bytes // frame_bytes; the
pool never grows, so resident bytes are <= the budget by construction.

Eviction is CLOCK (second chance): a fault sweeps the hand past pinned
frames and frames whose reference bit is set (clearing it), and reclaims
the first cold unpinned frame. Frames are *pinned* for the duration of a
scan chunk (fault() pins, the executor unpins after the scan), so a
concurrent fault can never steal a frame mid-scan; faulting more
partitions than the pool seats raises, which is what forces the
executor's streaming chunked scan.

Admission policy (scan resistance): `fault(pids, admit=False)` marks a
one-off stream -- a paged *exact* search reads every partition exactly
once, and admitting that stream would flush the hot ANN working set.
Non-admitted faults cycle through a small reusable *scan ring* of at
most `scan_frames` frames (a fraction of the pool; same byte budget),
never touching admitted frames' reference bits; ring frames are the
preferred eviction victims for admitted traffic, and a later admitted
hit on a ring frame promotes it out of the ring. Probes already resident
still hit (and stay hot), so a full scan reuses the warm set for free.

Fault path: all missing partitions of a probe set are fetched in ONE SQL
round-trip (VectorStore.scan_partitions -- the clustered primary key
makes each partition a sequential range read) and scattered into the
pool in one batched device write.

Invalidation contract: any write that changes a partition's durable rows
(delta flush into it, a split/merge moving rows, upsert/delete of one of
its rows, a rebuild) must call invalidate(pids) / invalidate_all(); the
next fault re-reads the partition from SQLite. Invalidating a partition
whose frame is pinned by an in-flight scan defers the release to the
last unpin -- the scan keeps its (pre-invalidation snapshot) frame, and
the mapping is dropped immediately so the next fault refetches. Counters
(hits / misses / evictions) are cumulative and surface through
MicroNN.stats().

Thread safety: every public method takes the cache's RLock, so the
background maintenance scheduler (storage/scheduler.py) and query
threads may interleave fault/invalidate/unpin safely (closing the PR 3
"single-writer/single-reader" restriction). Scans themselves run outside
the lock: pinned frames cannot be evicted, and the pool arrays are
functionally rebound -- a scan always reads a consistent snapshot.

Fault scatter: when no *other* scan holds pins, the batched fault
scatters fetched frames into the pool through a jitted donated update
(`donate_argnums`) -- XLA aliases the output to the input buffer and
updates the touched frames in place, so a fault never allocates a second
pool-sized buffer (asserted by tests/test_pager.py via the compiled
memory analysis). With foreign pins outstanding the fault falls back to
a copying scatter: donation would invalidate the buffer a concurrent
scan may still be reading.

Read-ahead staging (PR 6 double-buffering): `stage(pids)` runs the SQL
round-trip + host-side block packing for a future chunk WITHOUT taking
frames, pins, or rebinding any pool -- the processed per-partition
blocks land in a host-side staging dict that the next fault() consumes
under the lock, paying only the frame scatter. The executor's paged
loop submits stage(chunk N+1) to a worker thread while the fused scan
chews on chunk N, overlapping the disk latency with compute at
UNCHANGED chunking (so results are trivially bit-identical with
staging off). Staging is purely advisory: entries are dropped by
invalidate()/resize() (a generation counter discards in-flight stages
that raced a writer), fault() falls back to SQLite for anything not
staged, and the buffer holds at most one scan chunk of host blocks --
the classic double-buffer cost, bounded by scan_frames * frame_bytes.
"""
from __future__ import annotations

import threading
import time
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core import quantize
from ..core.types import INVALID_ID, normalize_if_cosine
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace


@partial(jax.jit, donate_argnums=(0, 1, 2))
def _scatter_frames(payload_pool, ids_pool, valid_pool, fidx, payload,
                    ids, valid):
    """Donated in-place scatter of freshly fetched frames into the pool:
    the three pool buffers are aliased input->output, so the update costs
    O(fetched frames) writes, not a pool-sized copy."""
    return (payload_pool.at[fidx].set(payload),
            ids_pool.at[fidx].set(ids),
            valid_pool.at[fidx].set(valid))


@partial(jax.jit, donate_argnums=(0,))
def _scatter_one(pool, fidx, block):
    """Donated single-pool scatter (the optional attrs pool)."""
    return pool.at[fidx].set(block)


class PartitionCache:
    """Memory-budgeted buffer pool of partition frames over a VectorStore."""

    def __init__(self, store, *, p_max: int, budget_bytes: int,
                 payload: str = "f32", metric: str = "l2",
                 qstats=None, with_attrs: bool = False,
                 metrics=None):
        assert payload in ("f32", "int8"), payload
        if payload == "int8":
            assert qstats is not None, "int8 frames need quantizer stats"
        self.store = store
        self.metric = metric
        self.payload = payload
        self.qstats = qstats
        self.with_attrs = bool(with_attrs and store.n_attr)
        self.budget_bytes = int(budget_bytes)
        # counters live in the process metrics registry (PR 8). The engine
        # passes its own scope so counts survive re-attachment (the scope's
        # get-or-create hands back the SAME counter objects); standalone
        # caches get a fresh uniquely-labeled scope, so they start at zero.
        if metrics is None:
            metrics = obs_metrics.default_registry().scope(
                component="pager", inst=str(obs_metrics.next_instance()))
        self._metrics = metrics
        self._c_hits = metrics.counter("hits")
        self._c_misses = metrics.counter("misses")
        self._c_evictions = metrics.counter("evictions")
        self._c_bytes_read = metrics.counter("bytes_read")
        self._c_bytes_staged = metrics.counter("bytes_staged")
        self._c_staged_consumed = metrics.counter("staged_consumed")
        # per-fault work breakdown, for the active trace's fault span:
        # (hits, misses, staged frames consumed, bytes synchronously read)
        self._last_fault = (0, 0, 0, 0)
        # guards every public method: the maintenance scheduler and query
        # threads may interleave fault/evict/invalidate (PR 5)
        self._lock = threading.RLock()
        self._alloc(p_max)

    # -- cumulative counters (registry-backed; plain ints out) ---------------
    @property
    def hits(self) -> int:
        return self._c_hits.value

    @hits.setter
    def hits(self, v: int):
        self._c_hits.set(int(v))

    @property
    def misses(self) -> int:
        return self._c_misses.value

    @misses.setter
    def misses(self, v: int):
        self._c_misses.set(int(v))

    @property
    def evictions(self) -> int:
        return self._c_evictions.value

    @evictions.setter
    def evictions(self, v: int):
        self._c_evictions.set(int(v))

    # -- pool allocation ----------------------------------------------------
    @staticmethod
    def compute_frame_bytes(p_max: int, dim: int, payload: str = "f32",
                            n_attr: int = 0) -> int:
        """Bytes one partition frame costs: payload + ids + valid + attrs."""
        per_row = (1 if payload == "int8" else 4) * dim + 4 + 1 + 4 * n_attr
        return p_max * per_row

    def _alloc(self, p_max: int):
        store = self.store
        d = store.dim
        n_attr = store.n_attr if self.with_attrs else 0
        # validate before mutating any state: a failed resize must leave
        # the cache fully usable at its old geometry
        frame_bytes = self.compute_frame_bytes(p_max, d, self.payload,
                                               n_attr)
        cap = self.budget_bytes // frame_bytes
        if cap < 1:
            raise ValueError(
                f"memory budget {self.budget_bytes}B cannot seat one "
                f"partition frame ({frame_bytes}B at p_max={p_max})")
        self.p_max = int(p_max)
        self.frame_bytes = frame_bytes
        self.capacity = int(cap)
        shape = (self.capacity, self.p_max, d)
        if self.payload == "int8":
            self.payload_pool = jnp.zeros(shape, jnp.int8)
        else:
            self.payload_pool = jnp.zeros(shape, jnp.float32)
        self.ids_pool = jnp.full((self.capacity, self.p_max), INVALID_ID,
                                 jnp.int32)
        self.valid_pool = jnp.zeros((self.capacity, self.p_max), bool)
        self.attrs_pool = (
            jnp.zeros((self.capacity, self.p_max, n_attr), jnp.float32)
            if self.with_attrs else None)
        # host-side frame table (the frame -> partition indirection)
        self._frame_pid = np.full(self.capacity, -1, np.int64)
        self._pid_frame: dict = {}
        self._ref = np.zeros(self.capacity, bool)
        self._pins = np.zeros(self.capacity, np.int64)
        # invalidated-while-pinned frames: freed at the last unpin
        self._stale = np.zeros(self.capacity, bool)
        self._hand = 0
        # scan-resistant admission: ring of frames owned by non-admitted
        # (one-off stream) faults; scan_frames bounds how much of the
        # pool a full scan may dirty
        self.scan_frames = max(1, self.capacity // 4)
        self._transient = np.zeros(self.capacity, bool)
        self._ring: list = []
        self._ring_hand = 0
        # read-ahead staging (PR 6): pid -> (payload, ids, valid, attrs)
        # host blocks prefetched by stage(); the generation counter lets
        # invalidate()/resize() discard stages still in flight
        self._staged: dict = {}
        self._stage_gen = getattr(self, "_stage_gen", 0) + 1

    def resize(self, p_max: int):
        """Reallocate the pool for a larger partition size (after a flush
        or merge grows some partition past p_max). Drops every frame --
        the caller already invalidated the moved partitions -- but keeps
        the cumulative counters and the byte budget. Waits for in-flight
        scans to unpin first: _alloc rebuilds the pin table (and may
        shrink the frame count), so reallocating under a live pin would
        corrupt a concurrent scan's unpin bookkeeping."""
        deadline = time.monotonic() + 30.0
        while True:
            with self._lock:
                if not self._pins.any():
                    self._alloc(p_max)
                    return
            if time.monotonic() > deadline:
                raise RuntimeError(
                    "resize timed out waiting for pinned frames -- a scan "
                    "leaked a pin (missing unpin())")
            time.sleep(0.001)

    # -- budget accounting ---------------------------------------------------
    @property
    def resident_bytes(self) -> int:
        pools = [self.payload_pool, self.ids_pool, self.valid_pool]
        if self.attrs_pool is not None:
            pools.append(self.attrs_pool)
        return int(sum(p.nbytes for p in pools))

    def stats(self) -> dict:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions,
                    "bytes_read": self._c_bytes_read.value,
                    "bytes_staged": self._c_bytes_staged.value,
                    "staged_consumed": self._c_staged_consumed.value,
                    "resident_bytes": self.resident_bytes,
                    "budget_bytes": self.budget_bytes,
                    "capacity_frames": self.capacity,
                    "frame_bytes": self.frame_bytes,
                    "resident_partitions": len(self._pid_frame)}

    # -- clock eviction ------------------------------------------------------
    def _release_ring(self, f: int):
        """Remove a frame from the scan ring (promotion or reclaim)."""
        self._transient[f] = False
        if f in self._ring:
            self._ring.remove(f)
            self._ring_hand = 0

    def _clock_victim(self) -> int:
        """Second-chance sweep: skip pinned frames, clear reference bits,
        reclaim the first cold unpinned frame (transient scan-ring frames
        carry no reference bit, so they fall out first)."""
        for _ in range(3 * self.capacity):
            f = self._hand
            self._hand = (self._hand + 1) % self.capacity
            if self._pins[f] > 0:
                continue
            if self._ref[f] and not self._transient[f]:
                self._ref[f] = False
                continue
            if self._transient[f]:
                self._release_ring(f)
            return f
        raise RuntimeError(
            "all cache frames pinned -- probe chunk exceeds pool capacity")

    def _victim(self) -> int:
        """Victim for an *admitted* fault: scan-ring frames first (a
        one-off stream must never force out hot admitted frames), then
        the CLOCK sweep."""
        for f in self._ring:
            if self._pins[f] == 0:
                self._release_ring(f)
                return f
        return self._clock_victim()

    def _scan_victim(self) -> int:
        """Victim for a NON-admitted (scan-resistant) fault: reuse ring
        frames round-robin; grow the ring (via the normal sweep) only up
        to scan_frames."""
        for _ in range(len(self._ring)):
            f = self._ring[self._ring_hand % len(self._ring)]
            self._ring_hand += 1
            if self._pins[f] == 0:
                return f
        if len(self._ring) < self.scan_frames:
            f = self._clock_victim()
            self._ring.append(f)
            self._transient[f] = True
            return f
        raise RuntimeError(
            "scan ring exhausted -- chunk a non-admitted scan to at most "
            f"scan_frames={self.scan_frames} missing partitions")

    # -- fetch / staging -----------------------------------------------------
    def _fetch_blocks(self, pids: Sequence[int]):
        """One batched SQL round-trip for the listed partitions, packed to
        pool layout on the host: (payload, ids, valid, attrs) numpy blocks
        of shape [len(pids), p_max, ...] (attrs is None without an attrs
        pool). int8 pools skip the f32 blobs entirely -- the fetch moves
        4x fewer bytes off disk (the point of the code tier) -- and
        backfill the rare code-less row from the f32 tier with the same
        deterministic encode the build used. Pure read: no pool, frame
        table, or counter is touched, so stage() may run it off-lock."""
        sq = self.payload == "int8"
        blocks = self.store.scan_partitions(
            list(pids), self.p_max,
            with_codes=sq, with_attrs=self.with_attrs, with_vecs=not sq)
        if sq:
            codes = blocks.codes
            stale = blocks.valid & ~blocks.code_ok
            if stale.any():
                # rare: rows without a durable code (written by a
                # pre-quantized engine) -- backfill just those rows
                # from the f32 tier and re-encode deterministically
                rows, _ = self.store.vectors_for(blocks.ids[stale])
                rows = np.asarray(normalize_if_cosine(
                    jnp.asarray(rows, jnp.float32), self.metric))
                codes[stale] = quantize.encode_np(self.qstats, rows)
            payload = codes
        else:
            payload = np.asarray(normalize_if_cosine(
                jnp.asarray(blocks.vecs, jnp.float32), self.metric))
        attrs = blocks.attrs if self.with_attrs else None
        return payload, blocks.ids, blocks.valid, attrs

    def stage(self, pids: Sequence[int]):
        """Read ahead: fetch + pack the listed partitions' blocks into the
        host-side staging dict so the next fault() skips its SQL round
        trip. Takes no frames and no pins, and never rebinds a pool --
        safe to run on a prefetch thread concurrently with a scan of the
        current chunk. Advisory only: a concurrent invalidate() bumps the
        generation and the whole in-flight stage is discarded (the next
        fault re-reads from SQLite)."""
        with self._lock:
            gen = self._stage_gen
            want = [int(p) for p in pids
                    if int(p) not in self._pid_frame
                    and int(p) not in self._staged]
        if not want:
            return
        payload, ids, valid, attrs = self._fetch_blocks(want)
        self._c_bytes_staged.inc(
            payload.nbytes + ids.nbytes + valid.nbytes +
            (0 if attrs is None else attrs.nbytes))
        with self._lock:
            if gen != self._stage_gen:
                return          # a writer invalidated mid-fetch: drop all
            # bound leftover entries (a scan that raised mid-stream never
            # consumes its staged chunk) -- the dict may never outgrow a
            # few chunks of host blocks
            if len(self._staged) > 2 * self.capacity:
                self._staged.clear()
            for i, p in enumerate(want):
                if p in self._pid_frame:    # faulted while we fetched
                    continue
                self._staged[p] = (payload[i], ids[i], valid[i],
                                   None if attrs is None else attrs[i])

    # -- fault / pin / invalidate -------------------------------------------
    def fault(self, pids: Sequence[int], admit: bool = True) -> np.ndarray:
        """Ensure every listed partition is resident; returns the frame
        index per pid (aligned to input order), with each frame PINNED --
        the caller must unpin() after its scan. All missing partitions are
        fetched in one batched SQL round-trip.

        `admit=False` flags a one-off stream (paged exact scan): misses
        land in the reusable scan ring instead of the admitted set, and
        hits do not touch reference bits -- so the stream cannot evict or
        artificially refresh the hot working set."""
        tr = obs_trace.current()
        if tr is None:
            with self._lock:
                return self._fault_locked(pids, admit)
        t0 = time.perf_counter()
        with self._lock:
            frames = self._fault_locked(pids, admit)
            h, m, st, nb = self._last_fault
        tr.record(obs_trace.STAGE_FAULT,
                  (time.perf_counter() - t0) * 1e3,
                  hits=h, misses=m, staged=st, bytes_read=nb,
                  admitted=bool(admit))
        return frames

    def _fault_locked(self, pids: Sequence[int], admit: bool) -> np.ndarray:
        # pins held by OTHER in-flight scans at entry decide whether the
        # scatter may donate the pool buffers (see module docstring)
        foreign_pins = int(self._pins.sum())
        want = [int(p) for p in pids]
        if len(want) > self.capacity:
            raise ValueError(
                f"probe set of {len(want)} partitions exceeds the pool's "
                f"{self.capacity} frames -- chunk the scan")
        frames = np.empty(len(want), np.int32)
        missing = []
        hit_frames = []
        for j, p in enumerate(want):
            f = self._pid_frame.get(p)
            if f is not None:
                if admit:
                    self._ref[f] = True
                    if self._transient[f]:
                        # an admitted hit proves the frame hot: promote
                        # it out of the scan ring into the admitted set
                        self._release_ring(f)
                self._pins[f] += 1
                frames[j] = f
                hit_frames.append(f)
            else:
                missing.append((j, p))
        if hit_frames:
            self._c_hits.inc(len(hit_frames))
        if not missing:
            self._last_fault = (len(hit_frames), 0, 0, 0)
            return frames
        new_frames = []
        n_evicted = 0
        for j, p in missing:
            f = self._victim() if admit else self._scan_victim()
            old = self._frame_pid[f]
            if old >= 0:
                del self._pid_frame[old]
                n_evicted += 1
            self._frame_pid[f] = p
            self._pid_frame[p] = f
            self._ref[f] = admit
            self._pins[f] += 1
            frames[j] = f
            new_frames.append(f)
        # counted BEFORE the fetch: a failed fetch still paid the miss
        # (and already evicted its victims) -- pinned by tests/test_pager
        self._c_misses.inc(len(missing))
        if n_evicted:
            self._c_evictions.inc(n_evicted)
        n_bytes = 0
        try:
            # consume staged read-ahead blocks first; anything not staged
            # is fetched in one batched SQL round-trip as before
            staged = {p: self._staged.pop(p)
                      for _, p in missing if p in self._staged}
            n_staged = len(staged)
            if n_staged:
                self._c_staged_consumed.inc(n_staged)
            fetch = [p for _, p in missing if p not in staged]
            if fetch:
                f_pay, f_ids, f_val, f_att = self._fetch_blocks(fetch)
                n_bytes = f_pay.nbytes + f_ids.nbytes + f_val.nbytes + \
                    (0 if f_att is None else f_att.nbytes)
                self._c_bytes_read.inc(n_bytes)
                for i, p in enumerate(fetch):
                    staged[p] = (f_pay[i], f_ids[i], f_val[i],
                                 None if f_att is None else f_att[i])
            order = [staged[p] for _, p in missing]
            payload = jnp.asarray(np.stack([e[0] for e in order]))
            bids = jnp.asarray(np.stack([e[1] for e in order]))
            bval = jnp.asarray(np.stack([e[2] for e in order]))
            battrs = None if self.attrs_pool is None else \
                jnp.asarray(np.stack([e[3] for e in order]))
            fidx = jnp.asarray(np.asarray(new_frames, np.int32))
            if foreign_pins == 0:
                # no concurrent scan can be reading the old pool objects:
                # donate them -- the scatter updates the buffers in place
                # instead of writing a second pool-sized copy
                self.payload_pool, self.ids_pool, self.valid_pool = \
                    _scatter_frames(self.payload_pool, self.ids_pool,
                                    self.valid_pool, fidx, payload,
                                    bids, bval)
                if self.attrs_pool is not None:
                    self.attrs_pool = _scatter_one(
                        self.attrs_pool, fidx, battrs)
            else:
                # a scan may still hold the old arrays: copy-on-write
                self.payload_pool = self.payload_pool.at[fidx].set(payload)
                self.ids_pool = self.ids_pool.at[fidx].set(bids)
                self.valid_pool = self.valid_pool.at[fidx].set(bval)
                if self.attrs_pool is not None:
                    self.attrs_pool = self.attrs_pool.at[fidx].set(battrs)
        except BaseException:
            # roll back the provisional registrations: the frames never
            # received data, so a later fault must not count them as hits
            # (and their pins must not leak until _victim starves); hit
            # pins are released too -- the caller gets no frames to unpin
            for (j, p), f in zip(missing, new_frames):
                self._pid_frame.pop(p, None)
                self._frame_pid[f] = -1
                self._ref[f] = False
                self._pins[f] -= 1
            for f in hit_frames:
                self._pins[f] -= 1
            raise
        self._last_fault = (len(hit_frames), len(missing), n_staged, n_bytes)
        return frames

    def _free_frame(self, f: int):
        self._frame_pid[f] = -1
        self._ref[f] = False
        self._stale[f] = False

    def unpin(self, frames: np.ndarray):
        with self._lock:
            for f in np.asarray(frames, np.int64):
                assert self._pins[f] > 0, f"frame {f} not pinned"
                self._pins[f] -= 1
                if self._pins[f] == 0 and self._stale[f]:
                    # invalidated while this scan was reading it: the
                    # deferred release happens at the last unpin
                    self._free_frame(f)

    def invalidate(self, pids: Sequence[int]):
        """Drop the listed partitions' frames (durable rows changed); the
        next fault re-reads them from SQLite. A frame pinned by an
        in-flight scan is released lazily at its last unpin -- the scan
        keeps its pre-invalidation snapshot, the mapping is gone at once."""
        with self._lock:
            # discard staged read-ahead for the changed partitions, and
            # bump the generation so an in-flight stage() that read them
            # mid-write drops its whole batch instead of inserting
            self._stage_gen += 1
            for p in pids:
                self._staged.pop(int(p), None)
                f = self._pid_frame.pop(int(p), None)
                if f is None:
                    continue
                if self._pins[f] > 0:
                    self._stale[f] = True
                    continue
                self._free_frame(f)

    def invalidate_all(self):
        with self._lock:
            self.invalidate(list(self._pid_frame))
