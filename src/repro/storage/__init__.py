from .store import VectorStore
from .engine import MicroNN
from . import checkpoint
