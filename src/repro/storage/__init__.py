from .store import VectorStore
from .engine import MicroNN
from .pager import PartitionCache
from . import checkpoint, pager
