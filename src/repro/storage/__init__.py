from .store import VectorStore
from .engine import MicroNN
from .pager import PartitionCache
from .scheduler import MaintenanceScheduler, StepReport
from . import checkpoint, pager, scheduler
