"""MicroNN: the embeddable engine facade (paper Fig. 1).

Ties together the durable SQLite tier, the device-resident IVF index, the
index monitor, and the hybrid query optimizer -- the public API an
application links against:

    eng = MicroNN(dim=128, n_attr=2)
    eng.upsert(ids, vecs, attrs)
    eng.build()                      # initial clustering
    res = eng.search(q, k=100, n_probe=8)
    res = eng.search(q, k=10, predicate=Pred(0, "eq", 3.0))
    eng.delete(ids)
    eng.maintain()                   # flush delta / rebuild as needed

Writes are serialised (single writer, paper §3.6); every write lands in
SQLite (durable, WAL) *and* in the device index (delta-store), so readers
see updates immediately while the host copy guarantees recoverability --
`MicroNN.recover()` rebuilds device state from SQLite after a crash.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import delta as delta_ops
from ..core import executor, ivf, maintenance
from ..core.hybrid import AttributeStats, Node, compile_filter
from ..core.monitor import IndexMonitor, MonitorConfig
from ..core.optimizer import HybridOptimizer
from ..core.types import IVFConfig, IVFIndex, SearchResult
from .store import VectorStore


class MicroNN:
    def __init__(self, dim: int, n_attr: int = 0, path: str = ":memory:",
                 config: Optional[IVFConfig] = None,
                 monitor: Optional[MonitorConfig] = None):
        self.store = VectorStore(path, dim=dim, n_attr=n_attr)
        self.config = config or IVFConfig(dim=dim)
        self.monitor = IndexMonitor(monitor)
        self.index: Optional[IVFIndex] = None
        self.optimizer: Optional[HybridOptimizer] = None
        self.maintenance_log = []

    # -- lifecycle -----------------------------------------------------------
    def build(self):
        """Initial clustering from the durable tier (mini-batch k-means
        streams from SQLite -- never the full dataset in memory)."""
        ids, _, vecs = self.store.all_rows()
        attrs = self.store.attributes_for(ids)
        self.index = ivf.build_index(
            vecs, ids.astype(np.int32), attrs, cfg=self.config)
        # persist the clustering back to the clustered table
        assign = self._current_assignment()
        self.store.set_partitions(ids, assign[ids], *self._centroid_state())
        self._refresh_stats()

    def recover(self):
        """Rebuild device state from SQLite after a crash/restart."""
        ids, parts, vecs = self.store.all_rows()
        attrs = self.store.attributes_for(ids)
        cents, csizes = self.store.centroids()
        if len(cents) == 0:
            if len(ids):
                self.index = None
            return
        live = parts >= 0
        packed = ivf.pack_partitions(
            vecs[live], ids[live].astype(np.int32), attrs[live],
            parts[live].astype(np.int64), len(cents),
            pad_to=self.config.pad_to)
        vec, vid, vat, val, counts = packed
        from ..core.types import DeltaStore
        idx = IVFIndex(
            centroids=jnp.asarray(cents), csizes=jnp.asarray(csizes),
            vectors=jnp.asarray(vec), ids=jnp.asarray(vid),
            attrs=jnp.asarray(vat), valid=jnp.asarray(val),
            counts=jnp.asarray(counts),
            delta=DeltaStore.empty(self.config.delta_capacity, self.store.dim,
                                   attrs.shape[1]),
            base_mean_size=jnp.asarray(max(counts.mean(), 1.0), jnp.float32),
            config=self.config)
        self.index = idx
        # replay delta rows (partition -1)
        if (~live).any():
            self.index = delta_ops.upsert(
                self.index, jnp.asarray(vecs[~live]),
                jnp.asarray(ids[~live].astype(np.int32)),
                jnp.asarray(attrs[~live]))
        self._refresh_stats()

    # -- writes ---------------------------------------------------------------
    def upsert(self, ids: np.ndarray, vecs: np.ndarray,
               attrs: Optional[np.ndarray] = None):
        n_attr = self.store.n_attr
        attrs = np.zeros((len(ids), n_attr), np.float32) if attrs is None \
            else attrs
        self.store.upsert(ids, vecs, attrs, partition_id=-1)
        if self.index is None:
            return
        if delta_ops.delta_free_slots(self.index) < len(ids):
            self.maintain(force="flush")
        self.index = delta_ops.upsert(
            self.index, jnp.asarray(vecs, jnp.float32),
            jnp.asarray(ids, jnp.int32), jnp.asarray(attrs, jnp.float32))

    def delete(self, ids: np.ndarray):
        self.store.delete(ids)
        if self.index is not None:
            self.index = delta_ops.delete(self.index,
                                          jnp.asarray(ids, jnp.int32))

    # -- maintenance ----------------------------------------------------------
    def maintain(self, force: Optional[str] = None) -> Optional[str]:
        if self.index is None:
            return None
        health = self.monitor.check(self.index)
        action = force or health.action
        if action == "flush":
            self.index, stats = maintenance.flush_delta(self.index)
            self.maintenance_log.append(stats)
            self.store.update_centroids(np.asarray(self.index.centroids),
                                        np.asarray(self.index.csizes))
            return "flush"
        if action == "rebuild":
            self.index, stats = maintenance.full_rebuild(self.index)
            self.maintenance_log.append(stats)
            ids, _, _ = self.store.all_rows()
            assign = self._current_assignment()
            self.store.set_partitions(
                ids, assign[ids], *self._centroid_state())
            self._refresh_stats()
            return "rebuild"
        return None

    # -- queries --------------------------------------------------------------
    def search(self, queries: np.ndarray, k: int = 100, n_probe: int = 8,
               predicate: Optional[Node] = None, exact: bool = False,
               batch_mqo: Optional[bool] = None,
               backend: Optional[str] = None) -> SearchResult:
        """Every path compiles to a QueryPlan run by core/executor.py's
        fused scan; the executor's query-count bucketing means a stream of
        variable-size batches compiles once per bucket, not per call.
        `batch_mqo` is kept for API compatibility -- a batched ANN plan
        *is* the MQO shared scan (same union + selection mask)."""
        assert self.index is not None, "build() or recover() first"
        del batch_mqo
        q = jnp.asarray(np.atleast_2d(queries), jnp.float32)
        if predicate is not None:
            res, _ = self.optimizer.execute(
                self.index, q, predicate, k, n_probe, backend=backend)
            return res
        if exact:
            return executor.search(self.index, q, k=k, kind="exact",
                                   backend=backend)
        return executor.search(self.index, q, k=k, kind="ann",
                               n_probe=n_probe, backend=backend)

    # -- helpers --------------------------------------------------------------
    def _refresh_stats(self):
        idx = self.index
        flat_attrs = np.asarray(idx.attrs).reshape(
            idx.k * idx.p_max, idx.n_attr)
        live = np.asarray(idx.valid).reshape(-1)
        self.optimizer = HybridOptimizer(AttributeStats(flat_attrs[live]))

    def _current_assignment(self) -> np.ndarray:
        idx = self.index
        vid = np.asarray(idx.ids)
        val = np.asarray(idx.valid)
        out = np.full(int(vid.max()) + 1 if vid.size else 1, -1, np.int64)
        for p in range(idx.k):
            rows = vid[p][val[p]]
            out[rows] = p
        return out

    def _centroid_state(self) -> Tuple[np.ndarray, np.ndarray]:
        return (np.asarray(self.index.centroids),
                np.asarray(self.index.csizes))
